//! Vendored offline subset of the `anyhow` API.
//!
//! The build image has no crates.io access, so this crate provides the
//! slice of `anyhow` the workspace actually uses: [`Error`], [`Result`],
//! and the [`anyhow!`], [`bail!`], [`ensure!`] macros. Error values carry
//! a message string plus an optional boxed source; `{}` and `{:#}`
//! formatting both render the full message chain.
//!
//! Deliberately *not* implemented (unused here): `Context`, downcasting,
//! backtraces.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error value, optionally wrapping a source error.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Build an error wrapping a concrete source error.
    pub fn new<E>(source: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error { msg: source.to_string(), source: Some(Box::new(source)) }
    }

    /// The root message (without the source chain).
    pub fn message(&self) -> &str {
        &self.msg
    }

    /// Iterate the `std::error::Error` source chain, if any.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn std::error::Error + 'static)> + '_ {
        let mut next = self
            .source
            .as_deref()
            .and_then(|e| e.source());
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for cause in self.chain() {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Matches anyhow's unwrap-friendly Debug: the message, then causes.
        write!(f, "{}", self.msg)?;
        let causes: Vec<String> = self.chain().map(|c| c.to_string()).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`.
// That keeps this blanket conversion coherent (no overlap with the
// reflexive `From<T> for T`), exactly as the real anyhow does.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(source: E) -> Self {
        Error::new(source)
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_num(s: &str) -> Result<i32> {
        let v: i32 = s.parse()?; // std error converts via `?`
        ensure!(v > 0, "expected positive, got {v}");
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_num("3").unwrap(), 3);
        let err = parse_num("abc").unwrap_err();
        assert!(err.to_string().contains("invalid digit"));
    }

    #[test]
    fn ensure_and_bail_format() {
        let err = parse_num("-2").unwrap_err();
        assert_eq!(err.to_string(), "expected positive, got -2");
        fn f() -> Result<()> {
            bail!("plain {}", "args");
        }
        assert_eq!(f().unwrap_err().to_string(), "plain args");
    }

    #[test]
    fn anyhow_macro_forms() {
        let x = 7;
        assert_eq!(anyhow!("inline {x}").to_string(), "inline 7");
        assert_eq!(anyhow!("a {} b", 1).to_string(), "a 1 b");
        let src = "q".parse::<i32>().unwrap_err();
        assert!(anyhow!(src).to_string().contains("invalid digit"));
    }

    #[test]
    fn display_alternate_is_stable() {
        let e = Error::msg("top");
        assert_eq!(format!("{e}"), "top");
        assert_eq!(format!("{e:#}"), "top");
    }
}
