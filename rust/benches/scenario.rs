//! Bench: A²CiD² vs the async baseline across a mid-run ring→exponential
//! switch with 20% link dropout (see `experiments::scenario`).
a2cid2::bench_main!(scenario);
