//! Perf bench — the §Perf deliverable's measurement harness.
//!
//! Measures the L3 hot paths against their practical rooflines:
//!   * fused gossip kernels (mix_grad / comm_apply_fused / mix_into) vs
//!     memcpy bandwidth;
//!   * the runtime pairing path: old mix→copy→apply composition vs the
//!     fused mix_into→comm_apply path, uncontended and under a gradient
//!     thread's contention;
//!   * chunk-pool scaling of the large-`dim` kernels vs single thread;
//!   * snapshot-read latency: published seqlock cell vs mutex lock+copy;
//!   * every hot kernel per explicit backend (scalar reference vs
//!     runtime-dispatched SIMD) against a same-size memcpy roofline;
//!   * memory locality: pooled kernels on pinned vs unpinned lanes over
//!     first-touch-placed buffers, a remote-touch counterfactual, and a
//!     per-NUMA-node memcpy roofline;
//!   * coordinator matching throughput: pairings/s, rendezvous vs
//!     batched strategy, at n = 16 / 64 / 256 workers;
//!   * simulator event throughput (events/s);
//!   * PJRT dispatch overhead for the standalone L1 kernel artifacts
//!     (needs `make artifacts`; skipped gracefully if missing).
//!
//! Alongside the printed table, every row is emitted machine-readable to
//! `BENCH_perf.json` (kernel, elements, ns/iter, GB/s) so future PRs have
//! a perf trajectory to diff against.
//!
//! `A2CID2_BENCH_FULL=1` raises iteration counts;
//! `A2CID2_BENCH_SMOKE=1` shrinks sizes and counts to a CI-sized smoke
//! run (seconds, not minutes) that still exercises every code path.

use std::io::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use a2cid2::gossip::{pool, vecops, Mixer};
use a2cid2::metrics::Table;
use a2cid2::runtime::SnapshotCell;
use a2cid2::util::two_mut;

/// Time `f` over `iters` iterations after `warmup`, returning seconds/iter.
fn time_it(warmup: usize, iters: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn gb_per_s(bytes_per_iter: usize, secs: f64) -> f64 {
    bytes_per_iter as f64 / secs / 1e9
}

/// Collects rows for the printed table AND the machine-readable JSON.
/// JSON rows carry a `kind` tag so trajectory tooling never mistakes a
/// derived ratio for a kernel timing: `kind: "kernel"` rows have
/// `ns_per_iter`/`gb_per_s`; `kind: "derived"` rows have `value` (the
/// ratio or rate shown in the table).
struct Bench {
    table: Table,
    json: Vec<String>,
}

impl Bench {
    fn new() -> Self {
        Self {
            table: Table::new(
                "Perf — L3 hot paths (R/W per element in 'notes')",
                &["kernel", "elements", "time/iter", "effective GB/s", "notes"],
            ),
            json: Vec::new(),
        }
    }

    /// One measured kernel: `secs` per iteration moving `bytes` per
    /// iteration.
    fn row(&mut self, kernel: &str, elements: usize, secs: f64, bytes: usize, notes: &str) {
        let gbs = gb_per_s(bytes, secs);
        let time = if secs >= 1e-4 {
            format!("{:.2} ms", secs * 1e3)
        } else {
            format!("{:.2} us", secs * 1e6)
        };
        self.table.row(&[
            kernel.into(),
            elements.to_string(),
            time,
            format!("{gbs:.1}"),
            notes.into(),
        ]);
        self.json.push(format!(
            "{{\"kernel\": \"{kernel}\", \"elements\": {elements}, \"kind\": \"kernel\", \
             \"ns_per_iter\": {:.1}, \"gb_per_s\": {gbs:.3}}}",
            secs * 1e9
        ));
    }

    /// One measured kernel pinned to an explicit [`vecops`] backend:
    /// labeled `kernel[backend]` in the table and carrying a `backend`
    /// field in the JSON so the CI perf gate diffs per-backend
    /// trajectories (rows without the field read back as backend "").
    fn backend_row(
        &mut self,
        kernel: &str,
        backend: &str,
        elements: usize,
        secs: f64,
        bytes: usize,
        notes: &str,
    ) {
        let gbs = gb_per_s(bytes, secs);
        let time = if secs >= 1e-4 {
            format!("{:.2} ms", secs * 1e3)
        } else {
            format!("{:.2} us", secs * 1e6)
        };
        self.table.row(&[
            format!("{kernel}[{backend}]"),
            elements.to_string(),
            time,
            format!("{gbs:.1}"),
            notes.into(),
        ]);
        self.json.push(format!(
            "{{\"kernel\": \"{kernel}\", \"backend\": \"{backend}\", \"elements\": {elements}, \
             \"kind\": \"kernel\", \"ns_per_iter\": {:.1}, \"gb_per_s\": {gbs:.3}}}",
            secs * 1e9
        ));
    }

    /// One measured kernel on an explicit (possibly pinned) pool:
    /// labeled `kernel[backend][pinned|unpinned]` in the table; the JSON
    /// row carries both `backend` and `pinned` fields so the CI perf
    /// gate tracks the pinned and unpinned trajectories separately.
    #[allow(clippy::too_many_arguments)]
    fn locality_row(
        &mut self,
        kernel: &str,
        backend: &str,
        pinned: bool,
        elements: usize,
        secs: f64,
        bytes: usize,
        notes: &str,
    ) {
        let gbs = gb_per_s(bytes, secs);
        let time = if secs >= 1e-4 {
            format!("{:.2} ms", secs * 1e3)
        } else {
            format!("{:.2} us", secs * 1e6)
        };
        let tag = if pinned { "pinned" } else { "unpinned" };
        self.table.row(&[
            format!("{kernel}[{backend}][{tag}]"),
            elements.to_string(),
            time,
            format!("{gbs:.1}"),
            notes.into(),
        ]);
        self.json.push(format!(
            "{{\"kernel\": \"{kernel}\", \"backend\": \"{backend}\", \"pinned\": {pinned}, \
             \"elements\": {elements}, \"kind\": \"kernel\", \"ns_per_iter\": {:.1}, \
             \"gb_per_s\": {gbs:.3}}}",
            secs * 1e9
        ));
    }

    /// A derived / informational row: `secs` is the representative time
    /// shown in the table, `display` the table's value column, and
    /// `value` the numeric form recorded in the JSON.
    fn note_row(
        &mut self,
        kernel: &str,
        elements: usize,
        secs: f64,
        display: &str,
        value: f64,
        notes: &str,
    ) {
        self.table.row(&[
            kernel.into(),
            elements.to_string(),
            format!("{:.0} ns", secs * 1e9),
            display.into(),
            notes.into(),
        ]);
        self.json.push(format!(
            "{{\"kernel\": \"{kernel}\", \"elements\": {elements}, \"kind\": \"derived\", \
             \"value\": {value:.4}}}"
        ));
    }

    fn write_json(&self, path: &str) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "[")?;
        for (i, row) in self.json.iter().enumerate() {
            let comma = if i + 1 == self.json.len() { "" } else { "," };
            writeln!(f, "  {row}{comma}")?;
        }
        writeln!(f, "]")?;
        Ok(())
    }
}

fn main() {
    let knobs = a2cid2::config::env::knobs();
    let full = knobs.bench_full;
    let smoke = knobs.bench_smoke;
    let iters = if smoke {
        5
    } else if full {
        400
    } else {
        100
    };
    // 16 MiB per f32 buffer at the full 4M; the smoke size still crosses
    // the pool threshold so the sharded path is exercised.
    let n: usize = if smoke { 4 * pool::CHUNK } else { 4 * 1024 * 1024 };

    let mut bench = Bench::new();

    // Roofline reference: memcpy.
    let src = vec![1.0f32; n];
    let mut dst = vec![0.0f32; n];
    let t = time_it(3, iters, || {
        dst.copy_from_slice(&src);
        std::hint::black_box(&dst);
    });
    bench.row("memcpy (roofline)", n, t, 8 * n, "1R + 1W");

    // Fused mixing + gradient step: 3R + 2W per element.
    let g = vec![0.5f32; n];
    let mut x = vec![1.0f32; n];
    let mut xt = vec![0.5f32; n];
    let t = time_it(3, iters, || {
        vecops::mix_grad(0.9, 0.1, 0.01, &g, &mut x, &mut xt);
        std::hint::black_box(&x);
    });
    bench.row("mix_grad (fused)", n, t, 20 * n, "3R + 2W");

    // Fused mixing + comm step: 3R + 2W per element.
    let xp = vec![0.25f32; n];
    let t = time_it(3, iters, || {
        vecops::comm_apply_fused(0.9, 0.1, 0.5, 1.5, &xp, &mut x, &mut xt);
        std::hint::black_box(&x);
    });
    bench.row("comm_apply_fused", n, t, 20 * n, "3R + 2W");

    // Read-only send-buffer build: 2R + 1W.
    let mut out = vec![0.0f32; n];
    let t = time_it(3, iters, || {
        vecops::mix_into(0.9, 0.1, &x, &xt, &mut out);
        std::hint::black_box(&out);
    });
    bench.row("mix_into (read-only)", n, t, 12 * n, "2R + 1W");

    // Unfused composition for comparison (what fusing saves).
    let t = time_it(3, iters, || {
        vecops::mix_pair(0.9, 0.1, &mut x, &mut xt);
        vecops::axpy(-0.01, &g, &mut x);
        vecops::axpy(-0.01, &g, &mut xt);
        std::hint::black_box(&x);
    });
    bench.row("mix+2*axpy (unfused)", n, t, 32 * n, "(2R+2W) + 2*(2R+1W)");

    // ---- Runtime pairing: old composition vs the fused path ----------
    // Old (two lock holds): mix in place (2R+2W), copy the snapshot out
    // (1R+1W), apply the degenerate comm pass on receive (3R+2W) = 44B/el.
    let peer = vec![0.25f32; n];
    let mut sendbuf = vec![0.0f32; n];
    let t_old = time_it(3, iters, || {
        vecops::mix_pair(0.9, 0.1, &mut x, &mut xt);
        sendbuf.copy_from_slice(&x);
        vecops::comm_apply_fused(1.0, 0.0, 0.5, 1.5, &peer, &mut x, &mut xt);
        std::hint::black_box(&sendbuf);
    });
    bench.row("pairing OLD mix→copy→apply", n, t_old, 44 * n, "6R + 5W, 2 locked passes");

    // New, fusion only (single thread, incl. the seqlock publish copy
    // the real receive path performs): isolates the 6R+5W → 6R+4W pass
    // reduction from pool parallelism, so an un-fusing regression can't
    // hide behind thread scaling.
    let mut pubbuf = vec![0.0f32; n];
    let t_new_1t = time_it(3, iters, || {
        vecops::mix_into(0.9, 0.1, &x, &xt, &mut sendbuf);
        vecops::comm_apply_fused(0.9, 0.1, 0.5, 1.5, &peer, &mut x, &mut xt);
        pubbuf.copy_from_slice(&x); // the publish copy, serial
        std::hint::black_box(&sendbuf);
    });
    bench.row("pairing NEW fused (1 thread)", n, t_new_1t, 40 * n, "6R + 4W incl. publish");
    bench.note_row(
        "pairing fusion-only speedup",
        n,
        t_new_1t,
        &format!("{:.2}x", t_old / t_new_1t),
        t_old / t_new_1t,
        "pass reduction alone, no pool",
    );

    // New, end to end (one locked RMW): read-only mix_into (2R+1W) +
    // fused comm_apply (3R+2W) + publish (1R+1W) = 40B/el, sharded
    // across the chunk pool at this size — exactly what the runtime's
    // comm thread executes per pairing.
    let init = vec![0.0f32; n];
    let published = SnapshotCell::new(&init);
    drop(init);
    let t_new = time_it(3, iters, || {
        pool::mix_into(0.9, 0.1, &x, &xt, &mut sendbuf);
        pool::comm_apply_fused(0.9, 0.1, 0.5, 1.5, &peer, &mut x, &mut xt);
        published.publish(&x);
        std::hint::black_box(&sendbuf);
    });
    bench.row("pairing NEW mix_into→comm_apply", n, t_new, 40 * n, "6R + 4W, 1 locked pass");
    bench.note_row(
        "pairing speedup NEW vs OLD",
        n,
        t_new,
        &format!("{:.2}x", t_old / t_new),
        t_old / t_new,
        "fusion + pool; target >= 1.5x at 4M",
    );

    // ---- Chunk-pool scaling -----------------------------------------
    {
        let lanes = pool::ChunkPool::global().lanes();
        let (mut xa, mut ta) = (vec![1.0f32; n], vec![0.5f32; n]);
        let (mut xb, mut tb) = (vec![-1.0f32; n], vec![0.25f32; n]);
        let t1 = time_it(2, iters, || {
            vecops::comm_pair_fused(
                0.9, 0.1, 0.8, 0.2, 0.5, 1.5, &mut xa, &mut ta, &mut xb, &mut tb,
            );
            std::hint::black_box(&xa);
        });
        bench.row("comm_pair_fused 1 thread", n, t1, 32 * n, "4R + 4W");
        let tp = time_it(2, iters, || {
            pool::comm_pair_fused(
                0.9, 0.1, 0.8, 0.2, 0.5, 1.5, &mut xa, &mut ta, &mut xb, &mut tb,
            );
            std::hint::black_box(&xa);
        });
        bench.row("comm_pair_fused pooled", n, tp, 32 * n, "4R + 4W");
        bench.note_row(
            "chunk-pool speedup (comm_pair)",
            n,
            tp,
            &format!("{:.2}x", t1 / tp),
            t1 / tp,
            &format!("{lanes} lanes; target >= 2x on >= 4 cores"),
        );

        let tg1 = time_it(2, iters, || {
            vecops::mix_grad(0.9, 0.1, 0.01, &g, &mut x, &mut xt);
            std::hint::black_box(&x);
        });
        let tgp = time_it(2, iters, || {
            pool::mix_grad(0.9, 0.1, 0.01, &g, &mut x, &mut xt);
            std::hint::black_box(&x);
        });
        bench.note_row(
            "chunk-pool speedup (mix_grad)",
            n,
            tgp,
            &format!("{:.2}x", tg1 / tgp),
            tg1 / tgp,
            &format!("{lanes} lanes"),
        );
    }

    // ---- Kernel backends: scalar reference vs explicit SIMD ----------
    // Every hot kernel timed once per available backend (trait methods
    // called directly, bypassing the latched dispatch) at a size that
    // does not collide with the rows above, plus a memcpy roofline at
    // the same size. The simd-vs-scalar derived rows pin the §Perf
    // acceptance target (>= 1.5x on comm_apply_fused / mix_into at 2^20).
    {
        let nb: usize = 1 << 20;
        let b_iters = if smoke { 10 } else { 100 };
        let backends = vecops::available_backends();

        let srcb = vec![1.0f32; nb];
        let mut dstb = vec![0.0f32; nb];
        let t = time_it(3, b_iters, || {
            dstb.copy_from_slice(&srcb);
            std::hint::black_box(&dstb);
        });
        bench.row("memcpy (roofline)", nb, t, 8 * nb, "1R + 1W");

        let gb = vec![0.5f32; nb];
        let pb = vec![0.25f32; nb];
        let mut xb = vec![1.0f32; nb];
        let mut xtb = vec![0.5f32; nb];
        let mut outb = vec![0.0f32; nb];
        let (mut xb2, mut xtb2) = (vec![-1.0f32; nb], vec![0.25f32; nb]);

        // (backend name, mix_into secs, comm_apply_fused secs) for the
        // derived speedup rows; available_backends() lists scalar first.
        let mut marks: Vec<(&'static str, f64, f64)> = Vec::new();
        for be in &backends {
            let name = be.name();
            let t = time_it(3, b_iters, || {
                be.axpy(1e-6, &gb, &mut xb);
                std::hint::black_box(&xb);
            });
            bench.backend_row("axpy", name, nb, t, 12 * nb, "2R + 1W");

            let t_mi = time_it(3, b_iters, || {
                be.mix_into(0.9, 0.1, &xb, &xtb, &mut outb);
                std::hint::black_box(&outb);
            });
            bench.backend_row("mix_into", name, nb, t_mi, 12 * nb, "2R + 1W");

            let t = time_it(3, b_iters, || {
                be.grad_step(1e-6, &gb, &mut xb, &mut xtb);
                std::hint::black_box(&xb);
            });
            bench.backend_row("grad_step", name, nb, t, 20 * nb, "3R + 2W");

            let t = time_it(3, b_iters, || {
                be.comm_only(0.5, 1.5, &pb, &mut xb, &mut xtb);
                std::hint::black_box(&xb);
            });
            bench.backend_row("comm_only", name, nb, t, 20 * nb, "3R + 2W");

            let t = time_it(3, b_iters, || {
                be.mix_pair(0.9, 0.1, &mut xb, &mut xtb);
                std::hint::black_box(&xb);
            });
            bench.backend_row("mix_pair", name, nb, t, 16 * nb, "2R + 2W");

            let t = time_it(3, b_iters, || {
                be.mix_grad(0.9, 0.1, 1e-6, &gb, &mut xb, &mut xtb);
                std::hint::black_box(&xb);
            });
            bench.backend_row("mix_grad", name, nb, t, 20 * nb, "3R + 2W");

            let t_ca = time_it(3, b_iters, || {
                be.comm_apply_fused(0.9, 0.1, 0.5, 1.5, &pb, &mut xb, &mut xtb);
                std::hint::black_box(&xb);
            });
            bench.backend_row("comm_apply_fused", name, nb, t_ca, 20 * nb, "3R + 2W");

            let t = time_it(3, b_iters, || {
                be.comm_pair_fused(
                    0.9, 0.1, 0.8, 0.2, 0.5, 1.5, &mut xb, &mut xtb, &mut xb2, &mut xtb2,
                );
                std::hint::black_box(&xb);
            });
            bench.backend_row("comm_pair_fused", name, nb, t, 32 * nb, "4R + 4W");

            let t = time_it(3, b_iters, || {
                std::hint::black_box(be.sq_dist(&xb, &pb));
            });
            bench.backend_row("sq_dist", name, nb, t, 8 * nb, "2R, striped f64 acc");

            marks.push((name, t_mi, t_ca));
        }
        if marks.len() > 1 {
            let (simd_name, simd_mi, simd_ca) = marks[marks.len() - 1];
            let (_, scalar_mi, scalar_ca) = marks[0];
            bench.note_row(
                "mix_into simd speedup",
                nb,
                simd_mi,
                &format!("{:.2}x", scalar_mi / simd_mi),
                scalar_mi / simd_mi,
                &format!("{simd_name} vs scalar; target >= 1.5x"),
            );
            bench.note_row(
                "comm_apply_fused simd speedup",
                nb,
                simd_ca,
                &format!("{:.2}x", scalar_ca / simd_ca),
                scalar_ca / simd_ca,
                &format!("{simd_name} vs scalar; target >= 1.5x"),
            );
        }
        println!("(kernel dispatch latched to backend: {})", vecops::backend_name());
    }

    // ---- Memory locality: pinned pool lanes + first-touch placement --
    // Pooled kernels on two same-width pools: one with lanes pinned to
    // cores (node-major interleave) and buffers first-touched by their
    // sticky owner lanes, one unpinned with the same buffers placed
    // wherever the unpinned lanes happened to run. Plus a remote-touch
    // counterfactual (claim offset rotated so every lane works chunks
    // another lane first-touched) and a per-node memcpy roofline.
    // Single-node hosts still produce every row — pinning is then pure
    // cache affinity and the speedups hover near 1x.
    {
        use a2cid2::gossip::pool::{AlignedVec, ChunkPool};
        use a2cid2::locality;

        let topo = locality::topology();
        let backend = vecops::backend_name();
        let l_iters = if smoke {
            5
        } else if full {
            100
        } else {
            30
        };
        let sizes: &[usize] = if full {
            &[1 << 20, 1 << 22, 1 << 24]
        } else {
            &[1 << 20, 1 << 22]
        };
        let top = *sizes.last().unwrap();
        let extra = 3; // width 4: spans nodes under the interleave, CI-sized
        let unpinned_pool = ChunkPool::new_with_pinning(extra, false);
        let pinned_pool = ChunkPool::new_with_pinning(extra, true);
        let mut cp_marks = [0.0f64; 2]; // comm_pair secs at `top`, [unpinned, pinned]
        for &nl in sizes {
            for (p, is_pinned) in [(&unpinned_pool, false), (&pinned_pool, true)] {
                let mut xa = AlignedVec::zeroed_on(p, nl);
                let mut ta = AlignedVec::zeroed_on(p, nl);
                let mut xb = AlignedVec::zeroed_on(p, nl);
                let mut tb = AlignedVec::zeroed_on(p, nl);
                xa.as_mut_slice().fill(1.0);
                ta.as_mut_slice().fill(0.5);
                xb.as_mut_slice().fill(-1.0);
                tb.as_mut_slice().fill(0.25);
                let t_cp = time_it(2, l_iters, || {
                    pool::comm_pair_fused_on(
                        p,
                        0.9,
                        0.1,
                        0.8,
                        0.2,
                        0.5,
                        1.5,
                        xa.as_mut_slice(),
                        ta.as_mut_slice(),
                        xb.as_mut_slice(),
                        tb.as_mut_slice(),
                    );
                    std::hint::black_box(xa.as_slice());
                });
                bench.locality_row(
                    "comm_pair_fused",
                    backend,
                    is_pinned,
                    nl,
                    t_cp,
                    32 * nl,
                    "4R + 4W, width-4 pool",
                );
                let t_mp = time_it(2, l_iters, || {
                    pool::mix_pair_on(p, 0.9, 0.1, xa.as_mut_slice(), ta.as_mut_slice());
                    std::hint::black_box(xa.as_slice());
                });
                bench.locality_row(
                    "mix_pair",
                    backend,
                    is_pinned,
                    nl,
                    t_mp,
                    16 * nl,
                    "2R + 2W, width-4 pool",
                );
                if nl == top {
                    cp_marks[is_pinned as usize] = t_cp;
                }
            }
        }
        bench.note_row(
            "locality pinned speedup",
            top,
            cp_marks[1],
            &format!("{:.2}x", cp_marks[0] / cp_marks[1]),
            cp_marks[0] / cp_marks[1],
            &format!(
                "{} NUMA node(s); informational on single-node hosts",
                topo.n_nodes()
            ),
        );

        // Counterfactual: the SAME pinned pool and buffers, claim offset
        // rotated so every lane starts on chunks another lane
        // first-touched — the cross-node traffic the sticky assignment
        // exists to avoid. Distinct kernel name so the CI perf gate
        // never mistakes this row for the sticky one.
        {
            let mut xa = AlignedVec::zeroed_on(&pinned_pool, top);
            let mut ta = AlignedVec::zeroed_on(&pinned_pool, top);
            let mut xb = AlignedVec::zeroed_on(&pinned_pool, top);
            let mut tb = AlignedVec::zeroed_on(&pinned_pool, top);
            pinned_pool.set_claim_offset(pinned_pool.lanes() / 2);
            let t = time_it(2, l_iters, || {
                pool::comm_pair_fused_on(
                    &pinned_pool,
                    0.9,
                    0.1,
                    0.8,
                    0.2,
                    0.5,
                    1.5,
                    xa.as_mut_slice(),
                    ta.as_mut_slice(),
                    xb.as_mut_slice(),
                    tb.as_mut_slice(),
                );
                std::hint::black_box(xa.as_slice());
            });
            pinned_pool.set_claim_offset(0);
            bench.locality_row(
                "comm_pair_fused remote-touch",
                backend,
                true,
                top,
                t,
                32 * top,
                "claim offset width/2",
            );
        }

        // Per-node memcpy roofline: pin the timing thread to each node's
        // first core, first-touch the buffers there, copy locally.
        for (k, node) in topo.nodes.iter().enumerate() {
            let Some(&cpu) = node.first() else { continue };
            if !locality::pin_current_thread(cpu) {
                println!("(skipping node{k} memcpy roofline: pinning unavailable)");
                break;
            }
            let srcn = vec![1.0f32; top];
            let mut dstn = vec![1.0f32; top];
            let t = time_it(2, l_iters, || {
                dstn.copy_from_slice(&srcn);
                std::hint::black_box(&dstn);
            });
            locality::unpin_current_thread();
            bench.row(
                &format!("memcpy node{k} (local)"),
                top,
                t,
                8 * top,
                "1R + 1W, pinned first-touch",
            );
        }
    }

    // ---- Snapshot-read latency: seqlock cell vs mutex lock+copy ------
    {
        let dim = 64 * 1024;
        let reads = if smoke { 500 } else { 20_000 };

        // Mutex baseline under writer churn.
        let state = Arc::new(Mutex::new(vec![0.0f32; dim]));
        let stop = Arc::new(AtomicBool::new(false));
        let churn = {
            let state = state.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut v = 0.0f32;
                while !stop.load(Ordering::Relaxed) {
                    let mut g = state.lock().unwrap();
                    v += 1.0;
                    g.fill(v);
                }
            })
        };
        let mut local = vec![0.0f32; dim];
        let t_mutex = time_it(10, reads, || {
            let g = state.lock().unwrap();
            local.copy_from_slice(&g);
            std::hint::black_box(&local);
        });
        stop.store(true, Ordering::Relaxed);
        churn.join().unwrap();
        bench.row("snapshot read: mutex+copy", dim, t_mutex, 8 * dim, "contended lock");

        // Published seqlock cell under publish churn.
        let init = vec![0.0f32; dim];
        let cell = Arc::new(SnapshotCell::new(&init));
        let stop = Arc::new(AtomicBool::new(false));
        let churn = {
            let cell = cell.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut buf = vec![0.0f32; dim];
                let mut v = 0.0f32;
                while !stop.load(Ordering::Relaxed) {
                    v += 1.0;
                    buf.fill(v);
                    cell.publish(&buf);
                }
            })
        };
        let mut scratch = Vec::new();
        let t_cell = time_it(10, reads, || {
            cell.read_into(&mut scratch);
            std::hint::black_box(&scratch);
        });
        stop.store(true, Ordering::Relaxed);
        churn.join().unwrap();
        bench.row("snapshot read: seqlock cell", dim, t_cell, 8 * dim, "lock-free");
        bench.note_row(
            "snapshot read speedup",
            dim,
            t_cell,
            &format!("{:.2}x", t_mutex / t_cell),
            t_mutex / t_cell,
            "reader under writer churn",
        );
    }

    // ---- Contended pairing throughput --------------------------------
    // One worker cell, a gradient thread hammering its side of the
    // protocol, while we time pairings. OLD: grad snapshots under the
    // state lock, pairing mixes+copies under the lock. NEW: grad reads
    // the published cell, pairing is mix_into + one fused RMW.
    {
        let dim = if smoke { 256 * 1024 } else { 1024 * 1024 };
        let pairings = if smoke { 10 } else { 60 };
        let mixer = Mixer::new(8.0);
        let w = mixer.weights(0.05);

        // OLD scheme.
        let state = Arc::new(Mutex::new((vec![1.0f32; dim], vec![0.5f32; dim])));
        let stop = Arc::new(AtomicBool::new(false));
        let contender = {
            let state = state.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let g = vec![0.1f32; dim];
                let mut snap = vec![0.0f32; dim];
                while !stop.load(Ordering::Relaxed) {
                    {
                        let st = state.lock().unwrap();
                        snap.copy_from_slice(&st.0);
                    }
                    std::hint::black_box(&snap);
                    let mut st = state.lock().unwrap();
                    let inner = &mut *st;
                    vecops::mix_grad(w.wa, w.wb, 0.001, &g, &mut inner.0, &mut inner.1);
                }
            })
        };
        let peer = vec![0.25f32; dim];
        let mut sendbuf = vec![0.0f32; dim];
        let t_old = time_it(2, pairings, || {
            {
                let mut st = state.lock().unwrap();
                let inner = &mut *st;
                vecops::mix_pair(w.wa, w.wb, &mut inner.0, &mut inner.1);
                sendbuf.copy_from_slice(&inner.0);
            }
            std::hint::black_box(&sendbuf);
            let mut st = state.lock().unwrap();
            let inner = &mut *st;
            vecops::comm_apply_fused(1.0, 0.0, 0.5, 1.5, &peer, &mut inner.0, &mut inner.1);
        });
        stop.store(true, Ordering::Relaxed);
        contender.join().unwrap();
        bench.note_row(
            "contended pairing OLD",
            dim,
            t_old,
            &format!("{:.1}/s", 1.0 / t_old),
            1.0 / t_old,
            "grad thread locks for snapshots",
        );

        // NEW scheme.
        let state = Arc::new(Mutex::new((vec![1.0f32; dim], vec![0.5f32; dim])));
        let init = vec![1.0f32; dim];
        let cell = Arc::new(SnapshotCell::new(&init));
        let stop = Arc::new(AtomicBool::new(false));
        let contender = {
            let state = state.clone();
            let cell = cell.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let g = vec![0.1f32; dim];
                let mut snap = Vec::new();
                while !stop.load(Ordering::Relaxed) {
                    cell.read_into(&mut snap);
                    std::hint::black_box(&snap);
                    let mut st = state.lock().unwrap();
                    let inner = &mut *st;
                    pool::mix_grad(w.wa, w.wb, 0.001, &g, &mut inner.0, &mut inner.1);
                    cell.publish(&inner.0);
                }
            })
        };
        let t_new = time_it(2, pairings, || {
            {
                let st = state.lock().unwrap();
                pool::mix_into(w.wa, w.wb, &st.0, &st.1, &mut sendbuf);
            }
            std::hint::black_box(&sendbuf);
            let mut st = state.lock().unwrap();
            let inner = &mut *st;
            pool::comm_apply_fused(w.wa, w.wb, 0.5, 1.5, &peer, &mut inner.0, &mut inner.1);
            cell.publish(&inner.0);
        });
        stop.store(true, Ordering::Relaxed);
        contender.join().unwrap();
        bench.note_row(
            "contended pairing NEW",
            dim,
            t_new,
            &format!("{:.1}/s", 1.0 / t_new),
            1.0 / t_new,
            "published reads, 1 locked RMW",
        );
        bench.note_row(
            "contended pairing speedup",
            dim,
            t_new,
            &format!("{:.2}x", t_old / t_new),
            t_old / t_new,
            "NEW vs OLD under grad contention",
        );
    }

    // ---- Simulator event throughput ----------------------------------
    {
        use a2cid2::graph::{Graph, Topology};
        let graph = Graph::build(&Topology::Ring, 64).unwrap();
        let rates = graph.edge_rates(1.0);
        let dim = 1024;
        let horizon = if smoke { 50.0 } else { 500.0 };
        let acid = a2cid2::gossip::AcidParams::accelerated(200.0, 1.0);
        let mixer = a2cid2::gossip::Mixer::new(acid.eta);
        let mut workers: Vec<a2cid2::gossip::WorkerState> = (0..64)
            .map(|i| a2cid2::gossip::WorkerState::new(vec![i as f32; dim]))
            .collect();
        // Gradient clocks at ~zero rate: comm-only stream.
        let mut queue = a2cid2::simulator::EventQueue::new(&[1e-9; 64], &rates, 1);
        let t0 = Instant::now();
        let mut events = 0u64;
        while let Some(ev) = queue.next(horizon) {
            if let a2cid2::simulator::EventKind::Comm { edge } = ev.kind {
                let (i, j) = graph.edges[edge];
                let (a, b) = two_mut(&mut workers, i, j);
                a2cid2::gossip::dynamics::comm_event(a, b, ev.t, &acid, &mixer);
                events += 1;
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        bench.row(
            "simulator comm events",
            dim,
            secs / events as f64,
            dim * 24,
            &format!("{events} events"),
        );
    }

    // Event-loop throughput: raw scheduler pops with no dynamics. This is
    // the §Perf guard for the DynamicsCore/Scheduler refactor — the
    // static-ring case must stay within ±10% of the pre-refactor loop.
    {
        use a2cid2::graph::{Graph, Topology};
        use a2cid2::simulator::{EventKind, EventQueue};
        let graph = Graph::build(&Topology::Ring, 64).unwrap();
        let rates = graph.edge_rates(1.0);
        let horizon = if smoke {
            500.0
        } else if full {
            20_000.0
        } else {
            5_000.0
        };

        // Static ring: the historical hot path.
        let mut queue = EventQueue::new(&[1.0; 64], &rates, 1);
        let t0 = Instant::now();
        let mut events = 0u64;
        while queue.next(horizon).is_some() {
            events += 1;
        }
        let secs = t0.elapsed().as_secs_f64();
        bench.note_row(
            "event loop (static ring)",
            64,
            secs / events as f64,
            &format!("{:.2} Mev/s", events as f64 / secs / 1e6),
            events as f64 / secs,
            &format!("{events} events"),
        );

        // Same workload under scenario churn: periodic rate retuning
        // (the set_rate path) must not sink the loop.
        let mut queue = EventQueue::new(&[1.0; 64], &rates, 1);
        let t0 = Instant::now();
        let mut events = 0u64;
        let mut updates = 0u64;
        let mut next_update = 10.0;
        loop {
            match queue.next(next_update.min(horizon)) {
                Some(ev) => {
                    if let EventKind::Comm { .. } = ev.kind {
                        // touch the event so the optimizer keeps it
                        std::hint::black_box(ev.t);
                    }
                    events += 1;
                }
                None => {
                    if next_update >= horizon {
                        break;
                    }
                    // Mirror VirtualTimeScheduler::apply — retunes are
                    // sampled from the update's own timestamp.
                    queue.advance_to(next_update);
                    for (e, &r) in rates.iter().enumerate() {
                        queue.set_comm_rate(e, if updates % 2 == 0 { r * 0.5 } else { r });
                    }
                    updates += 1;
                    next_update += 10.0;
                }
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        bench.note_row(
            "event loop (rate churn)",
            64,
            secs / events as f64,
            &format!("{:.2} Mev/s", events as f64 / secs / 1e6),
            events as f64 / secs,
            &format!("{events} events, {updates} retunes"),
        );
    }

    // ---- Coordinator matching throughput -----------------------------
    // n workers hammer the pairing protocol over a ring (no payloads, no
    // Reconfigure churn) until each completes a quota of pairings; the
    // measured rate is pairings matched per second, rendezvous vs
    // batched. The batched strategy must win at n = 64 (§Perf target).
    {
        use a2cid2::engine::WallClock;
        use a2cid2::graph::{Graph, Topology};
        use a2cid2::runtime::coordinator::spawn_coordinator_with;
        use a2cid2::runtime::{CoordMsg, MatchStrategy, PairReply};
        use std::sync::mpsc;
        use std::time::Duration;

        let per_worker = if smoke {
            25
        } else if full {
            400
        } else {
            150
        };
        for n_workers in [16usize, 64, 256] {
            let mut rates = [0.0f64; 2];
            for (si, strategy) in
                [MatchStrategy::Rendezvous, MatchStrategy::Batched].into_iter().enumerate()
            {
                let net = Arc::new(WallClock::from_graph(
                    &Graph::build(&Topology::Ring, n_workers).unwrap(),
                    1.0,
                ));
                let (tx, handle) = spawn_coordinator_with(net, strategy);
                let t0 = Instant::now();
                let threads: Vec<_> = (0..n_workers)
                    .map(|w| {
                        let tx = tx.clone();
                        std::thread::spawn(move || {
                            let mut done = 0usize;
                            while done < per_worker {
                                let (rtx, rrx) = mpsc::channel();
                                tx.send(CoordMsg::Available { worker: w, reply: rtx })
                                    .unwrap();
                                match rrx.recv_timeout(Duration::from_millis(100)) {
                                    Ok(PairReply::Peer(_)) => done += 1,
                                    Ok(PairReply::NoPartnerEver) => break,
                                    Ok(PairReply::Cancelled) => {}
                                    Err(_) => {
                                        // Timed out waiting: cancel, then
                                        // honor whichever reply won the race.
                                        tx.send(CoordMsg::Cancel { worker: w }).unwrap();
                                        match rrx.recv() {
                                            Ok(PairReply::Peer(_)) => done += 1,
                                            Ok(PairReply::NoPartnerEver) => break,
                                            _ => {}
                                        }
                                    }
                                }
                            }
                            tx.send(CoordMsg::Leave { worker: w }).unwrap();
                        })
                    })
                    .collect();
                for th in threads {
                    th.join().unwrap();
                }
                let stats = handle.join().unwrap();
                let secs = t0.elapsed().as_secs_f64();
                let rate = stats.total as f64 / secs;
                rates[si] = rate;
                let label = match strategy {
                    MatchStrategy::Rendezvous => "coordinator rendezvous",
                    MatchStrategy::Batched => "coordinator batched",
                };
                bench.note_row(
                    label,
                    n_workers,
                    secs / stats.total.max(1) as f64,
                    &format!("{rate:.0}/s"),
                    rate,
                    &format!("{} pairings matched", stats.total),
                );
            }
            bench.note_row(
                "coordinator batched speedup",
                n_workers,
                1.0 / rates[1].max(1e-9),
                &format!("{:.2}x", rates[1] / rates[0].max(1e-9)),
                rates[1] / rates[0].max(1e-9),
                "pairings/s vs rendezvous; target > 1x at n=64",
            );
        }
    }

    // PJRT kernel dispatch (the L1 artifact), if artifacts are built.
    #[cfg(feature = "pjrt")]
    match pjrt_kernel_bench(if full { 200 } else { 50 }) {
        Ok(rows) => {
            for (name, size, secs, bytes) in rows {
                bench.row(&name, size, secs, bytes, "incl. literal copies");
            }
        }
        Err(e) => println!("(skipping PJRT kernel bench: {e})"),
    }

    bench.table.print();
    match bench.write_json("BENCH_perf.json") {
        Ok(()) => println!("wrote BENCH_perf.json ({} rows)", bench.json.len()),
        Err(e) => println!("(failed to write BENCH_perf.json: {e})"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_kernel_bench(iters: usize) -> a2cid2::Result<Vec<(String, usize, f64, usize)>> {
    use a2cid2::runtime::artifacts::{default_artifact_dir, Manifest};
    use a2cid2::runtime::pjrt::{lit_f32, lit_scalar, PjrtContext};
    let manifest = Manifest::load(default_artifact_dir())?;
    let ctx = PjrtContext::cpu()?;
    let mut out = Vec::new();
    for size in [4096usize, 65536] {
        let name = format!("acid_mix_grad_{size}");
        let exe = ctx.load_artifact(&manifest, &name)?;
        let x = vec![1.0f32; size];
        let t = time_it(3, iters, || {
            let outs = exe
                .run(&[
                    lit_f32(&x),
                    lit_f32(&x),
                    lit_f32(&x),
                    lit_scalar(0.1),
                    lit_scalar(0.5),
                    lit_scalar(0.01),
                ])
                .expect("kernel run");
            std::hint::black_box(outs);
        });
        out.push((format!("PJRT {name}"), size, t, size * 20));
    }
    Ok(out)
}
