//! Perf bench — the §Perf deliverable's measurement harness.
//!
//! Measures the L3 hot paths against their practical rooflines:
//!   * fused gossip kernels (mix_grad / mix_comm) vs memcpy bandwidth;
//!   * simulator event throughput (events/s);
//!   * PJRT dispatch overhead for the standalone L1 kernel artifacts
//!     (needs `make artifacts`; skipped gracefully if missing);
//!
//! `A2CID2_BENCH_FULL=1` raises iteration counts.

use std::time::Instant;

use a2cid2::gossip::vecops;
use a2cid2::metrics::Table;

/// Time `f` over `iters` iterations after `warmup`, returning seconds/iter.
fn time_it(warmup: usize, iters: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn gb_per_s(bytes_per_iter: usize, secs: f64) -> f64 {
    bytes_per_iter as f64 / secs / 1e9
}

fn main() {
    let full = std::env::var("A2CID2_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let iters = if full { 400 } else { 100 };
    let n: usize = 4 * 1024 * 1024; // 16 MiB per f32 buffer

    let mut table = Table::new(
        "Perf — L3 hot paths (bytes/element per column 'notes')",
        &["kernel", "elements", "time/iter", "effective GB/s", "notes"],
    );

    // Roofline reference: memcpy.
    let src = vec![1.0f32; n];
    let mut dst = vec![0.0f32; n];
    let t = time_it(3, iters, || {
        dst.copy_from_slice(&src);
        std::hint::black_box(&dst);
    });
    table.row(&[
        "memcpy (roofline)".into(),
        n.to_string(),
        format!("{:.2} ms", t * 1e3),
        format!("{:.1}", gb_per_s(8 * n, t)),
        "1R + 1W".into(),
    ]);

    // Fused mixing + gradient step: 3R + 2W per element.
    let g = vec![0.5f32; n];
    let mut x = vec![1.0f32; n];
    let mut xt = vec![0.5f32; n];
    let t = time_it(3, iters, || {
        vecops::mix_grad(0.9, 0.1, 0.01, &g, &mut x, &mut xt);
        std::hint::black_box(&x);
    });
    table.row(&[
        "mix_grad (fused)".into(),
        n.to_string(),
        format!("{:.2} ms", t * 1e3),
        format!("{:.1}", gb_per_s(20 * n, t)),
        "3R + 2W".into(),
    ]);

    // Fused mixing + comm step: 3R + 2W per element.
    let xp = vec![0.25f32; n];
    let t = time_it(3, iters, || {
        vecops::mix_comm(0.9, 0.1, 0.5, 1.5, &xp, &mut x, &mut xt);
        std::hint::black_box(&x);
    });
    table.row(&[
        "mix_comm (fused)".into(),
        n.to_string(),
        format!("{:.2} ms", t * 1e3),
        format!("{:.1}", gb_per_s(20 * n, t)),
        "3R + 2W".into(),
    ]);

    // Unfused composition for comparison (what fusing saves).
    let t = time_it(3, iters, || {
        vecops::mix_pair(0.9, 0.1, &mut x, &mut xt);
        vecops::axpy(-0.01, &g, &mut x);
        vecops::axpy(-0.01, &g, &mut xt);
        std::hint::black_box(&x);
    });
    table.row(&[
        "mix+2*axpy (unfused)".into(),
        n.to_string(),
        format!("{:.2} ms", t * 1e3),
        format!("{:.1}", gb_per_s(32 * n, t)),
        "(2R+2W) + 2*(2R+1W)".into(),
    ]);

    // Simulator event throughput on a pure-gossip workload.
    {
        use a2cid2::graph::{Graph, Topology};
        let graph = Graph::build(&Topology::Ring, 64).unwrap();
        let rates = graph.edge_rates(1.0);
        let dim = 1024;
        let acid = a2cid2::gossip::AcidParams::accelerated(200.0, 1.0);
        let mixer = a2cid2::gossip::Mixer::new(acid.eta);
        let mut workers: Vec<a2cid2::gossip::WorkerState> = (0..64)
            .map(|i| a2cid2::gossip::WorkerState::new(vec![i as f32; dim]))
            .collect();
        // Gradient clocks at ~zero rate: comm-only stream.
        let mut queue = a2cid2::simulator::EventQueue::new(&vec![1e-9; 64], &rates, 1);
        let t0 = Instant::now();
        let mut events = 0u64;
        while let Some(ev) = queue.next(500.0) {
            if let a2cid2::simulator::EventKind::Comm { edge } = ev.kind {
                let (i, j) = graph.edges[edge];
                let (l, r) = workers.split_at_mut(j);
                a2cid2::gossip::dynamics::comm_event(&mut l[i], &mut r[0], ev.t, &acid, &mixer);
                events += 1;
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        table.row(&[
            "simulator comm events".into(),
            format!("dim={dim}"),
            format!("{:.2} us/event", secs / events as f64 * 1e6),
            format!("{:.1}", gb_per_s(events as usize * dim * 24, secs)),
            format!("{events} events"),
        ]);
    }

    // Event-loop throughput: raw scheduler pops with no dynamics. This is
    // the §Perf guard for the DynamicsCore/Scheduler refactor — the
    // static-ring case must stay within ±10% of the pre-refactor loop.
    {
        use a2cid2::graph::{Graph, Topology};
        use a2cid2::simulator::{EventKind, EventQueue};
        let graph = Graph::build(&Topology::Ring, 64).unwrap();
        let rates = graph.edge_rates(1.0);
        let horizon = if full { 20_000.0 } else { 5_000.0 };

        // Static ring: the historical hot path.
        let mut queue = EventQueue::new(&vec![1.0; 64], &rates, 1);
        let t0 = Instant::now();
        let mut events = 0u64;
        while queue.next(horizon).is_some() {
            events += 1;
        }
        let secs = t0.elapsed().as_secs_f64();
        table.row(&[
            "event loop (static ring)".into(),
            "n=64".into(),
            format!("{:.0} ns/event", secs / events as f64 * 1e9),
            format!("{:.2} Mev/s", events as f64 / secs / 1e6),
            format!("{events} events"),
        ]);

        // Same workload under scenario churn: periodic rate retuning
        // (the set_rate path) must not sink the loop.
        let mut queue = EventQueue::new(&vec![1.0; 64], &rates, 1);
        let t0 = Instant::now();
        let mut events = 0u64;
        let mut updates = 0u64;
        let mut next_update = 10.0;
        loop {
            match queue.next(next_update.min(horizon)) {
                Some(ev) => {
                    if let EventKind::Comm { .. } = ev.kind {
                        // touch the event so the optimizer keeps it
                        std::hint::black_box(ev.t);
                    }
                    events += 1;
                }
                None => {
                    if next_update >= horizon {
                        break;
                    }
                    // Mirror VirtualTimeScheduler::apply — retunes are
                    // sampled from the update's own timestamp.
                    queue.advance_to(next_update);
                    for (e, &r) in rates.iter().enumerate() {
                        queue.set_comm_rate(e, if updates % 2 == 0 { r * 0.5 } else { r });
                    }
                    updates += 1;
                    next_update += 10.0;
                }
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        table.row(&[
            "event loop (rate churn)".into(),
            format!("{updates} retunes"),
            format!("{:.0} ns/event", secs / events as f64 * 1e9),
            format!("{:.2} Mev/s", events as f64 / secs / 1e6),
            format!("{events} events"),
        ]);
    }

    // PJRT kernel dispatch (the L1 artifact), if artifacts are built.
    #[cfg(feature = "pjrt")]
    match pjrt_kernel_bench(if full { 200 } else { 50 }) {
        Ok(rows) => {
            for r in rows {
                table.row(&r);
            }
        }
        Err(e) => println!("(skipping PJRT kernel bench: {e})"),
    }

    table.print();
}

#[cfg(feature = "pjrt")]
fn pjrt_kernel_bench(iters: usize) -> a2cid2::Result<Vec<Vec<String>>> {
    use a2cid2::runtime::artifacts::{default_artifact_dir, Manifest};
    use a2cid2::runtime::pjrt::{lit_f32, lit_scalar, PjrtContext};
    let manifest = Manifest::load(default_artifact_dir())?;
    let ctx = PjrtContext::cpu()?;
    let mut out = Vec::new();
    for size in [4096usize, 65536] {
        let name = format!("acid_mix_grad_{size}");
        let exe = ctx.load_artifact(&manifest, &name)?;
        let x = vec![1.0f32; size];
        let t = time_it(3, iters, || {
            let outs = exe
                .run(&[
                    lit_f32(&x),
                    lit_f32(&x),
                    lit_f32(&x),
                    lit_scalar(0.1),
                    lit_scalar(0.5),
                    lit_scalar(0.01),
                ])
                .expect("kernel run");
            std::hint::black_box(outs);
        });
        out.push(vec![
            format!("PJRT {name}"),
            size.to_string(),
            format!("{:.1} us/call", t * 1e6),
            format!("{:.2}", size as f64 * 20.0 / t / 1e9),
            "incl. literal copies".into(),
        ]);
    }
    Ok(out)
}
