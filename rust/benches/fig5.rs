//! Bench: regenerate the paper's fig5 (see experiments::fig5).
//! Quick scale by default; A2CID2_BENCH_FULL=1 for the paper-sized grid.
fn main() {
    let scale = a2cid2::experiments::Scale::from_env();
    let t0 = std::time::Instant::now();
    let (_data, tables) = a2cid2::experiments::fig5::run(scale).expect("fig5");
    for t in tables {
        t.print();
    }
    println!("[fig5] completed in {:.1}s at {scale:?} scale", t0.elapsed().as_secs_f64());
}
