//! Bench: regenerate the paper's Fig. 3 (see `experiments::fig3`).
//! Quick scale by default; `A2CID2_BENCH_FULL=1` for the paper-sized grid.
a2cid2::bench_main!(fig3);
