//! The scenario sweep as a bench target: runs the dropout × switch-time ×
//! adaptive-vs-frozen grid at the env-selected scale, prints the table,
//! and writes `BENCH_sweep.json` (cargo runs benches with cwd = the
//! package root, so the file lands under `rust/`) for CI to archive.

use a2cid2::experiments::{sweep, Scale};

fn main() {
    let scale = Scale::from_env();
    let t0 = std::time::Instant::now();
    let (points, tables) = sweep::run(scale).expect("sweep");
    for t in tables {
        t.print();
    }
    match sweep::write_json(&points, std::path::Path::new("BENCH_sweep.json")) {
        Ok(()) => println!("wrote BENCH_sweep.json ({} rows)", points.len()),
        Err(e) => println!("(failed to write BENCH_sweep.json: {e})"),
    }
    println!(
        "[sweep] completed in {:.1}s at {scale:?} scale",
        t0.elapsed().as_secs_f64()
    );
}
