//! The scenario sweep as a bench target: the dropout × switch-time ×
//! churn × adaptive-vs-frozen grid at the env-selected scale. Resolved
//! through the experiment registry, which prints the table and maintains
//! the `BENCH_sweep.json` artifact (cargo runs benches with cwd = the
//! package root, so the file lands under `rust/`) for CI to archive.
a2cid2::bench_main!(sweep);
