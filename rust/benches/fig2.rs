//! Bench: regenerate the paper's fig2 (see experiments::fig2).
//! Quick scale by default; A2CID2_BENCH_FULL=1 for the paper-sized grid.
fn main() {
    let scale = a2cid2::experiments::Scale::from_env();
    let t0 = std::time::Instant::now();
    let tables = a2cid2::experiments::fig2::run(scale).expect("fig2");
    for t in tables {
        t.print();
    }
    println!("[fig2] completed in {:.1}s at {scale:?} scale", t0.elapsed().as_secs_f64());
}
