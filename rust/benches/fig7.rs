//! Bench: regenerate the paper's fig7 (see experiments::fig7).
//! Quick scale by default; A2CID2_BENCH_FULL=1 for the paper-sized grid.
fn main() {
    let scale = a2cid2::experiments::Scale::from_env();
    let t0 = std::time::Instant::now();
    let tables = a2cid2::experiments::fig7::run(scale).expect("fig7");
    for t in tables {
        t.print();
    }
    println!("[fig7] completed in {:.1}s at {scale:?} scale", t0.elapsed().as_secs_f64());
}
