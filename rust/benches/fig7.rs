//! Bench: regenerate the paper's Fig. 7 (see `experiments::fig7`).
//! Quick scale by default; `A2CID2_BENCH_FULL=1` for the paper-sized grid.
a2cid2::bench_main!(fig7);
