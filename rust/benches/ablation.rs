//! Bench: ablation of the theory-given momentum rate η* (DESIGN.md's
//! called-out design choice). `A2CID2_BENCH_FULL=1` runs at n=64.
a2cid2::bench_main!(ablation);
