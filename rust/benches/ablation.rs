//! Bench: ablation of the theory-given momentum rate η* (DESIGN.md's
//! called-out design choice). A2CID2_BENCH_FULL=1 runs at n=64.
fn main() {
    let scale = a2cid2::experiments::Scale::from_env();
    let t0 = std::time::Instant::now();
    let (_rows, tables) = a2cid2::experiments::ablation::run(scale).expect("ablation");
    for t in tables {
        t.print();
    }
    println!("[ablation] completed in {:.1}s at {scale:?} scale", t0.elapsed().as_secs_f64());
}
