//! Bench: regenerate the paper's tab3 (see experiments::tab3).
//! Quick scale by default; A2CID2_BENCH_FULL=1 for the paper-sized grid.
fn main() {
    let scale = a2cid2::experiments::Scale::from_env();
    let t0 = std::time::Instant::now();
    let (_data, tables) = a2cid2::experiments::tab3::run(scale).expect("tab3");
    for t in tables {
        t.print();
    }
    println!("[tab3] completed in {:.1}s at {scale:?} scale", t0.elapsed().as_secs_f64());
}
