//! Bench: regenerate the paper's Tab. 3 (see `experiments::tab3`).
//! Quick scale by default; `A2CID2_BENCH_FULL=1` for the paper-sized grid.
a2cid2::bench_main!(tab3);
