//! Bench: regenerate the paper's Tab. 1 (see `experiments::tab1`).
//! Quick scale by default; `A2CID2_BENCH_FULL=1` for the paper-sized grid.
a2cid2::bench_main!(tab1);
