//! Bench: regenerate the paper's tab5 (see experiments::tab5).
//! Quick scale by default; A2CID2_BENCH_FULL=1 for the paper-sized grid.
fn main() {
    let scale = a2cid2::experiments::Scale::from_env();
    let t0 = std::time::Instant::now();
    let tables = a2cid2::experiments::tab5::run(scale).expect("tab5");
    for t in tables {
        t.print();
    }
    println!("[tab5] completed in {:.1}s at {scale:?} scale", t0.elapsed().as_secs_f64());
}
