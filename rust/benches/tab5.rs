//! Bench: regenerate the paper's Tab. 5 (see `experiments::tab5`).
//! Quick scale by default; `A2CID2_BENCH_FULL=1` for the paper-sized grid.
a2cid2::bench_main!(tab5);
