//! The massive-fleet scaling grid as a bench target: cluster_ring(k, m)
//! fleets up to 10⁵ (10⁶ at full scale) virtual workers on the
//! multiplexed engine, with Lanczos-estimated (χ₁, χ₂) against the flat
//! ring's closed form. Resolved through the experiment registry, which
//! prints the table and times the run.
a2cid2::bench_main!(scaling);
