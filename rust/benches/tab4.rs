//! Bench: regenerate the paper's Tab. 4 (see `experiments::tab4`).
//! Quick scale by default; `A2CID2_BENCH_FULL=1` for the paper-sized grid.
a2cid2::bench_main!(tab4);
