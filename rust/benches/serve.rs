//! Serve bench — the load generator for the training-as-a-service path.
//!
//! Measures what `a2cid2 serve` adds on top of a plain controlled run:
//!   * training throughput (fleet grads/s) with NO snapshot readers — the
//!     baseline the daemon must not sink;
//!   * the same run with N concurrent reader threads hammering
//!     `ServeControl::consensus_snapshot` off the lock-free cells:
//!     snapshot-read QPS plus the training-throughput degradation it
//!     costs (target <= 10% — readers retry on seqlock tears, they never
//!     block the writers);
//!   * post-run serving: consensus assembly latency once the fleet is
//!     done (the daemon keeps answering `snapshot` after `stop`);
//!   * the runtime checkpoint path: encode+decode round trip, the full
//!     save→load cycle through `write_atomic`, and the FNV-1a checksum.
//!
//! Alongside the printed table every row lands machine-readable in
//! `BENCH_serve.json` (same `kind: kernel|derived` tagging as
//! `BENCH_perf.json`) so the degradation number is pinned for future PRs.
//!
//! `A2CID2_BENCH_FULL=1` raises sizes and reader counts;
//! `A2CID2_BENCH_SMOKE=1` shrinks everything to a CI-sized smoke run.

use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use a2cid2::config::Method;
use a2cid2::data::{GaussianMixture, Sharding};
use a2cid2::graph::{Graph, Topology};
use a2cid2::metrics::Table;
use a2cid2::model::{Logistic, Model};
use a2cid2::optim::LrSchedule;
use a2cid2::rng::Xoshiro256;
use a2cid2::runtime::serve::{fnv1a_params, RuntimeCheckpoint};
use a2cid2::runtime::{
    run_async_controlled, GradSource, RuntimeOptions, RustGradSource, ServeControl,
};

/// Time `f` over `iters` iterations after `warmup`, returning seconds/iter.
fn time_it(warmup: usize, iters: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Table + machine-readable JSON rows, following `BENCH_perf.json`'s
/// schema: `kind: "kernel"` rows carry `ns_per_iter`/`gb_per_s`,
/// `kind: "derived"` rows carry `value`.
struct Bench {
    table: Table,
    json: Vec<String>,
}

impl Bench {
    fn new() -> Self {
        Self {
            table: Table::new(
                "Serve — snapshot load vs training throughput",
                &["path", "elements", "time/iter", "value", "notes"],
            ),
            json: Vec::new(),
        }
    }

    fn row(&mut self, kernel: &str, elements: usize, secs: f64, bytes: usize, notes: &str) {
        let gbs = bytes as f64 / secs / 1e9;
        let time = if secs >= 1e-4 {
            format!("{:.2} ms", secs * 1e3)
        } else {
            format!("{:.2} us", secs * 1e6)
        };
        self.table.row(&[
            kernel.into(),
            elements.to_string(),
            time,
            format!("{gbs:.1} GB/s"),
            notes.into(),
        ]);
        self.json.push(format!(
            "{{\"kernel\": \"{kernel}\", \"elements\": {elements}, \"kind\": \"kernel\", \
             \"ns_per_iter\": {:.1}, \"gb_per_s\": {gbs:.3}}}",
            secs * 1e9
        ));
    }

    fn note_row(
        &mut self,
        kernel: &str,
        elements: usize,
        secs: f64,
        display: &str,
        value: f64,
        notes: &str,
    ) {
        self.table.row(&[
            kernel.into(),
            elements.to_string(),
            format!("{:.0} ns", secs * 1e9),
            display.into(),
            notes.into(),
        ]);
        self.json.push(format!(
            "{{\"kernel\": \"{kernel}\", \"elements\": {elements}, \"kind\": \"derived\", \
             \"value\": {value:.4}}}"
        ));
    }

    fn write_json(&self, path: &str) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "[")?;
        for (i, row) in self.json.iter().enumerate() {
            let comma = if i + 1 == self.json.len() { "" } else { "," };
            writeln!(f, "  {row}{comma}")?;
        }
        writeln!(f, "]")?;
        Ok(())
    }
}

/// Outcome of one loaded run: training throughput plus the reader side.
struct Loaded {
    grads_per_sec: f64,
    wall_secs: f64,
    /// Successful (`Some`) consensus reads across all reader threads.
    reads: u64,
    /// Reads per second over the training window.
    qps: f64,
    model_dim: usize,
    /// The control block outlives the run — the daemon serves snapshots
    /// and checkpoints off it after `stop`, and so do the post-run rows.
    ctrl: Arc<ServeControl>,
    avg_params: Vec<f32>,
    grads_total: u64,
}

/// One controlled training run with `readers` concurrent snapshot-reader
/// threads. The grad sources are paced (`pace` per step) so training
/// models real gradient compute instead of a pure CPU spin — that is the
/// regime the <= 10% degradation target is meant for.
fn run_loaded(n: usize, steps: u64, pace: Duration, readers: usize, ds_dim: usize) -> Loaded {
    let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
    let ds = Arc::new(
        GaussianMixture { dim: ds_dim, n_classes: 2, margin: 3.0, sigma: 1.0 }.sample(128, 11),
    );
    let shards = Sharding::FullShuffled.assign(&ds, n, 11);
    let model = Arc::new(Logistic::new(ds, 0.0));
    let model_dim = model.dim();
    let mut rng = Xoshiro256::seed_from_u64(11);
    let init = model.init_params(&mut rng);
    let sources: Vec<Box<dyn GradSource>> = (0..n)
        .map(|w| {
            let mut s = RustGradSource::new(
                model.clone() as Arc<dyn Model>,
                shards.per_worker[w].clone(),
                4,
                w as u64,
            );
            s.extra_delay = Some(pace);
            Box::new(s) as Box<dyn GradSource>
        })
        .collect();
    let opts = RuntimeOptions {
        comm_rate: 1.0,
        method: Method::Acid,
        lr: LrSchedule::Constant { lr: 0.05 },
        momentum: 0.9,
        steps_per_worker: steps,
        seed: 11,
        monitor_interval: Duration::from_millis(5),
        link_delay: None,
        scenario: None,
    };

    let ctrl = Arc::new(ServeControl::new());
    let stop = Arc::new(AtomicBool::new(false));
    let reads = Arc::new(AtomicU64::new(0));
    // Readers start before the run and tolerate the pre-startup `None`;
    // only `Some` reads count toward QPS.
    let handles: Vec<_> = (0..readers)
        .map(|_| {
            let ctrl = ctrl.clone();
            let stop = stop.clone();
            let reads = reads.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    match ctrl.consensus_snapshot() {
                        Some(snap) => {
                            std::hint::black_box(snap[0]);
                            reads.fetch_add(1, Ordering::Relaxed);
                        }
                        None => std::thread::yield_now(),
                    }
                }
            })
        })
        .collect();

    let res = run_async_controlled(graph, sources, init, opts, ctrl.clone())
        .expect("loaded run completes");
    stop.store(true, Ordering::Release);
    for h in handles {
        h.join().unwrap();
    }

    let grads_total: u64 = res.grads_per_worker.iter().sum();
    let reads = reads.load(Ordering::Acquire);
    Loaded {
        grads_per_sec: grads_total as f64 / res.wall_secs,
        wall_secs: res.wall_secs,
        reads,
        qps: reads as f64 / res.wall_secs,
        model_dim,
        ctrl,
        avg_params: res.avg_params,
        grads_total,
    }
}

fn main() {
    let knobs = a2cid2::config::env::knobs();
    let full = knobs.bench_full;
    let smoke = knobs.bench_smoke;

    let n_workers = 4usize;
    let (steps, ds_dim) = if smoke {
        (200u64, 512usize)
    } else if full {
        (4_000, 16_384)
    } else {
        (1_000, 4_096)
    };
    let pace = Duration::from_micros(250);
    let reader_counts: &[usize] = if smoke {
        &[2]
    } else if full {
        &[1, 2, 4, 8]
    } else {
        &[1, 4]
    };

    let mut bench = Bench::new();

    // ---- Baseline: the fleet alone -----------------------------------
    let base = run_loaded(n_workers, steps, pace, 0, ds_dim);
    let dim = base.model_dim;
    bench.note_row(
        "train throughput (no readers)",
        dim,
        base.wall_secs / (base.grads_total.max(1) as f64),
        &format!("{:.0} grads/s", base.grads_per_sec),
        base.grads_per_sec,
        &format!("{n_workers} workers, {steps} steps, paced {}us", pace.as_micros()),
    );

    // ---- Loaded: snapshot readers vs the same fleet ------------------
    let mut worst_degradation = 0.0f64;
    for &r in reader_counts {
        let loaded = run_loaded(n_workers, steps, pace, r, ds_dim);
        bench.note_row(
            &format!("train throughput ({r} readers)"),
            dim,
            loaded.wall_secs / (loaded.grads_total.max(1) as f64),
            &format!("{:.0} grads/s", loaded.grads_per_sec),
            loaded.grads_per_sec,
            &format!("{} consensus reads landed", loaded.reads),
        );
        bench.note_row(
            &format!("snapshot QPS ({r} readers)"),
            dim,
            if loaded.qps > 0.0 { 1.0 / loaded.qps } else { 0.0 },
            &format!("{:.0}/s", loaded.qps),
            loaded.qps,
            "consensus_snapshot off lock-free cells",
        );
        let degradation =
            (base.grads_per_sec - loaded.grads_per_sec) / base.grads_per_sec * 100.0;
        worst_degradation = worst_degradation.max(degradation);
        bench.note_row(
            &format!("train degradation ({r} readers)"),
            dim,
            loaded.wall_secs / (loaded.grads_total.max(1) as f64),
            &format!("{degradation:.1}%"),
            degradation,
            "vs no readers; target <= 10%",
        );
    }
    bench.note_row(
        "train degradation (worst)",
        dim,
        0.0,
        &format!("{worst_degradation:.1}%"),
        worst_degradation,
        "max over reader counts; target <= 10%",
    );

    // ---- Post-run serving: the daemon after `stop` -------------------
    // The cells stay registered after the run returns, so `snapshot` and
    // `checkpoint` keep working; these rows time that quiescent path.
    let ctrl = base.ctrl;
    let iters = if smoke { 20 } else { 200 };
    let t = time_it(3, iters, || {
        std::hint::black_box(ctrl.consensus_snapshot());
    });
    // n cell reads + one mean write per element.
    bench.row(
        "consensus snapshot (post-run)",
        dim,
        t,
        4 * dim * (n_workers + 1),
        &format!("mean over {n_workers} cells"),
    );

    // ---- Runtime checkpoint path -------------------------------------
    let ck = RuntimeCheckpoint {
        n_workers: n_workers as u32,
        seed: 11,
        grads: base.grads_total,
        params: base.avg_params.clone(),
    };
    let t = time_it(3, iters, || {
        let bytes = ck.to_bytes();
        let back = RuntimeCheckpoint::from_bytes(&bytes).unwrap();
        std::hint::black_box(back.params[0]);
    });
    bench.row("checkpoint encode+decode", dim, t, 2 * 4 * dim, "in-memory round trip");

    let dir = std::env::temp_dir().join(format!("a2serve_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create bench temp dir");
    let path = dir.join("bench.ckpt");
    let t = time_it(2, iters.min(50), || {
        ck.save(&path).expect("checkpoint save");
        let back = RuntimeCheckpoint::load(&path).expect("checkpoint load");
        std::hint::black_box(back.grads);
    });
    bench.row("checkpoint save+load", dim, t, 2 * 4 * dim, "write_atomic staging + rename");
    std::fs::remove_dir_all(&dir).ok();

    let t = time_it(3, iters, || {
        std::hint::black_box(fnv1a_params(&ck.params));
    });
    bench.row("fnv1a checksum", dim, t, 4 * dim, "1R, the `snapshot` reply hash");

    bench.table.print();
    if worst_degradation > 10.0 {
        println!(
            "WARNING: snapshot readers cost {worst_degradation:.1}% training throughput \
             (target <= 10%)"
        );
    }
    match bench.write_json("BENCH_serve.json") {
        Ok(()) => println!("wrote BENCH_serve.json ({} rows)", bench.json.len()),
        Err(e) => println!("(failed to write BENCH_serve.json: {e})"),
    }
}
