//! The algorithm zoo head-to-head as a bench target: every update rule
//! (`adpsgd`, `a2cid2`, `localsgd:4`, `allreduce`) on the shared
//! consensus race and the ring / churn training units, at the
//! env-selected scale. Resolved through the experiment registry, which
//! prints the table and maintains the `BENCH_compare.json` artifact
//! (cargo runs benches with cwd = the package root, so the file lands
//! under `rust/`) for CI to archive.
a2cid2::bench_main!(compare);
