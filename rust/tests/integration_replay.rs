//! The determinism replay, promoted from CI into `cargo test`: the
//! seeded churn scenario (topology switch + dropout window + a
//! leave/join cycle) must produce BIT-identical output across kernel-pool
//! widths (1 and 4), kernel backends (scalar reference vs the
//! auto-dispatched SIMD path), AND thread affinity (`A2CID2_PIN=0/1` —
//! pinned lanes + first-touch placement), and the FNV checksum over the
//! final
//! averaged parameters must reproduce the checked-in golden value
//! (`rust/oracle/replay_golden.toml` — blessed on first run, pinned
//! thereafter; see `testing::golden`).
//!
//! Both the pool width (`A2CID2_POOL_THREADS`) and the kernel backend
//! (`A2CID2_KERNEL_BACKEND`) are latched process-wide on first use, so
//! each cell of the matrix runs the real `a2cid2` binary as a
//! subprocess — which also makes this an end-to-end CLI test of the
//! `replay` subcommand, exactly what CI's `determinism` job drives.
//! Because the SIMD backend is bit-identical to scalar by contract (no
//! FMA, no reassociation; see `gossip::vecops`), all four cells share
//! the same golden checksum — no backend-specific keys exist.

use std::path::Path;
use std::process::Command;

use a2cid2::testing::golden::{check_or_bless, GoldenStatus};

/// The CI determinism scenario: ring→exponential switch at t=0.5, a
/// dropout window, 25% of the fleet leaving at t=0.3 and re-joining at
/// t=0.7.
const SCENARIO: &str = "ring@0,exponential@0.5;drop=0.2:0.25:0.75:7;leave=0.25:0.3:1;join=0.25:0.7";

/// `--dim 65536` gives a 131074-parameter synthetic model — past
/// `POOL_MIN_DIM` (131072), so every kernel actually shards and a chunk
/// boundary that depended on lane count would flip the checksum.
const ARGS: [&str; 10] = [
    "replay", "--scenario", SCENARIO, "--workers", "8", "--steps", "40", "--seed", "7", "--dim",
];

fn replay_at(width: &str, backend: &str, pin: &str) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_a2cid2"))
        .args(ARGS)
        .arg("65536")
        .env("A2CID2_POOL_THREADS", width)
        .env("A2CID2_KERNEL_BACKEND", backend)
        .env("A2CID2_PIN", pin)
        .output()
        .expect("spawn a2cid2 replay");
    assert!(
        out.status.success(),
        "replay at pool width {width} / backend '{backend}' / pin {pin} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("replay output is UTF-8")
}

fn extract_checksum(stdout: &str) -> String {
    let tail = stdout
        .split("checksum=")
        .nth(1)
        .unwrap_or_else(|| panic!("no checksum in replay output:\n{stdout}"));
    let sum: String = tail.chars().take_while(|c| c.is_ascii_hexdigit()).collect();
    assert_eq!(sum.len(), 16, "FNV-1a checksum is 16 hex digits: '{sum}'");
    sum
}

#[test]
fn churn_replay_reproduces_golden_checksums_across_widths_and_backends() {
    // The reference cell: serial scalar, affinity off.
    let reference = replay_at("1", "scalar", "0");
    // The probe must actually engage the pool, or the width axis tests
    // nothing. (Backend engagement is asserted separately below: a
    // typo'd backend name panics the subprocess, failing replay_at.)
    let pooled_scalar = replay_at("4", "scalar", "0");
    assert!(
        pooled_scalar.contains("pool ON"),
        "probe did not engage the pool:\n{pooled_scalar}"
    );

    // Cross-width, cross-backend, and cross-affinity bit-determinism:
    // the entire stdout — event counts, checksum, everything printed —
    // must be identical in every cell. `A2CID2_PIN=1` pins pool lanes
    // and worker threads and routes buffer zeroing through first-touch
    // placement; none of that may move a bit. This is the in-process
    // half of the contract; no CI dependency.
    for (width, backend, pin) in [
        ("4", "scalar", "0"),
        ("1", "auto", "0"),
        ("4", "auto", "0"),
        ("4", "scalar", "1"),
        ("4", "auto", "1"),
    ] {
        let run = if width == "4" && backend == "scalar" && pin == "0" {
            pooled_scalar.clone()
        } else {
            replay_at(width, backend, pin)
        };
        assert_eq!(
            reference, run,
            "replay output diverged: pool width {width}, backend '{backend}', \
             pin {pin} vs serial scalar unpinned"
        );
    }

    // Cross-commit bit-determinism: the checksum must match the
    // checked-in golden value (blessed on the first run). The pool1/pool4
    // key pair predates the backend axis; both keys pin the same value
    // and the SIMD cells share it by the bit-identity contract.
    let checksum = extract_checksum(&reference);
    let golden = Path::new(env!("CARGO_MANIFEST_DIR")).join("oracle/replay_golden.toml");
    for key in [
        "churn_replay_w8_s40_seed7_dim65536_pool1",
        "churn_replay_w8_s40_seed7_dim65536_pool4",
    ] {
        match check_or_bless(&golden, key, &checksum).unwrap_or_else(|e| panic!("{e:#}")) {
            GoldenStatus::Matched => {}
            GoldenStatus::Blessed => println!(
                "blessed {key} = {checksum} in {} — commit the file to pin it",
                golden.display()
            ),
        }
    }
}

/// One interrupted replay: run to tick `at`, checkpoint, exit; then a
/// SECOND process restores from the file and runs to completion.
/// Returns the resumed process's full stdout.
fn replay_interrupted_at(width: &str, at: &str, ckpt: &Path) -> String {
    let ckpt_str = ckpt.to_str().expect("utf-8 temp path");
    let out = Command::new(env!("CARGO_BIN_EXE_a2cid2"))
        .args(ARGS)
        .arg("65536")
        .args(["--checkpoint-at", at, "--checkpoint", ckpt_str])
        .env("A2CID2_POOL_THREADS", width)
        .env("A2CID2_KERNEL_BACKEND", "auto")
        .env("A2CID2_PIN", "0")
        .output()
        .expect("spawn a2cid2 replay (checkpoint leg)");
    assert!(
        out.status.success(),
        "checkpoint leg at width {width} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert!(
        stdout.contains(&format!("checkpointed at tick {at}")),
        "interruption did not land at tick {at}:\n{stdout}"
    );
    assert!(
        !stdout.contains("checksum="),
        "the interrupted leg must exit before finishing:\n{stdout}"
    );
    let out = Command::new(env!("CARGO_BIN_EXE_a2cid2"))
        .args(ARGS)
        .arg("65536")
        .args(["--restore", ckpt_str])
        .env("A2CID2_POOL_THREADS", width)
        .env("A2CID2_KERNEL_BACKEND", "auto")
        .env("A2CID2_PIN", "0")
        .output()
        .expect("spawn a2cid2 replay (resume leg)");
    assert!(
        out.status.success(),
        "resume leg at width {width} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).unwrap()
}

#[test]
fn checkpoint_restore_reproduces_the_uninterrupted_golden_checksum() {
    // The tentpole determinism contract: interrupt the churn replay at an
    // arbitrary tick, persist the full engine state (params, momentum,
    // sampler cursors, RNG positions, event queue), restore in a FRESH
    // process, run to completion — and land on the SAME golden checksum
    // as an uninterrupted run, at pool widths 1 and 4. The golden keys
    // are shared with the uninterrupted test above, so a divergence
    // between the two paths cannot hide behind a re-bless.
    let dir = std::env::temp_dir().join(format!("a2ckpt_replay_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let golden = Path::new(env!("CARGO_MANIFEST_DIR")).join("oracle/replay_golden.toml");
    for (width, key) in [
        ("1", "churn_replay_w8_s40_seed7_dim65536_pool1"),
        ("4", "churn_replay_w8_s40_seed7_dim65536_pool4"),
    ] {
        let ckpt = dir.join(format!("interrupt_w{width}.ckpt"));
        // Tick 137 sits mid-run, past the first scenario updates — an
        // arbitrary but fixed interruption point.
        let resumed = replay_interrupted_at(width, "137", &ckpt);
        assert!(resumed.contains("restored from"), "{resumed}");
        let checksum = extract_checksum(&resumed);
        match check_or_bless(&golden, key, &checksum).unwrap_or_else(|e| panic!("{e:#}")) {
            GoldenStatus::Matched => {}
            GoldenStatus::Blessed => println!(
                "blessed {key} = {checksum} via the RESUMED path — commit to pin it"
            ),
        }
        // Resumed event counts must match the uninterrupted run's too —
        // the checksum pins the parameters, these pin the trace.
        let uninterrupted = replay_at(width, "auto", "0");
        let tail = |s: &str| {
            s.lines()
                .find(|l| l.contains("checksum="))
                .map(String::from)
                .unwrap_or_default()
        };
        assert_eq!(
            tail(&resumed),
            tail(&uninterrupted),
            "resumed grads/comms/net_updates/checksum line diverged at width {width}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
