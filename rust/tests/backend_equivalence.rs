//! Backend-equivalence property tests — the bit-identity contract of the
//! kernel-backend layer (`gossip::vecops`).
//!
//! Every kernel must produce **bit-identical** results on every available
//! backend (the explicit SIMD path does elementwise mul+add per lane with
//! no FMA contraction and no reassociation, so lane-parallel evaluation
//! commutes exactly with the scalar reference). The lengths exercised
//! include zero, lengths below one SIMD lane width, exact multiples, and
//! ragged tails around every plausible lane width (4 / 8 / 16), so the
//! vector-body + scalar-tail seam is crossed in both directions.
//!
//! `sq_dist` is a reduction: it must be bit-identical across backends
//! *and* pool widths because its striped 8-lane f64 accumulation order is
//! fixed by contract, independent of how the work is vectorized.

use a2cid2::gossip::vecops::{self, available_backends, scalar_backend, KernelBackend};
use a2cid2::rng::{standard_normal, Xoshiro256};

/// Lengths crossing every lane-width boundary: empty, sub-lane, exact
/// multiples of 4/8/16, off-by-one around them, and large-ish odd sizes.
const LENS: [usize; 18] = [0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 33, 64, 100, 1000, 4097];

fn rv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..n).map(|_| standard_normal(&mut rng) as f32).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The non-scalar backends to compare against the scalar reference (may
/// be empty on targets without a SIMD implementation — the test then
/// degenerates to scalar-vs-scalar, which still pins the harness).
fn others() -> Vec<&'static dyn KernelBackend> {
    available_backends()
        .into_iter()
        .filter(|b| b.name() != scalar_backend().name())
        .collect()
}

#[test]
fn axpy_bit_identical_across_backends() {
    for be in available_backends() {
        for (i, &n) in LENS.iter().enumerate() {
            let x = rv(n, 100 + i as u64);
            let y0 = rv(n, 200 + i as u64);
            let mut y_ref = y0.clone();
            scalar_backend().axpy(0.37, &x, &mut y_ref);
            let mut y = y0.clone();
            be.axpy(0.37, &x, &mut y);
            assert_eq!(bits(&y), bits(&y_ref), "axpy len={n} backend={}", be.name());
        }
    }
}

#[test]
fn mix_into_bit_identical_across_backends() {
    for be in available_backends() {
        for (i, &n) in LENS.iter().enumerate() {
            let x = rv(n, 300 + i as u64);
            let xt = rv(n, 400 + i as u64);
            let mut out_ref = vec![0.0f32; n];
            scalar_backend().mix_into(0.8, 0.2, &x, &xt, &mut out_ref);
            let mut out = vec![f32::NAN; n]; // output-only: stale bits must not leak
            be.mix_into(0.8, 0.2, &x, &xt, &mut out);
            assert_eq!(bits(&out), bits(&out_ref), "mix_into len={n} backend={}", be.name());
        }
    }
}

#[test]
fn grad_step_bit_identical_across_backends() {
    for be in available_backends() {
        for (i, &n) in LENS.iter().enumerate() {
            let g = rv(n, 500 + i as u64);
            let x0 = rv(n, 600 + i as u64);
            let t0 = rv(n, 700 + i as u64);
            let (mut x_ref, mut t_ref) = (x0.clone(), t0.clone());
            scalar_backend().grad_step(0.043, &g, &mut x_ref, &mut t_ref);
            let (mut x, mut t) = (x0.clone(), t0.clone());
            be.grad_step(0.043, &g, &mut x, &mut t);
            assert_eq!(bits(&x), bits(&x_ref), "grad_step x len={n} backend={}", be.name());
            assert_eq!(bits(&t), bits(&t_ref), "grad_step xt len={n} backend={}", be.name());
        }
    }
}

#[test]
fn comm_only_bit_identical_across_backends() {
    for be in available_backends() {
        for (i, &n) in LENS.iter().enumerate() {
            let xj = rv(n, 800 + i as u64);
            let x0 = rv(n, 900 + i as u64);
            let t0 = rv(n, 1000 + i as u64);
            let (mut x_ref, mut t_ref) = (x0.clone(), t0.clone());
            scalar_backend().comm_only(0.5, 1.7, &xj, &mut x_ref, &mut t_ref);
            let (mut x, mut t) = (x0.clone(), t0.clone());
            be.comm_only(0.5, 1.7, &xj, &mut x, &mut t);
            assert_eq!(bits(&x), bits(&x_ref), "comm_only x len={n} backend={}", be.name());
            assert_eq!(bits(&t), bits(&t_ref), "comm_only xt len={n} backend={}", be.name());
        }
    }
}

#[test]
fn mix_pair_bit_identical_across_backends() {
    for be in available_backends() {
        for (i, &n) in LENS.iter().enumerate() {
            let x0 = rv(n, 1100 + i as u64);
            let t0 = rv(n, 1200 + i as u64);
            let (mut x_ref, mut t_ref) = (x0.clone(), t0.clone());
            scalar_backend().mix_pair(0.77, 0.23, &mut x_ref, &mut t_ref);
            let (mut x, mut t) = (x0.clone(), t0.clone());
            be.mix_pair(0.77, 0.23, &mut x, &mut t);
            assert_eq!(bits(&x), bits(&x_ref), "mix_pair x len={n} backend={}", be.name());
            assert_eq!(bits(&t), bits(&t_ref), "mix_pair xt len={n} backend={}", be.name());
        }
    }
}

#[test]
fn mix_grad_bit_identical_across_backends() {
    for be in available_backends() {
        for (i, &n) in LENS.iter().enumerate() {
            let g = rv(n, 1300 + i as u64);
            let x0 = rv(n, 1400 + i as u64);
            let t0 = rv(n, 1500 + i as u64);
            let (mut x_ref, mut t_ref) = (x0.clone(), t0.clone());
            scalar_backend().mix_grad(0.9, 0.1, 0.021, &g, &mut x_ref, &mut t_ref);
            let (mut x, mut t) = (x0.clone(), t0.clone());
            be.mix_grad(0.9, 0.1, 0.021, &g, &mut x, &mut t);
            assert_eq!(bits(&x), bits(&x_ref), "mix_grad x len={n} backend={}", be.name());
            assert_eq!(bits(&t), bits(&t_ref), "mix_grad xt len={n} backend={}", be.name());
        }
    }
}

#[test]
fn comm_apply_fused_bit_identical_across_backends() {
    for be in available_backends() {
        for (i, &n) in LENS.iter().enumerate() {
            let xj = rv(n, 1600 + i as u64);
            let x0 = rv(n, 1700 + i as u64);
            let t0 = rv(n, 1800 + i as u64);
            let (mut x_ref, mut t_ref) = (x0.clone(), t0.clone());
            scalar_backend().comm_apply_fused(0.85, 0.15, 0.5, 1.3, &xj, &mut x_ref, &mut t_ref);
            let (mut x, mut t) = (x0.clone(), t0.clone());
            be.comm_apply_fused(0.85, 0.15, 0.5, 1.3, &xj, &mut x, &mut t);
            assert_eq!(bits(&x), bits(&x_ref), "comm_apply x len={n} backend={}", be.name());
            assert_eq!(bits(&t), bits(&t_ref), "comm_apply xt len={n} backend={}", be.name());
        }
    }
}

#[test]
fn mix_comm_bit_identical_across_backends() {
    for be in available_backends() {
        for (i, &n) in LENS.iter().enumerate() {
            let xj = rv(n, 1900 + i as u64);
            let x0 = rv(n, 2000 + i as u64);
            let t0 = rv(n, 2100 + i as u64);
            let (mut x_ref, mut t_ref) = (x0.clone(), t0.clone());
            scalar_backend().mix_comm(0.85, 0.15, 0.5, 1.3, &xj, &mut x_ref, &mut t_ref);
            let (mut x, mut t) = (x0.clone(), t0.clone());
            be.mix_comm(0.85, 0.15, 0.5, 1.3, &xj, &mut x, &mut t);
            assert_eq!(bits(&x), bits(&x_ref), "mix_comm x len={n} backend={}", be.name());
            assert_eq!(bits(&t), bits(&t_ref), "mix_comm xt len={n} backend={}", be.name());
        }
    }
}

#[test]
fn comm_pair_fused_bit_identical_across_backends() {
    for be in available_backends() {
        for (i, &n) in LENS.iter().enumerate() {
            let a0 = rv(n, 2200 + i as u64);
            let ta0 = rv(n, 2300 + i as u64);
            let b0 = rv(n, 2400 + i as u64);
            let tb0 = rv(n, 2500 + i as u64);
            let (mut a_ref, mut ta_ref) = (a0.clone(), ta0.clone());
            let (mut b_ref, mut tb_ref) = (b0.clone(), tb0.clone());
            scalar_backend().comm_pair_fused(
                0.9, 0.1, 0.8, 0.2, 0.5, 1.5, &mut a_ref, &mut ta_ref, &mut b_ref, &mut tb_ref,
            );
            let (mut a, mut ta) = (a0.clone(), ta0.clone());
            let (mut b, mut tb) = (b0.clone(), tb0.clone());
            be.comm_pair_fused(0.9, 0.1, 0.8, 0.2, 0.5, 1.5, &mut a, &mut ta, &mut b, &mut tb);
            assert_eq!(bits(&a), bits(&a_ref), "comm_pair a len={n} backend={}", be.name());
            assert_eq!(bits(&ta), bits(&ta_ref), "comm_pair ta len={n} backend={}", be.name());
            assert_eq!(bits(&b), bits(&b_ref), "comm_pair b len={n} backend={}", be.name());
            assert_eq!(bits(&tb), bits(&tb_ref), "comm_pair tb len={n} backend={}", be.name());
        }
    }
}

#[test]
fn average_pair_bit_identical_across_backends() {
    for be in available_backends() {
        for (i, &n) in LENS.iter().enumerate() {
            let x0 = rv(n, 2600 + i as u64);
            let y0 = rv(n, 2700 + i as u64);
            let (mut x_ref, mut y_ref) = (x0.clone(), y0.clone());
            scalar_backend().average_pair(&mut x_ref, &mut y_ref);
            let (mut x, mut y) = (x0.clone(), y0.clone());
            be.average_pair(&mut x, &mut y);
            assert_eq!(bits(&x), bits(&x_ref), "average x len={n} backend={}", be.name());
            assert_eq!(bits(&y), bits(&y_ref), "average y len={n} backend={}", be.name());
        }
    }
}

#[test]
fn sq_dist_bit_identical_across_backends() {
    for be in available_backends() {
        for (i, &n) in LENS.iter().enumerate() {
            let x = rv(n, 2800 + i as u64);
            let y = rv(n, 2900 + i as u64);
            let d_ref = scalar_backend().sq_dist(&x, &y);
            let d = be.sq_dist(&x, &y);
            assert_eq!(
                d.to_bits(),
                d_ref.to_bits(),
                "sq_dist len={n} backend={}",
                be.name()
            );
        }
    }
}

/// The dispatched free functions must agree bit-for-bit with the scalar
/// reference regardless of which backend the process latched — this is
/// what makes the golden replay checksums backend-independent.
#[test]
fn dispatched_free_fns_match_scalar_reference() {
    let n = 4097;
    let x = rv(n, 3000);
    let xt = rv(n, 3100);
    let xj = rv(n, 3200);
    let (mut x_ref, mut t_ref) = (x.clone(), xt.clone());
    scalar_backend().comm_apply_fused(0.85, 0.15, 0.5, 1.3, &xj, &mut x_ref, &mut t_ref);
    let (mut xd, mut td) = (x.clone(), xt.clone());
    vecops::comm_apply_fused(0.85, 0.15, 0.5, 1.3, &xj, &mut xd, &mut td);
    assert_eq!(bits(&xd), bits(&x_ref), "dispatched via {}", vecops::backend_name());
    assert_eq!(bits(&td), bits(&t_ref), "dispatched via {}", vecops::backend_name());
}

/// AVX-512 coverage is implicit above (it joins `available_backends()`
/// when compiled and detected), which makes its ABSENCE silent. This
/// test prints a visible skip marker when the backend is missing — so a
/// CI log answers "did the 512-bit path actually run?" at a glance —
/// and pins one dense end-to-end identity check when it is present.
#[test]
fn avx512_backend_bit_identical_or_visibly_skipped() {
    let Some(be) = available_backends().into_iter().find(|b| b.name() == "avx512") else {
        println!("SKIPPED: avx512 backend unavailable on this CPU/toolchain");
        return;
    };
    for (i, &n) in [0usize, 15, 16, 17, 31, 33, 4097].iter().enumerate() {
        let a0 = rv(n, 5000 + i as u64);
        let ta0 = rv(n, 5100 + i as u64);
        let b0 = rv(n, 5200 + i as u64);
        let tb0 = rv(n, 5300 + i as u64);
        let (mut a_ref, mut ta_ref) = (a0.clone(), ta0.clone());
        let (mut b_ref, mut tb_ref) = (b0.clone(), tb0.clone());
        scalar_backend().comm_pair_fused(
            0.9, 0.1, 0.8, 0.2, 0.5, 1.5, &mut a_ref, &mut ta_ref, &mut b_ref, &mut tb_ref,
        );
        let (mut a, mut ta) = (a0.clone(), ta0.clone());
        let (mut b, mut tb) = (b0.clone(), tb0.clone());
        be.comm_pair_fused(0.9, 0.1, 0.8, 0.2, 0.5, 1.5, &mut a, &mut ta, &mut b, &mut tb);
        assert_eq!(bits(&a), bits(&a_ref), "avx512 comm_pair a len={n}");
        assert_eq!(bits(&ta), bits(&ta_ref), "avx512 comm_pair ta len={n}");
        assert_eq!(bits(&b), bits(&b_ref), "avx512 comm_pair b len={n}");
        assert_eq!(bits(&tb), bits(&tb_ref), "avx512 comm_pair tb len={n}");
        assert_eq!(
            be.sq_dist(&a0, &b0).to_bits(),
            scalar_backend().sq_dist(&a0, &b0).to_bits(),
            "avx512 sq_dist len={n}"
        );
    }
}

/// `sq_dist` across pool widths: the pooled consensus path never calls
/// it chunked (the striped order is a whole-slice contract), but the
/// large-dim sizes here overlap the pool threshold region so any future
/// chunking of the reduction would have to preserve these exact bits.
#[test]
fn sq_dist_bit_identical_at_pool_scale_dims() {
    use a2cid2::gossip::pool::CHUNK;
    for &n in &[CHUNK - 1, CHUNK, 2 * CHUNK + 3] {
        let x = rv(n, 3300);
        let y = rv(n, 3400);
        let d_ref = scalar_backend().sq_dist(&x, &y);
        for be in others() {
            assert_eq!(
                be.sq_dist(&x, &y).to_bits(),
                d_ref.to_bits(),
                "sq_dist dim={n} backend={}",
                be.name()
            );
        }
        assert_eq!(vecops::sq_dist(&x, &y).to_bits(), d_ref.to_bits(), "dispatched dim={n}");
    }
}
