//! Allocation accounting for the monitor's steady-state read path.
//!
//! The runtime's monitor loop used to materialize a `Vec<Vec<f32>>` of
//! every worker's parameters each tick. The published-snapshot rework
//! replaces that with [`ConsensusAccumulator`] streaming over each cell's
//! seqlock buffer — and this test pins the contract with a counting
//! global allocator: after the first (warm-up) measurement, further
//! ticks perform ZERO heap allocations.
//!
//! This lives in its own integration-test binary because the
//! `#[global_allocator]` is process-wide, and everything runs in ONE
//! `#[test]` so no concurrent test thread can pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use a2cid2::runtime::{ConsensusAccumulator, SnapshotCell};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn published_read_paths_allocate_nothing_in_steady_state() {
    // --- Monitor consensus ticks -------------------------------------
    let n = 8;
    let dim = 4096;
    let cells: Vec<SnapshotCell> = (0..n)
        .map(|w| {
            let row: Vec<f32> = (0..dim).map(|d| (w * dim + d) as f32 * 1e-3).collect();
            SnapshotCell::new(&row)
        })
        .collect();

    let mut acc = ConsensusAccumulator::new();
    // Warm-up tick: the accumulator sizes its persistent buffers here.
    let warm = acc.measure(cells.iter());
    assert!(warm > 0.0, "distinct rows have positive consensus distance");

    let before = ALLOCS.load(Ordering::Relaxed);
    let mut last = 0.0;
    for _ in 0..100 {
        last = acc.measure(cells.iter());
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(after - before, 0, "steady-state consensus ticks must not allocate");
    assert!((last - warm).abs() <= 1e-9 * warm, "same snapshots, same measure");

    // --- Gradient-thread snapshot reads + publishes ------------------
    let cell = &cells[0];
    let mut dst = Vec::new();
    cell.read_into(&mut dst); // sizes the destination

    let before = ALLOCS.load(Ordering::Relaxed);
    for k in 0..100u32 {
        // Publishing reuses the cell's two fixed buffers; reading reuses
        // the caller's sized destination.
        cell.publish(&dst);
        cell.read_into(&mut dst);
        std::hint::black_box(k);
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(after - before, 0, "publish/read cycles must not allocate");
}
