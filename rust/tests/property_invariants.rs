//! Property-based invariants of the paper's algorithm and its substrates,
//! via the in-tree mini property harness (`a2cid2::testing`; proptest is
//! unreachable offline — see DESIGN.md §3).

use a2cid2::gossip::dynamics::{comm_event, WorkerState};
use a2cid2::gossip::{consensus_distance_sq, vecops, AcidParams, Mixer};
use a2cid2::graph::{Graph, Topology};
use a2cid2::testing::{check, default_cases, f64_in, usize_in, vec_f32};

/// The mixing flow is doubly stochastic and conserves x + x̃ for any
/// (η, Δt).
#[test]
fn prop_mixing_conserves_mass() {
    check("mixing-mass", default_cases(), |rng| {
        let eta = f64_in(rng, 0.0, 5.0);
        let dt = f64_in(rng, 0.0, 10.0);
        let dim = usize_in(rng, 1, 64);
        let mut x = vec_f32(rng, dim, 3.0);
        let mut xt = vec_f32(rng, dim, 3.0);
        let sums: Vec<f32> = x.iter().zip(&xt).map(|(a, b)| a + b).collect();
        let w = Mixer::new(eta).weights(dt);
        assert!((w.wa + w.wb - 1.0).abs() < 1e-6);
        vecops::mix_pair(w.wa, w.wb, &mut x, &mut xt);
        for (i, s) in sums.iter().enumerate() {
            assert!(
                (x[i] + xt[i] - s).abs() < 1e-4,
                "mass violated at {i}: {} vs {s}",
                x[i] + xt[i]
            );
        }
    });
}

/// A communication event conserves the global sums Σ(x + x̃) for ANY
/// (α, α̃) — the antisymmetry of the pairwise update.
#[test]
fn prop_comm_event_conserves_global_sums() {
    check("comm-conserves-sums", default_cases(), |rng| {
        let chi1 = f64_in(rng, 1.0, 100.0);
        let chi2 = f64_in(rng, 0.5, chi1);
        let p = AcidParams::accelerated(chi1, chi2);
        let mixer = Mixer::new(p.eta);
        let dim = usize_in(rng, 1, 32);
        let mut a = WorkerState::new(vec_f32(rng, dim, 2.0));
        let mut b = WorkerState::new(vec_f32(rng, dim, 2.0));
        // Desynchronize.
        a.apply_grad(f64_in(rng, 0.0, 0.5), 0.01, &vec_f32(rng, dim, 1.0), &mixer);
        let sum = |w: &WorkerState| -> f64 {
            w.x.iter().chain(&w.xt).map(|&v| v as f64).sum()
        };
        let before = sum(&a) + sum(&b);
        comm_event(&mut a, &mut b, f64_in(rng, 0.5, 2.0), &p, &mixer);
        let after = sum(&a) + sum(&b);
        assert!(
            (before - after).abs() < 1e-3 * before.abs().max(1.0),
            "{before} -> {after}"
        );
    });
}

/// Gossip-only dynamics contract consensus on any connected topology, for
/// both the baseline and the accelerated parameters.
#[test]
fn prop_gossip_contracts_consensus() {
    check("gossip-contracts", 24, |rng| {
        let n = usize_in(rng, 3, 10);
        let topo = match usize_in(rng, 0, 4) {
            0 => Topology::Ring,
            1 => Topology::Complete,
            2 => Topology::Path,
            _ => Topology::Star,
        };
        let graph = Graph::build(&topo, n).unwrap();
        let s = graph.spectrum(1.0);
        let accelerated = usize_in(rng, 0, 2) == 1;
        let p = if accelerated {
            AcidParams::from_spectrum(&s)
        } else {
            AcidParams::baseline()
        };
        let mixer = Mixer::new(p.eta);
        let dim = usize_in(rng, 1, 16);
        let mut workers: Vec<WorkerState> =
            (0..n).map(|_| WorkerState::new(vec_f32(rng, dim, 5.0))).collect();
        let d0 = consensus_distance_sq(&workers);
        // Many rounds of uniformly random edge activations.
        let mut t = 0.0;
        for _ in 0..60 * n {
            t += 0.05;
            let &(i, j) = &graph.edges[usize_in(rng, 0, graph.edges.len())];
            let (l, r) = workers.split_at_mut(j);
            comm_event(&mut l[i], &mut r[0], t, &p, &mixer);
        }
        for w in &mut workers {
            w.mix_to(t, &mixer);
        }
        let d1 = consensus_distance_sq(&workers);
        assert!(
            d1 < 0.5 * d0 + 1e-9,
            "{} n={n} acc={accelerated}: consensus {d0} -> {d1}",
            topo.name()
        );
    });
}

/// χ₂ ≤ χ₁ on random connected Erdős–Rényi graphs at random rates
/// (Eq. 3's inequality) and the spectral gap is positive when connected.
#[test]
fn prop_chi2_le_chi1_random_graphs() {
    check("chi2-le-chi1", 24, |rng| {
        let n = usize_in(rng, 4, 14);
        let p = f64_in(rng, 0.3, 0.9);
        let seed = rng.next_u64();
        let graph = Graph::build(&Topology::ErdosRenyi { p, seed }, n).unwrap();
        let rate = f64_in(rng, 0.1, 4.0);
        let s = graph.spectrum(rate);
        assert!(s.chi1 > 0.0 && s.chi2 > 0.0);
        assert!(
            s.chi2 <= s.chi1 * (1.0 + 1e-6),
            "chi2={} chi1={}",
            s.chi2,
            s.chi1
        );
        assert!(s.lambda2 > 0.0, "connected ⇒ positive spectral gap");
    });
}

/// The simulator is a pure function of its seed: identical seeds replay
/// identical trajectories (routing/batching/state determinism).
#[test]
fn prop_simulator_deterministic_replay() {
    use a2cid2::config::{Method, Task};
    use a2cid2::data::{GaussianMixture, Sharding};
    use a2cid2::model::Logistic;
    use std::sync::Arc;
    check("sim-replay", 6, |rng| {
        let seed = rng.next_u64() % 1000;
        let cfg = a2cid2::config::ExperimentConfig {
            n_workers: 4,
            topology: Topology::Ring,
            method: if seed % 2 == 0 { Method::Acid } else { Method::AsyncBaseline },
            task: Task::CifarLike,
            comm_rate: 1.0,
            batch_size: 4,
            base_lr: 0.05,
            momentum: 0.9,
            weight_decay: 0.0,
            steps_per_worker: 30,
            sharding: Sharding::FullShuffled,
            dataset_size: 128,
            seed,
            compute_jitter: 0.2,
            scenario: None,
            algorithm: None,
        };
        let ds = Arc::new(GaussianMixture::cifar_like().sample(128, 1));
        let shards = cfg.sharding.assign(&ds, 4, seed);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let a = a2cid2::simulator::run_simulation(&cfg, model.clone(), &shards).unwrap();
        let b = a2cid2::simulator::run_simulation(&cfg, model, &shards).unwrap();
        assert_eq!(a.avg_params, b.avg_params);
        assert_eq!(a.n_comms, b.n_comms);
        assert_eq!(a.grads_per_worker, b.grads_per_worker);
    });
}

/// Fused vecops match their unfused compositions for random inputs
/// (the L3 mirror of the L1 kernel-vs-ref pytest).
#[test]
fn prop_fused_ops_match_composition() {
    check("fused-vs-composed", default_cases(), |rng| {
        let dim = usize_in(rng, 1, 128);
        let wa = (0.5 + 0.5 * rng.next_f64()) as f32;
        let wb = 1.0 - wa;
        let gamma = rng.next_f32() * 0.5;
        let alpha = rng.next_f32();
        let alpha_tilde = rng.next_f32() * 4.0;
        let g = vec_f32(rng, dim, 1.0);
        let xj = vec_f32(rng, dim, 1.0);
        let x0 = vec_f32(rng, dim, 2.0);
        let t0 = vec_f32(rng, dim, 2.0);

        // mix_grad
        let (mut x1, mut t1) = (x0.clone(), t0.clone());
        vecops::mix_grad(wa, wb, gamma, &g, &mut x1, &mut t1);
        let (mut x2, mut t2) = (x0.clone(), t0.clone());
        vecops::mix_pair(wa, wb, &mut x2, &mut t2);
        vecops::axpy(-gamma, &g, &mut x2);
        vecops::axpy(-gamma, &g, &mut t2);
        for i in 0..dim {
            assert!((x1[i] - x2[i]).abs() < 1e-4);
            assert!((t1[i] - t2[i]).abs() < 1e-4);
        }

        // mix_comm
        let (mut x1, mut t1) = (x0.clone(), t0.clone());
        vecops::mix_comm(wa, wb, alpha, alpha_tilde, &xj, &mut x1, &mut t1);
        let (mut x2, mut t2) = (x0, t0);
        vecops::mix_pair(wa, wb, &mut x2, &mut t2);
        let m: Vec<f32> = x2.iter().zip(&xj).map(|(a, b)| a - b).collect();
        vecops::axpy(-alpha, &m, &mut x2);
        vecops::axpy(-alpha_tilde, &m, &mut t2);
        for i in 0..dim {
            assert!((x1[i] - x2[i]).abs() < 1e-4);
            assert!((t1[i] - t2[i]).abs() < 1e-4);
        }
    });
}

/// A mid-retune split pairing conserves the pair mean: when an adaptive
/// retune lands between the two endpoints' parameter refreshes — the
/// sender still holds the old (η, α, α̃) epoch, the receiver the new one
/// — both sides applying the *agreed* snapshot through
/// `comm_apply_agreed` must conserve the pair's total mass Σ(x + x̃),
/// exactly like a pairing between same-epoch workers. (Each side
/// applying its OWN α̃ would leak mass through the x̃ row; the runtime
/// resolves the race to the smaller publish epoch — see
/// `WallClock::publish_acid` and `DynamicsCore::comm_apply_agreed`.)
#[test]
fn prop_split_pairing_agreed_params_conserve_pair_mean() {
    use a2cid2::engine::DynamicsCore;
    use a2cid2::optim::{LrSchedule, Sgd};
    check("agreed-pairing-pair-mean", default_cases(), |rng| {
        let chis = |rng: &mut a2cid2::rng::Xoshiro256| {
            let chi1 = f64_in(rng, 1.0, 60.0);
            let chi2 = f64_in(rng, 0.5, chi1.min(4.0));
            (chi1, chi2)
        };
        let (c1, c2) = chis(rng);
        let old_p = AcidParams::accelerated(c1, c2);
        let (c1, c2) = chis(rng);
        let new_p = AcidParams::accelerated(c1, c2);
        let lr = LrSchedule::Constant { lr: 0.05 };
        // Sender a: still on the old epoch. Receiver b: already retuned.
        let core_a = DynamicsCore::with_params(old_p, lr.clone());
        let mut core_b = DynamicsCore::with_params(old_p, lr);
        core_b.set_params(new_p);

        let dim = usize_in(rng, 1, 48);
        let mut a = WorkerState::new(vec_f32(rng, dim, 2.0));
        let mut b = WorkerState::new(vec_f32(rng, dim, 2.0));
        // Desynchronize the lazy-mixing clocks with gradient events at
        // different times, under each worker's own param epoch.
        let (mut opt_a, mut opt_b) = (Sgd::new(0.0), Sgd::new(0.0));
        core_a.grad_event(&mut a, f64_in(rng, 0.0, 0.5), &mut opt_a, &vec_f32(rng, dim, 1.0));
        core_b.grad_event(&mut b, f64_in(rng, 0.0, 0.5), &mut opt_b, &vec_f32(rng, dim, 1.0));

        let t = f64_in(rng, 0.5, 2.0);
        let mut buf_a = vec![0.0f32; dim];
        let mut buf_b = vec![0.0f32; dim];
        core_a.mix_into(&a, t, &mut buf_a);
        core_b.mix_into(&b, t, &mut buf_b);
        let mass = |u: &WorkerState, v: &WorkerState| -> f64 {
            u.x.iter().chain(&u.xt).chain(&v.x).chain(&v.xt).map(|&f| f as f64).sum()
        };
        let before = mass(&a, &b);
        // Both endpoints agree on the OLDER epoch's snapshot.
        core_a.comm_apply_agreed(&mut a, t, &buf_b, old_p);
        core_b.comm_apply_agreed(&mut b, t, &buf_a, old_p);
        let after = mass(&a, &b);
        assert!(
            (before - after).abs() < 2e-3 * before.abs().max(1.0),
            "pair mass leaked across the split pairing: {before} -> {after} \
             (old α̃ {}, new α̃ {})",
            old_p.alpha_tilde,
            new_p.alpha_tilde
        );
    });
}

/// `metrics::render_records` emits strictly valid JSON for adversarial
/// records: control characters, quotes and backslashes in keys and
/// strings, NaN/±inf floats (which must render as `null`), and nested
/// row arrays — pinned by the in-tree strict validator.
#[test]
fn prop_render_records_always_valid_json() {
    use a2cid2::metrics::{render_records, Record};
    use a2cid2::testing::validate_json;
    const NASTY: &[char] = &[
        'a', 'Z', '9', '"', '\\', '/', '\n', '\r', '\t', '\u{1}', '\u{1f}', '\u{7f}', 'é', '🦀',
        ' ',
    ];
    fn nasty_string(rng: &mut a2cid2::rng::Xoshiro256) -> String {
        (0..usize_in(rng, 0, 12)).map(|_| NASTY[usize_in(rng, 0, NASTY.len())]).collect()
    }
    fn nasty_record(rng: &mut a2cid2::rng::Xoshiro256, depth: usize) -> Record {
        let mut rec = Record::new();
        for _ in 0..usize_in(rng, 0, 6) {
            let key = nasty_string(rng);
            rec = match usize_in(rng, 0, if depth > 0 { 6 } else { 5 }) {
                // Raw bit patterns cover NaN payloads, ±inf, subnormals.
                0 => rec.f64(key, f64::from_bits(rng.next_u64())),
                1 => {
                    let v = [f64::NAN, f64::INFINITY, f64::NEG_INFINITY][usize_in(rng, 0, 3)];
                    rec.f64(key, v)
                }
                2 => rec.str(key, nasty_string(rng)),
                3 => rec.u64(key, rng.next_u64()),
                4 => rec.opt_f64(key, None),
                _ => rec.records(
                    key,
                    (0..usize_in(rng, 0, 3)).map(|_| nasty_record(rng, depth - 1)).collect(),
                ),
            };
        }
        rec
    }
    check("render-records-valid-json", default_cases(), |rng| {
        let rows: Vec<Record> =
            (0..usize_in(rng, 0, 4)).map(|_| nasty_record(rng, 2)).collect();
        let text = render_records(&rows);
        validate_json(&text).unwrap_or_else(|e| panic!("invalid JSON ({e}):\n{text}"));
        assert!(!text.contains("NaN") && !text.contains("inf"), "non-finite must render null");
    });
}

/// Poisson sampling matches its rate in expectation for any rate (the
/// runtime's comm-budget emulation is unbiased).
#[test]
fn prop_poisson_budget_matches_rate() {
    check("poisson-budget", 12, |rng| {
        let rate = f64_in(rng, 0.1, 6.0);
        let d = a2cid2::rng::Poisson::new(rate);
        let n = 20_000;
        let total: u64 = (0..n).map(|_| d.sample(rng)).sum();
        let mean = total as f64 / n as f64;
        assert!(
            (mean - rate).abs() < 0.15 * rate + 0.05,
            "rate {rate}: mean {mean}"
        );
    });
}

/// The sparse Lanczos spectrum estimator agrees with the dense Jacobi
/// eigensolver to 1e-6 relative on random connected graphs — (χ₁, χ₂)
/// both — across sizes, densities, rates, and seeds, including the
/// induced subgraphs a churn event leaves behind (remapped alive
/// workers, exactly what `active_chis` hands the estimator mid-run).
#[test]
fn prop_lanczos_spectrum_matches_dense_on_random_graphs() {
    use a2cid2::linalg::lanczos::LanczosOptions;

    fn connected(n: usize, edges: &[(usize, usize)]) -> bool {
        let mut adj = vec![Vec::new(); n];
        for &(i, j) in edges {
            adj[i].push(j);
            adj[j].push(i);
        }
        let mut seen = vec![false; n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &u in &adj[v] {
                if !seen[u] {
                    seen[u] = true;
                    count += 1;
                    stack.push(u);
                }
            }
        }
        count == n
    }

    fn assert_close(name: &str, sparse: f64, dense: f64) {
        let rel = (sparse - dense).abs() / dense.abs().max(1e-300);
        assert!(rel < 1e-6, "{name}: sparse {sparse} vs dense {dense} (rel {rel:.3e})");
    }

    let max_n = if cfg!(debug_assertions) { 96 } else { 256 };
    check("lanczos-vs-dense", 12, |rng| {
        let n = usize_in(rng, 4, max_n);
        let p = f64_in(rng, 0.25, 0.9);
        let seed = rng.next_u64();
        let graph = Graph::build(&Topology::ErdosRenyi { p, seed }, n).unwrap();
        let rate = f64_in(rng, 0.1, 4.0);
        let rates = graph.edge_rates(rate);
        let dense = graph.spectrum_with_rates(&rates);
        let sparse = graph.spectrum_lanczos(&rates, &LanczosOptions::sized_for(graph.n));
        assert_close("chi1", sparse.chi1, dense.chi1);
        assert_close("chi2", sparse.chi2, dense.chi2);
        assert_close("lambda2", sparse.lambda2, dense.lambda2);

        // Post-churn active subgraph: drop a random ~quarter of the
        // workers, remap the survivors contiguously (the same remap
        // `active_chis` performs), and re-check on the induced graph.
        let alive: Vec<usize> = (0..n).filter(|_| rng.next_u64() % 4 != 0).collect();
        if alive.len() < 3 {
            return;
        }
        let mut remap = vec![usize::MAX; n];
        for (new, &old) in alive.iter().enumerate() {
            remap[old] = new;
        }
        let sub_edges: Vec<(usize, usize)> = graph
            .edges
            .iter()
            .filter(|(i, j)| remap[*i] != usize::MAX && remap[*j] != usize::MAX)
            .map(|&(i, j)| (remap[i], remap[j]))
            .collect();
        if sub_edges.is_empty() || !connected(alive.len(), &sub_edges) {
            return; // a disconnected remnant never reaches the estimator
        }
        let sub = Graph::from_edges(alive.len(), sub_edges);
        let sub_rates = sub.edge_rates(rate);
        let dense = sub.spectrum_with_rates(&sub_rates);
        let sparse = sub.spectrum_lanczos(&sub_rates, &LanczosOptions::sized_for(sub.n));
        assert_close("churn chi1", sparse.chi1, dense.chi1);
        assert_close("churn chi2", sparse.chi2, dense.chi2);
    });
}
