//! Integration: the paper-conformance oracle end-to-end over real
//! registry runs — the `cargo test` half of the `a2cid2 verify`
//! contract (CI's `experiments-smoke` job runs `verify all` in
//! release; here the cheap, spectra/timeline-driven experiments run
//! in-process so the gate holds with no CI dependency).

use a2cid2::experiments::registry;
use a2cid2::experiments::Scale;
use a2cid2::metrics::{render_records, Value};
use a2cid2::testing::oracle::{extract, Oracle, Outcome, Verdict};
use a2cid2::testing::validate_json;

/// The cheap end of the registry: closed-form spectra, the timeline
/// schematic, and the eigensolve grid. Running these through the full
/// `run_record` path exercises the exact record shapes `verify all`
/// diffs.
const CHEAP_IDS: [&str; 3] = ["fig6", "fig2", "tab2"];

#[test]
fn oracle_passes_on_cheap_experiments_at_quick_scale() {
    let oracle = Oracle::builtin();
    for id in CHEAP_IDS {
        let exp = registry::find(id).unwrap();
        let rec = registry::run_record(exp, Scale::Quick).unwrap();
        let verdicts = oracle.judge(id, &rec, Scale::Quick);
        assert!(!verdicts.is_empty(), "{id}: no oracle entries");
        for v in &verdicts {
            assert_ne!(
                v.outcome,
                Outcome::Fail,
                "conformance failure: {}",
                v.message()
            );
        }
        assert!(
            verdicts.iter().any(|v| v.outcome == Outcome::Pass),
            "{id}: every check skipped at quick scale"
        );
    }
}

#[test]
fn perturbed_run_fails_with_observed_expected_and_tolerance() {
    // Run fig6 for real, then detune the ring's chi1 row the way a
    // mis-derived spectrum would: the oracle must catch it and the
    // failure message must carry observed, expected, and the tolerance.
    let exp = registry::find("fig6").unwrap();
    let mut rec = registry::run_record(exp, Scale::Quick).unwrap();
    let before = extract(&rec, "rows.2.chi1").expect("fig6 row 2 has chi1");
    for (key, value) in &mut rec.fields {
        if key.as_str() != "rows" {
            continue;
        }
        if let Value::Records(rows) = value {
            for (k, v) in &mut rows[2].fields {
                if k.as_str() == "chi1" {
                    *v = Value::F64(before * 2.0); // a detuned spectrum
                }
            }
        }
    }
    let verdicts = Oracle::builtin().judge("fig6", &rec, Scale::Quick);
    let failed: Vec<&Verdict> = verdicts
        .iter()
        .filter(|v| v.outcome == Outcome::Fail)
        .collect();
    assert_eq!(failed.len(), 1, "exactly the perturbed metric fails");
    let v = failed[0];
    assert_eq!(v.check.metric, "rows.2.chi1");
    let msg = v.message();
    assert!(msg.contains(&format!("observed {}", before * 2.0)), "{msg}");
    assert!(msg.contains("expected 13.14"), "{msg}");
    assert!(msg.contains("± 0.05"), "tolerance in message: {msg}");
    assert!(v.margin().unwrap() > 0.0, "positive margin outside the band");
}

#[test]
fn verify_cli_writes_conformance_artifact() {
    let dir = std::env::temp_dir().join("a2cid2_verify_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_conformance.json");
    let exp_path = dir.join("BENCH_experiments.json");
    a2cid2::testing::oracle::verify_cli("fig6", None, Some(&path), Some(&exp_path), Scale::Quick)
        .unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    validate_json(&text).unwrap_or_else(|e| panic!("invalid conformance JSON ({e}):\n{text}"));
    // One row per compared metric, with the full verdict schema.
    assert_eq!(
        text.matches("\"outcome\": ").count(),
        Oracle::builtin().checks_for("fig6").len(),
        "one conformance row per oracle entry"
    );
    assert!(text.contains("\"outcome\": \"pass\""), "{text}");
    assert!(!text.contains("\"outcome\": \"fail\""), "{text}");
    for field in ["\"observed\": ", "\"expected\": ", "\"allowed\": ", "\"margin\": ", "\"note\": "]
    {
        assert!(text.contains(field), "missing {field} in {text}");
    }
    // --experiments-json: the consolidated per-experiment artifact from
    // the same pass (what CI archives instead of a second `experiment
    // all` run).
    let exp_text = std::fs::read_to_string(&exp_path).unwrap();
    validate_json(&exp_text).unwrap_or_else(|e| panic!("invalid experiments JSON ({e})"));
    assert!(exp_text.contains("\"id\": \"fig6\""), "{exp_text}");
    assert!(exp_text.contains("\"n_rows\": 7"), "{exp_text}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn verify_cli_rejects_unknown_ids() {
    let err = a2cid2::testing::oracle::verify_cli("fig99", None, None, None, Scale::Quick)
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown experiment"), "{err}");
}

/// Verdict records survive the exact writer the CLI uses (escaping,
/// null margins on skips).
#[test]
fn skip_verdicts_render_null_observed() {
    let oracle = Oracle::parse("[x.m]\nexpected = 1\nscales = \"full\"\n").unwrap();
    let rec = a2cid2::metrics::Record::new().str("id", "x").f64("m", 1.0);
    let verdicts = oracle.judge("x", &rec, Scale::Quick);
    assert_eq!(verdicts[0].outcome, Outcome::Skip);
    let text = render_records(&[verdicts[0].record()]);
    validate_json(&text).unwrap();
    assert!(text.contains("\"outcome\": \"skip\""));
    assert!(text.contains("\"observed\": null"));
}
