//! Integration: the algorithm zoo under the shared `DynamicsCore`.
//!
//! The zoo's contract is *one seeded event stream, many update rules*:
//! rules may SKIP a proposed pairing (local SGD's pacing gate) but never
//! reschedule one, so every algorithm replays the identical tick
//! sequence for a given seed, and both engine code paths — the
//! simulator's fused two-endpoint pass and the runtime's
//! mix_into/comm_apply pairing (gated the same way the worker loop
//! gates availability) — agree at event granularity under every rule.
//! On top of the replay contract: AD-PSGD's pairwise averaging
//! conserves the pair mean end to end, selecting `algorithm = a2cid2`
//! explicitly is bit-identical to the pre-zoo default (the golden
//! replay checksums cannot move), and every arm of the zoo is
//! seed-deterministic through the config surface.

use std::sync::Arc;

use a2cid2::config::{Algorithm, ExperimentConfig, Method, Task};
use a2cid2::data::{GaussianMixture, Sharding};
use a2cid2::engine::{DynamicsCore, UpdateRule};
use a2cid2::gossip::{consensus_distance, WorkerState};
use a2cid2::graph::{Graph, Topology};
use a2cid2::model::Logistic;
use a2cid2::optim::{LrSchedule, Sgd};
use a2cid2::simulator::{
    run_allreduce, run_simulation, ArTimingConfig, EventKind, EventQueue,
};
use a2cid2::util::two_mut;

/// The asynchronous arms (all-reduce has no event stream to replay).
fn async_arms() -> Vec<Algorithm> {
    vec![Algorithm::AdPsgd, Algorithm::A2cid2, Algorithm::LocalSgd { h: 4 }]
}

/// Deterministic pseudo-gradient keyed by (worker, step) so replicas
/// consume identical gradients without a dataset.
fn grad_of(w: usize, k: u64, dim: usize) -> Vec<f32> {
    (0..dim).map(|i| ((w * 31 + i) as f32 * 0.11 + k as f32 * 0.01).cos()).collect()
}

/// Replay one seeded ring-8 event stream under `algo` through BOTH
/// engine code paths side by side. Returns the tick trace
/// `(t, kind-tag, index)` and the number of APPLIED pairings.
fn replay_both_paths(algo: Algorithm) -> (Vec<(f64, u8, usize)>, u64, u64) {
    let (n, dim) = (8, 16);
    let graph = Graph::build(&Topology::Ring, n).unwrap();
    let rates = graph.edge_rates(1.0);
    let spectrum = graph.spectrum_with_rates(&rates);
    let lr = LrSchedule::Constant { lr: 0.05 };
    let core = DynamicsCore::for_algorithm(algo, &spectrum, lr).unwrap();

    let init: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.37).sin()).collect();
    let mut sim: Vec<WorkerState> = (0..n).map(|_| WorkerState::new(init.clone())).collect();
    let mut rt: Vec<WorkerState> = (0..n).map(|_| WorkerState::new(init.clone())).collect();
    let mut opt_sim: Vec<Sgd> = (0..n).map(|_| Sgd::new(0.0)).collect();
    let mut opt_rt: Vec<Sgd> = (0..n).map(|_| Sgd::new(0.0)).collect();
    let mut buf_a = vec![0.0f32; dim];
    let mut buf_b = vec![0.0f32; dim];

    let mut queue = EventQueue::new(&vec![1.0; n], &rates, 42);
    let mut trace = Vec::new();
    let mut proposed = 0u64;
    let mut applied = 0u64;
    for _ in 0..2000 {
        let ev = queue.next(f64::INFINITY).expect("events keep flowing");
        match ev.kind {
            EventKind::Grad { worker } => {
                trace.push((ev.t, 0u8, worker));
                let g = grad_of(worker, sim[worker].n_grads, dim);
                core.grad_event(&mut sim[worker], ev.t, &mut opt_sim[worker], &g);
                core.grad_event(&mut rt[worker], ev.t, &mut opt_rt[worker], &g);
            }
            EventKind::Comm { edge } => {
                trace.push((ev.t, 1u8, edge));
                proposed += 1;
                let (i, j) = graph.edges[edge];
                // Simulator: both endpoints fused in one pass; the rule
                // gates inside comm_event.
                let sim_applied = {
                    let (a, b) = two_mut(&mut sim, i, j);
                    core.comm_event(a, b, ev.t)
                };
                // Runtime: the worker loop asks the rule for readiness
                // before announcing availability, then does read-only
                // sends + one locked RMW per endpoint.
                let rt_applied = core.rule.admits_pair(&rt[i], &rt[j]);
                if rt_applied {
                    core.mix_into(&rt[i], ev.t, &mut buf_a);
                    core.mix_into(&rt[j], ev.t, &mut buf_b);
                    core.comm_apply(&mut rt[i], ev.t, &buf_b);
                    core.comm_apply(&mut rt[j], ev.t, &buf_a);
                }
                assert_eq!(
                    sim_applied, rt_applied,
                    "{algo}: the engines disagreed on whether a pairing applies"
                );
                if sim_applied {
                    applied += 1;
                }
            }
        }
    }
    // Event-granularity agreement between the two engine paths.
    let (ca, cb) = (consensus_distance(&sim), consensus_distance(&rt));
    assert!(
        (ca - cb).abs() <= 1e-4 * (1.0 + ca.abs()),
        "{algo}: consensus diverged between engine paths: {ca} vs {cb}"
    );
    for w in 0..n {
        for (u, v) in sim[w].x.iter().zip(rt[w].x.iter()) {
            assert!(
                (u - v).abs() <= 1e-4 * (1.0 + u.abs()),
                "{algo}: worker {w} diverged between engine paths: {u} vs {v}"
            );
        }
        assert_eq!(sim[w].n_comms, rt[w].n_comms, "{algo}: applied-comm counters");
        assert_eq!(sim[w].n_grads, rt[w].n_grads, "{algo}: gradient counters");
    }
    (trace, proposed, applied)
}

#[test]
fn every_algorithm_replays_the_same_tick_stream_through_both_engines() {
    let runs: Vec<_> = async_arms().into_iter().map(replay_both_paths).collect();
    // Rules skip, they never reschedule: the (time, kind, index) trace
    // is identical across every algorithm for the same seed.
    let (reference, proposed, adpsgd_applied) = (&runs[0].0, runs[0].1, runs[0].2);
    assert!(proposed > 100, "pairings actually proposed: {proposed}");
    for (trace, p, _) in &runs {
        assert_eq!(trace, reference, "the seeded tick stream is algorithm-independent");
        assert_eq!(*p, proposed);
    }
    // Always-admitting rules apply every proposal; the local-SGD gate
    // genuinely skips some (its pacing is the whole point) yet still
    // communicates.
    assert_eq!(adpsgd_applied, proposed, "adpsgd applies every proposal");
    assert_eq!(runs[1].2, proposed, "a2cid2 applies every proposal");
    let localsgd_applied = runs[2].2;
    assert!(
        localsgd_applied > 0 && localsgd_applied < proposed,
        "localsgd:4 skips some proposals but not all: {localsgd_applied}/{proposed}"
    );
}

#[test]
fn adpsgd_conserves_the_pair_mean_end_to_end() {
    let (n, dim) = (8, 16);
    let graph = Graph::build(&Topology::Ring, n).unwrap();
    let rates = graph.edge_rates(1.0);
    let spectrum = graph.spectrum_with_rates(&rates);
    let core = DynamicsCore::for_algorithm(
        Algorithm::AdPsgd,
        &spectrum,
        LrSchedule::Constant { lr: 0.0 },
    )
    .unwrap();
    let mut rng = a2cid2::rng::Xoshiro256::seed_from_u64(3);
    let mut workers: Vec<WorkerState> = (0..n)
        .map(|_| {
            WorkerState::new(
                (0..dim).map(|_| a2cid2::rng::standard_normal(&mut rng) as f32).collect(),
            )
        })
        .collect();
    let fleet_mean = |ws: &[WorkerState]| -> Vec<f64> {
        let mut m = vec![0.0f64; dim];
        for w in ws {
            for (mi, xi) in m.iter_mut().zip(w.x.iter()) {
                *mi += f64::from(*xi) / n as f64;
            }
        }
        m
    };
    let m0 = fleet_mean(&workers);
    let mut queue = EventQueue::new(&vec![1e-12; n], &rates, 9);
    for _ in 0..500 {
        let ev = queue.next(f64::INFINITY).unwrap();
        if let EventKind::Comm { edge } = ev.kind {
            let (i, j) = graph.edges[edge];
            let before: Vec<f64> = workers[i]
                .x
                .iter()
                .zip(workers[j].x.iter())
                .map(|(a, b)| f64::from(*a) + f64::from(*b))
                .collect();
            let (a, b) = two_mut(&mut workers, i, j);
            assert!(core.comm_event(a, b, ev.t), "adpsgd admits every pairing");
            for (k, s) in before.iter().enumerate() {
                let after = f64::from(workers[i].x[k]) + f64::from(workers[j].x[k]);
                assert!(
                    (after - s).abs() <= 1e-4 * (1.0 + s.abs()),
                    "pair sum moved at coord {k}: {s} -> {after}"
                );
            }
        }
    }
    // Conservation composes: the fleet mean is where it started, and the
    // gradient-free dynamic has genuinely contracted toward it.
    let m1 = fleet_mean(&workers);
    for (a, b) in m0.iter().zip(&m1) {
        assert!((a - b).abs() <= 1e-3 * (1.0 + a.abs()), "fleet mean drifted: {a} vs {b}");
    }
    assert!(consensus_distance(&workers) < 1.0, "plain averaging still contracts");
}

fn zoo_cfg(algo: Algorithm) -> ExperimentConfig {
    ExperimentConfig {
        n_workers: 8,
        topology: Topology::Ring,
        method: Method::Acid,
        task: Task::CifarLike,
        comm_rate: 1.0,
        batch_size: 8,
        base_lr: 0.02,
        momentum: 0.0,
        weight_decay: 0.0,
        steps_per_worker: 60,
        sharding: Sharding::FullShuffled,
        dataset_size: 256,
        seed: 11,
        compute_jitter: 0.1,
        scenario: None,
        algorithm: Some(algo),
    }
    .validate()
    .unwrap()
}

#[test]
fn explicit_a2cid2_selection_is_bit_identical_to_the_default() {
    // `algorithm = a2cid2` must take the exact code path the pre-zoo
    // engine took (the golden replay checksums pin the same property at
    // the artifact level).
    let explicit = zoo_cfg(Algorithm::A2cid2);
    let mut implicit = explicit.clone();
    implicit.algorithm = None;
    let ds = Arc::new(GaussianMixture::cifar_like().sample(explicit.dataset_size, 5));
    let shards = explicit.sharding.assign(&ds, explicit.n_workers, explicit.seed);
    let model = Arc::new(Logistic::new(ds, 0.0));
    let a = run_simulation(&explicit, model.clone(), &shards).unwrap();
    let b = run_simulation(&implicit, model, &shards).unwrap();
    assert_eq!(a.avg_params, b.avg_params, "explicit selection changed the dynamics");
    assert_eq!(a.n_comms, b.n_comms);
    assert_eq!(a.n_grads, b.n_grads);
    assert_eq!(a.acid, b.acid);
}

#[test]
fn every_zoo_arm_is_seed_deterministic_through_the_config_surface() {
    let ds = Arc::new(GaussianMixture::cifar_like().sample(256, 5));
    for algo in [
        Algorithm::AdPsgd,
        Algorithm::A2cid2,
        Algorithm::LocalSgd { h: 4 },
        Algorithm::AllReduce,
    ] {
        let cfg = zoo_cfg(algo);
        let shards = cfg.sharding.assign(&ds, cfg.n_workers, cfg.seed);
        let model = Arc::new(Logistic::new(ds.clone(), 0.0));
        if algo == Algorithm::AllReduce {
            let t = ArTimingConfig::default();
            let a = run_allreduce(&cfg, model.clone(), &shards, &t).unwrap();
            let b = run_allreduce(&cfg, model, &shards, &t).unwrap();
            assert_eq!(a.params, b.params, "allreduce replay is bit-identical");
            assert!(a.final_loss().is_finite());
            continue;
        }
        let a = run_simulation(&cfg, model.clone(), &shards).unwrap();
        let b = run_simulation(&cfg, model, &shards).unwrap();
        assert_eq!(a.avg_params, b.avg_params, "{algo}: replay is bit-identical");
        assert_eq!(a.n_comms, b.n_comms, "{algo}");
        assert!(a.final_loss().is_finite(), "{algo}: training stays live");
        assert_eq!(a.acid, b.acid, "{algo}");
    }
}
