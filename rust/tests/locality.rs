//! Memory-locality regression tests: affinity pinning, first-touch
//! placement, and sticky/stolen chunk claiming must NEVER change a bit.
//!
//! The contract under test (see `gossip::pool` and `locality`): chunk
//! boundaries are fixed at `CHUNK` elements and every kernel is
//! element-wise within its chunk, so WHERE a chunk's pages live, WHICH
//! lane claims it, and in WHAT order the claims happen are all invisible
//! to the arithmetic. These tests drive the full placement matrix —
//! {pinned, unpinned} × {sticky, stolen (rotated claim offset)} × pool
//! widths {1, 4} — against the serial reference and require exact
//! equality, the same property the golden replay checksums pin
//! end-to-end in CI.

use a2cid2::gossip::pool::{self, AlignedVec, ChunkPool, CHUNK, PAGE};
use a2cid2::gossip::vecops;
use a2cid2::locality;
use a2cid2::rng::Xoshiro256;

/// 4 full chunks + a ragged tail: wide enough that a width-4 pool gives
/// every lane a sticky chunk, ragged so the tail path is exercised.
const DIM: usize = 4 * CHUNK + 1234;

fn random_vec(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
}

#[test]
fn placement_matrix_is_bit_identical_to_serial() {
    let mut rng = Xoshiro256::seed_from_u64(0xA2C1D2);
    let xa0 = random_vec(&mut rng, DIM);
    let ta0 = random_vec(&mut rng, DIM);
    let xb0 = random_vec(&mut rng, DIM);
    let tb0 = random_vec(&mut rng, DIM);

    // Serial reference.
    let (mut rxa, mut rta) = (xa0.clone(), ta0.clone());
    let (mut rxb, mut rtb) = (xb0.clone(), tb0.clone());
    vecops::comm_pair_fused(
        0.9, 0.1, 0.8, 0.2, 0.5, 1.5, &mut rxa, &mut rta, &mut rxb, &mut rtb,
    );
    vecops::mix_pair(0.7, 0.3, &mut rxa, &mut rta);

    for extra in [0usize, 3] {
        for pin in [false, true] {
            let p = ChunkPool::new_with_pinning(extra, pin);
            // Offset 0 = pure sticky claiming; nonzero offsets start
            // every lane on another lane's range (all-stolen work).
            for offset in [0usize, 1, 2] {
                p.set_claim_offset(offset);
                let (mut xa, mut ta) = (xa0.clone(), ta0.clone());
                let (mut xb, mut tb) = (xb0.clone(), tb0.clone());
                pool::comm_pair_fused_on(
                    &p, 0.9, 0.1, 0.8, 0.2, 0.5, 1.5, &mut xa, &mut ta, &mut xb, &mut tb,
                );
                pool::mix_pair_on(&p, 0.7, 0.3, &mut xa, &mut ta);
                let case = format!("extra={extra} pin={pin} offset={offset}");
                assert_eq!(xa, rxa, "xa diverged: {case}");
                assert_eq!(ta, rta, "ta diverged: {case}");
                assert_eq!(xb, rxb, "xb diverged: {case}");
                assert_eq!(tb, rtb, "tb diverged: {case}");
            }
        }
    }
}

#[test]
fn first_touch_buffers_are_zero_aligned_and_roundtrip() {
    let p = ChunkPool::new_with_pinning(3, true);
    for len in [0usize, 7, CHUNK, DIM] {
        let v = AlignedVec::zeroed_on(&p, len);
        assert_eq!(v.len(), len);
        assert!(v.as_slice().iter().all(|&x| x == 0.0), "len={len} not zeroed");
        if len * 4 >= PAGE {
            assert_eq!(
                v.as_slice().as_ptr() as usize % PAGE,
                0,
                "len={len} not page-aligned"
            );
        }
    }
    // A first-touch-placed buffer is an ordinary buffer to the kernels.
    let mut rng = Xoshiro256::seed_from_u64(7);
    let src = random_vec(&mut rng, DIM);
    let mut placed = AlignedVec::zeroed_on(&p, DIM);
    placed.as_mut_slice().copy_from_slice(&src);
    assert_eq!(placed.as_slice(), &src[..]);
}

#[test]
fn topology_is_sane_and_covers_every_lane_slot() {
    let topo = locality::topology();
    assert!(topo.n_nodes() >= 1);
    assert!(topo.n_cpus() >= 1);
    for slot in 0..64 {
        let cpu = topo.cpu_for_slot(slot);
        if let Some(c) = cpu {
            assert!(
                topo.nodes.iter().any(|n| n.contains(&c)),
                "slot {slot} mapped to unknown cpu {c}"
            );
        }
    }
}

#[test]
fn pinning_roundtrip_is_harmless_wherever_it_lands() {
    // Pin to the first known CPU (may legitimately fail under a
    // restricted cpuset or non-Linux target), then restore the startup
    // mask. Neither call may panic, and work proceeds either way.
    let topo = locality::topology();
    if let Some(c) = topo.cpu_for_slot(0) {
        let pinned = locality::pin_current_thread(c);
        let restored = locality::unpin_current_thread();
        if pinned {
            assert!(restored, "pinned but could not restore startup affinity");
        }
    }
    let ones = vec![1.0f32; 64];
    let mut x = vec![1.0f32; 64];
    vecops::axpy(2.0, &ones, &mut x);
    assert!(x.iter().all(|&v| v == 3.0));
}
