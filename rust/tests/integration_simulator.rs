//! Integration: the virtual-time engine end-to-end — convergence,
//! topology effects, and the acceleration ordering on the ring.

use std::sync::Arc;

use a2cid2::config::{ExperimentConfig, Method, Task};
use a2cid2::data::{GaussianMixture, Sharding};
use a2cid2::graph::Topology;
use a2cid2::model::{Mlp, Model};
use a2cid2::simulator::{run_allreduce, run_simulation, ArTimingConfig};

fn cfg(n: usize, topo: Topology, method: Method, steps: u64) -> ExperimentConfig {
    ExperimentConfig {
        n_workers: n,
        topology: topo,
        method,
        task: Task::CifarLike,
        comm_rate: 1.0,
        batch_size: 16,
        base_lr: 0.1,
        momentum: 0.9,
        weight_decay: 5e-4,
        steps_per_worker: steps,
        sharding: Sharding::FullShuffled,
        dataset_size: 2048,
        seed: 0,
        compute_jitter: 0.1,
        scenario: None,
        algorithm: None,
    }
}

fn setup(c: &ExperimentConfig) -> (Arc<Mlp>, a2cid2::data::ShardedIndices) {
    let ds = Arc::new(GaussianMixture::cifar_like().sample(c.dataset_size, 7));
    let shards = c.sharding.assign(&ds, c.n_workers, c.seed);
    (Arc::new(Mlp::new(ds, 32, 5e-4)), shards)
}

#[test]
fn mlp_converges_on_all_topologies() {
    for topo in [Topology::Ring, Topology::Complete, Topology::Exponential] {
        let c = cfg(8, topo.clone(), Method::AsyncBaseline, 250);
        let (model, shards) = setup(&c);
        let res = run_simulation(&c, model.clone(), &shards).unwrap();
        let idx: Vec<usize> = (0..2048).collect();
        let acc = model.accuracy(&res.avg_params, &idx).unwrap();
        assert!(acc > 0.7, "{}: acc={acc}", topo.name());
    }
}

#[test]
fn acid_beats_baseline_on_large_ring() {
    // The paper's headline ordering at the consensus-limited scale.
    let steps = 200;
    let c_base = cfg(32, Topology::Ring, Method::AsyncBaseline, steps);
    let (model, shards) = setup(&c_base);
    let base = run_simulation(&c_base, model.clone(), &shards).unwrap();
    let c_acid = cfg(32, Topology::Ring, Method::Acid, steps);
    let acid = run_simulation(&c_acid, model, &shards).unwrap();
    // A²CiD² must reduce the consensus error materially at equal budget.
    let cb = base.final_consensus();
    let ca = acid.final_consensus();
    assert!(
        ca < cb,
        "consensus: acid {ca} should be below baseline {cb}"
    );
    // ...and not hurt the loss.
    assert!(
        acid.final_loss() < base.final_loss() * 1.1,
        "loss: acid {} vs baseline {}",
        acid.final_loss(),
        base.final_loss()
    );
}

#[test]
fn comm_rate_improves_consensus() {
    let mut c = cfg(16, Topology::Ring, Method::AsyncBaseline, 150);
    let (model, shards) = setup(&c);
    let r1 = run_simulation(&c, model.clone(), &shards).unwrap();
    c.comm_rate = 4.0;
    let r4 = run_simulation(&c, model, &shards).unwrap();
    assert!(
        r4.final_consensus() < r1.final_consensus(),
        "rate 4 consensus {} should beat rate 1 {}",
        r4.final_consensus(),
        r1.final_consensus()
    );
    // Comm event count scales with the rate.
    assert!(r4.n_comms > 3 * r1.n_comms);
}

#[test]
fn allreduce_matches_async_sample_budget() {
    let c = cfg(8, Topology::Complete, Method::AllReduce, 150);
    let (model, shards) = setup(&c);
    let ar = run_allreduce(&c, model.clone(), &shards, &ArTimingConfig::default()).unwrap();
    assert_eq!(ar.rounds, 150);
    let c2 = cfg(8, Topology::Complete, Method::AsyncBaseline, 150);
    let asy = run_simulation(&c2, model, &shards).unwrap();
    // Same total gradient count (the paper's equal-sample protocol).
    assert_eq!(
        asy.grads_per_worker.iter().sum::<u64>(),
        ar.grads_per_worker * 8
    );
}

#[test]
fn spectrum_wired_into_results() {
    let c = cfg(16, Topology::Ring, Method::Acid, 20);
    let (model, shards) = setup(&c);
    let res = run_simulation(&c, model, &shards).unwrap();
    assert!((res.spectrum.chi1 - 13.14).abs() < 0.5, "ring-16 chi1");
    assert!(res.acid.is_accelerated());
    assert!((res.acid.eta - 1.0 / (2.0 * res.spectrum.chi_acc())).abs() < 1e-9);
}
