//! Integration: worker churn + per-phase adaptive (η, α̃), end to end.
//!
//! The acceptance bar for churn is *bit-identical replay across both
//! engines*: the virtual-time simulator and the real-thread runtime
//! share one `DynamicsCore`, so one seeded event sequence — gradients,
//! pairings, leaves, neighbor-snapshot re-joins, and adaptive retunes —
//! must produce the same consensus trajectory at event granularity
//! whichever engine's code path applies it. The first test replays a
//! compiled churn scenario's exact tick stream through the simulator's
//! fused two-endpoint path AND the runtime's mix_into/comm_apply
//! pairing path side by side. The rest pin seed-determinism and
//! liveness of full runs on each engine.

use std::sync::Arc;
use std::time::Duration;

use a2cid2::config::{ExperimentConfig, Method, Scenario, Task};
use a2cid2::data::{GaussianMixture, Sharding};
use a2cid2::engine::{DynamicsCore, Tick, VirtualTimeScheduler};
use a2cid2::gossip::consensus_distance;
use a2cid2::gossip::dynamics::WorkerState;
use a2cid2::graph::{Graph, Topology};
use a2cid2::model::{Logistic, Model};
use a2cid2::optim::{LrSchedule, Sgd};
use a2cid2::runtime::{run_async, GradSource, RustGradSource, RuntimeOptions};
use a2cid2::simulator::run_simulation;

const CHURN_SCENARIO: &str =
    "ring@0,exponential@0.5;leave=0.25:0.2:3;join=0.25:0.7;drop=0.2:0.3:0.6:7";

#[test]
fn churn_replay_agrees_across_engine_paths_at_event_granularity() {
    let n = 8;
    let dim = 16;
    let scenario = Scenario::parse(CHURN_SCENARIO).unwrap();
    let plan = scenario.compile(n, 1.0, 60.0, &vec![1.0; n]).unwrap();
    let mut sched = VirtualTimeScheduler::new(&plan, 42);

    // Two replicas of the fleet, one per engine code path, plus one
    // dynamics core each (retuned independently from the same changes).
    let lr = LrSchedule::Constant { lr: 0.05 };
    let mut core_sim = DynamicsCore::for_method(Method::Acid, &plan.spectrum, lr.clone()).unwrap();
    let mut core_rt = core_sim.clone();
    let init: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.37).sin()).collect();
    let mut sim: Vec<WorkerState> = (0..n).map(|_| WorkerState::new(init.clone())).collect();
    let mut rt: Vec<WorkerState> = (0..n).map(|_| WorkerState::new(init.clone())).collect();
    let mut opt_sim: Vec<Sgd> = (0..n).map(|_| Sgd::new(0.0)).collect();
    let mut opt_rt: Vec<Sgd> = (0..n).map(|_| Sgd::new(0.0)).collect();
    let mut in_fleet = vec![true; n];
    // Deterministic pseudo-gradient keyed by (worker, step) so the two
    // replicas consume identical gradients without a dataset.
    let grad_of = |w: usize, k: u64| -> Vec<f32> {
        (0..dim)
            .map(|i| ((w * 31 + i) as f32 * 0.11 + k as f32 * 0.01).cos())
            .collect()
    };

    let mut n_comms = 0u64;
    let mut n_changes = 0usize;
    let mut buf_a = vec![0.0f32; dim];
    let mut buf_b = vec![0.0f32; dim];
    for _ in 0..3000 {
        let tick = sched.next().expect("events keep flowing");
        for ch in sched.drain_changes() {
            n_changes += 1;
            for &w in &ch.left {
                in_fleet[w] = false;
            }
            for &j in &ch.joined {
                let donor = plan.union.neighbors(j).iter().copied().find(|&d| in_fleet[d]);
                if let Some(d) = donor {
                    // Simulator path and runtime path use the SAME donor
                    // rule (smallest active union neighbor) and the same
                    // re-init primitive.
                    let donor_sim = sim[d].x.clone();
                    core_sim.rejoin_from(&mut sim[j], &donor_sim, ch.t);
                    let donor_rt = rt[d].x.clone();
                    core_rt.rejoin_from(&mut rt[j], &donor_rt, ch.t);
                }
            }
            for &j in &ch.joined {
                in_fleet[j] = true;
            }
            if let Some((c1, c2)) = ch.chis {
                core_sim.retune(c1, c2);
                core_rt.retune(c1, c2);
            }
        }
        match tick {
            Tick::Grad { worker, t } => {
                let g = grad_of(worker, sim[worker].n_grads);
                core_sim.grad_event(&mut sim[worker], t, &mut opt_sim[worker], &g);
                core_rt.grad_event(&mut rt[worker], t, &mut opt_rt[worker], &g);
            }
            Tick::Comm { i, j, t } => {
                n_comms += 1;
                // Simulator: both endpoints fused in one pass.
                let (a, b) = if i < j {
                    let (lo, hi) = sim.split_at_mut(j);
                    (&mut lo[i], &mut hi[0])
                } else {
                    let (lo, hi) = sim.split_at_mut(i);
                    (&mut hi[0], &mut lo[j])
                };
                core_sim.comm_event(a, b, t);
                // Runtime: read-only send buffers, one locked RMW each.
                core_rt.mix_into(&rt[i], t, &mut buf_a);
                core_rt.mix_into(&rt[j], t, &mut buf_b);
                core_rt.comm_apply(&mut rt[i], t, &buf_b);
                core_rt.comm_apply(&mut rt[j], t, &buf_a);
            }
        }
        // Consensus trajectories agree at EVERY event (f32-exact on the
        // runtime path vs itself; the fused simulator pass is compared
        // through the same tolerance the core's unit test uses).
        if n_comms % 64 == 0 {
            let (ca, cb) = (consensus_distance(&sim), consensus_distance(&rt));
            assert!(
                (ca - cb).abs() <= 1e-4 * (1.0 + ca.abs()),
                "consensus diverged at comm {n_comms}: {ca} vs {cb}"
            );
        }
    }
    assert!(n_comms > 100, "pairings actually happened: {n_comms}");
    // Dropout boundaries carry no churn and no spectrum, so exactly the
    // leave, the switch, and the join surface as changes.
    assert!(n_changes >= 3, "leave/switch/join all landed: {n_changes}");
    assert_eq!(core_sim.acid, core_rt.acid, "both cores retuned identically");
    assert!(
        core_sim.acid != a2cid2::gossip::AcidParams::from_spectrum(&plan.spectrum),
        "adaptive retune moved off the phase-0 parameters"
    );
    for w in 0..n {
        for (u, v) in sim[w].x.iter().zip(&rt[w].x) {
            assert!(
                (u - v).abs() <= 1e-4 * (1.0 + u.abs()),
                "worker {w} diverged between engine paths: {u} vs {v}"
            );
        }
        assert_eq!(sim[w].n_comms, rt[w].n_comms);
        assert_eq!(sim[w].n_grads, rt[w].n_grads);
    }
}

#[test]
fn simulator_churn_scenario_is_seed_deterministic() {
    let cfg = ExperimentConfig {
        n_workers: 8,
        topology: Topology::Ring,
        method: Method::Acid,
        task: Task::CifarLike,
        comm_rate: 1.0,
        batch_size: 8,
        base_lr: 0.02,
        momentum: 0.0,
        weight_decay: 0.0,
        steps_per_worker: 120,
        sharding: Sharding::FullShuffled,
        dataset_size: 256,
        seed: 11,
        compute_jitter: 0.1,
        scenario: Some(Scenario::parse(CHURN_SCENARIO).unwrap()),
        algorithm: None,
    };
    let ds = Arc::new(GaussianMixture::cifar_like().sample(cfg.dataset_size, 5));
    let shards = cfg.sharding.assign(&ds, cfg.n_workers, cfg.seed);
    let model = Arc::new(Logistic::new(ds, 0.0));
    let a = run_simulation(&cfg, model.clone(), &shards).unwrap();
    let b = run_simulation(&cfg, model.clone(), &shards).unwrap();
    assert_eq!(a.avg_params, b.avg_params, "bit-identical churn replay");
    assert_eq!(a.n_comms, b.n_comms);
    assert_eq!(a.net_updates, b.net_updates);
    assert!(a.net_updates >= 4, "leave + drop + recover + switch + join");
    assert_eq!(a.acid, b.acid);

    let mut c2 = cfg.clone();
    c2.seed = 12;
    let d = run_simulation(&c2, model, &shards).unwrap();
    assert_ne!(a.avg_params, d.avg_params, "the seed genuinely matters");
}

#[test]
fn runtime_churn_scenario_stays_live_and_respects_membership() {
    let n = 8;
    let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
    let ds = Arc::new(GaussianMixture::cifar_like().sample(256, 6));
    let shards = Sharding::FullShuffled.assign(&ds, n, 0);
    let model = Arc::new(Logistic::new(ds, 0.0));
    let mut rng = a2cid2::rng::Xoshiro256::seed_from_u64(0);
    let init = model.init_params(&mut rng);
    let sources: Vec<Box<dyn GradSource>> = (0..n)
        .map(|w| {
            let mut s = RustGradSource::new(
                model.clone() as Arc<dyn Model>,
                shards.per_worker[w].clone(),
                8,
                w as u64,
            );
            s.extra_delay = Some(Duration::from_micros(300));
            Box::new(s) as Box<dyn GradSource>
        })
        .collect();
    let opts = RuntimeOptions {
        comm_rate: 1.0,
        method: Method::Acid,
        lr: LrSchedule::Constant { lr: 0.02 },
        momentum: 0.0,
        steps_per_worker: 100,
        seed: 0,
        monitor_interval: Duration::from_millis(2),
        link_delay: None,
        scenario: Some(Scenario::parse(CHURN_SCENARIO).unwrap()),
    };
    let res = run_async(graph, sources, init, opts).unwrap();
    // Everyone re-joined, so everyone finishes its budget; the scenario's
    // full update list landed (possibly flushed at the end).
    assert_eq!(res.grads_per_worker, vec![100; n]);
    assert!(res.net_updates >= 4, "updates landed: {}", res.net_updates);
    // Pairings stay inside the ring ∪ exponential union.
    let union = {
        let ring = Graph::build(&Topology::Ring, n).unwrap();
        let exp = Graph::build(&Topology::Exponential, n).unwrap();
        Graph::from_edges(n, ring.edges.iter().chain(exp.edges.iter()).copied())
    };
    for i in 0..n {
        for j in 0..n {
            if i != j && !union.has_edge(i, j) {
                assert_eq!(res.pairing.counts[i][j], 0, "pairing outside the union {i}-{j}");
            }
        }
    }
    let c = res.recorder.get("consensus").unwrap();
    assert!(c.points.iter().all(|(_, v)| v.is_finite()));
}
