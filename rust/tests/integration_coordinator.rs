//! Integration: the pairing coordinator under forced contention — the
//! liveness and safety properties the paper claims over AD-PSGD
//! (deadlock-freedom, availability-based matching).

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use a2cid2::engine::WallClock;
use a2cid2::graph::{Graph, Topology};
use a2cid2::runtime::coordinator::{spawn_coordinator, CoordMsg, PairReply};

fn graph(topo: Topology, n: usize) -> Arc<Graph> {
    Arc::new(Graph::build(&topo, n).unwrap())
}

fn net(g: &Graph) -> Arc<WallClock> {
    Arc::new(WallClock::from_graph(g, 1.0))
}

/// Hammer the coordinator with many threads doing rapid
/// available→pair→repeat cycles; every request must complete (no
/// deadlock) and every pairing must respect the topology.
fn hammer(topo: Topology, n: usize, rounds: usize) {
    let g = graph(topo, n);
    let (tx, handle) = spawn_coordinator(net(&g));
    let mut joins = Vec::new();
    for w in 0..n {
        let tx = tx.clone();
        joins.push(std::thread::spawn(move || {
            let mut paired = 0usize;
            for _ in 0..rounds {
                let (rtx, rrx) = mpsc::channel();
                tx.send(CoordMsg::Available { worker: w, reply: rtx }).unwrap();
                match rrx.recv_timeout(Duration::from_secs(20)) {
                    Ok(PairReply::Peer(_)) => paired += 1,
                    Ok(_) => break,
                    Err(e) => panic!("worker {w} starved: {e}"),
                }
            }
            let _ = tx.send(CoordMsg::Leave { worker: w });
            paired
        }));
    }
    drop(tx);
    let mut total = 0usize;
    for j in joins {
        total += j.join().unwrap();
    }
    let stats = handle.join().unwrap();
    // Each pairing involves two workers.
    assert_eq!(total, 2 * stats.total as usize);
    for i in 0..n {
        for j in 0..n {
            if stats.counts[i][j] > 0 {
                assert!(g.has_edge(i, j), "paired non-neighbors {i},{j}");
            }
        }
    }
    assert!(stats.total > 0);
}

#[test]
fn hammer_ring() {
    hammer(Topology::Ring, 8, 200);
}

#[test]
fn hammer_complete() {
    hammer(Topology::Complete, 8, 200);
}

#[test]
fn hammer_star() {
    // Star is the worst case for FIFO matching: only the hub can pair, so
    // the leaves serialize through it. Liveness must still hold.
    hammer(Topology::Star, 6, 50);
}

#[test]
fn hammer_exponential_many_workers() {
    hammer(Topology::Exponential, 16, 100);
}

#[test]
fn staggered_departures_release_everyone() {
    // Workers leave at staggered times while others still request
    // pairings; stragglers whose neighborhood empties must get None.
    let n = 6;
    let g = graph(Topology::Ring, n);
    let (tx, handle) = spawn_coordinator(net(&g));
    let mut joins = Vec::new();
    for w in 0..n {
        let tx = tx.clone();
        joins.push(std::thread::spawn(move || {
            // Workers with small ids leave almost immediately.
            let my_rounds = 3 * (w + 1);
            for _ in 0..my_rounds {
                let (rtx, rrx) = mpsc::channel();
                tx.send(CoordMsg::Available { worker: w, reply: rtx }).unwrap();
                match rrx.recv_timeout(Duration::from_secs(20)) {
                    Ok(PairReply::Peer(_)) => {}
                    Ok(_) => break,
                    Err(e) => panic!("worker {w} starved after departures: {e}"),
                }
            }
            let _ = tx.send(CoordMsg::Leave { worker: w });
        }));
    }
    drop(tx);
    for j in joins {
        j.join().unwrap();
    }
    handle.join().unwrap();
}

#[test]
fn pairing_histogram_roughly_uniform_on_complete() {
    // On the complete graph with symmetric load, FIFO matching should use
    // partners near-uniformly (Fig. 7's claim). Tolerate wide CV — this
    // is a stochastic schedule, not an exact shuffle.
    let n = 8;
    let g = graph(Topology::Complete, n);
    let (tx, handle) = spawn_coordinator(net(&g));
    let mut joins = Vec::new();
    for w in 0..n {
        let tx = tx.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..300 {
                let (rtx, rrx) = mpsc::channel();
                tx.send(CoordMsg::Available { worker: w, reply: rtx }).unwrap();
                if !matches!(
                    rrx.recv_timeout(Duration::from_secs(20)).unwrap(),
                    PairReply::Peer(_)
                ) {
                    break;
                }
                // Small jitter to shuffle arrival order.
                if i % (w + 2) == 0 {
                    std::thread::yield_now();
                }
            }
            let _ = tx.send(CoordMsg::Leave { worker: w });
        }));
    }
    drop(tx);
    for j in joins {
        j.join().unwrap();
    }
    let stats = handle.join().unwrap();
    let cv = stats.edge_uniformity_cv(&g);
    assert!(cv < 1.5, "edge-usage CV too high: {cv}");
    // Every worker paired with several distinct partners.
    for i in 0..n {
        let partners = (0..n).filter(|&j| stats.counts[i][j] > 0).count();
        assert!(partners >= 3, "worker {i} only saw {partners} partners");
    }
}
