//! Integration: the real-thread runtime under true asynchrony — PJRT
//! artifacts on the request path, straggler injection, topology safety.
//!
//! PJRT tests are skipped (with a message) when `artifacts/` is absent;
//! `make artifacts` builds them.

use std::sync::Arc;
use std::time::Duration;

use a2cid2::config::Method;
use a2cid2::data::{GaussianMixture, Sharding};
use a2cid2::graph::{Graph, Topology};
use a2cid2::model::{Logistic, Model};
use a2cid2::optim::LrSchedule;
#[cfg(feature = "pjrt")]
use a2cid2::runtime::artifacts::{default_artifact_dir, Manifest};
#[cfg(feature = "pjrt")]
use a2cid2::runtime::pjrt::PjrtContext;
#[cfg(feature = "pjrt")]
use a2cid2::runtime::pjrt_grad::MlpPjrtGradSource;
use a2cid2::runtime::worker::{run_async, GradSource, RuntimeOptions, RustGradSource};

#[cfg(feature = "pjrt")]
fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::load(default_artifact_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_mlp_grad_matches_manifest_shapes() {
    let Some(manifest) = manifest_or_skip() else { return };
    let ctx = PjrtContext::cpu().unwrap();
    let meta = manifest.get("mlp_grad").unwrap();
    let dim = meta.param_dim().unwrap();
    let feat = meta.int("feat_dim").unwrap() as usize;
    let classes = meta.int("n_classes").unwrap() as usize;
    let batch = meta.int("batch").unwrap() as usize;
    let init = manifest.load_init("mlp").unwrap();
    assert_eq!(init.len(), dim);

    let ds = Arc::new(
        GaussianMixture { dim: feat, n_classes: classes, margin: 3.0, sigma: 1.0 }
            .sample(256, 1),
    );
    let exe = ctx.load_artifact(&manifest, "mlp_grad").unwrap();
    let mut src =
        MlpPjrtGradSource::new(exe, ds, (0..256).collect(), batch, dim, 0);
    let mut grad = vec![0.0f32; dim];
    let loss = src.grad(&init, &mut grad).unwrap();
    // Fresh head ⇒ loss ≈ ln(n_classes); gradient non-trivial and finite.
    assert!(
        (loss - (classes as f32).ln()).abs() < 0.5,
        "initial loss {loss}"
    );
    assert!(grad.iter().all(|g| g.is_finite()));
    let norm: f32 = grad.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(norm > 1e-3, "gradient should be non-zero, norm={norm}");
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_training_descends_loss() {
    let Some(manifest) = manifest_or_skip() else { return };
    let ctx = PjrtContext::cpu().unwrap();
    let meta = manifest.get("mlp_grad").unwrap();
    let dim = meta.param_dim().unwrap();
    let feat = meta.int("feat_dim").unwrap() as usize;
    let classes = meta.int("n_classes").unwrap() as usize;
    let batch = meta.int("batch").unwrap() as usize;
    let mut params = manifest.load_init("mlp").unwrap();
    let ds = Arc::new(
        GaussianMixture { dim: feat, n_classes: classes, margin: 3.0, sigma: 1.0 }
            .sample(512, 2),
    );
    let exe = ctx.load_artifact(&manifest, "mlp_grad").unwrap();
    let mut src = MlpPjrtGradSource::new(exe, ds, (0..512).collect(), batch, dim, 3);
    let mut grad = vec![0.0f32; dim];
    let first = src.grad(&params, &mut grad).unwrap();
    let mut last = first;
    for _ in 0..80 {
        last = src.grad(&params, &mut grad).unwrap();
        for (p, g) in params.iter_mut().zip(&grad) {
            *p -= 0.1 * g;
        }
    }
    assert!(
        last < 0.7 * first,
        "plain SGD through the artifact should descend: {first} -> {last}"
    );
}

#[test]
fn runtime_with_injected_stragglers_spreads_wall_time() {
    // Pure-Rust grad sources; one worker is 5x slower than the rest. The
    // runtime must still terminate, train, and respect the topology.
    let n = 4;
    let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
    let ds = Arc::new(GaussianMixture::cifar_like().sample(512, 4));
    let shards = Sharding::FullShuffled.assign(&ds, n, 0);
    let model = Arc::new(Logistic::new(ds, 0.0));
    let mut rng = a2cid2::rng::Xoshiro256::seed_from_u64(0);
    let init = model.init_params(&mut rng);
    let sources: Vec<Box<dyn GradSource>> = (0..n)
        .map(|w| {
            let mut s = RustGradSource::new(
                model.clone() as Arc<dyn Model>,
                shards.per_worker[w].clone(),
                16,
                w as u64,
            );
            if w == 0 {
                s.extra_delay = Some(Duration::from_millis(2));
            }
            Box::new(s) as Box<dyn GradSource>
        })
        .collect();
    let opts = RuntimeOptions {
        comm_rate: 1.0,
        method: Method::Acid,
        lr: LrSchedule::Constant { lr: 0.05 },
        momentum: 0.0,
        steps_per_worker: 80,
        seed: 0,
        monitor_interval: Duration::from_millis(5),
        link_delay: None,
        scenario: None,
    };
    let res = run_async(graph.clone(), sources, init, opts).unwrap();
    assert_eq!(res.grads_per_worker, vec![80; n]);
    // Straggler never paired with a non-neighbor.
    for i in 0..n {
        for j in 0..n {
            if i != j && !graph.has_edge(i, j) {
                assert_eq!(res.pairing.counts[i][j], 0, "non-edge {i}-{j}");
            }
        }
    }
    // Consensus remained finite and training progressed.
    let idx: Vec<usize> = (0..512).collect();
    let acc = model.accuracy(&res.avg_params, &idx).unwrap();
    assert!(acc > 0.5, "acc={acc}");
}

#[test]
fn runtime_with_link_delay_still_terminates() {
    let n = 3;
    let graph = Arc::new(Graph::build(&Topology::Complete, n).unwrap());
    let ds = Arc::new(GaussianMixture::cifar_like().sample(256, 5));
    let shards = Sharding::FullShuffled.assign(&ds, n, 0);
    let model = Arc::new(Logistic::new(ds, 0.0));
    let mut rng = a2cid2::rng::Xoshiro256::seed_from_u64(0);
    let init = model.init_params(&mut rng);
    let sources: Vec<Box<dyn GradSource>> = (0..n)
        .map(|w| {
            Box::new(RustGradSource::new(
                model.clone() as Arc<dyn Model>,
                shards.per_worker[w].clone(),
                8,
                w as u64,
            )) as Box<dyn GradSource>
        })
        .collect();
    let opts = RuntimeOptions {
        comm_rate: 0.5,
        method: Method::AsyncBaseline,
        lr: LrSchedule::Constant { lr: 0.02 },
        momentum: 0.0,
        steps_per_worker: 40,
        seed: 0,
        monitor_interval: Duration::from_millis(5),
        link_delay: Some(Duration::from_micros(300)),
        scenario: None,
    };
    let res = run_async(graph, sources, init, opts).unwrap();
    assert_eq!(res.grads_per_worker, vec![40; n]);
    assert_eq!(
        res.comms_per_worker.iter().sum::<u64>(),
        2 * res.pairing.total
    );
}

#[test]
fn simulator_and_runtime_agree_on_convergence() {
    // The two engines run the same dynamics; at equal budgets they must
    // land at comparable accuracy (not bit-equal — different event orders).
    let n = 4;
    let steps = 150u64;
    let ds = Arc::new(GaussianMixture::cifar_like().sample(1024, 6));
    let test: Vec<usize> = (0..1024).collect();
    let shards = Sharding::FullShuffled.assign(&ds, n, 0);
    let model = Arc::new(Logistic::new(ds.clone(), 0.0));

    // Simulator.
    let cfg = a2cid2::config::ExperimentConfig {
        n_workers: n,
        topology: Topology::Ring,
        method: Method::AsyncBaseline,
        task: a2cid2::config::Task::CifarLike,
        comm_rate: 1.0,
        batch_size: 16,
        base_lr: 0.05,
        momentum: 0.0,
        weight_decay: 0.0,
        steps_per_worker: steps,
        sharding: Sharding::FullShuffled,
        dataset_size: 1024,
        seed: 0,
        compute_jitter: 0.1,
        scenario: None,
        algorithm: None,
    };
    let sim = a2cid2::simulator::run_simulation(&cfg, model.clone(), &shards).unwrap();
    let sim_acc = model.accuracy(&sim.avg_params, &test).unwrap();

    // Runtime. NOTE: the simulator's LR schedule is paper_cifar_sqrt; use
    // the same here.
    let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
    let mut rng = a2cid2::rng::Xoshiro256::seed_from_u64(cfg.seed);
    let init = model.init_params(&mut rng);
    let sources: Vec<Box<dyn GradSource>> = (0..n)
        .map(|w| {
            Box::new(RustGradSource::new(
                model.clone() as Arc<dyn Model>,
                shards.per_worker[w].clone(),
                16,
                w as u64,
            )) as Box<dyn GradSource>
        })
        .collect();
    let opts = RuntimeOptions {
        comm_rate: 1.0,
        method: Method::AsyncBaseline,
        lr: LrSchedule::paper_cifar_sqrt(0.05, n, steps),
        momentum: 0.0,
        steps_per_worker: steps,
        seed: 0,
        monitor_interval: Duration::from_millis(5),
        link_delay: None,
        scenario: None,
    };
    let run = run_async(graph, sources, init, opts).unwrap();
    let run_acc = model.accuracy(&run.avg_params, &test).unwrap();
    assert!(
        (sim_acc - run_acc).abs() < 0.15,
        "engines disagree: sim {sim_acc} vs runtime {run_acc}"
    );
}

/// Failure injection: a gradient source that errors mid-training must not
/// hang the runtime — the worker's completion flags fire on the error
/// path, the coordinator releases everyone, and run_async surfaces Err.
#[test]
fn failing_grad_source_does_not_hang() {
    struct FailingSource {
        inner: RustGradSource,
        fail_at: u64,
        count: u64,
    }
    impl GradSource for FailingSource {
        fn dim(&self) -> usize {
            self.inner.dim()
        }
        fn grad(&mut self, x: &[f32], out: &mut [f32]) -> a2cid2::Result<f32> {
            self.count += 1;
            if self.count >= self.fail_at {
                anyhow::bail!("injected gradient failure");
            }
            self.inner.grad(x, out)
        }
    }

    let n = 4;
    let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
    let ds = Arc::new(GaussianMixture::cifar_like().sample(256, 9));
    let shards = Sharding::FullShuffled.assign(&ds, n, 0);
    let model = Arc::new(Logistic::new(ds, 0.0));
    let mut rng = a2cid2::rng::Xoshiro256::seed_from_u64(0);
    let init = model.init_params(&mut rng);
    let sources: Vec<Box<dyn GradSource>> = (0..n)
        .map(|w| {
            let inner = RustGradSource::new(
                model.clone() as Arc<dyn Model>,
                shards.per_worker[w].clone(),
                8,
                w as u64,
            );
            if w == 2 {
                Box::new(FailingSource { inner, fail_at: 10, count: 0 }) as Box<dyn GradSource>
            } else {
                Box::new(inner) as Box<dyn GradSource>
            }
        })
        .collect();
    let opts = RuntimeOptions {
        comm_rate: 1.0,
        method: Method::AsyncBaseline,
        lr: LrSchedule::Constant { lr: 0.02 },
        momentum: 0.0,
        steps_per_worker: 60,
        seed: 0,
        monitor_interval: Duration::from_millis(5),
        link_delay: None,
        scenario: None,
    };
    // Must terminate (test harness timeout would catch a hang) and
    // surface the injected error.
    let result = run_async(graph, sources, init, opts);
    let err = format!("{:#}", result.err().expect("should propagate the failure"));
    assert!(err.contains("injected gradient failure"), "{err}");
}
