//! Integration: the AOT artifact contract — manifest, init blobs, and the
//! numeric equivalence of the PJRT-executed L1 kernel with the Rust
//! vecops mirror (the cross-language correctness pin).
//!
//! All tests skip gracefully when `artifacts/` is absent. The whole file
//! requires the `pjrt` feature (the offline image has no `xla` crate).
#![cfg(feature = "pjrt")]

use a2cid2::gossip::vecops;
use a2cid2::runtime::artifacts::{default_artifact_dir, Manifest};
use a2cid2::runtime::pjrt::{lit_f32, lit_scalar, to_scalar_f32, to_vec_f32, PjrtContext};

fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::load(default_artifact_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn manifest_covers_all_request_path_artifacts() {
    let Some(m) = manifest_or_skip() else { return };
    for name in [
        "mlp_train_step",
        "mlp_grad",
        "mlp_eval",
        "mlp_comm_step",
        "mlp_init",
        "transformer_train_step",
        "transformer_grad",
        "transformer_eval",
        "transformer_comm_step",
        "transformer_init",
        "acid_mix_grad_4096",
        "acid_mix_comm_4096",
    ] {
        let meta = m.get(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(
            m.path_of(meta).exists(),
            "{name}: file {} missing",
            meta.file
        );
        assert!(meta.param_dim().unwrap() > 0);
    }
}

#[test]
fn init_blobs_match_param_dims() {
    let Some(m) = manifest_or_skip() else { return };
    for model in ["mlp", "transformer"] {
        let init = m.load_init(model).unwrap();
        let dim = m.get(&format!("{model}_grad")).unwrap().param_dim().unwrap();
        assert_eq!(init.len(), dim, "{model} init length");
        assert!(init.iter().all(|v| v.is_finite()));
        // Not all-zero (He/normal init on the weights).
        assert!(init.iter().any(|&v| v != 0.0));
    }
}

#[test]
fn pjrt_mix_grad_kernel_matches_rust_vecops() {
    let Some(m) = manifest_or_skip() else { return };
    let ctx = PjrtContext::cpu().unwrap();
    let exe = ctx.load_artifact(&m, "acid_mix_grad_4096").unwrap();
    let n = 4096;
    let mut rng = a2cid2::rng::Xoshiro256::seed_from_u64(1);
    let x: Vec<f32> = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    let xt: Vec<f32> = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    let (eta, dt, gamma) = (0.3f32, 0.7f32, 0.05f32);

    let outs = exe
        .run(&[
            lit_f32(&x),
            lit_f32(&xt),
            lit_f32(&g),
            lit_scalar(eta),
            lit_scalar(dt),
            lit_scalar(gamma),
        ])
        .unwrap();
    assert_eq!(outs.len(), 2);
    let got_x = to_vec_f32(&outs[0]).unwrap();
    let got_xt = to_vec_f32(&outs[1]).unwrap();

    // Rust mirror.
    let w = a2cid2::gossip::Mixer::new(eta as f64).weights(dt as f64);
    let mut want_x = x.clone();
    let mut want_xt = xt.clone();
    vecops::mix_grad(w.wa, w.wb, gamma, &g, &mut want_x, &mut want_xt);
    for i in 0..n {
        assert!(
            (got_x[i] - want_x[i]).abs() < 1e-5,
            "x[{i}]: pjrt {} vs rust {}",
            got_x[i],
            want_x[i]
        );
        assert!((got_xt[i] - want_xt[i]).abs() < 1e-5);
    }
}

#[test]
fn pjrt_mix_comm_kernel_matches_rust_vecops() {
    let Some(m) = manifest_or_skip() else { return };
    let ctx = PjrtContext::cpu().unwrap();
    let exe = ctx.load_artifact(&m, "acid_mix_comm_4096").unwrap();
    let n = 4096;
    let mut rng = a2cid2::rng::Xoshiro256::seed_from_u64(2);
    let x: Vec<f32> = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    let xt: Vec<f32> = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    let xp: Vec<f32> = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    let (eta, dt, alpha, alpha_tilde) = (0.2f32, 0.4f32, 0.5f32, 1.8f32);

    let outs = exe
        .run(&[
            lit_f32(&x),
            lit_f32(&xt),
            lit_f32(&xp),
            lit_scalar(eta),
            lit_scalar(dt),
            lit_scalar(alpha),
            lit_scalar(alpha_tilde),
        ])
        .unwrap();
    let got_x = to_vec_f32(&outs[0]).unwrap();
    let got_xt = to_vec_f32(&outs[1]).unwrap();

    let w = a2cid2::gossip::Mixer::new(eta as f64).weights(dt as f64);
    let mut want_x = x.clone();
    let mut want_xt = xt.clone();
    vecops::mix_comm(w.wa, w.wb, alpha, alpha_tilde, &xp, &mut want_x, &mut want_xt);
    for i in 0..n {
        assert!((got_x[i] - want_x[i]).abs() < 1e-5);
        assert!((got_xt[i] - want_xt[i]).abs() < 1e-5);
    }
}

#[test]
fn mlp_eval_artifact_returns_finite_loss() {
    let Some(m) = manifest_or_skip() else { return };
    let ctx = PjrtContext::cpu().unwrap();
    let meta = m.get("mlp_eval").unwrap();
    let dim = meta.param_dim().unwrap();
    let feat = meta.int("feat_dim").unwrap() as usize;
    let batch = meta.int("batch").unwrap() as usize;
    let exe = ctx.load_artifact(&m, "mlp_eval").unwrap();
    let params = m.load_init("mlp").unwrap();
    assert_eq!(params.len(), dim);
    let xb = vec![0.1f32; batch * feat];
    let yb: Vec<i32> = (0..batch as i32).map(|i| i % 10).collect();
    let outs = exe
        .run(&[
            lit_f32(&params),
            a2cid2::runtime::pjrt::lit_f32_matrix(&xb, batch, feat).unwrap(),
            xla::Literal::vec1(&yb),
        ])
        .unwrap();
    let loss = to_scalar_f32(&outs[0]).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
}
