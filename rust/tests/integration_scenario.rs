//! Integration: time-varying network scenarios, end to end on BOTH
//! engines — the virtual-time simulator (exact, seed-deterministic) and
//! the real-thread runtime (live, terminates and respects the union
//! topology while links switch and drop under it).

use std::sync::Arc;
use std::time::Duration;

use a2cid2::config::{ExperimentConfig, Method, Scenario, Task};
use a2cid2::data::{GaussianMixture, Sharding};
use a2cid2::graph::{Graph, Topology};
use a2cid2::model::{Logistic, Model};
use a2cid2::optim::LrSchedule;
use a2cid2::runtime::{run_async, GradSource, RustGradSource, RuntimeOptions};
use a2cid2::simulator::run_simulation;

const SWITCH_AND_DROP: &str = "ring@0,exponential@0.5;drop=0.2:0.25:0.75:7";

fn cfg(n: usize, scenario: &str) -> ExperimentConfig {
    ExperimentConfig {
        n_workers: n,
        topology: Topology::Ring,
        method: Method::Acid,
        task: Task::CifarLike,
        comm_rate: 1.0,
        batch_size: 8,
        base_lr: 0.02,
        momentum: 0.0,
        weight_decay: 0.0,
        steps_per_worker: 120,
        sharding: Sharding::FullShuffled,
        dataset_size: 256,
        seed: 11,
        compute_jitter: 0.1,
        scenario: Some(Scenario::parse(scenario).unwrap()),
        algorithm: None,
    }
}

#[test]
fn simulator_scenario_is_seed_deterministic() {
    let c = cfg(8, SWITCH_AND_DROP);
    let ds = Arc::new(GaussianMixture::cifar_like().sample(c.dataset_size, 5));
    let shards = c.sharding.assign(&ds, c.n_workers, c.seed);
    let model = Arc::new(Logistic::new(ds, 0.0));
    let a = run_simulation(&c, model.clone(), &shards).unwrap();
    let b = run_simulation(&c, model.clone(), &shards).unwrap();
    assert_eq!(a.avg_params, b.avg_params, "bit-identical replay");
    assert_eq!(a.n_comms, b.n_comms);
    assert_eq!(a.net_updates, b.net_updates);
    assert!(a.net_updates >= 3, "switch + drop + recover: {}", a.net_updates);

    // A different seed genuinely changes the trajectory.
    let mut c2 = cfg(8, SWITCH_AND_DROP);
    c2.seed = 12;
    let d = run_simulation(&c2, model, &shards).unwrap();
    assert_ne!(a.avg_params, d.avg_params);
}

#[test]
fn simulator_scenario_still_learns() {
    let c = cfg(8, SWITCH_AND_DROP);
    let ds = Arc::new(GaussianMixture::cifar_like().sample(c.dataset_size, 5));
    let shards = c.sharding.assign(&ds, c.n_workers, c.seed);
    let model = Arc::new(Logistic::new(ds, 0.0));
    let res = run_simulation(&c, model.clone(), &shards).unwrap();
    let idx: Vec<usize> = (0..c.dataset_size).collect();
    let acc = model.accuracy(&res.avg_params, &idx).unwrap();
    assert!(acc > 0.5, "training rode through the switch: acc={acc}");
    // Consensus stays finite through the dropout window.
    let cons = res.recorder.get("consensus").unwrap();
    assert!(cons.points.iter().all(|(_, v)| v.is_finite()));
}

#[test]
fn runtime_scenario_terminates_and_respects_union_topology() {
    let n = 6;
    let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
    let ds = Arc::new(GaussianMixture::cifar_like().sample(256, 6));
    let shards = Sharding::FullShuffled.assign(&ds, n, 0);
    let model = Arc::new(Logistic::new(ds, 0.0));
    let mut rng = a2cid2::rng::Xoshiro256::seed_from_u64(0);
    let init = model.init_params(&mut rng);
    let sources: Vec<Box<dyn GradSource>> = (0..n)
        .map(|w| {
            let mut s = RustGradSource::new(
                model.clone() as Arc<dyn Model>,
                shards.per_worker[w].clone(),
                8,
                w as u64,
            );
            // Pace the run so the scenario replay lands mid-training.
            s.extra_delay = Some(Duration::from_micros(300));
            Box::new(s) as Box<dyn GradSource>
        })
        .collect();
    let opts = RuntimeOptions {
        comm_rate: 1.0,
        method: Method::Acid,
        lr: LrSchedule::Constant { lr: 0.02 },
        momentum: 0.0,
        steps_per_worker: 100,
        seed: 0,
        monitor_interval: Duration::from_millis(2),
        link_delay: None,
        scenario: Some(Scenario::parse(SWITCH_AND_DROP).unwrap()),
    };
    let res = run_async(graph, sources, init, opts).unwrap();
    assert_eq!(res.grads_per_worker, vec![100; n]);
    assert!(res.net_updates >= 1, "scenario updates landed: {}", res.net_updates);

    // Pairings must stay inside the UNION of ring(6) and exponential(6)
    // — under a scenario the instantaneous check is the coordinator's,
    // but the union bound is externally verifiable.
    let union = {
        let ring = Graph::build(&Topology::Ring, n).unwrap();
        let exp = Graph::build(&Topology::Exponential, n).unwrap();
        Graph::from_edges(n, ring.edges.iter().chain(exp.edges.iter()).copied())
    };
    for i in 0..n {
        for j in 0..n {
            if i != j && !union.has_edge(i, j) {
                assert_eq!(res.pairing.counts[i][j], 0, "pairing outside the union {i}-{j}");
            }
        }
    }
}

#[test]
fn scenario_parse_rejects_garbage_but_roundtrips_config() {
    // The satellite contract: scenario strings parse (or fail) the same
    // way through the TOML config layer as directly.
    assert!(Scenario::parse("ring@0,exp@0.5").is_ok());
    assert!(Scenario::parse("ring@0,exp@2.0").is_err());
    let toml = format!("[experiment]\nscenario = \"{SWITCH_AND_DROP}\"\n");
    let cfg = ExperimentConfig::from_toml(&toml).unwrap();
    assert_eq!(cfg.scenario, Some(Scenario::parse(SWITCH_AND_DROP).unwrap()));
}
