//! Ring acceleration demo: sweep worker counts on the ring and print the
//! three-way comparison (AR-SGD, async baseline, A²CiD²) plus consensus,
//! dumping loss curves to CSV for plotting.
//!
//! ```bash
//! cargo run --release --example ring_acceleration [-- n_max] [-- out.csv]
//! ```

use a2cid2::config::Method;
use a2cid2::experiments::common::{base_config, set_workers, train_once};
use a2cid2::experiments::registry;
use a2cid2::graph::Topology;
use a2cid2::metrics::{Recorder, Table};

fn main() -> a2cid2::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_max: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(16);
    let csv = args.get(1).cloned().unwrap_or_else(|| "results/ring_acceleration.csv".into());

    let scale = registry::scale();
    let mut cfg = base_config(scale);
    cfg.topology = Topology::Ring;
    cfg.task = a2cid2::config::Task::ImagenetLike;

    let mut table = Table::new(
        "ring acceleration sweep",
        &["n", "method", "final loss", "held-out acc", "consensus", "chi1", "sqrt(chi1*chi2)"],
    );
    let mut rec = Recorder::new();
    let mut n = 4usize;
    while n <= n_max {
        set_workers(&mut cfg, n, scale);
        for method in [Method::AllReduce, Method::AsyncBaseline, Method::Acid] {
            cfg.method = method;
            let out = train_once(&cfg)?;
            let cons = out
                .consensus
                .as_ref()
                .and_then(|s| s.last())
                .map(|(_, v)| format!("{v:.4}"))
                .unwrap_or_else(|| "-".into());
            let (c1, cacc) = out
                .chis
                .map(|(a, b)| (format!("{a:.1}"), format!("{:.1}", (a * b).sqrt())))
                .unwrap_or(("-".into(), "-".into()));
            table.row(&[
                n.to_string(),
                method.name().into(),
                format!("{:.4}", out.final_loss),
                format!("{:.3}", out.accuracy.unwrap_or(f64::NAN)),
                cons,
                c1,
                cacc,
            ]);
            let mut series = out.loss.clone();
            series.name = format!("loss/n{n}/{}", method.name());
            rec.series.push(series);
        }
        n *= 2;
    }
    table.print();
    rec.write_csv(std::path::Path::new(&csv), 1000)?;
    println!("loss curves -> {csv}");
    Ok(())
}
