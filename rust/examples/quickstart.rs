//! Quickstart: train a small classifier with 4 asynchronous decentralized
//! workers on the ring graph, with and without the A²CiD² momentum, using
//! the AOT-compiled HLO artifacts on the request path (no Python).
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use a2cid2::config::Method;
use a2cid2::data::{GaussianMixture, Sharding};
use a2cid2::graph::{Graph, Topology};
use a2cid2::optim::LrSchedule;
use a2cid2::runtime::artifacts::{default_artifact_dir, Manifest};
use a2cid2::runtime::pjrt::PjrtContext;
use a2cid2::runtime::pjrt_grad::MlpPjrtGradSource;
use a2cid2::runtime::worker::{run_async, GradSource, RuntimeOptions};

fn main() -> a2cid2::Result<()> {
    let n = 4;
    let steps = 150;
    let graph = Arc::new(Graph::build(&Topology::Ring, n)?);
    let spectrum = graph.spectrum(1.0);
    println!(
        "ring graph n={n}: chi1={:.2} chi2={:.2} (accelerated factor sqrt(chi1*chi2)={:.2})",
        spectrum.chi1,
        spectrum.chi2,
        spectrum.chi_acc()
    );

    // The L2 model was AOT-lowered by `make artifacts`; load it via PJRT.
    let manifest = Manifest::load(default_artifact_dir())?;
    let ctx = PjrtContext::cpu()?;
    println!("PJRT platform: {}", ctx.platform());
    let grad_meta = manifest.get("mlp_grad")?;
    let param_dim = grad_meta.param_dim()?;
    let feat_dim = grad_meta.int("feat_dim")? as usize;
    let n_classes = grad_meta.int("n_classes")? as usize;
    let batch = grad_meta.int("batch")? as usize;
    let init = manifest.load_init("mlp")?;

    // Synthetic 10-class task matching the artifact's input shapes.
    let dataset = Arc::new(
        GaussianMixture { dim: feat_dim, n_classes, margin: 3.0, sigma: 1.0 }.sample(4096, 7),
    );
    let shards = Sharding::FullShuffled.assign(&dataset, n, 1);
    let eval_idx: Vec<usize> = (0..dataset.len()).collect();

    for method in [Method::AsyncBaseline, Method::Acid] {
        let sources: Vec<Box<dyn GradSource>> = (0..n)
            .map(|w| {
                let exe = ctx.load_artifact(&manifest, "mlp_grad").expect("load artifact");
                Box::new(MlpPjrtGradSource::new(
                    exe,
                    dataset.clone(),
                    shards.per_worker[w].clone(),
                    batch,
                    param_dim,
                    w as u64,
                )) as Box<dyn GradSource>
            })
            .collect();
        let opts = RuntimeOptions {
            comm_rate: 1.0,
            method,
            lr: LrSchedule::Constant { lr: 0.05 },
            momentum: 0.9,
            steps_per_worker: steps,
            seed: 0,
            ..Default::default()
        };
        let res = run_async(graph.clone(), sources, init.clone(), opts)?;
        // Accuracy of the averaged model, via a pure-Rust evaluator.
        let eval = a2cid2::model::Mlp::new(dataset.clone(), 64, 0.0);
        use a2cid2::model::Model;
        let acc = eval.accuracy(&res.avg_params, &eval_idx).unwrap();
        let loss = res
            .recorder
            .get("train_loss")
            .map(|s| s.tail_mean(0.2))
            .unwrap_or(f64::NAN);
        println!(
            "{:>15}: wall {:.1}s  grads/worker {:?}  pairings {}  final loss {:.3}  accuracy {:.3}",
            res.acid.label(),
            res.wall_secs,
            res.grads_per_worker,
            res.pairing.total,
            loss,
            acc
        );
    }
    println!("quickstart OK");
    Ok(())
}
