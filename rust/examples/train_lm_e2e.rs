//! End-to-end driver (the EXPERIMENTS.md validation run): train a
//! transformer language model with asynchronous decentralized workers on
//! the ring graph, with all three layers composed on the request path —
//!
//!   L1 Pallas fused-mixing kernel + L2 JAX transformer fwd/bwd
//!   (AOT-compiled HLO, executed via PJRT — Python-free), driven by
//!   L3's worker cells (gradient + communication threads) and the FIFO
//!   availability-queue coordinator.
//!
//! Runs the async baseline and A²CiD² back-to-back on the same corpus and
//! logs per-method loss curves + consensus to CSV.
//!
//! ```bash
//! make artifacts   # builds transformer artifacts (preset: small, ~0.9M)
//! cargo run --release --example train_lm_e2e [-- workers] [-- steps]
//! # paper-scale (~100M params; heavy!):
//! #   A2CID2_TRANSFORMER_PRESET=paper make artifacts && ...
//! ```

use std::sync::Arc;

use a2cid2::config::Method;
use a2cid2::data::MarkovCorpus;
use a2cid2::graph::{Graph, Topology};
use a2cid2::metrics::{Recorder, Table};
use a2cid2::optim::LrSchedule;
use a2cid2::runtime::artifacts::{default_artifact_dir, Manifest};
use a2cid2::runtime::pjrt::PjrtContext;
use a2cid2::runtime::pjrt_grad::LmPjrtGradSource;
use a2cid2::runtime::worker::{run_async, GradSource, RuntimeOptions};

fn main() -> a2cid2::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);

    // --- artifacts (L1 + L2, compiled once by `make artifacts`).
    let manifest = Manifest::load(default_artifact_dir())?;
    let ctx = PjrtContext::cpu()?;
    let meta = manifest.get("transformer_grad")?;
    let param_dim = meta.param_dim()?;
    let vocab = meta.int("vocab")? as usize;
    let seq = meta.int("seq")? as usize;
    let batch = meta.int("batch")? as usize;
    let init = manifest.load_init("transformer")?;
    println!(
        "transformer artifact: P={param_dim} vocab={vocab} seq={seq} batch={batch} \
         ({} layers, d={})",
        meta.int("n_layers")?,
        meta.int("d_model")?
    );

    // --- workload: synthetic Markov corpus with a known entropy floor.
    let branch = 4;
    let corpus = Arc::new(MarkovCorpus::generate(vocab, branch, 200_000, 11));
    println!(
        "corpus: {} tokens over {vocab} symbols, entropy floor {:.3} nats/token",
        corpus.tokens.len(),
        MarkovCorpus::entropy_floor(branch)
    );

    let graph = Arc::new(Graph::build(&Topology::Ring, n)?);
    let spectrum = graph.spectrum(1.0);
    println!(
        "ring n={n}: chi1={:.2} chi2={:.2} sqrt={:.2}",
        spectrum.chi1,
        spectrum.chi2,
        spectrum.chi_acc()
    );

    let mut rec = Recorder::new();
    let mut table = Table::new(
        "train_lm_e2e — asynchronous decentralized transformer LM (ring)",
        &[
            "method",
            "wall s",
            "steps/worker",
            "pairings",
            "final loss",
            "floor",
            "consensus end",
        ],
    );
    for method in [Method::AsyncBaseline, Method::Acid] {
        let sources: Vec<Box<dyn GradSource>> = (0..n)
            .map(|w| {
                let exe = ctx
                    .load_artifact(&manifest, "transformer_grad")
                    .expect("load transformer_grad");
                Box::new(LmPjrtGradSource::new(
                    exe,
                    corpus.clone(),
                    batch,
                    seq,
                    param_dim,
                    1000 + w as u64,
                )) as Box<dyn GradSource>
            })
            .collect();
        let opts = RuntimeOptions {
            comm_rate: 1.0,
            method,
            lr: LrSchedule::WarmupStep {
                base_lr: 0.05,
                scale: (n as f64).sqrt(),
                warmup_steps: steps / 10,
                milestones: vec![steps / 2, steps * 3 / 4],
            },
            momentum: 0.9,
            steps_per_worker: steps,
            seed: 0,
            monitor_interval: std::time::Duration::from_millis(200),
            link_delay: None,
            scenario: None,
        };
        let t0 = std::time::Instant::now();
        let res = run_async(graph.clone(), sources, init.clone(), opts)?;
        let wall = t0.elapsed().as_secs_f64();

        let loss = res.recorder.get("train_loss").cloned().unwrap_or_default();
        let final_loss = loss.tail_mean(0.15);
        let consensus = res
            .recorder
            .get("consensus")
            .and_then(|s| s.last())
            .map(|(_, v)| v)
            .unwrap_or(f64::NAN);
        println!(
            "{}: {:.1}s, loss {:.3} (start {:.3}), {} pairings",
            res.acid.label(),
            wall,
            final_loss,
            loss.points.first().map(|p| p.1).unwrap_or(f64::NAN),
            res.pairing.total
        );
        table.row(&[
            res.acid.label().into(),
            format!("{wall:.1}"),
            format!("{:?}", res.grads_per_worker.iter().max().unwrap()),
            res.pairing.total.to_string(),
            format!("{final_loss:.3}"),
            format!("{:.3}", MarkovCorpus::entropy_floor(branch)),
            format!("{consensus:.4}"),
        ]);
        for (name, series_name) in [("loss", "train_loss"), ("consensus", "consensus")] {
            if let Some(s) = res.recorder.get(series_name) {
                let mut s = s.clone();
                s.name = format!("{name}/{}", res.acid.label());
                rec.series.push(s);
            }
        }
    }
    table.print();
    let csv = "results/train_lm_e2e.csv";
    rec.write_csv(std::path::Path::new(csv), 2000)?;
    println!("curves -> {csv}");
    Ok(())
}
