//! Heterogeneous-data (federated-style) extension — the paper's
//! conclusion flags data heterogeneity as the natural next step for
//! A²CiD²; its theory already covers it through the ζ² term.
//!
//! This example Dirichlet-skews the label distribution across workers
//! (smaller α = more skew) and compares the async baseline with A²CiD² on
//! the ring: with heterogeneity, local models drift toward their local
//! optima between averagings, so consensus — and hence the momentum's
//! acceleration — matters much more than in the IID case.
//!
//! ```bash
//! cargo run --release --example heterogeneous_data
//! ```

use a2cid2::config::Method;
use a2cid2::data::Sharding;
use a2cid2::experiments::common::{base_config, set_workers, train_once};
use a2cid2::experiments::registry;
use a2cid2::graph::Topology;
use a2cid2::metrics::Table;

fn main() -> a2cid2::Result<()> {
    let scale = registry::scale();
    let mut cfg = base_config(scale);
    cfg.topology = Topology::Ring;
    cfg.task = a2cid2::config::Task::CifarLike;
    set_workers(&mut cfg, 16, scale);

    let mut table = Table::new(
        "heterogeneous data (Dirichlet label skew), ring n=16",
        &["sharding", "method", "final loss", "held-out acc", "consensus"],
    );
    let shardings = [
        ("iid".to_string(), Sharding::Iid),
        ("dirichlet a=1.0".to_string(), Sharding::Dirichlet { alpha: 1.0 }),
        ("dirichlet a=0.1".to_string(), Sharding::Dirichlet { alpha: 0.1 }),
    ];
    for (name, sharding) in shardings {
        for method in [Method::AsyncBaseline, Method::Acid] {
            cfg.sharding = sharding.clone();
            cfg.method = method;
            let out = train_once(&cfg)?;
            let cons = out
                .consensus
                .as_ref()
                .map(|s| s.tail_mean(0.5))
                .unwrap_or(f64::NAN);
            table.row(&[
                name.clone(),
                method.name().into(),
                format!("{:.4}", out.final_loss),
                format!("{:.3}", out.accuracy.unwrap_or(f64::NAN)),
                format!("{cons:.4}"),
            ]);
        }
    }
    table.print();
    println!(
        "Note: increasing skew raises the consensus floor; A2CiD2's lower \
         effective chi keeps local replicas closer to the average."
    );
    Ok(())
}
