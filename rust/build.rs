//! Toolchain probe: AVX-512 intrinsics are stable only from Rust 1.89,
//! and this crate builds on older toolchains too. The `a2cid2_avx512`
//! cfg gates `gossip::vecops::avx512` so the crate compiles everywhere
//! and the 512-bit path simply does not exist (runtime selection falls
//! back to the 256-bit backend) on toolchains that predate it.

use std::process::Command;

fn rustc_version() -> Option<(u64, u64)> {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (abc123 2025-07-01)" — second word is the version.
    let ver = text.split_whitespace().nth(1)?;
    let mut parts = ver.split(['.', '-']);
    let major = parts.next()?.parse().ok()?;
    let minor = parts.next()?.parse().ok()?;
    Some((major, minor))
}

fn main() {
    // Declared unconditionally so `unexpected_cfgs` (deny-by-default in
    // CI's clippy run) knows the name even when the cfg is off.
    println!("cargo:rustc-check-cfg=cfg(a2cid2_avx512)");
    if let Some((major, minor)) = rustc_version() {
        if (major, minor) >= (1, 89) {
            println!("cargo:rustc-cfg=a2cid2_avx512");
        }
    }
    println!("cargo:rerun-if-changed=build.rs");
}
