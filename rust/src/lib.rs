//! # A²CiD² — Accelerating Asynchronous Communication in Decentralized Deep Learning
//!
//! A from-scratch reproduction of the paper's full system as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the decentralized asynchronous training
//!   runtime: per-worker gradient & communication threads (the paper's
//!   Algorithm 1), a FIFO availability-queue pairing coordinator, the
//!   continuous-momentum gossip dynamics, and a virtual-time discrete-event
//!   simulator that runs the same dynamics at large worker counts.
//! * **Layer 2** — JAX training-step graphs (MLP classifier, transformer LM)
//!   over flattened parameter vectors, AOT-lowered to HLO text in
//!   `python/compile/model.py` and executed here through PJRT
//!   ([`runtime::pjrt`]).
//! * **Layer 1** — the fused A²CiD² mixing/update Pallas kernel
//!   (`python/compile/kernels/acid_mix.py`), lowered into the same HLO.
//!
//! The public surface is organized bottom-up: substrates ([`rng`],
//! [`linalg`], [`graph`], [`data`], [`model`], [`optim`], [`metrics`],
//! [`config`]), the paper's algorithm ([`gossip`]), the shared execution
//! core ([`engine`]: the per-event [`engine::DynamicsCore`] plus the
//! [`engine::Scheduler`] implementations both engines drive), and two
//! execution engines ([`simulator`] for virtual time, [`runtime`] for
//! real threads + PJRT) that replay the same time-varying network
//! [`config::Scenario`]s. [`experiments`] maps every table and figure of
//! the paper to a runnable driver, and [`testing::oracle`] holds the
//! paper-conformance contract: checked-in reference values with
//! tolerances (`rust/oracle/paper.toml`) that `a2cid2 verify` enforces
//! over every registry run.

pub mod cli;
pub mod config;
pub mod data;
pub mod engine;
pub mod experiments;
pub mod gossip;
pub mod graph;
pub mod linalg;
pub mod locality;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod simulator;
pub mod testing;
pub mod util;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;
