//! Multinomial logistic regression (softmax + cross-entropy) over the
//! Gaussian-mixture tasks — the convex classification workhorse of the
//! mid-scale sweeps.

use std::sync::Arc;

use super::Model;
use crate::data::Dataset;
use crate::rng::Xoshiro256;

/// Softmax regression: parameters are a row-major `n_classes × (dim + 1)`
/// matrix (weights + bias column), flattened.
#[derive(Clone)]
pub struct Logistic {
    pub data: Arc<Dataset>,
    pub weight_decay: f32,
}

impl Logistic {
    pub fn new(data: Arc<Dataset>, weight_decay: f32) -> Self {
        Self { data, weight_decay }
    }

    fn n_classes(&self) -> usize {
        self.data.n_classes
    }

    fn row(&self) -> usize {
        self.data.dim + 1
    }

    /// Class logits for one example into `logits`.
    fn logits(&self, params: &[f32], x: &[f32], logits: &mut [f32]) {
        let row = self.row();
        for (c, l) in logits.iter_mut().enumerate() {
            let w = &params[c * row..(c + 1) * row];
            let mut acc = w[self.data.dim]; // bias
            for (wi, xi) in w[..self.data.dim].iter().zip(x) {
                acc += wi * xi;
            }
            *l = acc;
        }
    }
}

/// Numerically-stable log-softmax in place; returns logsumexp.
pub(crate) fn log_softmax(logits: &mut [f32]) -> f32 {
    let max = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse = max
        + logits
            .iter()
            .map(|&l| (l - max).exp())
            .sum::<f32>()
            .ln();
    for l in logits.iter_mut() {
        *l -= lse;
    }
    lse
}

impl Model for Logistic {
    fn dim(&self) -> usize {
        self.n_classes() * self.row()
    }

    fn init_params(&self, _rng: &mut Xoshiro256) -> Vec<f32> {
        vec![0.0; self.dim()]
    }

    fn loss_grad(&self, params: &[f32], idx: &[usize], grad: &mut [f32]) -> f32 {
        let row = self.row();
        let k = self.n_classes();
        grad.fill(0.0);
        let inv_b = 1.0 / idx.len().max(1) as f32;
        let mut loss = 0.0f64;
        let mut logits = vec![0.0f32; k];
        for &i in idx {
            let (x, y) = self.data.example(i);
            self.logits(params, x, &mut logits);
            log_softmax(&mut logits);
            loss -= logits[y as usize] as f64;
            for c in 0..k {
                // dL/dlogit_c = p_c − 1{c == y}
                let p = logits[c].exp() - if c as u32 == y { 1.0 } else { 0.0 };
                let coeff = p * inv_b;
                let g = &mut grad[c * row..(c + 1) * row];
                for (gi, &xi) in g[..self.data.dim].iter_mut().zip(x) {
                    *gi += coeff * xi;
                }
                g[self.data.dim] += coeff;
            }
        }
        if self.weight_decay > 0.0 {
            for (g, &w) in grad.iter_mut().zip(params) {
                *g += self.weight_decay * w;
            }
        }
        (loss * inv_b as f64) as f32
    }

    fn accuracy(&self, params: &[f32], idx: &[usize]) -> Option<f64> {
        let k = self.n_classes();
        let mut logits = vec![0.0f32; k];
        let mut correct = 0usize;
        for &i in idx {
            let (x, y) = self.data.example(i);
            self.logits(params, x, &mut logits);
            let best = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if best as u32 == y {
                correct += 1;
            }
        }
        Some(correct as f64 / idx.len().max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::GaussianMixture;

    fn setup() -> Logistic {
        let ds = GaussianMixture { dim: 8, n_classes: 4, margin: 4.0, sigma: 1.0 }
            .sample(300, 1);
        Logistic::new(Arc::new(ds), 1e-4)
    }

    #[test]
    fn initial_loss_is_log_k() {
        let m = setup();
        let idx: Vec<usize> = (0..300).collect();
        let mut rng = Xoshiro256::seed_from_u64(0);
        let p = m.init_params(&mut rng);
        let l = m.eval_loss(&p, &idx);
        assert!((l - (4.0f32).ln()).abs() < 1e-4, "loss={l}");
    }

    #[test]
    fn gradient_finite_diff() {
        let m = setup();
        let idx: Vec<usize> = (0..64).collect();
        super::super::finite_diff_check(&m, &idx, 5, 2e-2);
    }

    #[test]
    fn sgd_reaches_high_accuracy() {
        let m = setup();
        let idx: Vec<usize> = (0..300).collect();
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut w = m.init_params(&mut rng);
        let mut g = vec![0.0f32; m.dim()];
        for step in 0..400 {
            let batch: Vec<usize> = (0..32).map(|_| rng.gen_range(300)).collect();
            m.loss_grad(&w, &batch, &mut g);
            let lr = 0.5 / (1.0 + step as f32 / 100.0);
            for (wi, gi) in w.iter_mut().zip(&g) {
                *wi -= lr * gi;
            }
        }
        let acc = m.accuracy(&w, &idx).unwrap();
        assert!(acc > 0.9, "accuracy={acc}");
    }

    #[test]
    fn log_softmax_normalizes() {
        let mut l = vec![1.0f32, 2.0, 3.0];
        log_softmax(&mut l);
        let total: f32 = l.iter().map(|v| v.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }
}
