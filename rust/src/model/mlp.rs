//! Two-layer MLP with manual backprop — the non-convex workhorse for the
//! paper's ResNet experiments at simulator scale (DESIGN.md §3: the
//! object of study is the decentralization gap, not the vision backbone).

use std::sync::Arc;

use super::logistic::log_softmax;
use super::Model;
use crate::data::Dataset;
use crate::rng::{standard_normal, Xoshiro256};

/// `dim → hidden (ReLU) → n_classes` classifier with softmax
/// cross-entropy. Parameter layout (flat):
/// `[W1 (hidden×dim), b1 (hidden), W2 (classes×hidden), b2 (classes)]`.
#[derive(Clone)]
pub struct Mlp {
    pub data: Arc<Dataset>,
    pub hidden: usize,
    pub weight_decay: f32,
}

impl Mlp {
    pub fn new(data: Arc<Dataset>, hidden: usize, weight_decay: f32) -> Self {
        Self { data, hidden, weight_decay }
    }

    fn sizes(&self) -> (usize, usize, usize, usize) {
        let d = self.data.dim;
        let h = self.hidden;
        let k = self.data.n_classes;
        (h * d, h, k * h, k)
    }

    /// Forward pass for one example; fills `hid` (post-ReLU) and `logits`.
    fn forward(&self, params: &[f32], x: &[f32], hid: &mut [f32], logits: &mut [f32]) {
        let d = self.data.dim;
        let h = self.hidden;
        let k = self.data.n_classes;
        let (s1, s2, s3, _) = self.sizes();
        let w1 = &params[..s1];
        let b1 = &params[s1..s1 + s2];
        let w2 = &params[s1 + s2..s1 + s2 + s3];
        let b2 = &params[s1 + s2 + s3..];
        for j in 0..h {
            let row = &w1[j * d..(j + 1) * d];
            let mut acc = b1[j];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            hid[j] = acc.max(0.0);
        }
        for c in 0..k {
            let row = &w2[c * h..(c + 1) * h];
            let mut acc = b2[c];
            for (wi, hi) in row.iter().zip(hid.iter()) {
                acc += wi * hi;
            }
            logits[c] = acc;
        }
    }
}

impl Model for Mlp {
    fn dim(&self) -> usize {
        let (s1, s2, s3, s4) = self.sizes();
        s1 + s2 + s3 + s4
    }

    fn init_params(&self, rng: &mut Xoshiro256) -> Vec<f32> {
        // He init for the ReLU layer, Xavier-ish for the head, zero biases.
        let d = self.data.dim;
        let h = self.hidden;
        let (s1, s2, s3, s4) = self.sizes();
        let mut p = vec![0.0f32; s1 + s2 + s3 + s4];
        let std1 = (2.0 / d as f64).sqrt();
        for v in &mut p[..s1] {
            *v = (standard_normal(rng) * std1) as f32;
        }
        let std2 = (1.0 / h as f64).sqrt();
        for v in &mut p[s1 + s2..s1 + s2 + s3] {
            *v = (standard_normal(rng) * std2) as f32;
        }
        p
    }

    fn loss_grad(&self, params: &[f32], idx: &[usize], grad: &mut [f32]) -> f32 {
        let d = self.data.dim;
        let h = self.hidden;
        let k = self.data.n_classes;
        let (s1, s2, s3, _) = self.sizes();
        grad.fill(0.0);
        let inv_b = 1.0 / idx.len().max(1) as f32;
        let mut loss = 0.0f64;
        let mut hid = vec![0.0f32; h];
        let mut logits = vec![0.0f32; k];
        let mut dhid = vec![0.0f32; h];
        let w2 = &params[s1 + s2..s1 + s2 + s3];
        for &i in idx {
            let (x, y) = self.data.example(i);
            self.forward(params, x, &mut hid, &mut logits);
            log_softmax(&mut logits);
            loss -= logits[y as usize] as f64;
            // Backprop.
            dhid.fill(0.0);
            {
                let (gw2, rest) = grad[s1 + s2..].split_at_mut(s3);
                let gb2 = rest;
                for c in 0..k {
                    let dl = (logits[c].exp() - if c as u32 == y { 1.0 } else { 0.0 }) * inv_b;
                    gb2[c] += dl;
                    let grow = &mut gw2[c * h..(c + 1) * h];
                    let wrow = &w2[c * h..(c + 1) * h];
                    for j in 0..h {
                        grow[j] += dl * hid[j];
                        dhid[j] += dl * wrow[j];
                    }
                }
            }
            {
                let (gw1, rest) = grad[..s1 + s2].split_at_mut(s1);
                let gb1 = rest;
                for j in 0..h {
                    if hid[j] <= 0.0 {
                        continue; // ReLU gate
                    }
                    let dj = dhid[j];
                    gb1[j] += dj;
                    let grow = &mut gw1[j * d..(j + 1) * d];
                    for (gi, &xi) in grow.iter_mut().zip(x) {
                        *gi += dj * xi;
                    }
                }
            }
        }
        if self.weight_decay > 0.0 {
            // The paper (following Goyal et al.) skips weight decay on the
            // batch-norm scale parameters; the analogue here is skipping
            // the biases.
            let (s1, s2, s3, _) = self.sizes();
            for (pos, (g, &w)) in grad.iter_mut().zip(params).enumerate() {
                let is_bias = (s1..s1 + s2).contains(&pos) || pos >= s1 + s2 + s3;
                if !is_bias {
                    *g += self.weight_decay * w;
                }
            }
        }
        (loss * inv_b as f64) as f32
    }

    fn accuracy(&self, params: &[f32], idx: &[usize]) -> Option<f64> {
        let h = self.hidden;
        let k = self.data.n_classes;
        let mut hid = vec![0.0f32; h];
        let mut logits = vec![0.0f32; k];
        let mut correct = 0usize;
        for &i in idx {
            let (x, y) = self.data.example(i);
            self.forward(params, x, &mut hid, &mut logits);
            let best = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if best as u32 == y {
                correct += 1;
            }
        }
        Some(correct as f64 / idx.len().max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::GaussianMixture;

    fn setup() -> Mlp {
        let ds = GaussianMixture { dim: 8, n_classes: 4, margin: 3.5, sigma: 1.0 }
            .sample(400, 1);
        Mlp::new(Arc::new(ds), 16, 0.0)
    }

    #[test]
    fn dim_layout() {
        let m = setup();
        assert_eq!(m.dim(), 16 * 8 + 16 + 4 * 16 + 4);
    }

    #[test]
    fn gradient_finite_diff() {
        let m = setup();
        let idx: Vec<usize> = (0..16).collect();
        super::super::finite_diff_check(&m, &idx, 7, 5e-2);
    }

    #[test]
    fn weight_decay_adds_to_weights_not_biases() {
        // Weight decay follows PyTorch semantics: it enters the gradient,
        // not the reported loss, so verify it algebraically:
        // grad_wd − grad_plain == wd·w on weight coords and 0 on biases.
        let ds = GaussianMixture { dim: 6, n_classes: 3, margin: 2.0, sigma: 1.0 }
            .sample(100, 2);
        let data = Arc::new(ds);
        let wd = 1e-2f32;
        let plain = Mlp::new(data.clone(), 8, 0.0);
        let decayed = Mlp::new(data, 8, wd);
        let mut rng = Xoshiro256::seed_from_u64(11);
        let params = plain.init_params(&mut rng);
        let idx: Vec<usize> = (0..16).collect();
        let mut g0 = vec![0.0f32; plain.dim()];
        let mut g1 = vec![0.0f32; plain.dim()];
        plain.loss_grad(&params, &idx, &mut g0);
        decayed.loss_grad(&params, &idx, &mut g1);
        let (s1, s2, s3, _) = decayed.sizes();
        for c in 0..plain.dim() {
            let is_bias = (s1..s1 + s2).contains(&c) || c >= s1 + s2 + s3;
            let want = if is_bias { 0.0 } else { wd * params[c] };
            assert!(
                (g1[c] - g0[c] - want).abs() < 1e-6,
                "coord {c}: delta {} vs {want}",
                g1[c] - g0[c]
            );
        }
    }

    #[test]
    fn sgd_learns_the_mixture() {
        let m = setup();
        let all: Vec<usize> = (0..400).collect();
        let mut rng = Xoshiro256::seed_from_u64(2);
        let mut w = m.init_params(&mut rng);
        let mut g = vec![0.0f32; m.dim()];
        let l0 = m.eval_loss(&w, &all);
        for _ in 0..600 {
            let batch: Vec<usize> = (0..32).map(|_| rng.gen_range(400)).collect();
            m.loss_grad(&w, &batch, &mut g);
            for (wi, gi) in w.iter_mut().zip(&g) {
                *wi -= 0.1 * gi;
            }
        }
        let l1 = m.eval_loss(&w, &all);
        let acc = m.accuracy(&w, &all).unwrap();
        assert!(l1 < 0.5 * l0, "{l0} -> {l1}");
        assert!(acc > 0.85, "accuracy={acc}");
    }
}
