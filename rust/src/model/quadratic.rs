//! Strongly-convex quadratic objective (least squares), the setting where
//! Prop. 3.6's strongly-convex rates — and therefore the χ₁ vs √(χ₁χ₂)
//! scaling of Tab. 1 — are sharp.

use std::sync::Arc;

use super::Model;
use crate::data::RegressionData;
use crate::rng::Xoshiro256;

/// Mini-batch least squares `f(w) = 1/(2|B|) Σ_{i∈B} (⟨w, x_i⟩ − y_i)²`
/// plus an optional ridge term `λ/2·‖w‖²` that pins the strong-convexity
/// constant μ ≥ λ.
#[derive(Clone)]
pub struct Quadratic {
    pub data: Arc<RegressionData>,
    pub ridge: f32,
}

impl Quadratic {
    pub fn new(data: Arc<RegressionData>, ridge: f32) -> Self {
        Self { data, ridge }
    }

    /// Excess distance to the generating weights, `‖w − w*‖²` (the paper's
    /// `‖x̄_T − x*‖²` convergence measure).
    pub fn dist_to_opt_sq(&self, params: &[f32]) -> f64 {
        params
            .iter()
            .zip(&self.data.w_star)
            .map(|(&a, &b)| {
                let d = a as f64 - b as f64;
                d * d
            })
            .sum()
    }
}

impl Model for Quadratic {
    fn dim(&self) -> usize {
        self.data.dim
    }

    fn init_params(&self, _rng: &mut Xoshiro256) -> Vec<f32> {
        // Start at zero: identical on every worker, consistent with the
        // paper's consensus-at-init All-Reduce.
        vec![0.0; self.data.dim]
    }

    fn loss_grad(&self, params: &[f32], idx: &[usize], grad: &mut [f32]) -> f32 {
        assert_eq!(grad.len(), self.data.dim);
        grad.fill(0.0);
        let inv_b = 1.0 / idx.len().max(1) as f32;
        let mut loss = 0.0f64;
        for &i in idx {
            let (x, y) = self.data.example(i);
            let pred: f32 = x.iter().zip(params).map(|(&a, &w)| a * w).sum();
            let resid = pred - y;
            loss += 0.5 * (resid as f64) * (resid as f64);
            let coeff = resid * inv_b;
            for (g, &xv) in grad.iter_mut().zip(x) {
                *g += coeff * xv;
            }
        }
        if self.ridge > 0.0 {
            for (g, &w) in grad.iter_mut().zip(params) {
                *g += self.ridge * w;
            }
            loss += 0.5
                * self.ridge as f64
                * params.iter().map(|&w| (w as f64) * (w as f64)).sum::<f64>();
        }
        (loss * inv_b as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LinearRegression;

    fn setup() -> Quadratic {
        let data = LinearRegression { dim: 8, noise: 0.1 }.sample(200, 1);
        Quadratic::new(Arc::new(data), 1e-3)
    }

    #[test]
    fn gradient_finite_diff() {
        let q = setup();
        let idx: Vec<usize> = (0..32).collect();
        super::super::finite_diff_check(&q, &idx, 3, 2e-2);
    }

    #[test]
    fn zero_loss_at_w_star_noiseless() {
        let data = LinearRegression { dim: 4, noise: 0.0 }.sample(64, 2);
        let w_star = data.w_star.clone();
        let q = Quadratic::new(Arc::new(data), 0.0);
        let idx: Vec<usize> = (0..64).collect();
        assert!(q.eval_loss(&w_star, &idx) < 1e-6);
        assert!(q.dist_to_opt_sq(&w_star) < 1e-12);
    }

    #[test]
    fn gd_converges() {
        let q = setup();
        let idx: Vec<usize> = (0..200).collect();
        let mut rng = Xoshiro256::seed_from_u64(0);
        let mut w = q.init_params(&mut rng);
        let mut g = vec![0.0f32; q.dim()];
        let l0 = q.eval_loss(&w, &idx);
        for _ in 0..200 {
            q.loss_grad(&w, &idx, &mut g);
            for (wi, gi) in w.iter_mut().zip(&g) {
                *wi -= 0.1 * gi;
            }
        }
        let l1 = q.eval_loss(&w, &idx);
        assert!(l1 < 0.05 * l0, "{l0} -> {l1}");
        assert!(q.dist_to_opt_sq(&w) < 0.1);
    }
}
