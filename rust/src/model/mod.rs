//! Pure-Rust reference models for the simulator and benches.
//!
//! The L2 JAX models (MLP / transformer, `python/compile/model.py`) are the
//! real request-path compute, executed through PJRT. The experiment
//! harness, however, sweeps hundreds of (n, topology, rate, seed)
//! configurations; for those we use equivalent pure-Rust models over the
//! same flat-parameter convention so a sweep finishes in seconds. The
//! integration tests pin the two implementations against each other
//! through the shared [`Model`] interface (loss decreases, gradients pass
//! finite-difference checks).

mod logistic;
mod mlp;
mod quadratic;

pub use logistic::Logistic;
pub use mlp::Mlp;
pub use quadratic::Quadratic;

use crate::rng::Xoshiro256;

/// A differentiable training objective over a flat `f32` parameter vector —
/// the exact contract the AOT'd HLO training step exposes to Layer 3.
pub trait Model: Send + Sync {
    /// Number of parameters.
    fn dim(&self) -> usize;

    /// Initialize a parameter vector.
    fn init_params(&self, rng: &mut Xoshiro256) -> Vec<f32>;

    /// Mini-batch loss and gradient at `params` on dataset rows `idx`.
    /// Writes the gradient into `grad` (len == dim) and returns the loss.
    fn loss_grad(&self, params: &[f32], idx: &[usize], grad: &mut [f32]) -> f32;

    /// Loss only (defaults to a gradient computation with a scratch buffer).
    fn eval_loss(&self, params: &[f32], idx: &[usize]) -> f32 {
        let mut scratch = vec![0.0f32; self.dim()];
        self.loss_grad(params, idx, &mut scratch)
    }

    /// Classification accuracy on rows `idx` (None for regression tasks).
    fn accuracy(&self, _params: &[f32], _idx: &[usize]) -> Option<f64> {
        None
    }
}

/// Central finite-difference gradient check used by each model's tests:
/// compares `loss_grad` against `(f(x+εe) − f(x−εe)) / 2ε` on several
/// random coordinates. Piecewise-linear activations (ReLU) make the loss
/// non-smooth on a measure-zero set that finite differences can still
/// straddle, so up to one of the sampled coordinates may exceed the
/// tolerance; a systematic gradient bug fails many.
#[cfg(test)]
pub(crate) fn finite_diff_check(model: &dyn Model, idx: &[usize], seed: u64, tol: f64) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let params = model.init_params(&mut rng);
    let mut grad = vec![0.0f32; model.dim()];
    model.loss_grad(&params, idx, &mut grad);
    let eps = 1e-3f32;
    let coords: Vec<usize> = (0..12.min(model.dim()))
        .map(|_| rng.gen_range(model.dim()))
        .collect();
    let mut failures = Vec::new();
    for &c in &coords {
        let mut plus = params.clone();
        plus[c] += eps;
        let mut minus = params.clone();
        minus[c] -= eps;
        let fd = (model.eval_loss(&plus, idx) as f64 - model.eval_loss(&minus, idx) as f64)
            / (2.0 * eps as f64);
        let an = grad[c] as f64;
        let denom = an.abs().max(fd.abs()).max(1e-3);
        if (fd - an).abs() / denom >= tol {
            failures.push(format!("coord {c}: finite-diff {fd} vs analytic {an}"));
        }
    }
    assert!(
        failures.len() <= 1,
        "{} of {} coords failed:\n{}",
        failures.len(),
        coords.len(),
        failures.join("\n")
    );
}
