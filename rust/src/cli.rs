//! Hand-rolled CLI argument parsing (no `clap` offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments and subcommands; generates usage text from the declared
//! options. Every subcommand shares ONE option namespace (declared once
//! via [`Cli::opt`]/[`Cli::flag`]); a [`SubSpec`] then scopes which of
//! the shared options each subcommand accepts, so `a2cid2 spectrum
//! --steps 9` fails loudly instead of silently ignoring the option.

use std::collections::BTreeMap;

/// Declarative option spec used for usage text and validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// The surface of one subcommand: its one-line description plus the
/// subset of the shared options/flags it accepts. Only options the user
/// typed explicitly are validated — seeded defaults never trip it.
#[derive(Clone, Debug)]
pub struct SubSpec {
    pub name: &'static str,
    pub about: String,
    pub opts: Vec<&'static str>,
    pub flags: Vec<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub options: BTreeMap<String, String>,
    /// Option names the user provided explicitly (seeded defaults are
    /// not listed) — the set subcommand validation checks.
    pub set: Vec<String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

/// A simple CLI definition: subcommand name → options.
pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    pub specs: Vec<OptSpec>,
    pub subs: Vec<SubSpec>,
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Self { program, about, specs: Vec::new(), subs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.specs.push(OptSpec { name, help, default, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    /// Declare a subcommand: which shared options and flags it accepts.
    /// Unknown subcommands are left unvalidated (the caller rejects
    /// them); every name listed here must be a declared option/flag.
    pub fn sub(
        mut self,
        name: &'static str,
        about: impl Into<String>,
        opts: &[&'static str],
        flags: &[&'static str],
    ) -> Self {
        self.subs.push(SubSpec {
            name,
            about: about.into(),
            opts: opts.to_vec(),
            flags: flags.to_vec(),
        });
        self
    }

    /// Parse a raw argv (excluding the program name). The first
    /// non-option token becomes the subcommand; later ones are positional.
    pub fn parse(&self, argv: &[String]) -> crate::Result<Args> {
        let mut args = Args::default();
        // Seed defaults.
        for spec in &self.specs {
            if let Some(d) = spec.default {
                args.options.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| {
                        anyhow::anyhow!("unknown option --{name}\n\n{}", self.usage())
                    })?;
                if spec.is_flag {
                    anyhow::ensure!(
                        inline_val.is_none(),
                        "--{name} is a flag and takes no value"
                    );
                    args.flags.push(name);
                } else {
                    let value = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?
                        }
                    };
                    args.set.push(name.clone());
                    args.options.insert(name, value);
                }
            } else if args.command.is_none() {
                args.command = Some(tok.clone());
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        self.validate_for_sub(&args)?;
        Ok(args)
    }

    /// If the parsed command has a [`SubSpec`], reject explicitly-set
    /// options and flags outside its declared surface.
    fn validate_for_sub(&self, args: &Args) -> crate::Result<()> {
        let Some(sub) = args
            .command
            .as_deref()
            .and_then(|c| self.subs.iter().find(|s| s.name == c))
        else {
            return Ok(());
        };
        let allowed = |names: &[&'static str]| {
            if names.is_empty() {
                "none".to_string()
            } else {
                names.iter().map(|n| format!("--{n}")).collect::<Vec<_>>().join(", ")
            }
        };
        for name in &args.set {
            anyhow::ensure!(
                sub.opts.iter().any(|o| o == name),
                "--{name} does not apply to '{}' (its options: {})",
                sub.name,
                allowed(&sub.opts)
            );
        }
        for flag in &args.flags {
            anyhow::ensure!(
                sub.flags.iter().any(|f| f == flag),
                "--{flag} does not apply to '{}' (its flags: {})",
                sub.name,
                allowed(&sub.flags)
            );
        }
        Ok(())
    }

    /// Render usage text.
    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for s in &self.specs {
            let tail = if s.is_flag {
                String::new()
            } else {
                match s.default {
                    Some(d) => format!(" <value>  (default: {d})"),
                    None => " <value>".to_string(),
                }
            };
            out.push_str(&format!("  --{}{}\n      {}\n", s.name, tail, s.help));
        }
        if !self.subs.is_empty() {
            out.push_str("\nSubcommands:\n");
            for sub in &self.subs {
                out.push_str(&format!("  {} — {}\n", sub.name, sub.about));
                let surface: Vec<String> = sub
                    .opts
                    .iter()
                    .chain(sub.flags.iter())
                    .map(|n| format!("--{n}"))
                    .collect();
                if !surface.is_empty() {
                    out.push_str(&format!("      accepts: {}\n", surface.join(" ")));
                }
            }
        }
        out
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> crate::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing --{name}"))?;
        raw.parse::<T>()
            .map_err(|e| anyhow::anyhow!("--{name}={raw}: {e}"))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("demo", "test cli")
            .opt("workers", "worker count", Some("8"))
            .opt("topology", "graph", Some("ring"))
            .flag("verbose", "chatty")
    }

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli().parse(&argv(&["run", "--workers", "16"])).unwrap();
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get("workers"), Some("16"));
        assert_eq!(a.get("topology"), Some("ring"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = cli()
            .parse(&argv(&["--workers=4", "--verbose", "cmd", "pos1"]))
            .unwrap();
        assert_eq!(a.get("workers"), Some("4"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.command.as_deref(), Some("cmd"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_errors_with_usage() {
        let err = cli().parse(&argv(&["--nope", "1"])).unwrap_err().to_string();
        assert!(err.contains("unknown option"));
        assert!(err.contains("--workers"));
    }

    #[test]
    fn typed_parse() {
        let a = cli().parse(&argv(&["--workers", "32"])).unwrap();
        let w: usize = a.get_parse("workers").unwrap();
        assert_eq!(w, 32);
        let bad: crate::Result<usize> = a.get_parse("topology");
        assert!(bad.is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cli().parse(&argv(&["--workers"])).is_err());
    }

    fn scoped_cli() -> Cli {
        cli()
            .opt("rate", "comm rate", Some("1.0"))
            .sub("run", "train something", &["workers", "topology"], &["verbose"])
            .sub("inspect", "look at a graph", &["topology"], &[])
    }

    #[test]
    fn sub_accepts_its_own_options_and_defaults() {
        // Explicit in-scope options pass; out-of-scope options that were
        // only seeded as defaults (rate) never trip validation.
        let a = scoped_cli().parse(&argv(&["run", "--workers", "4", "--verbose"])).unwrap();
        assert_eq!(a.get("workers"), Some("4"));
        assert_eq!(a.get("rate"), Some("1.0"));
        assert_eq!(a.set, vec!["workers"]);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn sub_rejects_out_of_scope_option_naming_the_surface() {
        let err = scoped_cli()
            .parse(&argv(&["inspect", "--workers", "4"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--workers does not apply to 'inspect'"), "{err}");
        assert!(err.contains("--topology"), "error lists the allowed set: {err}");
    }

    #[test]
    fn sub_rejects_out_of_scope_flag() {
        let err = scoped_cli()
            .parse(&argv(&["inspect", "--verbose"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--verbose does not apply to 'inspect'"), "{err}");
        assert!(err.contains("none"), "empty flag surface renders as 'none': {err}");
    }

    #[test]
    fn unknown_subcommand_is_left_unvalidated() {
        // The caller rejects unknown subcommands; the parser must not
        // second-guess options for commands it has no spec for.
        let a = scoped_cli().parse(&argv(&["mystery", "--rate", "2.0"])).unwrap();
        assert_eq!(a.command.as_deref(), Some("mystery"));
        assert_eq!(a.get("rate"), Some("2.0"));
    }

    #[test]
    fn usage_lists_subcommand_surfaces() {
        let u = scoped_cli().usage();
        assert!(u.contains("Subcommands:"), "{u}");
        assert!(u.contains("run — train something"), "{u}");
        assert!(u.contains("accepts: --workers --topology --verbose"), "{u}");
        assert!(u.contains("accepts: --topology\n"), "{u}");
    }
}
