//! Deterministic pseudo-random number generation and the samplers the
//! paper's dynamics need (uniform, normal, exponential, Poisson).
//!
//! The build image has no `rand` crate available offline, so this module is
//! a small self-contained substrate: a xoshiro256++ generator (Blackman &
//! Vigna) seeded through SplitMix64, plus inverse-CDF / Box–Muller / Knuth
//! samplers. Everything is reproducible from a `u64` seed, which the
//! simulator and experiment harness rely on for exact replay.

mod distributions;

pub use distributions::{standard_normal, Exponential, Normal, Poisson};

/// xoshiro256++ PRNG. Fast, 256-bit state, passes BigCrush; more than
/// adequate for Monte-Carlo event simulation.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

/// SplitMix64 step — used to expand a 64-bit seed into xoshiro state and as
/// a cheap stateless hash for stream splitting.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Xoshiro256 {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Derive an independent stream for substream `idx` (worker id, edge id…).
    /// Uses a hash of (seed material, idx) so streams do not overlap in
    /// practice for simulation purposes.
    pub fn split(&self, idx: u64) -> Self {
        let mut sm = self.s[0] ^ self.s[2] ^ idx.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Rebuild a generator from a previously captured [`Xoshiro256::state`].
    /// The all-zero state is rejected (xoshiro256++ would emit zeros
    /// forever); it can never be produced by `seed_from_u64`/`split`.
    pub fn from_state(s: [u64; 4]) -> Self {
        assert!(s.iter().any(|&w| w != 0), "all-zero xoshiro state");
        Self { s }
    }

    /// The raw 256-bit stream position. `from_state(state())` resumes the
    /// stream exactly — the checkpoint/restore surface.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Overwrite this generator's stream position in place.
    pub fn restore(&mut self, s: [u64; 4]) {
        assert!(s.iter().any(|&w| w != 0), "all-zero xoshiro state");
        self.s = s;
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection-free-ish method with
    /// rejection for exactness).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        let n = n as u64;
        // Rejection sampling on the top bits to avoid modulo bias.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, data: &mut [T]) {
        for i in (1..data.len()).rev() {
            let j = self.gen_range(i + 1);
            data.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (floyd's algorithm for
    /// small k, shuffle for large).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in n - k..n {
                let t = self.gen_range(j + 1);
                let v = if chosen.insert(t) { t } else { j };
                chosen.insert(v);
                out.push(v);
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_round_trip_resumes_the_stream() {
        let mut a = Xoshiro256::seed_from_u64(42);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..50).map(|_| a.next_u64()).collect();
        // from_state resumes mid-stream…
        let mut b = Xoshiro256::from_state(snap);
        let resumed: Vec<u64> = (0..50).map(|_| b.next_u64()).collect();
        assert_eq!(tail, resumed);
        // …and restore() rewinds in place.
        a.restore(snap);
        let rewound: Vec<u64> = (0..50).map(|_| a.next_u64()).collect();
        assert_eq!(tail, rewound);
    }

    #[test]
    #[should_panic]
    fn zero_state_rejected() {
        Xoshiro256::from_state([0; 4]);
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_differ() {
        let root = Xoshiro256::seed_from_u64(7);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut sum = 0.0;
        const N: usize = 100_000;
        for _ in 0..N {
            let u = rng.next_f64();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Xoshiro256::seed_from_u64(13);
        for &(n, k) in &[(100usize, 5usize), (10, 10), (1000, 100)] {
            let idx = rng.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), k);
            assert!(idx.iter().all(|&i| i < n));
        }
    }
}
