//! Samplers over [`Xoshiro256`]: Normal (Box–Muller), Exponential
//! (inverse CDF), Poisson (Knuth for small mean, PTRS transformed
//! rejection for large mean).

use super::Xoshiro256;

/// Normal distribution `N(mean, std²)` sampled via Box–Muller (the spare
/// variate is cached so consecutive draws cost one transcendental pair per
/// two samples).
#[derive(Clone, Debug)]
pub struct Normal {
    pub mean: f64,
    pub std: f64,
    spare: Option<f64>,
}

impl Normal {
    pub fn new(mean: f64, std: f64) -> Self {
        assert!(std >= 0.0, "negative std");
        Self { mean, std, spare: None }
    }

    /// Draw one sample.
    pub fn sample(&mut self, rng: &mut Xoshiro256) -> f64 {
        let z = if let Some(z) = self.spare.take() {
            z
        } else {
            // Box–Muller: u1 in (0,1] to avoid ln(0).
            let u1 = 1.0 - rng.next_f64();
            let u2 = rng.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            r * theta.cos()
        };
        self.mean + self.std * z
    }
}

/// Standard normal draw without carrying sampler state.
pub fn standard_normal(rng: &mut Xoshiro256) -> f64 {
    let u1 = 1.0 - rng.next_f64();
    let u2 = rng.next_f64();
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

/// Exponential distribution with rate `lambda` (mean `1/lambda`), the
/// inter-arrival law of the paper's Poisson point processes
/// (Assumption 3.2).
#[derive(Clone, Copy, Debug)]
pub struct Exponential {
    pub rate: f64,
}

impl Exponential {
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0, "exponential rate must be positive, got {rate}");
        Self { rate }
    }

    /// Draw one inter-arrival time.
    #[inline]
    pub fn sample(&self, rng: &mut Xoshiro256) -> f64 {
        // Inverse CDF; 1-u in (0,1] avoids ln(0).
        -(1.0 - rng.next_f64()).ln() / self.rate
    }
}

/// Poisson distribution with mean `lambda`. Used by the runtime to draw
/// "number of p2p averagings between two gradient steps" exactly as the
/// paper's implementation does (Sec. 4.1).
#[derive(Clone, Copy, Debug)]
pub struct Poisson {
    pub lambda: f64,
}

impl Poisson {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda >= 0.0, "poisson mean must be non-negative, got {lambda}");
        Self { lambda }
    }

    /// Draw one count.
    pub fn sample(&self, rng: &mut Xoshiro256) -> u64 {
        if self.lambda == 0.0 {
            return 0;
        }
        if self.lambda < 30.0 {
            self.sample_knuth(rng)
        } else {
            self.sample_ptrs(rng)
        }
    }

    /// Knuth's product-of-uniforms method, O(lambda).
    fn sample_knuth(&self, rng: &mut Xoshiro256) -> u64 {
        let l = (-self.lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= rng.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Hörmann's PTRS transformed-rejection sampler, O(1) for large mean.
    fn sample_ptrs(&self, rng: &mut Xoshiro256) -> u64 {
        let lam = self.lambda;
        let log_lam = lam.ln();
        let b = 0.931 + 2.53 * lam.sqrt();
        let a = -0.059 + 0.02483 * b;
        let inv_alpha = 1.1239 + 1.1328 / (b - 3.4);
        let v_r = 0.9277 - 3.6224 / (b - 2.0);
        loop {
            let u = rng.next_f64() - 0.5;
            let v = rng.next_f64();
            let us = 0.5 - u.abs();
            let k = ((2.0 * a / us + b) * u + lam + 0.43).floor();
            if us >= 0.07 && v <= v_r {
                return k as u64;
            }
            if k < 0.0 || (us < 0.013 && v > us) {
                continue;
            }
            if (v * inv_alpha / (a / (us * us) + b)).ln()
                <= k * log_lam - lam - ln_factorial(k as u64)
            {
                return k as u64;
            }
        }
    }
}

/// `ln(k!)` via Stirling's series for large k, exact table for small k.
fn ln_factorial(k: u64) -> f64 {
    const TABLE: [f64; 10] = [
        0.0,
        0.0,
        0.693147180559945,
        1.791759469228055,
        3.178053830347946,
        4.787491742782046,
        6.579251212010101,
        8.525161361065415,
        10.604602902745251,
        12.801827480081469,
    ];
    if (k as usize) < TABLE.len() {
        return TABLE[k as usize];
    }
    let x = (k + 1) as f64;
    // Stirling series for ln Gamma(x).
    (x - 0.5) * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI).ln() + 1.0 / (12.0 * x)
        - 1.0 / (360.0 * x * x * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(samples: &[f64]) -> (f64, f64) {
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut d = Normal::new(2.0, 3.0);
        let samples: Vec<f64> = (0..200_000).map(|_| d.sample(&mut rng)).collect();
        let (mean, var) = moments(&samples);
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
        assert!((var - 9.0).abs() < 0.2, "var={var}");
    }

    #[test]
    fn exponential_moments() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let d = Exponential::new(4.0);
        let samples: Vec<f64> = (0..200_000).map(|_| d.sample(&mut rng)).collect();
        let (mean, var) = moments(&samples);
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
        assert!((var - 0.0625).abs() < 0.01, "var={var}");
    }

    #[test]
    fn exponential_positive() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let d = Exponential::new(0.1);
        for _ in 0..1000 {
            assert!(d.sample(&mut rng) > 0.0);
        }
    }

    #[test]
    fn poisson_small_mean_moments() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let d = Poisson::new(1.5);
        let samples: Vec<f64> = (0..200_000).map(|_| d.sample(&mut rng) as f64).collect();
        let (mean, var) = moments(&samples);
        assert!((mean - 1.5).abs() < 0.03, "mean={mean}");
        assert!((var - 1.5).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_large_mean_moments() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let d = Poisson::new(100.0);
        let samples: Vec<f64> = (0..100_000).map(|_| d.sample(&mut rng) as f64).collect();
        let (mean, var) = moments(&samples);
        assert!((mean - 100.0).abs() < 0.5, "mean={mean}");
        assert!((var - 100.0).abs() < 3.0, "var={var}");
    }

    #[test]
    fn poisson_zero_mean() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let d = Poisson::new(0.0);
        for _ in 0..10 {
            assert_eq!(d.sample(&mut rng), 0);
        }
    }

    #[test]
    fn ln_factorial_matches_direct() {
        let mut acc = 0.0f64;
        for k in 1..30u64 {
            acc += (k as f64).ln();
            assert!((ln_factorial(k) - acc).abs() < 1e-7, "k={k}");
        }
    }
}
