//! Worker cells and the runtime driver — Algorithm 1 of the paper.
//!
//! One cell per worker, two threads per cell (gradient + communication)
//! over a shared, locked `{x, x̃, t_last}` state. Gradients are computed
//! on a snapshot *outside* the lock so the communication thread averages
//! in parallel — the decoupling that removes the paper's idle time. The
//! update application itself goes through the shared
//! [`DynamicsCore`] — the exact code the virtual-time simulator drives.
//!
//! §Perf — the pairing hot path costs one locked read-modify-write pass
//! and zero steady-state allocations (full accounting in the README):
//!
//! * **send**: `mix_into` computes the momentum-mixed `x` straight into
//!   the (recycled) outgoing buffer without mutating state — a read-only
//!   2R + 1W pass, replacing the old mix-in-place + snapshot-copy
//!   (3R + 3W) lock hold;
//! * **receive**: `comm_apply` folds the still-pending mix and the
//!   `(α, α̃)` update into one 3R + 2W read-modify-write pass — the only
//!   write lock a pairing ever takes — then publishes `x` (one 1R + 1W
//!   copy, pool-sharded at large dim) so readers stay lock-free;
//! * **reads**: the gradient thread and the monitor read parameters from
//!   each cell's published [`SnapshotCell`] (a double-buffered seqlock),
//!   never contending with the communication thread's lock. The monitor
//!   streams its consensus measurement over the published buffers with
//!   zero per-tick allocation.
//!
//! Time-varying networks: a [`crate::config::Scenario`] compiles to a
//! [`NetworkPlan`] whose updates the monitor loop pushes into the shared
//! [`WallClock`] as normalized wall-clock time crosses each timestamp —
//! comm threads see new Poisson rates, the coordinator sees the new
//! active adjacency, gradient threads see drifted speed factors.
//!
//! Worker churn (`leave=`/`join=` phases): a departed worker's threads
//! *park* — the gradient thread stops stepping, the comm thread stops
//! announcing availability, and the coordinator's Reconfigure scan
//! releases it if it was already queued — until the scenario re-joins it,
//! at which point the monitor re-initializes its replica from an active
//! neighbor's published snapshot before re-admitting it. Once the plan
//! has no update left, still-departed workers are final and their
//! threads exit. Adaptive (η, α̃): updates that change the phase or the
//! worker set carry the active subgraph's (χ₁, χ₂); the monitor derives
//! the new parameters and publishes them through the [`WallClock`]'s
//! epoch-gated cell. Threads refresh *between* events, and each pairing
//! carries the sender's snapshot + epoch on the bus: if a retune splits
//! a match, both endpoints deterministically average with the OLDER
//! snapshot, so the pairwise update stays symmetric and the pair mean is
//! conserved.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::scenario::NetUpdate;
use crate::config::{Algorithm, Method, NetworkPlan, Scenario};
use crate::engine::{BatchSampler, DynamicsCore, LossEma, Scheduler, UpdateRule, WallClock};
use crate::gossip::dynamics::WorkerState;
use crate::gossip::AcidParams;
use crate::graph::Graph;
use crate::metrics::Recorder;
use crate::model::Model;
use crate::optim::{LrSchedule, Sgd};
use crate::rng::{Poisson, Xoshiro256};
use crate::runtime::bus::{build_bus, BusHandle, PairMsg};
use crate::runtime::coordinator::{spawn_coordinator, CoordMsg, PairReply, PairingStats};
use crate::runtime::snapshot::{ConsensusAccumulator, SnapshotCell};

/// How long a comm thread waits for a partner before re-checking its
/// budget/liveness via a cancel round-trip.
const PAIR_WAIT: Duration = Duration::from_millis(100);

/// A mini-batch gradient oracle. The runtime is agnostic to whether the
/// compute runs through PJRT (the AOT HLO artifacts) or a pure-Rust model
/// — both implement this.
pub trait GradSource: Send {
    /// Parameter dimension.
    fn dim(&self) -> usize;
    /// Compute the next mini-batch loss and gradient at `x` into `out`.
    fn grad(&mut self, x: &[f32], out: &mut [f32]) -> crate::Result<f32>;
}

/// [`GradSource`] over a pure-Rust [`Model`] and a shard of example
/// indices (used by tests and the mid-scale runtime experiments). Batches
/// come from the same [`BatchSampler`] the virtual-time engine uses.
pub struct RustGradSource {
    pub model: Arc<dyn Model>,
    sampler: BatchSampler,
    pub batch_size: usize,
    /// Optional artificial compute slowdown (straggler injection).
    pub extra_delay: Option<Duration>,
}

impl RustGradSource {
    pub fn new(model: Arc<dyn Model>, shard: Vec<usize>, batch_size: usize, seed: u64) -> Self {
        Self {
            model,
            sampler: BatchSampler::from_seed(shard, seed),
            batch_size,
            extra_delay: None,
        }
    }
}

impl GradSource for RustGradSource {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn grad(&mut self, x: &[f32], out: &mut [f32]) -> crate::Result<f32> {
        if let Some(d) = self.extra_delay {
            std::thread::sleep(d);
        }
        let batch = self.sampler.next_batch(self.batch_size);
        Ok(self.model.loss_grad(x, batch, out))
    }
}

/// Options for a runtime run.
#[derive(Clone)]
pub struct RuntimeOptions {
    /// Expected p2p averagings per gradient step per worker.
    pub comm_rate: f64,
    /// Baseline vs A²CiD² (AllReduce is rejected here).
    pub method: Method,
    pub lr: LrSchedule,
    pub momentum: f32,
    pub steps_per_worker: u64,
    pub seed: u64,
    /// Monitor sampling period for consensus/loss curves.
    pub monitor_interval: Duration,
    /// Injected per-link transfer delay.
    pub link_delay: Option<Duration>,
    /// Optional time-varying network scenario. When set it supersedes the
    /// `graph` argument's topology (the worker count must match); the
    /// scenario's horizon is `steps_per_worker` normalized time units.
    pub scenario: Option<Scenario>,
}

impl Default for RuntimeOptions {
    fn default() -> Self {
        Self {
            comm_rate: 1.0,
            method: Method::Acid,
            lr: LrSchedule::Constant { lr: 0.05 },
            momentum: 0.9,
            steps_per_worker: 100,
            seed: 0,
            monitor_interval: Duration::from_millis(20),
            link_delay: None,
            scenario: None,
        }
    }
}

/// Outcome of a runtime run.
pub struct RuntimeResult {
    /// `train_loss` (EMA across workers) and `consensus` vs wall seconds.
    pub recorder: Recorder,
    pub pairing: PairingStats,
    pub grads_per_worker: Vec<u64>,
    pub comms_per_worker: Vec<u64>,
    pub wall_secs: f64,
    /// Final states (mixed to their last event times).
    pub workers: Vec<WorkerState>,
    /// Network average of the final parameters.
    pub avg_params: Vec<f32>,
    /// The (η, α, α̃) applied.
    pub acid: AcidParams,
    /// Scenario network updates applied during the run.
    pub net_updates: u64,
}

/// Control surface for a supervised runtime run — the seam the serve
/// daemon drives. A plain [`run_async`] is a controlled run with a
/// default (inert) control block.
///
/// * **Drain-stop** ([`ServeControl::request_halt`]): gradient threads
///   finish their in-flight step and exit, communication threads drain
///   like any budget-exhausted worker, and the run returns a normal
///   [`RuntimeResult`] — the same orderly wind-down as natural
///   completion, just earlier. Parked (churned-out) threads observe the
///   halt too, so a stop can never hang on a departed worker.
/// * **Live injection** ([`ServeControl::inject`]): compiled
///   [`NetUpdate`]s queued from outside; the monitor applies each on its
///   next tick through the very same epoch-gated [`WallClock`] publish
///   path the scenario replay uses (topology switch, rate change, churn
///   — anything the scenario grammar compiles to).
/// * **Concurrent snapshot reads** ([`ServeControl::consensus_snapshot`]):
///   the per-worker published [`SnapshotCell`]s are registered here at
///   startup, so any number of external readers can assemble a
///   consensus-model snapshot off the lock-free seqlocks without
///   touching a state lock or stalling a writer.
/// * **Metrics stream** ([`ServeControl::metrics_since`]): one
///   consolidated-JSON record appended per monitor tick.
pub struct ServeControl {
    halt: AtomicBool,
    injected: Mutex<VecDeque<NetUpdate>>,
    injected_applied: AtomicU64,
    cells: Mutex<Vec<Arc<SnapshotCell>>>,
    metrics: Mutex<Vec<String>>,
    running: AtomicBool,
    /// Fleet-total completed gradient steps, refreshed each monitor tick
    /// (the daemon stamps checkpoints with it).
    grads_total: AtomicU64,
}

impl Default for ServeControl {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeControl {
    pub fn new() -> Self {
        Self {
            halt: AtomicBool::new(false),
            injected: Mutex::new(VecDeque::new()),
            injected_applied: AtomicU64::new(0),
            cells: Mutex::new(Vec::new()),
            metrics: Mutex::new(Vec::new()),
            running: AtomicBool::new(false),
            grads_total: AtomicU64::new(0),
        }
    }

    /// Ask the run to stop draining-safely. Idempotent.
    pub fn request_halt(&self) {
        self.halt.store(true, Ordering::Release);
    }

    pub fn halted(&self) -> bool {
        self.halt.load(Ordering::Acquire)
    }

    /// Queue compiled network updates for the monitor's next tick (their
    /// `t` stamps are ignored — injection means *now*). Applied in FIFO
    /// order, one tick may apply several.
    pub fn inject(&self, updates: Vec<NetUpdate>) {
        self.injected.lock().unwrap().extend(updates);
    }

    /// Number of injected updates applied so far.
    pub fn injected_applied(&self) -> u64 {
        self.injected_applied.load(Ordering::Acquire)
    }

    /// Whether a controlled run is currently between startup and return.
    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::Acquire)
    }

    /// Fleet-total completed gradient steps, as of the last monitor tick.
    pub fn grads_total(&self) -> u64 {
        self.grads_total.load(Ordering::Acquire)
    }

    /// The per-worker published snapshot cells (empty before startup).
    /// Cloned `Arc`s — hold them as long as you like; reads stay
    /// lock-free and never block the training writers.
    pub fn snapshot_cells(&self) -> Vec<Arc<SnapshotCell>> {
        self.cells.lock().unwrap().clone()
    }

    /// Assemble a consensus-model snapshot (the mean of every worker's
    /// published parameters) off the lock-free cells. `None` before
    /// startup. Each per-worker read is torn-free (seqlock); the mean is
    /// taken across whatever each worker most recently published — the
    /// same consistency the monitor's consensus measurement has.
    pub fn consensus_snapshot(&self) -> Option<Vec<f32>> {
        let cells = self.snapshot_cells();
        let first = cells.first()?;
        let dim = first.dim();
        let mut mean = vec![0.0f64; dim];
        let mut buf = vec![0.0f32; dim];
        for c in &cells {
            c.read_into_slice(&mut buf);
            for (m, &v) in mean.iter_mut().zip(&buf) {
                *m += v as f64;
            }
        }
        let inv = 1.0 / cells.len() as f64;
        Some(mean.iter().map(|&m| (m * inv) as f32).collect())
    }

    /// Metrics records appended since index `from` (one JSON line per
    /// monitor tick), plus the next cursor to poll from.
    pub fn metrics_since(&self, from: usize) -> (Vec<String>, usize) {
        let m = self.metrics.lock().unwrap();
        let start = from.min(m.len());
        (m[start..].to_vec(), m.len())
    }

    fn set_running(&self, v: bool) {
        self.running.store(v, Ordering::Release);
    }

    fn register_cells(&self, cells: &[Arc<Cell>]) {
        *self.cells.lock().unwrap() = cells.iter().map(|c| c.published.clone()).collect();
    }

    fn drain_injected(&self) -> Vec<NetUpdate> {
        let mut q = self.injected.lock().unwrap();
        let out: Vec<NetUpdate> = q.drain(..).collect();
        out
    }

    fn push_metric(&self, line: String) {
        self.metrics.lock().unwrap().push(line);
    }
}

/// Shared per-worker cell.
struct Cell {
    state: Mutex<WorkerState>,
    /// Published snapshot of `x` (double-buffered seqlock): the gradient
    /// thread and the monitor read here without taking `state`. Whoever
    /// mutates `x` under the lock publishes before releasing it. Behind
    /// an `Arc` so [`ServeControl`] can hand the cell to concurrent
    /// external readers (the daemon's snapshot query path).
    published: Arc<SnapshotCell>,
    /// Remaining p2p averagings before the next budget refill.
    comm_budget: AtomicI64,
    grads_done: AtomicU64,
    comms_done: AtomicU64,
    /// Gradient thread finished (no more budget will be added).
    grad_done: AtomicBool,
    /// Communication thread exited (budget drained or no partners left —
    /// a worker released with leftover budget still counts as done).
    comm_done: AtomicBool,
    /// EMA of this worker's train loss (f64 bits).
    loss_ema: AtomicU64,
    /// EMA of gradient duration in nanoseconds (time normalization).
    avg_grad_nanos: AtomicU64,
}

impl Cell {
    fn store_loss(&self, v: f64) {
        self.loss_ema.store(v.to_bits(), Ordering::Relaxed);
    }
    fn load_loss(&self) -> f64 {
        f64::from_bits(self.loss_ema.load(Ordering::Relaxed))
    }
    /// Normalized time: wall seconds since `start` over the average
    /// gradient duration (the paper's Sec. 4.1 normalization).
    fn now(&self, start: Instant) -> f64 {
        let avg = self.avg_grad_nanos.load(Ordering::Relaxed).max(1) as f64;
        start.elapsed().as_nanos() as f64 / avg
    }
}

/// Run the asynchronous runtime: `n = grad_sources.len()` workers over
/// `graph`, starting from the shared `init` parameters.
pub fn run_async(
    graph: Arc<Graph>,
    grad_sources: Vec<Box<dyn GradSource>>,
    init: Vec<f32>,
    opts: RuntimeOptions,
) -> crate::Result<RuntimeResult> {
    run_async_controlled(graph, grad_sources, init, opts, Arc::new(ServeControl::new()))
}

/// [`run_async`] under external supervision: the `ctrl` block receives
/// the published snapshot cells and the metrics stream, and its halt
/// flag / injection queue are honored by the worker threads and the
/// monitor. This is the entry point the serve daemon drives.
pub fn run_async_controlled(
    graph: Arc<Graph>,
    mut grad_sources: Vec<Box<dyn GradSource>>,
    init: Vec<f32>,
    opts: RuntimeOptions,
    ctrl: Arc<ServeControl>,
) -> crate::Result<RuntimeResult> {
    let n = graph.n;
    anyhow::ensure!(grad_sources.len() == n, "need one grad source per worker");
    // The update rule: a scenario's `algo=` key wins, else the legacy
    // method maps onto its algorithm (Acid → a2cid2, baseline → adpsgd).
    let algo = opts
        .scenario
        .as_ref()
        .and_then(|s| s.algo)
        .unwrap_or(Algorithm::from_method(opts.method));
    anyhow::ensure!(
        algo != Algorithm::AllReduce,
        "run_async is for the asynchronous algorithms"
    );
    for s in &grad_sources {
        anyhow::ensure!(s.dim() == init.len(), "grad source dim mismatch");
    }

    // Compile the network plan: scenario phases over the run horizon, or
    // the static graph. Normalized wall-clock time ≈ gradient steps per
    // worker, so the horizon matches the virtual-time engine's.
    let plan = match &opts.scenario {
        Some(sc) => sc.compile(n, opts.comm_rate, opts.steps_per_worker as f64, &vec![1.0; n])?,
        None => NetworkPlan::static_plan((*graph).clone(), opts.comm_rate, &vec![1.0; n]),
    };
    let core = Arc::new(DynamicsCore::for_algorithm(algo, &plan.spectrum, opts.lr.clone())?);
    let wall = Arc::new(WallClock::new(&plan));
    // Seed the published (η, α, α̃) with the phase-0 values; worker
    // threads track this cell so adaptive retunes reach them mid-run.
    wall.publish_acid(core.acid);
    let adaptive = opts.scenario.as_ref().is_none_or(|s| s.adaptive);

    let cells: Vec<Arc<Cell>> = (0..n)
        .map(|_| {
            Arc::new(Cell {
                state: Mutex::new(WorkerState::new(init.clone())),
                published: Arc::new(SnapshotCell::new(&init)),
                comm_budget: AtomicI64::new(0),
                grads_done: AtomicU64::new(0),
                comms_done: AtomicU64::new(0),
                grad_done: AtomicBool::new(false),
                comm_done: AtomicBool::new(false),
                loss_ema: AtomicU64::new(f64::NAN.to_bits()),
                // Seed the normalizer with 1ms; replaced by the first
                // measured gradient.
                avg_grad_nanos: AtomicU64::new(1_000_000),
            })
        })
        .collect();

    let (bus, mut inboxes) = build_bus(n, opts.link_delay);
    let (coord_tx, coord_handle) = spawn_coordinator(wall.clone());
    let start = Instant::now();
    ctrl.register_cells(&cells);
    ctrl.set_running(true);

    // Worker→core affinity: with `A2CID2_PIN` engaged and enough CPUs, a
    // worker's gradient and comm threads share one core (they alternate
    // on the same state and published cell, so co-locating them keeps
    // that traffic within one cache hierarchy; the node-major slot
    // interleave spreads distinct workers across NUMA nodes). With more
    // workers than CPUs the oversubscription would turn pinning into a
    // scheduling straitjacket, so the runtime leaves placement to the OS.
    let topo = crate::locality::topology();
    let pin_workers = crate::locality::pin_lanes() && n <= topo.n_cpus();

    let mut grad_handles = Vec::new();
    let mut comm_handles = Vec::new();
    for w in (0..n).rev() {
        let inbox = inboxes.pop().unwrap();
        let src = grad_sources.pop().unwrap();
        let cpu = if pin_workers { topo.cpu_for_slot(w) } else { None };
        grad_handles.push(spawn_grad_thread(
            w,
            src,
            cells[w].clone(),
            core.clone(),
            wall.clone(),
            opts.clone(),
            start,
            cpu,
            ctrl.clone(),
        ));
        comm_handles.push(spawn_comm_thread(
            w,
            cells[w].clone(),
            inbox,
            bus.clone(),
            coord_tx.clone(),
            core.clone(),
            wall.clone(),
            start,
            cpu,
            ctrl.clone(),
        ));
    }

    // Applies one plan update: re-join churned workers from a neighbor
    // snapshot FIRST (donors are the pre-update active set, and the
    // joiner's threads are still parked while we reset its replica),
    // then swap the rate tables/membership, then publish retuned
    // (η, α̃) when the update carries a usable spectrum.
    let mut snapbuf: Vec<f32> = Vec::new();
    let apply_update = |upd: &NetUpdate, snapbuf: &mut Vec<f32>| {
        for &j in &upd.join {
            let donor = wall
                .union_neighbors(j)
                .iter()
                .copied()
                .find(|&d| wall.is_active(d));
            if let Some(d) = donor {
                snapbuf.resize(cells[d].published.dim(), 0.0);
                cells[d].published.read_into(snapbuf);
                let mut st = cells[j].state.lock().unwrap();
                let t = cells[j].now(start);
                core.rejoin_from(&mut st, snapbuf, t);
                cells[j].published.publish(&st.x);
            }
        }
        wall.apply_shared(upd);
        if adaptive && core.acid.is_accelerated() {
            if let Some((c1, c2)) = upd.chis {
                if let Some(p) = AcidParams::from_chis_clamped(c1, c2) {
                    wall.publish_acid(p);
                }
            }
        }
        let _ = coord_tx.send(CoordMsg::Reconfigure);
    };

    // Monitor: sample consensus + mean loss, replay the scenario's
    // network updates, until all gradient threads finish and all comm
    // budgets drain. The loop reads only published snapshots and atomics
    // — no state locks, and (after the accumulator's first tick) no
    // allocation.
    let mut recorder = Recorder::new();
    let mut consensus_acc = ConsensusAccumulator::new();
    let mut pending = plan.updates.iter();
    let mut next_update = pending.next();
    loop {
        std::thread::sleep(opts.monitor_interval);
        // Live injection: updates pushed through the control block apply
        // NOW, through the same epoch-gated publish path as the
        // scenario's own updates (their compile-time `t` stamps are
        // ignored — the injector decides *when* by injecting).
        for upd in ctrl.drain_injected() {
            apply_update(&upd, &mut snapbuf);
            ctrl.injected_applied.fetch_add(1, Ordering::Release);
        }
        // Scenario replay: the plan's horizon is denominated in gradient
        // steps per worker, so the replay clock is the mean completed
        // step count — exact from the first step, unlike Cell::now(),
        // whose 1ms-seeded normalizer is garbage until the first real
        // gradient duration lands (a ~1s/step grad source would
        // otherwise see every update fire at the start of the run).
        if next_update.is_some() {
            let t_norm = cells
                .iter()
                .map(|c| c.grads_done.load(Ordering::Relaxed) as f64)
                .sum::<f64>()
                / n as f64;
            while let Some(upd) = next_update {
                if upd.t > t_norm {
                    break;
                }
                apply_update(upd, &mut snapbuf);
                next_update = pending.next();
            }
        }
        // Churn can stall the mean-step clock below a late update's
        // timestamp (departed workers stop stepping). Once every ACTIVE
        // worker has finished training, flush whatever remains of the
        // plan so parked joiners are released to finish their steps —
        // or, if nothing re-joins them, are marked departed for good.
        if next_update.is_some() {
            let active_done = cells.iter().enumerate().all(|(w, c)| {
                c.grad_done.load(Ordering::Acquire) || !wall.is_active(w)
            });
            if active_done {
                while let Some(upd) = next_update {
                    apply_update(upd, &mut snapbuf);
                    next_update = pending.next();
                }
            }
        }
        if next_update.is_none() {
            // No update left: still-departed workers can never return;
            // their parked threads exit on this flag.
            wall.finalize_updates();
        }
        let t = start.elapsed().as_secs_f64();
        let consensus_sq =
            consensus_acc.measure(cells.iter().map(|c| c.published.as_ref()));
        let consensus = (consensus_sq / n as f64).sqrt();
        recorder.record("consensus", t, consensus);
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;
        for c in &cells {
            let v = c.load_loss();
            if v.is_finite() {
                loss_sum += v;
                loss_n += 1;
            }
        }
        let mean_loss = if loss_n > 0 { Some(loss_sum / loss_n as f64) } else { None };
        if let Some(l) = mean_loss {
            recorder.record("train_loss", t, l);
        }
        // Incremental metrics stream: one consolidated-JSON record per
        // monitor tick (the daemon serves these over the socket; a
        // detached run just accumulates them in memory).
        let grads_total: u64 =
            cells.iter().map(|c| c.grads_done.load(Ordering::Relaxed)).sum();
        let comms_total: u64 =
            cells.iter().map(|c| c.comms_done.load(Ordering::Relaxed)).sum();
        let active = (0..n).filter(|&w| wall.is_active(w)).count() as u64;
        ctrl.grads_total.store(grads_total, Ordering::Release);
        ctrl.push_metric(
            crate::metrics::Record::new()
                .f64("t_wall", t)
                .u64("grads", grads_total)
                .u64("comms", comms_total)
                .u64("active_workers", active)
                .u64("net_updates", Scheduler::updates_applied(&wall))
                .f64("consensus", consensus)
                .opt_f64("train_loss", mean_loss)
                .to_json(),
        );
        let all_done = cells.iter().all(|c| {
            c.grad_done.load(Ordering::Acquire) && c.comm_done.load(Ordering::Acquire)
        });
        if all_done {
            break;
        }
    }
    drop(coord_tx);
    ctrl.set_running(false);

    for h in grad_handles {
        h.join().map_err(|_| anyhow::anyhow!("grad thread panicked"))??;
    }
    for h in comm_handles {
        h.join().map_err(|_| anyhow::anyhow!("comm thread panicked"))??;
    }
    let pairing = coord_handle
        .join()
        .map_err(|_| anyhow::anyhow!("coordinator panicked"))?;

    // Sync all workers to a common final time and average (the paper's
    // closing All-Reduce before evaluation). The closing mix runs under
    // the FINAL published (η, α̃) — the parameters the last phase's
    // events were applied with — not phase-0's.
    let final_core = {
        let mut c = (*core).clone();
        c.set_params(wall.acid());
        c
    };
    let t_final = cells
        .iter()
        .map(|c| c.now(start))
        .fold(0.0f64, f64::max);
    let mut workers = Vec::with_capacity(n);
    for c in &cells {
        let mut st = c.state.lock().unwrap().clone();
        final_core.mix_to(&mut st, t_final);
        workers.push(st);
    }
    let avg_params = crate::gossip::consensus::average_params(&workers);
    let wall_secs = start.elapsed().as_secs_f64();
    recorder.record(
        "consensus",
        wall_secs,
        crate::gossip::consensus_distance(&workers),
    );

    Ok(RuntimeResult {
        recorder,
        pairing,
        grads_per_worker: cells.iter().map(|c| c.grads_done.load(Ordering::Relaxed)).collect(),
        comms_per_worker: cells.iter().map(|c| c.comms_done.load(Ordering::Relaxed)).collect(),
        wall_secs,
        workers,
        avg_params,
        acid: wall.acid(),
        net_updates: Scheduler::updates_applied(&wall),
    })
}

#[allow(clippy::too_many_arguments)]
fn spawn_grad_thread(
    w: usize,
    mut src: Box<dyn GradSource>,
    cell: Arc<Cell>,
    core: Arc<DynamicsCore>,
    wall: Arc<WallClock>,
    opts: RuntimeOptions,
    start: Instant,
    cpu: Option<usize>,
    ctrl: Arc<ServeControl>,
) -> std::thread::JoinHandle<crate::Result<()>> {
    std::thread::Builder::new()
        .name(format!("a2cid2-grad-{w}"))
        .spawn(move || {
            if let Some(c) = cpu {
                crate::locality::pin_current_thread(c);
            }
            // The completion flag must be set on EVERY exit path (incl.
            // gradient-source failures) or the monitor loop spins forever.
            let result = grad_loop(w, &mut src, &cell, &core, &wall, &opts, start, &ctrl);
            cell.grad_done.store(true, Ordering::Release);
            result
        })
        .expect("spawn grad thread")
}

#[allow(clippy::too_many_arguments)]
fn grad_loop(
    w: usize,
    src: &mut Box<dyn GradSource>,
    cell: &Cell,
    core: &DynamicsCore,
    wall: &WallClock,
    opts: &RuntimeOptions,
    start: Instant,
    ctrl: &ServeControl,
) -> crate::Result<()> {
    let mut opt = Sgd::new(opts.momentum);
    let mut rng = Xoshiro256::seed_from_u64(opts.seed ^ (w as u64) << 20);
    let dim = src.dim();
    let mut gradbuf = vec![0.0f32; dim];
    let mut snapshot = vec![0.0f32; dim];
    // Local copy of the dynamics core: adaptive (η, α̃) retunes are
    // pulled from the WallClock's epoch-gated cell between steps.
    let mut core = core.clone();
    let (mut acid_seen, p0) = wall.acid_snapshot();
    core.set_params(p0);
    for step in 0..opts.steps_per_worker {
        // Drain-stop: finish between steps, never mid-update. The parked
        // loop below checks too, so a halted run can never hang on a
        // churned-out worker waiting for a re-join that will not come.
        if ctrl.halted() {
            return Ok(());
        }
        // Churn: a departed worker parks (no steps, no budget refills)
        // until the scenario re-joins it — or exits once no remaining
        // update can.
        while !wall.is_active(w) {
            if wall.departed_for_good(w) || ctrl.halted() {
                return Ok(());
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        if wall.acid_epoch() != acid_seen {
            let (epoch, p) = wall.acid_snapshot();
            acid_seen = epoch;
            core.set_params(p);
        }
        let t0 = Instant::now();
        // Gradient at a snapshot from the published cell — no lock taken,
        // so the comm thread keeps averaging concurrently (the paper's
        // decoupling; the resulting staleness is part of the modeled
        // dynamic).
        cell.published.read_into(&mut snapshot);
        let loss = src.grad(&snapshot, &mut gradbuf)? as f64;
        // Scenario speed drift: real threads cannot run faster than the
        // hardware, so the runtime anchors on the currently-fastest
        // worker and stretches everyone else's compute time relative to
        // it — the same speed *ratios* the virtual engine replays via
        // gradient-rate updates, including speeds above nominal.
        let stretch = wall.stretch(w);
        if stretch > 1.001 {
            std::thread::sleep(t0.elapsed().mul_f64((stretch - 1.0).min(20.0)));
        }
        // Update the time normalization with this (stretched) duration.
        let dur = t0.elapsed().as_nanos() as u64;
        let prev = cell.avg_grad_nanos.load(Ordering::Relaxed);
        let ema = if step == 0 { dur.max(1) } else { (prev * 9 + dur) / 10 };
        cell.avg_grad_nanos.store(ema.max(1), Ordering::Relaxed);

        {
            let mut st = cell.state.lock().unwrap();
            let t = cell.now(start);
            core.grad_event(&mut st, t, &mut opt, &gradbuf);
            cell.published.publish(&st.x);
        }
        cell.store_loss(LossEma::fold(cell.load_loss(), loss, 0.95));
        cell.grads_done.fetch_add(1, Ordering::Relaxed);
        // Refill the communication budget: Poisson(#com/#grad) at the
        // worker's CURRENT total link rate Σ_j λ^ij — exactly the
        // paper's emulation of the M^ij clocks, tracking scenario
        // updates as they land.
        let quota = Poisson::new(wall.comm_rate(w)).sample(&mut rng) as i64;
        if quota > 0 {
            cell.comm_budget.fetch_add(quota, Ordering::Release);
        }
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn spawn_comm_thread(
    w: usize,
    cell: Arc<Cell>,
    inbox: mpsc::Receiver<PairMsg>,
    bus: BusHandle,
    coord: mpsc::Sender<CoordMsg>,
    core: Arc<DynamicsCore>,
    wall: Arc<WallClock>,
    start: Instant,
    cpu: Option<usize>,
    ctrl: Arc<ServeControl>,
) -> std::thread::JoinHandle<crate::Result<()>> {
    std::thread::Builder::new()
        .name(format!("a2cid2-comm-{w}"))
        .spawn(move || {
            if let Some(c) = cpu {
                crate::locality::pin_current_thread(c);
            }
            // Leave + the completion flag must fire on EVERY exit path
            // (incl. bus errors), or the coordinator and monitor wait
            // forever on this worker.
            let result =
                comm_loop(w, &cell, &inbox, &bus, &coord, &core, &wall, start, &ctrl);
            let _ = coord.send(CoordMsg::Leave { worker: w });
            cell.comm_done.store(true, Ordering::Release);
            result
        })
        .expect("spawn comm thread")
}

/// Outcome of one availability declaration.
enum Pairing {
    Partner(usize),
    /// Cancelled by our own timeout: re-check budget/liveness and maybe
    /// re-announce.
    Retry,
    /// No partner can ever arrive (or the coordinator is gone).
    Stop,
}

/// Declare availability and wait for a partner, with a cancel round-trip
/// every [`PAIR_WAIT`] so a worker waiting on a link the scenario dropped
/// (or a finished neighborhood) never blocks forever.
fn wait_for_partner(w: usize, coord: &mpsc::Sender<CoordMsg>) -> Pairing {
    let (reply_tx, reply_rx) = mpsc::channel();
    if coord.send(CoordMsg::Available { worker: w, reply: reply_tx }).is_err() {
        return Pairing::Stop; // coordinator gone (shutdown)
    }
    loop {
        match reply_rx.recv_timeout(PAIR_WAIT) {
            Ok(PairReply::Peer(p)) => return Pairing::Partner(p),
            Ok(PairReply::NoPartnerEver) => return Pairing::Stop,
            Ok(PairReply::Cancelled) => return Pairing::Retry,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if coord.send(CoordMsg::Cancel { worker: w }).is_err() {
                    return Pairing::Stop;
                }
                // After the cancel is processed a definitive reply is
                // guaranteed: either Cancelled, or the pairing that raced
                // ahead of it.
                match reply_rx.recv() {
                    Ok(PairReply::Peer(p)) => return Pairing::Partner(p),
                    Ok(PairReply::Cancelled) => return Pairing::Retry,
                    _ => return Pairing::Stop,
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return Pairing::Stop,
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn comm_loop(
    w: usize,
    cell: &Cell,
    inbox: &mpsc::Receiver<PairMsg>,
    bus: &BusHandle,
    coord: &mpsc::Sender<CoordMsg>,
    core: &DynamicsCore,
    wall: &WallClock,
    start: Instant,
    ctrl: &ServeControl,
) -> crate::Result<()> {
    // §Perf: the buffer received from each pairing is recycled as the
    // next pairing's send buffer — zero steady-state allocation on the
    // communication hot path.
    let mut recycled: Option<Vec<f32>> = None;
    // Params refresh only here, at the top of a pairing: once matched,
    // the pairing runs to completion under the snapshot it started with.
    // (epoch, params) are read as one consistent pair — the pairing
    // protocol's tie-break needs "equal epoch ⇒ identical params".
    let mut core = core.clone();
    let (mut acid_seen, p0) = wall.acid_snapshot();
    core.set_params(p0);
    loop {
        // Drain-stop: checked only at the top of a pairing — once
        // matched, the pairing runs to completion (breaking between the
        // bus send and the inbox receive would strand the peer). The
        // leftover budget is best-effort, like a churn departure's.
        if ctrl.halted() {
            break;
        }
        // Churn: a departed worker stops announcing availability. Its
        // leftover budget is best-effort — once training is over (the
        // grad thread exited, possibly because the departure is final)
        // the thread winds down like any budget-exhausted worker.
        if !wall.is_active(w) {
            if cell.grad_done.load(Ordering::Acquire) {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }
        if wall.acid_epoch() != acid_seen {
            let (epoch, p) = wall.acid_snapshot();
            acid_seen = epoch;
            core.set_params(p);
        }
        if cell.comm_budget.load(Ordering::Acquire) <= 0 {
            if cell.grad_done.load(Ordering::Acquire) {
                break;
            }
            std::thread::sleep(Duration::from_micros(200));
            continue;
        }
        // Pacing rules (local SGD): an endpoint that has not taken H
        // local steps since its last applied pairing does not announce
        // availability. The skipped opportunity still consumes one
        // budget unit — the budget models the shared Poisson clocks, and
        // a skipped proposal is still a spent clock tick — so the run
        // drains on the same schedule as an always-admitting rule.
        let ready = {
            let st = cell.state.lock().unwrap();
            core.rule.admits_endpoint(&st)
        };
        if !ready {
            cell.comm_budget.fetch_sub(1, Ordering::Release);
            continue;
        }
        let peer = match wait_for_partner(w, coord) {
            Pairing::Partner(p) => p,
            Pairing::Retry => {
                // Training over and still no partner (e.g. the scenario
                // dropped our links): leftover budget is best-effort.
                if cell.grad_done.load(Ordering::Acquire) {
                    break;
                }
                continue;
            }
            Pairing::Stop => break,
        };
        // Send side: build the momentum-mixed snapshot straight into the
        // outgoing buffer WITHOUT mutating state (the pending mix is
        // folded into the receive pass below). Read-only under the lock;
        // no copy, no allocation.
        let (sendbuf, t_pair) = {
            let st = cell.state.lock().unwrap();
            let t = cell.now(start);
            let mut buf = match recycled.take() {
                Some(buf) if buf.len() == st.x.len() => buf,
                _ => vec![0.0f32; st.x.len()],
            };
            core.mix_into(&st, t, &mut buf);
            (buf, t)
        };
        bus.send(
            peer,
            PairMsg { from: w, data: sendbuf, acid: core.acid, acid_epoch: acid_seen },
        )?;
        let msg = inbox
            .recv()
            .map_err(|_| anyhow::anyhow!("worker {w}: inbox closed mid-pairing"))?;
        anyhow::ensure!(
            msg.from == peer,
            "worker {w}: expected msg from {peer}, got {}",
            msg.from
        );
        anyhow::ensure!(
            msg.dim() == cell.published.dim(),
            "worker {w}: dim mismatch from {peer}: {} vs {}",
            msg.dim(),
            cell.published.dim()
        );
        // Receive side: the pairing's single locked read-modify-write
        // pass (pending mix + (α, α̃) update, fused). If an adaptive
        // retune split this pairing — the peer refreshed before the
        // publish, we after (or vice versa) — both sides deterministically
        // average with the OLDER snapshot, so the pair mean is conserved.
        let agreed = if msg.acid_epoch < acid_seen { msg.acid } else { core.acid };
        {
            let mut st = cell.state.lock().unwrap();
            core.comm_apply_agreed(&mut st, t_pair, &msg.data, agreed);
            cell.published.publish(&st.x);
        }
        recycled = Some(msg.data);
        cell.comms_done.fetch_add(1, Ordering::Relaxed);
        cell.comm_budget.fetch_sub(1, Ordering::Release);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{GaussianMixture, Sharding};
    use crate::graph::Topology;
    use crate::model::Logistic;

    fn sources(
        n: usize,
        model: &Arc<Logistic>,
        shards: &crate::data::ShardedIndices,
    ) -> Vec<Box<dyn GradSource>> {
        (0..n)
            .map(|w| {
                Box::new(RustGradSource::new(
                    model.clone() as Arc<dyn Model>,
                    shards.per_worker[w].clone(),
                    8,
                    w as u64,
                )) as Box<dyn GradSource>
            })
            .collect()
    }

    fn run(n: usize, method: Method, steps: u64) -> (RuntimeResult, Arc<Logistic>) {
        let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
        let ds = Arc::new(
            GaussianMixture { dim: 8, n_classes: 4, margin: 3.0, sigma: 1.0 }.sample(512, 2),
        );
        let shards = Sharding::FullShuffled.assign(&ds, n, 3);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let mut rng = Xoshiro256::seed_from_u64(0);
        let init = model.init_params(&mut rng);
        let opts = RuntimeOptions {
            comm_rate: 1.0,
            method,
            lr: LrSchedule::Constant { lr: 0.05 },
            momentum: 0.0,
            steps_per_worker: steps,
            seed: 0,
            monitor_interval: Duration::from_millis(5),
            link_delay: None,
            scenario: None,
        };
        let res = run_async(graph, sources(n, &model, &shards), init, opts).unwrap();
        (res, model)
    }

    #[test]
    fn trains_and_terminates() {
        let (res, model) = run(4, Method::AsyncBaseline, 120);
        assert_eq!(res.grads_per_worker, vec![120; 4]);
        let idx: Vec<usize> = (0..512).collect();
        let acc = model.accuracy(&res.avg_params, &idx).unwrap();
        assert!(acc > 0.6, "acc={acc}");
        // Communications happened and respected the topology.
        assert!(res.pairing.total > 50, "total={}", res.pairing.total);
        assert_eq!(res.pairing.counts[0][2], 0, "0-2 not adjacent on ring(4)");
        assert_eq!(res.net_updates, 0);
    }

    #[test]
    fn acid_method_runs() {
        let (res, _) = run(4, Method::Acid, 60);
        assert!(res.acid.is_accelerated());
        assert!(res.comms_per_worker.iter().sum::<u64>() > 0);
        let c = res.recorder.get("consensus").unwrap();
        assert!(c.points.iter().all(|(_, v)| v.is_finite()));
    }

    #[test]
    fn comm_counts_match_budgets() {
        let (res, _) = run(3, Method::AsyncBaseline, 100);
        // Each comm increments both endpoints' counters; the pairing total
        // counts each pairing once.
        let total: u64 = res.comms_per_worker.iter().sum();
        assert_eq!(total, 2 * res.pairing.total);
        // Poisson(1) per grad step: expect roughly one comm per grad.
        let grads: u64 = res.grads_per_worker.iter().sum();
        let ratio = total as f64 / grads as f64;
        assert!((0.4..1.6).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn localsgd_scenario_paces_runtime_comms() {
        // The `algo=localsgd:4` scenario key must reach the runtime's
        // comm loop: at most one applied pairing per 4 local steps per
        // endpoint, and the run still terminates.
        let n = 4;
        let steps = 100u64;
        let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
        let ds = Arc::new(GaussianMixture::cifar_like().sample(128, 3));
        let shards = Sharding::FullShuffled.assign(&ds, n, 0);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let mut rng = Xoshiro256::seed_from_u64(5);
        let init = model.init_params(&mut rng);
        let opts = RuntimeOptions {
            comm_rate: 1.0,
            method: Method::AsyncBaseline,
            lr: LrSchedule::Constant { lr: 0.02 },
            momentum: 0.0,
            steps_per_worker: steps,
            seed: 0,
            monitor_interval: Duration::from_millis(2),
            link_delay: None,
            scenario: Some(Scenario::parse("ring@0;algo=localsgd:4").unwrap()),
        };
        let srcs = paced_sources(n, &model, &shards, Duration::from_micros(300));
        let res = run_async(graph, srcs, init, opts).unwrap();
        assert_eq!(res.grads_per_worker, vec![steps; n]);
        let total: u64 = res.comms_per_worker.iter().sum();
        assert!(total > 0, "some pairings must still apply");
        for (w, &c) in res.comms_per_worker.iter().enumerate() {
            assert!(
                c <= res.grads_per_worker[w] / 4 + 1,
                "worker {w}: {c} comms for {} grads breaks the H = 4 gate",
                res.grads_per_worker[w]
            );
        }
        assert!(!res.acid.is_accelerated(), "local SGD averages with η = 0");
    }

    #[test]
    fn zero_comm_rate_still_terminates() {
        let graph = Arc::new(Graph::build(&Topology::Ring, 3).unwrap());
        let ds = Arc::new(GaussianMixture::cifar_like().sample(128, 1));
        let shards = Sharding::FullShuffled.assign(&ds, 3, 0);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let mut rng = Xoshiro256::seed_from_u64(0);
        let init = model.init_params(&mut rng);
        let opts = RuntimeOptions {
            comm_rate: 0.0,
            method: Method::AsyncBaseline,
            steps_per_worker: 30,
            momentum: 0.0,
            ..Default::default()
        };
        let srcs: Vec<Box<dyn GradSource>> = (0..3)
            .map(|w| {
                Box::new(RustGradSource::new(
                    model.clone() as Arc<dyn Model>,
                    shards.per_worker[w].clone(),
                    8,
                    w as u64,
                )) as Box<dyn GradSource>
            })
            .collect();
        let res = run_async(graph, srcs, init, opts).unwrap();
        assert_eq!(res.pairing.total, 0);
    }

    #[test]
    fn scenario_switch_runs_and_respects_the_union() {
        // ring(6) → complete(6) at half-time: pairings before the switch
        // stay on the ring; over the whole run they stay in the union
        // (which is every pair here), and the switch must actually land.
        let n = 6;
        let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
        let ds = Arc::new(GaussianMixture::cifar_like().sample(256, 8));
        let shards = Sharding::FullShuffled.assign(&ds, n, 0);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let mut rng = Xoshiro256::seed_from_u64(1);
        let init = model.init_params(&mut rng);
        let srcs: Vec<Box<dyn GradSource>> = (0..n)
            .map(|w| {
                let mut s = RustGradSource::new(
                    model.clone() as Arc<dyn Model>,
                    shards.per_worker[w].clone(),
                    8,
                    w as u64,
                );
                // Pace the run so the monitor's scenario replay lands
                // mid-training, not after it.
                s.extra_delay = Some(Duration::from_micros(300));
                Box::new(s) as Box<dyn GradSource>
            })
            .collect();
        let opts = RuntimeOptions {
            comm_rate: 1.0,
            method: Method::Acid,
            lr: LrSchedule::Constant { lr: 0.02 },
            momentum: 0.0,
            steps_per_worker: 150,
            seed: 0,
            monitor_interval: Duration::from_millis(2),
            link_delay: None,
            scenario: Some(Scenario::parse("ring@0,complete@0.5").unwrap()),
        };
        let res = run_async(graph, srcs, init, opts).unwrap();
        assert_eq!(res.grads_per_worker, vec![150; n]);
        assert_eq!(res.net_updates, 1, "the topology switch landed");
        // Chord pairings (non-ring edges) only exist thanks to the switch.
        let ring = Graph::build(&Topology::Ring, n).unwrap();
        let chord_pairings: u64 = (0..n)
            .flat_map(|i| (i + 1..n).map(move |j| (i, j)))
            .filter(|&(i, j)| !ring.has_edge(i, j))
            .map(|(i, j)| res.pairing.counts[i][j])
            .sum();
        assert!(chord_pairings > 0, "switch should open the chords");
    }

    fn paced_sources(
        n: usize,
        model: &Arc<Logistic>,
        shards: &crate::data::ShardedIndices,
        delay: Duration,
    ) -> Vec<Box<dyn GradSource>> {
        (0..n)
            .map(|w| {
                let mut s = RustGradSource::new(
                    model.clone() as Arc<dyn Model>,
                    shards.per_worker[w].clone(),
                    8,
                    w as u64,
                );
                s.extra_delay = Some(delay);
                Box::new(s) as Box<dyn GradSource>
            })
            .collect()
    }

    #[test]
    fn churn_leave_without_rejoin_terminates_with_partial_steps() {
        // One worker departs at 30% and never returns: the run must still
        // terminate, with the departed worker short of its step budget
        // and everyone else completing theirs.
        let n = 4;
        let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
        let ds = Arc::new(GaussianMixture::cifar_like().sample(128, 3));
        let shards = Sharding::FullShuffled.assign(&ds, n, 0);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let mut rng = Xoshiro256::seed_from_u64(2);
        let init = model.init_params(&mut rng);
        let scenario = Scenario::parse("ring@0;leave=0.25:0.3:1").unwrap();
        let leaver = scenario
            .compile(n, 1.0, 80.0, &[1.0; n])
            .unwrap()
            .updates[0]
            .leave[0];
        let opts = RuntimeOptions {
            comm_rate: 1.0,
            method: Method::Acid,
            lr: LrSchedule::Constant { lr: 0.02 },
            momentum: 0.0,
            steps_per_worker: 80,
            seed: 0,
            monitor_interval: Duration::from_millis(2),
            link_delay: None,
            scenario: Some(scenario),
        };
        let srcs = paced_sources(n, &model, &shards, Duration::from_micros(300));
        let res = run_async(graph, srcs, init, opts).unwrap();
        assert!(res.net_updates >= 1, "the leave landed");
        assert!(
            res.grads_per_worker[leaver] < 80,
            "departed worker stopped early: {:?}",
            res.grads_per_worker
        );
        for w in 0..n {
            if w != leaver {
                assert_eq!(res.grads_per_worker[w], 80, "worker {w}");
            }
        }
    }

    #[test]
    fn churn_rejoin_completes_all_steps_with_adaptive_params() {
        // Leave 25% at 20%, re-join at 60%: parked workers resume (after
        // a neighbor-snapshot re-init) and finish their budget. The
        // ring→complete switch carries a spectrum, so the published
        // (η, α̃) must have moved off the phase-0 ring values.
        let n = 4;
        let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
        let ds = Arc::new(GaussianMixture::cifar_like().sample(128, 4));
        let shards = Sharding::FullShuffled.assign(&ds, n, 0);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let mut rng = Xoshiro256::seed_from_u64(3);
        let init = model.init_params(&mut rng);
        let opts = RuntimeOptions {
            comm_rate: 1.0,
            method: Method::Acid,
            lr: LrSchedule::Constant { lr: 0.02 },
            momentum: 0.0,
            steps_per_worker: 100,
            seed: 0,
            monitor_interval: Duration::from_millis(2),
            link_delay: None,
            scenario: Some(
                Scenario::parse("ring@0,complete@0.5;leave=0.25:0.2:1;join=0.25:0.6").unwrap(),
            ),
        };
        let srcs = paced_sources(n, &model, &shards, Duration::from_micros(300));
        let res = run_async(graph.clone(), srcs, init, opts).unwrap();
        assert_eq!(res.grads_per_worker, vec![100; n], "re-joined worker caught up");
        assert!(res.net_updates >= 3, "leave + switch + join: {}", res.net_updates);
        // Adaptive default: the final published params are the complete
        // graph's, not the ring's.
        let ring_params =
            crate::gossip::AcidParams::from_spectrum(&graph.spectrum(1.0));
        assert!(res.acid.is_accelerated());
        assert!(
            (res.acid.eta - ring_params.eta).abs() > 1e-9,
            "params were retuned off phase-0: {:?}",
            res.acid
        );
        let c = res.recorder.get("consensus").unwrap();
        assert!(c.points.iter().all(|(_, v)| v.is_finite()));
    }

    #[test]
    fn halt_drains_mid_run_and_a_restart_completes() {
        // The serve daemon's stop/restart path: request_halt on a run
        // sized to outlive the test by orders of magnitude, join with a
        // bounded timeout (a hang here is exactly the stranded-worker /
        // parked-thread drain bug this guards against), then restart a
        // fresh run from the halted run's averaged parameters — the
        // runtime checkpoint contract.
        let n = 4;
        let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
        let ds = Arc::new(GaussianMixture::cifar_like().sample(128, 6));
        let shards = Sharding::FullShuffled.assign(&ds, n, 0);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let mut rng = Xoshiro256::seed_from_u64(7);
        let init = model.init_params(&mut rng);
        let opts = RuntimeOptions {
            comm_rate: 1.0,
            method: Method::Acid,
            lr: LrSchedule::Constant { lr: 0.02 },
            momentum: 0.0,
            steps_per_worker: 1_000_000, // would run ~forever without the halt
            seed: 0,
            monitor_interval: Duration::from_millis(2),
            link_delay: None,
            scenario: None,
        };
        let ctrl = Arc::new(ServeControl::new());
        let handle = {
            let (graph, ctrl) = (graph.clone(), ctrl.clone());
            let srcs = paced_sources(n, &model, &shards, Duration::from_micros(200));
            let init = init.clone();
            std::thread::spawn(move || run_async_controlled(graph, srcs, init, opts, ctrl))
        };
        // Let it train for a few monitor ticks, then stop.
        let t0 = Instant::now();
        while ctrl.metrics_since(0).1 < 3 {
            assert!(t0.elapsed() < Duration::from_secs(30), "run never started ticking");
            std::thread::sleep(Duration::from_millis(2));
        }
        ctrl.request_halt();
        let t0 = Instant::now();
        while !handle.is_finished() {
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "halted run failed to drain"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        let res = handle.join().unwrap().unwrap();
        assert!(!ctrl.is_running());
        let total: u64 = res.grads_per_worker.iter().sum();
        assert!(total > 0, "did some training before the halt");
        assert!(total < n as u64 * 1_000_000, "halt cut the run short");
        // Restart: a fresh run seeded with the halted run's consensus
        // model runs to natural completion.
        let opts2 = RuntimeOptions {
            steps_per_worker: 20,
            momentum: 0.0,
            monitor_interval: Duration::from_millis(2),
            ..Default::default()
        };
        let res2 = run_async(graph, sources(n, &model, &shards), res.avg_params.clone(), opts2)
            .unwrap();
        assert_eq!(res2.grads_per_worker, vec![20; n]);
    }

    #[test]
    fn injected_updates_apply_through_the_scenario_path() {
        // Live injection: a ring→complete switch compiled from the
        // scenario grammar and pushed through the control block must land
        // via the same epoch-gated WallClock publish path a scenario
        // replay uses — counted in net_updates, visible as chord
        // pairings the static ring could never produce.
        let n = 4;
        let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
        let ds = Arc::new(GaussianMixture::cifar_like().sample(128, 5));
        let shards = Sharding::FullShuffled.assign(&ds, n, 0);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let mut rng = Xoshiro256::seed_from_u64(11);
        let init = model.init_params(&mut rng);
        let opts = RuntimeOptions {
            comm_rate: 1.0,
            method: Method::AsyncBaseline,
            lr: LrSchedule::Constant { lr: 0.02 },
            momentum: 0.0,
            steps_per_worker: 150,
            seed: 0,
            monitor_interval: Duration::from_millis(2),
            link_delay: None,
            scenario: None, // static ring: the only update is the injected one
        };
        let ctrl = Arc::new(ServeControl::new());
        let handle = {
            let (graph, ctrl) = (graph.clone(), ctrl.clone());
            let srcs = paced_sources(n, &model, &shards, Duration::from_micros(300));
            std::thread::spawn(move || run_async_controlled(graph, srcs, init, opts, ctrl))
        };
        let t0 = Instant::now();
        while !ctrl.is_running() {
            assert!(t0.elapsed() < Duration::from_secs(30), "run never started");
            std::thread::sleep(Duration::from_millis(1));
        }
        // Compile the switch exactly as the daemon does — through the
        // scenario grammar (the update's own `t` stamp is ignored;
        // injection means now).
        let plan = Scenario::parse("ring@0,complete@0.5")
            .unwrap()
            .compile(n, 1.0, 1.0, &[1.0; n])
            .unwrap();
        ctrl.inject(vec![plan.updates[0].clone()]);
        let t0 = Instant::now();
        while ctrl.injected_applied() < 1 {
            assert!(t0.elapsed() < Duration::from_secs(30), "injection never applied");
            std::thread::sleep(Duration::from_millis(1));
        }
        let res = handle.join().unwrap().unwrap();
        assert_eq!(res.net_updates, 1, "injected update counted like a scenario's");
        assert_eq!(res.grads_per_worker, vec![150; n]);
        let ring = Graph::build(&Topology::Ring, n).unwrap();
        let chord_pairings: u64 = (0..n)
            .flat_map(|i| (i + 1..n).map(move |j| (i, j)))
            .filter(|&(i, j)| !ring.has_edge(i, j))
            .map(|(i, j)| res.pairing.counts[i][j])
            .sum();
        assert!(chord_pairings > 0, "the injected switch opened the chords");
    }

    #[test]
    fn concurrent_snapshot_and_metrics_reads_during_a_run() {
        // The daemon's query path: external readers hammer
        // consensus_snapshot() off the lock-free cells for the whole run;
        // training must complete all steps and every observed snapshot
        // must be dimension-correct and finite. The metrics stream must
        // be cursor-pollable JSON, one record per monitor tick.
        let n = 4;
        let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
        let ds = Arc::new(GaussianMixture::cifar_like().sample(128, 9));
        let shards = Sharding::FullShuffled.assign(&ds, n, 0);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let mut rng = Xoshiro256::seed_from_u64(13);
        let init = model.init_params(&mut rng);
        let opts = RuntimeOptions {
            comm_rate: 1.0,
            method: Method::Acid,
            lr: LrSchedule::Constant { lr: 0.02 },
            momentum: 0.0,
            steps_per_worker: 100,
            seed: 0,
            monitor_interval: Duration::from_millis(2),
            link_delay: None,
            scenario: None,
        };
        let ctrl = Arc::new(ServeControl::new());
        assert!(ctrl.consensus_snapshot().is_none(), "no cells before startup");
        let handle = {
            let (graph, ctrl) = (graph.clone(), ctrl.clone());
            let srcs = paced_sources(n, &model, &shards, Duration::from_micros(200));
            std::thread::spawn(move || run_async_controlled(graph, srcs, init, opts, ctrl))
        };
        let mut reads = 0u64;
        let t0 = Instant::now();
        while !handle.is_finished() {
            assert!(t0.elapsed() < Duration::from_secs(60), "run hung");
            if let Some(snap) = ctrl.consensus_snapshot() {
                assert_eq!(snap.len(), model.dim());
                assert!(snap.iter().all(|v| v.is_finite()));
                reads += 1;
            }
            std::thread::sleep(Duration::from_micros(500));
        }
        let res = handle.join().unwrap().unwrap();
        assert_eq!(res.grads_per_worker, vec![100; n]);
        assert!(reads > 0, "snapshots were read concurrently");
        // Metrics stream: each record is one JSON object per tick, and
        // polling from the end cursor returns nothing new.
        let (lines, cursor) = ctrl.metrics_since(0);
        assert_eq!(lines.len(), cursor);
        assert!(cursor >= 1, "at least one monitor tick recorded");
        for l in &lines {
            assert!(
                l.starts_with('{') && l.contains("\"grads\"") && l.contains("\"consensus\""),
                "malformed metrics record: {l}"
            );
        }
        let (more, c2) = ctrl.metrics_since(cursor);
        assert!(more.is_empty() && c2 == cursor);
    }

    #[test]
    fn scenario_dropout_does_not_hang() {
        // Drop ALL links for the middle half of the run: comm threads
        // must ride through the outage (cancel/retry) and terminate.
        let n = 4;
        let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
        let ds = Arc::new(GaussianMixture::cifar_like().sample(128, 3));
        let shards = Sharding::FullShuffled.assign(&ds, n, 0);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let mut rng = Xoshiro256::seed_from_u64(2);
        let init = model.init_params(&mut rng);
        let srcs: Vec<Box<dyn GradSource>> = (0..n)
            .map(|w| {
                let mut s = RustGradSource::new(
                    model.clone() as Arc<dyn Model>,
                    shards.per_worker[w].clone(),
                    8,
                    w as u64,
                );
                s.extra_delay = Some(Duration::from_micros(300));
                Box::new(s) as Box<dyn GradSource>
            })
            .collect();
        let opts = RuntimeOptions {
            comm_rate: 1.0,
            method: Method::AsyncBaseline,
            lr: LrSchedule::Constant { lr: 0.02 },
            momentum: 0.0,
            steps_per_worker: 80,
            seed: 0,
            monitor_interval: Duration::from_millis(2),
            link_delay: None,
            scenario: Some(Scenario::parse("ring@0;drop=1.0:0.25:0.75:5").unwrap()),
        };
        let res = run_async(graph, srcs, init, opts).unwrap();
        assert_eq!(res.grads_per_worker, vec![80; n]);
        assert!(res.net_updates >= 1, "dropout window landed");
    }
}
