//! Published parameter snapshots: the lock-free read side of a worker
//! cell.
//!
//! The runtime used to funnel every reader through the cell's state
//! mutex: the gradient thread copied `x` under the lock before each
//! mini-batch, and the monitor locked *every* worker each tick to clone
//! all parameter vectors — both contending with the communication thread
//! on the hot path. [`SnapshotCell`] replaces that read side with a
//! version-stamped, double-buffered snapshot (a seqlock): writers (who
//! already hold the state mutex, so they are serialized) publish `x`
//! into the buffer the readers are *not* looking at and then flip an
//! atomic stamp; readers copy without any lock and retry on the rare
//! version tear. Readers never block writers and writers never block
//! readers.
//!
//! [`ConsensusAccumulator`] builds the monitor's consensus measurement
//! on top: a streamed fold over every worker's published buffer with
//! zero steady-state allocation, replacing the per-tick
//! `Vec<Vec<f32>>` materialization.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// A double-buffered, version-stamped snapshot of one worker's `x`.
///
/// Writer side ([`SnapshotCell::publish`]) must be externally serialized —
/// in the runtime, publishers hold the cell's state mutex. Readers
/// ([`SnapshotCell::read_into`]) are lock-free and wait-free against the
/// writer except when two publishes land mid-copy (then they retry).
pub struct SnapshotCell {
    bufs: [UnsafeCell<Box<[f32]>>; 2],
    /// Per-buffer seqlock stamps: odd while that buffer is being written.
    seqs: [AtomicU64; 2],
    /// Index of the most recently published buffer.
    latest: AtomicUsize,
    /// Cached parameter dimension, so `dim()` never forms a reference
    /// into a buffer a concurrent publish may hold `&mut`.
    dim: usize,
}

// SAFETY: the raw buffer accesses follow the seqlock protocol — readers
// validate the per-buffer stamp around their copy and discard torn data;
// writers are serialized by contract (the cell's state mutex).
unsafe impl Sync for SnapshotCell {}
unsafe impl Send for SnapshotCell {}

impl SnapshotCell {
    /// Create with both buffers holding `init` (so the first read is
    /// valid before the first publish).
    pub fn new(init: &[f32]) -> Self {
        Self {
            bufs: [
                UnsafeCell::new(init.to_vec().into_boxed_slice()),
                UnsafeCell::new(init.to_vec().into_boxed_slice()),
            ],
            seqs: [AtomicU64::new(0), AtomicU64::new(0)],
            latest: AtomicUsize::new(0),
            dim: init.len(),
        }
    }

    /// Parameter dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Publish a new snapshot. Callers must be serialized (hold the
    /// worker's state mutex). Cost: one 1R + 1W copy into the buffer no
    /// reader is directed at.
    pub fn publish(&self, x: &[f32]) {
        let idx = self.latest.load(Ordering::Relaxed) ^ 1;
        let seq = &self.seqs[idx];
        // SeqCst (not Release): the odd stamp must become visible BEFORE
        // any of the buffer stores below — a release RMW only orders
        // *prior* accesses, and on a weakly-ordered CPU the data writes
        // could hoist above it, letting a reader validate a torn copy.
        seq.fetch_add(1, Ordering::SeqCst); // odd: write in progress
        // SAFETY: writers are serialized by contract, and readers only
        // trust a buffer whose stamp is even and unchanged around their
        // copy — this in-progress write is flagged by the odd stamp. The
        // copy shards across the chunk pool at large dim; the pool's own
        // synchronization sequences every chunk write between the two
        // stamp bumps.
        unsafe {
            let buf = &mut *self.bufs[idx].get();
            crate::gossip::pool::copy(x, buf);
        }
        seq.fetch_add(1, Ordering::Release); // even again: stable
        self.latest.store(idx, Ordering::Release);
    }

    /// Copy a version-consistent snapshot into `dst` (resized to the
    /// parameter dimension; steady-state calls never allocate). Lock-free:
    /// retries only if two publishes landed during the copy.
    pub fn read_into(&self, dst: &mut Vec<f32>) {
        dst.resize(self.dim(), 0.0);
        self.read_into_slice(dst.as_mut_slice());
    }

    /// As [`SnapshotCell::read_into`], into an exactly-sized slice.
    pub fn read_into_slice(&self, dst: &mut [f32]) {
        assert_eq!(dst.len(), self.dim());
        loop {
            let idx = self.latest.load(Ordering::Acquire);
            let seq = &self.seqs[idx];
            let s1 = seq.load(Ordering::Acquire);
            if s1 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            // SAFETY: a concurrent write to this buffer is detected by
            // the stamp check below and the torn copy is discarded.
            // Caveat, stated openly: under the strict memory model this
            // overlapping non-atomic read/write pair is a data race even
            // though the torn bytes are never USED — the classic seqlock
            // compromise (crossbeam's SeqLock reads the same way). The
            // payload is plain f32s (no pointers/invariants), the copy
            // is fenced, and the stamp check gates every consumer, so we
            // accept it rather than pay per-word volatile reads on a
            // multi-MB hot path.
            unsafe {
                let src = &*self.bufs[idx].get();
                std::ptr::copy_nonoverlapping(src.as_ptr(), dst.as_mut_ptr(), dst.len());
            }
            std::sync::atomic::fence(Ordering::Acquire);
            if seq.load(Ordering::Acquire) == s1 {
                return;
            }
        }
    }
}

/// Streamed consensus measurement over published snapshots with zero
/// steady-state allocation.
///
/// Each tick reads every worker's snapshot exactly once into one
/// persistent row matrix, then computes `Σᵢ‖xᵢ − x̄‖²` with the same
/// two-pass mean-then-deviation algorithm as
/// [`crate::gossip::consensus_of`] — NOT the one-pass
/// `Σ‖xᵢ‖² − n‖x̄‖²` identity, whose catastrophic cancellation would
/// floor the metric orders of magnitude too early near convergence.
/// After the first call, [`ConsensusAccumulator::measure`] allocates
/// nothing: the matrix and the mean buffer are reused across ticks.
#[derive(Default)]
pub struct ConsensusAccumulator {
    /// Persistent `n × dim` row-major copy of this tick's snapshots.
    rows: Vec<f32>,
    mean: Vec<f64>,
}

impl ConsensusAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// `Σᵢ ‖xᵢ − x̄‖²` over the cells' published snapshots (the same
    /// quantity as [`crate::gossip::consensus_of`]).
    pub fn measure<'a>(&mut self, cells: impl Iterator<Item = &'a SnapshotCell>) -> f64 {
        let mut n = 0usize;
        let mut dim = 0usize;
        for cell in cells {
            if n == 0 {
                dim = cell.dim();
            }
            assert_eq!(cell.dim(), dim, "ragged parameter rows");
            let end = (n + 1) * dim;
            if self.rows.len() < end {
                self.rows.resize(end, 0.0);
            }
            cell.read_into_slice(&mut self.rows[n * dim..end]);
            n += 1;
        }
        if n == 0 || dim == 0 {
            return 0.0;
        }
        self.mean.clear();
        self.mean.resize(dim, 0.0);
        for r in 0..n {
            let row = &self.rows[r * dim..(r + 1) * dim];
            for (m, &v) in self.mean.iter_mut().zip(row) {
                *m += v as f64;
            }
        }
        let inv = 1.0 / n as f64;
        for m in &mut self.mean {
            *m *= inv;
        }
        let mut acc = 0.0f64;
        for r in 0..n {
            let row = &self.rows[r * dim..(r + 1) * dim];
            for (m, &v) in self.mean.iter().zip(row) {
                let d = v as f64 - *m;
                acc += d * d;
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gossip::consensus_of;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn first_read_returns_init() {
        let cell = SnapshotCell::new(&[1.0, 2.0, 3.0]);
        let mut out = Vec::new();
        cell.read_into(&mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0]);
        assert_eq!(cell.dim(), 3);
    }

    #[test]
    fn publish_then_read_round_trips() {
        let cell = SnapshotCell::new(&[0.0; 4]);
        let mut out = Vec::new();
        for k in 1..10 {
            let v = vec![k as f32; 4];
            cell.publish(&v);
            cell.read_into(&mut out);
            assert_eq!(out, v);
        }
    }

    #[test]
    fn torn_reads_never_observed_under_write_churn() {
        // The seqlock stress test: a writer publishes constant-valued
        // snapshots as fast as it can while readers verify that every
        // snapshot they obtain is internally consistent (all elements
        // equal — a torn read would mix two versions).
        let dim = 1024;
        let init = vec![0.0f32; dim];
        let cell = Arc::new(SnapshotCell::new(&init));
        let stop = Arc::new(AtomicBool::new(false));

        let writer = {
            let cell = cell.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut buf = vec![0.0f32; dim];
                let mut v = 0u32;
                while !stop.load(Ordering::Relaxed) {
                    v = v.wrapping_add(1);
                    buf.fill(v as f32);
                    cell.publish(&buf);
                }
                v
            })
        };

        let readers: Vec<_> = (0..2)
            .map(|_| {
                let cell = cell.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut out = Vec::new();
                    let mut reads = 0u64;
                    let mut last = 0.0f32;
                    while !stop.load(Ordering::Relaxed) {
                        cell.read_into(&mut out);
                        let first = out[0];
                        assert!(
                            out.iter().all(|&x| x == first),
                            "torn snapshot: {} vs {}",
                            first,
                            out.iter().find(|&&x| x != first).unwrap()
                        );
                        // Published versions are monotone for one writer.
                        assert!(first >= last, "went backwards: {last} -> {first}");
                        last = first;
                        reads += 1;
                    }
                    reads
                })
            })
            .collect();

        std::thread::sleep(std::time::Duration::from_millis(300));
        stop.store(true, Ordering::Relaxed);
        let versions = writer.join().unwrap();
        let total_reads: u64 = readers.into_iter().map(|r| r.join().unwrap()).sum();
        assert!(versions > 100, "writer made progress: {versions}");
        assert!(total_reads > 100, "readers made progress: {total_reads}");
    }

    #[test]
    fn checkpointer_sweeps_never_stall_writers_or_tear() {
        // The serve daemon's checkpoint/query path: a "checkpointer"
        // thread assembling full-fleet consensus snapshots (reading EVERY
        // cell back-to-back, like `ServeControl::consensus_snapshot`)
        // while each cell's writer publishes flat out. The seqlock
        // contract under test: readers never block writers — the
        // checkpointer must observe only torn-free, monotone snapshots,
        // and every writer must keep making substantial progress while
        // being swept.
        let dim = 1024;
        let n_cells = 4;
        let cells: Vec<Arc<SnapshotCell>> = (0..n_cells)
            .map(|_| Arc::new(SnapshotCell::new(&vec![0.0f32; dim])))
            .collect();
        let stop = Arc::new(AtomicBool::new(false));

        let writers: Vec<_> = cells
            .iter()
            .map(|cell| {
                let cell = cell.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut buf = vec![0.0f32; dim];
                    let mut v = 0u32;
                    while !stop.load(Ordering::Relaxed) {
                        v = v.wrapping_add(1);
                        buf.fill(v as f32);
                        cell.publish(&buf);
                    }
                    v
                })
            })
            .collect();

        let checkpointer = {
            let cells = cells.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut row = vec![0.0f32; dim];
                let mut last = vec![0.0f32; n_cells];
                let mut sweeps = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    for (c, cell) in cells.iter().enumerate() {
                        cell.read_into_slice(&mut row);
                        let first = row[0];
                        assert!(
                            row.iter().all(|&x| x == first),
                            "torn checkpoint row from cell {c}"
                        );
                        assert!(first >= last[c], "cell {c} went backwards");
                        last[c] = first;
                    }
                    sweeps += 1;
                }
                sweeps
            })
        };

        std::thread::sleep(std::time::Duration::from_millis(300));
        stop.store(true, Ordering::Relaxed);
        let sweeps = checkpointer.join().unwrap();
        assert!(sweeps > 50, "checkpointer made progress: {sweeps}");
        for (c, w) in writers.into_iter().enumerate() {
            let versions = w.join().unwrap();
            assert!(
                versions > 1000,
                "writer {c} stalled under checkpoint sweeps: {versions} publishes"
            );
        }
    }

    #[test]
    fn consensus_accumulator_matches_consensus_of() {
        let rows: Vec<Vec<f32>> = vec![
            vec![1.0, -2.0, 0.5, 3.0],
            vec![0.0, 1.0, -1.0, 2.0],
            vec![2.5, 0.25, 1.5, -0.5],
        ];
        let cells: Vec<SnapshotCell> =
            rows.iter().map(|r| SnapshotCell::new(r)).collect();
        let want = consensus_of(rows.iter().map(|r| r.as_slice()));
        let mut acc = ConsensusAccumulator::new();
        let got = acc.measure(cells.iter());
        assert!(
            (got - want).abs() <= 1e-9 * want.max(1.0),
            "{got} vs {want}"
        );
        // Second tick reuses the buffers and agrees.
        let got2 = acc.measure(cells.iter());
        assert!((got2 - want).abs() <= 1e-9 * want.max(1.0));
    }

    #[test]
    fn consensus_accumulator_zero_for_identical_rows() {
        let cells: Vec<SnapshotCell> =
            (0..4).map(|_| SnapshotCell::new(&[1.0, 2.0])).collect();
        let mut acc = ConsensusAccumulator::new();
        assert_eq!(acc.measure(cells.iter()), 0.0);
        assert_eq!(acc.measure(std::iter::empty()), 0.0);
    }
}
