//! `a2cid2 serve` — training as a service over a Unix control socket.
//!
//! A [`ServeDaemon`] owns one threaded-runtime training run
//! ([`crate::runtime::worker::run_async_controlled`]) plus a Unix domain
//! socket accept loop. Clients speak a line-delimited protocol: one
//! UTF-8 command line in, exactly one JSON object line back.
//!
//! ```text
//! status                 → {"running": …, "done": …, "grads": …, "injected_applied": …, "metrics": …}
//! inject <scenario>      → {"ok": true, "updates": N, "dropped_edges": D}
//! snapshot               → {"dim": …, "checksum": "<fnv1a hex>", "norm": …}
//! metrics [cursor]       → {"next": C, "records": [ … ]}
//! checkpoint <path>      → {"ok": true, "path": …, "grads": …, "dim": …}
//! stop                   → {"ok": true}          (drain-stop the run; daemon keeps serving)
//! shutdown               → {"ok": true}          (stop + exit the accept loop)
//! ```
//!
//! Errors come back as `{"error": "…"}` — the connection stays usable.
//!
//! `inject` reuses the [`Scenario`] grammar verbatim: the daemon compiles
//! the string with [`Scenario::compile`] and queues every resulting
//! [`NetUpdate`] through [`ServeControl::inject`]; the monitor applies
//! them on its next tick via the same epoch-gated [`WallClock`] publish
//! path a scenario replay uses (`t` stamps are ignored — injection means
//! *now*). A single-phase scenario (`complete@0`) compiles to zero
//! updates, so the daemon synthesizes one from the plan's initial state:
//! "switch to this topology now". Because a compiled plan indexes edge
//! rates by ITS OWN union edge list while the running [`WallClock`] is
//! fixed to the union the run started with, every injected update is
//! remapped onto the running union — rates for edges the running union
//! does not carry are dropped (and counted in the reply), running-union
//! edges the injected topology omits go silent (rate 0).
//!
//! `snapshot` and `checkpoint` assemble the consensus model off the
//! per-worker lock-free [`crate::runtime::SnapshotCell`]s — concurrent
//! readers never take a state lock and never stall the training writers.
//! A runtime checkpoint ([`RuntimeCheckpoint`]) is the consensus
//! parameters plus run metadata in a versioned binary format, written
//! through [`write_atomic`]; a restart is a fresh run seeded with those
//! parameters (the threaded runtime is wall-clock driven, so unlike the
//! virtual-time simulator's [`crate::simulator::SimCheckpoint`] there is
//! no bit-identical trace to resume — the contract is "continue training
//! from the saved consensus model").
//!
//! [`WallClock`]: crate::engine::WallClock

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write as _};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::scenario::NetUpdate;
use crate::config::Scenario;
use crate::graph::Graph;
use crate::metrics::Record;
use crate::runtime::artifacts::write_atomic;
use crate::runtime::worker::{
    run_async_controlled, GradSource, RuntimeOptions, RuntimeResult, ServeControl,
};

/// 8-byte magic + version prefix of a runtime checkpoint file.
pub const RUNTIME_CKPT_MAGIC: &[u8; 8] = b"A2SRVCK1";

/// A threaded-runtime checkpoint: the consensus model plus the metadata
/// a restart validates against. Wire format (all little-endian):
/// magic, n_workers u32, seed u64, grads u64, dim u64, params f32-bits.
#[derive(Clone, Debug, PartialEq)]
pub struct RuntimeCheckpoint {
    pub n_workers: u32,
    pub seed: u64,
    /// Fleet-total completed gradient steps at capture time.
    pub grads: u64,
    /// Consensus model (mean of every worker's published parameters).
    pub params: Vec<f32>,
}

impl RuntimeCheckpoint {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + 4 + 8 + 8 + 8 + 4 * self.params.len());
        out.extend_from_slice(RUNTIME_CKPT_MAGIC);
        out.extend_from_slice(&self.n_workers.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.grads.to_le_bytes());
        out.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        for v in &self.params {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> crate::Result<Self> {
        let take = |bytes: &[u8], at: &mut usize, n: usize| -> crate::Result<Vec<u8>> {
            anyhow::ensure!(
                bytes.len() - *at >= n,
                "truncated runtime checkpoint: wanted {n} bytes at {at}, have {}",
                bytes.len() - *at
            );
            let out = bytes[*at..*at + n].to_vec();
            *at += n;
            Ok(out)
        };
        let mut at = 0usize;
        let magic = take(bytes, &mut at, 8)?;
        anyhow::ensure!(
            magic == RUNTIME_CKPT_MAGIC,
            "not a runtime checkpoint (bad magic {magic:02x?})"
        );
        let n_workers = u32::from_le_bytes(take(bytes, &mut at, 4)?.try_into().unwrap());
        let seed = u64::from_le_bytes(take(bytes, &mut at, 8)?.try_into().unwrap());
        let grads = u64::from_le_bytes(take(bytes, &mut at, 8)?.try_into().unwrap());
        let dim = u64::from_le_bytes(take(bytes, &mut at, 8)?.try_into().unwrap()) as usize;
        // Guard against allocating from a corrupt length field.
        anyhow::ensure!(
            bytes.len() - at == 4 * dim,
            "runtime checkpoint length mismatch: dim {dim} wants {} payload bytes, have {}",
            4 * dim,
            bytes.len() - at
        );
        let mut params = Vec::with_capacity(dim);
        for chunk in bytes[at..].chunks_exact(4) {
            params.push(f32::from_bits(u32::from_le_bytes(chunk.try_into().unwrap())));
        }
        Ok(Self { n_workers, seed, grads, params })
    }

    /// Write through the atomic-rename path (crash-safe, race-safe).
    pub fn save(&self, path: &Path) -> crate::Result<()> {
        write_atomic(path, &self.to_bytes())
    }

    pub fn load(path: &Path) -> crate::Result<Self> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("cannot read checkpoint {}: {e}", path.display()))?;
        Self::from_bytes(&bytes)
    }
}

/// FNV-1a over the exact bit patterns of a parameter vector — the same
/// fingerprint `a2cid2 replay` prints, so socket clients and CI can diff
/// snapshots without shipping the full vector.
pub fn fnv1a_params(params: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for v in params {
        for b in v.to_bits().to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Compile a scenario string into injectable updates for a run whose
/// [`WallClock`] was built over `running` (see the module docs for the
/// remapping contract). Returns the updates plus the count of injected
/// edge-rate entries that had to be dropped because the running union
/// does not carry their edge.
///
/// [`WallClock`]: crate::engine::WallClock
pub fn compile_injection(
    scenario: &str,
    running: &Graph,
    comm_rate: f64,
    horizon: f64,
) -> crate::Result<(Vec<NetUpdate>, usize)> {
    let n = running.n;
    let sc = Scenario::parse(scenario)?;
    let plan = sc.compile(n, comm_rate, horizon, &vec![1.0; n])?;
    let mut updates = plan.updates;
    if updates.is_empty() {
        // Single-phase scenario: "switch to this state now".
        updates.push(NetUpdate {
            t: 0.0,
            edge_rates: Some(plan.initial_edge_rates.clone()),
            grad_rates: Some(plan.initial_grad_rates.clone()),
            edge_diff: Vec::new(),
            grad_diff: Vec::new(),
            leave: Vec::new(),
            join: Vec::new(),
            chis: Some((plan.spectrum.chi1, plan.spectrum.chi2)),
        });
    }
    let mut dropped = 0usize;
    for upd in &mut updates {
        if let Some(rates) = upd.edge_rates.take() {
            let by_pair: HashMap<(usize, usize), f64> =
                plan.union.edges.iter().copied().zip(rates).collect();
            dropped += by_pair
                .iter()
                .filter(|(&(i, j), &r)| r > 0.0 && !running.has_edge(i, j))
                .count();
            let remapped: Vec<f64> = running
                .edges
                .iter()
                .map(|ij| by_pair.get(ij).copied().unwrap_or(0.0))
                .collect();
            upd.edge_rates = Some(remapped);
            // The compiled diff indexes the OLD union; clear it so the
            // scheduler falls back to the dense vector above.
            upd.edge_diff.clear();
        }
    }
    Ok((updates, dropped))
}

/// State shared between the run thread, the accept loop, and every
/// connection handler.
struct Shared {
    ctrl: Arc<ServeControl>,
    outcome: Mutex<Option<crate::Result<RuntimeResult>>>,
    shutdown: AtomicBool,
    /// The union graph the running `WallClock` is fixed to.
    union: Arc<Graph>,
    comm_rate: f64,
    horizon: f64,
    seed: u64,
}

/// The training-as-a-service daemon: one controlled runtime run plus a
/// Unix-socket control plane. See the module docs for the protocol.
pub struct ServeDaemon {
    shared: Arc<Shared>,
    run: Option<JoinHandle<()>>,
    accept: Option<JoinHandle<()>>,
    socket_path: PathBuf,
}

impl ServeDaemon {
    /// Bind `socket`, start training, start serving. The run begins on
    /// the static `graph` topology; evolve it live via `inject`.
    pub fn start(
        graph: Arc<Graph>,
        grad_sources: Vec<Box<dyn GradSource>>,
        init: Vec<f32>,
        opts: RuntimeOptions,
        socket: &Path,
    ) -> crate::Result<ServeDaemon> {
        anyhow::ensure!(
            opts.scenario.is_none(),
            "serve runs start on the static --topology; push changes over the socket instead"
        );
        // A stale socket file from a dead daemon would make bind fail.
        let _ = std::fs::remove_file(socket);
        let listener = UnixListener::bind(socket)
            .map_err(|e| anyhow::anyhow!("cannot bind {}: {e}", socket.display()))?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            ctrl: Arc::new(ServeControl::new()),
            outcome: Mutex::new(None),
            shutdown: AtomicBool::new(false),
            union: graph.clone(),
            comm_rate: opts.comm_rate,
            horizon: opts.steps_per_worker as f64,
            seed: opts.seed,
        });
        let run = {
            let shared = shared.clone();
            let ctrl = shared.ctrl.clone();
            std::thread::Builder::new()
                .name("a2cid2-serve-run".into())
                .spawn(move || {
                    let r = run_async_controlled(graph, grad_sources, init, opts, ctrl);
                    *shared.outcome.lock().unwrap() = Some(r);
                })
                .expect("spawn serve run thread")
        };
        let accept = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("a2cid2-serve-accept".into())
                .spawn(move || accept_loop(listener, &shared))
                .expect("spawn serve accept thread")
        };
        Ok(ServeDaemon {
            shared,
            run: Some(run),
            accept: Some(accept),
            socket_path: socket.to_path_buf(),
        })
    }

    /// The control block (same handles the socket handlers use), for
    /// in-process supervision and tests.
    pub fn ctrl(&self) -> Arc<ServeControl> {
        self.shared.ctrl.clone()
    }

    /// Whether a `shutdown` command has been received.
    pub fn is_shutdown(&self) -> bool {
        self.shared.shutdown.load(Ordering::Acquire)
    }

    /// Block until a `shutdown` command lands (halting any still-active
    /// run), then return the training outcome (`None` only if the run
    /// thread was never able to report, i.e. it panicked).
    pub fn wait(mut self) -> crate::Result<Option<RuntimeResult>> {
        while !self.is_shutdown() {
            std::thread::sleep(Duration::from_millis(10));
        }
        // `shutdown` already requested the halt; make it idempotent here
        // so wait() converges even if the flag was set in-process.
        self.shared.ctrl.request_halt();
        if let Some(h) = self.run.take() {
            h.join().map_err(|_| anyhow::anyhow!("serve run thread panicked"))?;
        }
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| anyhow::anyhow!("serve accept thread panicked"))?;
        }
        let _ = std::fs::remove_file(&self.socket_path);
        let outcome = self.shared.outcome.lock().unwrap().take();
        outcome.transpose()
    }
}

fn accept_loop(listener: UnixListener, shared: &Arc<Shared>) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !shared.shutdown.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _)) => {
                let shared = shared.clone();
                handlers.push(
                    std::thread::Builder::new()
                        .name("a2cid2-serve-conn".into())
                        .spawn(move || handle_client(stream, &shared))
                        .expect("spawn serve connection handler"),
                );
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
    // Handlers poll the shutdown flag between reads (bounded read
    // timeout), so joining here cannot hang on an idle client.
    for h in handlers {
        let _ = h.join();
    }
}

fn handle_client(stream: UnixStream, shared: &Shared) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        match reader.read_line(&mut line) {
            Ok(0) => {
                // EOF; serve a final unterminated command if any.
                let cmd = line.trim().to_string();
                if !cmd.is_empty() {
                    let _ = writeln!(writer, "{}", dispatch(&cmd, shared));
                }
                return;
            }
            Ok(_) => {
                let cmd = line.trim().to_string();
                line.clear();
                if cmd.is_empty() {
                    continue;
                }
                let reply = dispatch(&cmd, shared);
                if writeln!(writer, "{reply}").is_err() || writer.flush().is_err() {
                    return;
                }
            }
            // Timeout mid-wait (or mid-line: read_line keeps what it got
            // in `line`, so partial commands survive the retry).
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => return,
        }
    }
}

fn err_json(msg: impl std::fmt::Display) -> String {
    Record::new().str("error", &msg.to_string()).to_json()
}

/// Execute one command line, producing exactly one JSON reply line.
fn dispatch(cmd: &str, shared: &Shared) -> String {
    let mut parts = cmd.splitn(2, ' ');
    let verb = parts.next().unwrap_or("");
    let arg = parts.next().map(str::trim).filter(|s| !s.is_empty());
    match (verb, arg) {
        ("status", _) => {
            let (_, cursor) = shared.ctrl.metrics_since(usize::MAX);
            Record::new()
                .bool("running", shared.ctrl.is_running())
                .bool("done", shared.outcome.lock().unwrap().is_some())
                .u64("grads", shared.ctrl.grads_total())
                .u64("injected_applied", shared.ctrl.injected_applied())
                .u64("metrics", cursor as u64)
                .to_json()
        }
        ("inject", Some(s)) => {
            match compile_injection(s, &shared.union, shared.comm_rate, shared.horizon) {
                Ok((updates, dropped)) => {
                    let n = updates.len();
                    shared.ctrl.inject(updates);
                    Record::new()
                        .bool("ok", true)
                        .u64("updates", n as u64)
                        .u64("dropped_edges", dropped as u64)
                        .to_json()
                }
                Err(e) => err_json(format!("inject: {e:#}")),
            }
        }
        ("inject", None) => err_json("inject needs a scenario string"),
        ("snapshot", _) => match shared.ctrl.consensus_snapshot() {
            Some(p) => {
                let norm = p.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt();
                Record::new()
                    .u64("dim", p.len() as u64)
                    .str("checksum", &format!("{:016x}", fnv1a_params(&p)))
                    .f64("norm", norm)
                    .to_json()
            }
            None => err_json("no snapshot yet (run not started)"),
        },
        ("metrics", cursor) => {
            let from = match cursor.map(str::parse::<usize>).transpose() {
                Ok(c) => c.unwrap_or(0),
                Err(_) => return err_json("metrics cursor must be an integer"),
            };
            let (records, next) = shared.ctrl.metrics_since(from);
            format!("{{\"next\": {next}, \"records\": [{}]}}", records.join(", "))
        }
        ("checkpoint", Some(path)) => match shared.ctrl.consensus_snapshot() {
            Some(params) => {
                let ck = RuntimeCheckpoint {
                    n_workers: shared.union.n as u32,
                    seed: shared.seed,
                    grads: shared.ctrl.grads_total(),
                    params,
                };
                match ck.save(Path::new(path)) {
                    Ok(()) => Record::new()
                        .bool("ok", true)
                        .str("path", path)
                        .u64("grads", ck.grads)
                        .u64("dim", ck.params.len() as u64)
                        .to_json(),
                    Err(e) => err_json(format!("checkpoint: {e:#}")),
                }
            }
            None => err_json("no snapshot yet (run not started)"),
        },
        ("checkpoint", None) => err_json("checkpoint needs a destination path"),
        ("stop", _) => {
            shared.ctrl.request_halt();
            Record::new().bool("ok", true).to_json()
        }
        ("shutdown", _) => {
            shared.ctrl.request_halt();
            shared.shutdown.store(true, Ordering::Release);
            Record::new().bool("ok", true).to_json()
        }
        _ => err_json(format!(
            "unknown command {verb:?} (status|inject|snapshot|metrics|checkpoint|stop|shutdown)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::data::{GaussianMixture, Sharding};
    use crate::graph::Topology;
    use crate::model::{Logistic, Model};
    use crate::optim::LrSchedule;
    use crate::rng::Xoshiro256;
    use crate::runtime::worker::{run_async, RustGradSource};
    use std::time::Instant;

    #[test]
    fn runtime_checkpoint_round_trips_and_rejects_corruption() {
        let ck = RuntimeCheckpoint {
            n_workers: 4,
            seed: 7,
            grads: 1234,
            params: vec![1.5, -0.25, f32::MIN_POSITIVE, 0.0],
        };
        let bytes = ck.to_bytes();
        assert_eq!(RuntimeCheckpoint::from_bytes(&bytes).unwrap(), ck);
        // Every proper prefix fails cleanly.
        for cut in 0..bytes.len() {
            assert!(RuntimeCheckpoint::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(RuntimeCheckpoint::from_bytes(&bad).is_err());
        // Corrupt dim field cannot overallocate: it fails the payload
        // length check before any allocation happens.
        let mut huge = bytes.clone();
        // The dim field sits after magic(8) + n_workers(4) + seed(8) + grads(8).
        huge[28..36].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = RuntimeCheckpoint::from_bytes(&huge).unwrap_err().to_string();
        assert!(err.contains("length mismatch"), "{err}");
        // Save/load through the atomic write path.
        let dir = std::env::temp_dir().join(format!("a2srv_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.ckpt");
        ck.save(&path).unwrap();
        assert_eq!(RuntimeCheckpoint::load(&path).unwrap(), ck);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn injection_compiles_and_remaps_onto_the_running_union() {
        // Running union ring(4); inject `complete@0`. The two chords the
        // ring cannot carry are dropped (and counted); the four ring
        // edges come back live.
        let ring = Graph::build(&Topology::Ring, 4).unwrap();
        let (updates, dropped) = compile_injection("complete@0", &ring, 1.0, 100.0).unwrap();
        assert_eq!(updates.len(), 1, "single phase synthesizes one update");
        assert_eq!(dropped, 2, "complete(4) has 2 chords off the ring");
        let rates = updates[0].edge_rates.as_ref().unwrap();
        assert_eq!(rates.len(), ring.edges.len(), "indexed by the RUNNING union");
        assert!(rates.iter().all(|&r| r > 0.0));
        assert!(updates[0].edge_diff.is_empty(), "dense fallback engaged");
        assert!(updates[0].chis.is_some(), "single-phase switch carries a spectrum");
        // Multi-phase + churn strings compile through the same path.
        let (updates, _) =
            compile_injection("ring@0,complete@0.5;leave=0.25:0.3:1;join=0.25:0.7", &ring, 1.0, 100.0)
                .unwrap();
        assert!(updates.len() >= 3, "switch + leave + join: {}", updates.len());
        for u in &updates {
            if let Some(r) = &u.edge_rates {
                assert_eq!(r.len(), ring.edges.len());
                assert!(u.edge_diff.is_empty());
            }
        }
        // Garbage is a clean error.
        assert!(compile_injection("no-such@grammar!!", &ring, 1.0, 100.0).is_err());
    }

    /// One round-trip on the client side of the line protocol.
    fn roundtrip(reader: &mut BufReader<UnixStream>, writer: &mut UnixStream, cmd: &str) -> String {
        writeln!(writer, "{cmd}").unwrap();
        writer.flush().unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        reply.trim().to_string()
    }

    #[test]
    fn daemon_serves_inject_snapshot_metrics_checkpoint_stop_restart() {
        // The full serve lifecycle over a real socket: start → status →
        // inject → snapshot → metrics → checkpoint → stop → (drained)
        // status → shutdown → wait, then restart a fresh run from the
        // checkpoint file.
        let n = 4;
        let graph = Arc::new(Graph::build(&Topology::Ring, n).unwrap());
        let ds = Arc::new(GaussianMixture::cifar_like().sample(128, 21));
        let shards = Sharding::FullShuffled.assign(&ds, n, 0);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let mut rng = Xoshiro256::seed_from_u64(17);
        let init = model.init_params(&mut rng);
        let sources: Vec<Box<dyn GradSource>> = (0..n)
            .map(|w| {
                let mut s = RustGradSource::new(
                    model.clone() as Arc<dyn Model>,
                    shards.per_worker[w].clone(),
                    8,
                    w as u64,
                );
                s.extra_delay = Some(Duration::from_micros(200));
                Box::new(s) as Box<dyn GradSource>
            })
            .collect();
        let opts = RuntimeOptions {
            comm_rate: 1.0,
            method: Method::Acid,
            lr: LrSchedule::Constant { lr: 0.02 },
            momentum: 0.0,
            steps_per_worker: 1_000_000, // runs until stopped
            seed: 3,
            monitor_interval: Duration::from_millis(2),
            link_delay: None,
            scenario: None,
        };
        let dir = std::env::temp_dir().join(format!("a2serve_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let socket = dir.join("ctl.sock");
        let ckpt = dir.join("run.ckpt");

        let daemon =
            ServeDaemon::start(graph.clone(), sources, init, opts, &socket).unwrap();
        let ctrl = daemon.ctrl();
        let t0 = Instant::now();
        while ctrl.metrics_since(0).1 < 3 {
            assert!(t0.elapsed() < Duration::from_secs(30), "run never started ticking");
            std::thread::sleep(Duration::from_millis(2));
        }

        let stream = UnixStream::connect(&socket).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let rt = |r: &mut BufReader<UnixStream>, w: &mut UnixStream, c: &str| roundtrip(r, w, c);

        let status = rt(&mut reader, &mut writer, "status");
        assert!(status.contains("\"running\": true"), "{status}");
        let inj = rt(&mut reader, &mut writer, "inject complete@0");
        assert!(inj.contains("\"ok\": true") && inj.contains("\"updates\": 1"), "{inj}");
        let t0 = Instant::now();
        while ctrl.injected_applied() < 1 {
            assert!(t0.elapsed() < Duration::from_secs(30), "injection never applied");
            std::thread::sleep(Duration::from_millis(1));
        }
        let snap = rt(&mut reader, &mut writer, "snapshot");
        assert!(
            snap.contains(&format!("\"dim\": {}", model.dim())) && snap.contains("checksum"),
            "{snap}"
        );
        let met = rt(&mut reader, &mut writer, "metrics 0");
        assert!(met.starts_with("{\"next\": ") && met.contains("\"grads\""), "{met}");
        let ck_reply = rt(&mut reader, &mut writer, &format!("checkpoint {}", ckpt.display()));
        assert!(ck_reply.contains("\"ok\": true"), "{ck_reply}");
        let bad = rt(&mut reader, &mut writer, "inject no-such@grammar!!");
        assert!(bad.contains("\"error\""), "{bad}");
        let unknown = rt(&mut reader, &mut writer, "frobnicate");
        assert!(unknown.contains("\"error\""), "{unknown}");

        let stop = rt(&mut reader, &mut writer, "stop");
        assert!(stop.contains("\"ok\": true"), "{stop}");
        // The run drains; the daemon keeps serving afterwards.
        let t0 = Instant::now();
        loop {
            assert!(t0.elapsed() < Duration::from_secs(30), "stop never drained");
            let status = rt(&mut reader, &mut writer, "status");
            if status.contains("\"done\": true") {
                assert!(status.contains("\"running\": false"), "{status}");
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        // Snapshot and checkpoint still work off the registered cells.
        let snap = rt(&mut reader, &mut writer, "snapshot");
        assert!(snap.contains("checksum"), "{snap}");
        let bye = rt(&mut reader, &mut writer, "shutdown");
        assert!(bye.contains("\"ok\": true"), "{bye}");
        drop((reader, writer));

        let res = daemon.wait().unwrap().expect("run reported an outcome");
        let total: u64 = res.grads_per_worker.iter().sum();
        assert!(total > 0, "trained before the stop");
        assert!(res.net_updates >= 1, "the injected switch landed");
        assert!(!socket.exists(), "socket file cleaned up");

        // Restart from the checkpoint: metadata validates, and a fresh
        // short run trains from the saved consensus model.
        let ck = RuntimeCheckpoint::load(&ckpt).unwrap();
        assert_eq!(ck.n_workers, n as u32);
        assert_eq!(ck.seed, 3);
        assert_eq!(ck.params.len(), model.dim());
        let sources2: Vec<Box<dyn GradSource>> = (0..n)
            .map(|w| {
                Box::new(RustGradSource::new(
                    model.clone() as Arc<dyn Model>,
                    shards.per_worker[w].clone(),
                    8,
                    w as u64,
                )) as Box<dyn GradSource>
            })
            .collect();
        let opts2 = RuntimeOptions {
            steps_per_worker: 20,
            momentum: 0.0,
            monitor_interval: Duration::from_millis(2),
            ..Default::default()
        };
        let res2 = run_async(graph, sources2, ck.params, opts2).unwrap();
        assert_eq!(res2.grads_per_worker, vec![20; n]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
