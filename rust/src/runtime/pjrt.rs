//! PJRT wrapper: load AOT HLO-text artifacts and execute them from the
//! Rust request path.
//!
//! Pattern per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. The artifacts are lowered with
//! `return_tuple=True`, so results untuple into their output list.

use std::path::Path;
use std::sync::Arc;

use crate::runtime::artifacts::Manifest;

/// A PJRT client handle shared by all executables.
///
/// Thread-safety: the underlying XLA CPU PJRT client is documented
/// thread-safe for compilation and execution; the raw-pointer Rust
/// wrapper just doesn't carry the marker, so we assert it here and share
/// one client across worker threads behind `Arc`.
pub struct PjrtContext {
    client: xla::PjRtClient,
}

// SAFETY: xla::PjRtClient wraps a C++ PjRtClient, which is thread-safe
// for Compile/Execute/BufferFromHost per the PJRT API contract. We only
// expose &self methods.
unsafe impl Send for PjrtContext {}
unsafe impl Sync for PjrtContext {}

impl PjrtContext {
    /// Create the CPU client.
    pub fn cpu() -> crate::Result<Arc<Self>> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu failed: {e:?}"))?;
        Ok(Arc::new(Self { client }))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact file.
    pub fn load_hlo_text(self: &Arc<Self>, path: &Path) -> crate::Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))?;
        Ok(Executable {
            _ctx: Arc::clone(self),
            exe,
            name: path.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        })
    }

    /// Load an artifact by manifest name.
    pub fn load_artifact(self: &Arc<Self>, manifest: &Manifest, name: &str) -> crate::Result<Executable> {
        let meta = manifest.get(name)?;
        self.load_hlo_text(&manifest.path_of(meta))
    }
}

/// A compiled HLO module ready to execute.
pub struct Executable {
    _ctx: Arc<PjrtContext>,
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

// SAFETY: see PjrtContext — PJRT loaded executables are thread-safe for
// Execute; each worker thread owns its own Executable anyway.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with literal inputs; returns the untupled outputs.
    pub fn run(&self, inputs: &[xla::Literal]) -> crate::Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?;
        let out = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow::anyhow!("{}: empty result", self.name))?
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal {}: {e:?}", self.name))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        out.to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {}: {e:?}", self.name))
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

/// f32 vector literal of shape `[len]`.
pub fn lit_f32(values: &[f32]) -> xla::Literal {
    xla::Literal::vec1(values)
}

/// f32 scalar literal (shape `[]`).
pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// i32 matrix literal of shape `[rows, cols]`.
pub fn lit_i32_matrix(values: &[i32], rows: usize, cols: usize) -> crate::Result<xla::Literal> {
    anyhow::ensure!(values.len() == rows * cols, "shape mismatch");
    xla::Literal::vec1(values)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

/// f32 matrix literal of shape `[rows, cols]`.
pub fn lit_f32_matrix(values: &[f32], rows: usize, cols: usize) -> crate::Result<xla::Literal> {
    anyhow::ensure!(values.len() == rows * cols, "shape mismatch");
    xla::Literal::vec1(values)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

/// Extract an f32 vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> crate::Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))
}

/// Extract the single f32 of a scalar literal.
pub fn to_scalar_f32(lit: &xla::Literal) -> crate::Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow::anyhow!("scalar f32: {e:?}"))
}

/// Copy a literal's f32 payload into an existing buffer (no allocation).
pub fn copy_to_f32(lit: &xla::Literal, dst: &mut [f32]) -> crate::Result<()> {
    lit.copy_raw_to(dst)
        .map_err(|e| anyhow::anyhow!("copy_raw_to: {e:?}"))
}
