//! Real-thread asynchronous runtime — the paper's Algorithm 1 in Rust.
//!
//! Each of the `n` simulated cluster workers is a cell of two OS threads
//! sharing a locked `{x, x̃, t_last}` state, exactly as the paper stores
//! both buffers in shared memory so either process can update them at any
//! time:
//!
//! * the **gradient thread** computes mini-batch gradients back-to-back
//!   (through an AOT-compiled HLO executable via PJRT, or a pure-Rust
//!   model) and applies the fused mixing + SGD update;
//! * the **communication thread** draws its p2p budget from a Poisson law
//!   (mean = the configured com/∇ rate, the paper's emulation of the
//!   `M_t^ij` clocks), declares itself available to the
//!   [`coordinator`], and performs pairwise averagings in parallel with
//!   the gradient thread.
//!
//! The [`coordinator`] reproduces the paper's deadlock-free matching: a
//! FIFO availability queue pairing the first two mutually-adjacent
//! available workers (Sec. 4.1), with the pairing histogram of Fig. 7
//! recorded on the side. Matching runs batched by default (drain all
//! ready declarations per wake-up, match via per-worker ticket slots);
//! the original rendezvous-per-message protocol stays available through
//! [`coordinator::MatchStrategy`]. Time is wall-clock normalized by a running
//! average of gradient durations, as in the paper's implementation.

pub mod artifacts;
pub mod bus;
pub mod clock;
pub mod coordinator;
// The PJRT execution path needs the external `xla` wrapper crate, which
// is not available in the offline build image — gated behind the `pjrt`
// feature (see rust/Cargo.toml).
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub mod pjrt_grad;
pub mod serve;
pub mod snapshot;
pub mod worker;

pub use artifacts::{ArtifactMeta, Manifest};
pub use clock::TimeNormalizer;
pub use coordinator::{CoordMsg, MatchStrategy, PairReply, PairingStats};
pub use serve::ServeDaemon;
pub use snapshot::{ConsensusAccumulator, SnapshotCell};
pub use worker::{
    run_async, run_async_controlled, GradSource, RustGradSource, RuntimeOptions, RuntimeResult,
    ServeControl,
};
