//! The pairing coordinator — the paper's deadlock-free replacement for
//! AD-PSGD's pseudo-random bipartite schedule (Sec. 2, Sec. 4.1).
//!
//! Workers that are ready to communicate (finished their previous
//! averaging, still have budget before the next gradient step) declare
//! themselves *available*; the coordinator pairs an arriving worker with
//! the **earliest-declared** queued worker adjacent to it in the
//! *currently active* communication graph (the [`WallClock`] view — a
//! scenario may switch topologies or drop links mid-run). Only worker
//! *indices* flow through the coordinator — parameter payloads go
//! peer-to-peer over the [`super::bus`] — which is the paper's "the
//! coordinator only exchanges integers with the workers" lightweightness.
//!
//! Two interchangeable matching strategies ([`MatchStrategy`]) implement
//! that contract:
//!
//! * **Rendezvous** — the original protocol: one blocking channel
//!   receive per message, and each `Available` scans the whole FIFO
//!   queue probing `has_active_edge` (a read-lock each) per entry —
//!   O(queue) lock rounds per pairing.
//! * **Batched** (default) — drains every ready message per wake-up and
//!   matches over the active-neighbor *lists*: one adjacency read-lock
//!   per availability hands the full candidate set, and the queue is a
//!   per-worker slot array carrying arrival tickets, so "first queued
//!   adjacent worker" becomes "minimum ticket over `w`'s active
//!   neighbors" — O(deg) per pairing, one channel park per batch. At
//!   sub-ms pairing cadence this amortization is what keeps the
//!   coordinator off the critical path past dozens of workers (the
//!   GossipGraD / AD-PSGD lesson); the `perf` bench pins
//!   batched > rendezvous pairings/sec.
//!
//! Both strategies produce the same pairings for the same message order
//! (the tests below run every behavioral check against both).
//!
//! Liveness under a time-varying graph: a queued worker may transiently
//! have no active neighbor, so release-on-`None` can no longer be decided
//! from adjacency alone. Three mechanisms keep everyone live:
//!
//! * a worker whose entire *union-graph* neighborhood has permanently
//!   departed is released with [`PairReply::NoPartnerEver`] (no phase can
//!   ever supply it a partner again);
//! * a waiting worker may time out and send [`CoordMsg::Cancel`]; the
//!   coordinator acknowledges with [`PairReply::Cancelled`] if the worker
//!   was still queued — or the worker finds the pairing that raced ahead
//!   of its cancel in its reply mailbox and honors it;
//! * on [`CoordMsg::Reconfigure`] (a scenario update landed) the queue is
//!   re-scanned and waiters that just became adjacent are paired.

use std::collections::HashSet;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::engine::WallClock;
use crate::graph::Graph;

/// Messages from workers (and the monitor) to the coordinator.
pub enum CoordMsg {
    /// Worker is ready for one pairwise averaging; the coordinator replies
    /// on `reply` with a [`PairReply`].
    Available { worker: usize, reply: mpsc::Sender<PairReply> },
    /// Worker gave up waiting (budget re-check); acknowledged with
    /// [`PairReply::Cancelled`] unless a pairing raced ahead.
    Cancel { worker: usize },
    /// Worker permanently leaves (its training and budget are exhausted).
    Leave { worker: usize },
    /// The active network changed (scenario update): re-scan the queue.
    Reconfigure,
}

/// Coordinator's answer to an [`CoordMsg::Available`] declaration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairReply {
    /// Averaging partner assigned.
    Peer(usize),
    /// No partner can ever arrive again — stop communicating.
    NoPartnerEver,
    /// The pending availability was cancelled at the worker's request.
    Cancelled,
}

/// How the coordinator turns availability declarations into pairings.
/// See the module docs; both strategies implement the same
/// earliest-declared-adjacent-waiter contract.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MatchStrategy {
    /// One message per wake-up, one full FIFO-queue scan per
    /// `Available`. The original protocol, kept as the reference arm of
    /// the coordinator micro-bench.
    Rendezvous,
    /// Drain all ready messages per wake-up, match via per-worker
    /// ticket slots against the active-neighbor lists.
    #[default]
    Batched,
}

/// Pairing history: `counts[i][j]` = number of averagings between i and j
/// (symmetric). Rendered as the Fig. 7 heat-map.
#[derive(Clone, Debug)]
pub struct PairingStats {
    pub counts: Vec<Vec<u64>>,
    pub total: u64,
}

impl PairingStats {
    pub fn new(n: usize) -> Self {
        Self { counts: vec![vec![0; n]; n], total: 0 }
    }

    fn record(&mut self, i: usize, j: usize) {
        self.counts[i][j] += 1;
        self.counts[j][i] += 1;
        self.total += 1;
    }

    /// Per-worker totals.
    pub fn per_worker(&self) -> Vec<u64> {
        self.counts.iter().map(|row| row.iter().sum()).collect()
    }

    /// Coefficient of variation of the *edge* usage counts — the paper's
    /// uniform-neighbor-selection check (Fig. 7): small means pairing is
    /// close to uniform over the graph's edges.
    pub fn edge_uniformity_cv(&self, graph: &Graph) -> f64 {
        let counts: Vec<f64> = graph
            .edges
            .iter()
            .map(|&(i, j)| self.counts[i][j] as f64)
            .collect();
        if counts.is_empty() {
            return 0.0;
        }
        let mean = counts.iter().sum::<f64>() / counts.len() as f64;
        if mean == 0.0 {
            return 0.0;
        }
        let var =
            counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>() / counts.len() as f64;
        var.sqrt() / mean
    }

    /// Render an ASCII heat-map (Fig. 7).
    pub fn render_heatmap(&self) -> String {
        let n = self.counts.len();
        let max = self
            .counts
            .iter()
            .flatten()
            .cloned()
            .max()
            .unwrap_or(0)
            .max(1) as f64;
        const SHADES: [char; 5] = [' ', '.', ':', '*', '#'];
        let mut out = String::new();
        for i in 0..n {
            for j in 0..n {
                let frac = self.counts[i][j] as f64 / max;
                let idx = ((frac * (SHADES.len() - 1) as f64).round() as usize)
                    .min(SHADES.len() - 1);
                out.push(SHADES[idx]);
            }
            out.push('\n');
        }
        out
    }
}

/// Spawn the coordinator thread over the shared network view with the
/// default (batched) matching strategy. It exits (returning the pairing
/// stats) once every worker has sent [`CoordMsg::Leave`].
pub fn spawn_coordinator(
    net: Arc<WallClock>,
) -> (mpsc::Sender<CoordMsg>, JoinHandle<PairingStats>) {
    spawn_coordinator_with(net, MatchStrategy::default())
}

/// As [`spawn_coordinator`], with an explicit [`MatchStrategy`] (the
/// coordinator micro-bench races the two against each other).
pub fn spawn_coordinator_with(
    net: Arc<WallClock>,
    strategy: MatchStrategy,
) -> (mpsc::Sender<CoordMsg>, JoinHandle<PairingStats>) {
    let (tx, rx) = mpsc::channel::<CoordMsg>();
    let handle = std::thread::Builder::new()
        .name("a2cid2-coordinator".into())
        .spawn(move || match strategy {
            MatchStrategy::Rendezvous => rendezvous_loop(&net, rx),
            MatchStrategy::Batched => batched_loop(&net, rx),
        })
        .expect("spawn coordinator");
    (tx, handle)
}

/// The original rendezvous protocol: process one message per wake-up,
/// scanning the FIFO queue with per-entry `has_active_edge` probes.
fn rendezvous_loop(net: &WallClock, rx: mpsc::Receiver<CoordMsg>) -> PairingStats {
    let n = net.n();
    let mut stats = PairingStats::new(n);
    // FIFO availability queue: (worker, reply channel).
    let mut queue: Vec<(usize, mpsc::Sender<PairReply>)> = Vec::new();
    let mut left: HashSet<usize> = HashSet::new();

    while left.len() < n {
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => break, // all worker handles dropped
        };
        match msg {
            CoordMsg::Available { worker, reply } => {
                debug_assert!(!left.contains(&worker), "available after leave");
                // FIFO scan: pair with the first queued active neighbor.
                if let Some(pos) =
                    queue.iter().position(|(q, _)| net.has_active_edge(*q, worker))
                {
                    let (peer, peer_reply) = queue.remove(pos);
                    stats.record(worker, peer);
                    // Replies may fail if a worker died; ignore — the
                    // partner's bus send will surface the error.
                    let _ = peer_reply.send(PairReply::Peer(worker));
                    let _ = reply.send(PairReply::Peer(peer));
                } else if net.union_neighbors(worker).iter().all(|nb| left.contains(nb)) {
                    // No phase of the scenario can ever supply a partner.
                    let _ = reply.send(PairReply::NoPartnerEver);
                } else {
                    queue.push((worker, reply));
                }
            }
            CoordMsg::Cancel { worker } => {
                if let Some(pos) = queue.iter().position(|(q, _)| *q == worker) {
                    let (_, reply) = queue.remove(pos);
                    let _ = reply.send(PairReply::Cancelled);
                }
                // Not queued: a pairing raced ahead of the cancel; the
                // worker will find PairReply::Peer in its mailbox.
            }
            CoordMsg::Leave { worker } => {
                if !left.insert(worker) {
                    continue; // idempotent
                }
                queue.retain(|(q, _)| *q != worker);
                // Release waiters whose whole union neighborhood departed.
                let mut released = Vec::new();
                queue.retain(|(q, reply)| {
                    if net.union_neighbors(*q).iter().all(|nb| left.contains(nb)) {
                        released.push(reply.clone());
                        false
                    } else {
                        true
                    }
                });
                for r in released {
                    let _ = r.send(PairReply::NoPartnerEver);
                }
            }
            CoordMsg::Reconfigure => {
                // Worker churn: a scenario leave can land after a worker
                // announced availability. Release such waiters with
                // Cancelled — their comm thread re-checks membership and
                // parks — so a departed worker can never be paired.
                let mut churned = Vec::new();
                queue.retain(|(q, reply)| {
                    if net.is_active(*q) {
                        true
                    } else {
                        churned.push(reply.clone());
                        false
                    }
                });
                for r in churned {
                    let _ = r.send(PairReply::Cancelled);
                }
                // The active graph changed: greedily pair now-adjacent
                // waiters, FIFO order.
                let mut i = 0;
                while i < queue.len() {
                    let partner = (i + 1..queue.len())
                        .find(|&j| net.has_active_edge(queue[i].0, queue[j].0));
                    match partner {
                        Some(j) => {
                            let (b, b_reply) = queue.remove(j);
                            let (a, a_reply) = queue.remove(i);
                            stats.record(a, b);
                            let _ = a_reply.send(PairReply::Peer(b));
                            let _ = b_reply.send(PairReply::Peer(a));
                        }
                        None => i += 1,
                    }
                }
            }
        }
    }
    // Drain-safe shutdown: on EVERY exit path (all workers left, or the
    // channel closed) release still-queued waiters with a definitive
    // Cancelled instead of silently dropping their reply senders. The
    // worker side also maps a dropped sender to Stop, but an explicit
    // reply keeps the exit ordering deterministic — a parked worker
    // observes shutdown immediately, not whenever the drop propagates.
    for (_, reply) in queue.drain(..) {
        let _ = reply.send(PairReply::Cancelled);
    }
    stats
}

/// Per-worker waiting slots for the batched strategy. A worker has at
/// most one outstanding availability (its comm thread blocks on the
/// reply), so a slot array replaces the FIFO `Vec`; monotone arrival
/// tickets encode the FIFO order ("first queued adjacent worker" ≡
/// "minimum ticket among the arriver's queued active neighbors").
///
/// Alongside the slots, a ticket-ordered index of the *queued* workers:
/// the Leave/Reconfigure churn scans walk that index — O(waiters) — not
/// all n slots. At n = 10⁵ with a handful of waiters per churn event,
/// the old `0..n` sweeps were the coordinator's dominant cost.
struct WaitSlots {
    slots: Vec<Option<(u64, mpsc::Sender<PairReply>)>>,
    /// ticket → worker for every queued worker; iteration order is
    /// ticket-ascending, i.e. arrival (FIFO) order.
    queued: std::collections::BTreeMap<u64, usize>,
    next_ticket: u64,
}

impl WaitSlots {
    fn new(n: usize) -> Self {
        Self { slots: vec![None; n], queued: std::collections::BTreeMap::new(), next_ticket: 0 }
    }

    fn enqueue(&mut self, w: usize, reply: mpsc::Sender<PairReply>) {
        debug_assert!(self.slots[w].is_none(), "duplicate availability");
        self.slots[w] = Some((self.next_ticket, reply));
        self.queued.insert(self.next_ticket, w);
        self.next_ticket += 1;
    }

    fn take(&mut self, w: usize) -> Option<(u64, mpsc::Sender<PairReply>)> {
        let entry = self.slots[w].take();
        if let Some((t, _)) = &entry {
            self.queued.remove(t);
        }
        entry
    }

    fn ticket(&self, w: usize) -> Option<u64> {
        self.slots[w].as_ref().map(|(t, _)| *t)
    }

    /// Snapshot of the queued workers in arrival (ticket) order. A
    /// snapshot — not an iterator — so callers can `take` while walking.
    fn queued_in_arrival_order(&self) -> Vec<(u64, usize)> {
        self.queued.iter().map(|(&t, &w)| (t, w)).collect()
    }
}

/// The batched strategy: drain every ready message per wake-up, then
/// match each `Available` against the arriver's active-neighbor list in
/// one pass. Produces the same pairings as [`rendezvous_loop`] for the
/// same message order, at O(deg) instead of O(queue) per availability
/// and one channel park per batch instead of per message.
fn batched_loop(net: &WallClock, rx: mpsc::Receiver<CoordMsg>) -> PairingStats {
    let n = net.n();
    let mut stats = PairingStats::new(n);
    let mut waits = WaitSlots::new(n);
    let mut left: HashSet<usize> = HashSet::new();
    let mut batch: Vec<CoordMsg> = Vec::new();
    // Reused active-neighbor scratch (one adjacency lock per query).
    let mut nbuf: Vec<usize> = Vec::new();

    while left.len() < n {
        match rx.recv() {
            Ok(m) => batch.push(m),
            Err(_) => break, // all worker handles dropped
        }
        while let Ok(m) = rx.try_recv() {
            batch.push(m);
        }
        for msg in batch.drain(..) {
            match msg {
                CoordMsg::Available { worker, reply } => {
                    debug_assert!(!left.contains(&worker), "available after leave");
                    net.active_neighbors_into(worker, &mut nbuf);
                    // Earliest-ticket queued active neighbor == the
                    // rendezvous loop's first FIFO-scan hit.
                    let best = nbuf
                        .iter()
                        .filter_map(|&nb| waits.ticket(nb).map(|t| (t, nb)))
                        .min();
                    if let Some((_, peer)) = best {
                        let (_, peer_reply) =
                            waits.take(peer).expect("ticket implies queued");
                        stats.record(worker, peer);
                        // Replies may fail if a worker died; ignore — the
                        // partner's bus send will surface the error.
                        let _ = peer_reply.send(PairReply::Peer(worker));
                        let _ = reply.send(PairReply::Peer(peer));
                    } else if net.union_neighbors(worker).iter().all(|nb| left.contains(nb)) {
                        // No phase of the scenario can ever supply a partner.
                        let _ = reply.send(PairReply::NoPartnerEver);
                    } else {
                        waits.enqueue(worker, reply);
                    }
                }
                CoordMsg::Cancel { worker } => {
                    if let Some((_, reply)) = waits.take(worker) {
                        let _ = reply.send(PairReply::Cancelled);
                    }
                    // Not queued: a pairing raced ahead of the cancel; the
                    // worker will find PairReply::Peer in its mailbox.
                }
                CoordMsg::Leave { worker } => {
                    if !left.insert(worker) {
                        continue; // idempotent
                    }
                    let _ = waits.take(worker);
                    // Release waiters whose whole union neighborhood
                    // departed — only the queued set is scanned.
                    for (_, w) in waits.queued_in_arrival_order() {
                        if net.union_neighbors(w).iter().all(|nb| left.contains(nb)) {
                            let (_, reply) = waits.take(w).expect("queued snapshot");
                            let _ = reply.send(PairReply::NoPartnerEver);
                        }
                    }
                }
                CoordMsg::Reconfigure => {
                    // Worker churn: release scenario-departed waiters with
                    // Cancelled so they can never be paired. Only the
                    // queued set is scanned — O(waiters), not O(n).
                    for (_, w) in waits.queued_in_arrival_order() {
                        if !net.is_active(w) {
                            let (_, reply) = waits.take(w).expect("queued snapshot");
                            let _ = reply.send(PairReply::Cancelled);
                        }
                    }
                    // The active graph changed: greedily pair now-adjacent
                    // waiters in arrival order (the queued index is
                    // already ticket-ascending), each with its earliest-
                    // ticket LATER-queued active neighbor — exactly the
                    // rendezvous FIFO re-scan.
                    let order = waits.queued_in_arrival_order();
                    for &(t, w) in &order {
                        if waits.ticket(w) != Some(t) {
                            continue; // already matched earlier this pass
                        }
                        net.active_neighbors_into(w, &mut nbuf);
                        let partner = nbuf
                            .iter()
                            .filter_map(|&nb| {
                                waits
                                    .ticket(nb)
                                    .and_then(|tb| (tb > t).then_some((tb, nb)))
                            })
                            .min();
                        if let Some((_, b)) = partner {
                            let (_, a_reply) = waits.take(w).expect("iterating queued");
                            let (_, b_reply) = waits.take(b).expect("partner queued");
                            stats.record(w, b);
                            let _ = a_reply.send(PairReply::Peer(b));
                            let _ = b_reply.send(PairReply::Peer(w));
                        }
                    }
                }
            }
        }
    }
    // Drain-safe shutdown: same contract as the rendezvous loop — every
    // still-queued waiter gets a definitive Cancelled on coordinator
    // exit, never a silently dropped reply sender.
    for (_, w) in waits.queued_in_arrival_order() {
        let (_, reply) = waits.take(w).expect("queued snapshot");
        let _ = reply.send(PairReply::Cancelled);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;

    const BOTH: [MatchStrategy; 2] = [MatchStrategy::Rendezvous, MatchStrategy::Batched];

    fn ring(n: usize) -> Arc<WallClock> {
        Arc::new(WallClock::from_graph(
            &Graph::build(&Topology::Ring, n).unwrap(),
            1.0,
        ))
    }

    fn available(
        tx: &mpsc::Sender<CoordMsg>,
        worker: usize,
    ) -> mpsc::Receiver<PairReply> {
        let (rtx, rrx) = mpsc::channel();
        tx.send(CoordMsg::Available { worker, reply: rtx }).unwrap();
        rrx
    }

    #[test]
    fn coordinator_exit_releases_queued_waiters() {
        // A worker parked waiting for a pairing whose coordinator exits
        // (every channel sender dropped) must observe shutdown as a
        // definitive Cancelled reply — not a silently dropped sender.
        for strategy in BOTH {
            let (tx, handle) = spawn_coordinator_with(ring(4), strategy);
            let r0 = available(&tx, 0); // no partner: stays queued
            drop(tx); // coordinator's recv errors -> exit path
            assert_eq!(
                r0.recv_timeout(std::time::Duration::from_secs(5)).unwrap(),
                PairReply::Cancelled,
                "{strategy:?}"
            );
            handle.join().unwrap();
        }
    }

    #[test]
    fn adjacent_workers_get_paired_fifo() {
        for strategy in BOTH {
            let (tx, handle) = spawn_coordinator_with(ring(4), strategy);
            let r0 = available(&tx, 0);
            // 2 is not adjacent to 0 on the 4-ring: ring(4) = 0-1,1-2,2-3,0-3.
            let r2 = available(&tx, 2);
            // 1 is adjacent to both 0 and 2; FIFO pairs it with 0 (first).
            let r1 = available(&tx, 1);
            assert_eq!(r0.recv().unwrap(), PairReply::Peer(1), "{strategy:?}");
            assert_eq!(r1.recv().unwrap(), PairReply::Peer(0), "{strategy:?}");
            // 3 arrives, pairs with the waiting 2.
            let r3 = available(&tx, 3);
            assert_eq!(r2.recv().unwrap(), PairReply::Peer(3), "{strategy:?}");
            assert_eq!(r3.recv().unwrap(), PairReply::Peer(2), "{strategy:?}");
            for w in 0..4 {
                tx.send(CoordMsg::Leave { worker: w }).unwrap();
            }
            let stats = handle.join().unwrap();
            assert_eq!(stats.total, 2);
            assert_eq!(stats.counts[0][1], 1);
            assert_eq!(stats.counts[2][3], 1);
        }
    }

    #[test]
    fn never_pairs_non_neighbors() {
        for strategy in BOTH {
            let (tx, handle) = spawn_coordinator_with(ring(6), strategy);
            // 0 and 3 are not adjacent on the 6-ring: both must wait.
            let r0 = available(&tx, 0);
            let r3 = available(&tx, 3);
            assert!(r0.try_recv().is_err());
            assert!(r3.try_recv().is_err());
            // 1 pairs with 0 (not with 3).
            let r1 = available(&tx, 1);
            assert_eq!(r0.recv().unwrap(), PairReply::Peer(1), "{strategy:?}");
            assert_eq!(r1.recv().unwrap(), PairReply::Peer(0), "{strategy:?}");
            // 4 pairs with 3.
            let r4 = available(&tx, 4);
            assert_eq!(r3.recv().unwrap(), PairReply::Peer(4), "{strategy:?}");
            assert_eq!(r4.recv().unwrap(), PairReply::Peer(3), "{strategy:?}");
            for w in 0..6 {
                tx.send(CoordMsg::Leave { worker: w }).unwrap();
            }
            let stats = handle.join().unwrap();
            assert_eq!(stats.counts[0][3], 0);
        }
    }

    #[test]
    fn waiter_released_when_neighborhood_leaves() {
        for strategy in BOTH {
            let (tx, handle) = spawn_coordinator_with(ring(4), strategy);
            let r0 = available(&tx, 0);
            // 0's neighbors are 1 and 3; both leave → 0 gets NoPartnerEver.
            tx.send(CoordMsg::Leave { worker: 1 }).unwrap();
            tx.send(CoordMsg::Leave { worker: 3 }).unwrap();
            assert_eq!(r0.recv().unwrap(), PairReply::NoPartnerEver, "{strategy:?}");
            tx.send(CoordMsg::Leave { worker: 0 }).unwrap();
            tx.send(CoordMsg::Leave { worker: 2 }).unwrap();
            handle.join().unwrap();
        }
    }

    #[test]
    fn available_with_all_neighbors_gone_returns_none_immediately() {
        for strategy in BOTH {
            let (tx, handle) = spawn_coordinator_with(ring(4), strategy);
            tx.send(CoordMsg::Leave { worker: 1 }).unwrap();
            tx.send(CoordMsg::Leave { worker: 3 }).unwrap();
            let r0 = available(&tx, 0);
            assert_eq!(r0.recv().unwrap(), PairReply::NoPartnerEver, "{strategy:?}");
            tx.send(CoordMsg::Leave { worker: 0 }).unwrap();
            tx.send(CoordMsg::Leave { worker: 2 }).unwrap();
            handle.join().unwrap();
        }
    }

    #[test]
    fn leave_is_idempotent_and_terminates() {
        for strategy in BOTH {
            let (tx, handle) = spawn_coordinator_with(ring(3), strategy);
            for _ in 0..3 {
                for w in 0..3 {
                    tx.send(CoordMsg::Leave { worker: w }).unwrap();
                }
            }
            let stats = handle.join().unwrap();
            assert_eq!(stats.total, 0);
        }
    }

    #[test]
    fn cancel_removes_a_waiter() {
        for strategy in BOTH {
            let (tx, handle) = spawn_coordinator_with(ring(6), strategy);
            let r0 = available(&tx, 0);
            tx.send(CoordMsg::Cancel { worker: 0 }).unwrap();
            assert_eq!(r0.recv().unwrap(), PairReply::Cancelled, "{strategy:?}");
            // 1 arrives later: 0 is no longer queued, so 1 must wait.
            let r1 = available(&tx, 1);
            assert!(r1.try_recv().is_err());
            // Cancel for a non-queued worker is a no-op.
            tx.send(CoordMsg::Cancel { worker: 5 }).unwrap();
            for w in 0..6 {
                tx.send(CoordMsg::Leave { worker: w }).unwrap();
            }
            let stats = handle.join().unwrap();
            assert_eq!(stats.total, 0);
        }
    }

    #[test]
    fn reconfigure_pairs_newly_adjacent_waiters() {
        // Scenario: ring(6) phase-0, complete graph after the switch. 0
        // and 3 wait (not ring-adjacent); the switch makes them adjacent
        // and Reconfigure pairs them.
        for strategy in BOTH {
            let plan = crate::config::Scenario::parse("ring@0,complete@0.5")
                .unwrap()
                .compile(6, 1.0, 10.0, &[1.0; 6])
                .unwrap();
            let net = Arc::new(WallClock::new(&plan));
            let (tx, handle) = spawn_coordinator_with(net.clone(), strategy);
            let r0 = available(&tx, 0);
            let r3 = available(&tx, 3);
            assert!(r0.try_recv().is_err());
            tx.send(CoordMsg::Reconfigure).unwrap(); // no change yet
            assert!(r0.try_recv().is_err());
            net.apply_shared(&plan.updates[0]);
            tx.send(CoordMsg::Reconfigure).unwrap();
            assert_eq!(r0.recv().unwrap(), PairReply::Peer(3), "{strategy:?}");
            assert_eq!(r3.recv().unwrap(), PairReply::Peer(0), "{strategy:?}");
            for w in 0..6 {
                tx.send(CoordMsg::Leave { worker: w }).unwrap();
            }
            let stats = handle.join().unwrap();
            assert_eq!(stats.counts[0][3], 1);
        }
    }

    #[test]
    fn reconfigure_releases_churn_departed_waiters() {
        // Worker 0 queues, then a scenario leave removes it; the next
        // Reconfigure must hand it Cancelled (never a peer), and its
        // now-silent links must not pair it with arriving neighbors.
        for strategy in BOTH {
            let plan = crate::config::Scenario::parse("ring@0;leave=0.25:0.5:1")
                .unwrap()
                .compile(4, 1.0, 10.0, &[1.0; 4])
                .unwrap();
            let net = Arc::new(WallClock::new(&plan));
            let leaver = plan.updates[0].leave[0];
            let (tx, handle) = spawn_coordinator_with(net.clone(), strategy);
            let r = available(&tx, leaver);
            net.apply_shared(&plan.updates[0]);
            tx.send(CoordMsg::Reconfigure).unwrap();
            assert_eq!(r.recv().unwrap(), PairReply::Cancelled, "{strategy:?}");
            // A neighbor arriving now cannot be paired with the departed
            // worker (no active edge) — it waits instead.
            let nb = (0..4).find(|&w| w != leaver && net.is_active(w)).unwrap();
            let rn = available(&tx, nb);
            assert!(rn.try_recv().is_err());
            for w in 0..4 {
                tx.send(CoordMsg::Leave { worker: w }).unwrap();
            }
            let stats = handle.join().unwrap();
            assert_eq!(stats.per_worker()[leaver], 0);
        }
    }

    #[test]
    fn reconfigure_rematch_respects_fifo_order_on_a_batch() {
        // Four waiters queue before the complete-graph switch; the
        // re-scan must pair (first, second) and (third, fourth) — FIFO,
        // not best-degree — under BOTH strategies.
        for strategy in BOTH {
            let plan = crate::config::Scenario::parse("ring@0,complete@0.5")
                .unwrap()
                .compile(6, 1.0, 10.0, &[1.0; 6])
                .unwrap();
            let net = Arc::new(WallClock::new(&plan));
            let (tx, handle) = spawn_coordinator_with(net.clone(), strategy);
            // None of 0, 2, 4 are ring(6)-adjacent; 3 is adjacent to 2
            // and 4 but queues AFTER them.
            let r0 = available(&tx, 0);
            let r2 = available(&tx, 2);
            let r4 = available(&tx, 4);
            assert!(r0.try_recv().is_err());
            net.apply_shared(&plan.updates[0]);
            tx.send(CoordMsg::Reconfigure).unwrap();
            // FIFO re-scan on the complete graph: 0 pairs with 2 (the
            // earliest later waiter), leaving 4 queued.
            assert_eq!(r0.recv().unwrap(), PairReply::Peer(2), "{strategy:?}");
            assert_eq!(r2.recv().unwrap(), PairReply::Peer(0), "{strategy:?}");
            assert!(r4.try_recv().is_err());
            let r5 = available(&tx, 5);
            assert_eq!(r4.recv().unwrap(), PairReply::Peer(5), "{strategy:?}");
            assert_eq!(r5.recv().unwrap(), PairReply::Peer(4), "{strategy:?}");
            for w in 0..6 {
                tx.send(CoordMsg::Leave { worker: w }).unwrap();
            }
            let stats = handle.join().unwrap();
            assert_eq!(stats.total, 2);
            assert_eq!(stats.counts[0][2], 1);
            assert_eq!(stats.counts[4][5], 1);
        }
    }

    #[test]
    fn heatmap_and_uniformity() {
        let g = Graph::build(&Topology::Ring, 4).unwrap();
        let mut stats = PairingStats::new(4);
        for _ in 0..10 {
            stats.record(0, 1);
            stats.record(1, 2);
            stats.record(2, 3);
            stats.record(0, 3);
        }
        assert_eq!(stats.total, 40);
        assert!(stats.edge_uniformity_cv(&g) < 1e-9, "perfectly uniform");
        let art = stats.render_heatmap();
        assert_eq!(art.lines().count(), 4);
        assert!(art.contains('#'));
        // Skewed usage has larger CV.
        stats.record(0, 1);
        stats.record(0, 1);
        assert!(stats.edge_uniformity_cv(&g) > 0.0);
        // Row 0: 12 pairings with 1 + 10 with 3.
        assert_eq!(stats.per_worker()[0], 22);
    }
}
