//! PJRT-backed gradient sources: the request-path compute runs through
//! the AOT-compiled HLO artifacts (L2 model + L1 kernel), Python-free.

use std::sync::Arc;

use crate::data::{Dataset, MarkovCorpus};
use crate::rng::Xoshiro256;
use crate::runtime::pjrt::{
    copy_to_f32, lit_f32, lit_f32_matrix, lit_i32_matrix, to_scalar_f32, Executable,
};
use crate::runtime::worker::GradSource;

/// Gradient source over the `mlp_grad` artifact:
/// `(x, batch_x f32[B,D], batch_y i32[B]) -> (loss, grad)`.
/// (`batch_y` is lowered as a `[B]` vector; reshape handles it.)
pub struct MlpPjrtGradSource {
    exe: Executable,
    dataset: Arc<Dataset>,
    shard: Vec<usize>,
    batch: usize,
    dim: usize,
    cursor: usize,
    rng: Xoshiro256,
    xs: Vec<f32>,
    ys: Vec<i32>,
}

impl MlpPjrtGradSource {
    pub fn new(
        exe: Executable,
        dataset: Arc<Dataset>,
        shard: Vec<usize>,
        batch: usize,
        param_dim: usize,
        seed: u64,
    ) -> Self {
        assert!(!shard.is_empty());
        Self {
            exe,
            dataset,
            shard,
            batch,
            dim: param_dim,
            cursor: 0,
            rng: Xoshiro256::seed_from_u64(seed),
            xs: Vec::new(),
            ys: Vec::new(),
        }
    }
}

impl GradSource for MlpPjrtGradSource {
    fn dim(&self) -> usize {
        self.dim
    }

    fn grad(&mut self, x: &[f32], out: &mut [f32]) -> crate::Result<f32> {
        self.xs.clear();
        self.ys.clear();
        for _ in 0..self.batch {
            let jump = self.rng.gen_range(3);
            self.cursor = (self.cursor + 1 + jump) % self.shard.len();
            let (feat, label) = self.dataset.example(self.shard[self.cursor]);
            self.xs.extend_from_slice(feat);
            self.ys.push(label as i32);
        }
        let lx = lit_f32(x);
        let lb = lit_f32_matrix(&self.xs, self.batch, self.dataset.dim)?;
        let ly = xla::Literal::vec1(&self.ys);
        let outs = self.exe.run(&[lx, lb, ly])?;
        anyhow::ensure!(outs.len() == 2, "mlp_grad returns (loss, grad)");
        let loss = to_scalar_f32(&outs[0])?;
        copy_to_f32(&outs[1], out)?;
        Ok(loss)
    }
}

/// Gradient source over the `transformer_grad` artifact:
/// `(x, tokens i32[B,S], targets i32[B,S]) -> (loss, grad)`.
pub struct LmPjrtGradSource {
    exe: Executable,
    corpus: Arc<MarkovCorpus>,
    batch: usize,
    seq: usize,
    dim: usize,
    rng: Xoshiro256,
    toks: Vec<u32>,
    tgts: Vec<u32>,
}

impl LmPjrtGradSource {
    pub fn new(
        exe: Executable,
        corpus: Arc<MarkovCorpus>,
        batch: usize,
        seq: usize,
        param_dim: usize,
        seed: u64,
    ) -> Self {
        Self {
            exe,
            corpus,
            batch,
            seq,
            dim: param_dim,
            rng: Xoshiro256::seed_from_u64(seed),
            toks: Vec::new(),
            tgts: Vec::new(),
        }
    }
}

impl GradSource for LmPjrtGradSource {
    fn dim(&self) -> usize {
        self.dim
    }

    fn grad(&mut self, x: &[f32], out: &mut [f32]) -> crate::Result<f32> {
        self.corpus.sample_batch(
            self.batch,
            self.seq,
            &mut self.rng,
            &mut self.toks,
            &mut self.tgts,
        );
        let to_i32 = |v: &[u32]| -> Vec<i32> { v.iter().map(|&t| t as i32).collect() };
        let lx = lit_f32(x);
        let lt = lit_i32_matrix(&to_i32(&self.toks), self.batch, self.seq)?;
        let lg = lit_i32_matrix(&to_i32(&self.tgts), self.batch, self.seq)?;
        let outs = self.exe.run(&[lx, lt, lg])?;
        anyhow::ensure!(outs.len() == 2, "transformer_grad returns (loss, grad)");
        let loss = to_scalar_f32(&outs[0])?;
        copy_to_f32(&outs[1], out)?;
        Ok(loss)
    }
}
