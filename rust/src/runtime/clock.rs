//! Time normalization: wall clock → the paper's unit of "one gradient
//! computation".
//!
//! The theoretical analysis normalizes time so each worker computes one
//! mini-batch per unit time (Assumption 3.2); the implementation applies
//! the A²CiD² mixing with *real* elapsed time, so the paper "maintains a
//! running average measure of the duration of the previous gradient steps
//! to normalize time" (Sec. 4.1). This is that running average.

use std::time::Instant;

/// Exponential running average of gradient durations, converting wall
/// seconds into gradient-time units.
#[derive(Debug)]
pub struct TimeNormalizer {
    start: Instant,
    /// EMA of gradient duration in seconds.
    avg_grad_secs: f64,
    /// EMA smoothing (per sample).
    beta: f64,
    initialized: bool,
}

impl TimeNormalizer {
    /// `initial_guess_secs` seeds the average before the first gradient
    /// completes (any positive value; it washes out quickly).
    pub fn new(initial_guess_secs: f64) -> Self {
        Self {
            start: Instant::now(),
            avg_grad_secs: initial_guess_secs.max(1e-9),
            beta: 0.9,
            initialized: false,
        }
    }

    /// Record one observed gradient duration.
    pub fn record_grad(&mut self, secs: f64) {
        let secs = secs.max(1e-9);
        if self.initialized {
            self.avg_grad_secs = self.beta * self.avg_grad_secs + (1.0 - self.beta) * secs;
        } else {
            self.avg_grad_secs = secs;
            self.initialized = true;
        }
    }

    /// Current time in gradient units.
    pub fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64() / self.avg_grad_secs
    }

    /// Convert a wall duration to gradient units.
    pub fn to_units(&self, secs: f64) -> f64 {
        secs / self.avg_grad_secs
    }

    /// The current average gradient duration estimate (seconds).
    pub fn avg_grad_secs(&self) -> f64 {
        self.avg_grad_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_sample_replaces_guess() {
        let mut tn = TimeNormalizer::new(100.0);
        tn.record_grad(0.1);
        assert!((tn.avg_grad_secs() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn ema_tracks_changes() {
        let mut tn = TimeNormalizer::new(1.0);
        for _ in 0..100 {
            tn.record_grad(0.2);
        }
        assert!((tn.avg_grad_secs() - 0.2).abs() < 1e-6);
        for _ in 0..100 {
            tn.record_grad(0.4);
        }
        assert!((tn.avg_grad_secs() - 0.4).abs() < 0.01);
    }

    #[test]
    fn units_conversion() {
        let mut tn = TimeNormalizer::new(1.0);
        tn.record_grad(0.5);
        assert!((tn.to_units(1.0) - 2.0).abs() < 1e-9);
        assert!(tn.now() >= 0.0);
    }
}
