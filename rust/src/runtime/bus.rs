//! In-process p2p message bus — the stand-in for the paper's NCCL/Gloo
//! point-to-point sends over Omni-Path (DESIGN.md §3).
//!
//! Each worker owns one inbox; a pairing exchanges exactly one parameter
//! buffer in each direction. An optional injected per-link delay models
//! constrained bandwidth so topology effects stay observable in wall
//! time.
//!
//! Buffer life cycle (§Perf, zero steady-state allocation): the payload
//! is produced by `mix_into` — the sender's momentum-mixed `x` computed
//! directly into the buffer, never a copy of live state — and ownership
//! moves through the channel; the receiver consumes it in the fused
//! `comm_apply` pass and then reuses the very same allocation as its
//! *next* outgoing buffer. After each side's first pairing, no
//! parameter-sized buffer is ever allocated or copied on the
//! communication path beyond the seqlock publish that keeps readers
//! lock-free (the mpsc channel and the coordinator round-trip still
//! make their own small bookkeeping allocations).

use std::sync::mpsc;
use std::time::Duration;

use crate::gossip::AcidParams;

/// One half of a pairwise exchange.
pub struct PairMsg {
    pub from: usize,
    /// The sender's parameters, momentum-mixed to the sender's event time
    /// (built by `mix_into`; the sender's own state is untouched until
    /// its receive-side `comm_apply` pass).
    pub data: Vec<f32>,
    /// The sender's (η, α, α̃) snapshot and its publish epoch. Both
    /// endpoints of one pairing must average with the SAME (α, α̃) or
    /// the pair mean drifts; when an adaptive retune lands mid-match the
    /// two sides deterministically agree on the *older* snapshot (the
    /// smaller epoch — see `comm_loop`).
    pub acid: AcidParams,
    pub acid_epoch: u64,
}

impl PairMsg {
    /// Parameter dimension carried by this message.
    pub fn dim(&self) -> usize {
        self.data.len()
    }
}

/// Sender side of the bus (cloneable, one per worker thread).
#[derive(Clone)]
pub struct BusHandle {
    senders: Vec<mpsc::Sender<PairMsg>>,
    /// Simulated link transfer delay applied before each send.
    pub link_delay: Option<Duration>,
}

impl BusHandle {
    /// Send `data` to worker `to`. Blocks for the injected link delay
    /// (models transfer time on the sender's comm thread, which is
    /// exactly where the paper's implementation pays it).
    pub fn send(&self, to: usize, msg: PairMsg) -> crate::Result<()> {
        if let Some(d) = self.link_delay {
            std::thread::sleep(d);
        }
        self.senders[to]
            .send(msg)
            .map_err(|_| anyhow::anyhow!("worker {to} inbox closed"))
    }

    pub fn n_workers(&self) -> usize {
        self.senders.len()
    }
}

/// Build the bus: a cloneable handle plus one inbox receiver per worker.
pub fn build_bus(
    n: usize,
    link_delay: Option<Duration>,
) -> (BusHandle, Vec<mpsc::Receiver<PairMsg>>) {
    let mut senders = Vec::with_capacity(n);
    let mut receivers = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = mpsc::channel();
        senders.push(tx);
        receivers.push(rx);
    }
    (BusHandle { senders, link_delay }, receivers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_to_point_delivery() {
        let (bus, rxs) = build_bus(3, None);
        bus.send(2, PairMsg { from: 0, data: vec![1.0, 2.0], acid: AcidParams::baseline(), acid_epoch: 0 }).unwrap();
        bus.send(2, PairMsg { from: 1, data: vec![3.0], acid: AcidParams::baseline(), acid_epoch: 0 }).unwrap();
        let m1 = rxs[2].recv().unwrap();
        let m2 = rxs[2].recv().unwrap();
        assert_eq!(m1.from, 0);
        assert_eq!(m1.data, vec![1.0, 2.0]);
        assert_eq!(m2.from, 1);
        assert!(rxs[0].try_recv().is_err(), "no cross-talk");
    }

    #[test]
    fn cross_thread_exchange() {
        let (bus, mut rxs) = build_bus(2, None);
        let rx1 = rxs.pop().unwrap();
        let rx0 = rxs.pop().unwrap();
        let bus2 = bus.clone();
        let h = std::thread::spawn(move || {
            bus2.send(0, PairMsg { from: 1, data: vec![7.0], acid: AcidParams::baseline(), acid_epoch: 0 }).unwrap();
            rx1.recv().unwrap().data
        });
        bus.send(1, PairMsg { from: 0, data: vec![9.0], acid: AcidParams::baseline(), acid_epoch: 0 }).unwrap();
        let got0 = rx0.recv().unwrap().data;
        let got1 = h.join().unwrap();
        assert_eq!(got0, vec![7.0]);
        assert_eq!(got1, vec![9.0]);
    }

    #[test]
    fn link_delay_is_applied() {
        let (bus, rxs) = build_bus(2, Some(Duration::from_millis(20)));
        let t0 = std::time::Instant::now();
        bus.send(1, PairMsg { from: 0, data: vec![], acid: AcidParams::baseline(), acid_epoch: 0 }).unwrap();
        rxs[1].recv().unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(18));
    }
}
