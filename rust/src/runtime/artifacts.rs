//! Artifact manifest: discovery and metadata for the AOT-compiled HLO
//! modules produced by `python/compile/aot.py`.
//!
//! Format (one artifact per line, `#` comments):
//! `name key=value key=value ...` — hand-rolled because serde is not
//! reachable offline, and deliberately trivial to parse from any language.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Metadata of one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// File name inside the artifact directory.
    pub file: String,
    /// `train_step`, `grad`, `eval`, `comm_step`, `init`, `kernel_*`.
    pub kind: String,
    pub fields: BTreeMap<String, String>,
}

impl ArtifactMeta {
    /// Typed accessor for an integer field.
    pub fn int(&self, key: &str) -> crate::Result<i64> {
        self.fields
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("artifact '{}' missing field '{key}'", self.name))?
            .parse()
            .map_err(|e| anyhow::anyhow!("artifact '{}' field '{key}': {e}", self.name))
    }

    /// Parameter dimension (present on all model/kernel artifacts).
    pub fn param_dim(&self) -> crate::Result<usize> {
        Ok(self.int("param_dim")? as usize)
    }
}

/// The parsed manifest plus its base directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> crate::Result<Manifest> {
        let mut artifacts = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let name = parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("line {}: empty", lineno + 1))?
                .to_string();
            let mut fields = BTreeMap::new();
            for kv in parts {
                let (k, v) = kv.split_once('=').ok_or_else(|| {
                    anyhow::anyhow!("line {}: expected key=value, got '{kv}'", lineno + 1)
                })?;
                fields.insert(k.to_string(), v.to_string());
            }
            let file = fields
                .get("file")
                .ok_or_else(|| anyhow::anyhow!("artifact '{name}' missing file="))?
                .clone();
            let kind = fields.get("kind").cloned().unwrap_or_default();
            artifacts.push(ArtifactMeta { name, file, kind, fields });
        }
        anyhow::ensure!(!artifacts.is_empty(), "manifest is empty");
        Ok(Manifest { dir, artifacts })
    }

    /// Find an artifact by exact name.
    pub fn get(&self, name: &str) -> crate::Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "artifact '{name}' not in manifest (have: {})",
                    self.artifacts
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    /// Absolute path of an artifact's file.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Load a `<model>_init.bin` raw f32 parameter vector.
    pub fn load_init(&self, model: &str) -> crate::Result<Vec<f32>> {
        let meta = self.get(&format!("{model}_init"))?;
        let bytes = std::fs::read(self.path_of(meta))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "init file not a multiple of 4 bytes");
        let params: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let expected = meta.param_dim()?;
        anyhow::ensure!(
            params.len() == expected,
            "init has {} params, manifest says {expected}",
            params.len()
        );
        Ok(params)
    }
}

/// Write `contents` to `path` atomically: write a uniquely named staging
/// sibling, then rename it over the destination. Readers — CI's artifact
/// upload, a plotter watching `BENCH_*.json`, a daemon client polling a
/// checkpoint — never observe a half-written file, and a crash mid-write
/// leaves the previous artifact intact.
///
/// The staging name embeds the process id and a process-wide counter.
/// The historical fixed `.tmp` sibling raced concurrent writers of the
/// same destination: writer A's staging file could be overwritten by
/// writer B mid-write and then renamed by A, publishing B's torn bytes
/// under A's rename — or removed out from under B entirely. With unique
/// staging names each rename publishes exactly the bytes its own writer
/// staged; last rename wins, every observable state is some writer's
/// complete payload.
pub fn write_atomic(path: &Path, contents: &[u8]) -> crate::Result<()> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static STAGING_SEQ: AtomicU64 = AtomicU64::new(0);

    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(format!(
        ".{}.{}.tmp",
        std::process::id(),
        STAGING_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let tmp = PathBuf::from(tmp);
    if let Err(e) = std::fs::write(&tmp, contents) {
        // A failed write must not leave a partial staging file behind
        // (ENOSPC can fail after creating the file).
        let _ = std::fs::remove_file(&tmp);
        anyhow::bail!("writing {}: {e}", tmp.display());
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        // A failed rename must not leave the half-artifact sibling
        // behind (a watcher globbing staging files, or a directory
        // cleanup, would trip over it).
        let _ = std::fs::remove_file(&tmp);
        anyhow::bail!("renaming {} over {}: {e}", tmp.display(), path.display());
    }
    Ok(())
}

/// Locate the artifact directory: `$A2CID2_ARTIFACTS` or `./artifacts`
/// relative to the crate root / current dir.
pub fn default_artifact_dir() -> PathBuf {
    if let Some(dir) = &crate::config::env::knobs().artifacts_dir {
        return PathBuf::from(dir);
    }
    for base in [".", env!("CARGO_MANIFEST_DIR")] {
        let p = Path::new(base).join("artifacts");
        if p.join("manifest.txt").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment line
mlp_train_step file=mlp_train_step.hlo.txt kind=train_step model=mlp param_dim=2762 batch=16
mlp_init file=mlp_init.bin kind=init model=mlp param_dim=4 seed=0
";

    #[test]
    fn parse_and_lookup() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("mlp_train_step").unwrap();
        assert_eq!(a.kind, "train_step");
        assert_eq!(a.param_dim().unwrap(), 2762);
        assert_eq!(a.int("batch").unwrap(), 16);
        assert!(a.int("missing").is_err());
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("x novalue\n", PathBuf::new()).is_err());
        assert!(Manifest::parse("# only comments\n", PathBuf::new()).is_err());
        assert!(Manifest::parse("x kind=grad\n", PathBuf::new()).is_err(), "missing file=");
    }

    /// Staging files left anywhere under `dir` (any name containing
    /// ".tmp" — the unique staging names all end with it).
    fn staging_files(dir: &Path) -> Vec<String> {
        std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".tmp"))
            .collect()
    }

    #[test]
    fn write_atomic_replaces_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("a2cid2_write_atomic_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        write_atomic(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        write_atomic(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        assert!(staging_files(&dir).is_empty(), "{:?}", staging_files(&dir));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn write_atomic_concurrent_writers_never_publish_torn_bytes() {
        // The bugfix regression test: many threads hammer the SAME
        // destination with distinct self-consistent payloads. Under the
        // old fixed `.tmp` staging name a reader could observe a mix of
        // two writers' bytes (writer A renames the file writer B is
        // mid-way through rewriting); with unique staging names every
        // read must be exactly one writer's complete payload.
        let dir = std::env::temp_dir().join(format!(
            "a2cid2_write_atomic_race_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("contended.bin");
        // Payloads are constant-filled and length-tagged so any splice
        // of two writers is detectable.
        let payload = |w: u8| vec![w; 4096 + w as usize];
        write_atomic(&path, &payload(1)).unwrap();

        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut writers = Vec::new();
        for w in 1..=4u8 {
            let path = path.clone();
            let stop = stop.clone();
            writers.push(std::thread::spawn(move || {
                let mut n = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    write_atomic(&path, &payload(w)).unwrap();
                    n += 1;
                }
                n
            }));
        }
        let reader = {
            let path = path.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut reads = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let bytes = std::fs::read(&path).unwrap();
                    let w = bytes[0];
                    assert!((1..=4).contains(&w), "unknown writer tag {w}");
                    assert_eq!(bytes.len(), 4096 + w as usize, "torn length");
                    assert!(bytes.iter().all(|&b| b == w), "spliced payload");
                    reads += 1;
                }
                reads
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(200));
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        let writes: u64 = writers.into_iter().map(|h| h.join().unwrap()).sum();
        let reads = reader.join().unwrap();
        assert!(writes > 20, "writers made progress: {writes}");
        assert!(reads > 20, "reader made progress: {reads}");
        assert!(staging_files(&dir).is_empty(), "{:?}", staging_files(&dir));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn write_atomic_parent_is_a_file_errors_without_droppings() {
        let dir = std::env::temp_dir().join("a2cid2_write_atomic_err_parent");
        std::fs::create_dir_all(&dir).unwrap();
        let blocker = dir.join("blocker");
        std::fs::write(&blocker, b"i am a file").unwrap();
        // The destination's parent is a regular file: create_dir_all (or
        // the write) must fail, and the error must surface.
        let err = write_atomic(&blocker.join("sub/out.json"), b"data").unwrap_err();
        let msg = format!("{err:#}");
        assert!(!msg.is_empty());
        assert_eq!(std::fs::read(&blocker).unwrap(), b"i am a file", "blocker untouched");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn write_atomic_failed_rename_cleans_tmp_and_keeps_destination() {
        let dir = std::env::temp_dir().join("a2cid2_write_atomic_err_rename");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        // Destination is a non-empty DIRECTORY: the tmp write succeeds
        // but the file-over-directory rename cannot.
        let dest = dir.join("out.json");
        std::fs::create_dir_all(dest.join("occupied")).unwrap();
        let err = write_atomic(&dest, b"data").unwrap_err();
        assert!(format!("{err:#}").contains("renaming"), "{err:#}");
        assert!(dest.is_dir(), "destination left intact");
        assert!(
            staging_files(&dir).is_empty(),
            "failed rename must not leave staging siblings behind: {:?}",
            staging_files(&dir)
        );
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn init_round_trip() {
        let dir = std::env::temp_dir().join("a2cid2_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let values = [1.0f32, -2.5, 3.25, 0.0];
        let bytes: Vec<u8> = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("mlp_init.bin"), bytes).unwrap();
        let m = Manifest::parse(SAMPLE, dir.clone()).unwrap();
        let params = m.load_init("mlp").unwrap();
        assert_eq!(params, values);
        std::fs::remove_dir_all(dir).ok();
    }
}
