//! Sparse-Laplacian eigenestimation for hierarchical fleets where the
//! dense Jacobi path (O(n³)) stops being an option.
//!
//! The operator is the rate-weighted Laplacian Λ applied in O(|ℰ|) per
//! matvec straight off the edge list — no dense matrix is ever formed.
//! The all-ones kernel is deflated explicitly (every iterate is kept
//! orthogonal to the constant vector), and two regimes cover the scale
//! axis:
//!
//! * **exact** (`max_pairs ≥ n−1`): restarted Lanczos with full
//!   reorthogonalization runs until all n−1 deflated eigenpairs are
//!   resolved. The restart — a fresh random vector deflated against every
//!   resolved Ritz vector — is what recovers degenerate eigenvalues (ring
//!   and torus spectra are full of multiplicity-2 pairs, which a single
//!   Krylov sequence can only surface once). Eigenpairs come out at near
//!   machine precision, so effective resistances match the dense
//!   `sym_pinv` route within the 1e-6 relative property gate.
//! * **truncated** (`max_pairs < n−1`): λ₂ comes from *inverse* Lanczos —
//!   Lanczos on Λ⁺ with each operator apply a deflated conjugate-gradient
//!   solve — because the low end of a big Laplacian spectrum is clustered
//!   (ring-like modes are quadratically spaced) and plain Lanczos would
//!   need thousands of iterations there, while 1/λ₂ is well separated in
//!   the inverse spectrum. χ₂'s `max` effective resistance is evaluated
//!   *exactly* (to CG tolerance) on a candidate edge set — truncating the
//!   spectral sum is hopeless when every one of n−1 modes contributes
//!   equally, as on rings — and λ_max comes from a cheap values-only
//!   Lanczos sweep. The candidate heuristic (lowest-rate edges, the
//!   slow-mode ranking, a deterministic stride sample) can in principle
//!   miss the true argmax edge, so the truncated χ₂ is a documented
//!   lower-bound estimate.

use crate::rng::{standard_normal, Xoshiro256};

use super::{dot, norm2, sym_eig, Matrix};

/// Tuning knobs for the estimators.
#[derive(Clone, Copy, Debug)]
pub struct LanczosOptions {
    /// Ritz-pair budget. `≥ n−1` selects exact mode.
    pub max_pairs: usize,
    /// Seed of the deterministic start vectors (fixed default so repeated
    /// estimates of one graph are bit-identical).
    pub seed: u64,
}

impl Default for LanczosOptions {
    fn default() -> Self {
        LanczosOptions { max_pairs: usize::MAX, seed: 0x51C2_A7E3 }
    }
}

impl LanczosOptions {
    /// Budget scaled to the fleet: exact below [`DENSE_EXACT_LIMIT`]
    /// nodes, truncated (inverse-Lanczos) above it.
    pub fn sized_for(n: usize) -> LanczosOptions {
        let max_pairs =
            if n <= DENSE_EXACT_LIMIT { n.saturating_sub(1) } else { TRUNCATED_PAIRS };
        LanczosOptions { max_pairs, ..LanczosOptions::default() }
    }
}

/// Below this node count [`LanczosOptions::sized_for`] runs exact mode.
pub const DENSE_EXACT_LIMIT: usize = 512;

/// Low-end pairs resolved in truncated mode — enough for λ₂ plus the
/// slow-mode edge ranking that seeds the χ₂ candidates.
const TRUNCATED_PAIRS: usize = 16;

/// Candidate edges whose resistance is CG-solved exactly in truncated χ₂.
const CHI2_CANDIDATES: usize = 32;

/// Spectral summary from the sparse path, the estimator-side mirror of
/// `graph::Spectrum` (the caller adds χ₁ = 1/λ₂ and the trace, which is
/// 2·Σ rates without any eigensolve).
#[derive(Clone, Copy, Debug)]
pub struct SparseSpectrum {
    pub lambda2: f64,
    pub lambda_max: f64,
    /// `max` effective resistance over the probed edges (χ₂ = half this).
    pub max_resistance: f64,
    /// True when the full deflated spectrum was resolved (small n).
    pub exact: bool,
}

/// Eigenpairs of a rate-weighted Laplacian restricted to the complement
/// of the all-ones kernel (exact mode output; truncated mode holds the
/// smallest `max_pairs` eigenpairs and an extremal estimate).
#[derive(Clone, Debug)]
pub struct LaplacianEig {
    pub n: usize,
    /// Resolved Ritz values, ascending.
    pub values: Vec<f64>,
    /// `vectors[k]` is the length-n Ritz vector of `values[k]`.
    pub vectors: Vec<Vec<f64>>,
    /// True when all n−1 deflated eigenpairs were resolved.
    pub exact: bool,
}

impl LaplacianEig {
    /// Algebraic connectivity λ₂(Λ).
    pub fn lambda2(&self) -> f64 {
        self.values.first().copied().unwrap_or(f64::NAN)
    }

    /// Largest resolved Ritz value (= λ_max(Λ) in exact mode).
    pub fn lambda_max(&self) -> f64 {
        self.values.last().copied().unwrap_or(f64::NAN)
    }

    /// Effective resistance `(e_i − e_j)ᵀ Λ⁺ (e_i − e_j)` from the
    /// spectral expansion over the resolved pairs (exact in exact mode).
    pub fn resistance(&self, i: usize, j: usize) -> f64 {
        let cut = self.kernel_cut();
        let mut r = 0.0;
        for (theta, y) in self.values.iter().zip(&self.vectors) {
            if *theta > cut {
                let d = y[i] - y[j];
                r += d * d / theta;
            }
        }
        r
    }

    /// `max_(i,j)∈edges` effective resistance, accumulated Ritz-pair-major
    /// so the edge sweep is O(|ℰ|) per pair.
    pub fn max_edge_resistance(&self, edges: &[(usize, usize)]) -> f64 {
        let mut resist = vec![0.0f64; edges.len()];
        self.accumulate_edge_resistance(edges, &mut resist);
        resist.iter().fold(0.0f64, |acc, &r| acc.max(r))
    }

    fn accumulate_edge_resistance(&self, edges: &[(usize, usize)], resist: &mut [f64]) {
        let cut = self.kernel_cut();
        for (theta, y) in self.values.iter().zip(&self.vectors) {
            if *theta <= cut {
                continue;
            }
            let inv = 1.0 / theta;
            for (r, &(i, j)) in resist.iter_mut().zip(edges) {
                let d = y[i] - y[j];
                *r += d * d * inv;
            }
        }
    }

    /// Threshold below which a Ritz value counts as a numerically zero
    /// (kernel) mode and is excluded from Λ⁺ (mirrors `sym_pinv`'s cut).
    fn kernel_cut(&self) -> f64 {
        1e-10 * self.lambda_max().abs().max(1e-300)
    }
}

/// `y = Λ x` off the edge list: `y_i = Σ_j w_ij (x_i − x_j)`.
fn lap_matvec(edges: &[(usize, usize)], rates: &[f64], x: &[f64], y: &mut [f64]) {
    y.fill(0.0);
    for (&(i, j), &w) in edges.iter().zip(rates) {
        let d = w * (x[i] - x[j]);
        y[i] += d;
        y[j] -= d;
    }
}

/// Subtract the mean (deflate the constant kernel direction).
fn project_out_ones(w: &mut [f64]) {
    let mean: f64 = w.iter().sum::<f64>() / w.len() as f64;
    for v in w.iter_mut() {
        *v -= mean;
    }
}

/// Remove the components of `w` along `ones/√n` and every vector in
/// `bases` (two classical Gram–Schmidt passes — "twice is enough").
fn deflate(w: &mut [f64], bases: &[&[Vec<f64>]]) {
    for _ in 0..2 {
        project_out_ones(w);
        for base in bases {
            for u in base.iter() {
                let c = dot(w, u);
                if c != 0.0 {
                    for (wv, uv) in w.iter_mut().zip(u) {
                        *wv -= c * uv;
                    }
                }
            }
        }
    }
}

/// Largest weighted degree — a Gershgorin-style scale for ‖Λ‖.
fn laplacian_scale(n: usize, edges: &[(usize, usize)], rates: &[f64]) -> f64 {
    let mut wdeg = vec![0.0f64; n];
    for (&(i, j), &w) in edges.iter().zip(rates) {
        wdeg[i] += w;
        wdeg[j] += w;
    }
    2.0 * wdeg.iter().fold(0.0f64, |acc, &d| acc.max(d)).max(1e-300)
}

/// Exact-mode driver: restarted, fully reorthogonalized Lanczos on Λ
/// until `min(max_pairs, n−1)` Ritz pairs are resolved.
pub fn laplacian_eigs(
    n: usize,
    edges: &[(usize, usize)],
    rates: &[f64],
    opts: &LanczosOptions,
) -> LaplacianEig {
    assert_eq!(edges.len(), rates.len(), "one rate per edge");
    let deflated_dim = n.saturating_sub(1);
    let target = deflated_dim.min(opts.max_pairs);
    let breakdown = 1e-12 * laplacian_scale(n, edges, rates);

    let mut rng = Xoshiro256::seed_from_u64(opts.seed);
    let mut ritz_values: Vec<f64> = Vec::with_capacity(target);
    let mut ritz_vectors: Vec<Vec<f64>> = Vec::with_capacity(target);
    let mut scratch = vec![0.0f64; n];

    // Each restart explores the orthogonal complement of everything
    // resolved so far; the cap only guards against a pathological stall
    // (every pass resolves ≥ 1 pair, so n−1 restarts always suffice).
    let max_restarts = deflated_dim + 4;
    let mut restarts = 0;
    while ritz_values.len() < target && restarts < max_restarts {
        restarts += 1;
        let pass_cap = target - ritz_values.len();
        let Some(v0) = fresh_start_vector(n, &mut rng, &[&ritz_vectors[..]]) else {
            break; // subspace numerically exhausted
        };

        let mut basis: Vec<Vec<f64>> = vec![v0];
        let mut alphas: Vec<f64> = Vec::with_capacity(pass_cap);
        let mut betas: Vec<f64> = Vec::new();
        loop {
            let j = alphas.len();
            lap_matvec(edges, rates, &basis[j], &mut scratch);
            let alpha = dot(&scratch, &basis[j]);
            alphas.push(alpha);
            if alphas.len() == pass_cap {
                break;
            }
            // Three-term recurrence, then full reorthogonalization against
            // the resolved Ritz vectors AND the whole in-pass basis.
            for (w, v) in scratch.iter_mut().zip(&basis[j]) {
                *w -= alpha * v;
            }
            if j > 0 {
                let b = betas[j - 1];
                for (w, v) in scratch.iter_mut().zip(&basis[j - 1]) {
                    *w -= b * v;
                }
            }
            deflate(&mut scratch, &[&ritz_vectors[..], &basis[..]]);
            let beta = norm2(&scratch);
            if beta <= breakdown {
                break; // invariant subspace: harvest and restart
            }
            betas.push(beta);
            basis.push(scratch.iter().map(|&w| w / beta).collect());
        }
        harvest_ritz_pairs(&basis, &alphas, &betas, &mut ritz_values, &mut ritz_vectors);
    }

    let (values, vectors) = sort_pairs(ritz_values, ritz_vectors);
    let exact = values.len() == deflated_dim;
    LaplacianEig { n, values, vectors, exact }
}

/// Draw a deterministic random vector orthogonal to `ones` and `bases`.
fn fresh_start_vector(
    n: usize,
    rng: &mut Xoshiro256,
    bases: &[&[Vec<f64>]],
) -> Option<Vec<f64>> {
    let mut v0 = vec![0.0f64; n];
    for _ in 0..8 {
        for v in v0.iter_mut() {
            *v = standard_normal(rng);
        }
        deflate(&mut v0, bases);
        let nrm = norm2(&v0);
        if nrm > 1e-8 {
            for v in v0.iter_mut() {
                *v /= nrm;
            }
            return Some(v0);
        }
    }
    None
}

/// Eigendecompose a pass's tridiagonal and append its Ritz pairs.
fn harvest_ritz_pairs(
    basis: &[Vec<f64>],
    alphas: &[f64],
    betas: &[f64],
    values: &mut Vec<f64>,
    vectors: &mut Vec<Vec<f64>>,
) {
    let m = alphas.len();
    if m == 0 {
        return;
    }
    let n = basis[0].len();
    let mut t = Matrix::zeros(m);
    for (k, &a) in alphas.iter().enumerate() {
        t[(k, k)] = a;
    }
    for (k, &b) in betas.iter().enumerate() {
        t[(k, k + 1)] = b;
        t[(k + 1, k)] = b;
    }
    let eig = sym_eig(&t);
    for k in 0..m {
        let mut y = vec![0.0f64; n];
        for (jj, v) in basis.iter().enumerate() {
            let z = eig.vectors[(jj, k)];
            if z != 0.0 {
                for (yv, vv) in y.iter_mut().zip(v) {
                    *yv += z * vv;
                }
            }
        }
        values.push(eig.values[k]);
        vectors.push(y);
    }
}

fn sort_pairs(values: Vec<f64>, vectors: Vec<Vec<f64>>) -> (Vec<f64>, Vec<Vec<f64>>) {
    let mut order: Vec<usize> = (0..values.len()).collect();
    order.sort_by(|&a, &b| values[a].partial_cmp(&values[b]).unwrap());
    let sorted_values: Vec<f64> = order.iter().map(|&k| values[k]).collect();
    let mut slots: Vec<Option<Vec<f64>>> = vectors.into_iter().map(Some).collect();
    let sorted_vectors =
        order.iter().map(|&k| slots[k].take().expect("taken once")).collect();
    (sorted_values, sorted_vectors)
}

/// Deflated conjugate gradient: solve `Λ x = b` on the complement of the
/// all-ones kernel (`b` must be ⊥ 1; the solution is returned ⊥ 1).
/// Returns the iterate when the residual drops below `tol·‖b‖` or the
/// iteration cap is hit (whichever comes first — CG on a PSD system only
/// improves, so the capped iterate is still the best estimate so far).
fn cg_solve(
    n: usize,
    edges: &[(usize, usize)],
    rates: &[f64],
    b: &[f64],
    tol: f64,
    max_iters: usize,
) -> Vec<f64> {
    let mut x = vec![0.0f64; n];
    let mut r = b.to_vec();
    project_out_ones(&mut r);
    let mut p = r.clone();
    let mut rs = dot(&r, &r);
    let threshold = (tol * norm2(b)).max(1e-300);
    let mut ap = vec![0.0f64; n];
    for it in 0..max_iters {
        if rs.sqrt() <= threshold {
            break;
        }
        lap_matvec(edges, rates, &p, &mut ap);
        let denom = dot(&p, &ap);
        if denom <= 0.0 {
            break; // numerically singular direction (kernel drift)
        }
        let alpha = rs / denom;
        for ((xv, rv), (pv, av)) in x.iter_mut().zip(r.iter_mut()).zip(p.iter().zip(&ap)) {
            *xv += alpha * pv;
            *rv -= alpha * av;
        }
        // Re-deflate periodically: rounding lets the kernel component
        // creep back in over long solves.
        if it % 64 == 63 {
            project_out_ones(&mut r);
        }
        let rs_new = dot(&r, &r);
        let beta = rs_new / rs;
        rs = rs_new;
        for (pv, rv) in p.iter_mut().zip(&r) {
            *pv = rv + beta * *pv;
        }
    }
    project_out_ones(&mut x);
    x
}

/// Exact (to CG tolerance) effective resistance of one pair:
/// `R(i,j) = (e_i − e_j)ᵀ Λ⁺ (e_i − e_j)` via one deflated CG solve.
pub fn effective_resistance(
    n: usize,
    edges: &[(usize, usize)],
    rates: &[f64],
    i: usize,
    j: usize,
) -> f64 {
    let mut b = vec![0.0f64; n];
    b[i] = 1.0;
    b[j] = -1.0;
    let x = cg_solve(n, edges, rates, &b, 1e-9, CG_MAX_ITERS);
    x[i] - x[j]
}

const CG_MAX_ITERS: usize = 3000;

/// Inverse Lanczos: fully reorthogonalized Lanczos on Λ⁺ (each apply a
/// deflated CG solve), returning the `pairs` smallest eigenpairs of Λ.
/// This is where λ₂ comes from at scale — in the inverse spectrum 1/λ₂ is
/// the well-separated top, so a handful of iterations converge where
/// plain Lanczos would crawl through the clustered low end.
fn smallest_eigs(
    n: usize,
    edges: &[(usize, usize)],
    rates: &[f64],
    pairs: usize,
    seed: u64,
) -> LaplacianEig {
    let iters = (2 * pairs + 8).min(n.saturating_sub(1));
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let Some(v0) = fresh_start_vector(n, &mut rng, &[]) else {
        return LaplacianEig { n, values: vec![], vectors: vec![], exact: false };
    };
    let mut basis: Vec<Vec<f64>> = vec![v0];
    let mut alphas: Vec<f64> = Vec::with_capacity(iters);
    let mut betas: Vec<f64> = Vec::new();
    loop {
        let j = alphas.len();
        let mut w = cg_solve(n, edges, rates, &basis[j], 1e-10, CG_MAX_ITERS);
        let alpha = dot(&w, &basis[j]);
        alphas.push(alpha);
        if alphas.len() == iters {
            break;
        }
        for (wv, v) in w.iter_mut().zip(&basis[j]) {
            *wv -= alpha * v;
        }
        if j > 0 {
            let b = betas[j - 1];
            for (wv, v) in w.iter_mut().zip(&basis[j - 1]) {
                *wv -= b * v;
            }
        }
        deflate(&mut w, &[&basis[..]]);
        let beta = norm2(&w);
        if beta <= 1e-12 * alphas[0].abs().max(1e-300) {
            break;
        }
        betas.push(beta);
        basis.push(w.iter().map(|&v| v / beta).collect());
    }
    // Ritz pairs of Λ⁺: μ descending are the converged ones; keep the top
    // `pairs` and map back to eigenvalues of Λ (λ = 1/μ).
    let mut mu_values: Vec<f64> = Vec::new();
    let mut mu_vectors: Vec<Vec<f64>> = Vec::new();
    harvest_ritz_pairs(&basis, &alphas, &betas, &mut mu_values, &mut mu_vectors);
    let (mu_values, mu_vectors) = sort_pairs(mu_values, mu_vectors);
    let keep = pairs.min(mu_values.len());
    let mut values: Vec<f64> = Vec::with_capacity(keep);
    let mut vectors: Vec<Vec<f64>> = Vec::with_capacity(keep);
    // Largest μ (last after the ascending sort) ↔ smallest λ.
    for (mu, y) in mu_values.into_iter().zip(mu_vectors).rev().take(keep) {
        if mu > 1e-300 {
            values.push(1.0 / mu);
            vectors.push(y);
        }
    }
    // `values` is now ascending in λ already (reverse of descending μ).
    LaplacianEig { n, values, vectors, exact: false }
}

/// Values-only Lanczos estimate of λ_max (no reorthogonalization, O(n)
/// memory). Ghost eigenvalues from lost orthogonality don't move the
/// maximal Ritz value, which is what we keep; Rayleigh–Ritz makes it a
/// lower bound on the true λ_max.
fn lambda_max_estimate(
    n: usize,
    edges: &[(usize, usize)],
    rates: &[f64],
    iters: usize,
    seed: u64,
) -> f64 {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let Some(mut v) = fresh_start_vector(n, &mut rng, &[]) else {
        return f64::NAN;
    };
    let mut v_prev = vec![0.0f64; n];
    let mut alphas = Vec::with_capacity(iters);
    let mut betas: Vec<f64> = Vec::new();
    let mut w = vec![0.0f64; n];
    for j in 0..iters.min(n.saturating_sub(1)) {
        lap_matvec(edges, rates, &v, &mut w);
        let alpha = dot(&w, &v);
        alphas.push(alpha);
        for ((wv, vv), pv) in w.iter_mut().zip(&v).zip(&v_prev) {
            *wv -= alpha * vv;
            if j > 0 {
                *wv -= betas[j - 1] * *pv;
            }
        }
        project_out_ones(&mut w);
        let beta = norm2(&w);
        if beta <= 1e-12 * alphas[0].abs().max(1e-300) {
            break;
        }
        betas.push(beta);
        std::mem::swap(&mut v_prev, &mut v);
        for (vv, wv) in v.iter_mut().zip(&w) {
            *vv = wv / beta;
        }
    }
    let m = alphas.len();
    let mut t = Matrix::zeros(m);
    for (k, &a) in alphas.iter().enumerate() {
        t[(k, k)] = a;
    }
    for (k, &b) in betas.iter().enumerate().take(m.saturating_sub(1)) {
        t[(k, k + 1)] = b;
        t[(k + 1, k)] = b;
    }
    sym_eig(&t).values.last().copied().unwrap_or(f64::NAN)
}

/// One-stop sparse spectral estimate: λ₂, λ_max and the maximal edge
/// resistance, dispatching between the exact and truncated regimes on
/// `opts.max_pairs` (see the module docs). The caller turns this into the
/// paper's functionals: χ₁ = 1/λ₂, χ₂ = max_resistance/2.
pub fn estimate_spectrum(
    n: usize,
    edges: &[(usize, usize)],
    rates: &[f64],
    opts: &LanczosOptions,
) -> SparseSpectrum {
    let deflated_dim = n.saturating_sub(1);
    if opts.max_pairs >= deflated_dim {
        let eig = laplacian_eigs(n, edges, rates, opts);
        return SparseSpectrum {
            lambda2: eig.lambda2(),
            lambda_max: eig.lambda_max(),
            max_resistance: eig.max_edge_resistance(edges),
            exact: eig.exact,
        };
    }
    let low = smallest_eigs(n, edges, rates, opts.max_pairs.max(4), opts.seed);
    let lambda_max = lambda_max_estimate(n, edges, rates, 48, opts.seed ^ 0x9E37);
    let mut max_resistance = 0.0f64;
    for (i, j) in chi2_candidates(edges, rates, &low) {
        max_resistance = max_resistance.max(effective_resistance(n, edges, rates, i, j));
    }
    SparseSpectrum { lambda2: low.lambda2(), lambda_max, max_resistance, exact: false }
}

/// Candidate edges for the truncated χ₂ max: the slow-mode ranking from
/// the resolved low eigenpairs (where slow modes differ most, resistance
/// is largest), the lowest-rate edges, and a deterministic stride sample
/// as a safety net.
fn chi2_candidates(
    edges: &[(usize, usize)],
    rates: &[f64],
    low: &LaplacianEig,
) -> Vec<(usize, usize)> {
    let m = edges.len();
    let budget = CHI2_CANDIDATES.min(m);
    let mut picked: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();
    // Slow-mode ranking (partial resistance off the resolved pairs).
    let mut partial = vec![0.0f64; m];
    low.accumulate_edge_resistance(edges, &mut partial);
    let mut by_partial: Vec<usize> = (0..m).collect();
    by_partial.sort_by(|&a, &b| partial[b].partial_cmp(&partial[a]).unwrap());
    picked.extend(by_partial.iter().take(budget / 2));
    // Lowest-rate edges (high per-edge resistance locally).
    let mut by_rate: Vec<usize> = (0..m).collect();
    by_rate.sort_by(|&a, &b| rates[a].partial_cmp(&rates[b]).unwrap());
    picked.extend(by_rate.iter().take(budget / 4));
    // Deterministic stride sample across the edge list.
    let stride = (m / budget.max(1)).max(1);
    picked.extend((0..m).step_by(stride).take(budget / 4));
    picked.into_iter().take(CHI2_CANDIDATES).map(|e| edges[e]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring_edges(n: usize) -> (Vec<(usize, usize)>, Vec<f64>) {
        let mut edges: Vec<(usize, usize)> =
            (0..n).map(|i| (i.min((i + 1) % n), i.max((i + 1) % n))).collect();
        edges.sort_unstable();
        edges.dedup();
        let rates = vec![0.5; edges.len()];
        (edges, rates)
    }

    #[test]
    fn exact_mode_matches_ring_closed_form() {
        let n = 16;
        let (edges, rates) = ring_edges(n);
        let eig = laplacian_eigs(n, &edges, &rates, &LanczosOptions::default());
        assert!(eig.exact);
        assert_eq!(eig.values.len(), n - 1);
        let lambda2 = 1.0 - (2.0 * std::f64::consts::PI / n as f64).cos();
        assert!((eig.lambda2() - lambda2).abs() < 1e-9, "{} vs {lambda2}", eig.lambda2());
        // Adjacent effective resistance on the weighted cycle: (1/w)(n−1)/n.
        let expect = 2.0 * (n as f64 - 1.0) / n as f64;
        assert!((eig.resistance(0, 1) - expect).abs() < 1e-8);
        assert!((eig.max_edge_resistance(&edges) - expect).abs() < 1e-8);
    }

    #[test]
    fn exact_mode_handles_degenerate_spectra() {
        // Complete graph with uniform weight w: λ = n·w with multiplicity
        // n−1 — one Krylov sequence alone would only surface it once.
        let n = 12;
        let w = 1.0 / (n as f64 - 1.0);
        let mut edges = Vec::new();
        for i in 0..n {
            for j in i + 1..n {
                edges.push((i, j));
            }
        }
        let rates = vec![w; edges.len()];
        let eig = laplacian_eigs(n, &edges, &rates, &LanczosOptions::default());
        assert!(eig.exact);
        let expect = n as f64 * w;
        for &v in &eig.values {
            assert!((v - expect).abs() < 1e-9, "{v} vs {expect}");
        }
    }

    #[test]
    fn cg_resistance_matches_spectral_sum() {
        let n = 24;
        let (edges, rates) = ring_edges(n);
        let exact = laplacian_eigs(n, &edges, &rates, &LanczosOptions::default());
        for &(i, j) in &[(0usize, 1usize), (0, 12), (3, 17)] {
            let via_cg = effective_resistance(n, &edges, &rates, i, j);
            let via_sum = exact.resistance(i, j);
            assert!(
                (via_cg - via_sum).abs() < 1e-6 * via_sum.max(1.0),
                "R({i},{j}): cg {via_cg} vs sum {via_sum}"
            );
        }
    }

    #[test]
    fn truncated_mode_nails_lambda2_on_a_big_torus() {
        // 30×20 torus (n = 600, past DENSE_EXACT_LIMIT) exercises the
        // inverse-Lanczos path with a tractable condition number and a
        // closed-form λ₂: uniform weight w = 1/4, λ₂ = 2w(1 − cos(2π/30)).
        let (rows, cols) = (30usize, 20usize);
        let n = rows * cols;
        let mut set = std::collections::BTreeSet::new();
        for r in 0..rows {
            for c in 0..cols {
                let id = r * cols + c;
                let right = r * cols + (c + 1) % cols;
                let down = ((r + 1) % rows) * cols + c;
                set.insert((id.min(right), id.max(right)));
                set.insert((id.min(down), id.max(down)));
            }
        }
        let edges: Vec<(usize, usize)> = set.into_iter().collect();
        let rates = vec![0.25; edges.len()];
        let opts = LanczosOptions::sized_for(n);
        assert!(opts.max_pairs < n - 1);
        let s = estimate_spectrum(n, &edges, &rates, &opts);
        assert!(!s.exact);
        let lambda2 = 0.5 * (1.0 - (2.0 * std::f64::consts::PI / rows as f64).cos());
        let rel = (s.lambda2 - lambda2).abs() / lambda2;
        assert!(rel < 1e-6, "λ₂ rel err {rel}: {} vs {lambda2}", s.lambda2);
        // The torus is edge-transitive within each axis class, so the
        // candidate sweep's max must match an exact per-edge CG solve.
        let r_row = effective_resistance(n, &edges, &rates, 0, 1);
        let r_col = effective_resistance(n, &edges, &rates, 0, cols);
        let expect_r = r_row.max(r_col);
        assert!(
            (s.max_resistance - expect_r).abs() < 1e-6 * expect_r,
            "R {} vs {expect_r}",
            s.max_resistance
        );
        // λ_max = 2 exactly (both axes even); the values-only sweep is a
        // Rayleigh–Ritz lower bound that should land in the right range.
        assert!(s.lambda_max <= 2.0 + 1e-9 && s.lambda_max > 1.5, "λ_max {}", s.lambda_max);
    }

    #[test]
    fn estimates_are_deterministic() {
        let (edges, rates) = ring_edges(24);
        let a = laplacian_eigs(24, &edges, &rates, &LanczosOptions::default());
        let b = laplacian_eigs(24, &edges, &rates, &LanczosOptions::default());
        assert_eq!(a.values.len(), b.values.len());
        for (x, y) in a.values.iter().zip(&b.values) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn sized_for_switches_regimes() {
        assert_eq!(LanczosOptions::sized_for(100).max_pairs, 99);
        assert_eq!(
            LanczosOptions::sized_for(DENSE_EXACT_LIMIT).max_pairs,
            DENSE_EXACT_LIMIT - 1
        );
        assert_eq!(LanczosOptions::sized_for(100_000).max_pairs, TRUNCATED_PAIRS);
    }
}
