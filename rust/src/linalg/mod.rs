//! Minimal dense linear algebra substrate: symmetric eigendecomposition
//! (cyclic Jacobi) and Moore–Penrose pseudoinverse, used to compute the
//! paper's graph functionals χ₁ (inverse algebraic connectivity, Eq. 2)
//! and χ₂ (maximal effective resistance, Eq. 3) from the rate-weighted
//! Laplacian Λ.
//!
//! No external linear-algebra crates are reachable offline, so this is a
//! self-contained implementation sized for `n ≤ ~2048` workers (Jacobi is
//! O(n³) per sweep and unconditionally stable for symmetric matrices).
//! Past that, the [`lanczos`] submodule estimates the same functionals in
//! O(|ℰ|) per matvec off the sparse edge list — the massive-fleet path.

pub mod lanczos;

/// A dense row-major `n × n` matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub n: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    /// The `n × n` zero matrix.
    pub fn zeros(n: usize) -> Self {
        Self { n, data: vec![0.0; n * n] }
    }

    /// The `n × n` identity.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(n: usize, f: impl Fn(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            for j in 0..n {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Matrix-vector product `self * x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0.0; self.n];
        for i in 0..self.n {
            let row = &self.data[i * self.n..(i + 1) * self.n];
            y[i] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
        y
    }

    /// Matrix-matrix product `self * other`.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.n, other.n);
        let n = self.n;
        let mut out = Matrix::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.n, |i, j| self[(j, i)])
    }

    /// Maximum absolute off-diagonal entry (Jacobi convergence criterion).
    pub fn max_offdiag(&self) -> f64 {
        let mut m = 0.0f64;
        for i in 0..self.n {
            for j in 0..self.n {
                if i != j {
                    m = m.max(self[(i, j)].abs());
                }
            }
        }
        m
    }

    /// Check symmetry up to `tol`.
    pub fn is_symmetric(&self, tol: f64) -> bool {
        for i in 0..self.n {
            for j in i + 1..self.n {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.n + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.n + j]
    }
}

/// Result of a symmetric eigendecomposition `A = V diag(w) Vᵀ`.
#[derive(Clone, Debug)]
pub struct SymEig {
    /// Eigenvalues in ascending order.
    pub values: Vec<f64>,
    /// `values[k]`'s eigenvector is column `k` of `vectors` (row-major).
    pub vectors: Matrix,
}

/// Cyclic Jacobi eigensolver for symmetric matrices.
///
/// Sweeps all off-diagonal pairs with Givens rotations until the largest
/// off-diagonal entry falls below `1e-12 * max|A|`, then sorts eigenpairs
/// ascending. Panics if `a` is not symmetric.
pub fn sym_eig(a: &Matrix) -> SymEig {
    assert!(a.is_symmetric(1e-9), "sym_eig on non-symmetric matrix");
    let n = a.n;
    let mut m = a.clone();
    let mut v = Matrix::identity(n);
    let scale: f64 = m
        .data
        .iter()
        .fold(0.0f64, |acc, &x| acc.max(x.abs()))
        .max(1e-300);
    let tol = 1e-13 * scale;
    const MAX_SWEEPS: usize = 100;

    for _sweep in 0..MAX_SWEEPS {
        if m.max_offdiag() <= tol {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() <= tol * 1e-2 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Rotation angle: tan(2θ) = 2 a_pq / (a_pp - a_qq)
                let theta = 0.5 * (2.0 * apq).atan2(app - aqq);
                let (s, c) = theta.sin_cos();
                // Apply rotation G(p,q,θ)ᵀ M G(p,q,θ) in place.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp + s * mkq;
                    m[(k, q)] = -s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk + s * mqk;
                    m[(q, k)] = -s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp + s * vkq;
                    v[(k, q)] = -s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract and sort ascending.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[(i, i)], i)).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let values: Vec<f64> = pairs.iter().map(|&(w, _)| w).collect();
    let mut vectors = Matrix::zeros(n);
    for (newcol, &(_, oldcol)) in pairs.iter().enumerate() {
        for k in 0..n {
            vectors[(k, newcol)] = v[(k, oldcol)];
        }
    }
    SymEig { values, vectors }
}

/// Moore–Penrose pseudoinverse of a symmetric PSD matrix via its
/// eigendecomposition: eigenvalues below `rcond * λ_max` are treated as
/// zero (the Laplacian of a connected graph has exactly one).
pub fn sym_pinv(a: &Matrix, rcond: f64) -> Matrix {
    let eig = sym_eig(a);
    let n = a.n;
    let wmax = eig
        .values
        .iter()
        .fold(0.0f64, |acc, &w| acc.max(w.abs()));
    let cut = rcond * wmax.max(1e-300);
    let mut out = Matrix::zeros(n);
    for k in 0..n {
        let w = eig.values[k];
        if w.abs() <= cut {
            continue;
        }
        let inv = 1.0 / w;
        for i in 0..n {
            let vik = eig.vectors[(i, k)];
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                out[(i, j)] += inv * vik * eig.vectors[(j, k)];
            }
        }
    }
    out
}

/// Euclidean norm of a vector.
pub fn norm2(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// Dot product.
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn eig_diagonal() {
        let mut m = Matrix::zeros(3);
        m[(0, 0)] = 3.0;
        m[(1, 1)] = 1.0;
        m[(2, 2)] = 2.0;
        let e = sym_eig(&m);
        assert!(approx(e.values[0], 1.0, 1e-10));
        assert!(approx(e.values[1], 2.0, 1e-10));
        assert!(approx(e.values[2], 3.0, 1e-10));
    }

    #[test]
    fn eig_2x2_known() {
        // [[2,1],[1,2]] has eigenvalues 1, 3.
        let m = Matrix::from_fn(2, |i, j| if i == j { 2.0 } else { 1.0 });
        let e = sym_eig(&m);
        assert!(approx(e.values[0], 1.0, 1e-10));
        assert!(approx(e.values[1], 3.0, 1e-10));
    }

    #[test]
    fn eig_reconstructs() {
        // Random-ish symmetric matrix; check A = V W Vᵀ and VᵀV = I.
        let n = 6;
        let seed = std::cell::Cell::new(123u64);
        let base = Matrix::from_fn(n, |_, _| {
            let mut s = seed.get();
            let v = crate::rng::splitmix64(&mut s);
            seed.set(s);
            (v % 1000) as f64 / 500.0 - 1.0
        });
        let a = Matrix::from_fn(n, |i, j| 0.5 * (base[(i, j)] + base[(j, i)]));
        let e = sym_eig(&a);
        // Orthonormality
        let vtv = e.vectors.transpose().matmul(&e.vectors);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(approx(vtv[(i, j)], want, 1e-9), "VtV[{i},{j}]={}", vtv[(i, j)]);
            }
        }
        // Reconstruction
        let mut w = Matrix::zeros(n);
        for k in 0..n {
            w[(k, k)] = e.values[k];
        }
        let rec = e.vectors.matmul(&w).matmul(&e.vectors.transpose());
        for i in 0..n {
            for j in 0..n {
                assert!(approx(rec[(i, j)], a[(i, j)], 1e-8));
            }
        }
    }

    #[test]
    fn pinv_of_laplacian_like() {
        // Path graph P3 Laplacian; pinv must satisfy A A⁺ A = A and
        // A⁺ 1 = 0 (kernel preserved).
        let mut a = Matrix::zeros(3);
        let edges = [(0usize, 1usize), (1, 2)];
        for &(i, j) in &edges {
            a[(i, i)] += 1.0;
            a[(j, j)] += 1.0;
            a[(i, j)] -= 1.0;
            a[(j, i)] -= 1.0;
        }
        let p = sym_pinv(&a, 1e-10);
        let apa = a.matmul(&p).matmul(&a);
        for i in 0..3 {
            for j in 0..3 {
                assert!(approx(apa[(i, j)], a[(i, j)], 1e-8));
            }
        }
        let ones = vec![1.0; 3];
        let y = p.matvec(&ones);
        assert!(norm2(&y) < 1e-8, "pinv must kill the all-ones kernel");
    }

    #[test]
    fn matvec_identity() {
        let m = Matrix::identity(4);
        let x = vec![1.0, -2.0, 3.0, 0.5];
        assert_eq!(m.matvec(&x), x);
    }

    #[test]
    #[should_panic]
    fn eig_rejects_asymmetric() {
        let mut m = Matrix::zeros(2);
        m[(0, 1)] = 1.0;
        sym_eig(&m);
    }
}
