//! Synthetic datasets standing in for CIFAR-10 / ImageNet / the LM corpus.
//!
//! The paper's evaluation studies *how the decentralized-vs-All-Reduce gap
//! scales with n, topology, and communication rate* — a property of the
//! optimization dynamics, not of natural images. We therefore substitute
//! (per DESIGN.md §3) controllable synthetic tasks:
//!
//! * [`GaussianMixture`] — k-class classification with tunable margin and
//!   dimension. `cifar_like()` (10 easy classes) and `imagenet_like()`
//!   (100 classes, tighter margin, more data) mirror the paper's two
//!   difficulty levels.
//! * [`LinearRegression`] — a strongly-convex quadratic used for the
//!   rate-scaling experiments (Tab. 1), where the theory is sharp.
//! * [`MarkovCorpus`] — a synthetic token stream with learnable bigram
//!   structure for the end-to-end transformer-LM driver.
//! * [`Sharding`] — IID or Dirichlet-heterogeneous assignment of data to
//!   workers (the paper gives every worker the full dataset with a
//!   different shuffling seed; heterogeneous splits support the
//!   federated-learning extension flagged in its conclusion).

use crate::rng::{standard_normal, Xoshiro256};

/// A dense supervised dataset: row-major features + integer labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dim: usize,
    pub n_classes: usize,
    /// `features[i*dim .. (i+1)*dim]` is example `i`.
    pub features: Vec<f32>,
    pub labels: Vec<u32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn example(&self, i: usize) -> (&[f32], u32) {
        (&self.features[i * self.dim..(i + 1) * self.dim], self.labels[i])
    }

    /// Gather a batch by indices into contiguous buffers.
    pub fn gather(&self, idx: &[usize], xs: &mut Vec<f32>, ys: &mut Vec<u32>) {
        xs.clear();
        ys.clear();
        for &i in idx {
            let (x, y) = self.example(i);
            xs.extend_from_slice(x);
            ys.push(y);
        }
    }
}

/// Gaussian-mixture classification: class `c` is `N(μ_c, σ²·I)` with the
/// `μ_c` sampled on a sphere of radius `margin`.
#[derive(Clone, Debug)]
pub struct GaussianMixture {
    pub dim: usize,
    pub n_classes: usize,
    /// Separation of class means (larger = easier).
    pub margin: f64,
    /// Within-class noise.
    pub sigma: f64,
}

impl GaussianMixture {
    /// 10 well-separated classes in 32-D — the "CIFAR-like" easy regime.
    pub fn cifar_like() -> Self {
        Self { dim: 32, n_classes: 10, margin: 3.0, sigma: 1.0 }
    }

    /// 100 classes in 64-D with tighter margin — the "ImageNet-like"
    /// harder regime where consensus drift visibly hurts.
    pub fn imagenet_like() -> Self {
        Self { dim: 64, n_classes: 100, margin: 2.0, sigma: 1.0 }
    }

    /// Sample a dataset of `n` examples.
    pub fn sample(&self, n: usize, seed: u64) -> Dataset {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        // Class means: random Gaussian directions scaled to `margin`.
        let mut means = vec![0.0f64; self.n_classes * self.dim];
        for c in 0..self.n_classes {
            let row = &mut means[c * self.dim..(c + 1) * self.dim];
            let mut norm = 0.0;
            for v in row.iter_mut() {
                *v = standard_normal(&mut rng);
                norm += *v * *v;
            }
            let norm = norm.sqrt().max(1e-12);
            for v in row.iter_mut() {
                *v *= self.margin / norm;
            }
        }
        let mut features = Vec::with_capacity(n * self.dim);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.gen_range(self.n_classes);
            let mu = &means[c * self.dim..(c + 1) * self.dim];
            for d in 0..self.dim {
                features.push((mu[d] + self.sigma * standard_normal(&mut rng)) as f32);
            }
            labels.push(c as u32);
        }
        Dataset { dim: self.dim, n_classes: self.n_classes, features, labels }
    }
}

/// Linear regression `y = ⟨w*, x⟩ + noise`: the strongly-convex quadratic
/// objective used for Tab. 1 (rate-vs-χ scaling).
#[derive(Clone, Debug)]
pub struct LinearRegression {
    pub dim: usize,
    pub noise: f64,
}

/// A regression dataset (features + float targets).
#[derive(Clone, Debug)]
pub struct RegressionData {
    pub dim: usize,
    pub features: Vec<f32>,
    pub targets: Vec<f32>,
    /// The generating weights (for excess-risk evaluation).
    pub w_star: Vec<f32>,
}

impl LinearRegression {
    pub fn sample(&self, n: usize, seed: u64) -> RegressionData {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let w_star: Vec<f32> = (0..self.dim)
            .map(|_| standard_normal(&mut rng) as f32)
            .collect();
        let mut features = Vec::with_capacity(n * self.dim);
        let mut targets = Vec::with_capacity(n);
        for _ in 0..n {
            let mut y = 0.0f64;
            for &w in &w_star {
                let x = standard_normal(&mut rng);
                features.push(x as f32);
                y += w as f64 * x;
            }
            targets.push((y + self.noise * standard_normal(&mut rng)) as f32);
        }
        RegressionData { dim: self.dim, features, targets, w_star }
    }
}

impl RegressionData {
    pub fn len(&self) -> usize {
        self.targets.len()
    }

    pub fn example(&self, i: usize) -> (&[f32], f32) {
        (&self.features[i * self.dim..(i + 1) * self.dim], self.targets[i])
    }
}

/// Synthetic token corpus with first-order (bigram) Markov structure —
/// gives a transformer LM a learnable signal with a known entropy floor.
#[derive(Clone, Debug)]
pub struct MarkovCorpus {
    pub vocab: usize,
    pub tokens: Vec<u32>,
}

impl MarkovCorpus {
    /// Generate `len` tokens over `vocab` symbols. Each symbol transitions
    /// to a small random subset of successors (sparsity `branch`), making
    /// next-token prediction learnable well below `log(vocab)` nats.
    pub fn generate(vocab: usize, branch: usize, len: usize, seed: u64) -> Self {
        assert!(vocab >= 2 && branch >= 1 && branch <= vocab);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        // successors[v] = allowed next tokens for v.
        let successors: Vec<Vec<u32>> = (0..vocab)
            .map(|_| {
                rng.sample_indices(vocab, branch)
                    .into_iter()
                    .map(|x| x as u32)
                    .collect()
            })
            .collect();
        let mut tokens = Vec::with_capacity(len);
        let mut cur = rng.gen_range(vocab) as u32;
        for _ in 0..len {
            tokens.push(cur);
            let succ = &successors[cur as usize];
            cur = succ[rng.gen_range(succ.len())];
        }
        Self { vocab, tokens }
    }

    /// The entropy floor of the generating process (nats/token): uniform
    /// over `branch` successors.
    pub fn entropy_floor(branch: usize) -> f64 {
        (branch as f64).ln()
    }

    /// Sample a batch of (input, target) windows of length `seq`.
    pub fn sample_batch(
        &self,
        batch: usize,
        seq: usize,
        rng: &mut Xoshiro256,
        inputs: &mut Vec<u32>,
        targets: &mut Vec<u32>,
    ) {
        assert!(self.tokens.len() > seq + 1, "corpus too short");
        inputs.clear();
        targets.clear();
        for _ in 0..batch {
            let start = rng.gen_range(self.tokens.len() - seq - 1);
            inputs.extend_from_slice(&self.tokens[start..start + seq]);
            targets.extend_from_slice(&self.tokens[start + 1..start + seq + 1]);
        }
    }
}

/// How data is assigned to workers.
#[derive(Clone, Debug, PartialEq)]
pub enum Sharding {
    /// The paper's protocol: every worker sees the full dataset, shuffled
    /// with its own seed.
    FullShuffled,
    /// Disjoint IID shards.
    Iid,
    /// Label-skewed shards via a Dirichlet(α) draw per class (smaller α =
    /// more heterogeneous), the standard FL heterogeneity model.
    Dirichlet { alpha: f64 },
}

/// Per-worker index streams into a shared dataset.
#[derive(Clone, Debug)]
pub struct ShardedIndices {
    pub per_worker: Vec<Vec<usize>>,
}

impl Sharding {
    /// Assign `dataset` indices to `n_workers` workers.
    pub fn assign(&self, dataset: &Dataset, n_workers: usize, seed: u64) -> ShardedIndices {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let n = dataset.len();
        let per_worker = match self {
            Sharding::FullShuffled => (0..n_workers)
                .map(|w| {
                    let mut idx: Vec<usize> = (0..n).collect();
                    let mut r = rng.split(w as u64);
                    r.shuffle(&mut idx);
                    idx
                })
                .collect(),
            Sharding::Iid => {
                let mut idx: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut idx);
                let mut shards = vec![Vec::new(); n_workers];
                for (k, i) in idx.into_iter().enumerate() {
                    shards[k % n_workers].push(i);
                }
                shards
            }
            Sharding::Dirichlet { alpha } => {
                // For each class, split its examples across workers with
                // Dirichlet(α) proportions (sampled via Gamma(α,1) draws).
                let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); dataset.n_classes];
                for i in 0..n {
                    by_class[dataset.labels[i] as usize].push(i);
                }
                let mut shards = vec![Vec::new(); n_workers];
                for class_idx in by_class {
                    let props = dirichlet(*alpha, n_workers, &mut rng);
                    let mut cuts = Vec::with_capacity(n_workers);
                    let mut acc = 0.0;
                    for p in &props {
                        acc += p;
                        cuts.push((acc * class_idx.len() as f64).round() as usize);
                    }
                    let mut start = 0usize;
                    for (w, &cut) in cuts.iter().enumerate() {
                        let end = cut.min(class_idx.len());
                        shards[w].extend_from_slice(&class_idx[start..end]);
                        start = end;
                    }
                }
                for (w, shard) in shards.iter_mut().enumerate() {
                    let mut r = rng.split(1000 + w as u64);
                    r.shuffle(shard);
                    // Never leave a worker with an empty shard.
                    if shard.is_empty() {
                        shard.push(rng.gen_range(n));
                    }
                }
                shards
            }
        };
        ShardedIndices { per_worker }
    }
}

/// Dirichlet(α,…,α) sample via normalized Gamma(α, 1) draws
/// (Marsaglia–Tsang for α ≥ 1, boost trick below 1).
fn dirichlet(alpha: f64, k: usize, rng: &mut Xoshiro256) -> Vec<f64> {
    let mut g: Vec<f64> = (0..k).map(|_| gamma_sample(alpha, rng)).collect();
    let sum: f64 = g.iter().sum();
    if sum <= 0.0 {
        return vec![1.0 / k as f64; k];
    }
    for v in &mut g {
        *v /= sum;
    }
    g
}

fn gamma_sample(alpha: f64, rng: &mut Xoshiro256) -> f64 {
    if alpha < 1.0 {
        // Boost: Gamma(α) = Gamma(α+1) · U^{1/α}.
        let u = rng.next_f64().max(1e-300);
        return gamma_sample(alpha + 1.0, rng) * u.powf(1.0 / alpha);
    }
    // Marsaglia–Tsang squeeze.
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = standard_normal(rng);
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.next_f64();
        if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
            return d * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gm_dataset_shapes_and_labels() {
        let ds = GaussianMixture::cifar_like().sample(500, 1);
        assert_eq!(ds.len(), 500);
        assert_eq!(ds.features.len(), 500 * 32);
        assert!(ds.labels.iter().all(|&l| (l as usize) < 10));
        // All classes appear.
        let classes: std::collections::HashSet<_> = ds.labels.iter().collect();
        assert_eq!(classes.len(), 10);
    }

    #[test]
    fn gm_is_separable_by_margin() {
        // With margin ≫ σ, nearest-class-mean classifies well above chance.
        let gen = GaussianMixture { dim: 16, n_classes: 4, margin: 6.0, sigma: 1.0 };
        let ds = gen.sample(400, 7);
        // Estimate class means from the data itself.
        let mut means = vec![0.0f64; 4 * 16];
        let mut counts = [0usize; 4];
        for i in 0..ds.len() {
            let (x, y) = ds.example(i);
            counts[y as usize] += 1;
            for d in 0..16 {
                means[y as usize * 16 + d] += x[d] as f64;
            }
        }
        for c in 0..4 {
            for d in 0..16 {
                means[c * 16 + d] /= counts[c] as f64;
            }
        }
        let mut correct = 0;
        for i in 0..ds.len() {
            let (x, y) = ds.example(i);
            let best = (0..4)
                .min_by(|&a, &b| {
                    let da: f64 = (0..16)
                        .map(|d| (x[d] as f64 - means[a * 16 + d]).powi(2))
                        .sum();
                    let db: f64 = (0..16)
                        .map(|d| (x[d] as f64 - means[b * 16 + d]).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as u32 == y {
                correct += 1;
            }
        }
        assert!(correct as f64 / ds.len() as f64 > 0.95);
    }

    #[test]
    fn regression_targets_follow_w_star() {
        let gen = LinearRegression { dim: 8, noise: 0.0 };
        let data = gen.sample(50, 3);
        for i in 0..data.len() {
            let (x, y) = data.example(i);
            let pred: f64 = x
                .iter()
                .zip(&data.w_star)
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum();
            assert!((pred - y as f64).abs() < 1e-4);
        }
    }

    #[test]
    fn markov_corpus_respects_vocab() {
        let c = MarkovCorpus::generate(50, 4, 10_000, 9);
        assert_eq!(c.tokens.len(), 10_000);
        assert!(c.tokens.iter().all(|&t| (t as usize) < 50));
        // Bigram structure: number of distinct successors per symbol ≤ branch.
        let mut succ: Vec<std::collections::HashSet<u32>> = vec![Default::default(); 50];
        for w in c.tokens.windows(2) {
            succ[w[0] as usize].insert(w[1]);
        }
        assert!(succ.iter().all(|s| s.len() <= 4));
    }

    #[test]
    fn batch_sampling_aligns_inputs_targets() {
        let c = MarkovCorpus::generate(20, 3, 5_000, 11);
        let mut rng = Xoshiro256::seed_from_u64(0);
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        c.sample_batch(4, 16, &mut rng, &mut xs, &mut ys);
        assert_eq!(xs.len(), 64);
        assert_eq!(ys.len(), 64);
        // target[t] is input[t+1] within each window.
        for b in 0..4 {
            for t in 0..15 {
                assert_eq!(ys[b * 16 + t], xs[b * 16 + t + 1]);
            }
        }
    }

    #[test]
    fn sharding_iid_partitions() {
        let ds = GaussianMixture::cifar_like().sample(100, 2);
        let sh = Sharding::Iid.assign(&ds, 4, 0);
        let total: usize = sh.per_worker.iter().map(|s| s.len()).sum();
        assert_eq!(total, 100);
        let all: std::collections::HashSet<_> =
            sh.per_worker.iter().flatten().collect();
        assert_eq!(all.len(), 100, "disjoint cover");
    }

    #[test]
    fn sharding_full_shuffled_gives_everyone_everything() {
        let ds = GaussianMixture::cifar_like().sample(60, 2);
        let sh = Sharding::FullShuffled.assign(&ds, 3, 0);
        for w in 0..3 {
            assert_eq!(sh.per_worker[w].len(), 60);
            let mut sorted = sh.per_worker[w].clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..60).collect::<Vec<_>>());
        }
        assert_ne!(sh.per_worker[0], sh.per_worker[1], "different shuffles");
    }

    #[test]
    fn sharding_dirichlet_skews_labels() {
        let ds = GaussianMixture { dim: 4, n_classes: 4, margin: 2.0, sigma: 1.0 }
            .sample(2000, 5);
        let skewed = Sharding::Dirichlet { alpha: 0.1 }.assign(&ds, 4, 1);
        let uniform = Sharding::Iid.assign(&ds, 4, 1);
        // Measure max class fraction on worker 0: skewed ≫ uniform.
        let frac = |idx: &[usize]| -> f64 {
            let mut counts = [0usize; 4];
            for &i in idx {
                counts[ds.labels[i] as usize] += 1;
            }
            *counts.iter().max().unwrap() as f64 / idx.len().max(1) as f64
        };
        let s = frac(&skewed.per_worker[0]);
        let u = frac(&uniform.per_worker[0]);
        assert!(s > u, "dirichlet skew {s} should exceed iid {u}");
        // Every worker still has data.
        assert!(skewed.per_worker.iter().all(|w| !w.is_empty()));
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        for &alpha in &[0.1, 1.0, 10.0] {
            let p = dirichlet(alpha, 8, &mut rng);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&v| v >= 0.0));
        }
    }
}
