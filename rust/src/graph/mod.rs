//! Communication-network topologies and the paper's graph functionals.
//!
//! The paper models the network as a set of edges `ℰ` with per-edge Poisson
//! communication rates `λ^ij`, summarized by the *instantaneous expected
//! Laplacian* `Λ = Σ_(i,j)∈ℰ λ^ij (e_i−e_j)(e_i−e_j)ᵀ` (Definition 3.1).
//! Two functionals of Λ drive everything:
//!
//! * `χ₁ = 1 / λ₂(Λ)` — inverse algebraic connectivity (Eq. 2);
//! * `χ₂ = ½·max_(i,j)∈ℰ (e_i−e_j)ᵀ Λ⁺ (e_i−e_j)` — maximal effective
//!   resistance (Eq. 3), with `χ₂ ≤ χ₁`.
//!
//! A²CiD²'s momentum parameters (η, α̃) are functions of (χ₁, χ₂); the
//! acceleration claim is that convergence degrades with `√(χ₁χ₂)` instead
//! of `χ₁` (e.g. ring: `Θ(n^{3/2})` instead of `Θ(n²)`).

use crate::linalg::lanczos::{self, LanczosOptions};
use crate::linalg::{sym_eig, sym_pinv, Matrix};

/// The topologies used in the paper (complete / exponential / ring, App. E.1)
/// plus extras useful for ablations and the hierarchical shapes that keep
/// χ₁ tractable at massive fleet sizes (GossipGraD/SWIFT-style clusters).
#[derive(Clone, Debug, PartialEq)]
pub enum Topology {
    /// All pairs connected.
    Complete,
    /// Cycle graph (the paper's hardest case: χ₁ = Θ(n²)).
    Ring,
    /// Undirected exponential graph of Assran et al. / AD-PSGD:
    /// node `i` is adjacent to `i ± 2^k mod n` for `2^k < n`.
    Exponential,
    /// One hub connected to all leaves.
    Star,
    /// Path graph (ring cut open).
    Path,
    /// 2-D torus `rows × cols` (requires `rows*cols == n`).
    Torus { rows: usize, cols: usize },
    /// Hypercube (requires `n` to be a power of two).
    Hypercube,
    /// Erdős–Rényi `G(n, p)`, resampled until connected.
    ErdosRenyi { p: f64, seed: u64 },
    /// `clusters` rings of `ring` nodes each, bridged by an exponential
    /// graph over the cluster representatives (node `c·ring` of each
    /// cluster). Grammar `cluster_ring:KxM`; requires `K·M == n`. χ₁
    /// stays ~flat in the cluster count for fixed ring size — the shape
    /// that makes n = 10⁵ fleets spectrally tractable.
    ClusterRing { clusters: usize, ring: usize },
    /// Same bridging, complete graphs inside each cluster. Grammar
    /// `cluster_complete:KxM`.
    ClusterComplete { clusters: usize, cluster: usize },
}

impl Topology {
    /// Parse from a CLI/config string like `"ring"`, `"torus:4x8"`,
    /// `"cluster_ring:100x1000"`, `"erdos:0.3:42"`.
    pub fn parse(s: &str) -> crate::Result<Topology> {
        // `KxM`-style dimension pair shared by torus and the hierarchical
        // grammars.
        fn dims(parts: &[&str], what: &str, example: &str) -> crate::Result<(usize, usize)> {
            let raw = parts
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("{what} needs dims, e.g. {example}"))?;
            let dims: Vec<&str> = raw.split('x').collect();
            anyhow::ensure!(dims.len() == 2, "{what} dims must be KxM, got '{raw}'");
            Ok((dims[0].parse()?, dims[1].parse()?))
        }
        let parts: Vec<&str> = s.split(':').collect();
        Ok(match parts[0] {
            "complete" => Topology::Complete,
            "ring" | "cycle" => Topology::Ring,
            "exponential" | "exp" => Topology::Exponential,
            "star" => Topology::Star,
            "path" => Topology::Path,
            "hypercube" => Topology::Hypercube,
            "torus" => {
                let (rows, cols) = dims(&parts, "torus", "torus:4x8")?;
                Topology::Torus { rows, cols }
            }
            "cluster_ring" => {
                let (clusters, ring) = dims(&parts, "cluster_ring", "cluster_ring:10x100")?;
                Topology::ClusterRing { clusters, ring }
            }
            "cluster_complete" => {
                let (clusters, cluster) =
                    dims(&parts, "cluster_complete", "cluster_complete:10x16")?;
                Topology::ClusterComplete { clusters, cluster }
            }
            "erdos" => {
                let p: f64 = parts
                    .get(1)
                    .ok_or_else(|| anyhow::anyhow!("erdos needs p, e.g. erdos:0.3"))?
                    .parse()?;
                let seed: u64 = parts.get(2).map(|s| s.parse()).transpose()?.unwrap_or(0);
                Topology::ErdosRenyi { p, seed }
            }
            other => anyhow::bail!("unknown topology '{other}'"),
        })
    }

    /// Full parseable spec string: the inverse of [`Topology::parse`]
    /// (unlike [`Topology::name`], parameterized topologies keep their
    /// parameters). Used by the `Scenario` string renderer.
    pub fn spec(&self) -> String {
        match self {
            Topology::Torus { rows, cols } => format!("torus:{rows}x{cols}"),
            Topology::ClusterRing { clusters, ring } => format!("cluster_ring:{clusters}x{ring}"),
            Topology::ClusterComplete { clusters, cluster } => {
                format!("cluster_complete:{clusters}x{cluster}")
            }
            Topology::ErdosRenyi { p, seed } => format!("erdos:{p}:{seed}"),
            other => other.name().to_string(),
        }
    }

    /// Short name used in reports.
    pub fn name(&self) -> &'static str {
        match self {
            Topology::Complete => "complete",
            Topology::Ring => "ring",
            Topology::Exponential => "exponential",
            Topology::Star => "star",
            Topology::Path => "path",
            Topology::Torus { .. } => "torus",
            Topology::Hypercube => "hypercube",
            Topology::ErdosRenyi { .. } => "erdos-renyi",
            Topology::ClusterRing { .. } => "cluster-ring",
            Topology::ClusterComplete { .. } => "cluster-complete",
        }
    }

    /// Closed-form (χ₁, χ₂) under the per-worker-rate protocol of
    /// [`Graph::edge_rates`], for the topologies where both functionals
    /// have exact expressions — the zero-eigensolve fast path that keeps
    /// `adapt=1` retuning cheap at any n. Returns `None` where no closed
    /// form is known (the Lanczos estimator is the fallback).
    pub fn closed_form_chis(&self, n: usize, rate_per_worker: f64) -> Option<(f64, f64)> {
        if n < 2 || rate_per_worker <= 0.0 {
            return None;
        }
        let nf = n as f64;
        let r = rate_per_worker;
        match self {
            // Uniform edge weight w = r/2: λ₂ = 2w(1 − cos(2π/n)) and the
            // adjacent-node resistance is (1/w)·(n−1)/n.
            Topology::Ring if n >= 3 => {
                let lambda2 = r * (1.0 - (2.0 * std::f64::consts::PI / nf).cos());
                Some((1.0 / lambda2, (nf - 1.0) / (r * nf)))
            }
            // Uniform weight w = r/(n−1): λ = n·w with multiplicity n−1,
            // and χ₁ = χ₂ (paper Sec. 4.2).
            Topology::Complete => {
                let chi = (nf - 1.0) / (r * nf);
                Some((chi, chi))
            }
            // Uniform weight w = r/2·(1/(n−1) + 1): spectrum {0, w, n·w},
            // hub–leaf resistance exactly 1/w.
            Topology::Star if n >= 3 => {
                let w = 0.5 * r * (1.0 / (nf - 1.0) + 1.0);
                Some((1.0 / w, 0.5 / w))
            }
            _ => None,
        }
    }
}

/// Exponential-graph bridges over the cluster representatives (node
/// `c·size` of each cluster): rep(c) — rep((c + 2^j) mod clusters) for
/// every power of two below the cluster count.
fn add_exponential_bridges(add: &mut impl FnMut(usize, usize), clusters: usize, size: usize) {
    let mut step = 1usize;
    while step < clusters {
        for c in 0..clusters {
            add(c * size, ((c + step) % clusters) * size);
        }
        step *= 2;
    }
}

/// An undirected communication graph over `n` workers.
///
/// Adjacency is stored in CSR form — one flat `usize` array sliced by a
/// per-node offset table — instead of n separate `Vec`s, so a 10⁵-node
/// fleet is two contiguous allocations and a degree/neighbor query never
/// chases a heap pointer per node. Each adjacency entry also carries the
/// index of its edge in `edges`, which is what makes per-edge rate lookups
/// along a node's neighborhood O(deg) (`neighbor_edges`/`edge_index`).
#[derive(Clone, Debug)]
pub struct Graph {
    pub n: usize,
    /// Canonical edge list with `i < j`, sorted.
    pub edges: Vec<(usize, usize)>,
    /// CSR offsets: node `i`'s adjacency is `adj_offsets[i]..adj_offsets[i+1]`.
    adj_offsets: Vec<usize>,
    /// Flat neighbor array, sorted within each node's slice.
    adj_nodes: Vec<usize>,
    /// `adj_edges[k]` is the `edges` index of the edge behind `adj_nodes[k]`.
    adj_edges: Vec<usize>,
}

impl Graph {
    /// Build a topology over `n` workers.
    pub fn build(topology: &Topology, n: usize) -> crate::Result<Graph> {
        anyhow::ensure!(n >= 2, "need at least 2 workers, got {n}");
        let mut set = std::collections::BTreeSet::new();
        let mut add = |i: usize, j: usize| {
            if i != j {
                set.insert((i.min(j), i.max(j)));
            }
        };
        match topology {
            Topology::Complete => {
                for i in 0..n {
                    for j in i + 1..n {
                        add(i, j);
                    }
                }
            }
            Topology::Ring => {
                for i in 0..n {
                    add(i, (i + 1) % n);
                }
            }
            Topology::Path => {
                for i in 0..n - 1 {
                    add(i, i + 1);
                }
            }
            Topology::Exponential => {
                let mut k = 1usize;
                while k < n {
                    for i in 0..n {
                        add(i, (i + k) % n);
                    }
                    k *= 2;
                }
            }
            Topology::Star => {
                for i in 1..n {
                    add(0, i);
                }
            }
            Topology::Torus { rows, cols } => {
                anyhow::ensure!(
                    rows * cols == n,
                    "torus {rows}x{cols} != n={n}"
                );
                for r in 0..*rows {
                    for c in 0..*cols {
                        let id = r * cols + c;
                        if *cols > 1 {
                            add(id, r * cols + (c + 1) % cols);
                        }
                        if *rows > 1 {
                            add(id, ((r + 1) % rows) * cols + c);
                        }
                    }
                }
            }
            Topology::Hypercube => {
                anyhow::ensure!(n.is_power_of_two(), "hypercube needs power-of-two n, got {n}");
                let bits = n.trailing_zeros() as usize;
                for i in 0..n {
                    for b in 0..bits {
                        add(i, i ^ (1 << b));
                    }
                }
            }
            Topology::ClusterRing { clusters, ring } => {
                anyhow::ensure!(
                    clusters * ring == n,
                    "cluster_ring {clusters}x{ring} != n={n}"
                );
                anyhow::ensure!(*clusters >= 1 && *ring >= 1, "cluster_ring dims must be ≥ 1");
                for c in 0..*clusters {
                    let base = c * ring;
                    for i in 0..*ring {
                        add(base + i, base + (i + 1) % ring);
                    }
                }
                add_exponential_bridges(&mut add, *clusters, *ring);
            }
            Topology::ClusterComplete { clusters, cluster } => {
                anyhow::ensure!(
                    clusters * cluster == n,
                    "cluster_complete {clusters}x{cluster} != n={n}"
                );
                anyhow::ensure!(
                    *clusters >= 1 && *cluster >= 1,
                    "cluster_complete dims must be ≥ 1"
                );
                for c in 0..*clusters {
                    let base = c * cluster;
                    for i in 0..*cluster {
                        for j in i + 1..*cluster {
                            add(base + i, base + j);
                        }
                    }
                }
                add_exponential_bridges(&mut add, *clusters, *cluster);
            }
            Topology::ErdosRenyi { p, seed } => {
                anyhow::ensure!((0.0..=1.0).contains(p), "erdos p out of range");
                let mut rng = crate::rng::Xoshiro256::seed_from_u64(*seed);
                for attempt in 0..1000 {
                    set.clear();
                    for i in 0..n {
                        for j in i + 1..n {
                            if rng.gen_bool(*p) {
                                set.insert((i, j));
                            }
                        }
                    }
                    let g = Graph::from_edge_set(n, &set);
                    if g.is_connected() {
                        return Ok(g);
                    }
                    anyhow::ensure!(attempt < 999, "could not sample connected G({n},{p})");
                }
            }
        }
        let g = Graph::from_edge_set(n, &set);
        anyhow::ensure!(g.is_connected(), "{} graph on n={n} is disconnected", topology.name());
        Ok(g)
    }

    /// Build a graph from an explicit edge list (loops dropped, duplicates
    /// merged, endpoints canonicalized to `i < j`). Used by the scenario
    /// layer to form the *union graph* over all phases of a time-varying
    /// network; connectivity is NOT enforced here.
    pub fn from_edges(n: usize, edges: impl IntoIterator<Item = (usize, usize)>) -> Graph {
        let set: std::collections::BTreeSet<(usize, usize)> = edges
            .into_iter()
            .filter(|&(i, j)| i != j)
            .map(|(i, j)| (i.min(j), i.max(j)))
            .collect();
        Graph::from_edge_set(n, &set)
    }

    fn from_edge_set(n: usize, set: &std::collections::BTreeSet<(usize, usize)>) -> Graph {
        let edges: Vec<(usize, usize)> = set.iter().copied().collect();
        // CSR fill. Walking the lexicographically sorted edge list keeps
        // each node's slice sorted for free: for node `i`, every lower
        // partner (h, i) precedes every higher partner (i, j) in the edge
        // order, and both runs arrive ascending.
        let mut adj_offsets = vec![0usize; n + 1];
        for &(i, j) in &edges {
            adj_offsets[i + 1] += 1;
            adj_offsets[j + 1] += 1;
        }
        for i in 0..n {
            adj_offsets[i + 1] += adj_offsets[i];
        }
        let mut cursor = adj_offsets[..n].to_vec();
        let mut adj_nodes = vec![0usize; 2 * edges.len()];
        let mut adj_edges = vec![0usize; 2 * edges.len()];
        for (e, &(i, j)) in edges.iter().enumerate() {
            adj_nodes[cursor[i]] = j;
            adj_edges[cursor[i]] = e;
            cursor[i] += 1;
            adj_nodes[cursor[j]] = i;
            adj_edges[cursor[j]] = e;
            cursor[j] += 1;
        }
        Graph { n, edges, adj_offsets, adj_nodes, adj_edges }
    }

    /// Sorted neighbor list of worker `i` (a CSR slice — no allocation).
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj_nodes[self.adj_offsets[i]..self.adj_offsets[i + 1]]
    }

    /// Edge indices (into [`Graph::edges`]) of worker `i`'s incident
    /// edges, parallel to [`Graph::neighbors`].
    pub fn neighbor_edges(&self, i: usize) -> &[usize] {
        &self.adj_edges[self.adj_offsets[i]..self.adj_offsets[i + 1]]
    }

    /// Degree of worker `i`.
    pub fn degree(&self, i: usize) -> usize {
        self.adj_offsets[i + 1] - self.adj_offsets[i]
    }

    /// Whether `(i, j)` is an edge.
    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        self.neighbors(i).binary_search(&j).is_ok()
    }

    /// Index of edge `(i, j)` in [`Graph::edges`], if present — O(log deg).
    pub fn edge_index(&self, i: usize, j: usize) -> Option<usize> {
        let pos = self.neighbors(i).binary_search(&j).ok()?;
        Some(self.neighbor_edges(i)[pos])
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut queue = std::collections::VecDeque::from([0usize]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for &v in self.neighbors(u) {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == self.n
    }

    /// Per-edge Poisson rates under the paper's protocol: each worker
    /// participates in p2p averagings at total rate `rate_per_worker`
    /// (communications per gradient step in expectation), choosing peers
    /// uniformly among its neighbors. The symmetric per-edge rate is then
    /// `λ^ij = rate/2 · (1/deg(i) + 1/deg(j))`, which for regular graphs
    /// reduces to `rate / deg` and satisfies `Σ_j λ^ij = rate`.
    pub fn edge_rates(&self, rate_per_worker: f64) -> Vec<f64> {
        self.edges
            .iter()
            .map(|&(i, j)| {
                0.5 * rate_per_worker
                    * (1.0 / self.degree(i) as f64 + 1.0 / self.degree(j) as f64)
            })
            .collect()
    }

    /// The instantaneous expected Laplacian Λ (Definition 3.1) for the
    /// given per-edge rates (aligned with `self.edges`).
    pub fn laplacian(&self, rates: &[f64]) -> Matrix {
        assert_eq!(rates.len(), self.edges.len());
        let mut lap = Matrix::zeros(self.n);
        for (&(i, j), &w) in self.edges.iter().zip(rates) {
            lap[(i, i)] += w;
            lap[(j, j)] += w;
            lap[(i, j)] -= w;
            lap[(j, i)] -= w;
        }
        lap
    }

    /// Compute (χ₁, χ₂) and related spectral quantities for per-worker
    /// communication rate `rate_per_worker`.
    pub fn spectrum(&self, rate_per_worker: f64) -> Spectrum {
        let rates = self.edge_rates(rate_per_worker);
        self.spectrum_with_rates(&rates)
    }

    /// Same as [`Graph::spectrum`] but with explicit per-edge rates.
    pub fn spectrum_with_rates(&self, rates: &[f64]) -> Spectrum {
        let lap = self.laplacian(rates);
        let eig = sym_eig(&lap);
        // λ₁ ≈ 0 (connected ⇒ simple kernel); algebraic connectivity is λ₂.
        let lambda2 = eig.values[1];
        let lambda_max = *eig.values.last().unwrap();
        let chi1 = 1.0 / lambda2;
        let pinv = sym_pinv(&lap, 1e-10);
        let mut max_resist = 0.0f64;
        for &(i, j) in &self.edges {
            // (e_i - e_j)ᵀ Λ⁺ (e_i - e_j)
            let r = pinv[(i, i)] + pinv[(j, j)] - 2.0 * pinv[(i, j)];
            max_resist = max_resist.max(r);
        }
        let chi2 = 0.5 * max_resist;
        let trace: f64 = (0..self.n).map(|i| lap[(i, i)]).sum();
        Spectrum { chi1, chi2, lambda2, lambda_max, trace }
    }

    /// Sparse-path spectrum via the `linalg::lanczos` estimator — O(|ℰ|)
    /// per matvec, never forms a dense matrix. Exact below
    /// [`lanczos::DENSE_EXACT_LIMIT`] nodes (full deflated spectrum);
    /// truncated above it (λ₂ from inverse Lanczos, χ₂ from CG-exact
    /// candidate-edge resistances — see the `lanczos` module docs).
    pub fn spectrum_lanczos(&self, rates: &[f64], opts: &LanczosOptions) -> Spectrum {
        let est = lanczos::estimate_spectrum(self.n, &self.edges, rates, opts);
        // Tr(Λ) = 2·Σ rates, exact without any eigensolve.
        let trace = 2.0 * rates.iter().sum::<f64>();
        Spectrum {
            chi1: 1.0 / est.lambda2,
            chi2: 0.5 * est.max_resistance,
            lambda2: est.lambda2,
            lambda_max: est.lambda_max,
            trace,
        }
    }

    /// Scale-dispatching spectrum: the dense Jacobi route (bit-identical
    /// to [`Graph::spectrum_with_rates`], so existing small-n replay
    /// checksums hold) up to [`lanczos::DENSE_EXACT_LIMIT`] nodes, the
    /// sparse Lanczos estimator beyond.
    pub fn spectrum_auto(&self, rates: &[f64]) -> Spectrum {
        if self.n <= lanczos::DENSE_EXACT_LIMIT {
            self.spectrum_with_rates(rates)
        } else {
            self.spectrum_lanczos(rates, &LanczosOptions::sized_for(self.n))
        }
    }
}

/// Spectral summary of a rate-weighted Laplacian.
#[derive(Clone, Copy, Debug)]
pub struct Spectrum {
    /// Inverse algebraic connectivity (Eq. 2).
    pub chi1: f64,
    /// Maximal effective resistance (Eq. 3).
    pub chi2: f64,
    /// Algebraic connectivity λ₂(Λ).
    pub lambda2: f64,
    /// Largest eigenvalue λ_max(Λ).
    pub lambda_max: f64,
    /// Tr(Λ); the expected number of communications per unit time is
    /// Tr(Λ)/2 (Prop. 3.6).
    pub trace: f64,
}

impl Spectrum {
    /// The accelerated connectivity factor `√(χ₁ χ₂)` appearing in the
    /// A²CiD² rates.
    pub fn chi_acc(&self) -> f64 {
        (self.chi1 * self.chi2).sqrt()
    }

    /// Expected communications per time unit across the network, Tr(Λ)/2.
    pub fn comms_per_unit_time(&self) -> f64 {
        0.5 * self.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64, rel: f64) -> bool {
        (a - b).abs() <= rel * b.abs().max(1.0)
    }

    #[test]
    fn complete_graph_counts() {
        let g = Graph::build(&Topology::Complete, 8).unwrap();
        assert_eq!(g.edges.len(), 28);
        assert!(g.is_connected());
        assert!((0..8).all(|i| g.degree(i) == 7));
    }

    #[test]
    fn ring_graph_counts() {
        let g = Graph::build(&Topology::Ring, 16).unwrap();
        assert_eq!(g.edges.len(), 16);
        assert!((0..16).all(|i| g.degree(i) == 2));
        assert!(g.has_edge(0, 15));
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(0, 2));
    }

    #[test]
    fn exponential_graph_structure() {
        // n=16: neighbors of 0 are ±1, ±2, ±4, 8 → degree 7.
        let g = Graph::build(&Topology::Exponential, 16).unwrap();
        assert_eq!(g.degree(0), 7);
        assert!(g.has_edge(0, 8));
        assert!(g.has_edge(0, 4));
        assert!(!g.has_edge(0, 3));
    }

    #[test]
    fn star_path_torus_hypercube() {
        let s = Graph::build(&Topology::Star, 9).unwrap();
        assert_eq!(s.degree(0), 8);
        assert!((1..9).all(|i| s.degree(i) == 1));

        let p = Graph::build(&Topology::Path, 5).unwrap();
        assert_eq!(p.edges.len(), 4);

        let t = Graph::build(&Topology::Torus { rows: 4, cols: 4 }, 16).unwrap();
        assert!((0..16).all(|i| t.degree(i) == 4));

        let h = Graph::build(&Topology::Hypercube, 16).unwrap();
        assert!((0..16).all(|i| h.degree(i) == 4));
    }

    #[test]
    fn erdos_renyi_connected() {
        let g = Graph::build(&Topology::ErdosRenyi { p: 0.3, seed: 5 }, 20).unwrap();
        assert!(g.is_connected());
    }

    #[test]
    fn laplacian_row_sums_zero_and_psd() {
        for topo in [Topology::Ring, Topology::Complete, Topology::Exponential] {
            let g = Graph::build(&topo, 12).unwrap();
            let lap = g.laplacian(&g.edge_rates(1.0));
            for i in 0..12 {
                let row_sum: f64 = (0..12).map(|j| lap[(i, j)]).sum();
                assert!(row_sum.abs() < 1e-12);
            }
            let eig = sym_eig(&lap);
            assert!(eig.values[0].abs() < 1e-9, "kernel eigenvalue");
            assert!(eig.values.iter().all(|&w| w > -1e-9), "PSD");
        }
    }

    #[test]
    fn ring_chi1_closed_form() {
        // Ring with per-worker rate 1 ⇒ per-edge weight 1/2;
        // λ₂ = 2·(1/2)·(1 − cos(2π/n)) ⇒ χ₁ = 1/(1 − cos(2π/n)).
        for n in [8usize, 16, 32] {
            let g = Graph::build(&Topology::Ring, n).unwrap();
            let s = g.spectrum(1.0);
            let expect = 1.0 / (1.0 - (2.0 * std::f64::consts::PI / n as f64).cos());
            assert!(approx(s.chi1, expect, 1e-6), "n={n}: {} vs {expect}", s.chi1);
        }
    }

    #[test]
    fn ring_chi2_closed_form() {
        // Adjacent-node effective resistance on a weighted cycle
        // (conductance w per edge): (1/w)·(n−1)/n; χ₂ is half that.
        let n = 16;
        let g = Graph::build(&Topology::Ring, n).unwrap();
        let s = g.spectrum(1.0);
        let w = 0.5;
        let expect = 0.5 * (1.0 / w) * (n as f64 - 1.0) / n as f64;
        assert!(approx(s.chi2, expect, 1e-6), "{} vs {expect}", s.chi2);
    }

    #[test]
    fn complete_chi1_equals_chi2() {
        // Paper Sec. 4.2: χ₁ = χ₂ for the complete graph.
        let g = Graph::build(&Topology::Complete, 16).unwrap();
        let s = g.spectrum(1.0);
        assert!(approx(s.chi1, s.chi2, 1e-6), "{} vs {}", s.chi1, s.chi2);
        // Fig. 6: (χ₁, χ₂) ≈ (1, 1) at rate 1.
        assert!(approx(s.chi1, 15.0 / 16.0, 1e-6));
    }

    #[test]
    fn fig6_paper_values_n16() {
        // Fig. 6 reports approximate (χ₁, χ₂) at 1 comm/grad:
        // complete (1,1), exponential (2,1), ring (13,1).
        let c = Graph::build(&Topology::Complete, 16).unwrap().spectrum(1.0);
        let e = Graph::build(&Topology::Exponential, 16).unwrap().spectrum(1.0);
        let r = Graph::build(&Topology::Ring, 16).unwrap().spectrum(1.0);
        assert!(c.chi1.round() == 1.0 && c.chi2.round() == 1.0, "complete {c:?}");
        assert!(e.chi1.round() <= 3.0 && e.chi2.round() == 1.0, "exp {e:?}");
        assert!((r.chi1 - 13.0).abs() < 1.0, "ring chi1 {}", r.chi1);
        assert!(r.chi2.round() == 1.0, "ring chi2 {}", r.chi2);
    }

    #[test]
    fn chi2_le_chi1_across_topologies() {
        for topo in [
            Topology::Ring,
            Topology::Complete,
            Topology::Exponential,
            Topology::Star,
            Topology::Path,
            Topology::Hypercube,
        ] {
            let g = Graph::build(&topo, 16).unwrap();
            let s = g.spectrum(1.0);
            assert!(
                s.chi2 <= s.chi1 * (1.0 + 1e-9),
                "{}: chi2={} > chi1={}",
                topo.name(),
                s.chi2,
                s.chi1
            );
        }
    }

    #[test]
    fn trace_matches_total_rate() {
        // Σ_j λ^ij = rate for regular graphs ⇒ Tr(Λ) = n·rate.
        let g = Graph::build(&Topology::Ring, 10).unwrap();
        let s = g.spectrum(2.0);
        assert!(approx(s.trace, 20.0, 1e-9));
        assert!(approx(s.comms_per_unit_time(), 10.0, 1e-9));
    }

    #[test]
    fn topology_parse_round_trip() {
        assert_eq!(Topology::parse("ring").unwrap(), Topology::Ring);
        assert_eq!(Topology::parse("complete").unwrap(), Topology::Complete);
        assert_eq!(Topology::parse("exp").unwrap(), Topology::Exponential);
        assert_eq!(
            Topology::parse("torus:4x8").unwrap(),
            Topology::Torus { rows: 4, cols: 8 }
        );
        assert!(Topology::parse("nope").is_err());
        assert!(Topology::parse("torus:4").is_err());
        // spec() is the inverse of parse() for every variant.
        for s in ["ring", "complete", "exponential", "star", "path", "hypercube",
                  "torus:4x8", "erdos:0.3:42", "cluster_ring:10x100",
                  "cluster_complete:8x16"] {
            let t = Topology::parse(s).unwrap();
            assert_eq!(Topology::parse(&t.spec()).unwrap(), t, "spec round-trip of '{s}'");
        }
    }

    #[test]
    fn hierarchical_grammar_round_trip_and_errors() {
        assert_eq!(
            Topology::parse("cluster_ring:10x100").unwrap(),
            Topology::ClusterRing { clusters: 10, ring: 100 }
        );
        assert_eq!(
            Topology::parse("cluster_complete:4x8").unwrap(),
            Topology::ClusterComplete { clusters: 4, cluster: 8 }
        );
        assert_eq!(Topology::ClusterRing { clusters: 10, ring: 100 }.spec(), "cluster_ring:10x100");
        assert_eq!(
            Topology::ClusterComplete { clusters: 4, cluster: 8 }.spec(),
            "cluster_complete:4x8"
        );
        // Error paths: missing dims, malformed dims, wrong arity.
        for bad in [
            "cluster_ring", "cluster_ring:4", "cluster_ring:axb", "cluster_ring:4x",
            "cluster_ring:4x8x2", "cluster_complete", "cluster_complete:x8",
            "cluster_rings:4x8",
        ] {
            assert!(Topology::parse(bad).is_err(), "should reject '{bad}'");
        }
        // Dim mismatch fails at build, not parse.
        let t = Topology::parse("cluster_ring:4x8").unwrap();
        assert!(Graph::build(&t, 33).is_err());
        assert!(Graph::build(&t, 32).is_ok());
    }

    #[test]
    fn cluster_ring_structure() {
        // 4 rings of 8, representatives 0, 8, 16, 24 bridged by the
        // exponential graph over cluster indices: steps 1 and 2 add
        // {0-8, 8-16, 16-24, 0-24} and {0-16, 8-24} → 6 bridges.
        let g = Graph::build(&Topology::ClusterRing { clusters: 4, ring: 8 }, 32).unwrap();
        assert!(g.is_connected());
        assert_eq!(g.edges.len(), 4 * 8 + 6);
        // Representatives: ring degree 2 + 3 bridge partners each.
        for rep in [0, 8, 16, 24] {
            assert_eq!(g.degree(rep), 5, "rep {rep}");
        }
        // Non-representatives keep plain ring degree.
        assert_eq!(g.degree(1), 2);
        assert!(g.has_edge(0, 16));
        assert!(!g.has_edge(1, 9));

        // Degenerate shapes stay valid: one cluster = plain ring; size-1
        // clusters = plain exponential graph over representatives.
        let one = Graph::build(&Topology::ClusterRing { clusters: 1, ring: 8 }, 8).unwrap();
        let ring = Graph::build(&Topology::Ring, 8).unwrap();
        assert_eq!(one.edges, ring.edges);
        let thin = Graph::build(&Topology::ClusterRing { clusters: 8, ring: 1 }, 8).unwrap();
        let expo = Graph::build(&Topology::Exponential, 8).unwrap();
        assert_eq!(thin.edges, expo.edges);
    }

    #[test]
    fn cluster_complete_structure() {
        let g =
            Graph::build(&Topology::ClusterComplete { clusters: 4, cluster: 4 }, 16).unwrap();
        assert!(g.is_connected());
        // 4 complete-4 clusters (6 edges each) + 6 bridges.
        assert_eq!(g.edges.len(), 4 * 6 + 6);
        assert!(g.has_edge(0, 4));
        assert!(g.has_edge(1, 2));
        assert!(!g.has_edge(1, 5));
    }

    #[test]
    fn csr_accessors_are_coherent() {
        let g = Graph::build(&Topology::Exponential, 16).unwrap();
        for i in 0..16 {
            let nbrs = g.neighbors(i);
            assert_eq!(nbrs.len(), g.degree(i));
            assert!(nbrs.windows(2).all(|w| w[0] < w[1]), "sorted CSR slice");
            for (&j, &e) in nbrs.iter().zip(g.neighbor_edges(i)) {
                let (a, b) = g.edges[e];
                assert!((a, b) == (i.min(j), i.max(j)), "edge back-pointer");
                assert_eq!(g.edge_index(i, j), Some(e));
                assert_eq!(g.edge_index(j, i), Some(e));
            }
        }
        assert_eq!(g.edge_index(0, 3), None);
    }

    #[test]
    fn closed_forms_match_dense_spectrum() {
        for (topo, n) in [
            (Topology::Ring, 16usize),
            (Topology::Ring, 9),
            (Topology::Complete, 16),
            (Topology::Star, 12),
        ] {
            for rate in [1.0, 2.5] {
                let (chi1, chi2) = topo.closed_form_chis(n, rate).unwrap();
                let s = Graph::build(&topo, n).unwrap().spectrum(rate);
                assert!(
                    (chi1 - s.chi1).abs() < 1e-8 * s.chi1,
                    "{} n={n} rate={rate}: χ₁ {chi1} vs {}",
                    topo.name(),
                    s.chi1
                );
                assert!(
                    (chi2 - s.chi2).abs() < 1e-8 * s.chi2,
                    "{} n={n} rate={rate}: χ₂ {chi2} vs {}",
                    topo.name(),
                    s.chi2
                );
            }
        }
        assert!(Topology::Hypercube.closed_form_chis(16, 1.0).is_none());
        assert!(Topology::ClusterRing { clusters: 4, ring: 4 }
            .closed_form_chis(16, 1.0)
            .is_none());
    }

    #[test]
    fn spectrum_auto_is_dense_at_small_n() {
        let g = Graph::build(&Topology::Ring, 24).unwrap();
        let rates = g.edge_rates(1.0);
        let dense = g.spectrum_with_rates(&rates);
        let auto = g.spectrum_auto(&rates);
        assert_eq!(dense.chi1.to_bits(), auto.chi1.to_bits());
        assert_eq!(dense.chi2.to_bits(), auto.chi2.to_bits());
        assert_eq!(dense.trace.to_bits(), auto.trace.to_bits());
    }

    #[test]
    fn lanczos_spectrum_agrees_with_dense_on_cluster_ring() {
        let topo = Topology::ClusterRing { clusters: 4, ring: 8 };
        let g = Graph::build(&topo, 32).unwrap();
        let rates = g.edge_rates(1.0);
        let dense = g.spectrum_with_rates(&rates);
        let sparse = g.spectrum_lanczos(&rates, &crate::linalg::lanczos::LanczosOptions::default());
        assert!((sparse.chi1 - dense.chi1).abs() < 1e-6 * dense.chi1);
        assert!((sparse.chi2 - dense.chi2).abs() < 1e-6 * dense.chi2);
    }

    #[test]
    fn cluster_ring_flattens_chi1_versus_flat_ring() {
        // The scaling headline in miniature: at equal n, clusters-of-rings
        // bridged exponentially have far smaller χ₁ than the flat ring,
        // and χ₁ stays ~flat as the cluster count grows.
        let flat = Graph::build(&Topology::Ring, 64).unwrap().spectrum(1.0);
        let hier = Graph::build(&Topology::ClusterRing { clusters: 8, ring: 8 }, 64)
            .unwrap()
            .spectrum(1.0);
        assert!(
            hier.chi1 < 0.5 * flat.chi1,
            "hierarchical χ₁ {} vs flat {}",
            hier.chi1,
            flat.chi1
        );
        let small = Graph::build(&Topology::ClusterRing { clusters: 4, ring: 8 }, 32)
            .unwrap()
            .spectrum(1.0);
        let big = Graph::build(&Topology::ClusterRing { clusters: 16, ring: 8 }, 128)
            .unwrap()
            .spectrum(1.0);
        // Quadrupling the fleet must not blow χ₁ up the way a flat ring
        // would (16× there); allow a loose 3× headroom.
        assert!(
            big.chi1 < 3.0 * small.chi1,
            "χ₁ trend: {} (n=128) vs {} (n=32)",
            big.chi1,
            small.chi1
        );
    }

    #[test]
    fn topology_parse_error_paths() {
        // Torus: missing dims, non-numeric dims, wrong arity.
        for bad in ["torus", "torus:4", "torus:axb", "torus:4x", "torus:4x8x2"] {
            assert!(Topology::parse(bad).is_err(), "should reject '{bad}'");
        }
        // Erdős–Rényi: missing or malformed p / seed.
        for bad in ["erdos", "erdos:nan-ish", "erdos:0.3:xyz"] {
            assert!(Topology::parse(bad).is_err(), "should reject '{bad}'");
        }
        // Unknown names (including near-misses) fail loudly.
        for bad in ["", "rings", "complete-graph", "hyper", "expo "] {
            assert!(Topology::parse(bad).is_err(), "should reject '{bad}'");
        }
        // Out-of-range erdos p parses the float but fails build.
        let p2 = Topology::parse("erdos:1.5").unwrap();
        assert!(Graph::build(&p2, 8).is_err());
        // Erdos seed defaults to 0 when omitted.
        assert_eq!(
            Topology::parse("erdos:0.5").unwrap(),
            Topology::ErdosRenyi { p: 0.5, seed: 0 }
        );
    }

    #[test]
    fn from_edges_canonicalizes() {
        // Duplicates, reversed pairs and self-loops collapse away.
        let g = Graph::from_edges(4, vec![(1, 0), (0, 1), (2, 2), (3, 2), (0, 3)]);
        assert_eq!(g.edges, vec![(0, 1), (0, 3), (2, 3)]);
        assert_eq!(g.degree(0), 2);
        assert!(!g.is_connected(), "2 is only reachable via 3");
    }
}
