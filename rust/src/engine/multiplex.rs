//! Multiplexed virtual-worker execution for massive fleets.
//!
//! The threaded runtime spends one OS thread per worker — fine at the
//! paper's n = 64, hopeless at the ROADMAP's 10⁵–10⁶. This module keeps
//! the *exact* virtual-time event stream ([`VirtualTimeScheduler`], so
//! every replay guarantee holds) but executes it cooperatively: M
//! virtual workers multiplexed over one fixed [`ChunkPool`] whose width
//! is pinned by `A2CID2_MUX_THREADS` (falling back to the kernel pool's
//! `A2CID2_POOL_THREADS`, so one knob can still size both).
//!
//! ## Worker→lane affinity
//!
//! Within a frame, ticks are bucketed by a stable hash of their primary
//! worker id onto a preferred pool lane before fan-out, and bucket `l`'s
//! tick groups carry chunk ids `≡ l (mod width)` — exactly the range
//! lane `l` drains first under the pool's sticky claiming. A virtual
//! worker therefore keeps landing on the same lane — and, with
//! `A2CID2_PIN`, the same core's L2 — frame after frame, while
//! steal-after-drain keeps lanes with light buckets busy. Ticks commute
//! within a frame (disjoint worker sets), so the grouping is invisible
//! to the result bits at any width, pinned or not.
//!
//! ## Frames
//!
//! The scheduler's event stream is cut into **frames**: maximal runs of
//! consecutive events whose worker sets are pairwise disjoint (a
//! gradient touches one worker, a pairwise averaging touches two). Ticks
//! within a frame commute — each handler mutates only its own workers'
//! state — so the pool may execute them in any order, on any lanes, and
//! the result is bit-identical to serial in-order execution. Frame
//! boundaries are a pure function of the event stream (never of thread
//! count or timing), so the partition itself is deterministic too: the
//! multiplexed replay equals the serial [`VirtualTimeScheduler`] replay
//! bit for bit at any pool width, which is what lets the golden replay
//! checksums pin it.
//!
//! With n workers and rate-proportional event mixing, the birthday bound
//! puts the expected disjoint-prefix length at Θ(√n): ~300 ticks per
//! frame at n = 10⁵ — far more than enough to keep a laptop-class pool
//! saturated while the per-frame bookkeeping stays O(frame).
//!
//! Scheduler-recorded [`NetChange`]s (churn re-inits, retunes) are
//! barriers: a change's effect may span workers (a re-join copies a
//! donor's state), so a frame never crosses one. The caller processes
//! [`Frame::changes`] serially — exactly like the serial engine loop —
//! then hands [`Frame::ticks`] to [`MultiplexEngine::execute`].

use std::cell::UnsafeCell;

use crate::config::scenario::{NetUpdate, NetworkPlan};
use crate::engine::scheduler::{NetChange, Scheduler, Tick, VirtualTimeScheduler};
use crate::gossip::pool::{self, ChunkPool};

/// Hard cap on ticks per frame: bounds the caller's frame buffer and the
/// latency between change barriers without affecting determinism (the
/// cap cuts the same prefix regardless of pool width).
pub const MAX_FRAME_TICKS: usize = 4096;

/// Ticks per pool task: each claimed chunk runs a fixed contiguous span
/// of the frame, amortizing the dispatch CAS over real work.
const TICKS_PER_CHUNK: usize = 16;

/// One multiplexed execution unit: changes first (serial, on the
/// caller), then a worker-disjoint run of ticks (parallel, on the pool).
#[derive(Debug, Default)]
pub struct Frame {
    /// Churn/retune changes that happened at-or-before the first tick;
    /// process these before executing `ticks`, in order.
    pub changes: Vec<NetChange>,
    /// Consecutive events with pairwise-disjoint worker sets, in virtual
    /// time order.
    pub ticks: Vec<Tick>,
}

/// The multiplexed engine: a [`VirtualTimeScheduler`] plus frame
/// assembly and a private pool to fan frames out on.
///
/// The pool is deliberately NOT [`ChunkPool::global`]: tick handlers
/// call the gossip kernels, which shard large-`dim` buffers across the
/// global pool — nesting one pool inside a *different* pool is safe
/// (distinct job slots; the inner `try_run` simply falls back to serial
/// under contention), re-entering the same pool is not.
pub struct MultiplexEngine {
    sched: VirtualTimeScheduler,
    pool: ChunkPool,
    /// Tick popped but not yet emitted: it conflicted with the frame
    /// under assembly, or changes preceded it.
    held: Option<Tick>,
    /// Changes that precede `held`.
    held_changes: Vec<NetChange>,
    /// `stamp[w] == frame_id` ⇔ worker w already has a tick in the frame
    /// under assembly (O(1) conflict test, no per-frame clearing).
    stamp: Vec<u64>,
    frame_id: u64,
}

impl MultiplexEngine {
    /// Build from a compiled plan; pool width follows
    /// `A2CID2_MUX_THREADS`, falling back to `A2CID2_POOL_THREADS` (the
    /// caller's thread participates, so width 1 means zero extra threads
    /// — fully serial).
    pub fn new(plan: &NetworkPlan, seed: u64) -> Self {
        Self::with_extra_threads(plan, seed, pool::configured_mux_extra_threads())
    }

    /// Build with an explicit number of extra pool threads (tests pin
    /// widths to prove bit-identity across them).
    pub fn with_extra_threads(plan: &NetworkPlan, seed: u64, extra: usize) -> Self {
        Self {
            sched: VirtualTimeScheduler::new(plan, seed),
            pool: ChunkPool::new(extra),
            held: None,
            held_changes: Vec::new(),
            stamp: vec![0; plan.union.n],
            frame_id: 0,
        }
    }

    /// Current virtual time (the last popped event's timestamp).
    pub fn now(&self) -> f64 {
        self.sched.now()
    }

    pub fn n_grad_events(&self) -> u64 {
        self.sched.n_grad_events()
    }

    pub fn n_comm_events(&self) -> u64 {
        self.sched.n_comm_events()
    }

    /// Total parallel lanes of the private pool.
    pub fn lanes(&self) -> usize {
        self.pool.lanes()
    }

    fn tick_workers(tick: Tick) -> (usize, Option<usize>) {
        match tick {
            Tick::Grad { worker, .. } => (worker, None),
            Tick::Comm { i, j, .. } => (i, Some(j)),
        }
    }

    fn conflicts(&self, tick: Tick) -> bool {
        let (a, b) = Self::tick_workers(tick);
        self.stamp[a] == self.frame_id || b.is_some_and(|w| self.stamp[w] == self.frame_id)
    }

    fn claim(&mut self, tick: Tick) {
        let (a, b) = Self::tick_workers(tick);
        self.stamp[a] = self.frame_id;
        if let Some(w) = b {
            self.stamp[w] = self.frame_id;
        }
    }

    /// Assemble the next frame: the maximal disjoint prefix of the
    /// remaining event stream (up to [`MAX_FRAME_TICKS`]), cut early at
    /// any [`NetChange`] barrier. `None` once the stream is exhausted.
    pub fn next_frame(&mut self) -> Option<Frame> {
        self.frame_id += 1;
        let mut frame =
            Frame { changes: std::mem::take(&mut self.held_changes), ticks: Vec::new() };
        if let Some(t) = self.held.take() {
            self.claim(t);
            frame.ticks.push(t);
        }
        while frame.ticks.len() < MAX_FRAME_TICKS {
            let Some(tick) = self.sched.next() else { break };
            let changes = self.sched.drain_changes();
            if !changes.is_empty() {
                if frame.ticks.is_empty() {
                    // Nothing emitted yet: the changes still precede
                    // every tick of THIS frame.
                    frame.changes.extend(changes);
                    self.claim(tick);
                    frame.ticks.push(tick);
                    continue;
                }
                // The changes sit between the frame's ticks and `tick`:
                // close here, re-emit both at the next frame.
                self.held = Some(tick);
                self.held_changes = changes;
                break;
            }
            if self.conflicts(tick) {
                self.held = Some(tick);
                break;
            }
            self.claim(tick);
            frame.ticks.push(tick);
        }
        (!frame.ticks.is_empty() || !frame.changes.is_empty()).then_some(frame)
    }

    /// Execute a frame's ticks over per-worker states on the pool.
    ///
    /// `grad(worker, t, state)` handles a gradient spike, `comm(t, a, b)`
    /// a pairwise averaging between the edge's endpoint states. Handlers
    /// run concurrently for distinct ticks but — by the frame's disjoint
    /// worker sets — never touch the same state, so any per-state
    /// mutation is race-free and the result is order-independent.
    /// Handlers must not mutate anything shared besides their states.
    pub fn execute<W, G, C>(&self, states: &mut [W], ticks: &[Tick], grad: &G, comm: &C)
    where
        W: Send,
        G: Fn(usize, f64, &mut W) + Sync,
        C: Fn(f64, &mut W, &mut W) + Sync,
    {
        // Reinterpret the exclusive borrow as shared cells: sound
        // because the frame invariant gives each index to at most one
        // tick, and `UnsafeCell<W>` is layout-identical to `W`.
        struct Cells<'a, W>(&'a [UnsafeCell<W>]);
        unsafe impl<W: Send> Sync for Cells<'_, W> {}
        let cells: Cells<'_, W> =
            Cells(unsafe { &*(states as *mut [W] as *const [UnsafeCell<W>]) });
        let run_tick = |tick: &Tick| match *tick {
            Tick::Grad { worker, t } => {
                // SAFETY: `worker` appears in exactly one frame tick.
                let w = unsafe { &mut *cells.0[worker].get() };
                grad(worker, t, w);
            }
            Tick::Comm { i, j, t } => {
                debug_assert_ne!(i, j, "self-loop edge in frame");
                // SAFETY: i ≠ j, and each appears in exactly one tick.
                let (a, b) = unsafe { (&mut *cells.0[i].get(), &mut *cells.0[j].get()) };
                comm(t, a, b);
            }
        };
        let width = self.pool.lanes();
        if width <= 1 || ticks.len() <= TICKS_PER_CHUNK {
            // One lane (or one group): contiguous spans, nothing to route.
            let n_chunks = ticks.len().div_ceil(TICKS_PER_CHUNK);
            self.pool.run(n_chunks, &|c| {
                let lo = c * TICKS_PER_CHUNK;
                let hi = (lo + TICKS_PER_CHUNK).min(ticks.len());
                for tick in &ticks[lo..hi] {
                    run_tick(tick);
                }
            });
            return;
        }
        // Worker→lane affinity: counting-sort tick indices into per-lane
        // buckets keyed by the primary worker's preferred lane, then hand
        // bucket l out as chunk ids ≡ l (mod width) so the pool's sticky
        // claiming sends each bucket to its lane first. O(frame) and a
        // few small Vecs per frame — noise next to the ticks themselves.
        let mut lane_of = Vec::with_capacity(ticks.len());
        let mut counts = vec![0u32; width];
        for &tick in ticks {
            let (a, _) = Self::tick_workers(tick);
            let lane = Self::preferred_lane(a, width);
            lane_of.push(lane as u32);
            counts[lane] += 1;
        }
        let mut starts = vec![0u32; width + 1];
        for l in 0..width {
            starts[l + 1] = starts[l] + counts[l];
        }
        let mut order = vec![0u32; ticks.len()];
        let mut cursor = starts.clone();
        for (i, &l) in lane_of.iter().enumerate() {
            order[cursor[l as usize] as usize] = i as u32;
            cursor[l as usize] += 1;
        }
        let max_groups =
            counts.iter().map(|&c| (c as usize).div_ceil(TICKS_PER_CHUNK)).max().unwrap_or(0);
        let (order, starts) = (&order, &starts);
        self.pool.run(width * max_groups, &|c| {
            let (lane, group) = (c % width, c / width);
            let bucket_lo = starts[lane] as usize;
            let bucket_hi = starts[lane + 1] as usize;
            let lo = bucket_lo + group * TICKS_PER_CHUNK;
            if lo >= bucket_hi {
                return; // this lane's bucket has fewer groups than the max
            }
            let hi = (lo + TICKS_PER_CHUNK).min(bucket_hi);
            for &ti in &order[lo..hi] {
                run_tick(&ticks[ti as usize]);
            }
        });
    }

    /// Stable worker→lane hash (Fibonacci multiplicative): uniform over
    /// lanes, a pure function of the worker id so a worker's ticks land
    /// on the same lane in every frame of every run.
    fn preferred_lane(worker: usize, width: usize) -> usize {
        (((worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 33) % width as u64) as usize
    }
}

impl Scheduler for MultiplexEngine {
    fn apply(&mut self, upd: &NetUpdate) {
        Scheduler::apply(&mut self.sched, upd);
    }

    fn updates_applied(&self) -> u64 {
        self.sched.updates_applied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::engine::DynamicsCore;
    use crate::gossip::{AcidParams, WorkerState};
    use crate::optim::{LrSchedule, Sgd};

    fn plan(s: &str, n: usize, horizon: f64) -> NetworkPlan {
        Scenario::parse(s).unwrap().compile(n, 1.0, horizon, &vec![1.0; n]).unwrap()
    }

    /// Per-virtual-worker slot: gossip pair plus its private optimizer —
    /// the unit of state the frame invariant hands to exactly one tick.
    struct Slot {
        ws: WorkerState,
        opt: Sgd,
    }

    fn init_slots(n: usize, dim: usize) -> Vec<Slot> {
        (0..n)
            .map(|w| Slot {
                ws: WorkerState::new(
                    (0..dim).map(|d| ((w * 31 + d * 7) % 13) as f32 - 6.0).collect(),
                ),
                opt: Sgd::new(0.9),
            })
            .collect()
    }

    fn test_core() -> DynamicsCore {
        DynamicsCore::with_params(
            AcidParams::accelerated(6.0, 1.5),
            LrSchedule::Constant { lr: 0.05 },
        )
    }

    fn synth_grad(worker: usize, dim: usize) -> Vec<f32> {
        (0..dim).map(|d| ((worker + d) % 5) as f32 * 0.1).collect()
    }

    /// Serial reference: the plain VirtualTimeScheduler loop, one event
    /// at a time, changes drained and processed before each tick.
    fn run_serial(plan: &NetworkPlan, seed: u64, events: usize, dim: usize) -> (Vec<Slot>, u64) {
        let core = test_core();
        let mut sched = VirtualTimeScheduler::new(plan, seed);
        let mut slots = init_slots(plan.union.n, dim);
        let mut in_fleet = vec![true; plan.union.n];
        let mut done = 0u64;
        for _ in 0..events {
            let Some(tick) = sched.next() else { break };
            for ch in sched.drain_changes() {
                apply_change(&core, &mut slots, &mut in_fleet, plan, &ch);
            }
            match tick {
                Tick::Grad { worker, t } => {
                    let g = synth_grad(worker, dim);
                    let s = &mut slots[worker];
                    core.grad_event(&mut s.ws, t, &mut s.opt, &g);
                }
                Tick::Comm { i, j, t } => {
                    let (l, r) = slots.split_at_mut(j);
                    core.comm_event(&mut l[i].ws, &mut r[0].ws, t);
                }
            }
            done += 1;
        }
        (slots, done)
    }

    fn apply_change(
        core: &DynamicsCore,
        slots: &mut [Slot],
        in_fleet: &mut [bool],
        plan: &NetworkPlan,
        ch: &NetChange,
    ) {
        for &w in &ch.left {
            in_fleet[w] = false;
        }
        for &j in &ch.joined {
            let donor = plan.union.neighbors(j).iter().copied().find(|&d| in_fleet[d]);
            if let Some(d) = donor {
                let donor_x = slots[d].ws.x.clone();
                core.rejoin_from(&mut slots[j].ws, &donor_x, ch.t);
            }
        }
        for &j in &ch.joined {
            in_fleet[j] = true;
        }
    }

    fn run_multiplexed(
        plan: &NetworkPlan,
        seed: u64,
        events: usize,
        dim: usize,
        extra: usize,
    ) -> (Vec<Slot>, u64) {
        let core = test_core();
        let mut eng = MultiplexEngine::with_extra_threads(plan, seed, extra);
        let mut slots = init_slots(plan.union.n, dim);
        let mut in_fleet = vec![true; plan.union.n];
        let mut done = 0u64;
        while let Some(frame) = eng.next_frame() {
            for ch in &frame.changes {
                apply_change(&core, &mut slots, &mut in_fleet, plan, ch);
            }
            let take = frame.ticks.len().min(events - done as usize);
            let ticks = &frame.ticks[..take];
            let core_ref = &core;
            eng.execute(
                &mut slots,
                ticks,
                &|worker, t, s: &mut Slot| {
                    let g = synth_grad(worker, dim);
                    core_ref.grad_event(&mut s.ws, t, &mut s.opt, &g);
                },
                &|t, a: &mut Slot, b: &mut Slot| {
                    core_ref.comm_event(&mut a.ws, &mut b.ws, t);
                },
            );
            done += take as u64;
            if done as usize >= events {
                break;
            }
        }
        (slots, done)
    }

    fn assert_slots_bit_equal(a: &[Slot], b: &[Slot]) {
        assert_eq!(a.len(), b.len());
        for (w, (u, v)) in a.iter().zip(b).enumerate() {
            let ub: Vec<u32> = u.ws.x.iter().map(|f| f.to_bits()).collect();
            let vb: Vec<u32> = v.ws.x.iter().map(|f| f.to_bits()).collect();
            assert_eq!(ub, vb, "worker {w} x");
            let ub: Vec<u32> = u.ws.xt.iter().map(|f| f.to_bits()).collect();
            let vb: Vec<u32> = v.ws.xt.iter().map(|f| f.to_bits()).collect();
            assert_eq!(ub, vb, "worker {w} xt");
            assert_eq!(u.ws.t_last.to_bits(), v.ws.t_last.to_bits(), "worker {w} t_last");
            assert_eq!(u.ws.n_grads, v.ws.n_grads, "worker {w} n_grads");
            assert_eq!(u.ws.n_comms, v.ws.n_comms, "worker {w} n_comms");
        }
    }

    #[test]
    fn frames_partition_the_event_stream_disjointly() {
        let plan = plan("ring@0,complete@0.5", 10, 50.0);
        let mut eng = MultiplexEngine::with_extra_threads(&plan, 3, 0);
        let mut serial = VirtualTimeScheduler::new(&plan, 3);
        let mut total = 0usize;
        while total < 1500 {
            let frame = eng.next_frame().expect("stream not exhausted");
            assert!(!frame.ticks.is_empty());
            // Disjointness within the frame.
            let mut seen = std::collections::HashSet::new();
            for &tick in &frame.ticks {
                let (a, b) = match tick {
                    Tick::Grad { worker, .. } => (worker, None),
                    Tick::Comm { i, j, .. } => (i, Some(j)),
                };
                assert!(seen.insert(a), "worker {a} twice in one frame");
                if let Some(w) = b {
                    assert!(seen.insert(w), "worker {w} twice in one frame");
                }
            }
            // Concatenation == the serial stream, in order.
            for &tick in &frame.ticks {
                assert_eq!(tick, serial.next().unwrap());
                let _ = serial.drain_changes();
            }
            total += frame.ticks.len();
        }
    }

    #[test]
    fn multiplexed_replay_bit_identical_to_serial_across_widths() {
        // Churn + a topology switch + drift: changes act as barriers and
        // re-joins copy donor state. The multiplexed replay must equal
        // the one-event-at-a-time serial loop bit for bit, at pool width
        // 1 and 4 alike.
        let plan = plan(
            "ring@0,exponential@0.5;drift=0.3:3:1;leave=0.25:0.3:2;join=0.25:0.7",
            12,
            80.0,
        );
        let (serial, n_serial) = run_serial(&plan, 11, 2500, 6);
        assert_eq!(n_serial, 2500);
        for extra in [0usize, 3] {
            let (multi, n_multi) = run_multiplexed(&plan, 11, 2500, 6, extra);
            assert_eq!(n_multi, 2500, "extra={extra}");
            assert_slots_bit_equal(&serial, &multi);
        }
    }

    #[test]
    fn affinity_fanout_runs_every_tick_exactly_once() {
        use std::sync::atomic::{AtomicU32, Ordering};
        // The counting-sort routing must be a permutation of the frame:
        // wide frames (> TICKS_PER_CHUNK) at width > 1 take the bucketed
        // path, and each tick's handler fires exactly once.
        let plan = plan("exponential@0", 1024, 1e6);
        let mut eng = MultiplexEngine::with_extra_threads(&plan, 5, 3);
        let mut slots = init_slots(1024, 2);
        let mut ran_wide_frame = false;
        for _ in 0..20 {
            let Some(frame) = eng.next_frame() else { break };
            ran_wide_frame |= frame.ticks.len() > TICKS_PER_CHUNK;
            let hits: Vec<AtomicU32> = (0..frame.ticks.len()).map(|_| AtomicU32::new(0)).collect();
            let expect: Vec<Tick> = frame.ticks.clone();
            let hits_ref = &hits;
            let expect_ref = &expect;
            let count = |worker: usize, t: f64| {
                let idx = expect_ref
                    .iter()
                    .position(|&tk| match tk {
                        Tick::Grad { worker: w, t: tt } => w == worker && tt == t,
                        Tick::Comm { i, t: tt, .. } => i == worker && tt == t,
                    })
                    .expect("handler fired for a tick not in the frame");
                hits_ref[idx].fetch_add(1, Ordering::SeqCst);
            };
            eng.execute(
                &mut slots,
                &frame.ticks,
                &|worker, t, _s: &mut Slot| count(worker, t),
                &|_t, _a: &mut Slot, _b: &mut Slot| {},
            );
            // Comm ticks don't carry the worker through the handler, so
            // count them via the grad path only; every grad tick must
            // have fired exactly once and nothing else.
            for (k, tick) in frame.ticks.iter().enumerate() {
                if matches!(tick, Tick::Grad { .. }) {
                    assert_eq!(hits[k].load(Ordering::SeqCst), 1, "tick {k}");
                }
            }
        }
        assert!(ran_wide_frame, "test never exercised the bucketed path");
        // The hash is a pure function: same worker, same lane, any call.
        for w in 0..64 {
            let l = MultiplexEngine::preferred_lane(w, 4);
            assert!(l < 4);
            assert_eq!(l, MultiplexEngine::preferred_lane(w, 4));
        }
    }

    #[test]
    fn frame_caps_and_scheduler_trait_surface() {
        let plan = plan("complete@0", 6, 1e6);
        let mut eng = MultiplexEngine::with_extra_threads(&plan, 1, 0);
        assert_eq!(eng.lanes(), 1);
        let before = Scheduler::updates_applied(&eng);
        let frame = eng.next_frame().unwrap();
        assert_eq!(Scheduler::updates_applied(&eng), before);
        assert!(frame.ticks.len() <= MAX_FRAME_TICKS);
        // A complete graph on 6 workers saturates fast: every frame is
        // at most 3 comm ticks wide plus grads, i.e. ≤ 6 workers' worth.
        let mut workers = 0;
        for &t in &frame.ticks {
            workers += match t {
                Tick::Grad { .. } => 1,
                Tick::Comm { .. } => 2,
            };
        }
        assert!(workers <= 6);
        assert!(eng.now() > 0.0);
        // The queue counters include the conflicting tick held for the
        // next frame (if any), hence the one-event slack.
        let popped = eng.n_grad_events() + eng.n_comm_events();
        assert!(popped >= frame.ticks.len() as u64);
        assert!(popped <= frame.ticks.len() as u64 + 1);
    }
}
