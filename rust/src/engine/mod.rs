//! The shared execution core both engines drive.
//!
//! The repo used to implement Eq. 4 twice: once in the virtual-time
//! simulator's event loop and once in the threaded runtime's worker
//! cells — every new behavior (and every bug fix) had to land in both.
//! This module factors the per-event update logic into one
//! [`DynamicsCore`] and abstracts *when events happen* behind the
//! [`Scheduler`] trait with two implementations:
//!
//! * [`VirtualTimeScheduler`] — the exact superposed-Poisson
//!   [`crate::simulator::EventQueue`], interleaved with a compiled
//!   scenario's timed rate updates; fully deterministic under a seed.
//! * [`WallClock`] — the thread-shared network state the real-thread
//!   runtime polls: per-worker Poisson communication rates, per-worker
//!   speed factors, and the currently-active adjacency. Scenario updates
//!   are applied by the runtime's monitor loop.
//!
//! [`BatchSampler`] is the shared mini-batch index stream (cursor +
//! seeded random jump) that both the simulator and
//! [`crate::runtime::RustGradSource`] draw from.

pub mod core;
pub mod multiplex;
pub mod sampler;
pub mod scheduler;

pub use self::core::{
    A2cid2Rule, AdPsgdRule, DynamicsCore, LocalSgdRule, LossEma, UpdateRule,
};
pub use multiplex::{Frame, MultiplexEngine};
pub use sampler::{BatchSampler, SamplerState};
pub use scheduler::{Scheduler, SchedulerState, Tick, VirtualTimeScheduler, WallClock};
