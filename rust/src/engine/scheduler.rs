//! Event scheduling: *when* the dynamics fire.
//!
//! Two [`Scheduler`] implementations replay the same compiled
//! [`NetworkPlan`]:
//!
//! * [`VirtualTimeScheduler`] — wraps the exact superposed-Poisson
//!   [`EventQueue`] and interleaves the plan's timed updates between
//!   events, so a scenario replays bit-identically under a seed.
//! * [`WallClock`] — the lock-light shared state real threads poll:
//!   per-worker communication rates (the Poisson budget draw), per-worker
//!   speed factors, and the active adjacency the pairing coordinator
//!   consults. The runtime's monitor loop pushes plan updates into it as
//!   normalized wall-clock time crosses each update's timestamp.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::config::scenario::{NetUpdate, NetworkPlan};
use crate::gossip::AcidParams;
use crate::graph::Graph;
use crate::simulator::events::{EventKind, EventQueue};

/// One dynamics event, with the union-edge endpoints already resolved.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Tick {
    /// Worker `worker` finishes a mini-batch gradient at time `t`.
    Grad { worker: usize, t: f64 },
    /// Workers `i` and `j` perform a pairwise averaging at time `t`.
    Comm { i: usize, j: usize, t: f64 },
}

/// The engine-facing slice both schedulers share: scenario updates are
/// pushed through `apply`, whatever the engine's notion of time is.
pub trait Scheduler {
    /// Retune the live rate/adjacency state to a compiled update.
    fn apply(&mut self, upd: &NetUpdate);
    /// Number of updates applied so far.
    fn updates_applied(&self) -> u64;
}

/// A worker-set or parameter change applied by a scheduler. Rate tables
/// live inside the scheduler, but churn re-inits and (η, α̃) retunes act
/// on state the *engine* owns (worker replicas, the dynamics core), so
/// the scheduler records them here for the engine loop to drain — in
/// application order, before the next popped event is processed.
#[derive(Clone, Debug, PartialEq)]
pub struct NetChange {
    pub t: f64,
    /// Workers that departed at this update.
    pub left: Vec<usize>,
    /// Workers that re-joined (each needs a neighbor-snapshot re-init).
    pub joined: Vec<usize>,
    /// New active-subgraph spectrum to retune (η, α̃) from, if any.
    pub chis: Option<(f64, f64)>,
}

/// The resumable position of a [`VirtualTimeScheduler`] — see
/// [`VirtualTimeScheduler::state`].
#[derive(Clone, Debug, PartialEq)]
pub struct SchedulerState {
    pub queue: crate::simulator::events::EventQueueState,
    pub applied: u64,
}

/// Exact virtual-time scheduler: the superposed Poisson clock plus the
/// plan's pending updates, applied *between* events in timestamp order.
pub struct VirtualTimeScheduler {
    queue: EventQueue,
    edges: Vec<(usize, usize)>,
    pending: std::collections::VecDeque<NetUpdate>,
    applied: u64,
    changes: Vec<NetChange>,
}

impl VirtualTimeScheduler {
    /// Build from a compiled plan. `seed` drives the Poisson clock.
    pub fn new(plan: &NetworkPlan, seed: u64) -> Self {
        Self {
            queue: EventQueue::new(&plan.initial_grad_rates, &plan.initial_edge_rates, seed),
            edges: plan.union.edges.clone(),
            pending: plan.updates.iter().cloned().collect(),
            applied: 0,
            changes: Vec::new(),
        }
    }

    /// Churn/retune changes applied since the last drain, in application
    /// order. The engine loop drains this after every
    /// [`VirtualTimeScheduler::next`] and processes the changes *before*
    /// the returned tick — every recorded change has `t` at or before the
    /// tick's time, so this keeps the replay event-ordered.
    pub fn drain_changes(&mut self) -> Vec<NetChange> {
        std::mem::take(&mut self.changes)
    }

    /// Current virtual time (the last popped event's timestamp).
    pub fn now(&self) -> f64 {
        self.queue.now
    }

    pub fn n_grad_events(&self) -> u64 {
        self.queue.n_grad_events
    }

    pub fn n_comm_events(&self) -> u64 {
        self.queue.n_comm_events
    }

    /// Checkpoint surface: the Poisson clock's full position plus the
    /// count of plan updates already applied. The pending-update tail and
    /// the union edge list are NOT captured — both are pure functions of
    /// the compiled plan, which restore reconstructs. Call only with
    /// [`VirtualTimeScheduler::drain_changes`] drained (checkpoints sit
    /// at tick boundaries); a pending change would be silently dropped.
    pub fn state(&self) -> SchedulerState {
        debug_assert!(self.changes.is_empty(), "checkpoint with undrained changes");
        SchedulerState { queue: self.queue.state(), applied: self.applied }
    }

    /// Restore a scheduler freshly built over the SAME plan and seed
    /// family: drops the already-applied prefix of the pending updates,
    /// then resumes the event queue exactly.
    pub fn restore(&mut self, st: &SchedulerState) -> crate::Result<()> {
        anyhow::ensure!(
            (st.applied as usize) <= self.pending.len(),
            "checkpoint applied {} updates but the plan compiles only {}",
            st.applied,
            self.pending.len(),
        );
        for _ in 0..st.applied {
            self.pending.pop_front();
        }
        self.queue.restore(&st.queue)?;
        self.applied = st.applied;
        self.changes.clear();
        Ok(())
    }

    /// Pop the next dynamics event, applying every plan update whose time
    /// has come first. `None` only if every process is silenced and no
    /// update remains.
    pub fn next(&mut self) -> Option<Tick> {
        loop {
            let horizon = self.pending.front().map_or(f64::INFINITY, |u| u.t);
            if let Some(ev) = self.queue.next(horizon) {
                return Some(match ev.kind {
                    EventKind::Grad { worker } => Tick::Grad { worker, t: ev.t },
                    EventKind::Comm { edge } => {
                        let (i, j) = self.edges[edge];
                        Tick::Comm { i, j, t: ev.t }
                    }
                });
            }
            let upd = self.pending.pop_front()?;
            Scheduler::apply(self, &upd);
        }
    }
}

impl Scheduler for VirtualTimeScheduler {
    fn apply(&mut self, upd: &NetUpdate) {
        // Retunes resample from the queue's clock; move it to the
        // update's own timestamp so the new rates govern [upd.t, ∞), not
        // the gap back to the last popped event.
        self.queue.advance_to(upd.t);
        // Sparse path: only the changed indices. Bit-identical to walking
        // the dense vector because the queue's setters no-op on an equal
        // rate — the dense walk touches the same entries. Hand-built
        // updates without diffs fall back to the dense vectors.
        if !upd.edge_diff.is_empty() {
            for &(e, r) in &upd.edge_diff {
                self.queue.set_comm_rate(e, r);
            }
        } else if let Some(rates) = &upd.edge_rates {
            for (e, &r) in rates.iter().enumerate() {
                self.queue.set_comm_rate(e, r);
            }
        }
        if !upd.grad_diff.is_empty() {
            for &(w, r) in &upd.grad_diff {
                self.queue.set_grad_rate(w, r);
            }
        } else if let Some(rates) = &upd.grad_rates {
            for (w, &r) in rates.iter().enumerate() {
                self.queue.set_grad_rate(w, r);
            }
        }
        if !upd.leave.is_empty() || !upd.join.is_empty() || upd.chis.is_some() {
            self.changes.push(NetChange {
                t: upd.t,
                left: upd.leave.clone(),
                joined: upd.join.clone(),
                chis: upd.chis,
            });
        }
        self.applied += 1;
    }

    fn updates_applied(&self) -> u64 {
        self.applied
    }
}

/// Thread-shared network state for the wall-clock engine.
///
/// Readers (one gradient + one communication thread per worker, plus the
/// pairing coordinator) see: the worker's total communication rate
/// `Σ_j λ^ij` over *active* incident links (the Poisson budget mean per
/// gradient step), the worker's relative speed factor, and the active
/// adjacency. Writers (the monitor loop replaying a scenario) swap whole
/// rate tables; rates and speeds are lock-free atomics, adjacency is
/// behind a seldom-written `RwLock`.
pub struct WallClock {
    n: usize,
    edges: Vec<(usize, usize)>,
    union_neighbors: Vec<Vec<usize>>,
    /// Union edge indices incident to each worker, aligned with
    /// `union_neighbors` (CSR order: partners ascending). Drives the
    /// O(edges changed) incremental update path.
    incident_edges: Vec<Vec<usize>>,
    /// Writer-side shadow of the current per-edge rates (monitor thread
    /// only) — what sparse diffs are applied against.
    cur_rates: Mutex<Vec<f64>>,
    /// Per-worker Σ of active incident edge rates, as f64 bits.
    comm_rates: Vec<AtomicU64>,
    /// Per-worker relative compute speed (1.0 = nominal), as f64 bits.
    speeds: Vec<AtomicU64>,
    /// Max over `speeds` (f64 bits) — real threads cannot run FASTER
    /// than the hardware, so the runtime normalizes to the fastest
    /// worker and stretches everyone else relative to it, preserving
    /// the compiled speed *ratios*.
    max_speed: AtomicU64,
    /// Active adjacency lists (sorted), rebuilt on edge-rate updates.
    active: RwLock<Vec<Vec<usize>>>,
    /// Per-worker churn membership: false while a scenario has the
    /// worker departed. Gradient/comm threads park while inactive.
    worker_active: Vec<AtomicBool>,
    /// Set once the scenario has no remaining updates: a still-inactive
    /// worker can never be re-joined, so its threads may exit.
    updates_exhausted: AtomicBool,
    /// The (publish epoch, (η, α, α̃)) currently published to the worker
    /// threads — kept as ONE mutex-guarded pair so a reader can never
    /// observe a new params value tagged with a stale epoch (the pairing
    /// protocol's older-snapshot tie-break relies on "equal epoch ⇒
    /// identical params"). Written at phase switches only; readers poll
    /// the `acid_epoch` mirror and take the lock only on a change, so
    /// the hot path pays one atomic load.
    acid: Mutex<(u64, AcidParams)>,
    acid_epoch: AtomicU64,
    /// Bumped on every applied update (cheap change detection).
    version: AtomicU64,
    applied: AtomicU64,
}

impl WallClock {
    /// Build from a compiled plan's initial state.
    pub fn new(plan: &NetworkPlan) -> Self {
        let n = plan.union.n;
        let wc = Self {
            n,
            edges: plan.union.edges.clone(),
            union_neighbors: (0..n).map(|i| plan.union.neighbors(i).to_vec()).collect(),
            incident_edges: (0..n).map(|i| plan.union.neighbor_edges(i).to_vec()).collect(),
            cur_rates: Mutex::new(vec![0.0; plan.union.edges.len()]),
            comm_rates: (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect(),
            speeds: (0..n).map(|_| AtomicU64::new(1f64.to_bits())).collect(),
            max_speed: AtomicU64::new(1f64.to_bits()),
            active: RwLock::new(vec![Vec::new(); n]),
            worker_active: (0..n).map(|_| AtomicBool::new(true)).collect(),
            updates_exhausted: AtomicBool::new(false),
            acid: Mutex::new((0, AcidParams::baseline())),
            acid_epoch: AtomicU64::new(0),
            version: AtomicU64::new(0),
            applied: AtomicU64::new(0),
        };
        wc.set_edge_rates(&plan.initial_edge_rates);
        wc.set_speeds(&plan.initial_grad_rates);
        wc
    }

    /// Static-network helper (tests, plain runs): every edge live at the
    /// graph's degree-based rates.
    pub fn from_graph(graph: &Graph, comm_rate: f64) -> Self {
        let base = vec![1.0; graph.n];
        Self::new(&NetworkPlan::static_plan(graph.clone(), comm_rate, &base))
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The union edge list all rate vectors index into.
    pub fn union_edges(&self) -> &[(usize, usize)] {
        &self.edges
    }

    /// Neighbors in the union graph (the set of workers that could EVER
    /// pair with `w` under some phase — liveness checks use this).
    pub fn union_neighbors(&self, w: usize) -> &[usize] {
        &self.union_neighbors[w]
    }

    /// Worker `w`'s current total communication rate.
    pub fn comm_rate(&self, w: usize) -> f64 {
        f64::from_bits(self.comm_rates[w].load(Ordering::Relaxed))
    }

    /// Worker `w`'s current relative compute speed.
    pub fn speed(&self, w: usize) -> f64 {
        f64::from_bits(self.speeds[w].load(Ordering::Relaxed))
    }

    /// The fastest worker's current speed (the runtime's pace anchor).
    pub fn max_speed(&self) -> f64 {
        f64::from_bits(self.max_speed.load(Ordering::Relaxed))
    }

    /// How much worker `w` must stretch its compute time relative to the
    /// fastest worker (≥ 1). The wall-clock engine sleeps the excess so
    /// the compiled speed ratios are reproduced even when the scenario
    /// assigns speeds above nominal.
    pub fn stretch(&self, w: usize) -> f64 {
        (self.max_speed() / self.speed(w).max(0.05)).max(1.0)
    }

    /// Whether the link `(i, j)` is currently active (rate > 0).
    pub fn has_active_edge(&self, i: usize, j: usize) -> bool {
        self.active.read().unwrap()[i].binary_search(&j).is_ok()
    }

    /// Copy worker `w`'s current active-neighbor list (sorted) into
    /// `out`, reusing its capacity. One read-lock acquisition hands the
    /// batched coordinator the whole candidate list, instead of one
    /// [`WallClock::has_active_edge`] lock round per queued worker.
    pub fn active_neighbors_into(&self, w: usize, out: &mut Vec<usize>) {
        out.clear();
        out.extend_from_slice(&self.active.read().unwrap()[w]);
    }

    /// Whether worker `w` is currently part of the fleet (churn).
    pub fn is_active(&self, w: usize) -> bool {
        self.worker_active[w].load(Ordering::Acquire)
    }

    /// Mark the scenario replay finished: no update remains, so inactive
    /// workers are departed for good. Idempotent.
    pub fn finalize_updates(&self) {
        self.updates_exhausted.store(true, Ordering::Release);
    }

    /// Whether worker `w` is departed with no remaining update that could
    /// ever re-join it — its threads may exit instead of parking.
    pub fn departed_for_good(&self, w: usize) -> bool {
        self.updates_exhausted.load(Ordering::Acquire) && !self.is_active(w)
    }

    /// Publish new (η, α, α̃) to the worker threads (the adaptive
    /// per-phase path). Threads refresh *between* pairings/steps; a
    /// pairing split by a publish is reconciled on the bus (both
    /// endpoints average with the older snapshot — see the runtime's
    /// `comm_loop`). The epoch bump and the value swap happen under one
    /// lock, and the polling mirror is updated before release.
    pub fn publish_acid(&self, p: AcidParams) {
        let mut guard = self.acid.lock().unwrap();
        guard.0 += 1;
        guard.1 = p;
        self.acid_epoch.store(guard.0, Ordering::Release);
    }

    /// The currently published (epoch, (η, α, α̃)) as one consistent
    /// pair — refresh `acid_seen` from THIS, never from the separate
    /// [`WallClock::acid_epoch`] poll, or a concurrent publish could tag
    /// new params with a stale epoch.
    pub fn acid_snapshot(&self) -> (u64, AcidParams) {
        *self.acid.lock().unwrap()
    }

    /// The currently published (η, α, α̃).
    pub fn acid(&self) -> AcidParams {
        self.acid.lock().unwrap().1
    }

    /// Monotonic mirror of the publish epoch — a cheap "did anything
    /// change" poll; read the authoritative pair via
    /// [`WallClock::acid_snapshot`].
    pub fn acid_epoch(&self) -> u64 {
        self.acid_epoch.load(Ordering::Acquire)
    }

    /// Monotonic change counter (readers cache derived state against it).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    fn set_edge_rates(&self, rates: &[f64]) {
        assert_eq!(rates.len(), self.edges.len(), "one rate per union edge");
        let mut totals = vec![0.0f64; self.n];
        let mut adj = vec![Vec::new(); self.n];
        for (&(i, j), &r) in self.edges.iter().zip(rates) {
            if r > 0.0 {
                totals[i] += r;
                totals[j] += r;
                adj[i].push(j);
                adj[j].push(i);
            }
        }
        for list in &mut adj {
            list.sort_unstable();
        }
        self.cur_rates.lock().unwrap().copy_from_slice(rates);
        *self.active.write().unwrap() = adj;
        for (slot, &t) in self.comm_rates.iter().zip(&totals) {
            slot.store(t.to_bits(), Ordering::Release);
        }
    }

    /// Sparse edge-rate update: rebuild only the touched workers'
    /// adjacency lists and rate totals — O(Σ deg over touched workers),
    /// never O(|ℰ|). Each touched worker's total is re-summed over its
    /// incident edges in CSR (partner-ascending) order, which is exactly
    /// the order the full rebuild accumulates in, so the stored totals
    /// are bit-identical to a dense [`WallClock::set_edge_rates`] call.
    fn apply_edge_diff(&self, diff: &[(usize, f64)]) {
        let mut cur = self.cur_rates.lock().unwrap();
        let mut touched: Vec<usize> = Vec::with_capacity(2 * diff.len());
        for &(e, r) in diff {
            cur[e] = r;
            let (i, j) = self.edges[e];
            touched.push(i);
            touched.push(j);
        }
        touched.sort_unstable();
        touched.dedup();
        let mut active = self.active.write().unwrap();
        for &w in &touched {
            let mut total = 0.0f64;
            let list = &mut active[w];
            list.clear();
            for &e in &self.incident_edges[w] {
                let r = cur[e];
                if r > 0.0 {
                    total += r;
                    let (i, j) = self.edges[e];
                    list.push(if i == w { j } else { i });
                }
            }
            self.comm_rates[w].store(total.to_bits(), Ordering::Release);
        }
    }

    /// Sparse speed update: store the changed slots, then re-derive the
    /// pace anchor (max must be rescanned — a diff may LOWER the
    /// previously-fastest worker).
    fn apply_speed_diff(&self, diff: &[(usize, f64)]) {
        for &(w, r) in diff {
            self.speeds[w].store(r.to_bits(), Ordering::Release);
        }
        let mut max = f64::MIN;
        for slot in &self.speeds {
            max = max.max(f64::from_bits(slot.load(Ordering::Relaxed)));
        }
        self.max_speed.store(max.max(0.05).to_bits(), Ordering::Release);
    }

    fn set_speeds(&self, rates: &[f64]) {
        assert_eq!(rates.len(), self.n, "one speed per worker");
        let mut max = f64::MIN;
        for (slot, &r) in self.speeds.iter().zip(rates) {
            slot.store(r.to_bits(), Ordering::Release);
            max = max.max(r);
        }
        self.max_speed.store(max.max(0.05).to_bits(), Ordering::Release);
    }

    /// Apply a plan update through a shared reference (the trait's `&mut`
    /// surface is implemented on `Arc<WallClock>`). Churn membership
    /// flips before the rate tables swap so a newly-joined worker never
    /// observes live incident edges while still marked departed.
    pub fn apply_shared(&self, upd: &NetUpdate) {
        for &w in &upd.join {
            self.worker_active[w].store(true, Ordering::Release);
        }
        for &w in &upd.leave {
            self.worker_active[w].store(false, Ordering::Release);
        }
        if !upd.edge_diff.is_empty() {
            self.apply_edge_diff(&upd.edge_diff);
        } else if let Some(rates) = &upd.edge_rates {
            self.set_edge_rates(rates);
        }
        if !upd.grad_diff.is_empty() {
            self.apply_speed_diff(&upd.grad_diff);
        } else if let Some(rates) = &upd.grad_rates {
            self.set_speeds(rates);
        }
        self.version.fetch_add(1, Ordering::AcqRel);
        self.applied.fetch_add(1, Ordering::AcqRel);
    }
}

impl Scheduler for Arc<WallClock> {
    fn apply(&mut self, upd: &NetUpdate) {
        self.apply_shared(upd);
    }

    fn updates_applied(&self) -> u64 {
        self.applied.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::graph::Topology;

    fn plan(s: &str, n: usize, horizon: f64) -> NetworkPlan {
        Scenario::parse(s).unwrap().compile(n, 1.0, horizon, &vec![1.0; n]).unwrap()
    }

    #[test]
    fn virtual_scheduler_replays_deterministically() {
        let run = |seed: u64| {
            let plan = plan("ring@0,complete@0.5;drop=0.3:0.2:0.8:3", 6, 50.0);
            let mut sched = VirtualTimeScheduler::new(&plan, seed);
            let mut ticks = Vec::new();
            for _ in 0..2000 {
                ticks.push(sched.next().unwrap());
            }
            (ticks, sched.updates_applied())
        };
        let (a, ua) = run(9);
        let (b, ub) = run(9);
        assert_eq!(a, b);
        assert_eq!(ua, ub);
        let (c, _) = run(10);
        assert_ne!(a, c);
    }

    #[test]
    fn virtual_scheduler_applies_updates_in_time_order() {
        let plan = plan("ring@0,complete@0.5", 6, 50.0);
        let mut sched = VirtualTimeScheduler::new(&plan, 1);
        let mut saw_non_ring_before_switch = false;
        let mut saw_non_ring_after_switch = false;
        let ring = Graph::build(&Topology::Ring, 6).unwrap();
        for _ in 0..4000 {
            let Some(tick) = sched.next() else { break };
            if let Tick::Comm { i, j, t } = tick {
                if !ring.has_edge(i, j) {
                    if t < 25.0 {
                        saw_non_ring_before_switch = true;
                    } else {
                        saw_non_ring_after_switch = true;
                    }
                }
            }
        }
        assert!(!saw_non_ring_before_switch, "chord fired before the switch");
        assert!(saw_non_ring_after_switch, "chords never fired after the switch");
        assert_eq!(sched.updates_applied(), 1);
    }

    #[test]
    fn virtual_scheduler_state_round_trip_resumes_the_tick_stream() {
        // Drive across a phase switch + churn so `applied`, epochs, and
        // stale heap entries are all non-trivial at the snapshot point,
        // then restore a FRESH scheduler and compare tick tails exactly.
        let p = plan("ring@0,complete@0.5;leave=0.25:0.25:3;join=0.25:0.75", 8, 100.0);
        let mut sched = VirtualTimeScheduler::new(&p, 21);
        for _ in 0..1500 {
            sched.next().unwrap();
            sched.drain_changes();
        }
        let st = sched.state();
        assert!(sched.updates_applied() > 0, "snapshot sits past a plan update");
        let tail: Vec<Tick> = (0..1500).map(|_| sched.next().unwrap()).collect();
        let mut resumed = VirtualTimeScheduler::new(&p, 21);
        resumed.restore(&st).unwrap();
        assert_eq!(resumed.updates_applied(), st.applied);
        let resumed_tail: Vec<Tick> = (0..1500).map(|_| resumed.next().unwrap()).collect();
        assert_eq!(tail, resumed_tail, "bit-identical resumed tick stream");
        // A checkpoint claiming more applied updates than the plan has is
        // rejected.
        let mut bad = st.clone();
        bad.applied = p.updates.len() as u64 + 1;
        let mut fresh = VirtualTimeScheduler::new(&p, 21);
        assert!(fresh.restore(&bad).is_err());
    }

    #[test]
    fn wall_clock_tracks_rates_and_adjacency() {
        let plan = plan("ring@0,complete@0.5", 4, 10.0);
        let wc = WallClock::new(&plan);
        assert_eq!(wc.n(), 4);
        // Ring phase: each worker's total rate ≈ 1, chords inactive.
        assert!((wc.comm_rate(0) - 1.0).abs() < 1e-9);
        assert!(wc.has_active_edge(0, 1));
        assert!(!wc.has_active_edge(0, 2));
        assert_eq!(wc.speed(2), 1.0);
        let v0 = wc.version();
        // Apply the switch: chords activate.
        let mut shared = Arc::new(wc);
        let upd = plan.updates[0].clone();
        Scheduler::apply(&mut shared, &upd);
        assert!(shared.has_active_edge(0, 2));
        assert!(shared.version() > v0);
        assert_eq!(Scheduler::updates_applied(&shared), 1);
        // Union adjacency is phase-independent.
        assert_eq!(shared.union_neighbors(0).len(), 3);
        // The batched coordinator's bulk accessor sees the same adjacency
        // as the per-edge probe.
        let mut nbuf = vec![99];
        shared.active_neighbors_into(0, &mut nbuf);
        assert_eq!(nbuf, vec![1, 2, 3]);
    }

    #[test]
    fn wall_clock_sparse_diff_matches_dense_rebuild() {
        // Replay the same compiled updates through the sparse incremental
        // path and the dense full-rebuild path: rate totals, adjacency,
        // and the pace anchor must match to the bit.
        let plan = plan(
            "ring@0,complete@0.5;drift=0.4:3:2;leave=0.25:0.2:1;join=0.25:0.8",
            8,
            80.0,
        );
        assert!(plan.updates.iter().any(|u| !u.edge_diff.is_empty()));
        let sparse = WallClock::new(&plan);
        let dense = WallClock::new(&plan);
        for upd in &plan.updates {
            sparse.apply_shared(upd);
            let mut stripped = upd.clone();
            stripped.edge_diff.clear();
            stripped.grad_diff.clear();
            dense.apply_shared(&stripped);
            let (mut a, mut b) = (Vec::new(), Vec::new());
            for w in 0..8 {
                assert_eq!(
                    sparse.comm_rate(w).to_bits(),
                    dense.comm_rate(w).to_bits(),
                    "worker {w} rate total at t={}",
                    upd.t
                );
                assert_eq!(sparse.speed(w).to_bits(), dense.speed(w).to_bits());
                sparse.active_neighbors_into(w, &mut a);
                dense.active_neighbors_into(w, &mut b);
                assert_eq!(a, b, "worker {w} adjacency at t={}", upd.t);
            }
            assert_eq!(sparse.max_speed().to_bits(), dense.max_speed().to_bits());
        }
    }

    #[test]
    fn virtual_scheduler_records_churn_changes() {
        let plan = plan("ring@0;leave=0.25:0.25:3;join=0.25:0.75", 8, 100.0);
        let mut sched = VirtualTimeScheduler::new(&plan, 4);
        let mut changes = Vec::new();
        let mut grads_for_left_during_gap = 0u64;
        let left_set = plan.updates[0].leave.clone();
        for _ in 0..4000 {
            let Some(tick) = sched.next() else { break };
            let drained = sched.drain_changes();
            changes.extend(drained);
            if let Tick::Grad { worker, t } = tick {
                if (25.0..75.0).contains(&t) && left_set.contains(&worker) {
                    grads_for_left_during_gap += 1;
                }
            }
        }
        changes.extend(sched.drain_changes());
        assert_eq!(changes.len(), 2, "leave + join recorded");
        assert_eq!(changes[0].left, left_set);
        assert!(changes[0].joined.is_empty());
        assert_eq!(changes[1].joined, left_set);
        assert!((changes[0].t - 25.0).abs() < 1e-12);
        assert_eq!(
            grads_for_left_during_gap, 0,
            "departed workers fire no gradient events"
        );
    }

    #[test]
    fn wall_clock_churn_membership_and_acid_publish() {
        let plan = plan("ring@0;leave=0.25:0.25:3;join=0.25:0.75", 8, 100.0);
        let wc = WallClock::new(&plan);
        assert!((0..8).all(|w| wc.is_active(w)));
        assert!(!wc.departed_for_good(0));
        let leavers = plan.updates[0].leave.clone();
        wc.apply_shared(&plan.updates[0]);
        for &w in &leavers {
            assert!(!wc.is_active(w));
            assert_eq!(wc.comm_rate(w), 0.0, "departed worker has no link budget");
            assert!(!wc.departed_for_good(w), "a re-join is still pending");
        }
        wc.apply_shared(&plan.updates[1]);
        assert!((0..8).all(|w| wc.is_active(w)));
        wc.finalize_updates();
        assert!(!wc.departed_for_good(leavers[0]), "re-joined before the end");

        // Param publishing: epoch-gated, last write wins, and the
        // (epoch, params) snapshot is one consistent pair.
        let e0 = wc.acid_epoch();
        let p = AcidParams::accelerated(3.0, 1.0);
        wc.publish_acid(p);
        assert_eq!(wc.acid_epoch(), e0 + 1);
        assert_eq!(wc.acid(), p);
        assert_eq!(wc.acid_snapshot(), (e0 + 1, p));
    }

    #[test]
    fn wall_clock_speed_updates() {
        let plan = plan("ring@0;drift=0.5:2:4", 4, 20.0);
        let wc = WallClock::new(&plan);
        let before: Vec<f64> = (0..4).map(|w| wc.speed(w)).collect();
        for upd in &plan.updates {
            wc.apply_shared(upd);
        }
        let after: Vec<f64> = (0..4).map(|w| wc.speed(w)).collect();
        assert_ne!(before, after);
        assert!(after.iter().all(|&s| s > 0.0));
        // Stretch anchors on the fastest worker: the max-speed worker
        // runs nominal (stretch 1), everyone else stretches by the
        // compiled speed ratio — speeds ABOVE 1.0 are honored too.
        let max = after.iter().cloned().fold(f64::MIN, f64::max);
        assert!((wc.max_speed() - max).abs() < 1e-12);
        for w in 0..4 {
            let expect = (max / after[w].max(0.05)).max(1.0);
            assert!((wc.stretch(w) - expect).abs() < 1e-9, "worker {w}");
        }
        let fastest = after.iter().position(|&s| s == max).unwrap();
        assert!((wc.stretch(fastest) - 1.0).abs() < 1e-12);
    }
}
