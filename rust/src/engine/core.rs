//! [`DynamicsCore`]: the per-event update logic of Eq. 4, shared by both
//! execution engines.
//!
//! The core owns the *what* of every event — hyper-parameters (η, α, α̃),
//! the continuous-momentum mixer, and the learning-rate schedule — and
//! exposes one method per event type. The engines own the *when*: the
//! simulator pops events from a [`crate::engine::VirtualTimeScheduler`],
//! the runtime's threads fire them at wall-clock Poisson times. Either
//! way, the same code path applies the update, so a scenario validated in
//! fast simulation runs unchanged under true asynchrony.

use std::sync::Arc;

use crate::config::{Algorithm, Method};
use crate::gossip::dynamics::{comm_event, WorkerState};
use crate::gossip::{AcidParams, Mixer};
use crate::graph::Spectrum;
use crate::optim::{LrSchedule, Sgd};

/// A pluggable per-event update rule: which (η, α, α̃) the dynamic runs
/// with, and whether a proposed pairing is admitted at all. The rule is
/// selected ONCE per run (when the core is built) — the per-event hot
/// path only ever sees the resolved [`AcidParams`]/[`Mixer`], plus one
/// cheap `admits_pair` counter check, so no dynamic dispatch reaches the
/// vector kernels.
///
/// All asynchronous algorithms share the engines' seeded event stream:
/// a rule never *reschedules* events, it only decides how (and whether)
/// each one applies. That is what makes head-to-head arms comparable —
/// same Poisson clocks, different update rules.
pub trait UpdateRule: Send + Sync + std::fmt::Debug {
    /// Canonical algorithm name (matches the config grammar).
    fn name(&self) -> &'static str;

    /// The (η, α, α̃) this rule runs with over the given network.
    fn params(&self, spectrum: &Spectrum) -> AcidParams;

    /// Whether this endpoint is ready to communicate. Default: always.
    fn admits_endpoint(&self, _w: &WorkerState) -> bool {
        true
    }

    /// Whether a proposed pairing applies. Default: both endpoints ready.
    fn admits_pair(&self, a: &WorkerState, b: &WorkerState) -> bool {
        self.admits_endpoint(a) && self.admits_endpoint(b)
    }
}

/// The paper's accelerated dynamic (Eq. 4, Prop. 3.6 parameters).
#[derive(Clone, Copy, Debug)]
pub struct A2cid2Rule;

impl UpdateRule for A2cid2Rule {
    fn name(&self) -> &'static str {
        "a2cid2"
    }

    fn params(&self, spectrum: &Spectrum) -> AcidParams {
        AcidParams::from_spectrum(spectrum)
    }
}

/// AD-PSGD-style plain pairwise averaging: η = 0, α = α̃ = ½, every
/// pairing applies (Lian et al., 2018 — the paper's async baseline).
#[derive(Clone, Copy, Debug)]
pub struct AdPsgdRule;

impl UpdateRule for AdPsgdRule {
    fn name(&self) -> &'static str {
        "adpsgd"
    }

    fn params(&self, _spectrum: &Spectrum) -> AcidParams {
        AcidParams::baseline()
    }
}

/// Locally-asynchronous local SGD: plain averaging like AD-PSGD, but an
/// endpoint only communicates after `h` local gradient steps since its
/// last applied pairing. Pairings proposed too early are skipped (the
/// event still ticks the shared stream; it just does not apply).
#[derive(Clone, Copy, Debug)]
pub struct LocalSgdRule {
    /// Local gradient steps required between two applied pairings.
    pub h: u64,
}

impl UpdateRule for LocalSgdRule {
    fn name(&self) -> &'static str {
        "localsgd"
    }

    fn params(&self, _spectrum: &Spectrum) -> AcidParams {
        AcidParams::baseline()
    }

    fn admits_endpoint(&self, w: &WorkerState) -> bool {
        w.n_grads.saturating_sub(w.grads_at_last_comm) >= self.h
    }
}

/// Engine-agnostic event application for the Eq. 4 dynamic.
#[derive(Clone, Debug)]
pub struct DynamicsCore {
    /// The (η, α, α̃) actually applied.
    pub acid: AcidParams,
    /// The continuous momentum flow `exp(Δt·[[−η,η],[η,−η]])`.
    pub mixer: Mixer,
    /// Per-worker learning-rate schedule, indexed by local step count.
    pub lr: LrSchedule,
    /// The update rule this core was built for (selected once per run).
    pub rule: Arc<dyn UpdateRule>,
}

impl DynamicsCore {
    /// Build from explicit parameters (the A²CiD² Eq. 4 rule; for other
    /// algorithms use [`DynamicsCore::for_algorithm`]).
    pub fn with_params(acid: AcidParams, lr: LrSchedule) -> Self {
        Self { acid, mixer: Mixer::new(acid.eta), lr, rule: Arc::new(A2cid2Rule) }
    }

    /// Build for an asynchronous algorithm over a network spectrum: the
    /// rule resolves its own (η, α, α̃) — [`Algorithm::A2cid2`] takes the
    /// Prop. 3.6 parameters, the averaging rules η = 0.
    /// [`Algorithm::AllReduce`] has no gossip dynamic and is rejected.
    pub fn for_algorithm(
        algo: Algorithm,
        spectrum: &Spectrum,
        lr: LrSchedule,
    ) -> crate::Result<Self> {
        let rule: Arc<dyn UpdateRule> = match algo {
            Algorithm::A2cid2 => Arc::new(A2cid2Rule),
            Algorithm::AdPsgd => Arc::new(AdPsgdRule),
            Algorithm::LocalSgd { h } => Arc::new(LocalSgdRule { h }),
            Algorithm::AllReduce => anyhow::bail!(
                "the gossip dynamics core is for the asynchronous algorithms"
            ),
        };
        let acid = rule.params(spectrum);
        Ok(Self { acid, mixer: Mixer::new(acid.eta), lr, rule })
    }

    /// Build for a legacy [`Method`]: [`Method::Acid`] maps to the
    /// A²CiD² rule, the async baseline to AD-PSGD averaging (they are the
    /// same η = 0 dynamic). [`Method::AllReduce`] is rejected.
    pub fn for_method(method: Method, spectrum: &Spectrum, lr: LrSchedule) -> crate::Result<Self> {
        Self::for_algorithm(Algorithm::from_method(method), spectrum, lr)
    }

    /// Swap in new (η, α, α̃) mid-run (the adaptive per-phase path). The
    /// mixer is rebuilt so the momentum flow uses the new η from the next
    /// event on; elapsed-but-unmixed time is charged at the new rate
    /// (piecewise-constant η, consistent between engines because both
    /// apply the change between events).
    pub fn set_params(&mut self, acid: AcidParams) {
        self.acid = acid;
        self.mixer = Mixer::new(acid.eta);
    }

    /// Re-derive the parameters from a new active-subgraph spectrum
    /// (χ₁, χ₂), preserving the method: accelerated cores retune, the
    /// η = 0 baseline ignores the spectrum entirely. Unusable spectra
    /// (see [`AcidParams::from_chis_clamped`]) keep the current values.
    pub fn retune(&mut self, chi1: f64, chi2: f64) {
        if !self.acid.is_accelerated() {
            return;
        }
        if let Some(p) = AcidParams::from_chis_clamped(chi1, chi2) {
            self.set_params(p);
        }
    }

    /// Churn re-join: reset `st` from a donor neighbor's parameters at
    /// time `t` (both engines route re-joins through this one method so
    /// the replay stays bit-identical between them).
    pub fn rejoin_from(&self, st: &mut WorkerState, donor_x: &[f32], t: f64) {
        st.reinit_from(donor_x, t);
    }

    /// Apply one gradient event at time `t`: momentum-mix the pair for
    /// the elapsed time, fold the raw gradient through the optimizer, and
    /// step both rows. The learning rate comes from the worker's own
    /// event count (both engines agree on this indexing). Returns the
    /// learning rate applied.
    pub fn grad_event(
        &self,
        st: &mut WorkerState,
        t: f64,
        optim: &mut Sgd,
        grad: &[f32],
    ) -> f32 {
        let lr = self.lr.at(st.n_grads) as f32;
        let dir = optim.direction(grad);
        st.apply_grad(t, lr, dir, &self.mixer);
        lr
    }

    /// Apply one full pairwise communication event at time `t` with both
    /// endpoints in hand (the virtual-time engine's path; fused). Returns
    /// whether the pairing applied: rules that pace communication (local
    /// SGD) skip pairings proposed before both endpoints are ready, and
    /// skipped pairings leave both states untouched so every algorithm
    /// replays the same seeded event stream.
    pub fn comm_event(&self, a: &mut WorkerState, b: &mut WorkerState, t: f64) -> bool {
        if !self.rule.admits_pair(a, b) {
            return false;
        }
        comm_event(a, b, t, &self.acid, &self.mixer);
        true
    }

    /// Bring a worker's pair up to time `t` (lazy momentum flow). Used
    /// when syncing workers to a common evaluation time; the runtime's
    /// pairing hot path no longer mixes in place (see
    /// [`DynamicsCore::mix_into`]).
    pub fn mix_to(&self, st: &mut WorkerState, t: f64) {
        st.mix_to(t, &self.mixer);
    }

    /// Apply this endpoint's half of a communication event given the
    /// peer's *already-mixed* parameters (the composed path: mix in
    /// place, exchange snapshots, then apply). Kept as the reference the
    /// fused runtime path is verified against.
    pub fn comm_half(&self, st: &mut WorkerState, peer_x: &[f32]) {
        st.apply_comm(&self.acid, peer_x);
    }

    /// Send side of a runtime pairing: compute the worker's
    /// momentum-mixed parameters at time `t` straight into the outgoing
    /// buffer, *without mutating state* — a read-only 2R + 1W pass, so
    /// the old mix-in-place + snapshot-copy lock hold disappears.
    pub fn mix_into(&self, st: &WorkerState, t: f64, out: &mut [f32]) {
        st.mix_into(t, &self.mixer, out);
    }

    /// Receive side of a runtime pairing: ONE locked read-modify-write
    /// pass folding the pending momentum mix (left pending by
    /// [`DynamicsCore::mix_into`] at the same `t`) and the `(α, α̃)`
    /// update. Together with `mix_into` this is the whole per-pairing
    /// cost on the runtime path.
    pub fn comm_apply(&self, st: &mut WorkerState, t: f64, peer_x: &[f32]) {
        st.apply_comm_fused(t, &self.acid, &self.mixer, peer_x);
    }

    /// [`DynamicsCore::comm_apply`] with an explicitly *agreed* (α, α̃):
    /// both endpoints of one pairing must average with the same step
    /// sizes or the pair mean drifts, so when an adaptive retune lands
    /// mid-match the two sides apply the older of their two snapshots
    /// (smaller publish epoch — both compute the same choice). The
    /// pending-mix η stays this worker's own: it must match the mix its
    /// outgoing buffer was built with.
    pub fn comm_apply_agreed(
        &self,
        st: &mut WorkerState,
        t: f64,
        peer_x: &[f32],
        agreed: AcidParams,
    ) {
        st.apply_comm_fused(t, &agreed, &self.mixer, peer_x);
    }

    /// Sync every worker to a common evaluation time (completes the lazy
    /// mixing; both engines do this before the closing All-Reduce). The
    /// per-worker catch-up runs through the pooled `mix_pair` path, so at
    /// replay-scale dims the closing sync is chunk-parallel too.
    pub fn sync_all(&self, workers: &mut [WorkerState], t: f64) {
        for w in workers {
            w.mix_to(t, &self.mixer);
        }
    }
}

/// Shared exponential-moving-average fold for train-loss reporting, NaN
/// seeded (the first sample replaces it).
#[derive(Clone, Copy, Debug)]
pub struct LossEma;

impl LossEma {
    /// `beta·prev + (1−beta)·value`, or `value` when `prev` is NaN/∞.
    #[inline]
    pub fn fold(prev: f64, value: f64, beta: f64) -> f64 {
        if prev.is_finite() {
            beta * prev + (1.0 - beta) * value
        } else {
            value
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, Topology};

    fn spectrum() -> Spectrum {
        Graph::build(&Topology::Ring, 8).unwrap().spectrum(1.0)
    }

    #[test]
    fn for_method_selects_parameters() {
        let lr = LrSchedule::Constant { lr: 0.1 };
        let base = DynamicsCore::for_method(Method::AsyncBaseline, &spectrum(), lr.clone())
            .unwrap();
        assert!(!base.acid.is_accelerated());
        let acid = DynamicsCore::for_method(Method::Acid, &spectrum(), lr.clone()).unwrap();
        assert!(acid.acid.is_accelerated());
        assert_eq!(acid.mixer.eta, acid.acid.eta);
        assert!(DynamicsCore::for_method(Method::AllReduce, &spectrum(), lr).is_err());
    }

    #[test]
    fn for_algorithm_selects_rules_and_parameters() {
        let lr = LrSchedule::Constant { lr: 0.1 };
        let acid =
            DynamicsCore::for_algorithm(Algorithm::A2cid2, &spectrum(), lr.clone()).unwrap();
        assert!(acid.acid.is_accelerated());
        assert_eq!(acid.rule.name(), "a2cid2");
        let adpsgd =
            DynamicsCore::for_algorithm(Algorithm::AdPsgd, &spectrum(), lr.clone()).unwrap();
        assert!(!adpsgd.acid.is_accelerated());
        assert_eq!(adpsgd.rule.name(), "adpsgd");
        assert_eq!(adpsgd.acid, AcidParams::baseline());
        let local = DynamicsCore::for_algorithm(
            Algorithm::LocalSgd { h: 3 },
            &spectrum(),
            lr.clone(),
        )
        .unwrap();
        assert_eq!(local.rule.name(), "localsgd");
        assert_eq!(local.acid, AcidParams::baseline());
        assert!(
            DynamicsCore::for_algorithm(Algorithm::AllReduce, &spectrum(), lr).is_err()
        );
    }

    #[test]
    fn localsgd_gate_skips_pairings_until_h_local_steps() {
        let core = DynamicsCore::for_algorithm(
            Algorithm::LocalSgd { h: 2 },
            &spectrum(),
            LrSchedule::Constant { lr: 0.1 },
        )
        .unwrap();
        let mut a = WorkerState::new(vec![0.0, 4.0]);
        let mut b = WorkerState::new(vec![2.0, 0.0]);
        let mut opt = Sgd::new(0.0);
        // Neither endpoint has taken a step: the pairing must be skipped
        // and leave both states untouched.
        let a_before = a.clone();
        assert!(!core.comm_event(&mut a, &mut b, 0.1));
        assert_eq!(a.x, a_before.x);
        assert_eq!(a.n_comms, 0);
        // One step each is still below H = 2.
        core.grad_event(&mut a, 0.2, &mut opt, &[0.0, 0.0]);
        core.grad_event(&mut b, 0.2, &mut opt, &[0.0, 0.0]);
        assert!(!core.comm_event(&mut a, &mut b, 0.3));
        // Two steps each: the pairing applies and is plain averaging.
        core.grad_event(&mut a, 0.4, &mut opt, &[0.0, 0.0]);
        core.grad_event(&mut b, 0.4, &mut opt, &[0.0, 0.0]);
        assert!(core.comm_event(&mut a, &mut b, 0.5));
        assert_eq!(a.x, vec![1.0, 2.0]);
        assert_eq!(b.x, vec![1.0, 2.0]);
        assert_eq!(a.n_comms, 1);
        // The gate re-arms: the very next pairing is skipped again.
        assert!(!core.comm_event(&mut a, &mut b, 0.6));
        assert_eq!(a.n_comms, 1);
        // A one-sided ready endpoint is not enough.
        core.grad_event(&mut a, 0.7, &mut opt, &[0.0, 0.0]);
        core.grad_event(&mut a, 0.8, &mut opt, &[0.0, 0.0]);
        assert!(!core.comm_event(&mut a, &mut b, 0.9));
    }

    #[test]
    fn adpsgd_gated_comm_conserves_pair_mean() {
        // The gated comm_event path for AD-PSGD is exact pairwise
        // averaging: applied on every proposal, pair mean conserved.
        let core = DynamicsCore::for_algorithm(
            Algorithm::AdPsgd,
            &spectrum(),
            LrSchedule::Constant { lr: 0.1 },
        )
        .unwrap();
        let mut a = WorkerState::new(vec![1.0, -3.0, 2.0]);
        let mut b = WorkerState::new(vec![5.0, 0.5, -1.0]);
        let sum = |u: &WorkerState, v: &WorkerState| -> f64 {
            u.x.iter().chain(v.x.iter()).map(|&p| p as f64).sum()
        };
        let before = sum(&a, &b);
        assert!(core.comm_event(&mut a, &mut b, 0.5));
        assert!((sum(&a, &b) - before).abs() < 1e-5);
        assert_eq!(a.x, b.x, "η = 0 pairing is exact averaging");
    }

    #[test]
    fn grad_event_applies_schedule_by_worker_step() {
        // A schedule that changes per step must be indexed by the
        // worker's own count, not any global counter.
        let lr = LrSchedule::WarmupStep {
            base_lr: 0.1,
            scale: 1.0,
            warmup_steps: 1,
            milestones: vec![1],
        };
        let core = DynamicsCore::with_params(AcidParams::baseline(), lr);
        let mut st = WorkerState::new(vec![0.0]);
        let mut opt = Sgd::new(0.0);
        let lr0 = core.grad_event(&mut st, 0.1, &mut opt, &[1.0]);
        let lr1 = core.grad_event(&mut st, 0.2, &mut opt, &[1.0]);
        assert!((lr0 - 0.1).abs() < 1e-6, "warmup step: {lr0}");
        assert!((lr1 - 0.01).abs() < 1e-6, "post-milestone: {lr1}");
        assert_eq!(st.n_grads, 2);
        assert!((st.x[0] - (-0.11)).abs() < 1e-6);
    }

    #[test]
    fn comm_paths_agree_between_engines() {
        // Three implementations of one pairwise communication event must
        // agree: the simulator's two-endpoint fused update, the old
        // composed runtime path (mix in place → snapshot → apply half),
        // and the new fused runtime path (read-only mix_into → one
        // comm_apply RMW pass). The two runtime paths must agree
        // BIT-IDENTICALLY — that is the acceptance proof that the single
        // locked pass computes exactly what the two-lock composition did.
        let p = AcidParams::accelerated(10.0, 1.0);
        let core = DynamicsCore::with_params(p, LrSchedule::Constant { lr: 0.1 });
        let mk = |v: &[f32]| WorkerState::new(v.to_vec());

        let mut a1 = mk(&[1.0, -2.0]);
        let mut b1 = mk(&[3.0, 0.5]);
        let mut opt = Sgd::new(0.0);
        core.grad_event(&mut a1, 0.2, &mut opt, &[1.0, 1.0]);
        let mut a2 = a1.clone();
        let mut b2 = b1.clone();
        let mut a3 = a1.clone();
        let mut b3 = b1.clone();

        // Engine 1: simulator, both endpoints fused in one pass.
        core.comm_event(&mut a1, &mut b1, 0.7);

        // Engine 2 (old runtime path): mix both in place, swap
        // snapshots, apply halves.
        core.mix_to(&mut a2, 0.7);
        core.mix_to(&mut b2, 0.7);
        let xa = a2.x.clone();
        let xb = b2.x.clone();
        core.comm_half(&mut a2, &xb);
        core.comm_half(&mut b2, &xa);

        // Engine 3 (new runtime path): read-only send buffers, then one
        // locked RMW pass per side.
        let mut buf_a = vec![0.0f32; 2];
        let mut buf_b = vec![0.0f32; 2];
        core.mix_into(&a3, 0.7, &mut buf_a);
        core.mix_into(&b3, 0.7, &mut buf_b);
        assert_eq!(buf_a, xa, "mix_into == in-place mix + snapshot, bitwise");
        assert_eq!(buf_b, xb);
        core.comm_apply(&mut a3, 0.7, &buf_b);
        core.comm_apply(&mut b3, 0.7, &buf_a);

        for (u, v) in a1.x.iter().zip(&a2.x) {
            assert!((u - v).abs() < 1e-5, "a.x: {u} vs {v}");
        }
        for (u, v) in b1.xt.iter().zip(&b2.xt) {
            assert!((u - v).abs() < 1e-5, "b.xt: {u} vs {v}");
        }
        assert_eq!(a1.n_comms, a2.n_comms);

        // Fused runtime path == composed runtime path, bit-for-bit.
        assert_eq!(a3.x, a2.x);
        assert_eq!(a3.xt, a2.xt);
        assert_eq!(b3.x, b2.x);
        assert_eq!(b3.xt, b2.xt);
        assert_eq!(a3.t_last, a2.t_last);
        assert_eq!(a3.n_comms, a2.n_comms);
    }

    #[test]
    fn split_pairing_with_agreed_params_conserves_pair_mean() {
        // A retune lands between the two endpoints' refreshes: worker a
        // still runs the old core, worker b the new one. Averaging with
        // each side's OWN α̃ would drift the pair's x̃ mean; averaging
        // with the agreed (older) snapshot on both sides conserves it.
        let old_p = AcidParams::accelerated(10.0, 1.0);
        let new_p = AcidParams::accelerated(2.0, 1.0);
        let lr = LrSchedule::Constant { lr: 0.1 };
        let core_a = DynamicsCore::with_params(old_p, lr.clone()); // not yet refreshed
        let core_b = DynamicsCore::with_params(new_p, lr); // already refreshed
        let mut a = WorkerState::new(vec![1.0, -2.0]);
        let mut b = WorkerState::new(vec![3.0, 0.5]);
        let mut opt = Sgd::new(0.0);
        core_a.grad_event(&mut a, 0.2, &mut opt, &[1.0, -1.0]);
        core_b.grad_event(&mut b, 0.3, &mut opt, &[0.5, 0.5]);
        let t = 0.7;
        let mut buf_a = vec![0.0f32; 2];
        let mut buf_b = vec![0.0f32; 2];
        core_a.mix_into(&a, t, &mut buf_a);
        core_b.mix_into(&b, t, &mut buf_b);
        // Total pair mass Σ(x + x̃): conserved by the mixing flow and by
        // a comm event iff both endpoints share (α, α̃).
        let mass = |u: &WorkerState, v: &WorkerState| -> f32 {
            u.x.iter().chain(&u.xt).chain(&v.x).chain(&v.xt).sum()
        };
        // Failure mode this guards: each side applying its OWN snapshot
        // (α̃ = 1.58 vs 0.71 here) leaks mass through the x̃ row.
        let (mut a_own, mut b_own) = (a.clone(), b.clone());
        let mass_before = mass(&a_own, &b_own);
        core_a.comm_apply(&mut a_own, t, &buf_b);
        core_b.comm_apply(&mut b_own, t, &buf_a);
        assert!(
            (mass(&a_own, &b_own) - mass_before).abs() > 1e-3,
            "own-snapshot split pairing must visibly leak (else this test is vacuous)"
        );
        // Agreed path: both sides use the older snapshot — mass conserved.
        core_a.comm_apply_agreed(&mut a, t, &buf_b, old_p);
        core_b.comm_apply_agreed(&mut b, t, &buf_a, old_p);
        let mass_after = mass(&a, &b);
        assert!(
            (mass_before - mass_after).abs() < 1e-4,
            "pair mass conserved under agreed params: {mass_before} vs {mass_after}"
        );
        assert_eq!(a.n_comms, 1);
        assert_eq!(b.n_comms, 1);
        // With the agreed params equal to the core's own, the path is
        // exactly comm_apply.
        let mut c = WorkerState::new(vec![1.0, 2.0]);
        let mut d = c.clone();
        core_b.comm_apply(&mut c, 0.1, &[0.5, 0.5]);
        core_b.comm_apply_agreed(&mut d, 0.1, &[0.5, 0.5], core_b.acid);
        assert_eq!(c.x, d.x);
        assert_eq!(c.xt, d.xt);
    }

    #[test]
    fn retune_swaps_params_only_for_accelerated_cores() {
        let lr = LrSchedule::Constant { lr: 0.1 };
        let mut acid = DynamicsCore::for_method(Method::Acid, &spectrum(), lr.clone()).unwrap();
        let before = acid.acid;
        acid.retune(2.0, 1.0);
        assert_ne!(acid.acid, before, "accelerated core retunes");
        assert_eq!(acid.acid, AcidParams::accelerated(2.0, 1.0));
        assert_eq!(acid.mixer.eta, acid.acid.eta, "mixer follows eta");
        // A degenerate spectrum holds the current parameters.
        let held = acid.acid;
        acid.retune(f64::NAN, 1.0);
        assert_eq!(acid.acid, held);
        // The baseline never grows a momentum, whatever the spectrum.
        let mut base =
            DynamicsCore::for_method(Method::AsyncBaseline, &spectrum(), lr).unwrap();
        base.retune(10.0, 1.0);
        assert!(!base.acid.is_accelerated());
        assert_eq!(base.mixer.eta, 0.0);
    }

    #[test]
    fn rejoin_resets_state_from_donor() {
        let core = DynamicsCore::with_params(
            AcidParams::accelerated(5.0, 1.0),
            LrSchedule::Constant { lr: 0.1 },
        );
        let mut st = WorkerState::new(vec![1.0, 2.0]);
        let mut opt = Sgd::new(0.0);
        core.grad_event(&mut st, 0.4, &mut opt, &[1.0, -1.0]);
        let grads_before = st.n_grads;
        core.rejoin_from(&mut st, &[7.0, -7.0], 2.5);
        assert_eq!(st.x, vec![7.0, -7.0]);
        assert_eq!(st.xt, st.x, "tracker restarts glued to x");
        assert_eq!(st.t_last, 2.5);
        assert_eq!(st.n_grads, grads_before, "step count survives the re-join");
    }

    #[test]
    fn sync_all_equalizes_event_times() {
        let core =
            DynamicsCore::with_params(AcidParams::accelerated(5.0, 1.0), LrSchedule::Constant {
                lr: 0.1,
            });
        let mut ws = vec![WorkerState::new(vec![1.0]), WorkerState::new(vec![-1.0])];
        let mut opt = Sgd::new(0.0);
        core.grad_event(&mut ws[0], 0.3, &mut opt, &[0.5]);
        core.sync_all(&mut ws, 2.0);
        assert!(ws.iter().all(|w| w.t_last == 2.0));
    }

    #[test]
    fn loss_ema_folds_and_seeds() {
        assert_eq!(LossEma::fold(f64::NAN, 2.0, 0.9), 2.0);
        let v = LossEma::fold(1.0, 2.0, 0.9);
        assert!((v - 1.1).abs() < 1e-12);
    }
}
