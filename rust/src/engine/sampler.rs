//! Shared mini-batch index stream.
//!
//! Both engines used to carry their own copy of the same sampling loop: a
//! shard cursor advanced by one plus a small seeded random jump, wrapping
//! modulo the shard length. The jump breaks the pathological periodicity
//! of workers sharing a shard while keeping the pass shard-ordered in
//! expectation (the paper's "full dataset, different shuffle" setup
//! degenerates to random cursor restarts here).

use crate::rng::Xoshiro256;

/// The resumable position of a [`BatchSampler`]: shard cursor plus RNG
/// stream position (the shard contents are reconstructed from config).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SamplerState {
    pub cursor: usize,
    pub rng: [u64; 4],
}

/// Cursor-plus-random-jump sampler over a shard of example indices.
pub struct BatchSampler {
    shard: Vec<usize>,
    cursor: usize,
    rng: Xoshiro256,
    batch: Vec<usize>,
}

impl BatchSampler {
    /// `shard` must be non-empty; `rng` is this worker's private stream.
    pub fn new(shard: Vec<usize>, rng: Xoshiro256) -> Self {
        assert!(!shard.is_empty(), "empty shard");
        Self { shard, cursor: 0, rng, batch: Vec::new() }
    }

    /// Convenience constructor from a worker-indexed seed.
    pub fn from_seed(shard: Vec<usize>, seed: u64) -> Self {
        Self::new(shard, Xoshiro256::seed_from_u64(seed))
    }

    /// Number of examples in the shard.
    pub fn shard_len(&self) -> usize {
        self.shard.len()
    }

    /// Checkpoint surface: the cursor position and the RNG stream
    /// position. The shard itself is NOT part of the state — restore
    /// reconstructs it deterministically from the config (sharding is a
    /// pure function of dataset + seed), so checkpoints stay small.
    pub fn state(&self) -> SamplerState {
        SamplerState { cursor: self.cursor, rng: self.rng.state() }
    }

    /// Resume from a captured [`SamplerState`]. The sampler must have
    /// been rebuilt over the same shard the state was captured on.
    pub fn restore(&mut self, st: &SamplerState) {
        assert!(st.cursor < self.shard.len(), "cursor outside shard");
        self.cursor = st.cursor;
        self.rng.restore(st.rng);
    }

    /// Draw the next mini-batch of `batch_size` example indices. The
    /// returned slice is valid until the next call.
    pub fn next_batch(&mut self, batch_size: usize) -> &[usize] {
        self.batch.clear();
        for _ in 0..batch_size {
            let jump = self.rng.gen_range(3);
            self.cursor = (self.cursor + 1 + jump) % self.shard.len();
            self.batch.push(self.shard[self.cursor]);
        }
        &self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_stay_in_shard_and_are_deterministic() {
        let shard: Vec<usize> = (100..150).collect();
        let mut a = BatchSampler::from_seed(shard.clone(), 7);
        let mut b = BatchSampler::from_seed(shard.clone(), 7);
        for _ in 0..20 {
            let ba = a.next_batch(8).to_vec();
            let bb = b.next_batch(8).to_vec();
            assert_eq!(ba, bb);
            assert!(ba.iter().all(|i| shard.contains(i)));
        }
        assert_eq!(a.shard_len(), 50);
    }

    #[test]
    fn different_seeds_decorrelate() {
        let shard: Vec<usize> = (0..64).collect();
        let mut a = BatchSampler::from_seed(shard.clone(), 1);
        let mut b = BatchSampler::from_seed(shard, 2);
        let same = (0..50)
            .filter(|_| a.next_batch(4).to_vec() == b.next_batch(4).to_vec())
            .count();
        assert!(same < 5, "seeds should decorrelate, {same} equal batches");
    }

    #[test]
    fn covers_the_shard_over_time() {
        let shard: Vec<usize> = (0..32).collect();
        let mut s = BatchSampler::from_seed(shard, 3);
        let mut seen = [false; 32];
        for _ in 0..100 {
            for &i in s.next_batch(8) {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "cursor pass covers the shard");
    }

    #[test]
    #[should_panic]
    fn rejects_empty_shard() {
        BatchSampler::from_seed(Vec::new(), 0);
    }

    #[test]
    fn state_round_trip_resumes_the_batch_stream() {
        let shard: Vec<usize> = (0..40).collect();
        let mut a = BatchSampler::from_seed(shard.clone(), 7);
        for _ in 0..13 {
            a.next_batch(8);
        }
        let st = a.state();
        let tail: Vec<Vec<usize>> = (0..10).map(|_| a.next_batch(8).to_vec()).collect();
        // A fresh sampler over the SAME shard, restored to the captured
        // position, continues identically.
        let mut b = BatchSampler::from_seed(shard, 999);
        b.restore(&st);
        let resumed: Vec<Vec<usize>> = (0..10).map(|_| b.next_batch(8).to_vec()).collect();
        assert_eq!(tail, resumed);
    }
}
