//! Local optimizer and learning-rate schedules.
//!
//! The paper trains with SGD (base LR 0.1, heavy-ball momentum 0.9, weight
//! decay 5e-4) under the large-batch recipe of Goyal et al.: the LR is
//! scaled with the worker count, linearly warmed up, then decayed by 10×
//! at fixed epoch milestones. Each worker runs this optimizer *locally*;
//! decentralization happens purely through the gossip layer on the
//! parameter vector.

/// Heavy-ball SGD with decoupled weight-decay handling left to the model
/// (the models add the decay term to the gradient so it passes through the
/// same momentum path as in PyTorch's SGD, matching the paper's setup).
#[derive(Clone, Debug)]
pub struct Sgd {
    /// Heavy-ball coefficient (paper: 0.9).
    pub momentum: f32,
    /// Velocity buffer (lazily sized).
    velocity: Vec<f32>,
}

impl Sgd {
    pub fn new(momentum: f32) -> Self {
        assert!((0.0..1.0).contains(&momentum), "momentum out of range");
        Self { momentum, velocity: Vec::new() }
    }

    /// Fold the raw gradient through the momentum buffer, producing the
    /// effective step direction `v ← m·v + g` (returned as a slice into
    /// internal storage). With `momentum = 0` this is the identity.
    pub fn direction<'a>(&'a mut self, grad: &'a [f32]) -> &'a [f32] {
        if self.momentum == 0.0 {
            return grad;
        }
        if self.velocity.len() != grad.len() {
            self.velocity = vec![0.0; grad.len()];
        }
        for (v, &g) in self.velocity.iter_mut().zip(grad) {
            *v = self.momentum * *v + g;
        }
        &self.velocity
    }

    /// Reset the velocity (used when parameters are externally replaced).
    pub fn reset(&mut self) {
        self.velocity.fill(0.0);
    }

    /// Checkpoint surface: the raw velocity buffer (empty until the first
    /// momentum-bearing [`Sgd::direction`] call — the lazy-size contract).
    pub fn velocity(&self) -> &[f32] {
        &self.velocity
    }

    /// Overwrite the velocity buffer from a checkpoint. An empty slice
    /// restores the pristine lazily-sized state.
    pub fn restore_velocity(&mut self, v: &[f32]) {
        self.velocity.clear();
        self.velocity.extend_from_slice(v);
    }
}

/// Learning-rate schedules.
#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    Constant {
        lr: f64,
    },
    /// Goyal et al. large-batch recipe: linear warmup from `lr/warmup` to
    /// `lr·scale` over `warmup` steps, then ×0.1 at each milestone.
    WarmupStep {
        base_lr: f64,
        /// Linear scaling factor (≈ number of workers).
        scale: f64,
        warmup_steps: u64,
        /// Step milestones after which LR is divided by 10.
        milestones: Vec<u64>,
    },
    /// Cosine decay from `lr` to `lr·floor` over `total_steps`.
    Cosine {
        lr: f64,
        floor: f64,
        total_steps: u64,
    },
}

impl LrSchedule {
    /// Learning rate at (0-indexed) step `t`.
    pub fn at(&self, t: u64) -> f64 {
        match self {
            LrSchedule::Constant { lr } => *lr,
            LrSchedule::WarmupStep { base_lr, scale, warmup_steps, milestones } => {
                let peak = base_lr * scale;
                if t < *warmup_steps {
                    // Linear ramp from base_lr to peak (Goyal et al. §2.2).
                    let frac = (t + 1) as f64 / *warmup_steps as f64;
                    base_lr + (peak - base_lr) * frac
                } else {
                    let drops = milestones.iter().filter(|&&m| t >= m).count() as i32;
                    peak * 0.1f64.powi(drops)
                }
            }
            LrSchedule::Cosine { lr, floor, total_steps } => {
                let frac = (t.min(*total_steps)) as f64 / (*total_steps).max(1) as f64;
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * frac).cos());
                lr * (*floor + (1.0 - floor) * cos)
            }
        }
    }

    /// The paper's CIFAR recipe scaled to `steps_total` local steps:
    /// decay at 50% and 75% of training, warmup over the first 5%.
    pub fn paper_cifar(base_lr: f64, n_workers: usize, steps_total: u64) -> Self {
        LrSchedule::WarmupStep {
            base_lr,
            scale: n_workers as f64,
            warmup_steps: (steps_total / 20).max(1),
            milestones: vec![steps_total / 2, steps_total * 3 / 4],
        }
    }

    /// Square-root LR scaling variant of [`LrSchedule::paper_cifar`]. The
    /// paper's linear scaling is tuned for ResNets with batch-norm; the
    /// small synthetic models of the experiment harness tolerate less, so
    /// the sweeps use √n scaling (orderings between methods are
    /// unaffected; DESIGN.md §3).
    pub fn paper_cifar_sqrt(base_lr: f64, n_workers: usize, steps_total: u64) -> Self {
        LrSchedule::WarmupStep {
            base_lr,
            scale: (n_workers as f64).sqrt(),
            warmup_steps: (steps_total / 20).max(1),
            milestones: vec![steps_total / 2, steps_total * 3 / 4],
        }
    }

    /// The paper's ImageNet recipe: decay at 33%, 66%, 89% (epochs
    /// 30/60/80 of 90).
    pub fn paper_imagenet(base_lr: f64, n_workers: usize, steps_total: u64) -> Self {
        LrSchedule::WarmupStep {
            base_lr,
            scale: n_workers as f64,
            warmup_steps: (steps_total / 18).max(1),
            milestones: vec![
                steps_total / 3,
                steps_total * 2 / 3,
                steps_total * 8 / 9,
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_no_momentum_is_identity() {
        let mut opt = Sgd::new(0.0);
        let g = vec![1.0f32, -2.0];
        assert_eq!(opt.direction(&g), &[1.0, -2.0]);
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let mut opt = Sgd::new(0.5);
        let g = vec![1.0f32];
        assert_eq!(opt.direction(&g), &[1.0]);
        assert_eq!(opt.direction(&g), &[1.5]);
        assert_eq!(opt.direction(&g), &[1.75]);
        opt.reset();
        assert_eq!(opt.direction(&g), &[1.0]);
    }

    #[test]
    fn sgd_velocity_round_trips() {
        let mut opt = Sgd::new(0.5);
        let g = vec![1.0f32, 2.0];
        opt.direction(&g);
        opt.direction(&g);
        let snap = opt.velocity().to_vec();
        assert_eq!(snap, vec![1.5, 3.0]);
        // A fresh optimizer restored from the snapshot continues the
        // same momentum trajectory.
        let mut fresh = Sgd::new(0.5);
        assert!(fresh.velocity().is_empty(), "lazily sized until first use");
        fresh.restore_velocity(&snap);
        assert_eq!(opt.direction(&g), fresh.direction(&g));
    }

    #[test]
    fn warmup_ramps_then_drops() {
        let s = LrSchedule::WarmupStep {
            base_lr: 0.1,
            scale: 4.0,
            warmup_steps: 10,
            milestones: vec![100, 200],
        };
        assert!(s.at(0) < s.at(5));
        assert!(s.at(5) < s.at(9));
        assert!((s.at(10) - 0.4).abs() < 1e-12);
        assert!((s.at(150) - 0.04).abs() < 1e-12);
        assert!((s.at(250) - 0.004).abs() < 1e-12);
    }

    #[test]
    fn cosine_monotone_decay() {
        let s = LrSchedule::Cosine { lr: 1.0, floor: 0.1, total_steps: 100 };
        assert!((s.at(0) - 1.0).abs() < 1e-9);
        assert!(s.at(50) < s.at(10));
        assert!((s.at(100) - 0.1).abs() < 1e-9);
        assert!((s.at(500) - 0.1).abs() < 1e-9, "clamps past the end");
    }

    #[test]
    fn paper_recipes_shape() {
        let s = LrSchedule::paper_cifar(0.1, 8, 1000);
        // Peak = 0.8 after warmup; one drop by 500, two by 750.
        assert!((s.at(100) - 0.8).abs() < 1e-12);
        assert!((s.at(600) - 0.08).abs() < 1e-12);
        assert!((s.at(800) - 0.008).abs() < 1e-12);
        let si = LrSchedule::paper_imagenet(0.1, 4, 900);
        assert!((si.at(200) - 0.4).abs() < 1e-12);
        assert!((si.at(850) - 0.0004).abs() < 1e-9);
    }
}
