//! Time-varying network scenarios.
//!
//! The paper's experiments (and the seed reproduction) only exercise
//! *static* topologies with *fixed* Poisson rates. Real decentralized
//! deployments are the opposite: links fail and recover, the overlay is
//! re-wired mid-run, worker speeds drift — and the worker *set* itself
//! churns, with machines departing and re-joining mid-training. A
//! [`Scenario`] describes such a network as data, and compiles —
//! deterministically under a seed — to a [`NetworkPlan`]: the *union
//! graph* over every phase plus a sorted list of timed updates. Both
//! execution engines replay the same plan: the virtual-time simulator
//! applies updates exactly between events
//! ([`crate::engine::VirtualTimeScheduler`]), the threaded runtime
//! applies them from its monitor loop ([`crate::engine::WallClock`]).
//!
//! ## Scenario string syntax
//!
//! ```text
//! phases[;option]*
//!
//! phases := topo[@frac](,topo@frac)*     e.g.  ring@0,exponential@0.5
//! drop   := drop=FRAC[:FROM[:TO[:SEED]]] e.g.  drop=0.2:0.25:0.75
//! het    := het=SIGMA[:SEED]             log-normal per-edge rate spread
//! drift  := drift=AMP[:STEPS[:SEED]]     linear per-worker speed drift
//! leave  := leave=FRAC:T[:SEED]          FRAC of the fleet departs at T
//! join   := join=FRAC:T                  departed workers re-join at T
//! adapt  := adapt=0|1                    re-derive (η, α̃) per phase (default 1)
//! algo   := algo=a2cid2|adpsgd|localsgd:H|allreduce   update rule (default: config's)
//! ```
//!
//! All times are *fractions of the run horizon* in `[0, 1)`; the horizon
//! is the expected virtual run length (`steps_per_worker` at unit
//! gradient rate, and the same in normalized wall-clock time). Example:
//! `"ring@0,exponential@0.5;drop=0.2:0.25:0.75;drift=0.3"` starts on the
//! ring, drops 20% of links over the middle half of the run, switches to
//! the exponential graph at half-time, and drifts worker speeds by ±30%.
//!
//! ## Worker churn
//!
//! `leave=FRAC:T[:SEED]` removes `round(FRAC·n)` of the currently-active
//! workers at horizon fraction `T` (membership drawn from `SEED`): their
//! gradient processes are silenced and every incident link rate drops to
//! zero. `join=FRAC:T` re-admits up to `round(FRAC·n)` departed workers
//! (longest-departed first); a re-joining worker re-initializes from a
//! neighbor snapshot (the engines pick the smallest-index active union
//! neighbor as the donor). Churn that could ever leave fewer than two
//! active workers is a *parse/compile error*, never a runtime panic.
//!
//! ## Adaptive (η, α̃)
//!
//! The A²CiD² parameters are functions of the communication graph's
//! spectrum (χ₁, χ₂). With `adapt=1` (the default) every update that
//! changes the topology phase or the worker set carries the spectrum of
//! the *newly-active subgraph* ([`NetUpdate::chis`]); the engines
//! re-derive (η, α̃) from it mid-run instead of holding phase-0's values.
//! `adapt=0` freezes the phase-0 parameters for the whole run (the
//! ablation arm of the sweep experiment). Dropout windows never retune —
//! a window may disconnect the graph — and a churn event that leaves the
//! active subgraph disconnected publishes no spectrum (the previous
//! parameters are held).

use std::collections::HashMap;
use std::fmt;

use super::Algorithm;
use crate::graph::{Graph, Spectrum, Topology};
use crate::rng::{standard_normal, Xoshiro256};

/// One topology phase, active from fraction `at` until the next phase.
#[derive(Clone, Debug, PartialEq)]
pub struct Phase {
    /// Start time as a fraction of the run horizon, in `[0, 1)`.
    pub at: f64,
    pub topology: Topology,
}

/// Random link failures: `frac` of the union edges go silent during
/// `[from, to)` (fractions of the horizon), then recover.
#[derive(Clone, Debug, PartialEq)]
pub struct Dropout {
    pub frac: f64,
    pub from: f64,
    pub to: f64,
    pub seed: u64,
}

/// Heterogeneous links: each union edge's rate is multiplied by an
/// i.i.d. log-normal factor `exp(σ·z − σ²/2)` (unit mean).
#[derive(Clone, Debug, PartialEq)]
pub struct RateSpread {
    pub sigma: f64,
    pub seed: u64,
}

/// Drifting compute speeds: worker `w`'s gradient rate ramps linearly to
/// `base·(1 ± amp)` over the run (per-worker direction drawn from `seed`),
/// applied as `steps` piecewise-constant rate updates.
#[derive(Clone, Debug, PartialEq)]
pub struct SpeedDrift {
    pub amp: f64,
    pub steps: usize,
    pub seed: u64,
}

/// Which way a churn event moves the worker set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnKind {
    Leave,
    Join,
}

/// One scheduled worker-set change (`leave=` / `join=` options), kept
/// sorted by `at` after parsing.
#[derive(Clone, Debug, PartialEq)]
pub struct ChurnEvent {
    pub kind: ChurnKind,
    /// Fraction of the *original* fleet affected, in `(0, 1)` for leave
    /// and `(0, 1]` for join.
    pub frac: f64,
    /// Event time as a fraction of the horizon, in `(0, 1)`.
    pub at: f64,
    /// Membership seed (leave events; joins re-admit FIFO).
    pub seed: u64,
}

/// A declarative time-varying network: topology phases plus optional
/// dropout, per-edge rate spread, per-worker speed drift, and worker
/// churn.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    pub phases: Vec<Phase>,
    pub dropout: Option<Dropout>,
    pub het: Option<RateSpread>,
    pub drift: Option<SpeedDrift>,
    /// Worker-set changes, sorted by time (strictly increasing).
    pub churn: Vec<ChurnEvent>,
    /// Re-derive (η, α̃) from the active subgraph's spectrum at every
    /// phase switch / churn event (`adapt=1`, the default) instead of
    /// holding phase-0's parameters (`adapt=0`).
    pub adaptive: bool,
    /// Update rule to run this scenario under (`algo=` option). `None`
    /// (the default, rendered as nothing by `Display`) defers to the
    /// config/CLI, so every pre-zoo scenario string is unchanged. The
    /// option exists so one *string* fully names a compare arm.
    pub algo: Option<Algorithm>,
}

/// One timed network update of a compiled plan. `None`/empty fields are
/// unchanged from the previous state.
#[derive(Clone, Debug, PartialEq)]
pub struct NetUpdate {
    /// Absolute time (virtual-time units / normalized wall-clock units).
    pub t: f64,
    /// New per-edge rates over the union edge list (0 = link inactive).
    pub edge_rates: Option<Vec<f64>>,
    /// New per-worker gradient rates.
    pub grad_rates: Option<Vec<f64>>,
    /// Sparse form of `edge_rates`: exactly the `(union edge index, new
    /// rate)` entries that differ from the preceding state, ascending by
    /// index. Schedulers apply THESE — O(edges changed) per update — and
    /// only fall back to the dense vector when a hand-built update
    /// carries no diff. Present iff `edge_rates` is.
    pub edge_diff: Vec<(usize, f64)>,
    /// Sparse form of `grad_rates`: the `(worker, new rate)` entries
    /// that changed, ascending by worker. Present iff `grad_rates` is.
    pub grad_diff: Vec<(usize, f64)>,
    /// Workers departing at this update (their rates are already zeroed
    /// in the vectors above).
    pub leave: Vec<usize>,
    /// Workers re-joining at this update; each re-initializes from a
    /// neighbor snapshot before its processes resume.
    pub join: Vec<usize>,
    /// (χ₁, χ₂) of the newly-active subgraph, present when the topology
    /// phase or the worker set changed under `adapt=1` and the active
    /// subgraph is connected. Engines running the accelerated method
    /// re-derive (η, α̃) from it; `None` holds the previous parameters.
    pub chis: Option<(f64, f64)>,
}

impl NetUpdate {
    /// Workers whose local view changed at this update: endpoints of
    /// every diffed edge, every worker with a diffed gradient rate, and
    /// the churn sets. Sorted, deduplicated. A coordinator rematch scan
    /// only needs to look at these — O(edges changed), never O(n).
    pub fn touched_workers(&self, union_edges: &[(usize, usize)]) -> Vec<usize> {
        let mut out = Vec::with_capacity(2 * self.edge_diff.len());
        for &(e, _) in &self.edge_diff {
            let (i, j) = union_edges[e];
            out.push(i);
            out.push(j);
        }
        out.extend(self.grad_diff.iter().map(|&(w, _)| w));
        out.extend_from_slice(&self.leave);
        out.extend_from_slice(&self.join);
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// A compiled scenario: union graph, initial rates, and sorted updates.
/// The edge indexing of every rate vector follows `union.edges`.
pub struct NetworkPlan {
    pub union: Graph,
    pub horizon: f64,
    pub initial_edge_rates: Vec<f64>,
    pub initial_grad_rates: Vec<f64>,
    pub updates: Vec<NetUpdate>,
    /// Spectrum of the phase-0 rate-weighted Laplacian (with the rate
    /// spread applied, dropout ignored) — the (χ₁, χ₂) the *initial*
    /// A²CiD² parameters are derived from. Under `adapt=1` later phases
    /// retune via [`NetUpdate::chis`]; under `adapt=0` these values are
    /// held for the whole run.
    pub spectrum: Spectrum,
}

impl NetworkPlan {
    /// Trivial plan for a static graph (no scenario): one phase, no
    /// updates. `comm_rate` may be 0 (no communication); the spectrum is
    /// computed at a floored rate so (χ₁, χ₂) stay finite.
    pub fn static_plan(graph: Graph, comm_rate: f64, base_grad_rates: &[f64]) -> NetworkPlan {
        assert_eq!(base_grad_rates.len(), graph.n, "one gradient rate per worker");
        let initial_edge_rates = graph.edge_rates(comm_rate);
        let spectrum = graph.spectrum_auto(&graph.edge_rates(comm_rate.max(1e-6)));
        NetworkPlan {
            union: graph,
            horizon: f64::INFINITY,
            initial_edge_rates,
            initial_grad_rates: base_grad_rates.to_vec(),
            updates: Vec::new(),
            spectrum,
        }
    }
}

impl Scenario {
    /// A single static phase — what a plain `topology` config denotes.
    pub fn static_topology(topology: Topology) -> Scenario {
        Scenario {
            phases: vec![Phase { at: 0.0, topology }],
            dropout: None,
            het: None,
            drift: None,
            churn: Vec::new(),
            adaptive: true,
            algo: None,
        }
    }

    /// Parse the scenario string syntax (see module docs).
    pub fn parse(s: &str) -> crate::Result<Scenario> {
        let mut parts = s.split(';');
        let phase_str = parts.next().unwrap_or("").trim();
        anyhow::ensure!(!phase_str.is_empty(), "scenario needs at least one phase");
        let mut phases = Vec::new();
        for (idx, item) in phase_str.split(',').enumerate() {
            let item = item.trim();
            let (topo_str, at) = match item.rsplit_once('@') {
                Some((t, f)) => {
                    let at: f64 = f
                        .parse()
                        .map_err(|e| anyhow::anyhow!("phase '{item}': bad time '{f}': {e}"))?;
                    (t, at)
                }
                None => {
                    anyhow::ensure!(
                        idx == 0,
                        "phase '{item}': only the first phase may omit '@time'"
                    );
                    (item, 0.0)
                }
            };
            anyhow::ensure!(
                (0.0..1.0).contains(&at),
                "phase '{item}': time {at} outside [0, 1)"
            );
            phases.push(Phase { at, topology: Topology::parse(topo_str)? });
        }
        anyhow::ensure!(
            phases[0].at == 0.0,
            "first phase must start at 0, got {}",
            phases[0].at
        );
        for w in phases.windows(2) {
            anyhow::ensure!(
                w[0].at < w[1].at,
                "phase times must be strictly increasing ({} then {})",
                w[0].at,
                w[1].at
            );
        }

        let mut scenario = Scenario {
            phases,
            dropout: None,
            het: None,
            drift: None,
            churn: Vec::new(),
            adaptive: true,
            algo: None,
        };
        for opt in parts {
            let opt = opt.trim();
            if opt.is_empty() {
                continue;
            }
            let (key, val) = opt
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("scenario option '{opt}' is not key=value"))?;
            let fields: Vec<&str> = val.split(':').collect();
            let f64_at = |i: usize, default: f64| -> crate::Result<f64> {
                match fields.get(i) {
                    Some(s) => s
                        .parse()
                        .map_err(|e| anyhow::anyhow!("{key}: bad number '{s}': {e}")),
                    None => Ok(default),
                }
            };
            let u64_at = |i: usize, default: u64| -> crate::Result<u64> {
                match fields.get(i) {
                    Some(s) => s
                        .parse()
                        .map_err(|e| anyhow::anyhow!("{key}: bad integer '{s}': {e}")),
                    None => Ok(default),
                }
            };
            match key {
                "drop" => {
                    let d = Dropout {
                        frac: f64_at(0, f64::NAN)?,
                        from: f64_at(1, 0.0)?,
                        to: f64_at(2, 1.0)?,
                        seed: u64_at(3, 0)?,
                    };
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&d.frac),
                        "drop fraction {} outside [0, 1]",
                        d.frac
                    );
                    anyhow::ensure!(
                        0.0 <= d.from && d.from < d.to && d.to <= 1.0,
                        "drop window [{}, {}) invalid",
                        d.from,
                        d.to
                    );
                    scenario.dropout = Some(d);
                }
                "het" => {
                    let h = RateSpread { sigma: f64_at(0, f64::NAN)?, seed: u64_at(1, 0)? };
                    anyhow::ensure!(h.sigma >= 0.0, "het sigma must be >= 0, got {}", h.sigma);
                    scenario.het = Some(h);
                }
                "drift" => {
                    let d = SpeedDrift {
                        amp: f64_at(0, f64::NAN)?,
                        steps: u64_at(1, 8)? as usize,
                        seed: u64_at(2, 0)?,
                    };
                    anyhow::ensure!(
                        (0.0..1.0).contains(&d.amp),
                        "drift amplitude {} outside [0, 1)",
                        d.amp
                    );
                    anyhow::ensure!(d.steps >= 1, "drift needs >= 1 steps");
                    scenario.drift = Some(d);
                }
                "leave" | "join" => {
                    let kind = if key == "leave" { ChurnKind::Leave } else { ChurnKind::Join };
                    let ev = ChurnEvent {
                        kind,
                        frac: f64_at(0, f64::NAN)?,
                        at: f64_at(1, f64::NAN)?,
                        seed: u64_at(2, 0)?,
                    };

                    anyhow::ensure!(
                        fields.len() >= 2,
                        "{key} needs FRAC:TIME, got '{val}'"
                    );
                    match kind {
                        ChurnKind::Leave => {
                            anyhow::ensure!(
                                ev.frac > 0.0 && ev.frac < 1.0,
                                "leave fraction {} outside (0, 1)",
                                ev.frac
                            );
                            anyhow::ensure!(
                                fields.len() <= 3,
                                "leave takes FRAC:TIME[:SEED] only, got '{val}'"
                            );
                        }
                        ChurnKind::Join => {
                            anyhow::ensure!(
                                ev.frac > 0.0 && ev.frac <= 1.0,
                                "join fraction {} outside (0, 1]",
                                ev.frac
                            );
                            // Joins re-admit FIFO — no membership draw, so
                            // a seed field would be silently meaningless
                            // (and Display couldn't round-trip it).
                            anyhow::ensure!(
                                fields.len() <= 2,
                                "join takes FRAC:TIME only, got '{val}'"
                            );
                        }
                    }
                    anyhow::ensure!(
                        ev.at > 0.0 && ev.at < 1.0,
                        "{key} time {} outside (0, 1)",
                        ev.at
                    );
                    scenario.churn.push(ev);
                }
                "adapt" => {
                    let v = u64_at(0, 1)?;
                    anyhow::ensure!(v <= 1, "adapt must be 0 or 1, got {v}");
                    scenario.adaptive = v == 1;
                }
                // The algorithm value itself may contain ':' (localsgd:H),
                // so it parses from the raw value, not the ':'-split fields.
                "algo" => scenario.algo = Some(Algorithm::parse(val)?),
                other => anyhow::bail!("unknown scenario option '{other}'"),
            }
        }

        // Churn sanity, independent of n: sort by time (events may be
        // written in any order), require distinct times, and walk the
        // fraction algebra so a history that could empty the graph is a
        // PARSE error, not a runtime panic.
        scenario
            .churn
            .sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
        for w in scenario.churn.windows(2) {
            anyhow::ensure!(
                w[0].at < w[1].at,
                "churn events need distinct times (two at {})",
                w[0].at
            );
        }
        let mut departed_frac = 0.0f64;
        for ev in &scenario.churn {
            match ev.kind {
                ChurnKind::Leave => {
                    departed_frac += ev.frac;
                    anyhow::ensure!(
                        departed_frac < 1.0,
                        "churn would empty the graph: {:.0}% departed by t={}",
                        departed_frac * 100.0,
                        ev.at
                    );
                }
                ChurnKind::Join => {
                    anyhow::ensure!(
                        departed_frac > 0.0,
                        "join at t={} but nothing has departed yet",
                        ev.at
                    );
                    departed_frac = (departed_frac - ev.frac).max(0.0);
                }
            }
        }
        Ok(scenario)
    }

    /// Cheap config-time validation: every phase topology must build
    /// (and be connected) for `n` workers, and no churn event may shrink
    /// the active fleet below two. Full compilation (union graph, RNG
    /// draws, the O(n³) spectrum eigensolves) is deferred to run start
    /// so config validation doesn't pay it twice.
    pub fn validate_for(&self, n: usize) -> crate::Result<()> {
        for phase in &self.phases {
            Graph::build(&phase.topology, n)?;
        }
        self.churn_counts(n)?;
        Ok(())
    }

    /// Walk the churn timeline with exact worker counts; errors if any
    /// leave would take the active fleet below two workers.
    fn churn_counts(&self, n: usize) -> crate::Result<Vec<usize>> {
        let mut active = n;
        let mut departed = 0usize;
        let mut counts = Vec::with_capacity(self.churn.len());
        for ev in &self.churn {
            let k = (ev.frac * n as f64).round() as usize;
            let k = match ev.kind {
                ChurnKind::Leave => {
                    anyhow::ensure!(
                        active >= k + 2,
                        "churn would leave fewer than 2 active workers at t={} \
                         ({} active, {} leaving)",
                        ev.at,
                        active,
                        k
                    );
                    active -= k;
                    departed += k;
                    k
                }
                ChurnKind::Join => {
                    let k = k.min(departed);
                    active += k;
                    departed -= k;
                    k
                }
            };
            counts.push(k);
        }
        Ok(counts)
    }

    /// Compile to a [`NetworkPlan`] for `n` workers. `comm_rate` is the
    /// per-worker expected communications per unit time, `horizon` the
    /// expected run length in the engine's time units, `base_grad_rates`
    /// the per-worker gradient rates before drift (one per worker).
    /// Deterministic: identical inputs yield an identical plan.
    pub fn compile(
        &self,
        n: usize,
        comm_rate: f64,
        horizon: f64,
        base_grad_rates: &[f64],
    ) -> crate::Result<NetworkPlan> {
        anyhow::ensure!(n >= 2, "need >= 2 workers");
        anyhow::ensure!(
            base_grad_rates.len() == n,
            "need one gradient rate per worker ({} != {n})",
            base_grad_rates.len()
        );
        anyhow::ensure!(
            horizon.is_finite() && horizon > 0.0,
            "scenario needs a finite positive horizon, got {horizon}"
        );

        // Per-phase graphs (each validated connected by Graph::build) and
        // their degree-based per-edge rates, keyed by endpoint pair.
        let mut phase_graphs = Vec::with_capacity(self.phases.len());
        let mut phase_rates: Vec<HashMap<(usize, usize), f64>> =
            Vec::with_capacity(self.phases.len());
        for phase in &self.phases {
            let g = Graph::build(&phase.topology, n)?;
            let rates = g.edge_rates(comm_rate);
            let map = g.edges.iter().copied().zip(rates).collect();
            phase_graphs.push(g);
            phase_rates.push(map);
        }

        // Union graph over all phases: the stable edge indexing every
        // rate vector uses.
        let union = Graph::from_edges(
            n,
            phase_graphs.iter().flat_map(|g| g.edges.iter().copied()),
        );

        // Per-edge heterogeneity multipliers (unit-mean log-normal).
        let het_mult: Vec<f64> = match &self.het {
            Some(h) => {
                let mut rng = Xoshiro256::seed_from_u64(h.seed ^ 0x4E37);
                union
                    .edges
                    .iter()
                    .map(|_| (h.sigma * standard_normal(&mut rng) - 0.5 * h.sigma * h.sigma).exp())
                    .collect()
            }
            None => vec![1.0; union.edges.len()],
        };

        // Dropped-link set, sampled once over the union edges.
        let dropped: Vec<bool> = match &self.dropout {
            Some(d) => {
                let mut rng = Xoshiro256::seed_from_u64(d.seed ^ 0xD201);
                let k = (d.frac * union.edges.len() as f64).round() as usize;
                let k = k.min(union.edges.len());
                let mut mask = vec![false; union.edges.len()];
                for e in rng.sample_indices(union.edges.len(), k) {
                    mask[e] = true;
                }
                mask
            }
            None => vec![false; union.edges.len()],
        };

        // Per-worker drift slopes in [-amp, +amp].
        let drift_slopes: Vec<f64> = match &self.drift {
            Some(d) => {
                let mut rng = Xoshiro256::seed_from_u64(d.seed ^ 0xD81F);
                (0..n).map(|_| d.amp * (2.0 * rng.next_f64() - 1.0)).collect()
            }
            None => vec![0.0; n],
        };

        // Churn membership, resolved in time order: each leave draws its
        // departing set from the event's seed over the currently-active
        // fleet; each join re-admits the longest-departed first.
        let churn_ks = self.churn_counts(n)?;
        let mut churn_deltas: Vec<(f64, Vec<usize>, Vec<usize>)> = Vec::new();
        {
            let mut active = vec![true; n];
            let mut departed: Vec<usize> = Vec::new();
            for (ev, &k) in self.churn.iter().zip(&churn_ks) {
                if k == 0 {
                    continue; // fraction rounds to nobody at this n
                }
                match ev.kind {
                    ChurnKind::Leave => {
                        let alive: Vec<usize> = (0..n).filter(|&w| active[w]).collect();
                        let mut rng = Xoshiro256::seed_from_u64(ev.seed ^ 0xC4B2);
                        let mut leavers: Vec<usize> = rng
                            .sample_indices(alive.len(), k)
                            .into_iter()
                            .map(|i| alive[i])
                            .collect();
                        leavers.sort_unstable();
                        for &w in &leavers {
                            active[w] = false;
                            departed.push(w);
                        }
                        churn_deltas.push((ev.at, leavers, Vec::new()));
                    }
                    ChurnKind::Join => {
                        let joiners: Vec<usize> = departed.drain(..k).collect();
                        for &w in &joiners {
                            active[w] = true;
                        }
                        churn_deltas.push((ev.at, Vec::new(), joiners));
                    }
                }
            }
        }

        // All change points as horizon fractions, deduplicated and sorted.
        let mut fracs: Vec<f64> = self.phases.iter().map(|p| p.at).collect();
        if let Some(d) = &self.dropout {
            fracs.push(d.from);
            fracs.push(d.to);
        }
        if let Some(d) = &self.drift {
            for k in 1..=d.steps {
                fracs.push(k as f64 / (d.steps + 1) as f64);
            }
        }
        for (at, _, _) in &churn_deltas {
            fracs.push(*at);
        }
        fracs.retain(|f| (0.0..1.0).contains(f));
        fracs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        fracs.dedup();

        let phase_at = |f: f64| -> usize {
            self.phases
                .iter()
                .rposition(|p| p.at <= f)
                .expect("first phase starts at 0")
        };
        let edge_rates_at = |f: f64, mask: &[bool]| -> Vec<f64> {
            let phase_idx = phase_at(f);
            let in_drop_window = self
                .dropout
                .as_ref()
                .is_some_and(|d| f >= d.from && f < d.to);
            union
                .edges
                .iter()
                .enumerate()
                .map(|(e, &(i, j))| {
                    if (in_drop_window && dropped[e]) || !(mask[i] && mask[j]) {
                        return 0.0;
                    }
                    phase_rates[phase_idx].get(&(i, j)).copied().unwrap_or(0.0) * het_mult[e]
                })
                .collect()
        };
        let grad_rates_at = |f: f64, mask: &[bool]| -> Vec<f64> {
            base_grad_rates
                .iter()
                .zip(&drift_slopes)
                .enumerate()
                .map(|(w, (&base, &s))| {
                    if mask[w] {
                        (base * (1.0 + s * f)).max(0.05)
                    } else {
                        0.0
                    }
                })
                .collect()
        };
        // (χ₁, χ₂) of the induced subgraph over the active workers under
        // phase `phase_idx` (dropout ignored, as for the phase-0
        // spectrum). `None` when the subgraph is disconnected or the
        // spectrum is unusable — the engines then hold their previous
        // parameters.
        let active_chis = |phase_idx: usize, mask: &[bool]| -> Option<(f64, f64)> {
            if comm_rate <= 0.0 {
                return None;
            }
            let alive: Vec<usize> = (0..n).filter(|&w| mask[w]).collect();
            if alive.len() < 2 {
                return None;
            }
            let remap: HashMap<usize, usize> =
                alive.iter().enumerate().map(|(new, &old)| (old, new)).collect();
            let mut pairs = Vec::new();
            let mut rate_of: HashMap<(usize, usize), f64> = HashMap::new();
            for (e, &(i, j)) in union.edges.iter().enumerate() {
                if !(mask[i] && mask[j]) {
                    continue;
                }
                let r = phase_rates[phase_idx].get(&(i, j)).copied().unwrap_or(0.0) * het_mult[e];
                if r > 0.0 {
                    let (a, b) = (remap[&i], remap[&j]);
                    pairs.push((a, b));
                    rate_of.insert((a.min(b), a.max(b)), r);
                }
            }
            if pairs.is_empty() {
                return None;
            }
            let g = Graph::from_edges(alive.len(), pairs);
            if !g.is_connected() {
                return None;
            }
            let rates: Vec<f64> = g.edges.iter().map(|ij| rate_of[ij]).collect();
            let s = g.spectrum_auto(&rates);
            (s.chi1.is_finite() && s.chi1 > 0.0 && s.chi2.is_finite() && s.chi2 > 0.0)
                .then(|| (s.chi1, s.chi2.min(s.chi1)))
        };

        let mut mask = vec![true; n];
        let initial_edge_rates = edge_rates_at(0.0, &mask);
        let initial_grad_rates = grad_rates_at(0.0, &mask);
        let mut updates = Vec::new();
        let mut prev_edges = initial_edge_rates.clone();
        let mut prev_grads = initial_grad_rates.clone();
        let mut prev_phase = 0usize;
        for &f in fracs.iter().filter(|&&f| f > 0.0) {
            // Apply any churn delta landing exactly at this change point
            // (exact f64 equality: both sides are the same parsed value).
            let delta = churn_deltas.iter().find(|(at, _, _)| *at == f);
            let (leave, join) = match delta {
                Some((_, l, j)) => (l.clone(), j.clone()),
                None => (Vec::new(), Vec::new()),
            };
            for &w in &leave {
                mask[w] = false;
            }
            for &w in &join {
                mask[w] = true;
            }
            let phase_idx = phase_at(f);
            let chis = if self.adaptive && (phase_idx != prev_phase || delta.is_some()) {
                active_chis(phase_idx, &mask)
            } else {
                None
            };
            prev_phase = phase_idx;
            let edges = edge_rates_at(f, &mask);
            let grads = grad_rates_at(f, &mask);
            // Diff against the running state: the sparse lists are what
            // schedulers apply; the dense vectors ride along for
            // consumers that want the full post-update state.
            let edge_diff: Vec<(usize, f64)> = edges
                .iter()
                .zip(&prev_edges)
                .enumerate()
                .filter(|(_, (new, old))| new != old)
                .map(|(e, (&new, _))| (e, new))
                .collect();
            let grad_diff: Vec<(usize, f64)> = grads
                .iter()
                .zip(&prev_grads)
                .enumerate()
                .filter(|(_, (new, old))| new != old)
                .map(|(w, (&new, _))| (w, new))
                .collect();
            let edge_rates = (!edge_diff.is_empty()).then(|| edges.clone());
            let grad_rates = (!grad_diff.is_empty()).then(|| grads.clone());
            prev_edges = edges;
            prev_grads = grads;
            if edge_rates.is_some()
                || grad_rates.is_some()
                || !leave.is_empty()
                || !join.is_empty()
                || chis.is_some()
            {
                updates.push(NetUpdate {
                    t: f * horizon,
                    edge_rates,
                    grad_rates,
                    edge_diff,
                    grad_diff,
                    leave,
                    join,
                    chis,
                });
            }
        }

        // (χ₁, χ₂) of the phase-0 network, dropout ignored (a dropout
        // window may disconnect the graph; the initial parameters come
        // from the clean phase-0 spectrum).
        let spectrum_rates: Vec<f64> = union
            .edges
            .iter()
            .enumerate()
            .map(|(e, ij)| {
                phase_rates[0].get(ij).copied().unwrap_or(0.0).max(0.0) * het_mult[e]
            })
            .collect();
        let floored: Vec<f64> = if comm_rate > 0.0 {
            spectrum_rates
        } else {
            union.edge_rates(1e-6)
        };
        let spectrum = union.spectrum_auto(&floored);

        Ok(NetworkPlan {
            union,
            horizon,
            initial_edge_rates,
            initial_grad_rates,
            updates,
            spectrum,
        })
    }
}

impl fmt::Display for Scenario {
    /// Render the canonical scenario string; `Scenario::parse` round-trips
    /// it exactly (f64 `Display` is shortest-round-trip in Rust).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, p) in self.phases.iter().enumerate() {
            if i > 0 {
                f.write_str(",")?;
            }
            write!(f, "{}@{}", p.topology.spec(), p.at)?;
        }
        if let Some(d) = &self.dropout {
            write!(f, ";drop={}:{}:{}:{}", d.frac, d.from, d.to, d.seed)?;
        }
        if let Some(h) = &self.het {
            write!(f, ";het={}:{}", h.sigma, h.seed)?;
        }
        if let Some(d) = &self.drift {
            write!(f, ";drift={}:{}:{}", d.amp, d.steps, d.seed)?;
        }
        for ev in &self.churn {
            match ev.kind {
                ChurnKind::Leave => write!(f, ";leave={}:{}:{}", ev.frac, ev.at, ev.seed)?,
                ChurnKind::Join => write!(f, ";join={}:{}", ev.frac, ev.at)?,
            }
        }
        if !self.adaptive {
            f.write_str(";adapt=0")?;
        }
        if let Some(a) = &self.algo {
            write!(f, ";algo={a}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_phases_and_options() {
        let s = Scenario::parse("ring@0,exponential@0.5;drop=0.2:0.25:0.75:7;het=0.5;drift=0.3:4:1")
            .unwrap();
        assert_eq!(s.phases.len(), 2);
        assert_eq!(s.phases[0], Phase { at: 0.0, topology: Topology::Ring });
        assert_eq!(s.phases[1], Phase { at: 0.5, topology: Topology::Exponential });
        assert_eq!(
            s.dropout,
            Some(Dropout { frac: 0.2, from: 0.25, to: 0.75, seed: 7 })
        );
        assert_eq!(s.het, Some(RateSpread { sigma: 0.5, seed: 0 }));
        assert_eq!(s.drift, Some(SpeedDrift { amp: 0.3, steps: 4, seed: 1 }));
        assert!(s.churn.is_empty());
        assert!(s.adaptive, "adaptive is the default");
    }

    #[test]
    fn parses_bare_single_phase() {
        let s = Scenario::parse("ring").unwrap();
        assert_eq!(s.phases, vec![Phase { at: 0.0, topology: Topology::Ring }]);
        s.validate_for(6).unwrap();
        // Topology sub-syntax passes through (torus:RxC contains ':').
        let t = Scenario::parse("torus:2x4@0").unwrap();
        assert_eq!(t.phases[0].topology, Topology::Torus { rows: 2, cols: 4 });
    }

    #[test]
    fn parses_churn_and_adapt() {
        // Events sort by time regardless of written order.
        let s = Scenario::parse("ring@0;join=0.25:0.6;leave=0.25:0.2:9;adapt=0").unwrap();
        assert_eq!(s.churn.len(), 2);
        assert_eq!(
            s.churn[0],
            ChurnEvent { kind: ChurnKind::Leave, frac: 0.25, at: 0.2, seed: 9 }
        );
        assert_eq!(
            s.churn[1],
            ChurnEvent { kind: ChurnKind::Join, frac: 0.25, at: 0.6, seed: 0 }
        );
        assert!(!s.adaptive);
        s.validate_for(8).unwrap();
    }

    #[test]
    fn parse_error_paths() {
        for bad in [
            "",
            "nope@0",
            "ring@0.5",              // first phase must start at 0
            "ring@0,exp",            // later phase without @time
            "ring@0,exp@0.5,complete@0.5", // non-increasing
            "ring@0;drop=1.5",       // frac out of range
            "ring@0;drop=0.2:0.9:0.1", // inverted window
            "ring@0;drift=2.0",      // amp out of range
            "ring@0;drift=0.3:0",    // zero steps
            "ring@0;het=-1",         // negative sigma
            "ring@0;wat=1",          // unknown option
            "ring@0;drop",           // not key=value
            "ring@1.2",              // time out of range
            "ring@0;algo=nope",      // unknown algorithm
            "ring@0;algo=localsgd",  // localsgd without pacing
            "ring@0;algo=localsgd:0", // zero pacing
        ] {
            assert!(Scenario::parse(bad).is_err(), "should reject '{bad}'");
        }
    }

    #[test]
    fn parses_algo_option() {
        let s = Scenario::parse("ring@0;algo=adpsgd").unwrap();
        assert_eq!(s.algo, Some(Algorithm::AdPsgd));
        // The ':' inside localsgd:H is part of the value, not a field split.
        let s = Scenario::parse("ring@0;algo=localsgd:4").unwrap();
        assert_eq!(s.algo, Some(Algorithm::LocalSgd { h: 4 }));
        // Unset stays None (the config/CLI decides).
        assert_eq!(Scenario::parse("ring@0").unwrap().algo, None);
    }

    #[test]
    fn churn_parse_error_paths() {
        for bad in [
            "ring@0;leave=0.25",            // missing time
            "ring@0;leave=x:0.5",           // malformed fraction
            "ring@0;leave=0.25:y",          // malformed time
            "ring@0;leave=0.25:0.5:z",      // malformed seed
            "ring@0;leave=0:0.5",           // zero fraction
            "ring@0;leave=1.0:0.5",         // would empty the graph outright
            "ring@0;leave=-0.2:0.5",        // negative fraction
            "ring@0;leave=0.25:0",          // time at 0
            "ring@0;leave=0.25:1.0",        // time at 1
            "ring@0;leave=0.25:1.5",        // time out of range
            "ring@0;join=0.25:0.5",         // join before any leave
            "ring@0;join=1.5:0.5",          // join fraction out of range
            "ring@0;leave=0.25:0.2;join=0.25:0.5:3", // join takes no seed
            "ring@0;leave=0.25:0.5:3:17",   // leave: trailing junk field
            "ring@0;leave=0.6:0.2;leave=0.6:0.4", // cumulative leave empties the graph
            "ring@0;leave=0.25:0.5;join=0.25:0.5", // duplicate churn time
            "ring@0;adapt=2",               // adapt must be 0|1
            "ring@0;adapt=x",               // malformed adapt
        ] {
            assert!(Scenario::parse(bad).is_err(), "should reject '{bad}'");
        }
        // leave then full re-join then leave again is a valid cycle.
        Scenario::parse("ring@0;leave=0.4:0.2;join=1.0:0.4;leave=0.4:0.6").unwrap();
    }

    #[test]
    fn display_round_trips() {
        for s in [
            "ring",
            "ring@0,exponential@0.5",
            "torus:2x4@0,erdos:0.4:3@0.25",
            "ring@0,exponential@0.5;drop=0.2:0.25:0.75:7;het=0.5;drift=0.3:4:1",
            "ring@0;leave=0.25:0.2:9;join=0.25:0.6",
            "ring@0;leave=0.25:0.2;adapt=0",
            "ring@0;algo=adpsgd",
            "ring@0;leave=0.25:0.2;adapt=0;algo=localsgd:4",
            "ring@0,exponential@0.5;drop=0.2:0.25:0.75:7;algo=a2cid2",
        ] {
            let parsed = Scenario::parse(s).unwrap();
            let rendered = parsed.to_string();
            let reparsed = Scenario::parse(&rendered)
                .unwrap_or_else(|e| panic!("'{rendered}' should re-parse: {e}"));
            assert_eq!(parsed, reparsed, "round-trip of '{s}' via '{rendered}'");
        }
    }

    #[test]
    fn validate_for_catches_empty_fleet_at_n() {
        // 25% of 4 workers is 1; three leaves take the fleet to 1 < 2.
        let s = Scenario::parse(
            "ring@0;leave=0.25:0.2;leave=0.25:0.4;leave=0.25:0.6",
        )
        .unwrap();
        s.validate_for(8).unwrap();
        assert!(s.validate_for(4).is_err());
        assert!(s.compile(4, 1.0, 10.0, &[1.0; 4]).is_err());
    }

    #[test]
    fn compile_is_deterministic() {
        let sc = Scenario::parse(
            "ring@0,exponential@0.5;drop=0.2:0.25:0.75:3;het=0.4:5;drift=0.3:4:2;leave=0.25:0.3:1;join=0.25:0.7",
        )
        .unwrap();
        let base = vec![1.0; 8];
        let a = sc.compile(8, 1.0, 100.0, &base).unwrap();
        let b = sc.compile(8, 1.0, 100.0, &base).unwrap();
        assert_eq!(a.initial_edge_rates, b.initial_edge_rates);
        assert_eq!(a.initial_grad_rates, b.initial_grad_rates);
        assert_eq!(a.updates, b.updates);
        assert!(!a.updates.is_empty());
    }

    #[test]
    fn union_covers_both_phases_and_switch_moves_rates() {
        let sc = Scenario::parse("ring@0,complete@0.5").unwrap();
        let plan = sc.compile(6, 1.0, 10.0, &[1.0; 6]).unwrap();
        // Union of ring(6) and complete(6) is the complete graph.
        assert_eq!(plan.union.edges.len(), 15);
        // At t=0 only the 6 ring edges are live.
        let live0 = plan.initial_edge_rates.iter().filter(|&&r| r > 0.0).count();
        assert_eq!(live0, 6);
        // Exactly one update (the switch), at half the horizon, making
        // every union edge live.
        assert_eq!(plan.updates.len(), 1);
        assert!((plan.updates[0].t - 5.0).abs() < 1e-12);
        let after = plan.updates[0].edge_rates.as_ref().unwrap();
        assert!(after.iter().all(|&r| r > 0.0));
        assert!(plan.updates[0].grad_rates.is_none());
        // Adaptive default: the switch carries the complete graph's
        // spectrum (χ₁ = χ₂ there).
        let (c1, c2) = plan.updates[0].chis.expect("switch retunes");
        assert!((c1 - c2).abs() < 1e-6, "complete graph: chi1 == chi2");
    }

    #[test]
    fn frozen_params_suppress_chis() {
        let sc = Scenario::parse("ring@0,complete@0.5;adapt=0").unwrap();
        let plan = sc.compile(6, 1.0, 10.0, &[1.0; 6]).unwrap();
        assert_eq!(plan.updates.len(), 1);
        assert!(plan.updates[0].chis.is_none(), "adapt=0 never retunes");
    }

    #[test]
    fn dropout_window_silences_and_recovers() {
        let sc = Scenario::parse("ring@0;drop=0.5:0.25:0.75:1").unwrap();
        let plan = sc.compile(8, 1.0, 100.0, &[1.0; 8]).unwrap();
        assert_eq!(plan.updates.len(), 2, "drop + recover");
        let at_drop = plan.updates[0].edge_rates.as_ref().unwrap();
        let at_recover = plan.updates[1].edge_rates.as_ref().unwrap();
        let silenced = at_drop.iter().filter(|&&r| r == 0.0).count();
        assert_eq!(silenced, 4, "50% of 8 ring edges");
        assert_eq!(at_recover, &plan.initial_edge_rates);
        // Dropout boundaries never retune (the window may disconnect).
        assert!(plan.updates.iter().all(|u| u.chis.is_none()));
        // Spectrum ignores the dropout window (stays the clean ring).
        assert!(plan.spectrum.chi1.is_finite() && plan.spectrum.chi1 > 1.0);
    }

    #[test]
    fn drift_emits_grad_rate_ramps() {
        let sc = Scenario::parse("ring@0;drift=0.5:4:9").unwrap();
        let plan = sc.compile(4, 1.0, 40.0, &[1.0; 4]).unwrap();
        let grad_updates: Vec<&NetUpdate> =
            plan.updates.iter().filter(|u| u.grad_rates.is_some()).collect();
        assert_eq!(grad_updates.len(), 4);
        // Rates stay positive and move monotonically per worker.
        let first = grad_updates[0].grad_rates.as_ref().unwrap();
        let last = grad_updates[3].grad_rates.as_ref().unwrap();
        for w in 0..4 {
            assert!(first[w] > 0.0 && last[w] > 0.0);
            let d0 = first[w] - plan.initial_grad_rates[w];
            let d1 = last[w] - plan.initial_grad_rates[w];
            assert!(d0.abs() <= d1.abs() + 1e-12, "worker {w} drifts outward");
        }
    }

    #[test]
    fn churn_compiles_to_leave_and_join_updates() {
        let sc = Scenario::parse("ring@0;leave=0.25:0.25:3;join=0.25:0.75").unwrap();
        let plan = sc.compile(8, 1.0, 100.0, &[1.0; 8]).unwrap();
        assert_eq!(plan.updates.len(), 2);
        let (l, j) = (&plan.updates[0], &plan.updates[1]);
        assert!((l.t - 25.0).abs() < 1e-12 && (j.t - 75.0).abs() < 1e-12);
        assert_eq!(l.leave.len(), 2, "25% of 8");
        assert!(l.join.is_empty());
        assert_eq!(j.join, l.leave, "FIFO re-admission");
        // The departing workers' gradient processes are silenced exactly,
        // no floor.
        let grads = l.grad_rates.as_ref().unwrap();
        for &w in &l.leave {
            assert_eq!(grads[w], 0.0);
        }
        // Every edge incident to a departed worker goes silent.
        let edges = l.edge_rates.as_ref().unwrap();
        for (e, &(a, b)) in plan.union.edges.iter().enumerate() {
            if l.leave.contains(&a) || l.leave.contains(&b) {
                assert_eq!(edges[e], 0.0, "edge {a}-{b} must be silent");
            }
        }
        // Re-join restores the initial state.
        assert_eq!(j.edge_rates.as_ref().unwrap(), &plan.initial_edge_rates);
        assert_eq!(j.grad_rates.as_ref().unwrap(), &plan.initial_grad_rates);
    }

    #[test]
    fn churn_chis_present_only_when_subgraph_connected() {
        // Removing 2 of 8 ring workers disconnects the remainder (two
        // paths) unless the leavers happen to be adjacent. Seed 1 on the
        // ring: whatever the draw, a connected induced subgraph yields
        // chis and a disconnected one yields None — assert consistency
        // with an explicit connectivity check.
        let sc = Scenario::parse("ring@0;leave=0.25:0.5:1").unwrap();
        let plan = sc.compile(8, 1.0, 100.0, &[1.0; 8]).unwrap();
        let upd = &plan.updates[0];
        let alive: Vec<usize> = (0..8).filter(|w| !upd.leave.contains(w)).collect();
        let remap: std::collections::HashMap<usize, usize> =
            alive.iter().enumerate().map(|(new, &old)| (old, new)).collect();
        let ring = Graph::build(&Topology::Ring, 8).unwrap();
        let sub = Graph::from_edges(
            alive.len(),
            ring.edges
                .iter()
                .filter(|(a, b)| remap.contains_key(a) && remap.contains_key(b))
                .map(|(a, b)| (remap[a], remap[b])),
        );
        assert_eq!(upd.chis.is_some(), sub.is_connected());
        if let Some((c1, c2)) = upd.chis {
            assert!(c1 >= c2 && c2 > 0.0);
        }
    }

    #[test]
    fn diff_lists_mirror_dense_vectors() {
        // Every compiled update's sparse diffs, replayed onto the running
        // state, must reproduce the dense vectors exactly — and list
        // exactly the entries that changed (no padding, no omissions).
        let sc = Scenario::parse(
            "ring@0,exponential@0.5;drop=0.2:0.25:0.75:3;drift=0.3:4:2;leave=0.25:0.3:1;join=0.25:0.7",
        )
        .unwrap();
        let plan = sc.compile(8, 1.0, 100.0, &[1.0; 8]).unwrap();
        assert!(!plan.updates.is_empty());
        let mut edges = plan.initial_edge_rates.clone();
        let mut grads = plan.initial_grad_rates.clone();
        for upd in &plan.updates {
            assert_eq!(upd.edge_rates.is_some(), !upd.edge_diff.is_empty());
            assert_eq!(upd.grad_rates.is_some(), !upd.grad_diff.is_empty());
            for w in upd.edge_diff.windows(2) {
                assert!(w[0].0 < w[1].0, "edge diff sorted & deduped");
            }
            for &(e, r) in &upd.edge_diff {
                assert_ne!(edges[e], r, "diff entry must actually change the rate");
                edges[e] = r;
            }
            for &(w, r) in &upd.grad_diff {
                assert_ne!(grads[w], r);
                grads[w] = r;
            }
            if let Some(dense) = &upd.edge_rates {
                assert_eq!(&edges, dense, "diff replay == dense vector at t={}", upd.t);
            }
            if let Some(dense) = &upd.grad_rates {
                assert_eq!(&grads, dense);
            }
            // touched_workers covers every diffed endpoint + churn.
            let touched = upd.touched_workers(&plan.union.edges);
            for &(e, _) in &upd.edge_diff {
                let (i, j) = plan.union.edges[e];
                assert!(touched.binary_search(&i).is_ok());
                assert!(touched.binary_search(&j).is_ok());
            }
            for &w in upd.leave.iter().chain(&upd.join) {
                assert!(touched.binary_search(&w).is_ok());
            }
        }
    }

    #[test]
    fn static_plan_matches_graph_rates() {
        let g = Graph::build(&Topology::Ring, 6).unwrap();
        let base = vec![1.0; 6];
        let plan = NetworkPlan::static_plan(g.clone(), 2.0, &base);
        assert_eq!(plan.initial_edge_rates, g.edge_rates(2.0));
        assert!(plan.updates.is_empty());
        assert_eq!(plan.initial_grad_rates, base);
    }

    #[test]
    fn compile_rejects_bad_sizes() {
        let sc = Scenario::parse("ring").unwrap();
        assert!(sc.compile(1, 1.0, 10.0, &[1.0]).is_err());
        assert!(sc.compile(4, 1.0, 10.0, &[1.0; 3]).is_err());
        assert!(sc.compile(4, 1.0, f64::INFINITY, &[1.0; 4]).is_err());
        // Torus dims must match n at compile time.
        let t = Scenario::parse("torus:3x3@0").unwrap();
        assert!(t.compile(8, 1.0, 10.0, &[1.0; 8]).is_err());
    }
}
