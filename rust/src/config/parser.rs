//! Minimal TOML-subset parser (tables, scalars, homogeneous arrays,
//! comments). Errors carry line numbers.

use std::collections::BTreeMap;

/// A parsed TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> crate::Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => anyhow::bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_int(&self) -> crate::Result<i64> {
        match self {
            TomlValue::Int(v) => Ok(*v),
            other => anyhow::bail!("expected integer, got {other:?}"),
        }
    }

    /// Accepts both ints and floats (TOML writers often drop the `.0`).
    pub fn as_float(&self) -> crate::Result<f64> {
        match self {
            TomlValue::Float(v) => Ok(*v),
            TomlValue::Int(v) => Ok(*v as f64),
            other => anyhow::bail!("expected float, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> crate::Result<bool> {
        match self {
            TomlValue::Bool(v) => Ok(*v),
            other => anyhow::bail!("expected bool, got {other:?}"),
        }
    }
}

/// A parsed document: `tables["name"]["key"] = value`. Top-level keys live
/// in the table named `""`.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub tables: BTreeMap<String, Vec<(String, TomlValue)>>,
}

impl TomlDoc {
    /// Iterate the `(key, value)` pairs of one table (empty if missing).
    pub fn iter_table<'a>(
        &'a self,
        name: &str,
    ) -> impl Iterator<Item = &'a (String, TomlValue)> {
        self.tables.get(name).into_iter().flatten()
    }

    pub fn get(&self, table: &str, key: &str) -> Option<&TomlValue> {
        self.tables
            .get(table)?
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v)
    }
}

/// Parse a TOML-subset document.
pub fn parse_toml(text: &str) -> crate::Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut current = String::new();
    doc.tables.entry(current.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            anyhow::ensure!(
                line.ends_with(']') && line.len() > 2,
                "line {}: malformed table header '{line}'",
                lineno + 1
            );
            current = line[1..line.len() - 1].trim().to_string();
            doc.tables.entry(current.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim();
        anyhow::ensure!(!key.is_empty(), "line {}: empty key", lineno + 1);
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let table = doc.tables.get_mut(&current).unwrap();
        anyhow::ensure!(
            !table.iter().any(|(k, _)| k == key),
            "line {}: duplicate key '{key}'",
            lineno + 1
        );
        table.push((key.to_string(), value));
    }
    Ok(doc)
}

/// Strip a `#` comment, respecting string quotes.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> crate::Result<TomlValue> {
    anyhow::ensure!(!s.is_empty(), "empty value");
    if s.starts_with('"') {
        anyhow::ensure!(
            s.len() >= 2 && s.ends_with('"'),
            "unterminated string {s}"
        );
        return Ok(TomlValue::Str(s[1..s.len() - 1].to_string()));
    }
    if s.starts_with('[') {
        anyhow::ensure!(s.ends_with(']'), "unterminated array {s}");
        let inner = s[1..s.len() - 1].trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items = split_top_level(inner)?
            .into_iter()
            .map(|item| parse_value(item.trim()))
            .collect::<crate::Result<Vec<_>>>()?;
        return Ok(TomlValue::Array(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    // Int before float: "5" parses as both.
    if let Ok(v) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(v));
    }
    if let Ok(v) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    anyhow::bail!("cannot parse value '{s}'")
}

/// Split an array body on commas not nested inside strings or brackets.
fn split_top_level(s: &str) -> crate::Result<Vec<&str>> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => {
                anyhow::ensure!(depth > 0, "unbalanced brackets");
                depth -= 1;
            }
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let doc = parse_toml(
            "a = 1\nb = 2.5\nc = \"hi\"\nd = true\ne = -3\nf = 1e-4\ng = 1_000\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "a"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("", "b"), Some(&TomlValue::Float(2.5)));
        assert_eq!(doc.get("", "c"), Some(&TomlValue::Str("hi".into())));
        assert_eq!(doc.get("", "d"), Some(&TomlValue::Bool(true)));
        assert_eq!(doc.get("", "e"), Some(&TomlValue::Int(-3)));
        assert_eq!(doc.get("", "f"), Some(&TomlValue::Float(1e-4)));
        assert_eq!(doc.get("", "g"), Some(&TomlValue::Int(1000)));
    }

    #[test]
    fn parses_tables_and_comments() {
        let doc = parse_toml(
            "# header\n[one]\nx = 1 # trailing\n[two]\nx = 2\ny = \"a # not comment\"\n",
        )
        .unwrap();
        assert_eq!(doc.get("one", "x"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("two", "x"), Some(&TomlValue::Int(2)));
        assert_eq!(
            doc.get("two", "y"),
            Some(&TomlValue::Str("a # not comment".into()))
        );
    }

    #[test]
    fn parses_arrays() {
        let doc = parse_toml("a = [1, 2, 3]\nb = [\"x\", \"y\"]\nc = []\n").unwrap();
        assert_eq!(
            doc.get("", "a"),
            Some(&TomlValue::Array(vec![
                TomlValue::Int(1),
                TomlValue::Int(2),
                TomlValue::Int(3)
            ]))
        );
        match doc.get("", "b") {
            Some(TomlValue::Array(items)) => assert_eq!(items.len(), 2),
            other => panic!("{other:?}"),
        }
        assert_eq!(doc.get("", "c"), Some(&TomlValue::Array(vec![])));
    }

    #[test]
    fn error_reporting() {
        assert!(parse_toml("novalue\n").is_err());
        assert!(parse_toml("[broken\n").is_err());
        assert!(parse_toml("a = \n").is_err());
        assert!(parse_toml("a = 1\na = 2\n").is_err(), "duplicate key");
        let err = parse_toml("x = 1\ny = ???\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn value_coercions() {
        assert_eq!(TomlValue::Int(3).as_float().unwrap(), 3.0);
        assert!(TomlValue::Str("x".into()).as_int().is_err());
        assert!(TomlValue::Bool(true).as_bool().unwrap());
    }
}
