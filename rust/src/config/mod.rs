//! Configuration system: a hand-rolled TOML-subset parser plus the typed
//! experiment/runtime configs the launcher consumes.
//!
//! Supported TOML subset (all the framework needs): `[table]` headers,
//! `key = value` with string / integer / float / boolean / homogeneous
//! array values, `#` comments. No serde offline — the parser is ~150 lines
//! and fully tested.

pub mod env;
mod parser;
pub mod scenario;

pub use parser::{parse_toml, TomlValue};
pub use scenario::{NetUpdate, NetworkPlan, Scenario};

use crate::data::Sharding;
use crate::graph::Topology;

/// Which dynamic to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Synchronous All-Reduce SGD (the paper's centralized baseline).
    AllReduce,
    /// Asynchronous pairwise gossip, η = 0 (≈ AD-PSGD).
    AsyncBaseline,
    /// Asynchronous gossip + continuous momentum (the paper's method).
    Acid,
}

impl Method {
    pub fn parse(s: &str) -> crate::Result<Method> {
        Ok(match s {
            "allreduce" | "ar" | "ar-sgd" => Method::AllReduce,
            "baseline" | "async" | "async-baseline" | "adpsgd" => Method::AsyncBaseline,
            "acid" | "a2cid2" => Method::Acid,
            other => anyhow::bail!("unknown method '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::AllReduce => "ar-sgd",
            Method::AsyncBaseline => "async-baseline",
            Method::Acid => "a2cid2",
        }
    }
}

/// Which per-event update rule the engines run — the algorithm-zoo axis.
///
/// [`Method`] predates this enum and survives as the coarse dispatch the
/// older configs/CLI use; `Algorithm` is the full zoo: it adds
/// [`Algorithm::LocalSgd`] (H local gradient steps between pairings, à la
/// locally-asynchronous local-SGD) which no `Method` can express. Every
/// `Algorithm` still maps back onto a `Method` ([`Algorithm::method`]) so
/// the simulator/runtime plumbing that branches on `Method` keeps
/// working; the per-event behavior difference lives in
/// [`crate::engine::DynamicsCore`]'s `UpdateRule`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Asynchronous gossip + continuous momentum (the paper's Eq. 4).
    A2cid2,
    /// Plain asynchronous pairwise averaging, no momentum (AD-PSGD).
    AdPsgd,
    /// Pairwise averaging gated on `h` local gradient steps since the
    /// worker's last applied communication (locally-async local-SGD).
    LocalSgd { h: u64 },
    /// Synchronous All-Reduce SGD (the centralized baseline).
    AllReduce,
}

impl Algorithm {
    /// Parse `a2cid2 | adpsgd | localsgd:H | allreduce` (plus the same
    /// aliases [`Method::parse`] accepts for the overlapping variants).
    pub fn parse(s: &str) -> crate::Result<Algorithm> {
        if let Some(h) = s.strip_prefix("localsgd:") {
            let h: u64 = h
                .parse()
                .map_err(|_| anyhow::anyhow!("localsgd:H needs an integer H, got '{s}'"))?;
            anyhow::ensure!(h >= 1, "localsgd:H needs H >= 1 (H = 1 is adpsgd-paced)");
            return Ok(Algorithm::LocalSgd { h });
        }
        Ok(match s {
            "a2cid2" | "acid" => Algorithm::A2cid2,
            "adpsgd" | "baseline" | "async-baseline" => Algorithm::AdPsgd,
            "allreduce" | "ar" | "ar-sgd" => Algorithm::AllReduce,
            "localsgd" => anyhow::bail!("localsgd needs a pacing: 'localsgd:H' with H >= 1"),
            other => anyhow::bail!(
                "unknown algorithm '{other}' (expected a2cid2|adpsgd|localsgd:H|allreduce)"
            ),
        })
    }

    /// The algorithm a legacy [`Method`] means (the back-compat default).
    pub fn from_method(m: Method) -> Algorithm {
        match m {
            Method::AllReduce => Algorithm::AllReduce,
            Method::AsyncBaseline => Algorithm::AdPsgd,
            Method::Acid => Algorithm::A2cid2,
        }
    }

    /// The coarse [`Method`] this algorithm runs under (which engine
    /// branch/parameter family applies). LocalSgd is an η = 0 gossip
    /// dynamic with a gated pairing, so it rides the async-baseline
    /// plumbing.
    pub fn method(&self) -> Method {
        match self {
            Algorithm::A2cid2 => Method::Acid,
            Algorithm::AdPsgd | Algorithm::LocalSgd { .. } => Method::AsyncBaseline,
            Algorithm::AllReduce => Method::AllReduce,
        }
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Algorithm::A2cid2 => write!(f, "a2cid2"),
            Algorithm::AdPsgd => write!(f, "adpsgd"),
            Algorithm::LocalSgd { h } => write!(f, "localsgd:{h}"),
            Algorithm::AllReduce => write!(f, "allreduce"),
        }
    }
}

/// Which synthetic task to train.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Gaussian mixture, 10 classes ("CIFAR-like").
    CifarLike,
    /// Gaussian mixture, 100 classes ("ImageNet-like").
    ImagenetLike,
    /// Strongly-convex linear regression.
    Quadratic,
}

impl Task {
    pub fn parse(s: &str) -> crate::Result<Task> {
        Ok(match s {
            "cifar" | "cifar-like" | "gm10" => Task::CifarLike,
            "imagenet" | "imagenet-like" | "gm100" => Task::ImagenetLike,
            "quadratic" | "convex" => Task::Quadratic,
            other => anyhow::bail!("unknown task '{other}'"),
        })
    }
}

/// Full experiment configuration (simulator or real-thread runtime).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub n_workers: usize,
    pub topology: Topology,
    pub method: Method,
    pub task: Task,
    /// Expected p2p averagings per gradient step per worker (the paper's
    /// "#com/#grad" knob).
    pub comm_rate: f64,
    pub batch_size: usize,
    pub base_lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    /// Total *local* gradient steps per worker (the paper fixes total
    /// samples, so per-worker steps shrink as n grows).
    pub steps_per_worker: u64,
    pub sharding: Sharding,
    pub dataset_size: usize,
    pub seed: u64,
    /// Compute-time jitter: each gradient duration is
    /// `max(0, N(1, jitter))` time units (stragglers).
    pub compute_jitter: f64,
    /// Optional time-varying network scenario (phased topology switches,
    /// link dropout, heterogeneous rates, speed drift, worker churn,
    /// per-phase adaptive (η, α̃)). When set it supersedes `topology`;
    /// see [`Scenario`] for the string syntax.
    pub scenario: Option<Scenario>,
    /// Explicit update rule (TOML `algorithm = "…"`, CLI `--algo`).
    /// `None` derives from `method`, so every pre-zoo config is
    /// unchanged; see [`ExperimentConfig::algo`] for the precedence.
    pub algorithm: Option<Algorithm>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            n_workers: 8,
            topology: Topology::Ring,
            method: Method::Acid,
            task: Task::CifarLike,
            comm_rate: 1.0,
            batch_size: 16,
            base_lr: 0.05,
            momentum: 0.9,
            weight_decay: 5e-4,
            steps_per_worker: 500,
            sharding: Sharding::FullShuffled,
            dataset_size: 4096,
            seed: 0,
            compute_jitter: 0.1,
            scenario: None,
            algorithm: None,
        }
    }
}

impl ExperimentConfig {
    /// The effective update rule: the scenario's `algo=` key wins, then
    /// the config's `algorithm`, then the legacy `method` mapping.
    pub fn algo(&self) -> Algorithm {
        self.scenario
            .as_ref()
            .and_then(|s| s.algo)
            .or(self.algorithm)
            .unwrap_or(Algorithm::from_method(self.method))
    }

    /// Validate invariants; returns self for chaining.
    pub fn validate(mut self) -> crate::Result<Self> {
        anyhow::ensure!(self.n_workers >= 2, "need >= 2 workers");
        anyhow::ensure!(self.comm_rate >= 0.0, "negative comm rate");
        anyhow::ensure!(self.batch_size >= 1, "batch size must be >= 1");
        anyhow::ensure!(self.base_lr > 0.0, "lr must be positive");
        anyhow::ensure!((0.0..1.0).contains(&self.momentum), "momentum in [0,1)");
        anyhow::ensure!(self.steps_per_worker >= 1, "need >= 1 step");
        anyhow::ensure!(self.dataset_size >= self.batch_size, "dataset < batch");
        anyhow::ensure!(self.compute_jitter >= 0.0, "negative jitter");
        if let (Some(a), Some(sa)) = (self.algorithm, self.scenario.as_ref().and_then(|s| s.algo))
        {
            anyhow::ensure!(
                a == sa,
                "algorithm '{a}' conflicts with the scenario's 'algo={sa}'"
            );
        }
        let algo = self.algo();
        if let Some(sc) = &self.scenario {
            // A scenario only shapes the gossip network; the synchronous
            // All-Reduce baseline would silently ignore it — reject
            // rather than hand back numbers the scenario never touched.
            anyhow::ensure!(
                algo != Algorithm::AllReduce,
                "scenario requires an asynchronous algorithm; allreduce ignores the gossip network"
            );
            // Surface bad phase/worker-count combinations (e.g. torus
            // dims) at config time; the engines compile the full plan
            // (incl. the spectrum eigensolve) once, at run start.
            sc.validate_for(self.n_workers)?;
        }
        // Canonicalize: `method` always mirrors the effective algorithm,
        // so the engines' coarse `Method` branches (parameter family,
        // allreduce dispatch) cannot disagree with the update rule. A
        // no-op for every pre-zoo config (`algo()` derives from `method`
        // when nothing is set).
        self.method = algo.method();
        Ok(self)
    }

    /// Load from a TOML file; unknown keys are an error (catch typos).
    pub fn from_toml(text: &str) -> crate::Result<Self> {
        let doc = parse_toml(text)?;
        let mut cfg = ExperimentConfig::default();
        for (key, value) in doc.iter_table("experiment") {
            match key.as_str() {
                "n_workers" => cfg.n_workers = value.as_int()? as usize,
                "topology" => cfg.topology = Topology::parse(value.as_str()?)?,
                "method" => cfg.method = Method::parse(value.as_str()?)?,
                "task" => cfg.task = Task::parse(value.as_str()?)?,
                "comm_rate" => cfg.comm_rate = value.as_float()?,
                "batch_size" => cfg.batch_size = value.as_int()? as usize,
                "base_lr" => cfg.base_lr = value.as_float()?,
                "momentum" => cfg.momentum = value.as_float()?,
                "weight_decay" => cfg.weight_decay = value.as_float()?,
                "steps_per_worker" => cfg.steps_per_worker = value.as_int()? as u64,
                "dataset_size" => cfg.dataset_size = value.as_int()? as usize,
                "seed" => cfg.seed = value.as_int()? as u64,
                "compute_jitter" => cfg.compute_jitter = value.as_float()?,
                "scenario" => cfg.scenario = Some(Scenario::parse(value.as_str()?)?),
                "algorithm" => cfg.algorithm = Some(Algorithm::parse(value.as_str()?)?),
                "sharding" => {
                    cfg.sharding = match value.as_str()? {
                        "full" | "full-shuffled" => Sharding::FullShuffled,
                        "iid" => Sharding::Iid,
                        s if s.starts_with("dirichlet:") => Sharding::Dirichlet {
                            alpha: s["dirichlet:".len()..].parse()?,
                        },
                        other => anyhow::bail!("unknown sharding '{other}'"),
                    }
                }
                other => anyhow::bail!("unknown key 'experiment.{other}'"),
            }
        }
        cfg.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_full_config() {
        let text = r#"
# an experiment
[experiment]
n_workers = 16
topology = "ring"
method = "a2cid2"
task = "cifar-like"
comm_rate = 2.0
batch_size = 32
base_lr = 0.1
steps_per_worker = 100
sharding = "dirichlet:0.5"
seed = 7
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.n_workers, 16);
        assert_eq!(cfg.topology, Topology::Ring);
        assert_eq!(cfg.method, Method::Acid);
        assert_eq!(cfg.comm_rate, 2.0);
        assert_eq!(cfg.sharding, Sharding::Dirichlet { alpha: 0.5 });
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn rejects_unknown_key() {
        let text = "[experiment]\nn_wrokers = 4\n";
        assert!(ExperimentConfig::from_toml(text).is_err());
    }

    #[test]
    fn rejects_invalid_values() {
        assert!(ExperimentConfig::from_toml("[experiment]\nn_workers = 1\n").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nbase_lr = 0.0\n").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nmomentum = 1.5\n").is_err());
    }

    #[test]
    fn parse_scenario_key() {
        let text = "[experiment]\nscenario = \"ring@0,exponential@0.5;drop=0.2\"\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        let sc = cfg.scenario.unwrap();
        assert_eq!(sc.phases.len(), 2);
        assert!(sc.dropout.is_some());
        // Bad scenario strings are config errors.
        assert!(ExperimentConfig::from_toml("[experiment]\nscenario = \"wat@0\"\n").is_err());
        // Valid string but incompatible with n (torus dims) fails validate.
        let bad = "[experiment]\nn_workers = 8\nscenario = \"torus:3x3@0\"\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        // AllReduce would silently ignore the scenario — rejected.
        let ar = "[experiment]\nmethod = \"allreduce\"\nscenario = \"ring@0,exp@0.5\"\n";
        assert!(ExperimentConfig::from_toml(ar).is_err());
    }

    #[test]
    fn parse_churn_scenario_key() {
        let text = "[experiment]\nn_workers = 8\n\
                    scenario = \"ring@0;leave=0.25:0.2:3;join=0.25:0.6;adapt=0\"\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        let sc = cfg.scenario.unwrap();
        assert_eq!(sc.churn.len(), 2);
        assert!(!sc.adaptive);
        // Churn that would empty the fleet fails at config time for this
        // n (3 × 25% of 4 workers leaves one), never at run time.
        let bad = "[experiment]\nn_workers = 4\n\
                   scenario = \"ring@0;leave=0.25:0.2;leave=0.25:0.4;leave=0.25:0.6\"\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        // Malformed churn options are config errors too.
        let malformed = "[experiment]\nscenario = \"ring@0;leave=0.25\"\n";
        assert!(ExperimentConfig::from_toml(malformed).is_err());
    }

    #[test]
    fn method_task_parse() {
        assert_eq!(Method::parse("ar").unwrap(), Method::AllReduce);
        assert_eq!(Method::parse("adpsgd").unwrap(), Method::AsyncBaseline);
        assert_eq!(Method::parse("a2cid2").unwrap(), Method::Acid);
        assert!(Method::parse("sync").is_err());
        assert_eq!(Task::parse("gm100").unwrap(), Task::ImagenetLike);
    }

    #[test]
    fn algorithm_parse_display_round_trip() {
        for (s, a) in [
            ("a2cid2", Algorithm::A2cid2),
            ("adpsgd", Algorithm::AdPsgd),
            ("localsgd:4", Algorithm::LocalSgd { h: 4 }),
            ("allreduce", Algorithm::AllReduce),
        ] {
            assert_eq!(Algorithm::parse(s).unwrap(), a);
            assert_eq!(a.to_string(), s, "Display round-trips the canonical spelling");
        }
        // Method aliases resolve too.
        assert_eq!(Algorithm::parse("acid").unwrap(), Algorithm::A2cid2);
        assert_eq!(Algorithm::parse("baseline").unwrap(), Algorithm::AdPsgd);
        assert_eq!(Algorithm::parse("ar").unwrap(), Algorithm::AllReduce);
        // Errors: unknown, unpaced localsgd, zero pacing, junk pacing.
        assert!(Algorithm::parse("nope").is_err());
        assert!(Algorithm::parse("localsgd").is_err());
        assert!(Algorithm::parse("localsgd:0").is_err());
        assert!(Algorithm::parse("localsgd:x").is_err());
    }

    #[test]
    fn algorithm_method_round_trip() {
        for m in [Method::AllReduce, Method::AsyncBaseline, Method::Acid] {
            assert_eq!(Algorithm::from_method(m).method(), m);
        }
        // LocalSgd rides the async-baseline plumbing.
        assert_eq!(Algorithm::LocalSgd { h: 3 }.method(), Method::AsyncBaseline);
    }

    #[test]
    fn algorithm_key_canonicalizes_method() {
        // `algorithm` wins over a conflicting legacy `method`, and
        // validate re-derives `method` so engine dispatch agrees.
        let text = "[experiment]\nmethod = \"a2cid2\"\nalgorithm = \"adpsgd\"\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.algo(), Algorithm::AdPsgd);
        assert_eq!(cfg.method, Method::AsyncBaseline);
        // Defaulting: no `algorithm` key derives from `method` (a2cid2).
        let cfg = ExperimentConfig::from_toml("[experiment]\n").unwrap();
        assert_eq!(cfg.algo(), Algorithm::A2cid2);
        assert!(cfg.algorithm.is_none());
        // localsgd pacing survives the TOML round trip.
        let text = "[experiment]\nalgorithm = \"localsgd:8\"\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.algo(), Algorithm::LocalSgd { h: 8 });
        assert_eq!(cfg.method, Method::AsyncBaseline);
        // Bad algorithm strings are config errors.
        assert!(ExperimentConfig::from_toml("[experiment]\nalgorithm = \"wat\"\n").is_err());
    }

    #[test]
    fn scenario_algo_precedence_and_conflicts() {
        // The scenario's algo= key is the effective rule.
        let text = "[experiment]\nscenario = \"ring@0;algo=adpsgd\"\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.algo(), Algorithm::AdPsgd);
        assert_eq!(cfg.method, Method::AsyncBaseline);
        // Agreeing config + scenario keys are fine…
        let ok = "[experiment]\nalgorithm = \"adpsgd\"\nscenario = \"ring@0;algo=adpsgd\"\n";
        assert!(ExperimentConfig::from_toml(ok).is_ok());
        // …conflicting ones are rejected rather than silently resolved.
        let bad = "[experiment]\nalgorithm = \"a2cid2\"\nscenario = \"ring@0;algo=adpsgd\"\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        // allreduce via the algorithm axis + scenario: same rejection as
        // the legacy method path.
        let ar = "[experiment]\nalgorithm = \"allreduce\"\nscenario = \"ring@0,exp@0.5\"\n";
        assert!(ExperimentConfig::from_toml(ar).is_err());
    }
}
