//! Configuration system: a hand-rolled TOML-subset parser plus the typed
//! experiment/runtime configs the launcher consumes.
//!
//! Supported TOML subset (all the framework needs): `[table]` headers,
//! `key = value` with string / integer / float / boolean / homogeneous
//! array values, `#` comments. No serde offline — the parser is ~150 lines
//! and fully tested.

mod parser;
pub mod scenario;

pub use parser::{parse_toml, TomlValue};
pub use scenario::{NetUpdate, NetworkPlan, Scenario};

use crate::data::Sharding;
use crate::graph::Topology;

/// Which dynamic to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Synchronous All-Reduce SGD (the paper's centralized baseline).
    AllReduce,
    /// Asynchronous pairwise gossip, η = 0 (≈ AD-PSGD).
    AsyncBaseline,
    /// Asynchronous gossip + continuous momentum (the paper's method).
    Acid,
}

impl Method {
    pub fn parse(s: &str) -> crate::Result<Method> {
        Ok(match s {
            "allreduce" | "ar" | "ar-sgd" => Method::AllReduce,
            "baseline" | "async" | "async-baseline" | "adpsgd" => Method::AsyncBaseline,
            "acid" | "a2cid2" => Method::Acid,
            other => anyhow::bail!("unknown method '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::AllReduce => "ar-sgd",
            Method::AsyncBaseline => "async-baseline",
            Method::Acid => "a2cid2",
        }
    }
}

/// Which synthetic task to train.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Gaussian mixture, 10 classes ("CIFAR-like").
    CifarLike,
    /// Gaussian mixture, 100 classes ("ImageNet-like").
    ImagenetLike,
    /// Strongly-convex linear regression.
    Quadratic,
}

impl Task {
    pub fn parse(s: &str) -> crate::Result<Task> {
        Ok(match s {
            "cifar" | "cifar-like" | "gm10" => Task::CifarLike,
            "imagenet" | "imagenet-like" | "gm100" => Task::ImagenetLike,
            "quadratic" | "convex" => Task::Quadratic,
            other => anyhow::bail!("unknown task '{other}'"),
        })
    }
}

/// Full experiment configuration (simulator or real-thread runtime).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub n_workers: usize,
    pub topology: Topology,
    pub method: Method,
    pub task: Task,
    /// Expected p2p averagings per gradient step per worker (the paper's
    /// "#com/#grad" knob).
    pub comm_rate: f64,
    pub batch_size: usize,
    pub base_lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    /// Total *local* gradient steps per worker (the paper fixes total
    /// samples, so per-worker steps shrink as n grows).
    pub steps_per_worker: u64,
    pub sharding: Sharding,
    pub dataset_size: usize,
    pub seed: u64,
    /// Compute-time jitter: each gradient duration is
    /// `max(0, N(1, jitter))` time units (stragglers).
    pub compute_jitter: f64,
    /// Optional time-varying network scenario (phased topology switches,
    /// link dropout, heterogeneous rates, speed drift, worker churn,
    /// per-phase adaptive (η, α̃)). When set it supersedes `topology`;
    /// see [`Scenario`] for the string syntax.
    pub scenario: Option<Scenario>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            n_workers: 8,
            topology: Topology::Ring,
            method: Method::Acid,
            task: Task::CifarLike,
            comm_rate: 1.0,
            batch_size: 16,
            base_lr: 0.05,
            momentum: 0.9,
            weight_decay: 5e-4,
            steps_per_worker: 500,
            sharding: Sharding::FullShuffled,
            dataset_size: 4096,
            seed: 0,
            compute_jitter: 0.1,
            scenario: None,
        }
    }
}

impl ExperimentConfig {
    /// Validate invariants; returns self for chaining.
    pub fn validate(self) -> crate::Result<Self> {
        anyhow::ensure!(self.n_workers >= 2, "need >= 2 workers");
        anyhow::ensure!(self.comm_rate >= 0.0, "negative comm rate");
        anyhow::ensure!(self.batch_size >= 1, "batch size must be >= 1");
        anyhow::ensure!(self.base_lr > 0.0, "lr must be positive");
        anyhow::ensure!((0.0..1.0).contains(&self.momentum), "momentum in [0,1)");
        anyhow::ensure!(self.steps_per_worker >= 1, "need >= 1 step");
        anyhow::ensure!(self.dataset_size >= self.batch_size, "dataset < batch");
        anyhow::ensure!(self.compute_jitter >= 0.0, "negative jitter");
        if let Some(sc) = &self.scenario {
            // A scenario only shapes the gossip network; the synchronous
            // All-Reduce baseline would silently ignore it — reject
            // rather than hand back numbers the scenario never touched.
            anyhow::ensure!(
                self.method != Method::AllReduce,
                "scenario requires an asynchronous method; allreduce ignores the gossip network"
            );
            // Surface bad phase/worker-count combinations (e.g. torus
            // dims) at config time; the engines compile the full plan
            // (incl. the spectrum eigensolve) once, at run start.
            sc.validate_for(self.n_workers)?;
        }
        Ok(self)
    }

    /// Load from a TOML file; unknown keys are an error (catch typos).
    pub fn from_toml(text: &str) -> crate::Result<Self> {
        let doc = parse_toml(text)?;
        let mut cfg = ExperimentConfig::default();
        for (key, value) in doc.iter_table("experiment") {
            match key.as_str() {
                "n_workers" => cfg.n_workers = value.as_int()? as usize,
                "topology" => cfg.topology = Topology::parse(value.as_str()?)?,
                "method" => cfg.method = Method::parse(value.as_str()?)?,
                "task" => cfg.task = Task::parse(value.as_str()?)?,
                "comm_rate" => cfg.comm_rate = value.as_float()?,
                "batch_size" => cfg.batch_size = value.as_int()? as usize,
                "base_lr" => cfg.base_lr = value.as_float()?,
                "momentum" => cfg.momentum = value.as_float()?,
                "weight_decay" => cfg.weight_decay = value.as_float()?,
                "steps_per_worker" => cfg.steps_per_worker = value.as_int()? as u64,
                "dataset_size" => cfg.dataset_size = value.as_int()? as usize,
                "seed" => cfg.seed = value.as_int()? as u64,
                "compute_jitter" => cfg.compute_jitter = value.as_float()?,
                "scenario" => cfg.scenario = Some(Scenario::parse(value.as_str()?)?),
                "sharding" => {
                    cfg.sharding = match value.as_str()? {
                        "full" | "full-shuffled" => Sharding::FullShuffled,
                        "iid" => Sharding::Iid,
                        s if s.starts_with("dirichlet:") => Sharding::Dirichlet {
                            alpha: s["dirichlet:".len()..].parse()?,
                        },
                        other => anyhow::bail!("unknown sharding '{other}'"),
                    }
                }
                other => anyhow::bail!("unknown key 'experiment.{other}'"),
            }
        }
        cfg.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_full_config() {
        let text = r#"
# an experiment
[experiment]
n_workers = 16
topology = "ring"
method = "a2cid2"
task = "cifar-like"
comm_rate = 2.0
batch_size = 32
base_lr = 0.1
steps_per_worker = 100
sharding = "dirichlet:0.5"
seed = 7
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.n_workers, 16);
        assert_eq!(cfg.topology, Topology::Ring);
        assert_eq!(cfg.method, Method::Acid);
        assert_eq!(cfg.comm_rate, 2.0);
        assert_eq!(cfg.sharding, Sharding::Dirichlet { alpha: 0.5 });
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn rejects_unknown_key() {
        let text = "[experiment]\nn_wrokers = 4\n";
        assert!(ExperimentConfig::from_toml(text).is_err());
    }

    #[test]
    fn rejects_invalid_values() {
        assert!(ExperimentConfig::from_toml("[experiment]\nn_workers = 1\n").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nbase_lr = 0.0\n").is_err());
        assert!(ExperimentConfig::from_toml("[experiment]\nmomentum = 1.5\n").is_err());
    }

    #[test]
    fn parse_scenario_key() {
        let text = "[experiment]\nscenario = \"ring@0,exponential@0.5;drop=0.2\"\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        let sc = cfg.scenario.unwrap();
        assert_eq!(sc.phases.len(), 2);
        assert!(sc.dropout.is_some());
        // Bad scenario strings are config errors.
        assert!(ExperimentConfig::from_toml("[experiment]\nscenario = \"wat@0\"\n").is_err());
        // Valid string but incompatible with n (torus dims) fails validate.
        let bad = "[experiment]\nn_workers = 8\nscenario = \"torus:3x3@0\"\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        // AllReduce would silently ignore the scenario — rejected.
        let ar = "[experiment]\nmethod = \"allreduce\"\nscenario = \"ring@0,exp@0.5\"\n";
        assert!(ExperimentConfig::from_toml(ar).is_err());
    }

    #[test]
    fn parse_churn_scenario_key() {
        let text = "[experiment]\nn_workers = 8\n\
                    scenario = \"ring@0;leave=0.25:0.2:3;join=0.25:0.6;adapt=0\"\n";
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        let sc = cfg.scenario.unwrap();
        assert_eq!(sc.churn.len(), 2);
        assert!(!sc.adaptive);
        // Churn that would empty the fleet fails at config time for this
        // n (3 × 25% of 4 workers leaves one), never at run time.
        let bad = "[experiment]\nn_workers = 4\n\
                   scenario = \"ring@0;leave=0.25:0.2;leave=0.25:0.4;leave=0.25:0.6\"\n";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        // Malformed churn options are config errors too.
        let malformed = "[experiment]\nscenario = \"ring@0;leave=0.25\"\n";
        assert!(ExperimentConfig::from_toml(malformed).is_err());
    }

    #[test]
    fn method_task_parse() {
        assert_eq!(Method::parse("ar").unwrap(), Method::AllReduce);
        assert_eq!(Method::parse("adpsgd").unwrap(), Method::AsyncBaseline);
        assert_eq!(Method::parse("a2cid2").unwrap(), Method::Acid);
        assert!(Method::parse("sync").is_err());
        assert_eq!(Task::parse("gm100").unwrap(), Task::ImagenetLike);
    }
}
