//! The process-wide `A2CID2_*` environment knobs, read ONCE.
//!
//! Every out-of-band switch the crate honors lives here, with a
//! single-read-per-process contract: the first call to [`knobs`] reads
//! the environment into a [`OnceLock`] and later mutations of the
//! process environment are invisible. That is deliberate — the knobs
//! configure process-wide singletons (the kernel backend, the chunk
//! pool, the bench scale) that must not change identity mid-run, and a
//! single documented read site keeps "which env vars does this binary
//! care about?" answerable by one module.
//!
//! | variable | effect |
//! |---|---|
//! | `A2CID2_ARTIFACTS` | artifact directory override (`BENCH_*.json`, HLO manifests) |
//! | `A2CID2_BENCH_FULL` | `1` = paper-sized experiment grids (`Scale::Full`) |
//! | `A2CID2_BENCH_SMOKE` | `1` = keep the perf bench to its smoke subset |
//! | `A2CID2_BLESS` | `1` = rewrite golden files with the observed values |
//! | `A2CID2_KERNEL_BACKEND` | `auto`\|`scalar`\|`simd`\|`avx2`\|`neon`\|`avx512` kernel dispatch |
//! | `A2CID2_MUX_THREADS` | total lanes of the multiplexed engine's private tick pool; falls back to `A2CID2_POOL_THREADS` |
//! | `A2CID2_NUMA` | `0`\|`1`\|`auto`: owner-lane first-touch placement of large `AlignedVec` buffers |
//! | `A2CID2_PIN` | `0`\|`1`\|`auto`: pin pool lanes / runtime worker threads to cores |
//! | `A2CID2_POOL_THREADS` | total pool lanes (`1` = fully serial); sizes the kernel chunk pool AND the experiment grid runner |
//!
//! `A2CID2_POOL_THREADS` historically sized BOTH the global kernel pool
//! and the `MultiplexEngine`'s private tick pool; `A2CID2_MUX_THREADS`
//! splits the latter out (e.g. a wide kernel pool with a narrow tick
//! pool on a shared host). Unset, it inherits `A2CID2_POOL_THREADS`, so
//! existing determinism matrices keep their meaning.
//!
//! Tests that must observe a knob's default should `remove_var` BEFORE
//! the first [`knobs`] call in the process (the cached read makes later
//! removals no-ops, which is exactly the contract).

use std::sync::OnceLock;

/// Every `A2CID2_*` variable the crate reads, sorted. The exhaustiveness
/// test below pins this list against [`Knobs`]' fields; grep for these
/// names to find the (single) consumer of each.
pub const VARS: [&str; 9] = [
    "A2CID2_ARTIFACTS",
    "A2CID2_BENCH_FULL",
    "A2CID2_BENCH_SMOKE",
    "A2CID2_BLESS",
    "A2CID2_KERNEL_BACKEND",
    "A2CID2_MUX_THREADS",
    "A2CID2_NUMA",
    "A2CID2_PIN",
    "A2CID2_POOL_THREADS",
];

/// The parsed knob values (one field per entry of [`VARS`]).
#[derive(Clone, Debug, Default)]
pub struct Knobs {
    /// `A2CID2_ARTIFACTS`: artifact directory override.
    pub artifacts_dir: Option<String>,
    /// `A2CID2_BENCH_FULL=1`: run the paper-sized grids.
    pub bench_full: bool,
    /// `A2CID2_BENCH_SMOKE=1`: keep the perf bench to its smoke subset.
    pub bench_smoke: bool,
    /// `A2CID2_BLESS=1`: rewrite golden entries with observed values.
    pub bless: bool,
    /// `A2CID2_KERNEL_BACKEND`: raw backend choice (validation happens at
    /// the dispatch site, which knows the accepted names).
    pub kernel_backend: Option<String>,
    /// `A2CID2_MUX_THREADS`: total multiplexed-engine tick-pool lanes;
    /// `>= 1` or ignored; falls back to [`pool_threads`](Self::pool_threads).
    pub mux_threads: Option<usize>,
    /// `A2CID2_NUMA`: raw first-touch policy (`0|1|auto`, validated in
    /// [`crate::locality`], which owns the topology it depends on).
    pub numa: Option<String>,
    /// `A2CID2_PIN`: raw affinity policy (`0|1|auto`, validated in
    /// [`crate::locality`]).
    pub pin: Option<String>,
    /// `A2CID2_POOL_THREADS`: total pool lanes; `>= 1` or ignored.
    pub pool_threads: Option<usize>,
}

fn read() -> Knobs {
    let flag = |name: &str| std::env::var(name).map(|v| v == "1").unwrap_or(false);
    Knobs {
        artifacts_dir: std::env::var("A2CID2_ARTIFACTS").ok(),
        bench_full: flag("A2CID2_BENCH_FULL"),
        bench_smoke: flag("A2CID2_BENCH_SMOKE"),
        bless: flag("A2CID2_BLESS"),
        kernel_backend: std::env::var("A2CID2_KERNEL_BACKEND").ok(),
        mux_threads: std::env::var("A2CID2_MUX_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1),
        numa: std::env::var("A2CID2_NUMA").ok(),
        pin: std::env::var("A2CID2_PIN").ok(),
        pool_threads: std::env::var("A2CID2_POOL_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1),
    }
}

/// The process-wide knobs, read from the environment exactly once.
pub fn knobs() -> &'static Knobs {
    static KNOBS: OnceLock<Knobs> = OnceLock::new();
    KNOBS.get_or_init(read)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exhaustiveness contract: [`VARS`] lists every knob, sorted
    /// and unique, and [`Knobs`] carries exactly one field per variable
    /// (pinned by the struct literal below — adding a knob without
    /// growing both this list and the struct fails to compile or fails
    /// here).
    #[test]
    fn vars_list_is_sorted_unique_and_matches_knobs() {
        assert!(VARS.windows(2).all(|w| w[0] < w[1]), "sorted + unique: {VARS:?}");
        assert!(VARS.iter().all(|v| v.starts_with("A2CID2_")), "one namespace");
        // One field per variable, same order as the docs table.
        let Knobs {
            artifacts_dir: _,
            bench_full: _,
            bench_smoke: _,
            bless: _,
            kernel_backend: _,
            mux_threads: _,
            numa: _,
            pin: _,
            pool_threads: _,
        } = Knobs::default();
        assert_eq!(VARS.len(), 9);
    }

    #[test]
    fn knobs_read_once_and_are_stable() {
        let a = knobs() as *const Knobs;
        let b = knobs() as *const Knobs;
        assert_eq!(a, b, "same cached instance");
        // Defaults are inert when the variables are unset.
        let k = read();
        if std::env::var("A2CID2_BENCH_FULL").is_err() {
            assert!(!k.bench_full);
        }
        if std::env::var("A2CID2_POOL_THREADS").is_err() {
            assert!(k.pool_threads.is_none());
        }
    }

    #[test]
    fn pool_threads_rejects_zero_and_garbage() {
        // The parse-and-filter pipeline (shared by the pool and the grid
        // runner) ignores 0 and non-numeric values rather than erroring.
        let parse = |v: &str| v.parse::<usize>().ok().filter(|&n| n >= 1);
        assert_eq!(parse("4"), Some(4));
        assert_eq!(parse("1"), Some(1));
        assert_eq!(parse("0"), None);
        assert_eq!(parse("lots"), None);
    }
}
