//! Theory-given hyper-parameters of the dynamic (Prop. 3.6).

use crate::graph::Spectrum;

/// The scalar hyper-parameters (η, α, α̃) of the SDE (Eq. 4).
///
/// * Baseline (≈ AD-PSGD): `η = 0`, `α = α̃ = ½` — the momentum buffer
///   stays glued to the parameters and the dynamic reduces to Eq. 6
///   (pairwise averaging + local SGD).
/// * A²CiD²: `η = 1/(2√(χ₁χ₂))`, `α = ½`, `α̃ = ½·√(χ₁/χ₂)` — the values
///   for which Prop. 3.6 proves the accelerated `√(χ₁χ₂)` dependence.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AcidParams {
    /// Continuous mixing rate of the (x, x̃) coupling.
    pub eta: f64,
    /// Communication step size on the parameters x.
    pub alpha: f64,
    /// Communication step size on the momentum buffer x̃.
    pub alpha_tilde: f64,
}

impl AcidParams {
    /// Non-accelerated baseline (η = 0, α = α̃ = ½).
    pub fn baseline() -> Self {
        AcidParams { eta: 0.0, alpha: 0.5, alpha_tilde: 0.5 }
    }

    /// Accelerated parameters from raw (χ₁, χ₂).
    pub fn accelerated(chi1: f64, chi2: f64) -> Self {
        assert!(chi1 > 0.0 && chi2 > 0.0, "chi must be positive: {chi1}, {chi2}");
        assert!(
            chi2 <= chi1 * (1.0 + 1e-6),
            "chi2={chi2} must not exceed chi1={chi1}"
        );
        AcidParams {
            eta: 1.0 / (2.0 * (chi1 * chi2).sqrt()),
            alpha: 0.5,
            alpha_tilde: 0.5 * (chi1 / chi2).sqrt(),
        }
    }

    /// Accelerated parameters from a computed graph spectrum.
    pub fn from_spectrum(s: &Spectrum) -> Self {
        Self::accelerated(s.chi1, s.chi2)
    }

    /// Accelerated parameters from a *measured* (χ₁, χ₂) — the adaptive
    /// per-phase path, which feeds eigensolver output straight in.
    /// Clamps χ₂ into `(0, χ₁]` instead of asserting, and returns `None`
    /// when the spectrum is unusable (non-finite or non-positive), so a
    /// degenerate active subgraph can never panic mid-run — the caller
    /// holds its previous parameters instead.
    pub fn from_chis_clamped(chi1: f64, chi2: f64) -> Option<Self> {
        if !(chi1.is_finite() && chi1 > 0.0 && chi2.is_finite() && chi2 > 0.0) {
            return None;
        }
        Some(Self::accelerated(chi1, chi2.min(chi1)))
    }

    /// Whether the momentum is active.
    pub fn is_accelerated(&self) -> bool {
        self.eta != 0.0
    }

    /// Human-readable label for experiment reports.
    pub fn label(&self) -> &'static str {
        if self.is_accelerated() {
            "A2CiD2"
        } else {
            "async-baseline"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, Topology};

    #[test]
    fn baseline_is_identity_momentum() {
        let p = AcidParams::baseline();
        assert_eq!(p.eta, 0.0);
        assert_eq!(p.alpha, 0.5);
        assert_eq!(p.alpha_tilde, 0.5);
        assert!(!p.is_accelerated());
    }

    #[test]
    fn accelerated_on_complete_graph_is_mild() {
        // χ₁ = χ₂ on the complete graph ⇒ α̃ = ½ (same as baseline) and
        // η = 1/(2χ₁): the momentum degenerates gracefully.
        let g = Graph::build(&Topology::Complete, 16).unwrap();
        let s = g.spectrum(1.0);
        let p = AcidParams::from_spectrum(&s);
        assert!((p.alpha_tilde - 0.5).abs() < 1e-6);
        assert!((p.eta - 1.0 / (2.0 * s.chi1)).abs() < 1e-6);
    }

    #[test]
    fn accelerated_on_ring_boosts_alpha_tilde() {
        // Ring: χ₁ ≈ n²/(2π²) ≫ χ₂ ≈ 1 ⇒ α̃ ≫ ½ and η small.
        let g = Graph::build(&Topology::Ring, 32).unwrap();
        let s = g.spectrum(1.0);
        let p = AcidParams::from_spectrum(&s);
        assert!(p.alpha_tilde > 2.0, "alpha_tilde={}", p.alpha_tilde);
        assert!(p.eta < 0.1, "eta={}", p.eta);
        assert!(p.is_accelerated());
    }

    #[test]
    #[should_panic]
    fn rejects_chi2_above_chi1() {
        AcidParams::accelerated(1.0, 2.0);
    }

    #[test]
    fn from_chis_clamped_never_panics() {
        // chi2 > chi1 (eigensolver slop) clamps instead of asserting.
        let p = AcidParams::from_chis_clamped(1.0, 2.0).unwrap();
        assert!((p.alpha_tilde - 0.5).abs() < 1e-12, "clamped to chi2 == chi1");
        assert!((p.eta - 0.5).abs() < 1e-12);
        // Degenerate spectra yield None, not a panic.
        for (c1, c2) in [
            (0.0, 1.0),
            (1.0, 0.0),
            (-1.0, 1.0),
            (f64::NAN, 1.0),
            (f64::INFINITY, 1.0),
            (1.0, f64::NAN),
        ] {
            assert!(AcidParams::from_chis_clamped(c1, c2).is_none(), "({c1}, {c2})");
        }
        // A clean spectrum matches the asserting constructor.
        assert_eq!(
            AcidParams::from_chis_clamped(10.0, 1.0).unwrap(),
            AcidParams::accelerated(10.0, 1.0)
        );
    }
}
