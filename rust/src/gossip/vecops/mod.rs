//! Fused vector kernels for the gossip hot path, behind an explicit
//! backend layer.
//!
//! These are the Rust mirrors of the L1 Pallas kernel
//! (`python/compile/kernels/acid_mix.py`): one pass over the parameter
//! vectors per event instead of a chain of BLAS-1 calls. Since PR 6 the
//! kernels live behind the [`KernelBackend`] trait:
//!
//! * [`scalar`] — the reference implementation (plain slice loops,
//!   LLVM-auto-vectorized). Defines the numerics every other backend
//!   must reproduce bit-for-bit.
//! * `simd` — explicit wide-lane `std::arch` kernels (AVX2 on x86_64,
//!   NEON on aarch64), runtime-detected. Bit-identical to scalar by
//!   construction: same per-element expression, separate multiply and
//!   add (no FMA contraction), scalar tails, and a fixed
//!   [`SQ_DIST_LANES`]-striped accumulation order for the one reduction.
//! * `avx512` — a real 512-bit path (16 f32 lanes), compiled when the
//!   toolchain has stable AVX-512 intrinsics (the `a2cid2_avx512` cfg
//!   from `build.rs`) and offered only when the CPU reports `avx512f`.
//!   Same bit-identity construction as `simd`.
//!
//! The backend is selected ONCE per process, on first kernel use:
//! `A2CID2_KERNEL_BACKEND=auto` (default) picks the 256-bit SIMD path
//! when the CPU supports it (deliberately NOT AVX-512 — the kernels are
//! memory-bound at the dims where the backend matters, and 512-bit
//! execution downclocks several client parts), `scalar` forces the
//! reference, `simd`/`avx2`/`neon` force the 256-bit wide path, and
//! `avx512` requests the 512-bit path, falling back to the 256-bit one
//! where it is unavailable (older toolchain or CPU — the historical
//! alias behavior) and panicking only if no wide path exists at all.
//! Because every backend is bit-identical, the replay goldens in
//! `rust/oracle/replay_golden.toml` and both engines' determinism
//! guarantees hold regardless of the selection; CI runs the golden
//! replay under both `scalar` and `auto` to enforce exactly that.
//!
//! The free functions below keep the historical call-side API; they
//! dispatch through [`backend`]. This trait is also the seam where the
//! future PJRT device backend plugs in. The `perf` bench measures every
//! backend's achieved bandwidth against the memcpy roofline.

pub mod scalar;
#[cfg(all(target_arch = "x86_64", a2cid2_avx512))]
mod avx512;
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
mod simd;

use std::sync::OnceLock;

pub use scalar::SQ_DIST_LANES;

/// The kernel interface every compute backend implements.
///
/// Default method bodies delegate to the [`scalar`] reference, so a
/// backend only overrides what it accelerates — and the reference is,
/// by construction, the semantics. Implementations MUST be bit-identical
/// to the defaults (see the module docs; the `backend_equivalence`
/// integration tests enforce this property for every in-tree backend).
#[allow(clippy::too_many_arguments)]
pub trait KernelBackend: Send + Sync {
    /// Short stable identifier ("scalar", "avx2", "neon", "avx512") —
    /// used by the `A2CID2_KERNEL_BACKEND` override, bench rows, and logs.
    fn name(&self) -> &'static str;

    /// `y ← y + a·x` (axpy).
    fn axpy(&self, a: f32, x: &[f32], y: &mut [f32]) {
        scalar::axpy(a, x, y)
    }

    /// `out ← wa·x + wb·x̃` (read-only mix into a send buffer).
    fn mix_into(&self, wa: f32, wb: f32, x: &[f32], xt: &[f32], out: &mut [f32]) {
        scalar::mix_into(wa, wb, x, xt, out)
    }

    /// `x ← x − γ·g`, `x̃ ← x̃ − γ·g` in one pass.
    fn grad_step(&self, gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
        scalar::grad_step(gamma, g, x, xt)
    }

    /// `x ← x − α·(x − xj)`, `x̃ ← x̃ − α̃·(x − xj)`.
    fn comm_only(&self, alpha: f32, alpha_tilde: f32, xj: &[f32], x: &mut [f32], xt: &mut [f32]) {
        scalar::comm_only(alpha, alpha_tilde, xj, x, xt)
    }

    /// `x' = wa·x + wb·x̃`, `x̃' = wb·x + wa·x̃` in place.
    fn mix_pair(&self, wa: f32, wb: f32, x: &mut [f32], xt: &mut [f32]) {
        scalar::mix_pair(wa, wb, x, xt)
    }

    /// `x' = mix(x, x̃) − γ·g`, `x̃' = mix(x̃, x) − γ·g`.
    fn mix_grad(&self, wa: f32, wb: f32, gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
        scalar::mix_grad(wa, wb, gamma, g, x, xt)
    }

    /// Receive-side fused pass: pending mix + `(α, α̃)` update.
    fn comm_apply_fused(
        &self,
        wa: f32,
        wb: f32,
        alpha: f32,
        alpha_tilde: f32,
        xj: &[f32],
        x: &mut [f32],
        xt: &mut [f32],
    ) {
        scalar::comm_apply_fused(wa, wb, alpha, alpha_tilde, xj, x, xt)
    }

    /// Historical name for [`KernelBackend::comm_apply_fused`] (mirrors
    /// the L1 Pallas kernel `acid_mix_comm`).
    fn mix_comm(
        &self,
        wa: f32,
        wb: f32,
        alpha: f32,
        alpha_tilde: f32,
        xj: &[f32],
        x: &mut [f32],
        xt: &mut [f32],
    ) {
        self.comm_apply_fused(wa, wb, alpha, alpha_tilde, xj, x, xt)
    }

    /// Fully-fused pairwise communication event over both endpoints.
    fn comm_pair_fused(
        &self,
        waa: f32,
        wba: f32,
        wab: f32,
        wbb: f32,
        alpha: f32,
        alpha_tilde: f32,
        xa: &mut [f32],
        xta: &mut [f32],
        xb: &mut [f32],
        xtb: &mut [f32],
    ) {
        scalar::comm_pair_fused(waa, wba, wab, wbb, alpha, alpha_tilde, xa, xta, xb, xtb)
    }

    /// `‖x − y‖²` with the fixed striped accumulation order.
    fn sq_dist(&self, x: &[f32], y: &[f32]) -> f64 {
        scalar::sq_dist(x, y)
    }

    /// `x, y ← (x+y)/2` into both.
    fn average_pair(&self, x: &mut [f32], y: &mut [f32]) {
        scalar::average_pair(x, y)
    }
}

/// The reference backend: every method keeps its default (scalar) body.
pub struct ScalarBackend;

impl KernelBackend for ScalarBackend {
    fn name(&self) -> &'static str {
        "scalar"
    }
}

static SCALAR_BACKEND: ScalarBackend = ScalarBackend;

/// The scalar reference backend (always available).
pub fn scalar_backend() -> &'static dyn KernelBackend {
    &SCALAR_BACKEND
}

fn simd_backend() -> Option<&'static dyn KernelBackend> {
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    {
        if simd::available() {
            return Some(&simd::SIMD_BACKEND);
        }
    }
    None
}

fn avx512_backend() -> Option<&'static dyn KernelBackend> {
    #[cfg(all(target_arch = "x86_64", a2cid2_avx512))]
    {
        if avx512::available() {
            return Some(&avx512::AVX512_BACKEND);
        }
    }
    None
}

fn select_backend() -> &'static dyn KernelBackend {
    let choice =
        crate::config::env::knobs().kernel_backend.clone().unwrap_or_default();
    match choice.trim().to_ascii_lowercase().as_str() {
        "" | "auto" => simd_backend().unwrap_or_else(scalar_backend),
        "scalar" => scalar_backend(),
        "simd" | "wide" | "avx2" | "neon" => simd_backend().unwrap_or_else(|| {
            panic!("A2CID2_KERNEL_BACKEND={choice}: no SIMD backend on this CPU/arch")
        }),
        // Falls back to the 256-bit path when the 512-bit one is out of
        // reach (toolchain or CPU) — "avx512" historically aliased the
        // 256-bit backend, and keeping that meaning lets one env matrix
        // span heterogeneous fleets without per-host branching.
        "avx512" => avx512_backend().or_else(simd_backend).unwrap_or_else(|| {
            panic!("A2CID2_KERNEL_BACKEND={choice}: no SIMD backend on this CPU/arch")
        }),
        other => {
            panic!("A2CID2_KERNEL_BACKEND={other}: expected auto|scalar|simd|avx2|neon|avx512")
        }
    }
}

/// The process-wide kernel backend, selected once on first use from
/// `A2CID2_KERNEL_BACKEND` (see module docs for the accepted values).
pub fn backend() -> &'static dyn KernelBackend {
    static BACKEND: OnceLock<&'static dyn KernelBackend> = OnceLock::new();
    *BACKEND.get_or_init(select_backend)
}

/// Name of the selected backend ("scalar", "avx2", "neon", "avx512").
pub fn backend_name() -> &'static str {
    backend().name()
}

/// Every backend usable on this machine, scalar first. This is what the
/// backend-equivalence tests and the per-backend bench rows iterate.
pub fn available_backends() -> Vec<&'static dyn KernelBackend> {
    let mut v: Vec<&'static dyn KernelBackend> = vec![scalar_backend()];
    if let Some(s) = simd_backend() {
        v.push(s);
    }
    if let Some(s) = avx512_backend() {
        v.push(s);
    }
    v
}

// ---------------------------------------------------------------------
// Historical free-function API: dispatches through the selected backend.
// ---------------------------------------------------------------------

/// `y ← y + a·x` (axpy).
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    backend().axpy(a, x, y)
}

/// Read-only momentum mixing into a separate output buffer:
/// `out ← wa·x + wb·x̃` — the send-side half of a runtime pairing. The
/// worker's state is *not* mutated (its pending mix stays pending, to be
/// folded into [`comm_apply_fused`] on receive), so building the outgoing
/// snapshot costs 2R + 1W outside the state write path instead of the old
/// mix-in-place (2R + 2W) plus snapshot copy (1R + 1W) under the lock.
///
/// Bit-compatible with [`mix_pair`]'s `x` row: the same `wa·a + wb·b`
/// expression, so a buffer built here is bit-identical to one copied out
/// of a state that was mixed in place.
#[inline]
pub fn mix_into(wa: f32, wb: f32, x: &[f32], xt: &[f32], out: &mut [f32]) {
    backend().mix_into(wa, wb, x, xt, out)
}

/// Fused two-row gradient step with no pending mix:
/// `x ← x − γ·g`, `x̃ ← x̃ − γ·g` in one pass (3R + 2W; `g` is read once),
/// replacing the two-axpy composition (4R + 2W) on the η = 0 path.
/// Bit-compatible with `axpy(−γ, g, ·)` applied to each row.
#[inline]
pub fn grad_step(gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
    backend().grad_step(gamma, g, x, xt)
}

/// The `(α, α̃)` averaging update alone, with no pending mix: given the
/// peer's vector `xj`, apply `x ← x − α·(x − xj)`, `x̃ ← x̃ − α̃·(x − xj)`
/// in one 3R + 2W pass. This is what [`super::dynamics::WorkerState::apply_comm`]
/// uses instead of paying [`comm_apply_fused`] with degenerate
/// `wa = 1, wb = 0` weights (which costs the same traffic but wastes two
/// multiplies and two adds per element).
#[inline]
pub fn comm_only(alpha: f32, alpha_tilde: f32, xj: &[f32], x: &mut [f32], xt: &mut [f32]) {
    backend().comm_only(alpha, alpha_tilde, xj, x, xt)
}

/// Fused momentum mixing: given mixing weights `(wa, wb)` with
/// `wa + wb = 1`, overwrite `(x, xt)` with
/// `x' = wa·x + wb·xt`, `xt' = wb·x + wa·xt` — a single pass, two reads +
/// two writes per element.
#[inline]
pub fn mix_pair(wa: f32, wb: f32, x: &mut [f32], xt: &mut [f32]) {
    backend().mix_pair(wa, wb, x, xt)
}

/// Fused mixing + gradient step (Algorithm 1, lines 9–11, per the SDE the
/// gradient hits both rows): `x' = mix(x,xt) − γ·g`, `xt' = mix(xt,x) − γ·g`.
#[inline]
pub fn mix_grad(wa: f32, wb: f32, gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
    backend().mix_grad(wa, wb, gamma, g, x, xt)
}

/// Fused mixing + communication step (Algorithm 1, lines 16–19): takes
/// the *already mixed* peer vector `xj`, folds this worker's own pending
/// momentum mix and the `(α, α̃)` update into one 3R + 2W pass:
/// `x' = mix − α·(mix − xj)`, `xt' = mixt − α̃·(mix − xj)`.
///
/// This is the receive-side half of a runtime pairing (the counterpart of
/// [`mix_into`]): the single locked read-modify-write pass over the
/// worker's state. Bit-compatible with `mix_pair` followed by
/// [`comm_only`] — the mixed rows are the same `wa·a + wb·b` expressions.
#[inline]
pub fn comm_apply_fused(
    wa: f32,
    wb: f32,
    alpha: f32,
    alpha_tilde: f32,
    xj: &[f32],
    x: &mut [f32],
    xt: &mut [f32],
) {
    backend().comm_apply_fused(wa, wb, alpha, alpha_tilde, xj, x, xt)
}

/// Historical name for [`comm_apply_fused`], kept because it mirrors the
/// L1 Pallas kernel (`acid_mix_comm` in `python/compile/kernels/`) and
/// the PJRT parity tests refer to the kernels by those names.
#[inline]
pub fn mix_comm(
    wa: f32,
    wb: f32,
    alpha: f32,
    alpha_tilde: f32,
    xj: &[f32],
    x: &mut [f32],
    xt: &mut [f32],
) {
    backend().mix_comm(wa, wb, alpha, alpha_tilde, xj, x, xt)
}

/// Fully-fused pairwise communication event over BOTH endpoints: applies
/// each side's pending momentum mixing (weights `(waa, wba)` for worker a,
/// `(wab, wbb)` for worker b — they differ because the workers' last event
/// times differ) and the antisymmetric `(α, α̃)` averaging update, in ONE
/// pass: 4 reads + 4 writes per element, no scratch allocation. This is
/// the simulator's hot path; `comm_event` composes it from
/// mix→snapshot→mix_comm on each side (≈ 11R + 9W) when buffers alias.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn comm_pair_fused(
    waa: f32,
    wba: f32,
    wab: f32,
    wbb: f32,
    alpha: f32,
    alpha_tilde: f32,
    xa: &mut [f32],
    xta: &mut [f32],
    xb: &mut [f32],
    xtb: &mut [f32],
) {
    backend().comm_pair_fused(waa, wba, wab, wbb, alpha, alpha_tilde, xa, xta, xb, xtb)
}

/// Sum of squared differences `‖x − y‖²` (consensus bookkeeping).
/// Accumulates in a fixed [`SQ_DIST_LANES`]-striped order that is the
/// same in every backend (see [`scalar::sq_dist`]).
#[inline]
pub fn sq_dist(x: &[f32], y: &[f32]) -> f64 {
    backend().sq_dist(x, y)
}

/// In-place average of two vectors into both: `x, y ← (x+y)/2`.
#[inline]
pub fn average_pair(x: &mut [f32], y: &mut [f32]) {
    backend().average_pair(x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn mix_pair_preserves_sum() {
        let mut x = vec![1.0f32, -2.0, 5.0];
        let mut xt = vec![3.0f32, 4.0, -1.0];
        let sums: Vec<f32> = x.iter().zip(&xt).map(|(a, b)| a + b).collect();
        mix_pair(0.7, 0.3, &mut x, &mut xt);
        for (i, s) in sums.iter().enumerate() {
            assert!((x[i] + xt[i] - s).abs() < 1e-6);
        }
    }

    #[test]
    fn mix_pair_identity_when_wa_one() {
        let mut x = vec![1.0f32, 2.0];
        let mut xt = vec![3.0f32, 4.0];
        mix_pair(1.0, 0.0, &mut x, &mut xt);
        assert_eq!(x, vec![1.0, 2.0]);
        assert_eq!(xt, vec![3.0, 4.0]);
    }

    #[test]
    fn mix_grad_matches_composition() {
        let g = vec![0.5f32, -1.0, 2.0];
        let mut x1 = vec![1.0f32, 2.0, 3.0];
        let mut t1 = vec![-1.0f32, 0.5, 1.5];
        let mut x2 = x1.clone();
        let mut t2 = t1.clone();
        // Fused
        mix_grad(0.8, 0.2, 0.1, &g, &mut x1, &mut t1);
        // Composition
        mix_pair(0.8, 0.2, &mut x2, &mut t2);
        axpy(-0.1, &g, &mut x2);
        axpy(-0.1, &g, &mut t2);
        for i in 0..3 {
            assert!((x1[i] - x2[i]).abs() < 1e-6);
            assert!((t1[i] - t2[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn mix_comm_matches_composition() {
        let xj = vec![0.0f32, 1.0, -1.0];
        let mut x1 = vec![1.0f32, 2.0, 3.0];
        let mut t1 = vec![-1.0f32, 0.5, 1.5];
        let mut x2 = x1.clone();
        let mut t2 = t1.clone();
        mix_comm(0.9, 0.1, 0.5, 1.7, &xj, &mut x1, &mut t1);
        mix_pair(0.9, 0.1, &mut x2, &mut t2);
        let m: Vec<f32> = x2.iter().zip(&xj).map(|(a, b)| a - b).collect();
        axpy(-0.5, &m, &mut x2);
        axpy(-1.7, &m, &mut t2);
        for i in 0..3 {
            assert!((x1[i] - x2[i]).abs() < 1e-6);
            assert!((t1[i] - t2[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn mix_comm_alpha_half_averages() {
        // With α = ½ and no mixing, x lands exactly on the pair average.
        let xj = vec![2.0f32, 4.0];
        let mut x = vec![0.0f32, 0.0];
        let mut xt = x.clone();
        mix_comm(1.0, 0.0, 0.5, 0.5, &xj, &mut x, &mut xt);
        assert_eq!(x, vec![1.0, 2.0]);
    }

    #[test]
    fn comm_pair_fused_matches_composed_path() {
        // Fused two-endpoint event == mix a; mix b; m = x_a − x_b;
        // apply ∓(α, α̃)m.
        let (waa, wba) = (0.85f32, 0.15f32);
        let (wab, wbb) = (0.6f32, 0.4f32);
        let (alpha, alpha_tilde) = (0.5f32, 1.9f32);
        let xa0 = vec![1.0f32, -2.0, 0.5];
        let ta0 = vec![0.2f32, 0.7, -1.0];
        let xb0 = vec![-1.0f32, 3.0, 2.0];
        let tb0 = vec![0.0f32, -0.5, 1.0];

        let (mut xa, mut ta) = (xa0.clone(), ta0.clone());
        let (mut xb, mut tb) = (xb0.clone(), tb0.clone());
        comm_pair_fused(
            waa, wba, wab, wbb, alpha, alpha_tilde, &mut xa, &mut ta, &mut xb, &mut tb,
        );

        // Composed reference.
        let (mut rxa, mut rta) = (xa0, ta0);
        let (mut rxb, mut rtb) = (xb0, tb0);
        mix_pair(waa, wba, &mut rxa, &mut rta);
        mix_pair(wab, wbb, &mut rxb, &mut rtb);
        let m: Vec<f32> = rxa.iter().zip(&rxb).map(|(a, b)| a - b).collect();
        axpy(-alpha, &m, &mut rxa);
        axpy(-alpha_tilde, &m, &mut rta);
        axpy(alpha, &m, &mut rxb);
        axpy(alpha_tilde, &m, &mut rtb);
        for i in 0..3 {
            assert!((xa[i] - rxa[i]).abs() < 1e-6);
            assert!((ta[i] - rta[i]).abs() < 1e-6);
            assert!((xb[i] - rxb[i]).abs() < 1e-6);
            assert!((tb[i] - rtb[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn mix_into_bit_identical_to_mix_pair_x_row() {
        let x = vec![1.0f32, -2.0, 0.5, 3.25];
        let xt = vec![0.2f32, 0.7, -1.0, 1.5];
        let mut out = vec![0.0f32; 4];
        mix_into(0.85, 0.15, &x, &xt, &mut out);
        let mut mx = x.clone();
        let mut mt = xt.clone();
        mix_pair(0.85, 0.15, &mut mx, &mut mt);
        assert_eq!(out, mx, "send buffer must match the in-place mixed x bit-for-bit");
    }

    #[test]
    fn grad_step_bit_identical_to_two_axpys() {
        let g = vec![0.5f32, -1.0, 2.0];
        let mut x1 = vec![1.0f32, 2.0, 3.0];
        let mut t1 = vec![-1.0f32, 0.5, 1.5];
        let mut x2 = x1.clone();
        let mut t2 = t1.clone();
        grad_step(0.1, &g, &mut x1, &mut t1);
        axpy(-0.1, &g, &mut x2);
        axpy(-0.1, &g, &mut t2);
        assert_eq!(x1, x2);
        assert_eq!(t1, t2);
    }

    #[test]
    fn comm_only_matches_degenerate_comm_apply_fused() {
        let xj = vec![0.0f32, 1.0, -1.0];
        let mut x1 = vec![1.0f32, 2.0, 3.0];
        let mut t1 = vec![-1.0f32, 0.5, 1.5];
        let mut x2 = x1.clone();
        let mut t2 = t1.clone();
        comm_only(0.5, 1.7, &xj, &mut x1, &mut t1);
        comm_apply_fused(1.0, 0.0, 0.5, 1.7, &xj, &mut x2, &mut t2);
        for i in 0..3 {
            assert!((x1[i] - x2[i]).abs() < 1e-7);
            assert!((t1[i] - t2[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn comm_apply_fused_matches_mix_then_comm_only() {
        // The fused receive pass == mix_pair + comm_only, bit-for-bit.
        let xj = vec![0.3f32, -2.0, 5.5];
        let mut x1 = vec![1.0f32, 2.0, 3.0];
        let mut t1 = vec![-1.0f32, 0.5, 1.5];
        let mut x2 = x1.clone();
        let mut t2 = t1.clone();
        comm_apply_fused(0.9, 0.1, 0.5, 1.7, &xj, &mut x1, &mut t1);
        mix_pair(0.9, 0.1, &mut x2, &mut t2);
        comm_only(0.5, 1.7, &xj, &mut x2, &mut t2);
        assert_eq!(x1, x2);
        assert_eq!(t1, t2);
    }

    #[test]
    fn average_pair_and_sq_dist() {
        let mut a = vec![0.0f32, 2.0];
        let mut b = vec![2.0f32, 0.0];
        assert_eq!(sq_dist(&a, &b), 8.0);
        average_pair(&mut a, &mut b);
        assert_eq!(a, vec![1.0, 1.0]);
        assert_eq!(b, vec![1.0, 1.0]);
        assert_eq!(sq_dist(&a, &b), 0.0);
    }

    #[test]
    fn backend_dispatch_is_latched_and_known() {
        let name = backend_name();
        assert!(
            matches!(name, "scalar" | "avx2" | "neon" | "avx512"),
            "unexpected backend {name}"
        );
        // Latched: the same selection is returned on every call.
        assert_eq!(backend().name(), name);
        let avail = available_backends();
        assert_eq!(avail[0].name(), "scalar");
        assert!(
            avail.iter().any(|b| b.name() == name),
            "selected backend {name} must be among the available ones"
        );
    }

    #[test]
    fn sq_dist_striped_order_is_exact_on_integers() {
        // 19 elements = 2 full stripes + ragged tail of 3; differences
        // are small integers, so every partial sum is exact and the
        // striped order must reproduce the plain sum exactly.
        let x: Vec<f32> = (0..19).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..19).map(|i| (i as f32) - 2.0).collect();
        assert_eq!(sq_dist(&x, &y), 4.0 * 19.0);
    }
}
