//! Scalar reference implementations of the gossip kernels.
//!
//! These are THE definition of every kernel's numerics: any other
//! backend (see `simd`) must produce bit-identical results, element by
//! element, because the replay goldens in `rust/oracle/replay_golden.toml`
//! and both engines' determinism tests were blessed against this code.
//! The loops are written over plain slices with exact-size iterators so
//! LLVM auto-vectorizes them; they double as the tail handler for the
//! explicit-SIMD backend on ragged lengths.
//!
//! Numeric contract (shared with every backend):
//! * elementwise kernels evaluate the exact per-element expression of the
//!   doc comment, left to right, with separate multiply and add — no FMA
//!   contraction (Rust never contracts `a * b + c` without fast-math, so
//!   these loops are a stable reference);
//! * the one reduction, [`sq_dist`], accumulates in a fixed
//!   [`SQ_DIST_LANES`]-striped order that is independent of how a backend
//!   vectorizes it (see its doc comment).

/// Number of independent accumulator lanes in [`sq_dist`].
///
/// Eight f64 lanes: the widest layout any in-tree backend wants (AVX2
/// processes 8 f32 per step and widens into two 4-lane f64 registers;
/// NEON covers the same 8-element block with four 2-lane f64 registers).
/// The scalar reference uses the same striping so every backend folds the
/// same partial sums in the same order.
pub const SQ_DIST_LANES: usize = 8;

/// `y ← y + a·x` (axpy).
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * *xi;
    }
}

/// `out ← wa·x + wb·x̃` (read-only momentum mix into a send buffer).
#[inline]
pub fn mix_into(wa: f32, wb: f32, x: &[f32], xt: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), xt.len());
    assert_eq!(x.len(), out.len());
    for ((o, xi), ti) in out.iter_mut().zip(x).zip(xt) {
        *o = wa * *xi + wb * *ti;
    }
}

/// `x ← x − γ·g`, `x̃ ← x̃ − γ·g` in one pass (`g` is read once).
#[inline]
pub fn grad_step(gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
    assert_eq!(x.len(), xt.len());
    assert_eq!(x.len(), g.len());
    let a = -gamma;
    for ((xi, ti), gi) in x.iter_mut().zip(xt.iter_mut()).zip(g) {
        let step = a * *gi;
        *xi += step;
        *ti += step;
    }
}

/// `x ← x − α·(x − xj)`, `x̃ ← x̃ − α̃·(x − xj)` with no pending mix.
#[inline]
pub fn comm_only(alpha: f32, alpha_tilde: f32, xj: &[f32], x: &mut [f32], xt: &mut [f32]) {
    assert_eq!(x.len(), xt.len());
    assert_eq!(x.len(), xj.len());
    for ((xi, ti), pj) in x.iter_mut().zip(xt.iter_mut()).zip(xj) {
        let m = *xi - *pj;
        *xi -= alpha * m;
        *ti -= alpha_tilde * m;
    }
}

/// `x' = wa·x + wb·x̃`, `x̃' = wb·x + wa·x̃` in place.
#[inline]
pub fn mix_pair(wa: f32, wb: f32, x: &mut [f32], xt: &mut [f32]) {
    assert_eq!(x.len(), xt.len());
    for (xi, ti) in x.iter_mut().zip(xt.iter_mut()) {
        let a = *xi;
        let b = *ti;
        *xi = wa * a + wb * b;
        *ti = wb * a + wa * b;
    }
}

/// `x' = mix(x, x̃) − γ·g`, `x̃' = mix(x̃, x) − γ·g` in one pass.
#[inline]
pub fn mix_grad(wa: f32, wb: f32, gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
    assert_eq!(x.len(), xt.len());
    assert_eq!(x.len(), g.len());
    for ((xi, ti), gi) in x.iter_mut().zip(xt.iter_mut()).zip(g) {
        let a = *xi;
        let b = *ti;
        let step = gamma * *gi;
        *xi = wa * a + wb * b - step;
        *ti = wb * a + wa * b - step;
    }
}

/// `x' = mix − α·(mix − xj)`, `x̃' = mixt − α̃·(mix − xj)` where
/// `mix/mixt` fold this worker's pending momentum mix.
#[inline]
pub fn comm_apply_fused(
    wa: f32,
    wb: f32,
    alpha: f32,
    alpha_tilde: f32,
    xj: &[f32],
    x: &mut [f32],
    xt: &mut [f32],
) {
    assert_eq!(x.len(), xt.len());
    assert_eq!(x.len(), xj.len());
    for ((xi, ti), pj) in x.iter_mut().zip(xt.iter_mut()).zip(xj) {
        let a = *xi;
        let b = *ti;
        let mixed_x = wa * a + wb * b;
        let mixed_t = wb * a + wa * b;
        let m = mixed_x - *pj;
        *xi = mixed_x - alpha * m;
        *ti = mixed_t - alpha_tilde * m;
    }
}

/// Fully-fused pairwise communication event over BOTH endpoints.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn comm_pair_fused(
    waa: f32,
    wba: f32,
    wab: f32,
    wbb: f32,
    alpha: f32,
    alpha_tilde: f32,
    xa: &mut [f32],
    xta: &mut [f32],
    xb: &mut [f32],
    xtb: &mut [f32],
) {
    assert_eq!(xa.len(), xta.len());
    assert_eq!(xa.len(), xb.len());
    assert_eq!(xa.len(), xtb.len());
    for (((a, ta), b), tb) in xa
        .iter_mut()
        .zip(xta.iter_mut())
        .zip(xb.iter_mut())
        .zip(xtb.iter_mut())
    {
        // Mix each endpoint to the event time.
        let (va, vta) = (*a, *ta);
        let (vb, vtb) = (*b, *tb);
        let ma = waa * va + wba * vta;
        let mta = wba * va + waa * vta;
        let mb = wab * vb + wbb * vtb;
        let mtb = wbb * vb + wab * vtb;
        // Antisymmetric averaging update: m = x_a − x_b.
        let m = ma - mb;
        *a = ma - alpha * m;
        *ta = mta - alpha_tilde * m;
        *b = mb + alpha * m;
        *tb = mtb + alpha_tilde * m;
    }
}

/// Sum of squared differences `‖x − y‖²` (consensus bookkeeping).
///
/// Fixed accumulation order, identical in every backend: the vectors are
/// walked in blocks of [`SQ_DIST_LANES`]; element `8·i + k` contributes
/// `d²` (with `d` the f32 difference widened to f64) to lane accumulator
/// `acc[k]`; a ragged tail of length `r` feeds lanes `0..r` in order; the
/// eight lane sums are then folded left to right. A SIMD backend that
/// keeps one virtual accumulator per lane reproduces this bit-for-bit, so
/// the reduction result does not depend on the selected backend.
#[inline]
pub fn sq_dist(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    let mut acc = [0.0f64; SQ_DIST_LANES];
    let mut i = 0usize;
    while i + SQ_DIST_LANES <= n {
        for k in 0..SQ_DIST_LANES {
            let d = (x[i + k] - y[i + k]) as f64;
            acc[k] += d * d;
        }
        i += SQ_DIST_LANES;
    }
    for (k, j) in (i..n).enumerate() {
        let d = (x[j] - y[j]) as f64;
        acc[k] += d * d;
    }
    acc.iter().sum()
}

/// In-place average of two vectors into both: `x, y ← (x+y)/2`.
#[inline]
pub fn average_pair(x: &mut [f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (a, b) in x.iter_mut().zip(y.iter_mut()) {
        let m = 0.5 * (*a + *b);
        *a = m;
        *b = m;
    }
}
