//! Explicit wide-lane SIMD backend (`std::arch`): AVX2 on x86_64, NEON
//! on aarch64.
//!
//! Bit-identity contract: every lane evaluates the scalar reference's
//! per-element expression with SEPARATE multiply and add instructions —
//! never a fused multiply-add, whose single rounding would diverge from
//! the scalar kernels' two roundings and flip the replay FNV checksums.
//! On x86 that means `_mm256_mul_ps` + `_mm256_add_ps` (no
//! `_mm256_fmadd_ps`); on aarch64 `vmulq_f32` + `vaddq_f32` (never
//! `vmlaq_f32`, which lowers to fused FMLA). Ragged tails shorter than a
//! vector are delegated to the scalar reference on the remainder slices,
//! which is bit-identical by construction. The [`sq_dist`] reduction
//! keeps one virtual f64 accumulator per stripe lane, matching the
//! scalar reference's fixed `SQ_DIST_LANES`-striped accumulation order.
//!
//! Every streaming loop issues an explicit software prefetch
//! (`_mm_prefetch` / `prfm pldl1keep`) one `PF`-stride ahead per input
//! stream. Prefetch is a pure hint — it never faults (so pointers past
//! the slice end are fine) and never changes a result bit — but on the
//! NUMA-placed buffers the pool produces it hides remote-node latency
//! the hardware prefetcher gives up on at page boundaries.
//!
//! A real AVX-512 path lives in the sibling `avx512` module (compiled
//! when the toolchain is new enough, selected by
//! `A2CID2_KERNEL_BACKEND=avx512`); `auto` keeps preferring this
//! 256-bit backend — the kernels are memory-bound at the dims where the
//! backend matters, and 512-bit execution downclocks several client
//! parts — so the opt-in is explicit.

use super::KernelBackend;

/// The wide-lane backend. Handed out by `super::select_backend` only
/// after [`available`] confirmed the required CPU features, which is what
/// makes the `unsafe` kernel calls inside sound.
pub(super) struct SimdBackend;

/// Singleton instance (the dispatch layer deals in `&'static dyn`).
pub(super) static SIMD_BACKEND: SimdBackend = SimdBackend;

#[cfg(target_arch = "x86_64")]
const NAME: &str = "avx2";
#[cfg(target_arch = "aarch64")]
const NAME: &str = "neon";

/// Whether this backend can run on the current CPU. NEON is mandatory
/// on aarch64; AVX2 is probed at runtime.
pub(super) fn available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(target_arch = "aarch64")]
    {
        true
    }
}

#[allow(clippy::too_many_arguments)]
impl KernelBackend for SimdBackend {
    fn name(&self) -> &'static str {
        NAME
    }

    fn axpy(&self, a: f32, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len());
        unsafe { imp::axpy(a, x, y) }
    }

    fn mix_into(&self, wa: f32, wb: f32, x: &[f32], xt: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), xt.len());
        assert_eq!(x.len(), out.len());
        unsafe { imp::mix_into(wa, wb, x, xt, out) }
    }

    fn grad_step(&self, gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
        assert_eq!(x.len(), xt.len());
        assert_eq!(x.len(), g.len());
        unsafe { imp::grad_step(gamma, g, x, xt) }
    }

    fn comm_only(&self, alpha: f32, alpha_tilde: f32, xj: &[f32], x: &mut [f32], xt: &mut [f32]) {
        assert_eq!(x.len(), xt.len());
        assert_eq!(x.len(), xj.len());
        unsafe { imp::comm_only(alpha, alpha_tilde, xj, x, xt) }
    }

    fn mix_pair(&self, wa: f32, wb: f32, x: &mut [f32], xt: &mut [f32]) {
        assert_eq!(x.len(), xt.len());
        unsafe { imp::mix_pair(wa, wb, x, xt) }
    }

    fn mix_grad(&self, wa: f32, wb: f32, gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
        assert_eq!(x.len(), xt.len());
        assert_eq!(x.len(), g.len());
        unsafe { imp::mix_grad(wa, wb, gamma, g, x, xt) }
    }

    fn comm_apply_fused(
        &self,
        wa: f32,
        wb: f32,
        alpha: f32,
        alpha_tilde: f32,
        xj: &[f32],
        x: &mut [f32],
        xt: &mut [f32],
    ) {
        assert_eq!(x.len(), xt.len());
        assert_eq!(x.len(), xj.len());
        unsafe { imp::comm_apply_fused(wa, wb, alpha, alpha_tilde, xj, x, xt) }
    }

    fn comm_pair_fused(
        &self,
        waa: f32,
        wba: f32,
        wab: f32,
        wbb: f32,
        alpha: f32,
        alpha_tilde: f32,
        xa: &mut [f32],
        xta: &mut [f32],
        xb: &mut [f32],
        xtb: &mut [f32],
    ) {
        assert_eq!(xa.len(), xta.len());
        assert_eq!(xa.len(), xb.len());
        assert_eq!(xa.len(), xtb.len());
        unsafe { imp::comm_pair_fused(waa, wba, wab, wbb, alpha, alpha_tilde, xa, xta, xb, xtb) }
    }

    fn sq_dist(&self, x: &[f32], y: &[f32]) -> f64 {
        assert_eq!(x.len(), y.len());
        unsafe { imp::sq_dist(x, y) }
    }

    fn average_pair(&self, x: &mut [f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len());
        unsafe { imp::average_pair(x, y) }
    }
}

/// AVX2: 8 f32 lanes per step. Safety: callers (the trait impl above)
/// guarantee equal slice lengths and that AVX2 was detected.
#[cfg(target_arch = "x86_64")]
mod imp {
    use crate::gossip::vecops::scalar;
    use core::arch::x86_64::*;

    const LANES: usize = 8;

    /// Prefetch distance in elements (1 KiB per f32 stream): far enough
    /// ahead to cover DRAM latency at streaming pace, close enough to
    /// stay in the L1 fill window.
    const PF: usize = 256;

    /// Hint-prefetch `p[i]` into L1. `wrapping_add` because the address
    /// may run past the slice near the end of a loop — prefetch never
    /// faults, so an out-of-range hint is merely ignored.
    #[inline(always)]
    unsafe fn pf(p: *const f32, i: usize) {
        _mm_prefetch::<_MM_HINT_T0>(p.wrapping_add(i) as *const i8);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = _mm256_set1_ps(a);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(x.as_ptr(), i + PF);
            pf(y.as_ptr(), i + PF);
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            // y + (a·x): separate mul and add — no FMA (bit-identity).
            let r = _mm256_add_ps(vy, _mm256_mul_ps(va, vx));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), r);
            i += LANES;
        }
        scalar::axpy(a, &x[i..], &mut y[i..]);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn mix_into(wa: f32, wb: f32, x: &[f32], xt: &[f32], out: &mut [f32]) {
        let n = x.len();
        let vwa = _mm256_set1_ps(wa);
        let vwb = _mm256_set1_ps(wb);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            pf(out.as_ptr(), i + PF);
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vt = _mm256_loadu_ps(xt.as_ptr().add(i));
            let r = _mm256_add_ps(_mm256_mul_ps(vwa, vx), _mm256_mul_ps(vwb, vt));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
            i += LANES;
        }
        scalar::mix_into(wa, wb, &x[i..], &xt[i..], &mut out[i..]);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn grad_step(gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
        let n = x.len();
        let va = _mm256_set1_ps(-gamma);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(g.as_ptr(), i + PF);
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let vg = _mm256_loadu_ps(g.as_ptr().add(i));
            let step = _mm256_mul_ps(va, vg);
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vt = _mm256_loadu_ps(xt.as_ptr().add(i));
            _mm256_storeu_ps(x.as_mut_ptr().add(i), _mm256_add_ps(vx, step));
            _mm256_storeu_ps(xt.as_mut_ptr().add(i), _mm256_add_ps(vt, step));
            i += LANES;
        }
        scalar::grad_step(gamma, &g[i..], &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn comm_only(
        alpha: f32,
        alpha_tilde: f32,
        xj: &[f32],
        x: &mut [f32],
        xt: &mut [f32],
    ) {
        let n = x.len();
        let val = _mm256_set1_ps(alpha);
        let vat = _mm256_set1_ps(alpha_tilde);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(xj.as_ptr(), i + PF);
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vt = _mm256_loadu_ps(xt.as_ptr().add(i));
            let vp = _mm256_loadu_ps(xj.as_ptr().add(i));
            let m = _mm256_sub_ps(vx, vp);
            let rx = _mm256_sub_ps(vx, _mm256_mul_ps(val, m));
            let rt = _mm256_sub_ps(vt, _mm256_mul_ps(vat, m));
            _mm256_storeu_ps(x.as_mut_ptr().add(i), rx);
            _mm256_storeu_ps(xt.as_mut_ptr().add(i), rt);
            i += LANES;
        }
        scalar::comm_only(alpha, alpha_tilde, &xj[i..], &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn mix_pair(wa: f32, wb: f32, x: &mut [f32], xt: &mut [f32]) {
        let n = x.len();
        let vwa = _mm256_set1_ps(wa);
        let vwb = _mm256_set1_ps(wb);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let a = _mm256_loadu_ps(x.as_ptr().add(i));
            let b = _mm256_loadu_ps(xt.as_ptr().add(i));
            let rx = _mm256_add_ps(_mm256_mul_ps(vwa, a), _mm256_mul_ps(vwb, b));
            let rt = _mm256_add_ps(_mm256_mul_ps(vwb, a), _mm256_mul_ps(vwa, b));
            _mm256_storeu_ps(x.as_mut_ptr().add(i), rx);
            _mm256_storeu_ps(xt.as_mut_ptr().add(i), rt);
            i += LANES;
        }
        scalar::mix_pair(wa, wb, &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn mix_grad(
        wa: f32,
        wb: f32,
        gamma: f32,
        g: &[f32],
        x: &mut [f32],
        xt: &mut [f32],
    ) {
        let n = x.len();
        let vwa = _mm256_set1_ps(wa);
        let vwb = _mm256_set1_ps(wb);
        let vgamma = _mm256_set1_ps(gamma);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(g.as_ptr(), i + PF);
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let a = _mm256_loadu_ps(x.as_ptr().add(i));
            let b = _mm256_loadu_ps(xt.as_ptr().add(i));
            let vg = _mm256_loadu_ps(g.as_ptr().add(i));
            let step = _mm256_mul_ps(vgamma, vg);
            let mx = _mm256_add_ps(_mm256_mul_ps(vwa, a), _mm256_mul_ps(vwb, b));
            let mt = _mm256_add_ps(_mm256_mul_ps(vwb, a), _mm256_mul_ps(vwa, b));
            _mm256_storeu_ps(x.as_mut_ptr().add(i), _mm256_sub_ps(mx, step));
            _mm256_storeu_ps(xt.as_mut_ptr().add(i), _mm256_sub_ps(mt, step));
            i += LANES;
        }
        scalar::mix_grad(wa, wb, gamma, &g[i..], &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn comm_apply_fused(
        wa: f32,
        wb: f32,
        alpha: f32,
        alpha_tilde: f32,
        xj: &[f32],
        x: &mut [f32],
        xt: &mut [f32],
    ) {
        let n = x.len();
        let vwa = _mm256_set1_ps(wa);
        let vwb = _mm256_set1_ps(wb);
        let val = _mm256_set1_ps(alpha);
        let vat = _mm256_set1_ps(alpha_tilde);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(xj.as_ptr(), i + PF);
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let a = _mm256_loadu_ps(x.as_ptr().add(i));
            let b = _mm256_loadu_ps(xt.as_ptr().add(i));
            let vp = _mm256_loadu_ps(xj.as_ptr().add(i));
            let mixed_x = _mm256_add_ps(_mm256_mul_ps(vwa, a), _mm256_mul_ps(vwb, b));
            let mixed_t = _mm256_add_ps(_mm256_mul_ps(vwb, a), _mm256_mul_ps(vwa, b));
            let m = _mm256_sub_ps(mixed_x, vp);
            let rx = _mm256_sub_ps(mixed_x, _mm256_mul_ps(val, m));
            let rt = _mm256_sub_ps(mixed_t, _mm256_mul_ps(vat, m));
            _mm256_storeu_ps(x.as_mut_ptr().add(i), rx);
            _mm256_storeu_ps(xt.as_mut_ptr().add(i), rt);
            i += LANES;
        }
        scalar::comm_apply_fused(wa, wb, alpha, alpha_tilde, &xj[i..], &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn comm_pair_fused(
        waa: f32,
        wba: f32,
        wab: f32,
        wbb: f32,
        alpha: f32,
        alpha_tilde: f32,
        xa: &mut [f32],
        xta: &mut [f32],
        xb: &mut [f32],
        xtb: &mut [f32],
    ) {
        let n = xa.len();
        let vwaa = _mm256_set1_ps(waa);
        let vwba = _mm256_set1_ps(wba);
        let vwab = _mm256_set1_ps(wab);
        let vwbb = _mm256_set1_ps(wbb);
        let val = _mm256_set1_ps(alpha);
        let vat = _mm256_set1_ps(alpha_tilde);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(xa.as_ptr(), i + PF);
            pf(xta.as_ptr(), i + PF);
            pf(xb.as_ptr(), i + PF);
            pf(xtb.as_ptr(), i + PF);
            let va = _mm256_loadu_ps(xa.as_ptr().add(i));
            let vta = _mm256_loadu_ps(xta.as_ptr().add(i));
            let vb = _mm256_loadu_ps(xb.as_ptr().add(i));
            let vtb = _mm256_loadu_ps(xtb.as_ptr().add(i));
            let ma = _mm256_add_ps(_mm256_mul_ps(vwaa, va), _mm256_mul_ps(vwba, vta));
            let mta = _mm256_add_ps(_mm256_mul_ps(vwba, va), _mm256_mul_ps(vwaa, vta));
            let mb = _mm256_add_ps(_mm256_mul_ps(vwab, vb), _mm256_mul_ps(vwbb, vtb));
            let mtb = _mm256_add_ps(_mm256_mul_ps(vwbb, vb), _mm256_mul_ps(vwab, vtb));
            let m = _mm256_sub_ps(ma, mb);
            _mm256_storeu_ps(xa.as_mut_ptr().add(i), _mm256_sub_ps(ma, _mm256_mul_ps(val, m)));
            _mm256_storeu_ps(
                xta.as_mut_ptr().add(i),
                _mm256_sub_ps(mta, _mm256_mul_ps(vat, m)),
            );
            _mm256_storeu_ps(xb.as_mut_ptr().add(i), _mm256_add_ps(mb, _mm256_mul_ps(val, m)));
            _mm256_storeu_ps(
                xtb.as_mut_ptr().add(i),
                _mm256_add_ps(mtb, _mm256_mul_ps(vat, m)),
            );
            i += LANES;
        }
        scalar::comm_pair_fused(
            waa,
            wba,
            wab,
            wbb,
            alpha,
            alpha_tilde,
            &mut xa[i..],
            &mut xta[i..],
            &mut xb[i..],
            &mut xtb[i..],
        );
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn sq_dist(x: &[f32], y: &[f32]) -> f64 {
        let n = x.len();
        // Two 4-wide f64 accumulators = virtual stripe lanes 0–3 / 4–7,
        // mirroring the scalar reference's SQ_DIST_LANES striping.
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let mut i = 0usize;
        while i + LANES <= n {
            pf(x.as_ptr(), i + PF);
            pf(y.as_ptr(), i + PF);
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            let d = _mm256_sub_ps(vx, vy); // f32 difference, then widen — as scalar
            let dlo = _mm256_cvtps_pd(_mm256_castps256_ps128(d));
            let dhi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(d));
            acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(dlo, dlo));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(dhi, dhi));
            i += LANES;
        }
        let mut acc = [0.0f64; LANES];
        _mm256_storeu_pd(acc.as_mut_ptr(), acc_lo);
        _mm256_storeu_pd(acc.as_mut_ptr().add(4), acc_hi);
        // Ragged tail feeds lanes 0..r, then fold left to right — the
        // exact order of the scalar reference.
        for (k, j) in (i..n).enumerate() {
            let d = (x[j] - y[j]) as f64;
            acc[k] += d * d;
        }
        acc.iter().sum()
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn average_pair(x: &mut [f32], y: &mut [f32]) {
        let n = x.len();
        let vhalf = _mm256_set1_ps(0.5);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(x.as_ptr(), i + PF);
            pf(y.as_ptr(), i + PF);
            let a = _mm256_loadu_ps(x.as_ptr().add(i));
            let b = _mm256_loadu_ps(y.as_ptr().add(i));
            let m = _mm256_mul_ps(vhalf, _mm256_add_ps(a, b));
            _mm256_storeu_ps(x.as_mut_ptr().add(i), m);
            _mm256_storeu_ps(y.as_mut_ptr().add(i), m);
            i += LANES;
        }
        scalar::average_pair(&mut x[i..], &mut y[i..]);
    }
}

/// NEON: 4 f32 lanes per step (mandatory on aarch64). Safety: callers
/// (the trait impl above) guarantee equal slice lengths.
#[cfg(target_arch = "aarch64")]
mod imp {
    use crate::gossip::vecops::scalar;
    use core::arch::aarch64::*;

    const LANES: usize = 4;

    /// Prefetch distance in elements (1 KiB per f32 stream) — see the
    /// x86_64 twin for the rationale.
    const PF: usize = 256;

    /// Hint-prefetch `p[i]` into L1 (`prfm pldl1keep`; aarch64 has no
    /// stable prefetch intrinsic). `wrapping_add` because the address
    /// may run past the slice near the end of a loop — prefetch never
    /// faults, so an out-of-range hint is merely ignored.
    #[inline(always)]
    unsafe fn pf(p: *const f32, i: usize) {
        core::arch::asm!(
            "prfm pldl1keep, [{0}]",
            in(reg) p.wrapping_add(i),
            options(nomem, nostack, preserves_flags),
        );
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = vdupq_n_f32(a);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(x.as_ptr(), i + PF);
            pf(y.as_ptr(), i + PF);
            let vx = vld1q_f32(x.as_ptr().add(i));
            let vy = vld1q_f32(y.as_ptr().add(i));
            // y + (a·x): vmulq + vaddq, never vmlaq (fused FMLA).
            vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(vy, vmulq_f32(va, vx)));
            i += LANES;
        }
        scalar::axpy(a, &x[i..], &mut y[i..]);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn mix_into(wa: f32, wb: f32, x: &[f32], xt: &[f32], out: &mut [f32]) {
        let n = x.len();
        let vwa = vdupq_n_f32(wa);
        let vwb = vdupq_n_f32(wb);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            pf(out.as_ptr(), i + PF);
            let vx = vld1q_f32(x.as_ptr().add(i));
            let vt = vld1q_f32(xt.as_ptr().add(i));
            let r = vaddq_f32(vmulq_f32(vwa, vx), vmulq_f32(vwb, vt));
            vst1q_f32(out.as_mut_ptr().add(i), r);
            i += LANES;
        }
        scalar::mix_into(wa, wb, &x[i..], &xt[i..], &mut out[i..]);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn grad_step(gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
        let n = x.len();
        let va = vdupq_n_f32(-gamma);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(g.as_ptr(), i + PF);
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let vg = vld1q_f32(g.as_ptr().add(i));
            let step = vmulq_f32(va, vg);
            let vx = vld1q_f32(x.as_ptr().add(i));
            let vt = vld1q_f32(xt.as_ptr().add(i));
            vst1q_f32(x.as_mut_ptr().add(i), vaddq_f32(vx, step));
            vst1q_f32(xt.as_mut_ptr().add(i), vaddq_f32(vt, step));
            i += LANES;
        }
        scalar::grad_step(gamma, &g[i..], &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn comm_only(
        alpha: f32,
        alpha_tilde: f32,
        xj: &[f32],
        x: &mut [f32],
        xt: &mut [f32],
    ) {
        let n = x.len();
        let val = vdupq_n_f32(alpha);
        let vat = vdupq_n_f32(alpha_tilde);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(xj.as_ptr(), i + PF);
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let vx = vld1q_f32(x.as_ptr().add(i));
            let vt = vld1q_f32(xt.as_ptr().add(i));
            let vp = vld1q_f32(xj.as_ptr().add(i));
            let m = vsubq_f32(vx, vp);
            vst1q_f32(x.as_mut_ptr().add(i), vsubq_f32(vx, vmulq_f32(val, m)));
            vst1q_f32(xt.as_mut_ptr().add(i), vsubq_f32(vt, vmulq_f32(vat, m)));
            i += LANES;
        }
        scalar::comm_only(alpha, alpha_tilde, &xj[i..], &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn mix_pair(wa: f32, wb: f32, x: &mut [f32], xt: &mut [f32]) {
        let n = x.len();
        let vwa = vdupq_n_f32(wa);
        let vwb = vdupq_n_f32(wb);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let a = vld1q_f32(x.as_ptr().add(i));
            let b = vld1q_f32(xt.as_ptr().add(i));
            let rx = vaddq_f32(vmulq_f32(vwa, a), vmulq_f32(vwb, b));
            let rt = vaddq_f32(vmulq_f32(vwb, a), vmulq_f32(vwa, b));
            vst1q_f32(x.as_mut_ptr().add(i), rx);
            vst1q_f32(xt.as_mut_ptr().add(i), rt);
            i += LANES;
        }
        scalar::mix_pair(wa, wb, &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn mix_grad(
        wa: f32,
        wb: f32,
        gamma: f32,
        g: &[f32],
        x: &mut [f32],
        xt: &mut [f32],
    ) {
        let n = x.len();
        let vwa = vdupq_n_f32(wa);
        let vwb = vdupq_n_f32(wb);
        let vgamma = vdupq_n_f32(gamma);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(g.as_ptr(), i + PF);
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let a = vld1q_f32(x.as_ptr().add(i));
            let b = vld1q_f32(xt.as_ptr().add(i));
            let vg = vld1q_f32(g.as_ptr().add(i));
            let step = vmulq_f32(vgamma, vg);
            let mx = vaddq_f32(vmulq_f32(vwa, a), vmulq_f32(vwb, b));
            let mt = vaddq_f32(vmulq_f32(vwb, a), vmulq_f32(vwa, b));
            vst1q_f32(x.as_mut_ptr().add(i), vsubq_f32(mx, step));
            vst1q_f32(xt.as_mut_ptr().add(i), vsubq_f32(mt, step));
            i += LANES;
        }
        scalar::mix_grad(wa, wb, gamma, &g[i..], &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn comm_apply_fused(
        wa: f32,
        wb: f32,
        alpha: f32,
        alpha_tilde: f32,
        xj: &[f32],
        x: &mut [f32],
        xt: &mut [f32],
    ) {
        let n = x.len();
        let vwa = vdupq_n_f32(wa);
        let vwb = vdupq_n_f32(wb);
        let val = vdupq_n_f32(alpha);
        let vat = vdupq_n_f32(alpha_tilde);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(xj.as_ptr(), i + PF);
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let a = vld1q_f32(x.as_ptr().add(i));
            let b = vld1q_f32(xt.as_ptr().add(i));
            let vp = vld1q_f32(xj.as_ptr().add(i));
            let mixed_x = vaddq_f32(vmulq_f32(vwa, a), vmulq_f32(vwb, b));
            let mixed_t = vaddq_f32(vmulq_f32(vwb, a), vmulq_f32(vwa, b));
            let m = vsubq_f32(mixed_x, vp);
            vst1q_f32(x.as_mut_ptr().add(i), vsubq_f32(mixed_x, vmulq_f32(val, m)));
            vst1q_f32(xt.as_mut_ptr().add(i), vsubq_f32(mixed_t, vmulq_f32(vat, m)));
            i += LANES;
        }
        scalar::comm_apply_fused(wa, wb, alpha, alpha_tilde, &xj[i..], &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn comm_pair_fused(
        waa: f32,
        wba: f32,
        wab: f32,
        wbb: f32,
        alpha: f32,
        alpha_tilde: f32,
        xa: &mut [f32],
        xta: &mut [f32],
        xb: &mut [f32],
        xtb: &mut [f32],
    ) {
        let n = xa.len();
        let vwaa = vdupq_n_f32(waa);
        let vwba = vdupq_n_f32(wba);
        let vwab = vdupq_n_f32(wab);
        let vwbb = vdupq_n_f32(wbb);
        let val = vdupq_n_f32(alpha);
        let vat = vdupq_n_f32(alpha_tilde);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(xa.as_ptr(), i + PF);
            pf(xta.as_ptr(), i + PF);
            pf(xb.as_ptr(), i + PF);
            pf(xtb.as_ptr(), i + PF);
            let va = vld1q_f32(xa.as_ptr().add(i));
            let vta = vld1q_f32(xta.as_ptr().add(i));
            let vb = vld1q_f32(xb.as_ptr().add(i));
            let vtb = vld1q_f32(xtb.as_ptr().add(i));
            let ma = vaddq_f32(vmulq_f32(vwaa, va), vmulq_f32(vwba, vta));
            let mta = vaddq_f32(vmulq_f32(vwba, va), vmulq_f32(vwaa, vta));
            let mb = vaddq_f32(vmulq_f32(vwab, vb), vmulq_f32(vwbb, vtb));
            let mtb = vaddq_f32(vmulq_f32(vwbb, vb), vmulq_f32(vwab, vtb));
            let m = vsubq_f32(ma, mb);
            vst1q_f32(xa.as_mut_ptr().add(i), vsubq_f32(ma, vmulq_f32(val, m)));
            vst1q_f32(xta.as_mut_ptr().add(i), vsubq_f32(mta, vmulq_f32(vat, m)));
            vst1q_f32(xb.as_mut_ptr().add(i), vaddq_f32(mb, vmulq_f32(val, m)));
            vst1q_f32(xtb.as_mut_ptr().add(i), vaddq_f32(mtb, vmulq_f32(vat, m)));
            i += LANES;
        }
        scalar::comm_pair_fused(
            waa,
            wba,
            wab,
            wbb,
            alpha,
            alpha_tilde,
            &mut xa[i..],
            &mut xta[i..],
            &mut xb[i..],
            &mut xtb[i..],
        );
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn sq_dist(x: &[f32], y: &[f32]) -> f64 {
        let n = x.len();
        // Four 2-wide f64 accumulators = virtual stripe lanes
        // 0–1/2–3/4–5/6–7, mirroring the scalar SQ_DIST_LANES striping.
        let mut acc01 = vdupq_n_f64(0.0);
        let mut acc23 = vdupq_n_f64(0.0);
        let mut acc45 = vdupq_n_f64(0.0);
        let mut acc67 = vdupq_n_f64(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            pf(x.as_ptr(), i + PF);
            pf(y.as_ptr(), i + PF);
            let d0 = vsubq_f32(vld1q_f32(x.as_ptr().add(i)), vld1q_f32(y.as_ptr().add(i)));
            let d1 = vsubq_f32(
                vld1q_f32(x.as_ptr().add(i + 4)),
                vld1q_f32(y.as_ptr().add(i + 4)),
            );
            let d01 = vcvt_f64_f32(vget_low_f32(d0));
            let d23 = vcvt_high_f64_f32(d0);
            let d45 = vcvt_f64_f32(vget_low_f32(d1));
            let d67 = vcvt_high_f64_f32(d1);
            acc01 = vaddq_f64(acc01, vmulq_f64(d01, d01));
            acc23 = vaddq_f64(acc23, vmulq_f64(d23, d23));
            acc45 = vaddq_f64(acc45, vmulq_f64(d45, d45));
            acc67 = vaddq_f64(acc67, vmulq_f64(d67, d67));
            i += 8;
        }
        let mut acc = [0.0f64; 8];
        vst1q_f64(acc.as_mut_ptr(), acc01);
        vst1q_f64(acc.as_mut_ptr().add(2), acc23);
        vst1q_f64(acc.as_mut_ptr().add(4), acc45);
        vst1q_f64(acc.as_mut_ptr().add(6), acc67);
        // Ragged tail feeds lanes 0..r, then fold left to right — the
        // exact order of the scalar reference.
        for (k, j) in (i..n).enumerate() {
            let d = (x[j] - y[j]) as f64;
            acc[k] += d * d;
        }
        acc.iter().sum()
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn average_pair(x: &mut [f32], y: &mut [f32]) {
        let n = x.len();
        let vhalf = vdupq_n_f32(0.5);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(x.as_ptr(), i + PF);
            pf(y.as_ptr(), i + PF);
            let a = vld1q_f32(x.as_ptr().add(i));
            let b = vld1q_f32(y.as_ptr().add(i));
            let m = vmulq_f32(vhalf, vaddq_f32(a, b));
            vst1q_f32(x.as_mut_ptr().add(i), m);
            vst1q_f32(y.as_mut_ptr().add(i), m);
            i += LANES;
        }
        scalar::average_pair(&mut x[i..], &mut y[i..]);
    }
}
