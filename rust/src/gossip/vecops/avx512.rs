//! Real AVX-512 backend: 16 f32 lanes per step, runtime-detected.
//!
//! Compiled only when the toolchain is new enough to have stable AVX-512
//! intrinsics (the `a2cid2_avx512` cfg, probed by `build.rs`), and handed
//! out only after [`available`] confirmed `avx512f` at runtime. Selected
//! by `A2CID2_KERNEL_BACKEND=avx512`; `auto` keeps preferring the 256-bit
//! backend (see `simd.rs` for why the 512-bit opt-in is explicit).
//!
//! Bit-identity contract, same as every backend: separate
//! `_mm512_mul_ps` + `_mm512_add_ps` (no FMA contraction), scalar tails
//! on ragged lengths, and the one reduction ([`KernelBackend::sq_dist`])
//! walks 8-element blocks whose eight widened f64 lanes land in ONE
//! `__m512d` accumulator — exactly the scalar reference's fixed
//! `SQ_DIST_LANES`-striped partial sums, folded in the same order.

use super::KernelBackend;

/// The 512-bit backend. Handed out by `super::select_backend` only after
/// [`available`] confirmed `avx512f`, which makes the `unsafe` kernel
/// calls inside sound.
pub(super) struct Avx512Backend;

/// Singleton instance (the dispatch layer deals in `&'static dyn`).
pub(super) static AVX512_BACKEND: Avx512Backend = Avx512Backend;

/// Whether this backend can run on the current CPU.
pub(super) fn available() -> bool {
    std::arch::is_x86_feature_detected!("avx512f")
}

#[allow(clippy::too_many_arguments)]
impl KernelBackend for Avx512Backend {
    fn name(&self) -> &'static str {
        "avx512"
    }

    fn axpy(&self, a: f32, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len());
        unsafe { imp::axpy(a, x, y) }
    }

    fn mix_into(&self, wa: f32, wb: f32, x: &[f32], xt: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), xt.len());
        assert_eq!(x.len(), out.len());
        unsafe { imp::mix_into(wa, wb, x, xt, out) }
    }

    fn grad_step(&self, gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
        assert_eq!(x.len(), xt.len());
        assert_eq!(x.len(), g.len());
        unsafe { imp::grad_step(gamma, g, x, xt) }
    }

    fn comm_only(&self, alpha: f32, alpha_tilde: f32, xj: &[f32], x: &mut [f32], xt: &mut [f32]) {
        assert_eq!(x.len(), xt.len());
        assert_eq!(x.len(), xj.len());
        unsafe { imp::comm_only(alpha, alpha_tilde, xj, x, xt) }
    }

    fn mix_pair(&self, wa: f32, wb: f32, x: &mut [f32], xt: &mut [f32]) {
        assert_eq!(x.len(), xt.len());
        unsafe { imp::mix_pair(wa, wb, x, xt) }
    }

    fn mix_grad(&self, wa: f32, wb: f32, gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
        assert_eq!(x.len(), xt.len());
        assert_eq!(x.len(), g.len());
        unsafe { imp::mix_grad(wa, wb, gamma, g, x, xt) }
    }

    fn comm_apply_fused(
        &self,
        wa: f32,
        wb: f32,
        alpha: f32,
        alpha_tilde: f32,
        xj: &[f32],
        x: &mut [f32],
        xt: &mut [f32],
    ) {
        assert_eq!(x.len(), xt.len());
        assert_eq!(x.len(), xj.len());
        unsafe { imp::comm_apply_fused(wa, wb, alpha, alpha_tilde, xj, x, xt) }
    }

    fn comm_pair_fused(
        &self,
        waa: f32,
        wba: f32,
        wab: f32,
        wbb: f32,
        alpha: f32,
        alpha_tilde: f32,
        xa: &mut [f32],
        xta: &mut [f32],
        xb: &mut [f32],
        xtb: &mut [f32],
    ) {
        assert_eq!(xa.len(), xta.len());
        assert_eq!(xa.len(), xb.len());
        assert_eq!(xa.len(), xtb.len());
        unsafe { imp::comm_pair_fused(waa, wba, wab, wbb, alpha, alpha_tilde, xa, xta, xb, xtb) }
    }

    fn sq_dist(&self, x: &[f32], y: &[f32]) -> f64 {
        assert_eq!(x.len(), y.len());
        unsafe { imp::sq_dist(x, y) }
    }

    fn average_pair(&self, x: &mut [f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len());
        unsafe { imp::average_pair(x, y) }
    }
}

/// AVX-512F: 16 f32 lanes per step. Safety: callers (the trait impl
/// above) guarantee equal slice lengths and that `avx512f` was detected.
mod imp {
    use crate::gossip::vecops::scalar;
    use core::arch::x86_64::*;

    const LANES: usize = 16;

    /// Prefetch distance in elements (1 KiB per f32 stream) — same as
    /// the 256-bit backend (`simd.rs`), where the rationale lives.
    const PF: usize = 256;

    /// Hint-prefetch `p[i]` into L1. `wrapping_add` because the address
    /// may run past the slice near the end of a loop — prefetch never
    /// faults, so an out-of-range hint is merely ignored.
    #[inline(always)]
    unsafe fn pf(p: *const f32, i: usize) {
        _mm_prefetch::<_MM_HINT_T0>(p.wrapping_add(i) as *const i8);
    }

    #[target_feature(enable = "avx512f")]
    pub unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = _mm512_set1_ps(a);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(x.as_ptr(), i + PF);
            pf(y.as_ptr(), i + PF);
            let vx = _mm512_loadu_ps(x.as_ptr().add(i));
            let vy = _mm512_loadu_ps(y.as_ptr().add(i));
            // y + (a·x): separate mul and add — no FMA (bit-identity).
            let r = _mm512_add_ps(vy, _mm512_mul_ps(va, vx));
            _mm512_storeu_ps(y.as_mut_ptr().add(i), r);
            i += LANES;
        }
        scalar::axpy(a, &x[i..], &mut y[i..]);
    }

    #[target_feature(enable = "avx512f")]
    pub unsafe fn mix_into(wa: f32, wb: f32, x: &[f32], xt: &[f32], out: &mut [f32]) {
        let n = x.len();
        let vwa = _mm512_set1_ps(wa);
        let vwb = _mm512_set1_ps(wb);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            pf(out.as_ptr(), i + PF);
            let vx = _mm512_loadu_ps(x.as_ptr().add(i));
            let vt = _mm512_loadu_ps(xt.as_ptr().add(i));
            let r = _mm512_add_ps(_mm512_mul_ps(vwa, vx), _mm512_mul_ps(vwb, vt));
            _mm512_storeu_ps(out.as_mut_ptr().add(i), r);
            i += LANES;
        }
        scalar::mix_into(wa, wb, &x[i..], &xt[i..], &mut out[i..]);
    }

    #[target_feature(enable = "avx512f")]
    pub unsafe fn grad_step(gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
        let n = x.len();
        let va = _mm512_set1_ps(-gamma);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(g.as_ptr(), i + PF);
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let vg = _mm512_loadu_ps(g.as_ptr().add(i));
            let step = _mm512_mul_ps(va, vg);
            let vx = _mm512_loadu_ps(x.as_ptr().add(i));
            let vt = _mm512_loadu_ps(xt.as_ptr().add(i));
            _mm512_storeu_ps(x.as_mut_ptr().add(i), _mm512_add_ps(vx, step));
            _mm512_storeu_ps(xt.as_mut_ptr().add(i), _mm512_add_ps(vt, step));
            i += LANES;
        }
        scalar::grad_step(gamma, &g[i..], &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "avx512f")]
    pub unsafe fn comm_only(
        alpha: f32,
        alpha_tilde: f32,
        xj: &[f32],
        x: &mut [f32],
        xt: &mut [f32],
    ) {
        let n = x.len();
        let va = _mm512_set1_ps(alpha);
        let vat = _mm512_set1_ps(alpha_tilde);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(xj.as_ptr(), i + PF);
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let vx = _mm512_loadu_ps(x.as_ptr().add(i));
            let vt = _mm512_loadu_ps(xt.as_ptr().add(i));
            let vp = _mm512_loadu_ps(xj.as_ptr().add(i));
            let m = _mm512_sub_ps(vx, vp);
            _mm512_storeu_ps(x.as_mut_ptr().add(i), _mm512_sub_ps(vx, _mm512_mul_ps(va, m)));
            _mm512_storeu_ps(xt.as_mut_ptr().add(i), _mm512_sub_ps(vt, _mm512_mul_ps(vat, m)));
            i += LANES;
        }
        scalar::comm_only(alpha, alpha_tilde, &xj[i..], &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "avx512f")]
    pub unsafe fn mix_pair(wa: f32, wb: f32, x: &mut [f32], xt: &mut [f32]) {
        let n = x.len();
        let vwa = _mm512_set1_ps(wa);
        let vwb = _mm512_set1_ps(wb);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let a = _mm512_loadu_ps(x.as_ptr().add(i));
            let b = _mm512_loadu_ps(xt.as_ptr().add(i));
            let rx = _mm512_add_ps(_mm512_mul_ps(vwa, a), _mm512_mul_ps(vwb, b));
            let rt = _mm512_add_ps(_mm512_mul_ps(vwb, a), _mm512_mul_ps(vwa, b));
            _mm512_storeu_ps(x.as_mut_ptr().add(i), rx);
            _mm512_storeu_ps(xt.as_mut_ptr().add(i), rt);
            i += LANES;
        }
        scalar::mix_pair(wa, wb, &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "avx512f")]
    pub unsafe fn mix_grad(
        wa: f32,
        wb: f32,
        gamma: f32,
        g: &[f32],
        x: &mut [f32],
        xt: &mut [f32],
    ) {
        let n = x.len();
        let vwa = _mm512_set1_ps(wa);
        let vwb = _mm512_set1_ps(wb);
        let vg2 = _mm512_set1_ps(gamma);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(g.as_ptr(), i + PF);
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let a = _mm512_loadu_ps(x.as_ptr().add(i));
            let b = _mm512_loadu_ps(xt.as_ptr().add(i));
            let vg = _mm512_loadu_ps(g.as_ptr().add(i));
            let step = _mm512_mul_ps(vg2, vg);
            let rx = _mm512_sub_ps(
                _mm512_add_ps(_mm512_mul_ps(vwa, a), _mm512_mul_ps(vwb, b)),
                step,
            );
            let rt = _mm512_sub_ps(
                _mm512_add_ps(_mm512_mul_ps(vwb, a), _mm512_mul_ps(vwa, b)),
                step,
            );
            _mm512_storeu_ps(x.as_mut_ptr().add(i), rx);
            _mm512_storeu_ps(xt.as_mut_ptr().add(i), rt);
            i += LANES;
        }
        scalar::mix_grad(wa, wb, gamma, &g[i..], &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "avx512f")]
    pub unsafe fn comm_apply_fused(
        wa: f32,
        wb: f32,
        alpha: f32,
        alpha_tilde: f32,
        xj: &[f32],
        x: &mut [f32],
        xt: &mut [f32],
    ) {
        let n = x.len();
        let vwa = _mm512_set1_ps(wa);
        let vwb = _mm512_set1_ps(wb);
        let va = _mm512_set1_ps(alpha);
        let vat = _mm512_set1_ps(alpha_tilde);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(xj.as_ptr(), i + PF);
            pf(x.as_ptr(), i + PF);
            pf(xt.as_ptr(), i + PF);
            let a = _mm512_loadu_ps(x.as_ptr().add(i));
            let b = _mm512_loadu_ps(xt.as_ptr().add(i));
            let vp = _mm512_loadu_ps(xj.as_ptr().add(i));
            let mixed_x = _mm512_add_ps(_mm512_mul_ps(vwa, a), _mm512_mul_ps(vwb, b));
            let mixed_t = _mm512_add_ps(_mm512_mul_ps(vwb, a), _mm512_mul_ps(vwa, b));
            let m = _mm512_sub_ps(mixed_x, vp);
            let rx = _mm512_sub_ps(mixed_x, _mm512_mul_ps(va, m));
            let rt = _mm512_sub_ps(mixed_t, _mm512_mul_ps(vat, m));
            _mm512_storeu_ps(x.as_mut_ptr().add(i), rx);
            _mm512_storeu_ps(xt.as_mut_ptr().add(i), rt);
            i += LANES;
        }
        scalar::comm_apply_fused(wa, wb, alpha, alpha_tilde, &xj[i..], &mut x[i..], &mut xt[i..]);
    }

    #[target_feature(enable = "avx512f")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn comm_pair_fused(
        waa: f32,
        wba: f32,
        wab: f32,
        wbb: f32,
        alpha: f32,
        alpha_tilde: f32,
        xa: &mut [f32],
        xta: &mut [f32],
        xb: &mut [f32],
        xtb: &mut [f32],
    ) {
        let n = xa.len();
        let vwaa = _mm512_set1_ps(waa);
        let vwba = _mm512_set1_ps(wba);
        let vwab = _mm512_set1_ps(wab);
        let vwbb = _mm512_set1_ps(wbb);
        let va = _mm512_set1_ps(alpha);
        let vat = _mm512_set1_ps(alpha_tilde);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(xa.as_ptr(), i + PF);
            pf(xta.as_ptr(), i + PF);
            pf(xb.as_ptr(), i + PF);
            pf(xtb.as_ptr(), i + PF);
            let a = _mm512_loadu_ps(xa.as_ptr().add(i));
            let ta = _mm512_loadu_ps(xta.as_ptr().add(i));
            let b = _mm512_loadu_ps(xb.as_ptr().add(i));
            let tb = _mm512_loadu_ps(xtb.as_ptr().add(i));
            let ma = _mm512_add_ps(_mm512_mul_ps(vwaa, a), _mm512_mul_ps(vwba, ta));
            let mta = _mm512_add_ps(_mm512_mul_ps(vwba, a), _mm512_mul_ps(vwaa, ta));
            let mb = _mm512_add_ps(_mm512_mul_ps(vwab, b), _mm512_mul_ps(vwbb, tb));
            let mtb = _mm512_add_ps(_mm512_mul_ps(vwbb, b), _mm512_mul_ps(vwab, tb));
            let m = _mm512_sub_ps(ma, mb);
            _mm512_storeu_ps(xa.as_mut_ptr().add(i), _mm512_sub_ps(ma, _mm512_mul_ps(va, m)));
            _mm512_storeu_ps(
                xta.as_mut_ptr().add(i),
                _mm512_sub_ps(mta, _mm512_mul_ps(vat, m)),
            );
            _mm512_storeu_ps(xb.as_mut_ptr().add(i), _mm512_add_ps(mb, _mm512_mul_ps(va, m)));
            _mm512_storeu_ps(
                xtb.as_mut_ptr().add(i),
                _mm512_add_ps(mtb, _mm512_mul_ps(vat, m)),
            );
            i += LANES;
        }
        scalar::comm_pair_fused(
            waa,
            wba,
            wab,
            wbb,
            alpha,
            alpha_tilde,
            &mut xa[i..],
            &mut xta[i..],
            &mut xb[i..],
            &mut xtb[i..],
        );
    }

    /// 8-element blocks (NOT 16): the stripe layout is fixed at
    /// `SQ_DIST_LANES = 8` f64 lanes, which is exactly one `__m512d` —
    /// lane `k` of the accumulator is the scalar reference's `acc[k]`.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn sq_dist(x: &[f32], y: &[f32]) -> f64 {
        let n = x.len();
        let mut vacc = _mm512_setzero_pd();
        let mut i = 0usize;
        while i + 8 <= n {
            pf(x.as_ptr(), i + PF);
            pf(y.as_ptr(), i + PF);
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            // Difference in f32 (one rounding, same as scalar), THEN
            // widen to f64 and square exactly.
            let d = _mm512_cvtps_pd(_mm256_sub_ps(vx, vy));
            vacc = _mm512_add_pd(vacc, _mm512_mul_pd(d, d));
            i += 8;
        }
        let mut acc = [0.0f64; 8];
        _mm512_storeu_pd(acc.as_mut_ptr(), vacc);
        for (k, j) in (i..n).enumerate() {
            let d = (x[j] - y[j]) as f64;
            acc[k] += d * d;
        }
        acc.iter().sum()
    }

    #[target_feature(enable = "avx512f")]
    pub unsafe fn average_pair(x: &mut [f32], y: &mut [f32]) {
        let n = x.len();
        let vhalf = _mm512_set1_ps(0.5);
        let mut i = 0usize;
        while i + LANES <= n {
            pf(x.as_ptr(), i + PF);
            pf(y.as_ptr(), i + PF);
            let a = _mm512_loadu_ps(x.as_ptr().add(i));
            let b = _mm512_loadu_ps(y.as_ptr().add(i));
            let m = _mm512_mul_ps(vhalf, _mm512_add_ps(a, b));
            _mm512_storeu_ps(x.as_mut_ptr().add(i), m);
            _mm512_storeu_ps(y.as_mut_ptr().add(i), m);
            i += LANES;
        }
        scalar::average_pair(&mut x[i..], &mut y[i..]);
    }
}
