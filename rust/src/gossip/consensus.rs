//! Consensus-distance tracking (Fig. 5b / Kong et al.'s consensus control).
//!
//! The consensus distance is `‖πx‖²_F = Σᵢ ‖xᵢ − x̄‖²` where
//! `x̄ = (1/n)Σᵢ xᵢ`. The paper uses it to show that A²CiD² halves the
//! effective consensus error on the ring — equivalent to doubling the
//! communication rate.

use super::dynamics::WorkerState;

/// `Σᵢ ‖xᵢ − x̄‖²` over the workers' parameter rows.
pub fn consensus_distance_sq(workers: &[WorkerState]) -> f64 {
    consensus_of(workers.iter().map(|w| w.x.as_slice()))
}

/// Root-mean-square consensus distance `√(‖πx‖²_F / n)` — the per-worker
/// deviation scale reported in the figures.
pub fn consensus_distance(workers: &[WorkerState]) -> f64 {
    (consensus_distance_sq(workers) / workers.len() as f64).sqrt()
}

/// Consensus of arbitrary parameter rows (also used by the runtime, where
/// rows live behind locks and are snapshotted first).
pub fn consensus_of<'a>(rows: impl Iterator<Item = &'a [f32]> + Clone) -> f64 {
    let n = rows.clone().count();
    if n == 0 {
        return 0.0;
    }
    let dim = rows.clone().next().unwrap().len();
    let mut mean = vec![0.0f64; dim];
    for row in rows.clone() {
        assert_eq!(row.len(), dim, "ragged parameter rows");
        for (m, &v) in mean.iter_mut().zip(row) {
            *m += v as f64;
        }
    }
    for m in &mut mean {
        *m /= n as f64;
    }
    let mut acc = 0.0f64;
    for row in rows {
        for (&m, &v) in mean.iter().zip(row) {
            let d = v as f64 - m;
            acc += d * d;
        }
    }
    acc
}

/// Average of all workers' parameters (the `x̄` a final All-Reduce would
/// produce; the paper averages once before testing).
pub fn average_params(workers: &[WorkerState]) -> Vec<f32> {
    assert!(!workers.is_empty());
    let dim = workers[0].dim();
    let mut mean = vec![0.0f64; dim];
    for w in workers {
        for (m, &v) in mean.iter_mut().zip(&w.x) {
            *m += v as f64;
        }
    }
    let n = workers.len() as f64;
    mean.iter().map(|&m| (m / n) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_when_identical() {
        let ws = vec![
            WorkerState::new(vec![1.0, 2.0]),
            WorkerState::new(vec![1.0, 2.0]),
        ];
        assert_eq!(consensus_distance_sq(&ws), 0.0);
    }

    #[test]
    fn known_value() {
        // x₁=(0,0), x₂=(2,2) ⇒ x̄=(1,1), Σ‖xᵢ−x̄‖² = 2 + 2 = 4.
        let ws = vec![
            WorkerState::new(vec![0.0, 0.0]),
            WorkerState::new(vec![2.0, 2.0]),
        ];
        assert!((consensus_distance_sq(&ws) - 4.0).abs() < 1e-9);
        assert!((consensus_distance(&ws) - (2.0f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn invariant_under_common_shift() {
        let mut ws = vec![
            WorkerState::new(vec![0.5, -1.0]),
            WorkerState::new(vec![1.5, 3.0]),
            WorkerState::new(vec![-2.0, 0.0]),
        ];
        let before = consensus_distance_sq(&ws);
        for w in &mut ws {
            for v in &mut w.x {
                *v += 10.0;
            }
        }
        let after = consensus_distance_sq(&ws);
        assert!((before - after).abs() < 1e-6);
    }

    #[test]
    fn average_params_is_mean() {
        let ws = vec![
            WorkerState::new(vec![0.0, 4.0]),
            WorkerState::new(vec![2.0, 0.0]),
        ];
        assert_eq!(average_params(&ws), vec![1.0, 2.0]);
    }
}
