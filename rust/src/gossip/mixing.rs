//! The continuous momentum operator of A²CiD².
//!
//! Between any two events a worker's `(x, x̃)` pair evolves by the linear
//! ODE `d(x,x̃)/dt = A·(x,x̃)` with `A = [[−η, η], [η, −η]]` (the "mixing
//! ODE" of Sec. 3.2). Its flow has the closed form
//!
//! ```text
//! exp(Δt·A) = [[ (1+c)/2, (1−c)/2 ],
//!              [ (1−c)/2, (1+c)/2 ]],   c = exp(−2·η·Δt),
//! ```
//!
//! a doubly-stochastic 2×2 matrix: mass `x + x̃` is conserved and the pair
//! relaxes toward its own average at rate 2η. Algorithm 1 applies this
//! flow lazily — right before every gradient or communication update —
//! which is what [`Mixer::weights`] computes.

/// Precomputed mixing coefficients for one worker.
#[derive(Clone, Copy, Debug)]
pub struct Mixer {
    /// Momentum rate η (0 disables mixing entirely).
    pub eta: f64,
}

/// The pair of mixing weights `(wa, wb)`; `x' = wa·x + wb·x̃`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MixWeights {
    pub wa: f32,
    pub wb: f32,
}

impl Mixer {
    pub fn new(eta: f64) -> Self {
        assert!(eta >= 0.0, "eta must be non-negative, got {eta}");
        Self { eta }
    }

    /// Mixing weights for an elapsed time `dt` since the worker's last
    /// event. `eta = 0` or `dt = 0` yields the identity `(1, 0)`.
    #[inline]
    pub fn weights(&self, dt: f64) -> MixWeights {
        debug_assert!(dt >= -1e-9, "negative elapsed time {dt}");
        if self.eta == 0.0 || dt <= 0.0 {
            return MixWeights { wa: 1.0, wb: 0.0 };
        }
        let c = (-2.0 * self.eta * dt).exp();
        MixWeights { wa: (0.5 * (1.0 + c)) as f32, wb: (0.5 * (1.0 - c)) as f32 }
    }

    /// Apply the flow for `dt` to a single scalar pair (used in tests and
    /// the 2-worker analytical checks).
    pub fn apply_scalar(&self, dt: f64, x: f64, xt: f64) -> (f64, f64) {
        let w = self.weights(dt);
        (
            w.wa as f64 * x + w.wb as f64 * xt,
            w.wb as f64 * x + w.wa as f64 * xt,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_at_zero_dt_or_zero_eta() {
        assert_eq!(Mixer::new(0.0).weights(5.0), MixWeights { wa: 1.0, wb: 0.0 });
        assert_eq!(Mixer::new(3.0).weights(0.0), MixWeights { wa: 1.0, wb: 0.0 });
    }

    #[test]
    fn weights_are_doubly_stochastic() {
        let m = Mixer::new(0.7);
        for &dt in &[0.01, 0.1, 1.0, 10.0, 1000.0] {
            let w = m.weights(dt);
            assert!((w.wa + w.wb - 1.0).abs() < 1e-6);
            assert!(w.wa >= 0.0 && w.wb >= 0.0);
            assert!(w.wa >= 0.5 - 1e-6, "wa >= 1/2 always");
        }
    }

    #[test]
    fn long_time_limit_is_average() {
        // As Δt → ∞, both components converge to (x + x̃)/2.
        let m = Mixer::new(1.0);
        let (x, xt) = m.apply_scalar(100.0, 2.0, 4.0);
        assert!((x - 3.0).abs() < 1e-6);
        assert!((xt - 3.0).abs() < 1e-6);
    }

    #[test]
    fn semigroup_property() {
        // exp((s+t)A) = exp(sA)·exp(tA): applying the flow in two steps
        // must equal one step of the total time.
        let m = Mixer::new(0.37);
        let (x0, t0) = (1.5, -2.5);
        let (x1, t1) = m.apply_scalar(0.4, x0, t0);
        let (x2, t2) = m.apply_scalar(0.9, x1, t1);
        // Weights are f32 (the production precision), so compare at 1e-6.
        let (xd, td) = m.apply_scalar(1.3, x0, t0);
        assert!((x2 - xd).abs() < 1e-6, "{x2} vs {xd}");
        assert!((t2 - td).abs() < 1e-6);
    }

    #[test]
    fn mass_conserved() {
        let m = Mixer::new(2.0);
        let (x, xt) = m.apply_scalar(0.123, 7.0, -3.0);
        assert!((x + xt - 4.0).abs() < 1e-6);
    }

    #[test]
    fn relaxation_rate_matches_2eta() {
        // x − x̃ decays exactly like exp(−2ηΔt).
        let eta = 0.8;
        let m = Mixer::new(eta);
        let dt = 0.65;
        let (x, xt) = m.apply_scalar(dt, 1.0, 0.0);
        let expect = (-2.0 * eta * dt).exp();
        assert!(((x - xt) - expect).abs() < 1e-6);
    }
}
