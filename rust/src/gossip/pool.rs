//! Deterministic chunked kernel pool for the large-`dim` hot path.
//!
//! The fused kernels in [`super::vecops`] are memory-bound single passes;
//! past ~64k elements one core can no longer saturate DRAM, so the mixing
//! kernels shard across a small persistent thread pool. Two properties
//! the engines rely on:
//!
//! * **Bit-determinism.** Every kernel here is element-wise (no
//!   cross-element reduction), and the shard boundaries are *fixed*
//!   ([`CHUNK`]-element chunks, independent of thread count or schedule),
//!   so the pooled result is bit-identical to the single-thread result —
//!   `deterministic_given_seed` and the scenario replay guarantees hold
//!   with the pool enabled.
//! * **Zero allocation.** Jobs borrow the caller's slices; the pool hands
//!   out chunk indices through one atomic cursor. Nothing is boxed per
//!   call.
//!
//! The pool is hand-rolled on `std::thread` (nothing heavier is available
//! offline): workers park on a condvar between jobs, and chunk claims go
//! through an epoch-tagged compare-exchange so a straggler from a
//! finished job can never claim (or run) a chunk of the next one.
//!
//! **Memory locality.** Lanes have stable identities: chunk `i` belongs
//! to lane `i % width` (the caller is lane 0) and each lane drains its
//! own range before stealing from the others — claims stay epoch-CAS'd,
//! so stealing is race-free and, because chunk *boundaries* are fixed,
//! claim order is provably irrelevant to the result bits. Under the
//! `A2CID2_PIN` policy ([`crate::locality::pin_lanes`]) worker lanes pin
//! themselves to distinct cores, spread round-robin across NUMA nodes;
//! under `A2CID2_NUMA` ([`crate::locality::numa_first_touch`]) large
//! [`AlignedVec`] buffers are first-touch-zeroed chunk-by-chunk by their
//! sticky owner lanes, so each page lands on the node of the core that
//! will stream it on every later kernel call. Both default to `auto`
//! (engage only on multi-node hosts) and degrade to today's behavior
//! when off — none of it changes a single arithmetic operation.
//!
//! Both engines reach this module through the same call chain —
//! [`crate::engine::DynamicsCore`] → [`super::dynamics`] → the wrappers
//! below — so the simulator and the threaded runtime shard identically.
//! The wrappers fall back to the plain kernels below [`POOL_MIN_DIM`]
//! (fork/join overhead would dominate) and, via
//! [`ChunkPool::try_run`], whenever another thread currently owns the
//! pool — a runtime worker holding its cell's state mutex degrades to
//! the serial kernel instead of queueing behind other workers' jobs
//! (bit-identical either way, so the timing-dependent choice cannot
//! break determinism; the single-threaded simulator always gets the
//! pool). Kernels must never re-enter the pool from inside a chunk task
//! (jobs are serialized on one slot).

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

use super::vecops;

/// Fixed shard width in elements (256 KiB of f32): large enough that the
/// per-chunk dispatch cost is noise, small enough that a 4M-element
/// vector yields 64-way parallelism.
pub const CHUNK: usize = 1 << 16;

/// Below this length the wrappers run the plain single-thread kernel —
/// with fewer than two chunks there is nothing to shard.
pub const POOL_MIN_DIM: usize = 2 * CHUNK;

const IDX_MASK: u64 = 0xFFFF_FFFF;

/// Raw pointer to the caller's borrowed task closure. Deliberately NOT a
/// reference: a slow-waking worker may still hold this value after the
/// job completed and the caller's frame died, and materializing a
/// dangling `&dyn Fn` (even if never called) would be UB. A reference is
/// only reconstituted AFTER a successful epoch-tagged chunk claim, which
/// proves the owning [`ChunkPool::run`] frame is still blocked alive.
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn Fn(usize) + Sync));

unsafe impl Send for TaskPtr {}
unsafe impl Sync for TaskPtr {}

/// The job slot: one job at a time, published under the mutex.
struct Job {
    /// Bumped once per job; workers use it to detect fresh work and the
    /// cursor tags chunk claims with it.
    epoch: u32,
    n_chunks: u32,
    task: Option<TaskPtr>,
}

struct Shared {
    job: Mutex<Job>,
    /// Workers park here between jobs.
    start: Condvar,
    /// The caller parks here until `remaining` drains.
    done: Condvar,
    /// One claim cursor per lane: `cursors[l]` holds
    /// `(epoch << 32) | k`, where lane `l`'s k-th own chunk is chunk
    /// `l + k·width`. Claims are CAS increments, so a claim can only
    /// succeed against the epoch it was read for; striding by `width`
    /// keeps every chunk owned by exactly one cursor, so "claimed
    /// exactly once" still follows from per-cursor monotonicity.
    cursors: Vec<AtomicU64>,
    /// Rotation applied to the claim scan: lane `l` starts draining the
    /// range of lane `(l + offset) % width`. 0 (the default) is the
    /// sticky policy; tests and the cross-NUMA counterfactual bench set
    /// it nonzero to force every lane onto a remote lane's range.
    claim_offset: AtomicUsize,
    /// Chunks claimed but not yet finished + chunks not yet claimed.
    remaining: AtomicU64,
    /// A chunk task panicked during the current job; the caller
    /// re-raises after the job drains.
    panicked: AtomicBool,
    shutdown: AtomicBool,
}

impl Shared {
    /// Claim-and-run loop shared by workers and the calling thread.
    ///
    /// Panic-safe: a panicking task is caught so `remaining` always
    /// drains (a hung caller would otherwise deadlock every future job)
    /// and pool workers survive; the flag makes [`ChunkPool::run`]
    /// re-raise on the calling thread once the job is fully drained —
    /// which also guarantees no worker still touches the caller's
    /// borrowed slices when the panic unwinds its frame.
    /// Sticky claiming: `lane` drains its own chunk range (chunks
    /// `lane, lane + width, lane + 2·width, …`) to exhaustion first,
    /// then steals from the other lanes' ranges in scan order. A lane's
    /// range never refills within a job (its cursor only grows), so one
    /// pass over all `width` cursors suffices — after it, every chunk
    /// of this epoch has been claimed by somebody.
    fn work(&self, lane: usize, epoch: u32, n_chunks: u32, task: TaskPtr) {
        let width = self.cursors.len();
        let offset = self.claim_offset.load(Ordering::Relaxed);
        for s in 0..width {
            let m = (lane + offset + s) % width;
            let cur = &self.cursors[m];
            loop {
                let c = cur.load(Ordering::SeqCst);
                if (c >> 32) as u32 != epoch {
                    return; // a newer job took the slot; we never claimed
                }
                let k = (c & IDX_MASK) as usize;
                let chunk = m + k * width;
                if chunk >= n_chunks as usize {
                    break; // lane m's range is drained; move to the next
                }
                if cur
                    .compare_exchange(c, c + 1, Ordering::SeqCst, Ordering::SeqCst)
                    .is_err()
                {
                    continue;
                }
                // SAFETY: the successful same-epoch claim above proves the
                // owning `run` frame is still parked in its drain loop (it
                // cannot return while this claimed chunk's `remaining`
                // decrement is outstanding), so the pointee is alive.
                let task_ref: &(dyn Fn(usize) + Sync) = unsafe { &*task.0 };
                let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    task_ref(chunk)
                }));
                if ok.is_err() {
                    self.panicked.store(true, Ordering::SeqCst);
                }
                if self.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
                    // Last chunk of the job: wake the caller. Taking the
                    // job mutex pairs with the caller's check-then-wait.
                    let _g = self.job.lock().unwrap();
                    self.done.notify_all();
                }
            }
        }
    }
}

/// A small persistent worker pool that fans fixed-boundary chunks of one
/// job out across threads. See the module docs for the guarantees.
pub struct ChunkPool {
    shared: std::sync::Arc<Shared>,
    /// Serializes callers: one job owns the slot at a time.
    caller: Mutex<()>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ChunkPool {
    /// Build a pool with `extra_threads` workers; the calling thread
    /// always participates, so total parallelism is `extra_threads + 1`.
    /// Lanes pin themselves to cores when the `A2CID2_PIN` policy says
    /// so ([`crate::locality::pin_lanes`]).
    pub fn new(extra_threads: usize) -> Self {
        Self::new_with_pinning(extra_threads, crate::locality::pin_lanes())
    }

    /// As [`ChunkPool::new`], with pinning decided by the caller instead
    /// of the env policy — the locality bench and tests build pinned and
    /// unpinned pools side by side in one process. Worker lane `l`
    /// (`1 ..= extra_threads`) pins to
    /// [`cpu_for_slot(l)`](crate::locality::Topology::cpu_for_slot),
    /// spreading lanes round-robin across NUMA nodes; lane 0 is whatever
    /// thread calls [`run`](Self::run) and is never pinned here. A
    /// failed pin warns once and the lane runs unpinned — placement is
    /// best-effort, correctness never depends on it.
    pub fn new_with_pinning(extra_threads: usize, pin: bool) -> Self {
        let width = extra_threads + 1;
        let shared = std::sync::Arc::new(Shared {
            job: Mutex::new(Job { epoch: 0, n_chunks: 0, task: None }),
            start: Condvar::new(),
            done: Condvar::new(),
            cursors: (0..width).map(|_| AtomicU64::new(0)).collect(),
            claim_offset: AtomicUsize::new(0),
            remaining: AtomicU64::new(0),
            panicked: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
        });
        let topo = crate::locality::topology();
        let threads = (0..extra_threads)
            .map(|i| {
                let lane = i + 1;
                let cpu = if pin { topo.cpu_for_slot(lane) } else { None };
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("a2cid2-pool-{i}"))
                    .spawn(move || {
                        if let Some(c) = cpu {
                            crate::locality::pin_current_thread(c);
                        }
                        worker_loop(&shared, lane)
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, caller: Mutex::new(()), threads }
    }

    /// The process-wide pool the kernel wrappers shard across: one worker
    /// per available core beyond the caller's, capped small (the kernels
    /// are memory-bound; a handful of streams saturates DRAM). Threads
    /// spawn lazily on the first large-`dim` kernel call.
    pub fn global() -> &'static ChunkPool {
        static GLOBAL: OnceLock<ChunkPool> = OnceLock::new();
        GLOBAL.get_or_init(|| ChunkPool::new(configured_extra_threads()))
    }

    /// Total parallel lanes (workers + the calling thread).
    pub fn lanes(&self) -> usize {
        self.threads.len() + 1
    }

    /// Rotate the claim scan: lane `l` drains lane `(l + offset) % width`'s
    /// chunk range first instead of its own. Results are bit-identical at
    /// any offset (fixed chunk boundaries make claim order irrelevant) —
    /// this exists so the regression tests can prove it and so the bench
    /// can measure the cross-NUMA-touch counterfactual, where every
    /// pinned lane deliberately streams a remote lane's first-touched
    /// pages. Takes effect on the next job.
    pub fn set_claim_offset(&self, offset: usize) {
        self.shared.claim_offset.store(offset, Ordering::Relaxed);
    }

    /// Run `task(chunk)` for every `chunk in 0..n_chunks`, returning once
    /// all chunks completed. The caller participates; workers join in.
    /// Blocks if another caller currently owns the job slot. `task` must
    /// be safe to call concurrently for distinct chunks and must not
    /// re-enter the pool.
    pub fn run(&self, n_chunks: usize, task: &(dyn Fn(usize) + Sync)) {
        if n_chunks <= 1 || self.threads.is_empty() {
            for c in 0..n_chunks {
                task(c);
            }
            return;
        }
        let guard = self.caller.lock().unwrap();
        self.run_owned(guard, n_chunks, task);
    }

    /// As [`ChunkPool::run`], but if another caller owns the job slot,
    /// returns `false` immediately WITHOUT running anything — the caller
    /// should fall back to its serial kernel instead of queueing. This is
    /// what the kernel wrappers use: a runtime worker holding its cell's
    /// state mutex must never park behind other workers' pool jobs
    /// (element-wise kernels are bit-identical either way, so the
    /// timing-dependent choice cannot break determinism).
    pub fn try_run(&self, n_chunks: usize, task: &(dyn Fn(usize) + Sync)) -> bool {
        if n_chunks <= 1 || self.threads.is_empty() {
            for c in 0..n_chunks {
                task(c);
            }
            return true;
        }
        match self.caller.try_lock() {
            Ok(guard) => {
                self.run_owned(guard, n_chunks, task);
                true
            }
            Err(std::sync::TryLockError::WouldBlock) => false,
            Err(std::sync::TryLockError::Poisoned(e)) => {
                self.run_owned(e.into_inner(), n_chunks, task);
                true
            }
        }
    }

    /// The job body, entered with the caller slot owned.
    fn run_owned(
        &self,
        serial: std::sync::MutexGuard<'_, ()>,
        n_chunks: usize,
        task: &(dyn Fn(usize) + Sync),
    ) {
        let panicked = {
            let _serial = serial;
            // A raw pointer, not a lifetime-erased reference — see
            // [`TaskPtr`]. Sound because this frame blocks until
            // `remaining` drains, and claims against a finished job are
            // rejected by the epoch-tagged CAS.
            let tp = TaskPtr(task as *const (dyn Fn(usize) + Sync));
            let (epoch, n) = {
                let mut g = self.shared.job.lock().unwrap();
                g.epoch = g.epoch.wrapping_add(1);
                g.n_chunks = n_chunks as u32;
                g.task = Some(tp);
                self.shared.remaining.store(n_chunks as u64, Ordering::SeqCst);
                for cur in &self.shared.cursors {
                    cur.store((g.epoch as u64) << 32, Ordering::SeqCst);
                }
                self.shared.start.notify_all();
                (g.epoch, g.n_chunks)
            };
            // The caller participates as lane 0.
            self.shared.work(0, epoch, n, tp);
            {
                let mut g = self.shared.job.lock().unwrap();
                while self.shared.remaining.load(Ordering::SeqCst) > 0 {
                    g = self.shared.done.wait(g).unwrap();
                }
                g.task = None;
            }
            // Re-raise OUTSIDE the caller lock's scope, or the unwind
            // would poison it and wedge every future job.
            self.shared.panicked.swap(false, Ordering::SeqCst)
        };
        if panicked {
            panic!("a chunk-pool task panicked (re-raised on the calling thread)");
        }
    }
}

/// Extra worker threads the `A2CID2_POOL_THREADS` policy prescribes —
/// the sizing [`ChunkPool::global`] uses.
/// `A2CID2_POOL_THREADS=1` means fully serial (zero extra threads);
/// unset falls back to available cores, capped small (the kernels are
/// memory-bound; a handful of streams saturates DRAM). CI's determinism
/// job runs the same seeded scenario at two widths and diffs the traces
/// — the fixed chunk boundaries must make the width unobservable.
pub fn configured_extra_threads() -> usize {
    extra_threads_for(crate::config::env::knobs().pool_threads)
}

/// Extra worker threads for the multiplexed engine's private tick pool:
/// `A2CID2_MUX_THREADS`, falling back to `A2CID2_POOL_THREADS` (for
/// years one knob sized both pools; setting only the shared knob keeps
/// that meaning), then to available cores. The two pools really are
/// independent — the mux pool shards *ticks*, the global pool shards
/// *elements* — so a wide kernel pool with a narrow tick pool is a
/// legitimate shape on a shared host.
pub fn configured_mux_extra_threads() -> usize {
    let k = crate::config::env::knobs();
    extra_threads_for(k.mux_threads.or(k.pool_threads))
}

fn extra_threads_for(lanes: Option<usize>) -> usize {
    match lanes {
        Some(n) => (n - 1).min(7),
        None => {
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            cores.saturating_sub(1).min(7)
        }
    }
}

impl Drop for ChunkPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        {
            let _g = self.shared.job.lock().unwrap();
            self.shared.start.notify_all();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl std::fmt::Debug for ChunkPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ChunkPool").field("lanes", &self.lanes()).finish()
    }
}

fn worker_loop(shared: &Shared, lane: usize) {
    let mut seen_epoch: u32 = 0;
    loop {
        let (epoch, n_chunks, task) = {
            let mut g = shared.job.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if g.epoch != seen_epoch {
                    if let Some(t) = g.task {
                        break (g.epoch, g.n_chunks, t);
                    }
                }
                g = shared.start.wait(g).unwrap();
            }
        };
        seen_epoch = epoch;
        shared.work(lane, epoch, n_chunks, task);
    }
}

/// Number of fixed-width chunks covering `len` elements.
fn n_chunks(len: usize) -> usize {
    len.div_ceil(CHUNK)
}

/// The fixed bounds of chunk `c` — a pure function of `(len, c)`, never
/// of the thread count, which is what makes pooled results deterministic.
fn chunk_bounds(len: usize, c: usize) -> (usize, usize) {
    let lo = c * CHUNK;
    (lo, (lo + CHUNK).min(len))
}

/// Host page size the chunk buffers align to.
pub const PAGE: usize = 4096;

/// A fixed-length f32 buffer whose backing allocation is page-aligned
/// (4 KiB) once it spans at least one page. [`CHUNK`] elements are
/// 256 KiB — a whole multiple of the page — so with an aligned base
/// every fixed chunk boundary the pool shards on lands exactly on a page
/// boundary: no two pool lanes ever touch the same page of a state
/// buffer (the NUMA/false-sharing prep carried in the ROADMAP).
/// Sub-page buffers keep f32's natural alignment — a 4 KiB floor would
/// multiply the footprint of 10⁵-worker fleets ~100×, and nothing
/// shards below [`POOL_MIN_DIM`] anyway.
///
/// Derefs to `[f32]`, so it drops into every kernel signature; contents
/// are bit-identical to the `Vec<f32>` it replaces (alignment moves the
/// allocation, never the values — the regression test pins this).
pub struct AlignedVec {
    ptr: std::ptr::NonNull<f32>,
    len: usize,
}

// SAFETY: AlignedVec uniquely owns its allocation, exactly like Vec.
unsafe impl Send for AlignedVec {}
unsafe impl Sync for AlignedVec {}

impl AlignedVec {
    fn layout(len: usize) -> std::alloc::Layout {
        let bytes = len * std::mem::size_of::<f32>();
        let align =
            if bytes >= PAGE { PAGE } else { std::mem::align_of::<f32>() };
        std::alloc::Layout::from_size_align(bytes, align).expect("valid f32 buffer layout")
    }

    /// Allocate a zeroed buffer of `len` elements.
    ///
    /// Under the `A2CID2_NUMA` first-touch policy
    /// ([`crate::locality::numa_first_touch`]), pool-scale buffers are
    /// zero-touched chunk-by-chunk by their sticky owner lanes on the
    /// global pool, so each page lands on the NUMA node of the core
    /// that will stream it on every later kernel call (Linux places a
    /// page on the node of the thread that first writes it). With the
    /// policy off — or below pool scale — this is a plain zeroed
    /// allocation touched by whoever writes first, exactly as before.
    pub fn zeroed(len: usize) -> Self {
        if len >= POOL_MIN_DIM && crate::locality::numa_first_touch() {
            return Self::zeroed_on(ChunkPool::global(), len);
        }
        Self::zeroed_serial(len)
    }

    /// First-touch a pool-scale buffer on an explicit pool, regardless
    /// of the env policy — the locality bench and tests place buffers on
    /// pools they built themselves. Falls back to the serial path below
    /// pool scale or when `pool` is busy ([`ChunkPool::try_run`] — a
    /// rejoining worker cloning state mid-job must not deadlock).
    pub fn zeroed_on(pool: &ChunkPool, len: usize) -> Self {
        if len < POOL_MIN_DIM {
            return Self::zeroed_serial(len);
        }
        let layout = Self::layout(len);
        // SAFETY: layout has non-zero size; the memory stays logically
        // uninitialized until every chunk below has been `write_bytes`'d
        // — only raw pointers touch it until then, never a slice.
        let raw = unsafe { std::alloc::alloc(layout) } as *mut f32;
        let Some(ptr) = std::ptr::NonNull::new(raw) else {
            std::alloc::handle_alloc_error(layout)
        };
        #[derive(Clone, Copy)]
        struct RawMut(*mut f32);
        // SAFETY: distinct chunks write disjoint ranges of one live
        // allocation, same argument as `Span`.
        unsafe impl Send for RawMut {}
        unsafe impl Sync for RawMut {}
        let base = RawMut(ptr.as_ptr());
        let pooled = pool.try_run(n_chunks(len), &|c| {
            let (lo, hi) = chunk_bounds(len, c);
            // SAFETY: in-bounds disjoint range of the allocation above.
            unsafe { std::ptr::write_bytes(base.0.add(lo), 0, hi - lo) };
        });
        if !pooled {
            // SAFETY: whole allocation, exclusively owned.
            unsafe { std::ptr::write_bytes(ptr.as_ptr(), 0, len) };
        }
        Self { ptr, len }
    }

    /// The pre-locality allocation path: zeroed by the allocator, pages
    /// placed wherever the first writer runs.
    fn zeroed_serial(len: usize) -> Self {
        if len == 0 {
            return Self { ptr: std::ptr::NonNull::dangling(), len: 0 };
        }
        let layout = Self::layout(len);
        // SAFETY: layout has non-zero size (len > 0); all-zero bytes are
        // a valid f32 pattern (+0.0).
        let raw = unsafe { std::alloc::alloc_zeroed(layout) } as *mut f32;
        let Some(ptr) = std::ptr::NonNull::new(raw) else {
            std::alloc::handle_alloc_error(layout)
        };
        Self { ptr, len }
    }

    /// Allocate and copy `src` into an aligned buffer. The copy itself
    /// is pool-sharded at pool scale ([`copy`]), so under first-touch
    /// the same sticky lanes that placed each chunk's pages also stream
    /// the bytes in.
    pub fn from_slice(src: &[f32]) -> Self {
        let mut buf = Self::zeroed(src.len());
        copy(src, buf.as_mut_slice());
        buf
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[f32] {
        // SAFETY: ptr covers len initialized elements (or is dangling
        // with len 0, for which from_raw_parts is defined).
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        // SAFETY: as above, plus &mut self gives exclusive access.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl Drop for AlignedVec {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: allocated in `zeroed` with this exact layout.
            unsafe { std::alloc::dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.len)) }
        }
    }
}

impl Clone for AlignedVec {
    fn clone(&self) -> Self {
        Self::from_slice(self.as_slice())
    }
}

impl std::ops::Deref for AlignedVec {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl std::ops::DerefMut for AlignedVec {
    fn deref_mut(&mut self) -> &mut [f32] {
        self.as_mut_slice()
    }
}

impl std::fmt::Debug for AlignedVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl PartialEq for AlignedVec {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Vec<f32>> for AlignedVec {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<AlignedVec> for Vec<f32> {
    fn eq(&self, other: &AlignedVec) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl From<Vec<f32>> for AlignedVec {
    fn from(v: Vec<f32>) -> Self {
        Self::from_slice(&v)
    }
}

impl<'a> IntoIterator for &'a AlignedVec {
    type Item = &'a f32;
    type IntoIter = std::slice::Iter<'a, f32>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl<'a> IntoIterator for &'a mut AlignedVec {
    type Item = &'a mut f32;
    type IntoIter = std::slice::IterMut<'a, f32>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_mut_slice().iter_mut()
    }
}

/// A raw view of a slice that can cross the pool's thread boundary.
/// Distinct chunks index disjoint ranges, so concurrent access from the
/// pool is race-free; the caller's borrow outlives the job.
#[derive(Clone, Copy)]
struct Span {
    ptr: *mut f32,
    len: usize,
}

unsafe impl Send for Span {}
unsafe impl Sync for Span {}

impl Span {
    fn of(s: &[f32]) -> Self {
        Span { ptr: s.as_ptr() as *mut f32, len: s.len() }
    }

    fn of_mut(s: &mut [f32]) -> Self {
        Span { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// # Safety
    /// `lo..hi` must be in bounds and not concurrently accessed mutably
    /// outside this chunk's task.
    unsafe fn read(&self, lo: usize, hi: usize) -> &'static [f32] {
        debug_assert!(hi <= self.len);
        std::slice::from_raw_parts(self.ptr.add(lo), hi - lo)
    }

    /// # Safety
    /// As [`Span::read`], plus exclusive access to `lo..hi`.
    #[allow(clippy::mut_from_ref)]
    unsafe fn write(&self, lo: usize, hi: usize) -> &'static mut [f32] {
        debug_assert!(hi <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo)
    }
}

/// Pool-sharded copy `dst ← src` — the published-snapshot write uses
/// this so even the 1R + 1W publish pass scales past one core at large
/// `dim` (falls back to `copy_from_slice` below [`POOL_MIN_DIM`]).
pub fn copy(src: &[f32], dst: &mut [f32]) {
    let len = dst.len();
    assert_eq!(src.len(), len);
    if len < POOL_MIN_DIM {
        dst.copy_from_slice(src);
        return;
    }
    let (ss, ds) = (Span::of(src), Span::of_mut(dst));
    let pooled = ChunkPool::global().try_run(n_chunks(len), &|c| {
        let (lo, hi) = chunk_bounds(len, c);
        unsafe {
            ds.write(lo, hi).copy_from_slice(ss.read(lo, hi));
        }
    });
    if !pooled {
        dst.copy_from_slice(src);
    }
}

/// Pool-sharded [`vecops::mix_grad`] (falls back below [`POOL_MIN_DIM`]).
pub fn mix_grad(wa: f32, wb: f32, gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
    let len = x.len();
    if len < POOL_MIN_DIM {
        return vecops::mix_grad(wa, wb, gamma, g, x, xt);
    }
    // The serial kernels assert matching lengths per call; the sharded
    // path must too, BEFORE handing raw chunk views to the pool.
    assert_eq!(g.len(), len);
    assert_eq!(xt.len(), len);
    let (gs, xs, ts) = (Span::of(g), Span::of_mut(x), Span::of_mut(xt));
    let pooled = ChunkPool::global().try_run(n_chunks(len), &|c| {
        let (lo, hi) = chunk_bounds(len, c);
        unsafe {
            vecops::mix_grad(wa, wb, gamma, gs.read(lo, hi), xs.write(lo, hi), ts.write(lo, hi));
        }
    });
    if !pooled {
        vecops::mix_grad(wa, wb, gamma, g, x, xt);
    }
}

/// Pool-sharded [`vecops::grad_step`] (falls back below [`POOL_MIN_DIM`]).
pub fn grad_step(gamma: f32, g: &[f32], x: &mut [f32], xt: &mut [f32]) {
    let len = x.len();
    if len < POOL_MIN_DIM {
        return vecops::grad_step(gamma, g, x, xt);
    }
    assert_eq!(g.len(), len);
    assert_eq!(xt.len(), len);
    let (gs, xs, ts) = (Span::of(g), Span::of_mut(x), Span::of_mut(xt));
    let pooled = ChunkPool::global().try_run(n_chunks(len), &|c| {
        let (lo, hi) = chunk_bounds(len, c);
        unsafe {
            vecops::grad_step(gamma, gs.read(lo, hi), xs.write(lo, hi), ts.write(lo, hi));
        }
    });
    if !pooled {
        vecops::grad_step(gamma, g, x, xt);
    }
}

/// Pool-sharded [`vecops::mix_into`] (falls back below [`POOL_MIN_DIM`]).
pub fn mix_into(wa: f32, wb: f32, x: &[f32], xt: &[f32], out: &mut [f32]) {
    let len = x.len();
    if len < POOL_MIN_DIM {
        return vecops::mix_into(wa, wb, x, xt, out);
    }
    assert_eq!(xt.len(), len);
    assert_eq!(out.len(), len);
    let (xs, ts, os) = (Span::of(x), Span::of(xt), Span::of_mut(out));
    let pooled = ChunkPool::global().try_run(n_chunks(len), &|c| {
        let (lo, hi) = chunk_bounds(len, c);
        unsafe {
            vecops::mix_into(wa, wb, xs.read(lo, hi), ts.read(lo, hi), os.write(lo, hi));
        }
    });
    if !pooled {
        vecops::mix_into(wa, wb, x, xt, out);
    }
}

/// Pool-sharded [`vecops::comm_apply_fused`] (falls back below
/// [`POOL_MIN_DIM`]).
///
/// Degenerate weights `wa = 1, wb = 0` (no pending mix) are routed to
/// the cheaper [`comm_only`] pass, mirroring what
/// [`super::dynamics::WorkerState::apply_comm`] does on the serial path —
/// the fused kernel would move the same bytes but waste two multiplies
/// and two adds per element. The two paths differ only on signed zeros
/// (`1·a + 0·b` flushes `−0.0` to `+0.0`; `comm_only` keeps `a` as is),
/// and [`super::mixing::Mixer::weights`] can never return exactly
/// `(1.0, 0.0)` for a positive `(η, Δt)` — `wb` stays a tiny nonzero f32
/// long before `wa` rounds to 1 — so the shortcut is unobservable in any
/// replay.
pub fn comm_apply_fused(
    wa: f32,
    wb: f32,
    alpha: f32,
    alpha_tilde: f32,
    xj: &[f32],
    x: &mut [f32],
    xt: &mut [f32],
) {
    if wa == 1.0 && wb == 0.0 {
        return comm_only(alpha, alpha_tilde, xj, x, xt);
    }
    let len = x.len();
    if len < POOL_MIN_DIM {
        return vecops::comm_apply_fused(wa, wb, alpha, alpha_tilde, xj, x, xt);
    }
    assert_eq!(xj.len(), len);
    assert_eq!(xt.len(), len);
    let (js, xs, ts) = (Span::of(xj), Span::of_mut(x), Span::of_mut(xt));
    let pooled = ChunkPool::global().try_run(n_chunks(len), &|c| {
        let (lo, hi) = chunk_bounds(len, c);
        unsafe {
            vecops::comm_apply_fused(
                wa,
                wb,
                alpha,
                alpha_tilde,
                js.read(lo, hi),
                xs.write(lo, hi),
                ts.write(lo, hi),
            );
        }
    });
    if !pooled {
        vecops::comm_apply_fused(wa, wb, alpha, alpha_tilde, xj, x, xt);
    }
}

/// Pool-sharded [`vecops::comm_only`] (falls back below [`POOL_MIN_DIM`]).
pub fn comm_only(alpha: f32, alpha_tilde: f32, xj: &[f32], x: &mut [f32], xt: &mut [f32]) {
    let len = x.len();
    if len < POOL_MIN_DIM {
        return vecops::comm_only(alpha, alpha_tilde, xj, x, xt);
    }
    assert_eq!(xj.len(), len);
    assert_eq!(xt.len(), len);
    let (js, xs, ts) = (Span::of(xj), Span::of_mut(x), Span::of_mut(xt));
    let pooled = ChunkPool::global().try_run(n_chunks(len), &|c| {
        let (lo, hi) = chunk_bounds(len, c);
        unsafe {
            let (j, xc, tc) = (js.read(lo, hi), xs.write(lo, hi), ts.write(lo, hi));
            vecops::comm_only(alpha, alpha_tilde, j, xc, tc);
        }
    });
    if !pooled {
        vecops::comm_only(alpha, alpha_tilde, xj, x, xt);
    }
}

/// Pool-sharded [`vecops::comm_pair_fused`] over both endpoints (falls
/// back below [`POOL_MIN_DIM`]).
#[allow(clippy::too_many_arguments)]
pub fn comm_pair_fused(
    waa: f32,
    wba: f32,
    wab: f32,
    wbb: f32,
    alpha: f32,
    alpha_tilde: f32,
    xa: &mut [f32],
    xta: &mut [f32],
    xb: &mut [f32],
    xtb: &mut [f32],
) {
    comm_pair_fused_on(
        ChunkPool::global(),
        waa,
        wba,
        wab,
        wbb,
        alpha,
        alpha_tilde,
        xa,
        xta,
        xb,
        xtb,
    )
}

/// As [`comm_pair_fused`], sharded on an explicit pool — the locality
/// bench and regression tests drive pinned and unpinned pools (at any
/// claim offset) side by side and prove the bits never move.
#[allow(clippy::too_many_arguments)]
pub fn comm_pair_fused_on(
    pool: &ChunkPool,
    waa: f32,
    wba: f32,
    wab: f32,
    wbb: f32,
    alpha: f32,
    alpha_tilde: f32,
    xa: &mut [f32],
    xta: &mut [f32],
    xb: &mut [f32],
    xtb: &mut [f32],
) {
    let len = xa.len();
    if len < POOL_MIN_DIM {
        return vecops::comm_pair_fused(
            waa, wba, wab, wbb, alpha, alpha_tilde, xa, xta, xb, xtb,
        );
    }
    assert_eq!(xta.len(), len);
    assert_eq!(xb.len(), len);
    assert_eq!(xtb.len(), len);
    let (sa, sta) = (Span::of_mut(xa), Span::of_mut(xta));
    let (sb, stb) = (Span::of_mut(xb), Span::of_mut(xtb));
    let pooled = pool.try_run(n_chunks(len), &|c| {
        let (lo, hi) = chunk_bounds(len, c);
        unsafe {
            vecops::comm_pair_fused(
                waa,
                wba,
                wab,
                wbb,
                alpha,
                alpha_tilde,
                sa.write(lo, hi),
                sta.write(lo, hi),
                sb.write(lo, hi),
                stb.write(lo, hi),
            );
        }
    });
    if !pooled {
        vecops::comm_pair_fused(
            waa, wba, wab, wbb, alpha, alpha_tilde, xa, xta, xb, xtb,
        );
    }
}

/// Pool-sharded [`vecops::mix_pair`] (falls back below [`POOL_MIN_DIM`]).
/// This is what routes `sync_all` / final-evaluation mixing through the
/// chunk pool at large `dim`, like the mid-run kernels.
pub fn mix_pair(wa: f32, wb: f32, x: &mut [f32], xt: &mut [f32]) {
    mix_pair_on(ChunkPool::global(), wa, wb, x, xt)
}

/// As [`mix_pair`], sharded on an explicit pool (see
/// [`comm_pair_fused_on`] for why that exists).
pub fn mix_pair_on(pool: &ChunkPool, wa: f32, wb: f32, x: &mut [f32], xt: &mut [f32]) {
    let len = x.len();
    if len < POOL_MIN_DIM {
        return vecops::mix_pair(wa, wb, x, xt);
    }
    assert_eq!(xt.len(), len);
    let (xs, ts) = (Span::of_mut(x), Span::of_mut(xt));
    let pooled = pool.try_run(n_chunks(len), &|c| {
        let (lo, hi) = chunk_bounds(len, c);
        unsafe {
            vecops::mix_pair(wa, wb, xs.write(lo, hi), ts.write(lo, hi));
        }
    });
    if !pooled {
        vecops::mix_pair(wa, wb, x, xt);
    }
}

/// Pool-sharded [`vecops::average_pair`] (falls back below
/// [`POOL_MIN_DIM`]) — final synchronization's `x, y ← (x+y)/2`.
pub fn average_pair(x: &mut [f32], y: &mut [f32]) {
    let len = x.len();
    if len < POOL_MIN_DIM {
        return vecops::average_pair(x, y);
    }
    assert_eq!(y.len(), len);
    let (xs, ys) = (Span::of_mut(x), Span::of_mut(y));
    let pooled = ChunkPool::global().try_run(n_chunks(len), &|c| {
        let (lo, hi) = chunk_bounds(len, c);
        unsafe {
            vecops::average_pair(xs.write(lo, hi), ys.write(lo, hi));
        }
    });
    if !pooled {
        vecops::average_pair(x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{standard_normal, Xoshiro256};

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n).map(|_| standard_normal(&mut rng) as f32).collect()
    }

    // Odd length: exercises the ragged final chunk.
    const DIM: usize = 2 * CHUNK + 1234;

    #[test]
    fn pooled_comm_pair_fused_bit_identical_to_serial() {
        let (xa0, ta0) = (randvec(DIM, 1), randvec(DIM, 2));
        let (xb0, tb0) = (randvec(DIM, 3), randvec(DIM, 4));
        let (mut xa, mut ta, mut xb, mut tb) =
            (xa0.clone(), ta0.clone(), xb0.clone(), tb0.clone());
        comm_pair_fused(
            0.85, 0.15, 0.6, 0.4, 0.5, 1.9, &mut xa, &mut ta, &mut xb, &mut tb,
        );
        let (mut rxa, mut rta, mut rxb, mut rtb) = (xa0, ta0, xb0, tb0);
        vecops::comm_pair_fused(
            0.85, 0.15, 0.6, 0.4, 0.5, 1.9, &mut rxa, &mut rta, &mut rxb, &mut rtb,
        );
        assert_eq!(xa, rxa);
        assert_eq!(ta, rta);
        assert_eq!(xb, rxb);
        assert_eq!(tb, rtb);
    }

    #[test]
    fn pooled_mix_grad_and_mix_into_bit_identical_to_serial() {
        let g = randvec(DIM, 5);
        let (x0, t0) = (randvec(DIM, 6), randvec(DIM, 7));
        let (mut x, mut t) = (x0.clone(), t0.clone());
        mix_grad(0.9, 0.1, 0.02, &g, &mut x, &mut t);
        let (mut rx, mut rt) = (x0, t0);
        vecops::mix_grad(0.9, 0.1, 0.02, &g, &mut rx, &mut rt);
        assert_eq!(x, rx);
        assert_eq!(t, rt);

        let mut out = vec![0.0f32; DIM];
        let mut rout = vec![0.0f32; DIM];
        mix_into(0.9, 0.1, &x, &t, &mut out);
        vecops::mix_into(0.9, 0.1, &rx, &rt, &mut rout);
        assert_eq!(out, rout);
    }

    #[test]
    fn pooled_results_stable_across_repeated_runs() {
        // Same inputs → same bits, run after run (fixed chunk boundaries;
        // no schedule dependence).
        let xj = randvec(DIM, 8);
        let (x0, t0) = (randvec(DIM, 9), randvec(DIM, 10));
        let mut first: Option<(Vec<f32>, Vec<f32>)> = None;
        for _ in 0..3 {
            let (mut x, mut t) = (x0.clone(), t0.clone());
            comm_apply_fused(0.8, 0.2, 0.5, 1.5, &xj, &mut x, &mut t);
            match &first {
                None => first = Some((x, t)),
                Some((fx, ft)) => {
                    assert_eq!(&x, fx);
                    assert_eq!(&t, ft);
                }
            }
        }
    }

    #[test]
    fn pooled_mix_pair_and_average_pair_bit_identical_to_serial() {
        let (x0, t0) = (randvec(DIM, 11), randvec(DIM, 12));
        let (mut x, mut t) = (x0.clone(), t0.clone());
        mix_pair(0.85, 0.15, &mut x, &mut t);
        let (mut rx, mut rt) = (x0.clone(), t0.clone());
        vecops::mix_pair(0.85, 0.15, &mut rx, &mut rt);
        assert_eq!(x, rx);
        assert_eq!(t, rt);

        let (mut a, mut b) = (x0.clone(), t0.clone());
        average_pair(&mut a, &mut b);
        let (mut ra, mut rb) = (x0, t0);
        vecops::average_pair(&mut ra, &mut rb);
        assert_eq!(a, ra);
        assert_eq!(b, rb);
    }

    #[test]
    fn degenerate_weights_route_to_comm_only() {
        // wa = 1, wb = 0 (no pending mix): pool::comm_apply_fused must
        // behave exactly like pool::comm_only, the path it routes to.
        let xj = randvec(DIM, 13);
        let (x0, t0) = (randvec(DIM, 14), randvec(DIM, 15));
        let (mut x, mut t) = (x0.clone(), t0.clone());
        comm_apply_fused(1.0, 0.0, 0.5, 1.5, &xj, &mut x, &mut t);
        let (mut rx, mut rt) = (x0, t0);
        comm_only(0.5, 1.5, &xj, &mut rx, &mut rt);
        assert_eq!(x, rx);
        assert_eq!(t, rt);
    }

    #[test]
    fn aligned_vec_page_aligns_large_buffers() {
        // At or past one page the base lands on a 4 KiB boundary, and —
        // because CHUNK·4 bytes is a whole multiple of the page — so does
        // every fixed chunk boundary the pool shards on.
        for len in [1024usize, CHUNK, DIM, 4 * CHUNK] {
            let buf = AlignedVec::zeroed(len);
            let addr = buf.as_slice().as_ptr() as usize;
            if len * 4 >= PAGE {
                assert_eq!(addr % PAGE, 0, "len {len}: base not page-aligned");
                for c in 0..n_chunks(len) {
                    let (lo, _) = chunk_bounds(len, c);
                    assert_eq!((addr + lo * 4) % PAGE, 0, "chunk {c} boundary");
                }
            }
            assert_eq!(buf.len(), len);
            assert!(buf.iter().all(|&v| v == 0.0));
        }
        // Sub-page buffers don't pay the page-rounding footprint.
        let small = AlignedVec::from_slice(&[1.0, 2.0, 3.0]);
        assert_eq!(small, vec![1.0, 2.0, 3.0]);
        let empty = AlignedVec::zeroed(0);
        assert!(empty.is_empty());
        let cloned = small.clone();
        assert_eq!(cloned, small);
    }

    #[test]
    fn aligned_buffers_bit_identical_to_vec_backed_kernels() {
        // The alignment regression pin: running the pooled kernels over
        // page-aligned buffers yields exactly the bits the Vec-backed
        // buffers produce — alignment moves allocations, never values.
        let g = randvec(DIM, 21);
        let (x0, t0) = (randvec(DIM, 22), randvec(DIM, 23));
        let (mut ax, mut at) = (AlignedVec::from_slice(&x0), AlignedVec::from_slice(&t0));
        mix_grad(0.9, 0.1, 0.02, &g, &mut ax, &mut at);
        comm_apply_fused(0.8, 0.2, 0.5, 1.5, &g, &mut ax, &mut at);
        let (mut vx, mut vt) = (x0, t0);
        mix_grad(0.9, 0.1, 0.02, &g, &mut vx, &mut vt);
        comm_apply_fused(0.8, 0.2, 0.5, 1.5, &g, &mut vx, &mut vt);
        assert_eq!(ax, vx);
        assert_eq!(at, vt);
    }

    #[test]
    fn local_pool_runs_every_chunk_exactly_once() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let pool = ChunkPool::new(3);
        for n in [0usize, 1, 2, 7, 64] {
            let counts: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
            pool.run(n, &|c| {
                counts[c].fetch_add(1, Ordering::SeqCst);
            });
            for (c, k) in counts.iter().enumerate() {
                assert_eq!(k.load(Ordering::SeqCst), 1, "chunk {c} of {n}");
            }
        }
    }

    #[test]
    fn sticky_claiming_runs_every_chunk_exactly_once_at_any_offset() {
        use std::sync::atomic::{AtomicU32, Ordering};
        // Per-lane cursors must still cover 0..n exactly once whether
        // lanes drain their own range first (offset 0) or are forced
        // onto remote ranges (the stolen/counterfactual offsets).
        let pool = ChunkPool::new_with_pinning(3, false);
        for offset in [0usize, 1, 2, 3, 7] {
            pool.set_claim_offset(offset);
            for n in [0usize, 1, 2, 3, 4, 7, 64, 65] {
                let counts: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
                pool.run(n, &|c| {
                    counts[c].fetch_add(1, Ordering::SeqCst);
                });
                for (c, k) in counts.iter().enumerate() {
                    assert_eq!(k.load(Ordering::SeqCst), 1, "chunk {c} of {n} at offset {offset}");
                }
            }
        }
    }

    #[test]
    fn first_touch_zeroed_matches_serial_zeroed() {
        // Owner-lane first touch changes which thread writes each page,
        // never the contents: all-zero, page-aligned, and kernel results
        // over it are bit-identical to a serially zeroed buffer.
        let pool = ChunkPool::new_with_pinning(3, false);
        for len in [0usize, 3, CHUNK, DIM, 4 * CHUNK] {
            let ft = AlignedVec::zeroed_on(&pool, len);
            assert_eq!(ft.len(), len);
            assert!(ft.iter().all(|&v| v == 0.0), "len {len}");
            if len * 4 >= PAGE {
                assert_eq!(ft.as_slice().as_ptr() as usize % PAGE, 0);
            }
        }
        let src = randvec(DIM, 31);
        let mut a = AlignedVec::zeroed_on(&pool, DIM);
        a.as_mut_slice().copy_from_slice(&src);
        let b = AlignedVec::from_slice(&src);
        assert_eq!(a, b);
    }

    #[test]
    fn explicit_pool_wrappers_bit_identical_to_global_and_serial() {
        let (x0, t0) = (randvec(DIM, 41), randvec(DIM, 42));
        let (xb0, tb0) = (randvec(DIM, 43), randvec(DIM, 44));
        let pool = ChunkPool::new_with_pinning(3, false);
        for offset in [0usize, 2] {
            pool.set_claim_offset(offset);
            let (mut xa, mut ta, mut xb, mut tb) =
                (x0.clone(), t0.clone(), xb0.clone(), tb0.clone());
            comm_pair_fused_on(
                &pool, 0.85, 0.15, 0.6, 0.4, 0.5, 1.9, &mut xa, &mut ta, &mut xb, &mut tb,
            );
            mix_pair_on(&pool, 0.7, 0.3, &mut xa, &mut ta);
            let (mut rxa, mut rta, mut rxb, mut rtb) =
                (x0.clone(), t0.clone(), xb0.clone(), tb0.clone());
            vecops::comm_pair_fused(
                0.85, 0.15, 0.6, 0.4, 0.5, 1.9, &mut rxa, &mut rta, &mut rxb, &mut rtb,
            );
            vecops::mix_pair(0.7, 0.3, &mut rxa, &mut rta);
            assert_eq!(xa, rxa, "offset {offset}");
            assert_eq!(ta, rta);
            assert_eq!(xb, rxb);
            assert_eq!(tb, rtb);
        }
    }

    #[test]
    fn mux_thread_knob_falls_back_to_pool_knob() {
        // Both knobs are Option<usize> lanes; the transform is shared.
        assert_eq!(super::extra_threads_for(Some(1)), 0);
        assert_eq!(super::extra_threads_for(Some(4)), 3);
        assert_eq!(super::extra_threads_for(Some(64)), 7, "capped");
        // Unset follows the core count, never exceeding the cap.
        assert!(super::extra_threads_for(None) <= 7);
    }

    #[test]
    fn panicking_task_is_reraised_and_pool_stays_usable() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let pool = ChunkPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, &|c| {
                if c == 3 {
                    panic!("injected chunk failure");
                }
            });
        }));
        assert!(caught.is_err(), "the chunk panic must surface to the caller");
        // The pool must not be poisoned: the next job runs normally.
        let hits = AtomicU32::new(0);
        pool.run(8, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn local_pool_survives_many_back_to_back_jobs() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let pool = ChunkPool::new(2);
        let total = AtomicU64::new(0);
        for _ in 0..200 {
            pool.run(5, &|c| {
                total.fetch_add(c as u64 + 1, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 200 * (1 + 2 + 3 + 4 + 5));
    }
}
