//! The paper's algorithm: continuized-momentum asynchronous gossip.
//!
//! This module is engine-agnostic — the exact same event-application code
//! is driven by the virtual-time [`crate::simulator`] and by the
//! real-thread [`crate::runtime`], so what we test in fast simulation is
//! what runs on the request path.
//!
//! Contents:
//! * [`mixing`] — the continuous momentum operator
//!   `exp(Δt·[[−η,η],[η,−η]])` in closed form (Algorithm 1, lines 9/17);
//! * [`params`] — the theory-given hyper-parameters (Prop. 3.6):
//!   baseline `η=0, α=α̃=½` vs A²CiD²
//!   `η=1/(2√(χ₁χ₂)), α=½, α̃=½·√(χ₁/χ₂)`;
//! * [`dynamics`] — per-worker state `{x, x̃, t_last}` and the two event
//!   types of the SDE (Eq. 4): local gradient spikes and p2p averagings;
//! * [`consensus`] — the consensus distance `‖πx‖_F` tracked in Fig. 5b;
//! * [`vecops`] — the fused vector kernels backing the hot path (the Rust
//!   mirror of the L1 Pallas kernel, used when PJRT is not in the loop),
//!   behind a runtime-dispatched backend layer: a scalar reference and
//!   bit-identical explicit-SIMD backends (AVX2/NEON, plus AVX-512 where
//!   the toolchain and CPU allow), selected once per process via
//!   `A2CID2_KERNEL_BACKEND`;
//! * [`pool`] — the deterministic chunked kernel pool that shards the
//!   fused kernels across threads for large `dim` (fixed chunk
//!   boundaries, so pooled results stay bit-identical to single-thread).

pub mod consensus;
pub mod dynamics;
pub mod mixing;
pub mod params;
pub mod pool;
pub mod vecops;

pub use consensus::{consensus_distance, consensus_distance_sq, consensus_of};
pub use dynamics::WorkerState;
pub use mixing::Mixer;
pub use params::AcidParams;
