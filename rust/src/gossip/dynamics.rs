//! Per-worker state and the two event types of the SDE (Eq. 4).
//!
//! A worker carries its model parameters `x`, the A²CiD² momentum buffer
//! `x̃`, and the timestamp of its last event. Every event first applies the
//! lazy momentum flow ([`super::mixing`]) for the elapsed time, then the
//! event's own update:
//!
//! * **gradient spike** (`dN_t^i`): `x ← x − γ·g`, `x̃ ← x̃ − γ·g`
//!   (the SDE applies the gradient term to both rows);
//! * **communication spike** (`dM_t^ij`): with `m = x^i − x^j` *after*
//!   both endpoints mixed to the event time,
//!   `x^i ← x^i − α·m`, `x̃^i ← x̃^i − α̃·m` (and symmetrically, `+` on j).
//!
//! With the baseline parameters (η = 0, α = α̃ = ½) and `x̃₀ = x₀` the two
//! buffers stay identical and the dynamic reduces to AD-PSGD-style pairwise
//! averaging + local SGD (Eq. 6) — asserted in the tests below.

use super::mixing::Mixer;
use super::params::AcidParams;
use super::pool;
use super::pool::AlignedVec;

/// One worker's replica state. The two buffers live in page-aligned
/// allocations ([`AlignedVec`]) so the chunk pool's fixed 64k-element
/// shard boundaries land on page boundaries at large `dim`.
#[derive(Clone, Debug)]
pub struct WorkerState {
    /// Model parameters `x^i`.
    pub x: AlignedVec,
    /// Momentum buffer `x̃^i` (equal to `x` at init).
    pub xt: AlignedVec,
    /// Time of this worker's last event (for lazy mixing).
    pub t_last: f64,
    /// Number of gradient events applied.
    pub n_grads: u64,
    /// Number of communication events applied.
    pub n_comms: u64,
    /// Value of `n_grads` when the last communication event was applied
    /// (0 before the first pairing). Update rules that pace communication
    /// by local progress — local SGD's "H gradient steps between
    /// pairings" — gate on `n_grads - grads_at_last_comm`.
    pub grads_at_last_comm: u64,
}

impl WorkerState {
    /// Initialize with `x̃ = x` (the paper's init; guarantees
    /// `mean(x̃₀) = mean(x₀)`, the tracker property of Eq. 5).
    pub fn new(x: Vec<f32>) -> Self {
        let x = AlignedVec::from(x);
        let xt = x.clone();
        Self { x, xt, t_last: 0.0, n_grads: 0, n_comms: 0, grads_at_last_comm: 0 }
    }

    /// Parameter dimension.
    pub fn dim(&self) -> usize {
        self.x.len()
    }

    /// Bring the pair up to time `t` by applying the momentum flow.
    /// Shards across the chunk pool at large `dim` (bit-identical to the
    /// serial kernel), so `sync_all` / final synchronization scales like
    /// the mid-run kernels.
    pub fn mix_to(&mut self, t: f64, mixer: &Mixer) {
        let dt = t - self.t_last;
        if dt > 0.0 && mixer.eta != 0.0 {
            let w = mixer.weights(dt);
            pool::mix_pair(w.wa, w.wb, &mut self.x, &mut self.xt);
        }
        if dt > 0.0 {
            self.t_last = t;
        }
    }

    /// Apply a gradient event at time `t`: mix, then step both rows.
    pub fn apply_grad(&mut self, t: f64, gamma: f32, g: &[f32], mixer: &Mixer) {
        let dt = t - self.t_last;
        if dt > 0.0 && mixer.eta != 0.0 {
            let w = mixer.weights(dt);
            pool::mix_grad(w.wa, w.wb, gamma, g, &mut self.x, &mut self.xt);
        } else {
            pool::grad_step(gamma, g, &mut self.x, &mut self.xt);
        }
        if dt > 0.0 {
            self.t_last = t;
        }
        self.n_grads += 1;
    }

    /// Compute this worker's momentum-mixed parameters at time `t` into
    /// `out` *without mutating state*: the send-side half of a runtime
    /// pairing (2R + 1W outside the state write path). The pending mix
    /// stays pending until [`WorkerState::apply_comm_fused`] folds it in
    /// on receive.
    pub fn mix_into(&self, t: f64, mixer: &Mixer, out: &mut [f32]) {
        let dt = t - self.t_last;
        if dt > 0.0 && mixer.eta != 0.0 {
            let w = mixer.weights(dt);
            pool::mix_into(w.wa, w.wb, &self.x, &self.xt, out);
        } else {
            out.copy_from_slice(&self.x);
        }
    }

    /// Re-initialize from a neighbor snapshot at time `t`: a worker
    /// re-joining after a churn departure adopts the donor's parameters
    /// (`x̃ = x`, the same coupling as a fresh init, so the pair tracker
    /// restarts clean) and resumes its lazy-mixing clock at `t`. Event
    /// counts are kept — it is the same worker resuming, and the
    /// learning-rate schedule indexes its local step count.
    pub fn reinit_from(&mut self, donor_x: &[f32], t: f64) {
        self.x.copy_from_slice(donor_x);
        self.xt.copy_from_slice(donor_x);
        self.t_last = t;
    }

    /// Apply this endpoint's half of a communication event, given the
    /// peer's *already-mixed* parameters `xj`. Both endpoints must be mixed
    /// to the same event time before either side computes its update; the
    /// engines guarantee this by mixing `i` and `j` first, then exchanging.
    pub fn apply_comm(&mut self, params: &AcidParams, xj: &[f32]) {
        pool::comm_only(
            params.alpha as f32,
            params.alpha_tilde as f32,
            xj,
            &mut self.x,
            &mut self.xt,
        );
        self.n_comms += 1;
        self.grads_at_last_comm = self.n_grads;
    }

    /// The receive-side half of a runtime pairing: fold this worker's own
    /// pending momentum mix (left pending by [`WorkerState::mix_into`] at
    /// the same event time `t`) and the `(α, α̃)` update into ONE
    /// read-modify-write pass over the state (3R + 2W). If an intervening
    /// gradient event already advanced `t_last` past `t`, the pending mix
    /// is gone and only the averaging update applies.
    pub fn apply_comm_fused(&mut self, t: f64, params: &AcidParams, mixer: &Mixer, xj: &[f32]) {
        let dt = t - self.t_last;
        if dt > 0.0 && mixer.eta != 0.0 {
            let w = mixer.weights(dt);
            pool::comm_apply_fused(
                w.wa,
                w.wb,
                params.alpha as f32,
                params.alpha_tilde as f32,
                xj,
                &mut self.x,
                &mut self.xt,
            );
        } else {
            pool::comm_only(
                params.alpha as f32,
                params.alpha_tilde as f32,
                xj,
                &mut self.x,
                &mut self.xt,
            );
        }
        if dt > 0.0 {
            self.t_last = t;
        }
        self.n_comms += 1;
        self.grads_at_last_comm = self.n_grads;
    }
}

/// Apply one full pairwise communication event between workers `a` and `b`
/// at time `t` (the engine-side helper both execution engines use).
///
/// Fully fused (§Perf): each side's pending momentum flow and the
/// antisymmetric `(α, α̃)` update run in one pass over the four buffers —
/// 4R + 4W per element, no allocation — instead of mixing each side,
/// snapshotting one, and applying two `comm_apply_fused` passes
/// (≈ 11R + 9W). Large `dim` shards across the chunk pool.
pub fn comm_event(
    a: &mut WorkerState,
    b: &mut WorkerState,
    t: f64,
    params: &AcidParams,
    mixer: &Mixer,
) {
    let wa = mixer.weights(t - a.t_last);
    let wb = mixer.weights(t - b.t_last);
    pool::comm_pair_fused(
        wa.wa,
        wa.wb,
        wb.wa,
        wb.wb,
        params.alpha as f32,
        params.alpha_tilde as f32,
        &mut a.x,
        &mut a.xt,
        &mut b.x,
        &mut b.xt,
    );
    if t > a.t_last {
        a.t_last = t;
    }
    if t > b.t_last {
        b.t_last = t;
    }
    a.n_comms += 1;
    b.n_comms += 1;
    a.grads_at_last_comm = a.n_grads;
    b.grads_at_last_comm = b.n_grads;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gossip::vecops;

    fn mk(x: &[f32]) -> WorkerState {
        WorkerState::new(x.to_vec())
    }

    #[test]
    fn baseline_keeps_buffers_glued() {
        // η = 0, α = α̃ = ½, x̃₀ = x₀ ⇒ x ≡ x̃ forever (Eq. 6 reduction).
        let p = AcidParams::baseline();
        let mixer = Mixer::new(p.eta);
        let mut a = mk(&[1.0, 2.0]);
        let mut b = mk(&[3.0, -2.0]);
        a.apply_grad(0.3, 0.1, &[1.0, -1.0], &mixer);
        comm_event(&mut a, &mut b, 0.7, &p, &mixer);
        b.apply_grad(0.9, 0.1, &[0.5, 0.5], &mixer);
        assert_eq!(a.x, a.xt);
        assert_eq!(b.x, b.xt);
    }

    #[test]
    fn baseline_comm_is_exact_averaging() {
        let p = AcidParams::baseline();
        let mixer = Mixer::new(p.eta);
        let mut a = mk(&[0.0, 4.0]);
        let mut b = mk(&[2.0, 0.0]);
        comm_event(&mut a, &mut b, 1.0, &p, &mixer);
        assert_eq!(a.x, vec![1.0, 2.0]);
        assert_eq!(b.x, vec![1.0, 2.0]);
    }

    #[test]
    fn comm_preserves_global_mean_of_x() {
        // The α-update is antisymmetric in (i, j): Σᵢ xᵢ is conserved.
        let p = AcidParams::accelerated(10.0, 1.0);
        let mixer = Mixer::new(p.eta);
        let mut a = mk(&[1.0, -3.0, 2.0]);
        let mut b = mk(&[5.0, 0.5, -1.0]);
        // Desynchronize the pairs so mixing actually does something.
        a.apply_grad(0.2, 0.05, &[1.0, 1.0, 1.0], &mixer);
        let total_before: f64 = a
            .x
            .iter()
            .chain(&b.x)
            .map(|&v| v as f64)
            .sum::<f64>();
        comm_event(&mut a, &mut b, 0.8, &p, &mixer);
        let total_after: f64 = a
            .x
            .iter()
            .chain(&b.x)
            .map(|&v| v as f64)
            .sum::<f64>();
        assert!((total_before - total_after).abs() < 1e-4);
    }

    #[test]
    fn comm_preserves_global_mean_of_xt() {
        let p = AcidParams::accelerated(10.0, 1.0);
        let mixer = Mixer::new(p.eta);
        let mut a = mk(&[1.0, -3.0]);
        let mut b = mk(&[5.0, 0.5]);
        let before: f64 = a.xt.iter().chain(&b.xt).map(|&v| v as f64).sum();
        comm_event(&mut a, &mut b, 0.5, &p, &mixer);
        let after: f64 = a.xt.iter().chain(&b.xt).map(|&v| v as f64).sum();
        assert!((before - after).abs() < 1e-4);
    }

    #[test]
    fn tracker_property_mean_x_equals_mean_xt() {
        // Eq. 5 / Sec. 3.2: with mean(x₀) = mean(x̃₀), the global means of
        // x and x̃ coincide *at any common evaluation time*: gradient spikes
        // hit both rows equally, comm spikes shift the per-worker
        // difference u_i = x_i − x̃_i antisymmetrically across the pair
        // (Σu conserved), and the mixing ODE decays every u_i at the same
        // rate 2η, so Σu(t) = Σu(0)·e^{−2ηt} = 0. The lazy per-worker
        // mixing makes this exact only after syncing all workers to a
        // common time, which is what the engines do before evaluation.
        let p = AcidParams::accelerated(8.0, 2.0);
        let mixer = Mixer::new(p.eta);
        let mut ws = vec![mk(&[1.0, 0.0]), mk(&[0.0, 2.0]), mk(&[3.0, -1.0])];
        let mean = |ws: &[WorkerState], f: fn(&WorkerState) -> &[f32]| -> f64 {
            ws.iter()
                .flat_map(|w| f(w).iter())
                .map(|&v| v as f64)
                .sum::<f64>()
                / (ws.len() * 2) as f64
        };
        // Interleave events.
        ws[0].apply_grad(0.1, 0.02, &[1.0, -2.0], &mixer);
        {
            let (l, r) = ws.split_at_mut(1);
            comm_event(&mut l[0], &mut r[0], 0.4, &p, &mixer);
        }
        ws[2].apply_grad(0.5, 0.02, &[0.3, 0.3], &mixer);
        {
            let (l, r) = ws.split_at_mut(2);
            comm_event(&mut l[1], &mut r[0], 0.9, &p, &mixer);
        }
        // Sync everyone to a common time, then the means must agree.
        for w in &mut ws {
            w.mix_to(1.5, &mixer);
        }
        let mx = mean(&ws, |w| &w.x);
        let mt = mean(&ws, |w| &w.xt);
        assert!((mx - mt).abs() < 1e-5, "mean x={mx} vs mean x̃={mt}");
    }

    #[test]
    fn fused_pairing_protocol_bit_identical_to_composed() {
        // The runtime's new pairing path (read-only mix_into on send, one
        // fused RMW pass on receive) must reproduce the old composed path
        // (mix in place under the lock, copy a snapshot, apply the comm
        // half) bit-for-bit.
        let p = AcidParams::accelerated(10.0, 1.0);
        let mixer = Mixer::new(p.eta);
        let mut a1 = mk(&[1.0, -2.0, 0.5]);
        let mut b1 = mk(&[3.0, 0.5, -1.5]);
        a1.apply_grad(0.2, 0.05, &[1.0, -1.0, 0.5], &mixer); // desync the pair
        let (mut a2, mut b2) = (a1.clone(), b1.clone());
        let t = 0.7;

        // New: both send buffers built without touching state, then one
        // locked read-modify-write pass per side.
        let mut buf_a = vec![0.0f32; 3];
        let mut buf_b = vec![0.0f32; 3];
        a1.mix_into(t, &mixer, &mut buf_a);
        b1.mix_into(t, &mixer, &mut buf_b);
        a1.apply_comm_fused(t, &p, &mixer, &buf_b);
        b1.apply_comm_fused(t, &p, &mixer, &buf_a);

        // Old: mix in place, snapshot, apply halves.
        a2.mix_to(t, &mixer);
        b2.mix_to(t, &mixer);
        let xa = a2.x.clone();
        let xb = b2.x.clone();
        a2.apply_comm(&p, &xb);
        b2.apply_comm(&p, &xa);

        assert_eq!(a1.x, a2.x);
        assert_eq!(a1.xt, a2.xt);
        assert_eq!(b1.x, b2.x);
        assert_eq!(b1.xt, b2.xt);
        assert_eq!(a1.t_last, a2.t_last);
        assert_eq!(a1.n_comms, a2.n_comms);
    }

    #[test]
    fn apply_comm_fused_degenerates_after_interleaved_grad() {
        // If a gradient event already advanced t_last past the pairing
        // time, the pending mix is gone: only the (α, α̃) update applies
        // and t_last must not move backwards.
        let p = AcidParams::accelerated(5.0, 1.0);
        let mixer = Mixer::new(p.eta);
        let mut a = mk(&[1.0, 2.0]);
        let t_pair = 0.4;
        let mut buf = vec![0.0f32; 2];
        a.mix_into(t_pair, &mixer, &mut buf);
        // A gradient lands between send and receive.
        a.apply_grad(0.6, 0.1, &[1.0, 1.0], &mixer);
        let mut reference = a.clone();
        a.apply_comm_fused(t_pair, &p, &mixer, &[0.5, -0.5]);
        reference.apply_comm(&p, &[0.5, -0.5]);
        assert_eq!(a.x, reference.x);
        assert_eq!(a.xt, reference.xt);
        assert_eq!(a.t_last, 0.6, "t_last never rewinds");
    }

    #[test]
    fn grad_event_counts() {
        let p = AcidParams::baseline();
        let mixer = Mixer::new(p.eta);
        let mut a = mk(&[0.0]);
        a.apply_grad(0.1, 1.0, &[1.0], &mixer);
        a.apply_grad(0.2, 1.0, &[1.0], &mixer);
        assert_eq!(a.n_grads, 2);
        assert_eq!(a.x, vec![-2.0]);
    }

    #[test]
    fn gossip_only_contracts_pair_difference() {
        // Repeated comm events shrink ‖x_a − x_b‖ for both dynamics.
        for p in [AcidParams::baseline(), AcidParams::accelerated(13.0, 1.0)] {
            let mixer = Mixer::new(p.eta);
            let mut a = mk(&[10.0, -4.0]);
            let mut b = mk(&[-10.0, 4.0]);
            let d0 = vecops::sq_dist(&a.x, &b.x);
            let mut t = 0.0;
            for _ in 0..20 {
                t += 0.1;
                comm_event(&mut a, &mut b, t, &p, &mixer);
            }
            let d1 = vecops::sq_dist(&a.x, &b.x);
            assert!(d1 < d0 * 1e-3, "{}: {d0} -> {d1}", p.label());
        }
    }
}
