//! CPU topology discovery and thread affinity — the memory-locality layer.
//!
//! The pooled kernel path is memory-bandwidth-bound at fleet scale, so
//! where a page lives relative to the core that streams it is the last
//! lever on raw kernel speed. This module gives the rest of the crate
//! three things, all with zero new dependencies:
//!
//! * **Topology** ([`topology`]): the set of CPUs this process may run
//!   on, grouped by NUMA node. Discovered from
//!   `/sys/devices/system/node/node*/cpulist` intersected with the
//!   process's affinity mask (`sched_getaffinity`), so restricted
//!   cpusets in CI containers are respected. Machines without the sysfs
//!   tree (or without NUMA) collapse to a single node.
//! * **Pinning** ([`pin_current_thread`] / [`unpin_current_thread`]):
//!   `sched_setaffinity` issued as a raw syscall through
//!   `core::arch::asm!` — the workspace is network-free and vendors no
//!   `libc`, and the two affinity syscalls are the only kernel surface
//!   we need. Non-Linux targets (and non-x86_64/aarch64) compile these
//!   to no-ops that return `false`, so the crate builds unchanged on
//!   macOS; callers treat a failed pin as "run unpinned".
//! * **Policy** ([`pin_lanes`] / [`numa_first_touch`]): the
//!   `A2CID2_PIN` / `A2CID2_NUMA` knobs (`0|1|auto`). `auto` — the
//!   default — only engages on machines that actually report more than
//!   one NUMA node: on a laptop or single-socket CI runner pinning buys
//!   nothing and can hurt an oversubscribed host, so we stay out of the
//!   scheduler's way. Failures (EPERM under a restrictive seccomp
//!   profile, invalid knob values) warn once on stderr and degrade to
//!   unpinned operation; they never abort a run.
//!
//! None of this touches arithmetic: affinity and page placement change
//! *where* a chunk is computed, never *what* is computed, so every
//! golden replay checksum holds bit-for-bit under any policy (see
//! `gossip::pool` for why claim order is irrelevant).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Upper bound on CPU ids we can express in an affinity mask
/// (16 × 64-bit words — comfortably above any current host).
const MASK_WORDS: usize = 16;
pub const MAX_CPUS: usize = MASK_WORDS * 64;

/// The CPUs this process may run on, grouped by NUMA node.
#[derive(Debug, Clone)]
pub struct Topology {
    /// `nodes[k]` = sorted CPU ids of the k-th populated NUMA node that
    /// intersects the process's allowed set. Always at least one entry.
    pub nodes: Vec<Vec<usize>>,
}

impl Topology {
    /// Number of NUMA nodes with at least one allowed CPU.
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Total number of allowed CPUs across all nodes.
    pub fn n_cpus(&self) -> usize {
        self.nodes.iter().map(|n| n.len()).sum()
    }

    /// CPU for logical slot `i`, interleaved node-major: slot 0 → first
    /// CPU of node 0, slot 1 → first CPU of node 1, …, wrapping within
    /// each node once every node has been visited. Spreading consecutive
    /// lanes across nodes balances memory bandwidth (each node's
    /// controllers serve an equal share of lanes) and pairs with sticky
    /// chunk claiming so chunk ranges distribute evenly too.
    pub fn cpu_for_slot(&self, slot: usize) -> Option<usize> {
        let nn = self.nodes.len();
        if nn == 0 {
            return None;
        }
        let node = &self.nodes[slot % nn];
        if node.is_empty() {
            return None;
        }
        Some(node[(slot / nn) % node.len()])
    }

    /// NUMA node index that [`cpu_for_slot`](Self::cpu_for_slot) places
    /// slot `i` on.
    pub fn node_of_slot(&self, slot: usize) -> Option<usize> {
        if self.nodes.is_empty() {
            None
        } else {
            Some(slot % self.nodes.len())
        }
    }
}

/// Parse a sysfs cpulist string such as `"0-15,32-47"` or `"0,2,4"`.
///
/// Returns the expanded, sorted CPU ids; malformed fragments are
/// skipped rather than failing the whole list (sysfs is trusted, but a
/// partial parse beats a panic during startup).
pub fn parse_cpu_list(s: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((lo, hi)) = part.split_once('-') {
            if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<usize>(), hi.trim().parse::<usize>()) {
                for c in lo..=hi.min(MAX_CPUS - 1) {
                    cpus.push(c);
                }
            }
        } else if let Ok(c) = part.parse::<usize>() {
            if c < MAX_CPUS {
                cpus.push(c);
            }
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    cpus
}

/// Process-wide topology, discovered once on first use.
pub fn topology() -> &'static Topology {
    static TOPO: OnceLock<Topology> = OnceLock::new();
    TOPO.get_or_init(detect)
}

fn detect() -> Topology {
    let allowed = allowed_cpus().unwrap_or_else(|| {
        let n = std::thread::available_parallelism().map_or(1, |p| p.get());
        (0..n).collect()
    });
    let mut nodes: Vec<(usize, Vec<usize>)> = Vec::new();
    if let Ok(entries) = std::fs::read_dir("/sys/devices/system/node") {
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let Some(idx) = name.strip_prefix("node").and_then(|s| s.parse::<usize>().ok()) else {
                continue;
            };
            let Ok(list) = std::fs::read_to_string(entry.path().join("cpulist")) else {
                continue;
            };
            let cpus: Vec<usize> = parse_cpu_list(&list)
                .into_iter()
                .filter(|c| allowed.binary_search(c).is_ok())
                .collect();
            if !cpus.is_empty() {
                nodes.push((idx, cpus));
            }
        }
    }
    nodes.sort_by_key(|(idx, _)| *idx);
    let nodes: Vec<Vec<usize>> = nodes.into_iter().map(|(_, cpus)| cpus).collect();
    if nodes.is_empty() {
        // No sysfs NUMA tree (macOS, stripped containers): one node.
        Topology {
            nodes: vec![allowed],
        }
    } else {
        Topology { nodes }
    }
}

// ---------------------------------------------------------------------
// Raw affinity syscalls (Linux x86_64 / aarch64); no-ops elsewhere.
// ---------------------------------------------------------------------

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod sys {
    use super::MASK_WORDS;

    #[cfg(target_arch = "x86_64")]
    const NR_SETAFFINITY: usize = 203;
    #[cfg(target_arch = "x86_64")]
    const NR_GETAFFINITY: usize = 204;
    #[cfg(target_arch = "aarch64")]
    const NR_SETAFFINITY: usize = 122;
    #[cfg(target_arch = "aarch64")]
    const NR_GETAFFINITY: usize = 123;

    /// `syscall(nr, pid, len, maskp)` — the shared 3-argument shape of
    /// both affinity syscalls. `pid == 0` targets the calling thread.
    #[cfg(target_arch = "x86_64")]
    #[inline]
    unsafe fn syscall3(nr: usize, a1: usize, a2: usize, a3: usize) -> isize {
        let ret: isize;
        core::arch::asm!(
            "syscall",
            inlateout("rax") nr as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    #[inline]
    unsafe fn syscall3(nr: usize, a1: usize, a2: usize, a3: usize) -> isize {
        let ret: isize;
        core::arch::asm!(
            "svc 0",
            in("x8") nr,
            inlateout("x0") a1 => ret,
            in("x1") a2,
            in("x2") a3,
            options(nostack),
        );
        ret
    }

    /// Affinity mask of the calling thread, or `None` on syscall error.
    pub fn get_mask() -> Option<[u64; MASK_WORDS]> {
        let mut mask = [0u64; MASK_WORDS];
        // On success the kernel returns the number of bytes it copied.
        let r = unsafe {
            syscall3(
                NR_GETAFFINITY,
                0,
                std::mem::size_of_val(&mask),
                mask.as_mut_ptr() as usize,
            )
        };
        (r > 0).then_some(mask)
    }

    /// Set the calling thread's affinity mask; `true` on success.
    pub fn set_mask(mask: &[u64; MASK_WORDS]) -> bool {
        let r = unsafe {
            syscall3(
                NR_SETAFFINITY,
                0,
                std::mem::size_of_val(mask),
                mask.as_ptr() as usize,
            )
        };
        r == 0
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod sys {
    use super::MASK_WORDS;

    // Affinity is best-effort: unsupported targets simply never pin.
    pub fn get_mask() -> Option<[u64; MASK_WORDS]> {
        None
    }

    pub fn set_mask(_mask: &[u64; MASK_WORDS]) -> bool {
        false
    }
}

/// The process's startup affinity mask, captured on first use so
/// [`unpin_current_thread`] can restore it after a temporary pin (the
/// per-node roofline bench pins the timing thread and must put it back).
fn startup_mask() -> Option<&'static [u64; MASK_WORDS]> {
    static MASK: OnceLock<Option<[u64; MASK_WORDS]>> = OnceLock::new();
    MASK.get_or_init(sys::get_mask).as_ref()
}

/// Sorted CPU ids the calling thread is currently allowed to run on, or
/// `None` where affinity is unsupported.
pub fn allowed_cpus() -> Option<Vec<usize>> {
    let mask = sys::get_mask()?;
    let mut cpus = Vec::new();
    for (w, &bits) in mask.iter().enumerate() {
        let mut bits = bits;
        while bits != 0 {
            let b = bits.trailing_zeros() as usize;
            cpus.push(w * 64 + b);
            bits &= bits - 1;
        }
    }
    Some(cpus)
}

static PIN_FAILED_WARNED: AtomicBool = AtomicBool::new(false);

/// Pin the calling thread to a single CPU. Returns `false` — after a
/// one-time stderr warning — if the syscall fails or the target does
/// not support affinity; callers then run unpinned.
pub fn pin_current_thread(cpu: usize) -> bool {
    if cpu >= MAX_CPUS {
        return false;
    }
    // Capture the restore mask before narrowing it.
    let _ = startup_mask();
    let mut mask = [0u64; MASK_WORDS];
    mask[cpu / 64] = 1u64 << (cpu % 64);
    let ok = sys::set_mask(&mask);
    if !ok && !PIN_FAILED_WARNED.swap(true, Ordering::Relaxed) {
        eprintln!(
            "a2cid2: sched_setaffinity(cpu {cpu}) failed or is unsupported; \
             running unpinned (further affinity warnings suppressed)"
        );
    }
    ok
}

/// Restore the calling thread's affinity to the process's startup mask.
pub fn unpin_current_thread() -> bool {
    match startup_mask() {
        Some(mask) => sys::set_mask(mask),
        None => false,
    }
}

// ---------------------------------------------------------------------
// Policy knobs
// ---------------------------------------------------------------------

/// Tri-state of the `A2CID2_PIN` / `A2CID2_NUMA` env knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// `0`: never pin / never first-touch.
    Off,
    /// `1`: always attempt (degrading gracefully on failure).
    On,
    /// unset or `auto`: engage only on multi-node machines.
    Auto,
}

fn parse_policy(raw: Option<&str>, var: &str, warned: &AtomicBool) -> Policy {
    match raw {
        None | Some("") | Some("auto") => Policy::Auto,
        Some("0") => Policy::Off,
        Some("1") => Policy::On,
        Some(other) => {
            if !warned.swap(true, Ordering::Relaxed) {
                eprintln!("a2cid2: ignoring invalid {var}={other:?} (expected 0|1|auto)");
            }
            Policy::Auto
        }
    }
}

/// Parsed `A2CID2_PIN` policy.
pub fn pin_policy() -> Policy {
    static WARNED: AtomicBool = AtomicBool::new(false);
    parse_policy(
        crate::config::env::knobs().pin.as_deref(),
        "A2CID2_PIN",
        &WARNED,
    )
}

/// Parsed `A2CID2_NUMA` policy.
pub fn numa_policy() -> Policy {
    static WARNED: AtomicBool = AtomicBool::new(false);
    parse_policy(
        crate::config::env::knobs().numa.as_deref(),
        "A2CID2_NUMA",
        &WARNED,
    )
}

fn effective(policy: Policy) -> bool {
    match policy {
        Policy::Off => false,
        Policy::On => true,
        Policy::Auto => topology().n_nodes() > 1,
    }
}

/// Should pool lanes (and runtime worker threads) be pinned to cores?
pub fn pin_lanes() -> bool {
    effective(pin_policy())
}

/// Should large [`gossip::pool::AlignedVec`](crate::gossip::pool::AlignedVec)
/// buffers be first-touch-initialized by their owning pool lanes?
pub fn numa_first_touch() -> bool {
    effective(numa_policy())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_list_parser_handles_ranges_singletons_and_garbage() {
        assert_eq!(parse_cpu_list("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpu_list("0,2,4"), vec![0, 2, 4]);
        assert_eq!(parse_cpu_list("0-2,8,10-11\n"), vec![0, 1, 2, 8, 10, 11]);
        assert_eq!(parse_cpu_list(" 5 , 1 - 2 "), vec![1, 2, 5]);
        assert_eq!(parse_cpu_list(""), Vec::<usize>::new());
        assert_eq!(parse_cpu_list("x,3,y-4"), vec![3]);
        // Duplicates collapse.
        assert_eq!(parse_cpu_list("1,1,1-2"), vec![1, 2]);
    }

    #[test]
    fn topology_reports_at_least_one_node_and_cpu() {
        let t = topology();
        assert!(t.n_nodes() >= 1);
        assert!(t.n_cpus() >= 1);
        // Every slot resolves to a CPU that the topology contains.
        let all: Vec<usize> = t.nodes.iter().flatten().copied().collect();
        for slot in 0..t.n_cpus() * 2 + 3 {
            let cpu = t.cpu_for_slot(slot).expect("slot must map to a cpu");
            assert!(all.contains(&cpu));
            assert!(t.node_of_slot(slot).unwrap() < t.n_nodes());
        }
    }

    #[test]
    fn slot_interleave_spreads_across_nodes_round_robin() {
        let t = Topology {
            nodes: vec![vec![0, 1], vec![4, 5]],
        };
        let cpus: Vec<usize> = (0..6).map(|s| t.cpu_for_slot(s).unwrap()).collect();
        assert_eq!(cpus, vec![0, 4, 1, 5, 0, 4]);
    }

    #[test]
    fn pinning_roundtrip_never_panics_and_restores_affinity() {
        // On Linux this pins to the first allowed CPU and restores the
        // startup mask; on other targets both calls are no-ops → false.
        if let Some(cpus) = allowed_cpus() {
            let before = cpus.clone();
            let c = *cpus.first().expect("non-empty allowed set");
            if pin_current_thread(c) {
                assert_eq!(allowed_cpus().unwrap(), vec![c]);
                assert!(unpin_current_thread());
                assert_eq!(allowed_cpus().unwrap(), before);
            }
        } else {
            assert!(!pin_current_thread(0));
            assert!(!unpin_current_thread());
        }
    }

    #[test]
    fn policy_parser_accepts_tri_state_and_warns_on_garbage() {
        let w = AtomicBool::new(false);
        assert_eq!(parse_policy(None, "X", &w), Policy::Auto);
        assert_eq!(parse_policy(Some(""), "X", &w), Policy::Auto);
        assert_eq!(parse_policy(Some("auto"), "X", &w), Policy::Auto);
        assert_eq!(parse_policy(Some("0"), "X", &w), Policy::Off);
        assert_eq!(parse_policy(Some("1"), "X", &w), Policy::On);
        assert!(!w.load(Ordering::Relaxed));
        assert_eq!(parse_policy(Some("yes"), "X", &w), Policy::Auto);
        assert!(w.load(Ordering::Relaxed));
    }
}
