//! `a2cid2` — the launcher.
//!
//! ```text
//! a2cid2 train       [--config cfg.toml] [--workers N] [--algo A] ...
//! a2cid2 spectrum    --topology ring --workers 64 [--rate 1.0]
//! a2cid2 experiment  <id|all> [--filter SUBSTR] [--json PATH]
//! a2cid2 verify      [id|all] [--filter SUBSTR] [--json PATH] [--experiments-json PATH]
//! a2cid2 compare     [--json PATH]            # algorithm zoo head-to-head
//! a2cid2 timeline    [--workers 8] [--rounds 20]
//! a2cid2 replay      [--scenario S] [--dim D] [--out trace.csv]   # determinism probe
//!                    [--checkpoint-at K --checkpoint ck.bin] [--restore ck.bin]
//! a2cid2 serve       --socket /tmp/a2.sock [--workers N --dim D --steps S] [--restore run.ckpt]
//! ```
//!
//! Every subcommand shares ONE option namespace declared once in
//! [`cli`]; per-subcommand [`a2cid2::cli::SubSpec`]s scope which shared
//! options apply, and the usage text (including the experiment id lists)
//! is generated from the experiment registry. Experiments resolve
//! through that registry (`a2cid2::experiments::registry`): `experiment
//! all` runs every registered id, `--filter` narrows by substring, and
//! `--json` writes the consolidated per-experiment artifact
//! (`BENCH_experiments.json`). `verify` runs the same experiments and
//! diffs every headline metric against the checked-in oracle
//! (`rust/oracle/paper.toml`), writing `BENCH_conformance.json` and
//! failing on any out-of-tolerance row (README §Verify). `compare` is a
//! shortcut for `experiment compare` — the update-rule zoo
//! (a2cid2/adpsgd/localsgd/allreduce) head-to-head.

use a2cid2::cli::Cli;
use a2cid2::config::{Algorithm, ExperimentConfig, Method, Scenario, Task};
use a2cid2::experiments::{registry, Scale};
use a2cid2::graph::{Graph, Topology};
use a2cid2::metrics::Table;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cli() -> Cli {
    Cli::new("a2cid2", "asynchronous decentralized training with A2CiD2 momentum")
        .opt("config", "TOML experiment config file", None)
        .opt("workers", "number of workers", Some("8"))
        .opt(
            "topology",
            "complete|ring|exponential|star|path|hypercube|torus:RxC|erdos:p|\
             cluster_ring:KxM|cluster_complete:KxM",
            Some("ring"),
        )
        .opt(
            "scenario",
            "time-varying network, e.g. 'ring@0,exp@0.5;drop=0.2:0.25:0.75;leave=0.25:0.3;join=0.25:0.7;adapt=1' (supersedes --topology)",
            None,
        )
        .opt("method", "allreduce|baseline|a2cid2", Some("a2cid2"))
        .opt(
            "algo",
            "a2cid2|adpsgd|localsgd:H|allreduce — per-event update rule (supersedes --method)",
            None,
        )
        .opt("task", "cifar-like|imagenet-like", Some("cifar-like"))
        .opt("rate", "p2p communications per gradient step", Some("1.0"))
        .opt("steps", "gradient steps per worker", Some("500"))
        .opt("lr", "base learning rate", Some("0.03"))
        .opt("seed", "random seed", Some("0"))
        .opt("rounds", "timeline rounds", Some("20"))
        .opt("dim", "replay: feature dimension of the synthetic model", Some("16"))
        .opt("out", "CSV output path for curves", None)
        .opt("socket", "serve: Unix control socket path", None)
        .opt(
            "checkpoint",
            "replay: write a simulator checkpoint to PATH at --checkpoint-at, then exit",
            None,
        )
        .opt(
            "checkpoint-at",
            "replay: engine tick to checkpoint at (simulated interruption)",
            None,
        )
        .opt(
            "restore",
            "replay: resume from a simulator checkpoint; serve: start from a runtime checkpoint",
            None,
        )
        .opt("filter", "experiment all: only run ids containing SUBSTR", None)
        .opt(
            "json",
            "experiment: write the consolidated per-experiment JSON artifact to PATH; \
             verify: the conformance artifact (default BENCH_conformance.json)",
            None,
        )
        .opt(
            "experiments-json",
            "verify: ALSO write the consolidated per-experiment artifact to PATH \
             (one registry pass yields both artifacts)",
            None,
        )
        .flag("full", "run experiments at paper scale (same as A2CID2_BENCH_FULL=1)")
        .sub(
            "train",
            "run one configuration end to end and print the headline metrics",
            &[
                "config", "workers", "topology", "scenario", "method", "algo", "task", "rate",
                "steps", "lr", "seed", "out",
            ],
            &["full"],
        )
        .sub(
            "spectrum",
            "print a topology's gossip spectrum and the derived (eta, alpha~)",
            &["workers", "topology", "rate"],
            &["full"],
        )
        .sub(
            "experiment",
            format!("run registered experiments by id ({}, all)", registry::known_ids()),
            &["filter", "json"],
            &["full"],
        )
        .sub(
            "verify",
            format!(
                "run experiments and diff them against the paper oracle ({}, all)",
                registry::known_ids()
            ),
            &["filter", "json", "experiments-json"],
            &["full"],
        )
        .sub(
            "compare",
            "algorithm zoo head-to-head (shortcut for `experiment compare`)",
            &["json"],
            &["full"],
        )
        .sub(
            "replay",
            "determinism probe: seeded scenario run + FNV checksum of the averaged parameters",
            &[
                "config", "workers", "topology", "scenario", "method", "algo", "task", "rate",
                "steps", "lr", "seed", "dim", "out", "checkpoint", "checkpoint-at", "restore",
            ],
            &["full"],
        )
        .sub(
            "serve",
            "training-as-a-service daemon: live injection, snapshots, checkpoints over a Unix socket",
            &[
                "workers", "topology", "method", "rate", "steps", "lr", "seed", "dim", "socket",
                "restore",
            ],
            &["full"],
        )
        .sub(
            "timeline",
            "ASCII sync-vs-async worker utilization timelines",
            &["workers", "rounds"],
            &["full"],
        )
}

fn real_main() -> a2cid2::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let spec = cli();
    if argv.is_empty() {
        // usage() ends with the per-subcommand surfaces (generated from
        // the SubSpecs, ids from the registry) — nothing to hand-list.
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(&argv)?;
    if args.has_flag("full") {
        // Pin before anything resolves the env-selected scale; the
        // registry's cell is THE one `Scale::from_env` call site.
        let _ = registry::force_scale(Scale::Full);
    }
    let scale = registry::scale();

    match args.command.as_deref() {
        Some("train") => {
            let cfg = build_config(&args)?;
            println!(
                "training: n={} topology={} method={} task={:?} rate={} steps={}",
                cfg.n_workers,
                cfg.topology.name(),
                cfg.method.name(),
                cfg.task,
                cfg.comm_rate,
                cfg.steps_per_worker
            );
            let out = a2cid2::experiments::train_once(&cfg)?;
            let mut table = Table::new("result", &["metric", "value"]);
            table.row(&["final train loss".into(), format!("{:.4}", out.final_loss)]);
            if let Some(acc) = out.accuracy {
                table.row(&["held-out accuracy".into(), format!("{:.2}%", 100.0 * acc)]);
            }
            table.row(&["virtual time".into(), format!("{:.1}", out.t_end)]);
            table.row(&["total comms".into(), out.n_comms.to_string()]);
            if let Some((c1, c2)) = out.chis {
                table.row(&["chi1 / chi2".into(), format!("{c1:.2} / {c2:.2}")]);
            }
            table.print();
            if let Some(path) = args.get("out") {
                let mut rec = a2cid2::metrics::Recorder::new();
                rec.series.push(out.loss.clone());
                if let Some(c) = &out.consensus {
                    rec.series.push(c.clone());
                }
                rec.write_csv(std::path::Path::new(path), 2000)?;
                println!("curves written to {path}");
            }
        }
        Some("spectrum") => {
            let n: usize = args.get_parse("workers")?;
            let topo = Topology::parse(args.get("topology").unwrap())?;
            let rate: f64 = args.get_parse("rate")?;
            let g = Graph::build(&topo, n)?;
            let s = g.spectrum(rate);
            let p = a2cid2::gossip::AcidParams::from_spectrum(&s);
            let mut table = Table::new(
                format!("{} graph, n={n}, rate={rate}", topo.name()),
                &["quantity", "value"],
            );
            table.row(&["edges".into(), g.edges.len().to_string()]);
            table.row(&["chi1 (Eq.2)".into(), format!("{:.3}", s.chi1)]);
            table.row(&["chi2 (Eq.3)".into(), format!("{:.3}", s.chi2)]);
            table.row(&["sqrt(chi1*chi2)".into(), format!("{:.3}", s.chi_acc())]);
            table.row(&[
                "comms per unit time Tr/2".into(),
                format!("{:.1}", s.comms_per_unit_time()),
            ]);
            table.row(&["A2CiD2 eta".into(), format!("{:.4}", p.eta)]);
            table.row(&["A2CiD2 alpha~".into(), format!("{:.4}", p.alpha_tilde)]);
            table.print();
        }
        Some("experiment") => {
            let id = args
                .positional
                .first()
                .map(|s| s.as_str())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "experiment needs an id (fig1..fig7, tab1..tab6, ablation, scenario, sweep, all)"
                    )
                })?;
            registry::run_cli(
                id,
                args.get("filter"),
                args.get("json").map(std::path::Path::new),
                scale,
            )?;
        }
        Some("verify") => {
            // Paper-conformance gate: run the selected experiments
            // through the registry and diff every headline metric
            // against the checked-in oracle (rust/oracle/paper.toml).
            // Always emits the machine-readable verdict artifact; the
            // process exits non-zero if any check fails.
            let id = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
            let json = args.get("json").unwrap_or("BENCH_conformance.json");
            a2cid2::testing::oracle::verify_cli(
                id,
                args.get("filter"),
                Some(std::path::Path::new(json)),
                args.get("experiments-json").map(std::path::Path::new),
                scale,
            )?;
        }
        Some("compare") => {
            // The algorithm zoo head-to-head is a registered experiment;
            // this subcommand is sugar for `experiment compare`.
            registry::run_cli("compare", None, args.get("json").map(std::path::Path::new), scale)?;
        }
        Some("replay") => {
            // Determinism probe: run a seeded scenario on a synthetic
            // Logistic model whose dimension is a CLI knob, so CI can
            // push it past the chunk-pool threshold (dim features D
            // gives 2·(D+1) parameters; D = 65536 engages the pool) and
            // diff traces + checksums across A2CID2_POOL_THREADS widths.
            // Everything printed is deterministic under --seed.
            let mut cfg = build_config(&args)?;
            cfg.batch_size = 4;
            cfg.dataset_size = 64;
            let dim: usize = args.get_parse("dim")?;
            let ds = std::sync::Arc::new(
                a2cid2::data::GaussianMixture { dim, n_classes: 2, margin: 3.0, sigma: 1.0 }
                    .sample(cfg.dataset_size, cfg.seed ^ 0xD5),
            );
            let shards = cfg.sharding.assign(&ds, cfg.n_workers, cfg.seed);
            let model = std::sync::Arc::new(a2cid2::model::Logistic::new(ds, 0.0));
            use a2cid2::model::Model;
            println!(
                "replay: n={} dim={} (model dim {}, pool {}) steps={} seed={} scenario={}",
                cfg.n_workers,
                dim,
                model.dim(),
                if model.dim() > a2cid2::gossip::pool::POOL_MIN_DIM { "ON" } else { "off" },
                cfg.steps_per_worker,
                cfg.seed,
                cfg.scenario.as_ref().map_or("-".to_string(), |s| s.to_string()),
            );
            let mut engine = a2cid2::simulator::SimEngine::new(&cfg, model, &shards)?;
            if let Some(path) = args.get("restore") {
                // Resume a previously-interrupted run: the constructor
                // rebuilt everything derivable from the config; the
                // checkpoint overwrites the mutable loop state, so the
                // resumed trace is bit-identical to an uninterrupted one.
                let ck = a2cid2::simulator::SimCheckpoint::load(std::path::Path::new(path))?;
                engine.restore(&ck)?;
                println!("replay: restored from {path} (tick {})", engine.ticks_done());
            }
            if let Some(k) = args.get("checkpoint-at") {
                // Simulated interruption: step to tick K, persist the
                // engine state, exit WITHOUT finishing the run.
                let k: u64 = k
                    .parse()
                    .map_err(|e| anyhow::anyhow!("--checkpoint-at must be a tick count: {e}"))?;
                let out = args.get("checkpoint").ok_or_else(|| {
                    anyhow::anyhow!("--checkpoint-at needs --checkpoint PATH to write to")
                })?;
                while engine.ticks_done() < k && engine.step()? {}
                engine.checkpoint().save(std::path::Path::new(out))?;
                println!("replay: checkpointed at tick {} to {out}", engine.ticks_done());
                return Ok(());
            }
            let res = engine.run()?;
            // FNV-1a over the averaged parameters' exact bit patterns:
            // any single-ULP divergence across runs/pool widths flips it.
            let h = a2cid2::runtime::serve::fnv1a_params(&res.avg_params);
            println!(
                "replay: grads={} comms={} net_updates={} checksum={h:016x}",
                res.n_grads, res.n_comms, res.net_updates
            );
            if let Some(path) = args.get("out") {
                res.recorder.write_csv(std::path::Path::new(path), 2000)?;
                println!("trace written to {path}");
            }
        }
        Some("serve") => {
            // Training as a service: the same synthetic Logistic task as
            // `replay`, run on the threaded runtime under a ServeDaemon —
            // inject scenarios, read snapshots, and checkpoint over the
            // Unix control socket; `shutdown` ends the process.
            use a2cid2::model::Model;
            let n: usize = args.get_parse("workers")?;
            let topo = Topology::parse(args.get("topology").unwrap())?;
            let method = Method::parse(args.get("method").unwrap())?;
            let rate: f64 = args.get_parse("rate")?;
            let steps: u64 = args.get_parse("steps")?;
            let lr: f64 = args.get_parse("lr")?;
            let seed: u64 = args.get_parse("seed")?;
            let dim: usize = args.get_parse("dim")?;
            let socket = args
                .get("socket")
                .ok_or_else(|| anyhow::anyhow!("serve needs --socket PATH"))?;
            let graph = std::sync::Arc::new(Graph::build(&topo, n)?);
            let ds = std::sync::Arc::new(
                a2cid2::data::GaussianMixture { dim, n_classes: 2, margin: 3.0, sigma: 1.0 }
                    .sample(64, seed ^ 0xD5),
            );
            let shards = a2cid2::data::Sharding::FullShuffled.assign(&ds, n, seed);
            let model = std::sync::Arc::new(a2cid2::model::Logistic::new(ds, 0.0));
            let init = match args.get("restore") {
                Some(p) => {
                    let ck = a2cid2::runtime::serve::RuntimeCheckpoint::load(
                        std::path::Path::new(p),
                    )?;
                    anyhow::ensure!(
                        ck.n_workers as usize == n && ck.params.len() == model.dim(),
                        "checkpoint {p} is for n={} dim={}, serve was asked for n={n} dim={}",
                        ck.n_workers,
                        ck.params.len(),
                        model.dim()
                    );
                    println!("serve: restored consensus model from {p} (grads={})", ck.grads);
                    ck.params
                }
                None => {
                    let mut rng = a2cid2::rng::Xoshiro256::seed_from_u64(seed);
                    model.init_params(&mut rng)
                }
            };
            let sources: Vec<Box<dyn a2cid2::runtime::GradSource>> = (0..n)
                .map(|w| {
                    Box::new(a2cid2::runtime::RustGradSource::new(
                        model.clone() as std::sync::Arc<dyn Model>,
                        shards.per_worker[w].clone(),
                        4,
                        seed ^ (w as u64),
                    )) as Box<dyn a2cid2::runtime::GradSource>
                })
                .collect();
            let opts = a2cid2::runtime::RuntimeOptions {
                comm_rate: rate,
                method,
                lr: a2cid2::optim::LrSchedule::Constant { lr },
                momentum: 0.9,
                steps_per_worker: steps,
                seed,
                monitor_interval: std::time::Duration::from_millis(20),
                link_delay: None,
                scenario: None,
            };
            println!(
                "serve: n={n} topology={} method={} dim={} steps={steps} socket={socket}",
                topo.name(),
                method.name(),
                model.dim()
            );
            let daemon = a2cid2::runtime::ServeDaemon::start(
                graph,
                sources,
                init,
                opts,
                std::path::Path::new(socket),
            )?;
            println!("serve: listening on {socket}");
            if let Some(r) = daemon.wait()? {
                println!(
                    "serve: run complete: grads={} comms={} net_updates={}",
                    r.grads_per_worker.iter().sum::<u64>(),
                    r.comms_per_worker.iter().sum::<u64>(),
                    r.net_updates
                );
            }
        }
        Some("timeline") => {
            let n: usize = args.get_parse("workers")?;
            let rounds: usize = args.get_parse("rounds")?;
            for (name, is_async) in [("synchronous", false), ("asynchronous", true)] {
                let s = a2cid2::simulator::simulate_timeline(n, rounds, 0.3, 0.15, is_async, 0);
                println!(
                    "{name}: utilization {:.1}%, idle {:.1}, wall {:.1}",
                    100.0 * s.utilization,
                    s.total_idle,
                    s.t_end
                );
                print!("{}", a2cid2::simulator::trace::render_ascii(&s, 72));
            }
        }
        Some(other) => anyhow::bail!("unknown subcommand '{other}'\n\n{}", spec.usage()),
        None => println!("{}", spec.usage()),
    }
    Ok(())
}

fn build_config(args: &a2cid2::cli::Args) -> a2cid2::Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        ExperimentConfig::from_toml(&std::fs::read_to_string(path)?)?
    } else {
        ExperimentConfig::default()
    };
    // CLI overrides.
    cfg.n_workers = args.get_parse("workers")?;
    cfg.topology = Topology::parse(args.get("topology").unwrap())?;
    cfg.method = Method::parse(args.get("method").unwrap())?;
    cfg.task = Task::parse(args.get("task").unwrap())?;
    cfg.comm_rate = args.get_parse("rate")?;
    cfg.steps_per_worker = args.get_parse("steps")?;
    cfg.base_lr = args.get_parse("lr")?;
    cfg.seed = args.get_parse("seed")?;
    if let Some(s) = args.get("scenario") {
        cfg.scenario = Some(Scenario::parse(s)?);
    }
    if let Some(a) = args.get("algo") {
        cfg.algorithm = Some(Algorithm::parse(a)?);
    }
    cfg.validate()
}

