//! Virtual-time discrete-event simulator.
//!
//! The paper's experiments sweep (n, topology, communication rate, method,
//! seed) over dozens of configurations × up to 64 workers. Running every
//! point through the real-thread runtime would be wall-clock-bound, so the
//! experiment harness drives this engine instead: an *exact* simulation of
//! the paper's event model (Assumption 3.2 — unit-rate Poisson gradient
//! clocks per worker, rate-λ^ij Poisson clocks per edge) applying the very
//! same [`crate::gossip::dynamics`] code the runtime uses. The real-thread
//! runtime ([`crate::runtime`]) then validates the same dynamics under true
//! asynchrony on a smaller grid.

mod allreduce;
pub mod checkpoint;
pub mod engine;
pub mod events;
pub mod trace;

pub use allreduce::{allreduce_round_time, run_allreduce, ArResult, ArTimingConfig};
pub use checkpoint::{CheckpointMeta, SimCheckpoint, WorkerCkpt};
pub use engine::{run_simulation, SimEngine, SimResult};
pub use events::{Event, EventKind, EventQueue, EventQueueState};
pub use trace::{simulate_timeline, TimelineStats};
