//! Versioned binary checkpoint format for [`super::SimEngine`].
//!
//! A checkpoint captures every piece of *mutable* loop state — model
//! parameters, A²CiD² momentum rows, optimizer velocities, sampler
//! cursors/RNG streams, the scheduler's event-queue state, and the
//! progress counters. Constructor-derived state (the compiled network
//! plan, the data shards, the LR schedule) is a pure function of the
//! config, so a restore rebuilds it by constructing a fresh engine from
//! the same config and validates the checkpoint's metadata against it.
//!
//! ## Wire format
//!
//! ```text
//! magic   8 bytes   b"A2CKPT01"
//! version u32       1
//! n_sects u32
//! sect*   { tag: u32, len: u64, payload: [u8; len] }
//! ```
//!
//! All integers and floats are little-endian; `f64`/`f32` are stored as
//! raw IEEE-754 bits (NaN-safe — `loss_ema` starts as NaN). Sections
//! are written in tag order; readers index them by tag, so a future
//! version can append sections without breaking old readers of its
//! mandatory prefix. Unknown tags are skipped; a missing mandatory tag
//! or a truncated payload is an error, never UB.
//!
//! Files are written through [`crate::runtime::artifacts::write_atomic`]
//! so a crashed checkpoint never leaves a half-written file at the
//! destination path.

use std::path::Path;

use crate::engine::{SamplerState, SchedulerState};
use crate::gossip::AcidParams;
use crate::simulator::events::EventQueueState;

/// File magic: "A2CKPT" + 2-digit format generation.
pub const MAGIC: &[u8; 8] = b"A2CKPT01";
/// Current format version.
pub const VERSION: u32 = 1;

const TAG_META: u32 = 1;
const TAG_SCHED: u32 = 2;
const TAG_WORKERS: u32 = 3;
const TAG_OPTIMS: u32 = 4;
const TAG_SAMPLERS: u32 = 5;
const TAG_CORE: u32 = 6;
const TAG_PROGRESS: u32 = 7;

/// Identity of the run a checkpoint belongs to. Restore refuses to
/// install state into an engine built from a different config — a
/// silent mismatch would not crash, it would just produce a divergent
/// (and therefore worthless) trace.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CheckpointMeta {
    pub n_workers: u32,
    pub dim: u64,
    pub seed: u64,
    pub steps_per_worker: u64,
    pub batch_size: u32,
    /// `Algorithm` display string (e.g. `a2cid2`, `local-sgd:4`).
    pub algo: String,
}

/// One worker's mutable replica state.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerCkpt {
    pub x: Vec<f32>,
    pub xt: Vec<f32>,
    pub t_last: f64,
    pub n_grads: u64,
    pub n_comms: u64,
    pub grads_at_last_comm: u64,
}

/// Complete mutable state of a paused [`super::SimEngine`].
#[derive(Clone, Debug)]
pub struct SimCheckpoint {
    pub meta: CheckpointMeta,
    pub sched: SchedulerState,
    pub workers: Vec<WorkerCkpt>,
    /// Per-worker SGD velocity buffers (empty = pristine lazily-sized).
    pub velocities: Vec<Vec<f32>>,
    pub samplers: Vec<SamplerState>,
    /// The (η, α, α̃) in effect — adaptive retunes move these mid-run.
    pub acid: AcidParams,
    pub loss_ema: f64,
    pub grads_done: u64,
    pub applied_comms: u64,
    pub ticks_done: u64,
    pub in_fleet: Vec<bool>,
}

// ---------------------------------------------------------------------
// Little-endian byte plumbing. Hand-rolled: the crate deliberately has
// no serde dependency, and the format is simple enough that explicit
// code is clearer than a derive.
// ---------------------------------------------------------------------

struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    fn new() -> Self {
        Self { buf: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }

    fn f64s(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f64(x);
        }
    }

    fn u32s(&mut self, v: &[u32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.u32(x);
        }
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        anyhow::ensure!(
            self.buf.len() - self.pos >= n,
            "truncated checkpoint: wanted {n} bytes at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> crate::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> crate::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> crate::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> crate::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Length-prefixed vector guard: a corrupt length must not turn
    /// into a multi-gigabyte allocation before the truncation check.
    fn len(&mut self, elem_bytes: usize) -> crate::Result<usize> {
        let n = self.u64()? as usize;
        anyhow::ensure!(
            n.saturating_mul(elem_bytes) <= self.buf.len() - self.pos,
            "corrupt checkpoint: length {n} exceeds remaining payload"
        );
        Ok(n)
    }

    fn f32s(&mut self) -> crate::Result<Vec<f32>> {
        let n = self.len(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    fn f64s(&mut self) -> crate::Result<Vec<f64>> {
        let n = self.len(8)?;
        (0..n).map(|_| self.f64()).collect()
    }

    fn u32s(&mut self) -> crate::Result<Vec<u32>> {
        let n = self.len(4)?;
        (0..n).map(|_| self.u32()).collect()
    }

    fn str(&mut self) -> crate::Result<String> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        Ok(std::str::from_utf8(raw)
            .map_err(|e| anyhow::anyhow!("checkpoint string not UTF-8: {e}"))?
            .to_string())
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

impl SimCheckpoint {
    /// Serialize to the versioned section format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut sections: Vec<(u32, Vec<u8>)> = Vec::new();

        let mut w = ByteWriter::new();
        w.u32(self.meta.n_workers);
        w.u64(self.meta.dim);
        w.u64(self.meta.seed);
        w.u64(self.meta.steps_per_worker);
        w.u32(self.meta.batch_size);
        w.str(&self.meta.algo);
        sections.push((TAG_META, w.buf));

        let mut w = ByteWriter::new();
        let q: &EventQueueState = &self.sched.queue;
        w.u64(self.sched.applied);
        w.u64(q.entries.len() as u64);
        for &(t, k, idx, epoch) in &q.entries {
            w.f64(t);
            w.u8(k);
            w.u64(idx as u64);
            w.u32(epoch);
        }
        w.f64s(&q.grad_rates);
        w.f64s(&q.comm_rates);
        w.u32s(&q.grad_epoch);
        w.u32s(&q.comm_epoch);
        for &s in &q.rng {
            w.u64(s);
        }
        w.f64(q.now);
        w.u64(q.n_grad_events);
        w.u64(q.n_comm_events);
        w.u64(q.n_rate_updates);
        sections.push((TAG_SCHED, w.buf));

        let mut w = ByteWriter::new();
        w.u32(self.workers.len() as u32);
        for wk in &self.workers {
            w.f32s(&wk.x);
            w.f32s(&wk.xt);
            w.f64(wk.t_last);
            w.u64(wk.n_grads);
            w.u64(wk.n_comms);
            w.u64(wk.grads_at_last_comm);
        }
        sections.push((TAG_WORKERS, w.buf));

        let mut w = ByteWriter::new();
        w.u32(self.velocities.len() as u32);
        for v in &self.velocities {
            w.f32s(v);
        }
        sections.push((TAG_OPTIMS, w.buf));

        let mut w = ByteWriter::new();
        w.u32(self.samplers.len() as u32);
        for s in &self.samplers {
            w.u64(s.cursor as u64);
            for &x in &s.rng {
                w.u64(x);
            }
        }
        sections.push((TAG_SAMPLERS, w.buf));

        let mut w = ByteWriter::new();
        w.f64(self.acid.eta);
        w.f64(self.acid.alpha);
        w.f64(self.acid.alpha_tilde);
        sections.push((TAG_CORE, w.buf));

        let mut w = ByteWriter::new();
        w.f64(self.loss_ema);
        w.u64(self.grads_done);
        w.u64(self.applied_comms);
        w.u64(self.ticks_done);
        w.u64(self.in_fleet.len() as u64);
        for &b in &self.in_fleet {
            w.u8(b as u8);
        }
        sections.push((TAG_PROGRESS, w.buf));

        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(sections.len() as u32).to_le_bytes());
        for (tag, payload) in &sections {
            out.extend_from_slice(&tag.to_le_bytes());
            out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            out.extend_from_slice(payload);
        }
        out
    }

    /// Parse the versioned section format. Every section payload must be
    /// consumed exactly; unknown tags are skipped (forward-compat room).
    pub fn from_bytes(buf: &[u8]) -> crate::Result<Self> {
        let mut r = ByteReader::new(buf);
        let magic = r.take(8)?;
        anyhow::ensure!(
            magic == MAGIC,
            "not a checkpoint file (bad magic {:02x?})",
            &magic[..magic.len().min(8)]
        );
        let version = r.u32()?;
        anyhow::ensure!(
            version == VERSION,
            "unsupported checkpoint version {version} (this build reads {VERSION})"
        );
        let n_sects = r.u32()?;

        let mut meta: Option<CheckpointMeta> = None;
        let mut sched: Option<SchedulerState> = None;
        let mut workers: Option<Vec<WorkerCkpt>> = None;
        let mut velocities: Option<Vec<Vec<f32>>> = None;
        let mut samplers: Option<Vec<SamplerState>> = None;
        let mut acid: Option<AcidParams> = None;
        let mut progress: Option<(f64, u64, u64, u64, Vec<bool>)> = None;

        for _ in 0..n_sects {
            let tag = r.u32()?;
            let len = r.u64()? as usize;
            let payload = r.take(len)?;
            let mut s = ByteReader::new(payload);
            match tag {
                TAG_META => {
                    meta = Some(CheckpointMeta {
                        n_workers: s.u32()?,
                        dim: s.u64()?,
                        seed: s.u64()?,
                        steps_per_worker: s.u64()?,
                        batch_size: s.u32()?,
                        algo: s.str()?,
                    });
                }
                TAG_SCHED => {
                    let applied = s.u64()?;
                    let n = s.len(8 + 1 + 8 + 4)?;
                    let mut entries = Vec::with_capacity(n);
                    for _ in 0..n {
                        let t = s.f64()?;
                        let k = s.u8()?;
                        let idx = s.u64()? as usize;
                        let epoch = s.u32()?;
                        entries.push((t, k, idx, epoch));
                    }
                    let grad_rates = s.f64s()?;
                    let comm_rates = s.f64s()?;
                    let grad_epoch = s.u32s()?;
                    let comm_epoch = s.u32s()?;
                    let mut rng = [0u64; 4];
                    for slot in &mut rng {
                        *slot = s.u64()?;
                    }
                    let now = s.f64()?;
                    let n_grad_events = s.u64()?;
                    let n_comm_events = s.u64()?;
                    let n_rate_updates = s.u64()?;
                    sched = Some(SchedulerState {
                        queue: EventQueueState {
                            entries,
                            grad_rates,
                            comm_rates,
                            grad_epoch,
                            comm_epoch,
                            rng,
                            now,
                            n_grad_events,
                            n_comm_events,
                            n_rate_updates,
                        },
                        applied,
                    });
                }
                TAG_WORKERS => {
                    let n = s.u32()? as usize;
                    let mut ws = Vec::with_capacity(n);
                    for _ in 0..n {
                        ws.push(WorkerCkpt {
                            x: s.f32s()?,
                            xt: s.f32s()?,
                            t_last: s.f64()?,
                            n_grads: s.u64()?,
                            n_comms: s.u64()?,
                            grads_at_last_comm: s.u64()?,
                        });
                    }
                    workers = Some(ws);
                }
                TAG_OPTIMS => {
                    let n = s.u32()? as usize;
                    let mut vs = Vec::with_capacity(n);
                    for _ in 0..n {
                        vs.push(s.f32s()?);
                    }
                    velocities = Some(vs);
                }
                TAG_SAMPLERS => {
                    let n = s.u32()? as usize;
                    let mut ss = Vec::with_capacity(n);
                    for _ in 0..n {
                        let cursor = s.u64()? as usize;
                        let mut rng = [0u64; 4];
                        for slot in &mut rng {
                            *slot = s.u64()?;
                        }
                        ss.push(SamplerState { cursor, rng });
                    }
                    samplers = Some(ss);
                }
                TAG_CORE => {
                    acid = Some(AcidParams {
                        eta: s.f64()?,
                        alpha: s.f64()?,
                        alpha_tilde: s.f64()?,
                    });
                }
                TAG_PROGRESS => {
                    let loss_ema = s.f64()?;
                    let grads_done = s.u64()?;
                    let applied_comms = s.u64()?;
                    let ticks_done = s.u64()?;
                    let n = s.len(1)?;
                    let raw = s.take(n)?;
                    let in_fleet = raw.iter().map(|&b| b != 0).collect();
                    progress =
                        Some((loss_ema, grads_done, applied_comms, ticks_done, in_fleet));
                }
                // Unknown tag from a newer writer: payload already
                // skipped by the outer take(len).
                _ => continue,
            }
            anyhow::ensure!(
                s.done(),
                "checkpoint section {tag} has {} trailing bytes",
                payload.len() - s.pos
            );
        }

        let missing = |what: &str| anyhow::anyhow!("checkpoint missing mandatory {what} section");
        let (loss_ema, grads_done, applied_comms, ticks_done, in_fleet) =
            progress.ok_or_else(|| missing("progress"))?;
        Ok(SimCheckpoint {
            meta: meta.ok_or_else(|| missing("meta"))?,
            sched: sched.ok_or_else(|| missing("scheduler"))?,
            workers: workers.ok_or_else(|| missing("workers"))?,
            velocities: velocities.ok_or_else(|| missing("optimizers"))?,
            samplers: samplers.ok_or_else(|| missing("samplers"))?,
            acid: acid.ok_or_else(|| missing("core"))?,
            loss_ema,
            grads_done,
            applied_comms,
            ticks_done,
            in_fleet,
        })
    }

    /// Write atomically (unique staging file + rename; see
    /// [`crate::runtime::artifacts::write_atomic`]).
    pub fn save(&self, path: &Path) -> crate::Result<()> {
        crate::runtime::artifacts::write_atomic(path, &self.to_bytes())
    }

    /// Read and parse a checkpoint file.
    pub fn load(path: &Path) -> crate::Result<Self> {
        let buf = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("read checkpoint {}: {e}", path.display()))?;
        Self::from_bytes(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SimCheckpoint {
        SimCheckpoint {
            meta: CheckpointMeta {
                n_workers: 3,
                dim: 4,
                seed: 7,
                steps_per_worker: 50,
                batch_size: 8,
                algo: "a2cid2".to_string(),
            },
            sched: SchedulerState {
                queue: EventQueueState {
                    entries: vec![(0.5, 0, 1, 0), (0.75, 1, 0, 2)],
                    grad_rates: vec![1.0, 0.9, 1.1],
                    comm_rates: vec![0.5, 0.5, 0.5],
                    grad_epoch: vec![0, 0, 1],
                    comm_epoch: vec![2, 0, 0],
                    rng: [1, 2, 3, 4],
                    now: 0.25,
                    n_grad_events: 10,
                    n_comm_events: 5,
                    n_rate_updates: 1,
                },
                applied: 1,
            },
            workers: (0..3)
                .map(|w| WorkerCkpt {
                    x: vec![w as f32; 4],
                    xt: vec![w as f32 + 0.5; 4],
                    t_last: 0.2 * w as f64,
                    n_grads: 3 + w as u64,
                    n_comms: w as u64,
                    grads_at_last_comm: w as u64,
                })
                .collect(),
            velocities: vec![vec![0.1, 0.2, 0.3, 0.4], Vec::new(), vec![1.0; 4]],
            samplers: (0..3)
                .map(|w| SamplerState { cursor: w, rng: [w as u64 + 1, 2, 3, 4] })
                .collect(),
            acid: AcidParams { eta: 1.5, alpha: 0.5, alpha_tilde: 0.7 },
            loss_ema: f64::NAN,
            grads_done: 9,
            applied_comms: 4,
            ticks_done: 14,
            in_fleet: vec![true, false, true],
        }
    }

    fn assert_round_trip_eq(a: &SimCheckpoint, b: &SimCheckpoint) {
        assert_eq!(a.meta, b.meta);
        assert_eq!(a.sched, b.sched);
        assert_eq!(a.workers, b.workers);
        assert_eq!(a.velocities, b.velocities);
        assert_eq!(a.samplers, b.samplers);
        assert_eq!(a.acid.eta.to_bits(), b.acid.eta.to_bits());
        assert_eq!(a.acid.alpha.to_bits(), b.acid.alpha.to_bits());
        assert_eq!(a.acid.alpha_tilde.to_bits(), b.acid.alpha_tilde.to_bits());
        // NaN-safe float comparison: the bits must survive, not the ==.
        assert_eq!(a.loss_ema.to_bits(), b.loss_ema.to_bits());
        assert_eq!(a.grads_done, b.grads_done);
        assert_eq!(a.applied_comms, b.applied_comms);
        assert_eq!(a.ticks_done, b.ticks_done);
        assert_eq!(a.in_fleet, b.in_fleet);
    }

    #[test]
    fn byte_round_trip_is_lossless() {
        let ck = sample();
        let bytes = ck.to_bytes();
        assert_eq!(&bytes[..8], MAGIC);
        let back = SimCheckpoint::from_bytes(&bytes).unwrap();
        assert_round_trip_eq(&ck, &back);
    }

    #[test]
    fn serialization_is_deterministic() {
        let ck = sample();
        assert_eq!(ck.to_bytes(), ck.to_bytes());
    }

    #[test]
    fn rejects_bad_magic_version_and_truncation() {
        let ck = sample();
        let bytes = ck.to_bytes();

        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(SimCheckpoint::from_bytes(&bad).unwrap_err().to_string().contains("magic"));

        let mut bad = bytes.clone();
        bad[8] = 99; // version LE byte 0
        assert!(SimCheckpoint::from_bytes(&bad)
            .unwrap_err()
            .to_string()
            .contains("version"));

        // Every proper prefix must fail cleanly, never panic.
        for cut in [7, 12, 17, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                SimCheckpoint::from_bytes(&bytes[..cut]).is_err(),
                "prefix of {cut} bytes parsed"
            );
        }
    }

    #[test]
    fn corrupt_length_does_not_overallocate() {
        let ck = sample();
        let mut bytes = ck.to_bytes();
        // The workers section starts with a u32 count followed by a
        // u64 x-vector length; smash a plausible interior length field
        // to u64::MAX and require a clean error (the guard compares
        // against remaining payload before allocating).
        let pos = bytes.len() - 9;
        for b in &mut bytes[pos..pos + 8] {
            *b = 0xFF;
        }
        assert!(SimCheckpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn save_load_round_trips_through_atomic_writes() {
        let dir = std::env::temp_dir().join(format!("a2ckpt_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.ckpt");
        let ck = sample();
        ck.save(&path).unwrap();
        // A second save over the same path replaces it atomically.
        ck.save(&path).unwrap();
        let back = SimCheckpoint::load(&path).unwrap();
        assert_round_trip_eq(&ck, &back);
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains("tmp"))
            .collect();
        assert!(leftovers.is_empty(), "staging files left behind: {leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
