//! Synchronous All-Reduce SGD baseline (the paper's AR-SGD).
//!
//! Two aspects are modeled:
//!
//! 1. **Optimization**: classic synchronous data parallelism — every round
//!    each worker computes one mini-batch gradient, gradients are averaged
//!    exactly, everyone applies the same update. Effective batch = n·b
//!    with the Goyal et al. scaled/warmed-up LR, matching the paper.
//! 2. **Time**: a round costs `max_i(compute_i) + allreduce(n, bytes)` —
//!    the barrier makes every round as slow as the slowest worker
//!    (the Straggler Problem the async methods dodge, Tab. 3/6).

use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::data::ShardedIndices;
use crate::metrics::Recorder;
use crate::model::Model;
use crate::optim::{LrSchedule, Sgd};
use crate::rng::{Normal, Xoshiro256};

/// Cost model for one All-Reduce of the parameter vector.
#[derive(Clone, Copy, Debug)]
pub struct ArTimingConfig {
    /// Per-message latency (time units; 1.0 = one gradient computation).
    pub latency: f64,
    /// Transfer time for the full parameter vector between two nodes.
    pub transfer: f64,
}

impl Default for ArTimingConfig {
    fn default() -> Self {
        // Cluster-like (100 Gb/s Omni-Path in the paper): one All-Reduce
        // costs a small fraction of one gradient computation at moderate
        // n; the barrier — not the transfer — dominates the AR penalty.
        Self { latency: 0.002, transfer: 0.02 }
    }
}

/// Ring All-Reduce round time: `2(n−1)` pipeline stages of latency plus
/// `2(n−1)/n` of the full-vector transfer (the standard ring cost).
pub fn allreduce_round_time(n: usize, timing: &ArTimingConfig) -> f64 {
    let n = n as f64;
    2.0 * (n - 1.0) * timing.latency + 2.0 * (n - 1.0) / n * timing.transfer
}

/// Result of a synchronous AR-SGD run.
pub struct ArResult {
    pub recorder: Recorder,
    pub params: Vec<f32>,
    /// Simulated wall time (straggler barrier + all-reduce per round).
    pub t_end: f64,
    pub rounds: u64,
    /// Every worker performs exactly `rounds` gradient steps.
    pub grads_per_worker: u64,
}

impl ArResult {
    pub fn final_loss(&self) -> f64 {
        self.recorder.get("train_loss").map(|s| s.tail_mean(0.1)).unwrap_or(f64::NAN)
    }
}

/// Run synchronous AR-SGD with the same total sample budget as the
/// asynchronous runs (`steps_per_worker` rounds, each consuming n batches).
pub fn run_allreduce(
    cfg: &ExperimentConfig,
    model: Arc<dyn Model>,
    shards: &ShardedIndices,
    timing: &ArTimingConfig,
) -> crate::Result<ArResult> {
    let n = cfg.n_workers;
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let mut params = model.init_params(&mut rng);
    let mut opt = Sgd::new(cfg.momentum as f32);
    let schedule = LrSchedule::paper_cifar_sqrt(cfg.base_lr, n, cfg.steps_per_worker);

    // Fixed per-worker speeds, same straggler model as the async engine.
    let mut speed_dist = Normal::new(1.0, cfg.compute_jitter);
    let speeds: Vec<f64> = (0..n).map(|_| speed_dist.sample(&mut rng).max(0.2)).collect();
    let mut round_noise = Normal::new(0.0, cfg.compute_jitter * 0.3);

    let ar_time = allreduce_round_time(n, timing);
    let mut recorder = Recorder::new();
    let mut t = 0.0f64;
    let mut cursors = vec![0usize; n];
    let mut grad = vec![0.0f32; model.dim()];
    let mut acc_grad = vec![0.0f32; model.dim()];
    let mut batch = Vec::with_capacity(cfg.batch_size);
    let mut loss_ema = f64::NAN;
    let record_every = (cfg.steps_per_worker / 500).max(1);

    for round in 0..cfg.steps_per_worker {
        // --- gradient phase: average the n worker gradients exactly.
        acc_grad.fill(0.0);
        let mut round_loss = 0.0f64;
        let mut slowest = 0.0f64;
        for w in 0..n {
            let shard = &shards.per_worker[w];
            batch.clear();
            for _ in 0..cfg.batch_size {
                cursors[w] = (cursors[w] + 1) % shard.len();
                batch.push(shard[cursors[w]]);
            }
            round_loss += model.loss_grad(&params, &batch, &mut grad) as f64;
            for (a, &g) in acc_grad.iter_mut().zip(&grad) {
                *a += g;
            }
            // Round duration for worker w: 1/speed + noise, barrier = max.
            let dur = (1.0 / speeds[w] + round_noise.sample(&mut rng)).max(0.05);
            slowest = slowest.max(dur);
        }
        let inv_n = 1.0 / n as f32;
        for a in acc_grad.iter_mut() {
            *a *= inv_n;
        }
        round_loss /= n as f64;

        // --- update phase (identical on all replicas).
        let lr = schedule.at(round) as f32;
        let dir = opt.direction(&acc_grad);
        for (p, &d) in params.iter_mut().zip(dir) {
            *p -= lr * d;
        }

        t += slowest + ar_time;
        loss_ema = if loss_ema.is_nan() {
            round_loss
        } else {
            0.95 * loss_ema + 0.05 * round_loss
        };
        if round % record_every == 0 {
            recorder.record("train_loss", t, loss_ema);
            recorder.record("lr", t, lr as f64);
        }
    }

    Ok(ArResult {
        recorder,
        params,
        t_end: t,
        rounds: cfg.steps_per_worker,
        grads_per_worker: cfg.steps_per_worker,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, Task};
    use crate::data::{GaussianMixture, Sharding};
    use crate::graph::Topology;
    use crate::model::Logistic;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig {
            n_workers: 4,
            topology: Topology::Complete,
            method: Method::AllReduce,
            task: Task::CifarLike,
            comm_rate: 1.0,
            batch_size: 8,
            base_lr: 0.02,
            momentum: 0.0,
            weight_decay: 0.0,
            steps_per_worker: 120,
            sharding: Sharding::FullShuffled,
            dataset_size: 256,
            seed: 4,
            compute_jitter: 0.2,
            scenario: None,
            algorithm: None,
        }
    }

    #[test]
    fn ar_converges() {
        let c = cfg();
        let ds = Arc::new(
            GaussianMixture { dim: 8, n_classes: 4, margin: 3.0, sigma: 1.0 }.sample(256, 2),
        );
        let shards = c.sharding.assign(&ds, c.n_workers, 3);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let res = run_allreduce(&c, model.clone(), &shards, &ArTimingConfig::default()).unwrap();
        let s = res.recorder.get("train_loss").unwrap();
        let first = s.points.first().unwrap().1;
        assert!(res.final_loss() < 0.6 * first);
        let idx: Vec<usize> = (0..256).collect();
        assert!(model.accuracy(&res.params, &idx).unwrap() > 0.7);
    }

    #[test]
    fn round_time_scales_with_n() {
        let t = ArTimingConfig::default();
        assert!(allreduce_round_time(64, &t) > allreduce_round_time(8, &t));
        assert!(allreduce_round_time(2, &t) > 0.0);
    }

    #[test]
    fn wall_time_hurts_with_stragglers() {
        // Same run, higher jitter ⇒ strictly larger simulated wall time.
        let mut fast = cfg();
        fast.compute_jitter = 0.0;
        let mut slow = cfg();
        slow.compute_jitter = 0.6;
        let ds = Arc::new(
            GaussianMixture { dim: 8, n_classes: 4, margin: 3.0, sigma: 1.0 }.sample(256, 2),
        );
        let shards = fast.sharding.assign(&ds, fast.n_workers, 3);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let t_fast = run_allreduce(&fast, model.clone(), &shards, &ArTimingConfig::default())
            .unwrap()
            .t_end;
        let t_slow =
            run_allreduce(&slow, model, &shards, &ArTimingConfig::default()).unwrap().t_end;
        assert!(t_slow > t_fast, "{t_slow} vs {t_fast}");
    }
}
