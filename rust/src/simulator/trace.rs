//! Worker-timeline traces and idle-time accounting (Fig. 2).
//!
//! The paper's Fig. 2 contrasts synchronous and asynchronous schedules:
//! synchronous workers idle at every barrier waiting for the slowest peer
//! and must serialize communication after computation, while asynchronous
//! workers compute back-to-back and communicate *in parallel* (one p2p
//! averaging per computation in expectation). This module regenerates that
//! picture quantitatively: per-worker busy/idle segments and aggregate
//! utilization for both schedules under the same speed heterogeneity.

use crate::rng::{Normal, Poisson, Xoshiro256};

/// One worker's timeline segments.
#[derive(Clone, Debug)]
pub struct WorkerTimeline {
    /// `(start, end)` of gradient computations.
    pub compute: Vec<(f64, f64)>,
    /// `(start, end)` of idle (barrier) waits.
    pub idle: Vec<(f64, f64)>,
    /// `(start, end)` of communications that block compute (sync only).
    pub blocking_comm: Vec<(f64, f64)>,
}

/// Aggregate utilization statistics.
#[derive(Clone, Debug)]
pub struct TimelineStats {
    pub timelines: Vec<WorkerTimeline>,
    /// Fraction of wall time spent computing, averaged over workers.
    pub utilization: f64,
    /// Total idle time across workers.
    pub total_idle: f64,
    /// Wall time of the traced window.
    pub t_end: f64,
    /// Gradient computations completed in the window.
    pub n_grads: u64,
    /// Pairwise communications in the window (async: in parallel).
    pub n_comms: u64,
}

/// Simulate `rounds` of the synchronous schedule: compute → barrier →
/// blocking All-Reduce, for `n` workers with speed jitter.
pub fn simulate_timeline(
    n: usize,
    rounds: usize,
    jitter: f64,
    comm_time: f64,
    asynchronous: bool,
    seed: u64,
) -> TimelineStats {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut speed = Normal::new(1.0, jitter);
    let durations: Vec<f64> = (0..n).map(|_| speed.sample(&mut rng).max(0.2)).collect();
    let mut timelines: Vec<WorkerTimeline> = (0..n)
        .map(|_| WorkerTimeline {
            compute: Vec::new(),
            idle: Vec::new(),
            blocking_comm: Vec::new(),
        })
        .collect();
    let mut noise = Normal::new(0.0, jitter * 0.3);
    let mut n_grads = 0u64;
    let mut n_comms = 0u64;

    let t_end = if asynchronous {
        // Each worker computes back-to-back until a common horizon (the
        // paper's fixed total sample budget: fast workers do more steps);
        // the comm thread overlaps, so no idle is charged to the compute
        // lane. Communications are drawn per gradient (Poisson, mean 1)
        // as in the paper's implementation.
        let horizon = rounds as f64; // ~rounds gradients at unit speed
        let comms_per_grad = Poisson::new(1.0);
        for (w, tl) in timelines.iter_mut().enumerate() {
            let mut t = 0.0;
            while t < horizon {
                let d = (1.0 / durations[w] + noise.sample(&mut rng)).max(0.05);
                let end = (t + d).min(horizon);
                tl.compute.push((t, end));
                t += d;
                n_grads += 1;
                n_comms += comms_per_grad.sample(&mut rng);
            }
        }
        // Pairwise comms involve 2 workers each.
        n_comms /= 2;
        horizon
    } else {
        // Synchronous: per round, everyone waits for the slowest, then a
        // blocking All-Reduce of length `comm_time`.
        let mut t = 0.0f64;
        for _ in 0..rounds {
            let durs: Vec<f64> = (0..n)
                .map(|w| (1.0 / durations[w] + noise.sample(&mut rng)).max(0.05))
                .collect();
            let slowest = durs.iter().cloned().fold(0.0, f64::max);
            for (w, tl) in timelines.iter_mut().enumerate() {
                tl.compute.push((t, t + durs[w]));
                if durs[w] < slowest {
                    tl.idle.push((t + durs[w], t + slowest));
                }
                tl.blocking_comm.push((t + slowest, t + slowest + comm_time));
                n_grads += 1;
            }
            n_comms += n as u64; // ring all-reduce ≈ n messages per round
            t += slowest + comm_time;
        }
        t
    };

    let busy: f64 = timelines
        .iter()
        .map(|tl| tl.compute.iter().map(|(s, e)| e - s).sum::<f64>())
        .sum();
    let total_idle: f64 = timelines
        .iter()
        .map(|tl| {
            tl.idle.iter().map(|(s, e)| e - s).sum::<f64>()
                + tl.blocking_comm.iter().map(|(s, e)| e - s).sum::<f64>()
        })
        .sum();
    let utilization = if t_end > 0.0 { busy / (n as f64 * t_end) } else { 0.0 };

    TimelineStats { timelines, utilization, total_idle, t_end, n_grads, n_comms }
}

/// Render a compact ASCII timeline (one row per worker, '#' compute,
/// '.' idle, '~' blocking comm) — the textual Fig. 2.
pub fn render_ascii(stats: &TimelineStats, width: usize) -> String {
    let scale = width as f64 / stats.t_end.max(1e-9);
    let mut out = String::new();
    for (w, tl) in stats.timelines.iter().enumerate() {
        let mut row = vec![' '; width];
        let mut paint = |segs: &[(f64, f64)], c: char| {
            for &(s, e) in segs {
                let a = ((s * scale) as usize).min(width.saturating_sub(1));
                let b = ((e * scale) as usize).min(width);
                for cell in row[a..b].iter_mut() {
                    *cell = c;
                }
            }
        };
        paint(&tl.compute, '#');
        paint(&tl.idle, '.');
        paint(&tl.blocking_comm, '~');
        out.push_str(&format!("w{w:02} |{}|\n", row.into_iter().collect::<String>()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn async_has_higher_utilization_than_sync() {
        let sync = simulate_timeline(8, 20, 0.3, 0.1, false, 1);
        let asyn = simulate_timeline(8, 20, 0.3, 0.1, true, 1);
        assert!(
            asyn.utilization > sync.utilization,
            "async {} vs sync {}",
            asyn.utilization,
            sync.utilization
        );
        // Async charges no idle to the compute lane at all.
        assert_eq!(asyn.total_idle, 0.0);
        assert!(sync.total_idle > 0.0);
    }

    #[test]
    fn sync_rounds_have_barriers() {
        let s = simulate_timeline(4, 5, 0.5, 0.05, false, 2);
        // With jitter, at least one worker idles almost every round.
        let idles: usize = s.timelines.iter().map(|t| t.idle.len()).sum();
        assert!(idles >= 4, "idles={idles}");
        assert_eq!(s.n_grads, 20);
    }

    #[test]
    fn ascii_render_shapes() {
        let s = simulate_timeline(3, 4, 0.2, 0.1, false, 3);
        let art = render_ascii(&s, 40);
        assert_eq!(art.lines().count(), 3);
        assert!(art.contains('#'));
        assert!(art.contains('~'));
    }

    #[test]
    fn counts_scale_with_rounds() {
        // Async runs to a common horizon of `rounds` time units; at unit
        // mean speed each worker lands near `rounds` gradients.
        let a = simulate_timeline(4, 10, 0.2, 0.1, true, 4);
        assert!((25..=60).contains(&a.n_grads), "n_grads={}", a.n_grads);
        // ~1 comm per grad in expectation, halved for pairing.
        assert!(a.n_comms > 5 && a.n_comms < 60, "{}", a.n_comms);
        assert!((a.t_end - 10.0).abs() < 1e-9);
    }
}
