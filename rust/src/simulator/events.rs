//! Poisson event machinery for the virtual-time engine.
//!
//! Assumption 3.2 of the paper: gradient spikes `N_t^i` are unit-rate
//! Poisson processes (one per worker, time renormalized so a worker
//! computes ~1 mini-batch per unit time) and communication spikes
//! `M_t^ij` are Poisson with rate `λ^ij` (one per edge). The engine keeps
//! one next-arrival entry per process in a binary heap and resamples the
//! fired process's next inter-arrival — an exact simulation of the
//! superposed process.
//!
//! Rates are *piecewise-constant in time*: [`EventQueue::set_grad_rate`] /
//! [`EventQueue::set_comm_rate`] retune a process mid-run (the `Scenario`
//! layer's topology switches, link failures and speed drifts). Because
//! Poisson processes are memoryless, resampling the remaining wait at the
//! change time with the new rate is an exact simulation of the
//! inhomogeneous process. Stale heap entries are invalidated lazily via a
//! per-process epoch counter, so a rate update is O(log n).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::rng::{Exponential, Xoshiro256};

/// What fired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Worker `i` finishes a gradient computation.
    Grad { worker: usize },
    /// Edge `e` (index into the graph's edge list) performs a pairwise
    /// averaging.
    Comm { edge: usize },
}

impl EventKind {
    /// Total-order key used for deterministic tie-breaks at equal times:
    /// gradient events before communication events, then by index.
    fn rank(&self) -> (u8, usize) {
        match self {
            EventKind::Grad { worker } => (0, *worker),
            EventKind::Comm { edge } => (1, *edge),
        }
    }
}

/// A scheduled event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub t: f64,
    pub kind: EventKind,
}

// Min-heap ordering on (time, kind): `BinaryHeap` is a max-heap, so both
// components are inverted. `eq` and `cmp` derive from the SAME `(t, kind)`
// key — `a == b ⇔ a.cmp(&b) == Equal` — which the `Ord` contract requires
// (a previous revision compared only `t` in `eq` while `cmp` tie-broke on
// the kind, so equal-by-eq events compared as unequal-by-cmp).
impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.kind == other.kind
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .t
            .partial_cmp(&self.t)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.kind.rank().cmp(&self.kind.rank()))
    }
}

/// Heap slot: an event plus the epoch of its process at scheduling time.
/// Entries whose process has since been retuned are skipped on pop. All
/// comparisons (Eq AND Ord) go through the event alone, keeping the two
/// consistent; the epoch is bookkeeping, not identity.
#[derive(Clone, Copy, Debug)]
struct Entry {
    ev: Event,
    epoch: u32,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.ev == other.ev
    }
}
impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.ev.cmp(&other.ev)
    }
}

/// The resumable position of an [`EventQueue`] — see
/// [`EventQueue::state`]. Heap entries are flattened to
/// `(t, kind-rank, index, epoch)` tuples in canonical sorted order.
#[derive(Clone, Debug, PartialEq)]
pub struct EventQueueState {
    pub entries: Vec<(f64, u8, usize, u32)>,
    pub grad_rates: Vec<f64>,
    pub comm_rates: Vec<f64>,
    pub grad_epoch: Vec<u32>,
    pub comm_epoch: Vec<u32>,
    pub rng: [u64; 4],
    pub now: f64,
    pub n_grad_events: u64,
    pub n_comm_events: u64,
    pub n_rate_updates: u64,
}

/// The superposed Poisson clock over all workers and edges.
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    /// Per-worker gradient-rate samplers (rate 1 by default, scaled by
    /// compute speed for straggler modeling).
    grad_exp: Vec<Exponential>,
    /// Per-edge communication samplers.
    comm_exp: Vec<Exponential>,
    /// Current rates (0 = process disabled).
    grad_rates: Vec<f64>,
    comm_rates: Vec<f64>,
    /// Per-process epochs, bumped by every rate update.
    grad_epoch: Vec<u32>,
    comm_epoch: Vec<u32>,
    rng: Xoshiro256,
    pub now: f64,
    pub n_grad_events: u64,
    pub n_comm_events: u64,
    /// Total rate updates applied (scenario bookkeeping).
    pub n_rate_updates: u64,
}

impl EventQueue {
    /// Build the clock. `grad_rates[i]` is worker i's gradient rate
    /// (1.0 = the paper's homogeneity assumption), `comm_rates[e]` the
    /// per-edge λ (zero-rate edges never fire).
    pub fn new(grad_rates: &[f64], comm_rates: &[f64], seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let grad_exp: Vec<Exponential> = grad_rates
            .iter()
            .map(|&r| Exponential::new(r.max(1e-12)))
            .collect();
        let comm_exp: Vec<Exponential> = comm_rates
            .iter()
            .map(|&r| Exponential::new(r.max(1e-300)))
            .collect();
        let mut heap = BinaryHeap::with_capacity(grad_exp.len() + comm_exp.len());
        for (i, exp) in grad_exp.iter().enumerate() {
            heap.push(Entry {
                ev: Event { t: exp.sample(&mut rng), kind: EventKind::Grad { worker: i } },
                epoch: 0,
            });
        }
        for (e, (exp, &rate)) in comm_exp.iter().zip(comm_rates).enumerate() {
            if rate > 0.0 {
                heap.push(Entry {
                    ev: Event { t: exp.sample(&mut rng), kind: EventKind::Comm { edge: e } },
                    epoch: 0,
                });
            }
        }
        Self {
            heap,
            grad_epoch: vec![0; grad_exp.len()],
            comm_epoch: vec![0; comm_exp.len()],
            grad_rates: grad_rates.to_vec(),
            comm_rates: comm_rates.to_vec(),
            grad_exp,
            comm_exp,
            rng,
            now: 0.0,
            n_grad_events: 0,
            n_comm_events: 0,
            n_rate_updates: 0,
        }
    }

    /// Retune worker `i`'s gradient rate from `now` on. The pending
    /// arrival is discarded and resampled at the new rate (exact, by
    /// memorylessness). A rate of 0 silences the process until retuned.
    pub fn set_grad_rate(&mut self, worker: usize, rate: f64) {
        if self.grad_rates[worker] == rate {
            return;
        }
        self.grad_rates[worker] = rate;
        self.grad_exp[worker] = Exponential::new(rate.max(1e-12));
        self.grad_epoch[worker] = self.grad_epoch[worker].wrapping_add(1);
        self.n_rate_updates += 1;
        if rate > 0.0 {
            let t = self.now + self.grad_exp[worker].sample(&mut self.rng);
            self.heap.push(Entry {
                ev: Event { t, kind: EventKind::Grad { worker } },
                epoch: self.grad_epoch[worker],
            });
        }
    }

    /// Retune edge `e`'s communication rate from `now` on (see
    /// [`EventQueue::set_grad_rate`]).
    pub fn set_comm_rate(&mut self, edge: usize, rate: f64) {
        if self.comm_rates[edge] == rate {
            return;
        }
        self.comm_rates[edge] = rate;
        self.comm_exp[edge] = Exponential::new(rate.max(1e-300));
        self.comm_epoch[edge] = self.comm_epoch[edge].wrapping_add(1);
        self.n_rate_updates += 1;
        if rate > 0.0 {
            let t = self.now + self.comm_exp[edge].sample(&mut self.rng);
            self.heap.push(Entry {
                ev: Event { t, kind: EventKind::Comm { edge } },
                epoch: self.comm_epoch[edge],
            });
        }
    }

    /// Advance the clock to `t` without popping (never moves backwards).
    /// Rate retunes resample from `now`, so a scheduled update must move
    /// the clock to its own timestamp first — otherwise the new rate
    /// would wrongly govern the gap back to the last popped event (and a
    /// freshly activated process could fire *before* the update time).
    /// Safe whenever every live pending event is at or past `t`, which
    /// holds after `next(t)` has returned `None`.
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }

    /// Current rate of edge `e`.
    pub fn comm_rate(&self, edge: usize) -> f64 {
        self.comm_rates[edge]
    }

    /// Current gradient rate of worker `i`.
    pub fn grad_rate(&self, worker: usize) -> f64 {
        self.grad_rates[worker]
    }

    fn is_live(&self, entry: &Entry) -> bool {
        match entry.ev.kind {
            EventKind::Grad { worker } => self.grad_epoch[worker] == entry.epoch,
            EventKind::Comm { edge } => self.comm_epoch[edge] == entry.epoch,
        }
    }

    /// Checkpoint surface: every field that evolves after construction,
    /// with the heap flattened into a canonical sorted order (a
    /// `BinaryHeap`'s internal layout is arbitrary; the multiset of
    /// entries is what determines future pops, since the `(t, kind)` key
    /// is a total order and same-key duplicates are epoch-disambiguated
    /// lazily). The `Exponential` samplers are NOT captured — they are
    /// pure functions of the rates and are rebuilt on restore.
    pub fn state(&self) -> EventQueueState {
        let mut entries: Vec<(f64, u8, usize, u32)> = self
            .heap
            .iter()
            .map(|e| {
                let (k, idx) = e.ev.kind.rank();
                (e.ev.t, k, idx, e.epoch)
            })
            .collect();
        entries.sort_by(|a, b| {
            a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)).then(a.3.cmp(&b.3))
        });
        EventQueueState {
            entries,
            grad_rates: self.grad_rates.clone(),
            comm_rates: self.comm_rates.clone(),
            grad_epoch: self.grad_epoch.clone(),
            comm_epoch: self.comm_epoch.clone(),
            rng: self.rng.state(),
            now: self.now,
            n_grad_events: self.n_grad_events,
            n_comm_events: self.n_comm_events,
            n_rate_updates: self.n_rate_updates,
        }
    }

    /// Restore a queue built over the same process count from a captured
    /// [`EventQueueState`]: rates, epochs, pending arrivals, the RNG
    /// stream position and the clock all resume exactly, so the future
    /// event stream is bit-identical to the uninterrupted run.
    pub fn restore(&mut self, st: &EventQueueState) -> crate::Result<()> {
        anyhow::ensure!(
            st.grad_rates.len() == self.grad_rates.len()
                && st.comm_rates.len() == self.comm_rates.len(),
            "checkpoint process counts ({} grad / {} comm) do not match the plan ({} / {})",
            st.grad_rates.len(),
            st.comm_rates.len(),
            self.grad_rates.len(),
            self.comm_rates.len(),
        );
        self.grad_rates = st.grad_rates.clone();
        self.comm_rates = st.comm_rates.clone();
        self.grad_exp =
            self.grad_rates.iter().map(|&r| Exponential::new(r.max(1e-12))).collect();
        self.comm_exp =
            self.comm_rates.iter().map(|&r| Exponential::new(r.max(1e-300))).collect();
        self.grad_epoch = st.grad_epoch.clone();
        self.comm_epoch = st.comm_epoch.clone();
        self.heap.clear();
        for &(t, kind, idx, epoch) in &st.entries {
            let kind = match kind {
                0 => EventKind::Grad { worker: idx },
                1 => EventKind::Comm { edge: idx },
                other => anyhow::bail!("corrupt checkpoint: event kind tag {other}"),
            };
            anyhow::ensure!(
                match kind {
                    EventKind::Grad { worker } => worker < self.grad_rates.len(),
                    EventKind::Comm { edge } => edge < self.comm_rates.len(),
                },
                "corrupt checkpoint: event index out of range"
            );
            self.heap.push(Entry { ev: Event { t, kind }, epoch });
        }
        self.rng.restore(st.rng);
        self.now = st.now;
        self.n_grad_events = st.n_grad_events;
        self.n_comm_events = st.n_comm_events;
        self.n_rate_updates = st.n_rate_updates;
        Ok(())
    }

    /// Pop the next event before `horizon`; reschedules the fired process.
    pub fn next(&mut self, horizon: f64) -> Option<Event> {
        loop {
            let entry = *self.heap.peek()?;
            if !self.is_live(&entry) {
                self.heap.pop();
                continue;
            }
            let ev = entry.ev;
            if ev.t > horizon {
                return None;
            }
            self.heap.pop();
            self.now = ev.t;
            let next_t = match ev.kind {
                EventKind::Grad { worker } => {
                    self.n_grad_events += 1;
                    ev.t + self.grad_exp[worker].sample(&mut self.rng)
                }
                EventKind::Comm { edge } => {
                    self.n_comm_events += 1;
                    ev.t + self.comm_exp[edge].sample(&mut self.rng)
                }
            };
            self.heap.push(Entry { ev: Event { t: next_t, kind: ev.kind }, epoch: entry.epoch });
            return Some(ev);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_are_time_ordered() {
        let mut q = EventQueue::new(&[1.0, 1.0], &[0.5], 1);
        let mut last = 0.0;
        for _ in 0..1000 {
            let ev = q.next(f64::INFINITY).unwrap();
            assert!(ev.t >= last);
            last = ev.t;
        }
    }

    #[test]
    fn rates_are_respected() {
        // 2 workers at rate 1, 1 edge at rate 3 → over horizon T expect
        // ~2T grads and ~3T comms.
        let mut q = EventQueue::new(&[1.0, 1.0], &[3.0], 2);
        while q.next(1000.0).is_some() {}
        let g = q.n_grad_events as f64;
        let c = q.n_comm_events as f64;
        assert!((g / 2000.0 - 1.0).abs() < 0.1, "grads={g}");
        assert!((c / 3000.0 - 1.0).abs() < 0.1, "comms={c}");
    }

    #[test]
    fn horizon_respected() {
        let mut q = EventQueue::new(&[1.0], &[1.0], 3);
        while let Some(ev) = q.next(10.0) {
            assert!(ev.t <= 10.0);
        }
        assert!(q.next(10.0).is_none());
    }

    #[test]
    fn zero_rate_edges_never_fire() {
        let mut q = EventQueue::new(&[1.0], &[0.0, 2.0], 4);
        let mut fired_edge0 = false;
        while let Some(ev) = q.next(100.0) {
            if let EventKind::Comm { edge: 0 } = ev.kind {
                fired_edge0 = true;
            }
        }
        assert!(!fired_edge0);
    }

    #[test]
    fn deterministic_replay() {
        let run = |seed: u64| -> Vec<(f64, EventKind)> {
            let mut q = EventQueue::new(&[1.0, 2.0], &[0.7, 1.3], seed);
            let mut out = Vec::new();
            while let Some(ev) = q.next(20.0) {
                out.push((ev.t, ev.kind));
            }
            out
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn straggler_rates_shift_counts() {
        // Worker 1 computes at half speed → about half the gradient count.
        let mut q = EventQueue::new(&[1.0, 0.5], &[], 5);
        let mut counts = [0u64; 2];
        while let Some(ev) = q.next(2000.0) {
            if let EventKind::Grad { worker } = ev.kind {
                counts[worker] += 1;
            }
        }
        let ratio = counts[1] as f64 / counts[0] as f64;
        assert!((ratio - 0.5).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn ord_and_eq_agree_on_the_same_key() {
        // The Ord contract: a == b ⇔ cmp(a, b) == Equal. Same time,
        // different kind must be unequal under BOTH.
        let a = Event { t: 1.0, kind: EventKind::Grad { worker: 0 } };
        let b = Event { t: 1.0, kind: EventKind::Comm { edge: 0 } };
        let c = Event { t: 1.0, kind: EventKind::Grad { worker: 0 } };
        assert_ne!(a, b);
        assert_ne!(a.cmp(&b), Ordering::Equal);
        assert_eq!(a, c);
        assert_eq!(a.cmp(&c), Ordering::Equal);
        // Deterministic tie-break: at equal t, grads pop before comms
        // (max-heap ⇒ "greater" pops first).
        assert!(a > b);
        // Earlier time still dominates the kind tie-break.
        let later = Event { t: 2.0, kind: EventKind::Grad { worker: 0 } };
        assert!(a > later);
    }

    #[test]
    fn rate_update_silences_and_revives_a_process() {
        let mut q = EventQueue::new(&[1.0], &[2.0], 6);
        // Drain a while with the edge live.
        let mut comms_before = 0;
        while let Some(ev) = q.next(50.0) {
            if matches!(ev.kind, EventKind::Comm { .. }) {
                comms_before += 1;
            }
        }
        assert!(comms_before > 50, "edge fired at rate 2: {comms_before}");
        // Silence the edge: no comm events in the next window.
        q.set_comm_rate(0, 0.0);
        while let Some(ev) = q.next(100.0) {
            assert!(
                !matches!(ev.kind, EventKind::Comm { .. }),
                "silenced edge fired at t={}",
                ev.t
            );
        }
        // Revive at a higher rate: comms come back, roughly 4/unit time.
        q.set_comm_rate(0, 4.0);
        let mut comms_after = 0;
        while let Some(ev) = q.next(200.0) {
            if matches!(ev.kind, EventKind::Comm { .. }) {
                comms_after += 1;
            }
        }
        let per_unit = comms_after as f64 / 100.0;
        assert!((per_unit - 4.0).abs() < 0.8, "revived rate ≈ 4, got {per_unit}");
        assert_eq!(q.n_rate_updates, 2);
    }

    #[test]
    fn grad_rate_update_shifts_counts() {
        let mut q = EventQueue::new(&[1.0, 1.0], &[], 8);
        while q.next(100.0).is_some() {}
        let g0 = q.n_grad_events;
        // Triple worker 0, halve worker 1: total rate 1+1 → 3+0.5.
        q.set_grad_rate(0, 3.0);
        q.set_grad_rate(1, 0.5);
        let mut counts = [0u64; 2];
        while let Some(ev) = q.next(600.0) {
            if let EventKind::Grad { worker } = ev.kind {
                counts[worker] += 1;
            }
        }
        assert!(q.n_grad_events > g0);
        let ratio = counts[0] as f64 / counts[1] as f64;
        assert!((ratio - 6.0).abs() < 1.0, "rate ratio 6, got {ratio}");
    }

    #[test]
    fn rate_updates_replay_deterministically() {
        let run = |seed: u64| {
            let mut q = EventQueue::new(&[1.0, 1.0], &[1.0, 1.0], seed);
            let mut out = Vec::new();
            while let Some(ev) = q.next(10.0) {
                out.push((ev.t, ev.kind));
            }
            q.set_comm_rate(0, 0.0);
            q.set_comm_rate(1, 3.0);
            q.set_grad_rate(0, 2.0);
            while let Some(ev) = q.next(20.0) {
                out.push((ev.t, ev.kind));
            }
            out
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }

    #[test]
    fn advanced_clock_gates_retuned_processes() {
        // A scheduled update must not let the new rate govern the gap
        // back to the last popped event: after advance_to(T), a revived
        // process's first arrival is at or after T — for EVERY seed.
        for seed in 0..50 {
            let mut q = EventQueue::new(&[1.0], &[0.0], seed);
            while q.next(25.0).is_some() {}
            q.advance_to(25.0);
            q.set_comm_rate(0, 100.0); // high rate → early fire if buggy
            let first_comm = std::iter::from_fn(|| q.next(30.0))
                .find(|ev| matches!(ev.kind, EventKind::Comm { .. }))
                .expect("rate-100 edge fires fast");
            assert!(first_comm.t >= 25.0, "seed {seed}: fired at {}", first_comm.t);
        }
    }

    #[test]
    fn rate_update_at_switch_timestamp_lands_in_new_phase() {
        // PR 1's ordering contract: a scheduled update advances the clock
        // to its own timestamp and THEN retunes, so the new rate governs
        // [t_switch, ∞). Regression: an update whose timestamp coincides
        // exactly with the window boundary the queue just drained to must
        // be applied to the NEW phase — epoch-bumped, resampled from
        // exactly t_switch — not dropped and not back-dated. Checked for
        // both the comm and the grad paths, across seeds.
        for seed in 0..20 {
            let mut q = EventQueue::new(&[1.0], &[0.5], seed);
            while q.next(40.0).is_some() {}
            q.advance_to(40.0); // now == t_switch exactly
            q.set_comm_rate(0, 8.0);
            q.set_grad_rate(0, 4.0);
            assert_eq!(q.n_rate_updates, 2, "boundary updates must not be dropped");
            let (mut comms, mut grads) = (0u64, 0u64);
            while let Some(ev) = q.next(90.0) {
                assert!(ev.t >= 40.0, "seed {seed}: event back-dated to {}", ev.t);
                match ev.kind {
                    EventKind::Comm { .. } => comms += 1,
                    EventKind::Grad { .. } => grads += 1,
                }
            }
            // 50 time units at the NEW rates: ≈ 400 comms / 200 grads.
            // The old rates (0.5 / 1) would give ≈ 25 / 50 — far outside
            // the windows below.
            assert!((300..520).contains(&comms), "seed {seed}: comms={comms}");
            assert!((140..270).contains(&grads), "seed {seed}: grads={grads}");
        }
    }

    #[test]
    fn coinciding_updates_at_one_timestamp_last_write_wins() {
        // A phase switch and a dropout boundary can land on the same
        // change point; the compiler merges them, but the queue must also
        // be safe under two retunes of one process at the same clock
        // reading: the first retune's pending entry is epoch-invalidated
        // by the second, so no event from the intermediate rate leaks.
        for seed in 0..20 {
            let mut q = EventQueue::new(&[], &[1.0], seed);
            while q.next(10.0).is_some() {}
            q.advance_to(10.0);
            q.set_comm_rate(0, 500.0); // intermediate (would flood)
            q.set_comm_rate(0, 0.5); // final
            let mut comms = 0u64;
            while let Some(ev) = q.next(110.0) {
                assert!(ev.t >= 10.0, "seed {seed}");
                comms += 1;
            }
            // 100 units at rate 0.5 ⇒ ≈ 50 events; a surviving rate-500
            // entry would add a burst and an immediate resample cascade.
            assert!((20..100).contains(&comms), "seed {seed}: comms={comms}");
            assert_eq!(q.n_rate_updates, 2);
        }
    }

    #[test]
    fn state_round_trip_resumes_the_event_stream() {
        // Drain a while (including a mid-run retune so epochs and stale
        // heap entries are in play), snapshot, keep draining, then
        // restore a FRESH queue and check the tails agree exactly.
        let mut q = EventQueue::new(&[1.0, 2.0], &[0.7, 1.3], 11);
        while q.next(10.0).is_some() {}
        q.advance_to(10.0);
        q.set_comm_rate(0, 3.0);
        q.set_grad_rate(1, 0.5);
        while q.next(15.0).is_some() {}
        let st = q.state();
        let tail: Vec<(u64, EventKind)> = std::iter::from_fn(|| q.next(40.0))
            .map(|ev| (ev.t.to_bits(), ev.kind))
            .collect();
        assert!(!tail.is_empty());
        // Restore into a queue built fresh from the ORIGINAL construction
        // parameters — the restore-by-reconstruction contract.
        let mut r = EventQueue::new(&[1.0, 2.0], &[0.7, 1.3], 999);
        r.restore(&st).unwrap();
        assert_eq!(r.now.to_bits(), st.now.to_bits());
        let resumed: Vec<(u64, EventKind)> = std::iter::from_fn(|| r.next(40.0))
            .map(|ev| (ev.t.to_bits(), ev.kind))
            .collect();
        assert_eq!(tail, resumed, "bit-identical resumed event stream");
        assert_eq!(q.n_grad_events, r.n_grad_events);
        assert_eq!(q.n_comm_events, r.n_comm_events);
        // Mismatched process counts are rejected, not silently truncated.
        let mut wrong = EventQueue::new(&[1.0], &[0.7], 0);
        assert!(wrong.restore(&st).is_err());
    }

    #[test]
    fn noop_rate_update_is_free() {
        let mut q = EventQueue::new(&[1.0], &[2.0], 9);
        q.set_comm_rate(0, 2.0);
        q.set_grad_rate(0, 1.0);
        assert_eq!(q.n_rate_updates, 0);
    }
}
