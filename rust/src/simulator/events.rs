//! Poisson event machinery for the virtual-time engine.
//!
//! Assumption 3.2 of the paper: gradient spikes `N_t^i` are unit-rate
//! Poisson processes (one per worker, time renormalized so a worker
//! computes ~1 mini-batch per unit time) and communication spikes
//! `M_t^ij` are Poisson with rate `λ^ij` (one per edge). The engine keeps
//! one next-arrival entry per process in a binary heap and resamples the
//! fired process's next inter-arrival — an exact simulation of the
//! superposed process.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::rng::{Exponential, Xoshiro256};

/// What fired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Worker `i` finishes a gradient computation.
    Grad { worker: usize },
    /// Edge `e` (index into the graph's edge list) performs a pairwise
    /// averaging.
    Comm { edge: usize },
}

/// A scheduled event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub t: f64,
    pub kind: EventKind,
}

// Min-heap ordering on time (BinaryHeap is a max-heap, so invert).
impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .t
            .partial_cmp(&self.t)
            .unwrap_or(Ordering::Equal)
            .then_with(|| match (&self.kind, &other.kind) {
                // Deterministic tie-break for reproducibility.
                (EventKind::Grad { worker: a }, EventKind::Grad { worker: b }) => b.cmp(a),
                (EventKind::Comm { edge: a }, EventKind::Comm { edge: b }) => b.cmp(a),
                (EventKind::Grad { .. }, EventKind::Comm { .. }) => Ordering::Greater,
                (EventKind::Comm { .. }, EventKind::Grad { .. }) => Ordering::Less,
            })
    }
}

/// The superposed Poisson clock over all workers and edges.
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    /// Per-worker gradient-rate samplers (rate 1 by default, scaled by
    /// compute speed for straggler modeling).
    grad_exp: Vec<Exponential>,
    /// Per-edge communication samplers.
    comm_exp: Vec<Exponential>,
    rng: Xoshiro256,
    pub now: f64,
    pub n_grad_events: u64,
    pub n_comm_events: u64,
}

impl EventQueue {
    /// Build the clock. `grad_rates[i]` is worker i's gradient rate
    /// (1.0 = the paper's homogeneity assumption), `comm_rates[e]` the
    /// per-edge λ (zero-rate edges never fire).
    pub fn new(grad_rates: &[f64], comm_rates: &[f64], seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let grad_exp: Vec<Exponential> = grad_rates
            .iter()
            .map(|&r| Exponential::new(r.max(1e-12)))
            .collect();
        let comm_exp: Vec<Exponential> = comm_rates
            .iter()
            .map(|&r| Exponential::new(r.max(1e-300)))
            .collect();
        let mut heap = BinaryHeap::with_capacity(grad_exp.len() + comm_exp.len());
        for (i, exp) in grad_exp.iter().enumerate() {
            heap.push(Event { t: exp.sample(&mut rng), kind: EventKind::Grad { worker: i } });
        }
        for (e, (exp, &rate)) in comm_exp.iter().zip(comm_rates).enumerate() {
            if rate > 0.0 {
                heap.push(Event { t: exp.sample(&mut rng), kind: EventKind::Comm { edge: e } });
            }
        }
        Self {
            heap,
            grad_exp,
            comm_exp,
            rng,
            now: 0.0,
            n_grad_events: 0,
            n_comm_events: 0,
        }
    }

    /// Pop the next event before `horizon`; reschedules the fired process.
    pub fn next(&mut self, horizon: f64) -> Option<Event> {
        let ev = *self.heap.peek()?;
        if ev.t > horizon {
            return None;
        }
        self.heap.pop();
        self.now = ev.t;
        let next_t = match ev.kind {
            EventKind::Grad { worker } => {
                self.n_grad_events += 1;
                ev.t + self.grad_exp[worker].sample(&mut self.rng)
            }
            EventKind::Comm { edge } => {
                self.n_comm_events += 1;
                ev.t + self.comm_exp[edge].sample(&mut self.rng)
            }
        };
        self.heap.push(Event { t: next_t, kind: ev.kind });
        Some(ev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_are_time_ordered() {
        let mut q = EventQueue::new(&[1.0, 1.0], &[0.5], 1);
        let mut last = 0.0;
        for _ in 0..1000 {
            let ev = q.next(f64::INFINITY).unwrap();
            assert!(ev.t >= last);
            last = ev.t;
        }
    }

    #[test]
    fn rates_are_respected() {
        // 2 workers at rate 1, 1 edge at rate 3 → over horizon T expect
        // ~2T grads and ~3T comms.
        let mut q = EventQueue::new(&[1.0, 1.0], &[3.0], 2);
        while q.next(1000.0).is_some() {}
        let g = q.n_grad_events as f64;
        let c = q.n_comm_events as f64;
        assert!((g / 2000.0 - 1.0).abs() < 0.1, "grads={g}");
        assert!((c / 3000.0 - 1.0).abs() < 0.1, "comms={c}");
    }

    #[test]
    fn horizon_respected() {
        let mut q = EventQueue::new(&[1.0], &[1.0], 3);
        while let Some(ev) = q.next(10.0) {
            assert!(ev.t <= 10.0);
        }
        assert!(q.next(10.0).is_none());
    }

    #[test]
    fn zero_rate_edges_never_fire() {
        let mut q = EventQueue::new(&[1.0], &[0.0, 2.0], 4);
        let mut fired_edge0 = false;
        while let Some(ev) = q.next(100.0) {
            if let EventKind::Comm { edge: 0 } = ev.kind {
                fired_edge0 = true;
            }
        }
        assert!(!fired_edge0);
    }

    #[test]
    fn deterministic_replay() {
        let run = |seed: u64| -> Vec<(f64, EventKind)> {
            let mut q = EventQueue::new(&[1.0, 2.0], &[0.7, 1.3], seed);
            let mut out = Vec::new();
            while let Some(ev) = q.next(20.0) {
                out.push((ev.t, ev.kind));
            }
            out
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn straggler_rates_shift_counts() {
        // Worker 1 computes at half speed → about half the gradient count.
        let mut q = EventQueue::new(&[1.0, 0.5], &[], 5);
        let mut counts = [0u64; 2];
        while let Some(ev) = q.next(2000.0) {
            if let EventKind::Grad { worker } = ev.kind {
                counts[worker] += 1;
            }
        }
        let ratio = counts[1] as f64 / counts[0] as f64;
        assert!((ratio - 0.5).abs() < 0.05, "ratio={ratio}");
    }
}
