//! The asynchronous decentralized training loop in virtual time.
//!
//! The loop is a thin driver now: the [`VirtualTimeScheduler`] decides
//! *when* (exact superposed Poisson clocks, interleaved with a scenario's
//! network updates) and the shared [`DynamicsCore`] decides *what* (the
//! Eq. 4 per-event updates) — the very same core the real-thread runtime
//! drives, so nothing is implemented twice.

use std::sync::Arc;

use crate::config::{Algorithm, ExperimentConfig, NetworkPlan};
use crate::data::ShardedIndices;
use crate::engine::{BatchSampler, DynamicsCore, LossEma, Tick, VirtualTimeScheduler};
use crate::gossip::consensus_distance;
use crate::gossip::dynamics::WorkerState;
use crate::gossip::AcidParams;
use crate::graph::{Graph, Spectrum};
use crate::metrics::Recorder;
use crate::model::Model;
use crate::optim::{LrSchedule, Sgd};
use crate::rng::{Normal, Xoshiro256};
use crate::simulator::checkpoint::{CheckpointMeta, SimCheckpoint, WorkerCkpt};
use crate::util::two_mut;

/// Outcome of one simulated run.
pub struct SimResult {
    /// Time series: `train_loss`, `consensus`, `lr`.
    pub recorder: Recorder,
    /// Final per-worker states (post run, pre averaging).
    pub workers: Vec<WorkerState>,
    /// Network-averaged parameters (the paper's final All-Reduce).
    pub avg_params: Vec<f32>,
    /// Spectral summary of the rate-weighted Laplacian used.
    pub spectrum: Spectrum,
    /// The (η, α, α̃) actually applied.
    pub acid: AcidParams,
    /// Total gradient / communication event counts.
    pub n_grads: u64,
    pub n_comms: u64,
    /// Scenario network updates applied during the run.
    pub net_updates: u64,
    /// Virtual time at the end of the run.
    pub t_end: f64,
    /// Per-worker gradient-step counts (straggler statistics, Tab. 6).
    pub grads_per_worker: Vec<u64>,
}

impl SimResult {
    /// Training-loss tail mean (robust "final loss" for tables).
    pub fn final_loss(&self) -> f64 {
        self.recorder.get("train_loss").map(|s| s.tail_mean(0.1)).unwrap_or(f64::NAN)
    }

    /// Final consensus distance.
    pub fn final_consensus(&self) -> f64 {
        self.recorder
            .get("consensus")
            .and_then(|s| s.last())
            .map(|(_, v)| v)
            .unwrap_or(f64::NAN)
    }
}

/// The virtual-time event loop as a steppable object.
///
/// [`run_simulation`] used to own the whole loop in one function body;
/// the serve daemon's checkpoint/restore needs to *pause* the loop at an
/// event boundary, serialize every piece of mutable state, and later
/// rebuild an engine that continues bit-identically. `SimEngine` holds
/// that state explicitly:
///
/// * [`SimEngine::new`] reproduces the exact construction (and RNG call)
///   order of the original function, so a fresh engine from the same
///   config is bit-identical to the pre-refactor loop;
/// * [`SimEngine::step`] executes exactly one scheduler tick (changes
///   drained first, then the Grad/Comm arm);
/// * [`SimEngine::checkpoint`] / [`SimEngine::restore`] capture and
///   reinstall the mutable state between ticks — constructor-time state
///   (plan, spectrum, shards, LR schedule) is deliberately NOT captured:
///   it is a pure function of the config and is rebuilt by constructing
///   a fresh engine from the same config before restoring.
///
/// The metrics [`Recorder`] is NOT part of a checkpoint: a resumed run
/// re-records only the tail of the series. The final parameters (and
/// hence the replay checksum) are unaffected — they never read the
/// recorder.
pub struct SimEngine {
    cfg: ExperimentConfig,
    model: Arc<dyn Model>,
    plan: NetworkPlan,
    spectrum: Spectrum,
    core: DynamicsCore,
    adaptive: bool,
    sched: VirtualTimeScheduler,
    workers: Vec<WorkerState>,
    optims: Vec<Sgd>,
    samplers: Vec<BatchSampler>,
    total_grads: u64,
    recorder: Recorder,
    grad: Vec<f32>,
    loss_ema: f64,
    grads_done: u64,
    applied_comms: u64,
    record_every: u64,
    in_fleet: Vec<bool>,
    /// Scheduler ticks executed so far (grad + comm). The unit the CLI's
    /// `--checkpoint-at K` counts in.
    ticks_done: u64,
}

impl SimEngine {
    /// Build a fresh engine. Construction order (and in particular the
    /// order of draws against the seeded RNG) matches the historical
    /// `run_simulation` body exactly — bit-compatibility with every
    /// golden checksum depends on it.
    pub fn new(
        cfg: &ExperimentConfig,
        model: Arc<dyn Model>,
        shards: &ShardedIndices,
    ) -> crate::Result<Self> {
        let algo = cfg.algo();
        anyhow::ensure!(
            algo != Algorithm::AllReduce,
            "run_simulation is for the asynchronous algorithms; use run_allreduce"
        );
        let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
        // Straggler model: per-worker compute speed ~ N(1, jitter), floored.
        let mut speed_dist = Normal::new(1.0, cfg.compute_jitter);
        let grad_rates: Vec<f64> = (0..cfg.n_workers)
            .map(|_| speed_dist.sample(&mut rng).max(0.2))
            .collect();

        // The network plan: either the static topology or a compiled
        // scenario (horizon = expected per-worker steps at unit rate).
        let plan = match &cfg.scenario {
            Some(sc) => sc.compile(
                cfg.n_workers,
                cfg.comm_rate,
                cfg.steps_per_worker as f64,
                &grad_rates,
            )?,
            None => NetworkPlan::static_plan(
                Graph::build(&cfg.topology, cfg.n_workers)?,
                cfg.comm_rate,
                &grad_rates,
            ),
        };
        let spectrum = plan.spectrum;
        let schedule =
            LrSchedule::paper_cifar_sqrt(cfg.base_lr, cfg.n_workers, cfg.steps_per_worker);
        let core = DynamicsCore::for_algorithm(algo, &spectrum, schedule)?;
        // Adaptive (η, α̃): scenario updates that change the phase or the
        // worker set carry the active subgraph's (χ₁, χ₂) unless the
        // scenario was compiled with `adapt=0`.
        let adaptive = cfg.scenario.as_ref().is_some_and(|s| s.adaptive);
        let sched = VirtualTimeScheduler::new(&plan, cfg.seed ^ 0x5EED);

        // Worker states: identical init (the paper's initial All-Reduce).
        let init = model.init_params(&mut rng);
        let workers: Vec<WorkerState> =
            (0..cfg.n_workers).map(|_| WorkerState::new(init.clone())).collect();
        let optims: Vec<Sgd> = (0..cfg.n_workers)
            .map(|_| Sgd::new(cfg.momentum as f32))
            .collect();
        let samplers: Vec<BatchSampler> = (0..cfg.n_workers)
            .map(|w| BatchSampler::new(shards.per_worker[w].clone(), rng.split(w as u64)))
            .collect();

        let total_grads = cfg.steps_per_worker * cfg.n_workers as u64;
        let grad = vec![0.0f32; model.dim()];
        // Record ~500 points per series regardless of run length.
        let record_every = (total_grads / 500).max(1);
        let n = cfg.n_workers;

        Ok(Self {
            cfg: cfg.clone(),
            model,
            plan,
            spectrum,
            core,
            adaptive,
            sched,
            workers,
            optims,
            samplers,
            total_grads,
            recorder: Recorder::new(),
            grad,
            loss_ema: f64::NAN,
            grads_done: 0,
            // Communication events actually APPLIED (pacing rules like
            // local SGD skip proposed pairings; for always-admitting
            // rules this equals the scheduler's proposal count, keeping
            // the series bit-identical).
            applied_comms: 0,
            record_every,
            // Churn bookkeeping: which workers are currently in the
            // fleet (the donor for a re-join is the smallest-index
            // active union neighbor — the same rule the runtime's
            // monitor applies).
            in_fleet: vec![true; n],
            ticks_done: 0,
        })
    }

    /// True once the gradient budget is exhausted and [`SimEngine::step`]
    /// will do nothing more.
    pub fn done(&self) -> bool {
        self.grads_done >= self.total_grads
    }

    /// Scheduler ticks executed so far.
    pub fn ticks_done(&self) -> u64 {
        self.ticks_done
    }

    /// Gradient events executed so far (out of
    /// `n_workers × steps_per_worker`).
    pub fn grads_done(&self) -> u64 {
        self.grads_done
    }

    /// Execute one scheduler tick. Returns `Ok(false)` once the total
    /// gradient budget is reached (the engine is then ready for
    /// [`SimEngine::finish`]).
    pub fn step(&mut self) -> crate::Result<bool> {
        if self.grads_done >= self.total_grads {
            return Ok(false);
        }
        let tick = self
            .sched
            .next()
            .ok_or_else(|| anyhow::anyhow!("event queue drained unexpectedly"))?;
        // Process scheduler-recorded changes BEFORE the popped tick:
        // every change has a timestamp at or before the tick's, so churn
        // re-inits and retunes stay event-ordered.
        for ch in self.sched.drain_changes() {
            for &w in &ch.left {
                self.in_fleet[w] = false;
            }
            for &j in &ch.joined {
                let donor = self
                    .plan
                    .union
                    .neighbors(j)
                    .iter()
                    .copied()
                    .find(|&d| self.in_fleet[d]);
                if let Some(d) = donor {
                    let donor_x = self.workers[d].x.clone();
                    self.core.rejoin_from(&mut self.workers[j], &donor_x, ch.t);
                }
            }
            for &j in &ch.joined {
                self.in_fleet[j] = true;
            }
            if self.adaptive {
                if let Some((c1, c2)) = ch.chis {
                    self.core.retune(c1, c2);
                }
            }
        }
        match tick {
            Tick::Grad { worker, t } => {
                let batch = self.samplers[worker].next_batch(self.cfg.batch_size);
                let loss =
                    self.model.loss_grad(&self.workers[worker].x, batch, &mut self.grad) as f64;
                let lr = self.core.grad_event(
                    &mut self.workers[worker],
                    t,
                    &mut self.optims[worker],
                    &self.grad,
                );
                self.loss_ema = LossEma::fold(self.loss_ema, loss, 0.98);
                self.grads_done += 1;
                if self.grads_done % self.record_every == 0 {
                    self.recorder.record("train_loss", t, self.loss_ema);
                    self.recorder.record("lr", t, lr as f64);
                    // Communication cost so far, aligned with the loss
                    // samples — the sweep reads "comm events to target
                    // loss" off these two series.
                    self.recorder.record("comms", t, self.applied_comms as f64);
                }
                if self.grads_done % (self.record_every * 10) == 0 {
                    self.recorder.record("consensus", t, consensus_distance(&self.workers));
                }
            }
            Tick::Comm { i, j, t } => {
                let (a, b) = two_mut(&mut self.workers, i, j);
                if self.core.comm_event(a, b, t) {
                    self.applied_comms += 1;
                }
            }
        }
        self.ticks_done += 1;
        Ok(true)
    }

    /// Close out the run: sync all workers to the final time (completes
    /// the lazy mixing), then take the final consensus + average (the
    /// paper's closing All-Reduce).
    pub fn finish(mut self) -> SimResult {
        let t_end = self.sched.now();
        self.core.sync_all(&mut self.workers, t_end);
        self.recorder.record("consensus", t_end, consensus_distance(&self.workers));
        let avg_params = crate::gossip::consensus::average_params(&self.workers);
        let grads_per_worker: Vec<u64> = self.workers.iter().map(|w| w.n_grads).collect();

        SimResult {
            recorder: self.recorder,
            avg_params,
            spectrum: self.spectrum,
            acid: self.core.acid,
            n_grads: self.sched.n_grad_events(),
            n_comms: self.applied_comms,
            net_updates: crate::engine::Scheduler::updates_applied(&self.sched),
            t_end,
            grads_per_worker,
            workers: self.workers,
        }
    }

    /// Drive the loop to completion.
    pub fn run(mut self) -> crate::Result<SimResult> {
        while self.step()? {}
        Ok(self.finish())
    }

    /// Capture every piece of mutable loop state into a
    /// [`SimCheckpoint`]. Must be called between ticks (which is the only
    /// time caller code can run). Constructor-derived state — the plan,
    /// the shards, the LR schedule — is identified by config metadata
    /// instead of being serialized; [`SimEngine::restore`] validates the
    /// metadata against the rebuilt engine.
    pub fn checkpoint(&self) -> SimCheckpoint {
        SimCheckpoint {
            meta: CheckpointMeta {
                n_workers: self.cfg.n_workers as u32,
                dim: self.model.dim() as u64,
                seed: self.cfg.seed,
                steps_per_worker: self.cfg.steps_per_worker,
                batch_size: self.cfg.batch_size as u32,
                algo: self.cfg.algo().to_string(),
            },
            sched: self.sched.state(),
            workers: self
                .workers
                .iter()
                .map(|w| WorkerCkpt {
                    x: w.x.to_vec(),
                    xt: w.xt.to_vec(),
                    t_last: w.t_last,
                    n_grads: w.n_grads,
                    n_comms: w.n_comms,
                    grads_at_last_comm: w.grads_at_last_comm,
                })
                .collect(),
            velocities: self.optims.iter().map(|o| o.velocity().to_vec()).collect(),
            samplers: self.samplers.iter().map(|s| s.state()).collect(),
            acid: self.core.acid,
            loss_ema: self.loss_ema,
            grads_done: self.grads_done,
            applied_comms: self.applied_comms,
            ticks_done: self.ticks_done,
            in_fleet: self.in_fleet.clone(),
        }
    }

    /// Reinstall checkpointed state into a freshly constructed engine.
    /// The engine must have been built from the same config + model +
    /// shards the checkpoint was taken under; metadata mismatches are
    /// rejected rather than silently producing a divergent trace.
    pub fn restore(&mut self, ck: &SimCheckpoint) -> crate::Result<()> {
        let m = &ck.meta;
        anyhow::ensure!(
            m.n_workers as usize == self.cfg.n_workers
                && m.dim as usize == self.model.dim()
                && m.seed == self.cfg.seed
                && m.steps_per_worker == self.cfg.steps_per_worker
                && m.batch_size as u32 == self.cfg.batch_size as u32
                && m.algo == self.cfg.algo().to_string(),
            "checkpoint metadata does not match this run's config: \
             checkpoint (n={}, dim={}, seed={}, steps={}, batch={}, algo={}) \
             vs config (n={}, dim={}, seed={}, steps={}, batch={}, algo={})",
            m.n_workers,
            m.dim,
            m.seed,
            m.steps_per_worker,
            m.batch_size,
            m.algo,
            self.cfg.n_workers,
            self.model.dim(),
            self.cfg.seed,
            self.cfg.steps_per_worker,
            self.cfg.batch_size,
            self.cfg.algo(),
        );
        anyhow::ensure!(
            ck.workers.len() == self.workers.len()
                && ck.velocities.len() == self.optims.len()
                && ck.samplers.len() == self.samplers.len()
                && ck.in_fleet.len() == self.in_fleet.len(),
            "checkpoint worker-set size mismatch"
        );
        for w in &ck.workers {
            anyhow::ensure!(
                w.x.len() == self.model.dim() && w.xt.len() == self.model.dim(),
                "checkpoint parameter dimension mismatch"
            );
        }
        self.sched.restore(&ck.sched)?;
        for (dst, src) in self.workers.iter_mut().zip(&ck.workers) {
            dst.x.copy_from_slice(&src.x);
            dst.xt.copy_from_slice(&src.xt);
            dst.t_last = src.t_last;
            dst.n_grads = src.n_grads;
            dst.n_comms = src.n_comms;
            dst.grads_at_last_comm = src.grads_at_last_comm;
        }
        for (opt, v) in self.optims.iter_mut().zip(&ck.velocities) {
            opt.restore_velocity(v);
        }
        for (s, st) in self.samplers.iter_mut().zip(&ck.samplers) {
            s.restore(st);
        }
        self.core.set_params(ck.acid);
        self.loss_ema = ck.loss_ema;
        self.grads_done = ck.grads_done;
        self.applied_comms = ck.applied_comms;
        self.ticks_done = ck.ticks_done;
        self.in_fleet.copy_from_slice(&ck.in_fleet);
        Ok(())
    }
}

/// Run the asynchronous decentralized dynamic of Eq. 4 in virtual time.
///
/// * `cfg.algo()` picks the update rule — A²CiD² (Prop. 3.6 parameters),
///   AD-PSGD averaging (η = 0), or paced local SGD;
///   [`Algorithm::AllReduce`] is rejected — use [`super::run_allreduce`].
/// * `cfg.scenario` (if set) supersedes `cfg.topology` with a compiled
///   time-varying network plan, replayed deterministically under the seed.
/// * Terminates when the total number of gradient events reaches
///   `n_workers × steps_per_worker` (the paper fixes the total sample
///   budget, not the per-worker step count).
pub fn run_simulation(
    cfg: &ExperimentConfig,
    model: Arc<dyn Model>,
    shards: &ShardedIndices,
) -> crate::Result<SimResult> {
    SimEngine::new(cfg, model, shards)?.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, Scenario, Task};
    use crate::data::{GaussianMixture, Sharding};
    use crate::graph::Topology;
    use crate::model::Logistic;

    fn small_cfg(method: Method) -> ExperimentConfig {
        ExperimentConfig {
            n_workers: 4,
            topology: Topology::Ring,
            method,
            task: Task::CifarLike,
            comm_rate: 1.0,
            batch_size: 8,
            base_lr: 0.02,
            momentum: 0.0,
            weight_decay: 0.0,
            steps_per_worker: 150,
            sharding: Sharding::FullShuffled,
            dataset_size: 256,
            seed: 1,
            compute_jitter: 0.1,
            scenario: None,
            algorithm: None,
        }
    }

    fn run_cfg(cfg: &ExperimentConfig) -> (SimResult, Arc<Logistic>) {
        let ds = Arc::new(
            GaussianMixture { dim: 8, n_classes: 4, margin: 3.0, sigma: 1.0 }
                .sample(cfg.dataset_size, 2),
        );
        let shards = cfg.sharding.assign(&ds, cfg.n_workers, 3);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let res = run_simulation(cfg, model.clone(), &shards).unwrap();
        (res, model)
    }

    fn run(method: Method) -> (SimResult, Arc<Logistic>) {
        run_cfg(&small_cfg(method))
    }

    #[test]
    fn training_reduces_loss() {
        let (res, model) = run(Method::AsyncBaseline);
        let s = res.recorder.get("train_loss").unwrap();
        let first = s.points.first().unwrap().1;
        let last = s.tail_mean(0.2);
        assert!(last < 0.6 * first, "loss {first} -> {last}");
        // Averaged model classifies above chance.
        let idx: Vec<usize> = (0..256).collect();
        let acc = model.accuracy(&res.avg_params, &idx).unwrap();
        assert!(acc > 0.7, "acc={acc}");
    }

    #[test]
    fn event_counts_match_rates() {
        let (res, _) = run(Method::AsyncBaseline);
        // 4 workers × 150 steps target.
        assert_eq!(res.grads_per_worker.iter().sum::<u64>(), 600);
        // comm events ≈ rate·n/2 per unit time × t_end (ring, rate 1).
        let expected = 0.5 * 4.0 * res.t_end;
        let ratio = res.n_comms as f64 / expected;
        assert!((0.6..1.4).contains(&ratio), "comms={} expected≈{expected}", res.n_comms);
        assert_eq!(res.net_updates, 0, "static run has no network updates");
    }

    #[test]
    fn acid_runs_and_tracks_consensus() {
        let (res, _) = run(Method::Acid);
        assert!(res.acid.is_accelerated());
        let c = res.recorder.get("consensus").unwrap();
        assert!(c.points.len() > 5);
        assert!(c.points.iter().all(|(_, v)| v.is_finite()));
        // Consensus stays bounded (no divergence).
        let max = c.points.iter().map(|(_, v)| *v).fold(0.0, f64::max);
        assert!(max < 100.0, "consensus exploded: {max}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, _) = run(Method::Acid);
        let (b, _) = run(Method::Acid);
        assert_eq!(a.avg_params, b.avg_params);
        assert_eq!(a.n_comms, b.n_comms);
    }

    #[test]
    fn deterministic_given_seed_at_pool_scale() {
        // dim crosses gossip::pool::POOL_MIN_DIM, so every grad/comm
        // event actually shards across the chunk pool — a non-vacuous
        // check that fixed chunk boundaries keep the engine
        // bit-deterministic (the small-dim determinism tests above never
        // enter the pooled path).
        use crate::gossip::pool::POOL_MIN_DIM;
        let mut cfg = small_cfg(Method::Acid);
        cfg.n_workers = 3;
        cfg.steps_per_worker = 8;
        cfg.batch_size = 2;
        cfg.dataset_size = 48;
        let feat = POOL_MIN_DIM / 2; // Logistic dim = 2·(feat+1) > POOL_MIN_DIM
        let ds = Arc::new(
            GaussianMixture { dim: feat, n_classes: 2, margin: 3.0, sigma: 1.0 }
                .sample(cfg.dataset_size, 2),
        );
        let shards = cfg.sharding.assign(&ds, cfg.n_workers, 3);
        let model = Arc::new(Logistic::new(ds, 0.0));
        assert!(model.dim() > POOL_MIN_DIM, "dim {} must shard", model.dim());
        let a = run_simulation(&cfg, model.clone(), &shards).unwrap();
        let b = run_simulation(&cfg, model, &shards).unwrap();
        assert_eq!(a.avg_params, b.avg_params);
        assert_eq!(a.n_comms, b.n_comms);
    }

    #[test]
    fn straggler_spread_in_grad_counts() {
        let mut cfg = small_cfg(Method::AsyncBaseline);
        cfg.compute_jitter = 0.5;
        cfg.n_workers = 8;
        let ds = Arc::new(
            GaussianMixture { dim: 8, n_classes: 4, margin: 3.0, sigma: 1.0 }.sample(256, 2),
        );
        let shards = cfg.sharding.assign(&ds, cfg.n_workers, 3);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let res = run_simulation(&cfg, model, &shards).unwrap();
        let min = *res.grads_per_worker.iter().min().unwrap();
        let max = *res.grads_per_worker.iter().max().unwrap();
        // Asynchrony: slow workers do fewer steps (Tab. 6's #∇ spread).
        assert!(max > min, "expected straggler spread, got uniform {min}");
    }

    #[test]
    fn localsgd_algorithm_paces_communication() {
        // Same seed ⇒ same proposed event stream; the H = 4 gate must
        // skip a visible fraction of the pairings while the gradient
        // budget stays identical.
        let cfg = small_cfg(Method::AsyncBaseline);
        let (base, _) = run_cfg(&cfg);
        let mut paced_cfg = cfg.clone();
        paced_cfg.algorithm = Some(Algorithm::LocalSgd { h: 4 });
        let (paced, _) = run_cfg(&paced_cfg);
        assert!(
            paced.n_comms < base.n_comms,
            "gate must skip pairings: {} vs {}",
            paced.n_comms,
            base.n_comms
        );
        assert!(paced.n_comms > 0, "but not all of them");
        assert_eq!(paced.grads_per_worker.iter().sum::<u64>(), 600);
        assert!(!paced.acid.is_accelerated(), "local SGD averages with η = 0");
    }

    #[test]
    fn rejects_allreduce_method() {
        let cfg = small_cfg(Method::AllReduce);
        let ds = Arc::new(GaussianMixture::cifar_like().sample(128, 1));
        let shards = cfg.sharding.assign(&ds, cfg.n_workers, 3);
        let model = Arc::new(Logistic::new(ds, 0.0));
        assert!(run_simulation(&cfg, model, &shards).is_err());
    }

    #[test]
    fn scenario_run_applies_updates_and_still_trains() {
        let mut cfg = small_cfg(Method::Acid);
        cfg.n_workers = 8;
        cfg.scenario = Some(
            Scenario::parse("ring@0,exponential@0.5;drop=0.2:0.25:0.75:7").unwrap(),
        );
        let (res, model) = run_cfg(&cfg);
        assert!(res.net_updates >= 3, "switch + drop + recover: {}", res.net_updates);
        let s = res.recorder.get("train_loss").unwrap();
        let first = s.points.first().unwrap().1;
        assert!(res.final_loss() < 0.8 * first, "still trains through the switch");
        let idx: Vec<usize> = (0..256).collect();
        assert!(model.accuracy(&res.avg_params, &idx).unwrap() > 0.5);
    }

    #[test]
    fn churn_scenario_trains_and_skews_step_counts() {
        let mut cfg = small_cfg(Method::Acid);
        cfg.n_workers = 8;
        cfg.compute_jitter = 0.0;
        cfg.scenario =
            Some(Scenario::parse("ring@0;leave=0.25:0.25:3;join=0.25:0.75").unwrap());
        let (res, _) = run_cfg(&cfg);
        assert!(res.net_updates >= 2, "leave + join: {}", res.net_updates);
        // Identify the churned workers from the compiled plan and check
        // they did measurably fewer local steps than the always-on fleet
        // (they were silenced for half the run).
        let plan = cfg
            .scenario
            .as_ref()
            .unwrap()
            .compile(8, 1.0, cfg.steps_per_worker as f64, &[1.0; 8])
            .unwrap();
        let churned = &plan.updates[0].leave;
        assert_eq!(churned.len(), 2);
        let avg_stay: f64 = (0..8)
            .filter(|w| !churned.contains(w))
            .map(|w| res.grads_per_worker[w] as f64)
            .sum::<f64>()
            / 6.0;
        for &w in churned {
            assert!(
                (res.grads_per_worker[w] as f64) < 0.8 * avg_stay,
                "churned worker {w} did {} steps vs {avg_stay:.0} average",
                res.grads_per_worker[w]
            );
        }
        // Training survives the churn.
        let s = res.recorder.get("train_loss").unwrap();
        let first = s.points.first().unwrap().1;
        assert!(res.final_loss() < 0.8 * first);
        // The comms series is recorded and monotone.
        let comms = res.recorder.get("comms").unwrap();
        assert!(comms.points.windows(2).all(|w| w[0].1 <= w[1].1));
    }

    #[test]
    fn churn_scenario_is_bit_deterministic() {
        let mut cfg = small_cfg(Method::Acid);
        cfg.n_workers = 8;
        cfg.scenario = Some(
            Scenario::parse(
                "ring@0,exponential@0.5;leave=0.25:0.2:5;join=0.25:0.7;drop=0.2:0.3:0.6:7",
            )
            .unwrap(),
        );
        let (a, _) = run_cfg(&cfg);
        let (b, _) = run_cfg(&cfg);
        assert_eq!(a.avg_params, b.avg_params, "bit-identical churn replay");
        assert_eq!(a.n_comms, b.n_comms);
        assert_eq!(a.net_updates, b.net_updates);
        assert_eq!(a.acid, b.acid, "adaptive retunes replay identically");
    }

    #[test]
    fn adaptive_params_retune_and_frozen_hold() {
        let mut cfg = small_cfg(Method::Acid);
        cfg.n_workers = 8;
        cfg.scenario = Some(Scenario::parse("ring@0,complete@0.5").unwrap());
        let (res, _) = run_cfg(&cfg);
        // On the complete graph χ₁ = χ₂ ⇒ α̃ = ½ exactly.
        assert!(res.acid.is_accelerated());
        assert!(
            (res.acid.alpha_tilde - 0.5).abs() < 1e-5,
            "final params follow the active phase: {:?}",
            res.acid
        );
        // adapt=0 pins the ring-derived values for the whole run, and the
        // trajectories genuinely differ.
        let mut frozen_cfg = cfg.clone();
        frozen_cfg.scenario =
            Some(Scenario::parse("ring@0,complete@0.5;adapt=0").unwrap());
        let (frozen, _) = run_cfg(&frozen_cfg);
        assert!(res.spectrum.chi1 > res.spectrum.chi2 + 1e-6, "ring: chi1 > chi2");
        assert!(
            frozen.acid.alpha_tilde > 0.5 + 1e-6,
            "frozen keeps phase-0 ring params: {:?}",
            frozen.acid
        );
        assert_ne!(res.avg_params, frozen.avg_params);
        // The baseline ignores spectra entirely, adaptive or not.
        let mut base_cfg = cfg.clone();
        base_cfg.method = Method::AsyncBaseline;
        let (base, _) = run_cfg(&base_cfg);
        assert!(!base.acid.is_accelerated());
    }

    #[test]
    fn stepped_engine_matches_run_simulation() {
        // The refactor contract: driving SimEngine tick by tick is the
        // same computation as the one-shot wrapper, bit for bit.
        let cfg = small_cfg(Method::Acid);
        let ds = Arc::new(
            GaussianMixture { dim: 8, n_classes: 4, margin: 3.0, sigma: 1.0 }
                .sample(cfg.dataset_size, 2),
        );
        let shards = cfg.sharding.assign(&ds, cfg.n_workers, 3);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let one_shot = run_simulation(&cfg, model.clone(), &shards).unwrap();
        let mut eng = SimEngine::new(&cfg, model, &shards).unwrap();
        while eng.step().unwrap() {}
        assert!(eng.done());
        let stepped = eng.finish();
        assert_eq!(one_shot.avg_params, stepped.avg_params);
        assert_eq!(one_shot.n_comms, stepped.n_comms);
        assert_eq!(one_shot.t_end.to_bits(), stepped.t_end.to_bits());
    }

    #[test]
    fn checkpoint_restore_resumes_bit_identically() {
        // The tentpole invariant: a run interrupted at tick K and resumed
        // from a (serialized!) checkpoint produces the exact bytes of an
        // uninterrupted run — through churn, adaptive retunes, and
        // momentum. Exercised again at pool scale + across processes by
        // tests/integration_replay.rs.
        let mut cfg = small_cfg(Method::Acid);
        cfg.n_workers = 8;
        cfg.momentum = 0.9;
        cfg.scenario = Some(
            Scenario::parse("ring@0,exponential@0.5;leave=0.25:0.3:1;join=0.25:0.7")
                .unwrap(),
        );
        let ds = Arc::new(
            GaussianMixture { dim: 8, n_classes: 4, margin: 3.0, sigma: 1.0 }
                .sample(cfg.dataset_size, 2),
        );
        let shards = cfg.sharding.assign(&ds, cfg.n_workers, 3);
        let model = Arc::new(Logistic::new(ds, 0.0));

        let base = run_simulation(&cfg, model.clone(), &shards).unwrap();

        let mut eng = SimEngine::new(&cfg, model.clone(), &shards).unwrap();
        for _ in 0..600 {
            assert!(eng.step().unwrap());
        }
        // Round-trip the checkpoint through its wire format, then throw
        // the interrupted engine away entirely.
        let bytes = eng.checkpoint().to_bytes();
        drop(eng);
        let ck = SimCheckpoint::from_bytes(&bytes).unwrap();
        assert_eq!(ck.ticks_done, 600);

        let mut resumed = SimEngine::new(&cfg, model, &shards).unwrap();
        resumed.restore(&ck).unwrap();
        let res = resumed.run().unwrap();

        assert_eq!(base.avg_params, res.avg_params, "resumed trace diverged");
        assert_eq!(base.n_comms, res.n_comms);
        assert_eq!(base.n_grads, res.n_grads);
        assert_eq!(base.net_updates, res.net_updates);
        assert_eq!(base.t_end.to_bits(), res.t_end.to_bits());
        assert_eq!(base.acid, res.acid);
        for (a, b) in base.workers.iter().zip(&res.workers) {
            assert_eq!(a.n_grads, b.n_grads);
            assert_eq!(a.n_comms, b.n_comms);
        }
    }

    #[test]
    fn restore_rejects_mismatched_config() {
        let cfg = small_cfg(Method::Acid);
        let ds = Arc::new(
            GaussianMixture { dim: 8, n_classes: 4, margin: 3.0, sigma: 1.0 }
                .sample(cfg.dataset_size, 2),
        );
        let shards = cfg.sharding.assign(&ds, cfg.n_workers, 3);
        let model = Arc::new(Logistic::new(ds, 0.0));
        let mut eng = SimEngine::new(&cfg, model.clone(), &shards).unwrap();
        for _ in 0..10 {
            eng.step().unwrap();
        }
        let ck = eng.checkpoint();
        // Different seed ⇒ different run identity ⇒ refuse.
        let mut other_cfg = cfg.clone();
        other_cfg.seed = 99;
        let mut other = SimEngine::new(&other_cfg, model, &shards).unwrap();
        let err = other.restore(&ck).unwrap_err().to_string();
        assert!(err.contains("metadata"), "unexpected error: {err}");
    }

    #[test]
    fn scenario_run_is_deterministic() {
        let mut cfg = small_cfg(Method::AsyncBaseline);
        cfg.scenario =
            Some(Scenario::parse("ring@0,complete@0.5;drop=0.25:0.2:0.8:3;drift=0.3:4:1").unwrap());
        let (a, _) = run_cfg(&cfg);
        let (b, _) = run_cfg(&cfg);
        assert_eq!(a.avg_params, b.avg_params);
        assert_eq!(a.n_comms, b.n_comms);
        assert_eq!(a.net_updates, b.net_updates);
        assert!(a.net_updates > 0);
    }
}
