//! Mini property-testing harness.
//!
//! `proptest` is not reachable offline (DESIGN.md §3), so this module
//! provides the slice of it the test suite needs: run a property over many
//! seeded random cases and report the failing seed so a failure is
//! reproducible with `PROP_SEED=<seed> cargo test <name>`.

use crate::rng::Xoshiro256;

/// Number of cases per property (override with env `PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `property` over `cases` RNG-seeded inputs. The closure receives a
/// fresh RNG per case and must panic on violation; the harness wraps the
/// panic with the case seed.
pub fn check(name: &str, cases: usize, property: impl Fn(&mut Xoshiro256)) {
    let base: u64 = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA2C1D2);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with PROP_SEED={base} (case offset {case})"
            );
        }
    }
}

/// Uniform float in `[lo, hi)`.
pub fn f64_in(rng: &mut Xoshiro256, lo: f64, hi: f64) -> f64 {
    lo + (hi - lo) * rng.next_f64()
}

/// Uniform usize in `[lo, hi)`.
pub fn usize_in(rng: &mut Xoshiro256, lo: usize, hi: usize) -> usize {
    lo + rng.gen_range(hi - lo)
}

/// A random f32 vector with entries in `[-scale, scale]`.
pub fn vec_f32(rng: &mut Xoshiro256, len: usize, scale: f32) -> Vec<f32> {
    (0..len)
        .map(|_| (rng.next_f32() * 2.0 - 1.0) * scale)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0);
        check("trivial", 10, |_| {
            count.set(count.get() + 1);
        });
        assert_eq!(count.get(), 10);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always-fails", 3, |_| panic!("boom"));
        });
        let msg = match result {
            Err(p) => p.downcast_ref::<String>().unwrap().clone(),
            Ok(_) => panic!("should have failed"),
        };
        assert!(msg.contains("always-fails"));
        assert!(msg.contains("seed"));
        assert!(msg.contains("boom"));
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..100 {
            let f = f64_in(&mut rng, -2.0, 3.0);
            assert!((-2.0..3.0).contains(&f));
            let u = usize_in(&mut rng, 5, 10);
            assert!((5..10).contains(&u));
        }
        let v = vec_f32(&mut rng, 32, 2.0);
        assert_eq!(v.len(), 32);
        assert!(v.iter().all(|&x| (-2.0..=2.0).contains(&x)));
    }
}
